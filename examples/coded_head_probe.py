"""CFL on a deep model: exact coded training of a linear readout head on
frozen-backbone features (the bridge between the paper's linear-regression
technique and the assigned architectures — see DESIGN.md §4).

A reduced granite-8b backbone embeds client token sequences; each client's
pooled features become its local regression dataset; the full CFL protocol
(redundancy optimization, private parity upload, deadline-clipped epochs)
then trains the head with the paper's guarantees.

    PYTHONPATH=src python examples/coded_head_probe.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.fed.coded_head import train_coded_head
from repro.models import transformer as T
from repro.sim.network import paper_fleet
from repro.api import coding_gain

N_CLIENTS, ELL, SEQ = 12, 64, 32


def main():
    cfg = get_config("granite-8b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    d_feat = cfg.d_model

    # each client holds raw token sequences
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (N_CLIENTS, ELL, SEQ), 0, cfg.vocab)

    # extract features once (frozen backbone, mean-pooled hidden states)
    def feats_one(client_toks):
        x = T._embed(cfg, params, client_toks, jnp.float32)
        positions = jnp.broadcast_to(jnp.arange(SEQ)[None, :],
                                     (client_toks.shape[0], SEQ))
        x, _ = T._run_backbone(cfg, params, x, positions, {})
        return jnp.mean(x, axis=1)  # (ell, d_model)

    feats = jax.vmap(feats_one)(toks)           # (n, ell, d)
    feats = feats / (jnp.std(feats) + 1e-6)

    # ground-truth head + noisy labels (linear probe target)
    beta_true = jax.random.normal(jax.random.PRNGKey(2), (d_feat,))
    ys = jnp.einsum("nld,d->nl", feats, beta_true) \
        + 0.1 * jax.random.normal(jax.random.PRNGKey(3), (N_CLIENTS, ELL))

    fleet = paper_fleet(0.2, 0.2, seed=0, n=N_CLIENTS, d=d_feat)
    out = train_coded_head(
        fleet, None, feats, ys, beta_true, lr=0.05, epochs=300,
        key=jax.random.PRNGKey(4), rng=np.random.default_rng(0),
        fixed_c=int(0.3 * N_CLIENTS * ELL))

    tgt = 5 * out["uncoded"].final_nmse()
    print(f"uncoded head: NMSE {out['uncoded'].final_nmse():.3e} "
          f"in {out['uncoded'].times[-1]:.0f}s")
    print(f"coded head:   NMSE {out['cfl'].final_nmse():.3e} "
          f"in {out['cfl'].times[-1]:.0f}s")
    print(f"coding gain (to NMSE {tgt:.1e}): "
          f"{coding_gain(out['uncoded'], out['cfl'], tgt):.2f}x")


if __name__ == "__main__":
    main()
