"""End-to-end driver: train the ~100M-parameter LM for a few hundred steps
with the straggler-aware federated substrate.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 50 --plain

Thin wrapper over the production launcher (repro.launch.train) so the
example exercises the same code path as the cluster entry point.
"""
import argparse
import sys

from repro.launch import train as launch_train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--plain", action="store_true",
                    help="plain data-parallel instead of federated")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    argv = ["--arch", "lm-100m", "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--log-every", "10"]
    if not args.plain:
        argv += ["--federated", "--n-clients", "8", "--nu", "0.2"]
    return launch_train.main(argv)


if __name__ == "__main__":
    sys.exit(main())
