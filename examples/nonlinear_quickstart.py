"""Non-linear quickstart: CodedFedL kernel classification end-to-end.

Builds a small multi-access-edge fleet, generates a classification
problem whose decision regions are genuinely non-linear (an RBF-network
teacher), maps it through CodedFedL's shared random-Fourier-feature map,
solves the MEC load allocation, and trains the coded one-vs-rest head —
then shows the head beating the best possible linear model on held-out
data.

    PYTHONPATH=src python examples/nonlinear_quickstart.py [--epochs 300]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Session, TrainData, make_strategy
from repro.data import classification_dataset, one_vs_rest_targets
from repro.sim.network import wireless_fleet

N, ELL, ELL_TEST, D_RAW, D_FEAT = 12, 100, 50, 6, 256
TEACHER_GAMMA = 2.0
LR = 0.5


def main(epochs: int = 300):
    print("=== CodedFedL non-linear quickstart ===")
    fleet = wireless_fleet(0.3, 0.3, nu_erasure=0.3, seed=0, n=N, d=D_FEAT)

    # non-linear classification data, split train / held-out per client
    xs, labels = classification_dataset(
        jax.random.PRNGKey(2), N, ELL + ELL_TEST, D_RAW,
        n_classes=2, centers=32, gamma=TEACHER_GAMMA)
    ys = one_vs_rest_targets(labels, 1)          # ±1 one-vs-rest targets
    xs_tr, xs_te = xs[:, :ELL], xs[:, ELL:]
    y_tr, y_te = ys[:, :ELL], np.asarray(ys[:, ELL:]).reshape(-1)

    # the sixth strategy: RFF kernel regression through the coded linear
    # machinery, planned under the MEC shifted-exponential delay model
    strategy = make_strategy("codedfedl", key_seed=7, d_feat=D_FEAT,
                             rff_gamma=TEACHER_GAMMA / D_RAW,
                             fixed_c=int(0.3 * N * ELL))

    # feature-space reference head (what the NMSE trace measures against)
    dummy = TrainData(xs=xs_tr, ys=y_tr, beta_true=jnp.zeros(D_FEAT))
    phi_tr = np.asarray(strategy.features(dummy),
                        np.float64).reshape(-1, D_FEAT)
    beta_ref, *_ = np.linalg.lstsq(
        phi_tr, np.asarray(y_tr, np.float64).reshape(-1), rcond=None)
    data = TrainData(xs=xs_tr, ys=y_tr,
                     beta_true=jnp.asarray(beta_ref, jnp.float32))

    state = strategy.plan(fleet, data)
    print(f"plan: c={state.plan.c} t*={state.plan.t_star:.2f}s "
          f"(MEC delay model, d_feat={D_FEAT})")

    report = Session(strategy=strategy, fleet=fleet, lr=LR,
                     epochs=epochs).run(data, rng=np.random.default_rng(0))

    # held-out accuracy of the trained head vs the best linear model
    phi_te = np.asarray(
        strategy.features(TrainData(xs=xs_te, ys=ys[:, ELL:],
                                    beta_true=jnp.zeros(D_FEAT))),
        np.float64).reshape(-1, D_FEAT)
    acc = np.mean((phi_te @ np.asarray(report.beta, np.float64) > 0)
                  == (y_te > 0))
    Xtr = np.asarray(xs_tr, np.float64).reshape(-1, D_RAW)
    Xte = np.asarray(xs_te, np.float64).reshape(-1, D_RAW)
    b_lin, *_ = np.linalg.lstsq(
        np.c_[Xtr, np.ones(len(Xtr))],
        np.asarray(y_tr, np.float64).reshape(-1), rcond=None)
    acc_lin = np.mean((np.c_[Xte, np.ones(len(Xte))] @ b_lin > 0)
                      == (y_te > 0))

    print(f"\ncoded kernel head: NMSE {report.final_nmse():.3f} to the "
          f"kernel regressor after {report.times[-1]:.0f}s simulated")
    print(f"held-out accuracy: kernel {acc:.3f} vs best-linear "
          f"{acc_lin:.3f}")
    assert acc > acc_lin, "kernel head should beat the linear ceiling"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=300)
    main(**vars(ap.parse_args()))
