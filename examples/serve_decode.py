"""Batched serving example: prefill + autoregressive decode with KV/SSM
caches across three architecture families (dense GQA, MoE, SSM).

    PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch import serve as launch_serve


def main():
    for arch in ["granite-8b", "phi3.5-moe-42b-a6.6b", "mamba2-1.3b"]:
        print(f"\n--- {arch} (reduced) ---")
        launch_serve.main(["--arch", arch, "--reduced", "--batch", "4",
                           "--prompt-len", "64", "--new-tokens", "16"])


if __name__ == "__main__":
    main()
