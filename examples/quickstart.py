"""Quickstart: Coded Federated Learning end-to-end in ~30 seconds.

Builds the paper's §IV setup (24 heterogeneous edge devices, linear
regression, d=500), runs the two-step redundancy optimization, trains with
CFL vs uncoded FL through the unified Strategy/Session API (one scan-jitted
epoch engine for both), and prints the coding gain.

    PYTHONPATH=src python examples/quickstart.py [--epochs 600]
"""
import argparse

import jax
import numpy as np

from repro.api import (CodedFL, Session, TrainData, UncodedFL, coding_gain,
                       convergence_time)
from repro.core.redundancy import solve_redundancy
from repro.sim.network import paper_fleet

N, ELL, D = 24, 300, 500
M = N * ELL
LR = 0.0085
TARGET = 1e-3


def main(epochs: int = 600):
    print("=== Coded Federated Learning quickstart ===")
    fleet = paper_fleet(nu_comp=0.2, nu_link=0.2, seed=0)
    data = TrainData.linreg(jax.random.PRNGKey(0), N, ELL, D)

    # Step 1-2: redundancy optimization (Eqs. 14-16)
    plan = solve_redundancy(fleet.edge, fleet.server, np.full(N, ELL),
                            fixed_c=int(0.28 * M))
    print(f"plan: c={plan.c} (delta={plan.delta:.2f}) t*={plan.t_star:.2f}s")
    print(f"per-device loads: {plan.loads.tolist()}")

    # baseline: synchronous uncoded FL (wait for every straggler)
    res_u = Session(strategy=UncodedFL(), fleet=fleet, lr=LR,
                    epochs=epochs).run(data, rng=np.random.default_rng(0))
    # CFL: parity upload once, then deadline-clipped epochs
    res_c = Session(strategy=CodedFL(key=jax.random.PRNGKey(1),
                                     fixed_c=plan.c,
                                     include_upload_delay=False),
                    fleet=fleet, lr=LR,
                    epochs=epochs).run(data, rng=np.random.default_rng(0))

    print(f"\nuncoded: NMSE {res_u.final_nmse():.2e} after "
          f"{res_u.times[-1]:.0f}s simulated")
    print(f"coded:   NMSE {res_c.final_nmse():.2e} after "
          f"{res_c.times[-1]:.0f}s simulated "
          f"(epoch deadline {plan.t_star:.1f}s)")
    g = coding_gain(res_u, res_c, TARGET)
    print(f"\ncoding gain to NMSE<={TARGET}: {g:.2f}x "
          f"(uncoded {convergence_time(res_u, TARGET):.0f}s vs "
          f"coded {convergence_time(res_c, TARGET):.0f}s)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=600,
                    help="training epochs (30 for a CI smoke run)")
    main(**vars(ap.parse_args()))
