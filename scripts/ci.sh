#!/usr/bin/env bash
# CI entry point: tier-1 tests + a 30-epoch quickstart smoke on the
# Strategy/Session API + a planner-latency budget check + a single-point
# sanity gate (plan latency, finite NMSE) for the repro.schemes strategies.
#
#   scripts/ci.sh [--perf]     # --perf additionally runs the full session
#                              # micro-benchmark incl. legacy baselines
#                              # (slower)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo
echo "== smoke: examples/quickstart.py --epochs 30 (new API) =="
python examples/quickstart.py --epochs 30

echo
echo "== smoke: planner latency budget (benchmarks/perf_session --smoke) =="
python -m benchmarks.perf_session --smoke

echo
echo "== smoke: new-scheme sanity (benchmarks/fig_schemes --smoke) =="
python -m benchmarks.fig_schemes --smoke

if [[ "${1:-}" == "--perf" ]]; then
    echo
    echo "== perf: planning + scan-jitted Session vs legacy =="
    python -m benchmarks.perf_session --epochs 200
fi

echo
echo "CI OK"
