#!/usr/bin/env bash
# CI entry point, split into named, individually timed, fail-fast stages.
#
#   scripts/ci.sh            # full tier: lint + tests + all smokes
#   scripts/ci.sh --fast     # lint + tier-1 tests only
#   scripts/ci.sh --perf     # full tier + the slow perf benchmark
#                            # (legacy baselines included)
#
# Stages (each reports its own wall time; the first failure stops the run
# and prints which stage died):
#
#   lint           ruff check + ruff format --check, both hard gates
#                  (pyproject.toml config; SKIPPED with a notice when
#                  ruff is absent — the GitHub workflow always installs
#                  it)
#   tests          tier-1 pytest (the ROADMAP verify command)
#   docs-check     executable-docs gate: every fenced python block in
#                  API.md and every examples/*.py runs (CI-budget args
#                  per file; scripts/check_docs.py) — subsumes the old
#                  quickstart smoke
#   perf-smoke     planner-latency budget gate  -> BENCH_perf.json
#   epoch-smoke    fused round-gradient path >= 1.3x reference
#                  epochs/sec on the §IV shapes (floor tunable via
#                  EPOCH_SMOKE_MIN_SPEEDUP)     -> BENCH_epoch.json
#   schemes-smoke  scheme sanity + plan budget  -> BENCH_schemes.json
#   nonlinear-smoke CodedFedL kernel head beats the equal-wall-clock
#                  uncoded run and the best linear model
#                                               -> BENCH_nonlinear.json
#   privacy-smoke  DP calibration + frontier    -> BENCH_privacy.json
#   sweep-smoke    batched sweep engine >= 3x   -> BENCH_sweep.json
#   serve-smoke    serving engine >= 2x sess/s  -> BENCH_serve.json
#   kernels-smoke  tuned tiles >= 1.2x default  -> BENCH_kernels.json
#                  (block="auto" vs hard-coded tiles at fleet scale;
#                  floor tunable via KERNELS_SMOKE_MIN_SPEEDUP)
#   fleet-smoke    100k-client sharded plan under wall budget, tiered
#                  encode tile-cache hit, budgeted-round sublinearity
#                                               -> BENCH_plan_scale.json
#   perf-trend     compares every BENCH_*.json metric against the
#                  previous run's artifacts in $PERF_BASELINE_DIR
#                  (downloaded by ci.yml; SKIPPED with a notice when
#                  absent — e.g. first run or local dev box).  Bands:
#                  PERF_TREND_TOL / PERF_TREND_GATE_TOL / PERF_TREND_SKIP.
#   perf-full      (--perf only) full session micro-benchmark
#
# The BENCH_*.json artifacts are machine-readable (timings + gate
# values); .github/workflows/ci.yml uploads them AND feeds the previous
# run's copies back in, so the perf trajectory is a hard gate across
# PRs, not just a tracked artifact.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

TIER="full"
case "${1:-}" in
    --fast) TIER="fast" ;;
    --perf) TIER="perf" ;;
    "") ;;
    *) echo "usage: scripts/ci.sh [--fast|--perf]" >&2; exit 2 ;;
esac

declare -a STAGE_SUMMARY=()

summary() {
    echo
    echo "== stage summary =="
    local line
    for line in "${STAGE_SUMMARY[@]}"; do
        echo "  $line"
    done
}

run_stage() {
    local name="$1"; shift
    echo
    echo "== stage: $name =="
    local t0=$SECONDS
    if "$@"; then
        STAGE_SUMMARY+=("$name: OK ($((SECONDS - t0))s)")
    else
        local code=$?
        STAGE_SUMMARY+=("$name: FAILED exit $code ($((SECONDS - t0))s)")
        echo
        echo "-- stage FAILED: $name (exit $code)" >&2
        summary
        exit "$code"
    fi
}

lint() {
    if ! command -v ruff >/dev/null 2>&1; then
        echo "SKIP: ruff not installed (pip install -r" \
             "requirements-dev.txt); the GitHub workflow runs this gate"
        return 0
    fi
    ruff check .
    # hard gate since the tree-wide format migration: run `ruff format .`
    # before committing when this trips
    ruff format --check .
}

perf_trend() {
    local dir="${PERF_BASELINE_DIR:-}"
    if [[ -z "$dir" || ! -d "$dir" ]] || \
            [[ -z "$(find "$dir" -name 'BENCH_*.json' -print -quit)" ]]
    then
        echo "SKIP: no baseline artifacts (PERF_BASELINE_DIR='${dir}');" \
             "first run or local dev box"
        return 0
    fi
    python -m benchmarks.perf_trend --baseline-dir "$dir" --new-dir .
}

run_stage lint lint
run_stage tests python -m pytest -x -q

if [[ "$TIER" != "fast" ]]; then
    run_stage docs-check python scripts/check_docs.py
    run_stage perf-smoke python -m benchmarks.perf_session --smoke
    run_stage epoch-smoke python -m benchmarks.perf_session --smoke --epoch
    run_stage schemes-smoke python -m benchmarks.fig_schemes --smoke
    run_stage nonlinear-smoke python -m benchmarks.fig_nonlinear --smoke
    run_stage privacy-smoke python -m benchmarks.fig_privacy --smoke
    run_stage sweep-smoke python -m benchmarks.perf_sweep --smoke
    run_stage serve-smoke python -m benchmarks.perf_serve --smoke
    run_stage kernels-smoke python -m benchmarks.kernels --smoke
    run_stage fleet-smoke python -m benchmarks.perf_fleet --smoke
    run_stage perf-trend perf_trend
fi

if [[ "$TIER" == "perf" ]]; then
    run_stage perf-full python -m benchmarks.perf_session --epochs 200
fi

summary
echo
echo "CI OK ($TIER tier)"
