"""Executable-docs gate: every fenced python block in API.md and every
script in examples/ must actually run.

Docs rot silently — an API rename leaves the prose compiling in the
reader's head and crashing in their shell.  This gate extracts each
fenced ```python block from the documentation, writes it to a temp file,
and executes it in a fresh subprocess with PYTHONPATH=src from the repo
root; examples run the same way with per-file CI-budget arguments.

A block is SKIPPED (reported, never executed) when either:

  * its info string carries the ``no-run`` marker (```python no-run), or
  * it contains a ``...`` placeholder — the doc idiom for "elided";
    such blocks are illustrative shapes, not programs.

Usage:
    python scripts/check_docs.py [--timeout 600] [--only api|examples]
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = ("API.md",)

# Per-file CI-budget arguments.  Entries missing from this table run with
# no arguments (and are flagged, so new examples get a deliberate entry).
EXAMPLE_ARGS: dict[str, list[str]] = {
    "quickstart.py": ["--epochs", "30"],
    "nonlinear_quickstart.py": ["--epochs", "60"],
    "coded_head_probe.py": [],
    # model-scale examples at their smallest runnable settings (~40s/~20s)
    "train_lm.py": ["--steps", "2", "--batch", "2", "--seq", "64"],
    "serve_decode.py": [],
}

_FENCE = re.compile(
    r"^```python([^\n]*)\n(.*?)^```\s*$", re.MULTILINE | re.DOTALL)


def extract_blocks(text: str) -> list[tuple[int, str, str]]:
    """(line number, info string, code) for every fenced python block."""
    out = []
    for m in _FENCE.finditer(text):
        line = text.count("\n", 0, m.start()) + 1
        out.append((line, m.group(1).strip(), m.group(2)))
    return out


def should_skip(info: str, code: str) -> str | None:
    """Reason string if the block is non-executable by contract."""
    if "no-run" in info:
        return "no-run marker"
    if "..." in code:
        return "contains ... placeholder"
    return None


def _run(cmd: list[str], timeout: float) -> tuple[bool, float, str]:
    env = dict(os.environ, PYTHONPATH="src")
    t0 = time.perf_counter()
    try:
        proc = subprocess.run(cmd, cwd=REPO, env=env, timeout=timeout,
                              capture_output=True, text=True)
    except subprocess.TimeoutExpired:
        return False, time.perf_counter() - t0, f"TIMEOUT after {timeout}s"
    dt = time.perf_counter() - t0
    if proc.returncode != 0:
        tail = "\n".join((proc.stderr or proc.stdout).splitlines()[-15:])
        return False, dt, tail
    return True, dt, ""


def check_doc_blocks(timeout: float) -> list[str]:
    failures: list[str] = []
    for doc in DOC_FILES:
        path = os.path.join(REPO, doc)
        with open(path) as fh:
            blocks = extract_blocks(fh.read())
        if not blocks:
            failures.append(f"{doc}: no fenced python blocks found "
                            f"(extraction broken or docs gutted?)")
            continue
        for line, info, code in blocks:
            name = f"{doc}:{line}"
            reason = should_skip(info, code)
            if reason:
                print(f"  SKIP {name} ({reason})")
                continue
            with tempfile.NamedTemporaryFile(
                    "w", suffix=".py", delete=False) as tf:
                tf.write(code)
                tmp = tf.name
            try:
                ok, dt, err = _run([sys.executable, tmp], timeout)
            finally:
                os.unlink(tmp)
            print(f"  {'PASS' if ok else 'FAIL'} {name} ({dt:.1f}s)")
            if not ok:
                failures.append(f"{name}:\n{err}")
    return failures


def check_examples(timeout: float) -> list[str]:
    failures: list[str] = []
    ex_dir = os.path.join(REPO, "examples")
    for fname in sorted(os.listdir(ex_dir)):
        if not fname.endswith(".py"):
            continue
        args = EXAMPLE_ARGS.get(fname)
        if args is None:
            print(f"  NOTE examples/{fname} missing from EXAMPLE_ARGS — "
                  f"running with no arguments; add a deliberate entry")
            args = []
        ok, dt, err = _run(
            [sys.executable, os.path.join("examples", fname), *args],
            timeout)
        print(f"  {'PASS' if ok else 'FAIL'} examples/{fname} ({dt:.1f}s)")
        if not ok:
            failures.append(f"examples/{fname}:\n{err}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python scripts/check_docs.py")
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="per-block/per-example wall budget (s)")
    ap.add_argument("--only", choices=("api", "examples"), default=None)
    args = ap.parse_args(argv)

    failures: list[str] = []
    if args.only in (None, "api"):
        print("== fenced python blocks ==")
        failures += check_doc_blocks(args.timeout)
    if args.only in (None, "examples"):
        print("== examples/ ==")
        failures += check_examples(args.timeout)

    if failures:
        print(f"\ndocs-check FAILED — {len(failures)} item(s):",
              file=sys.stderr)
        for f in failures:
            print(f"\n--- {f}", file=sys.stderr)
        return 1
    print("\ndocs-check OK: every executable doc block and example runs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
