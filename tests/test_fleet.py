"""Tests for the hierarchical fleet layer (`repro.fleet`).

The load-bearing guarantees:

  * tier equivalence — a `HierarchicalCFL` round over a SINGLE all-client
    tier is bit-for-bit the wrapped strategy's flat round (full-width
    masked contraction: masking adds exact +-0.0 terms), for all five
    built-in strategies; a multi-tier partition reassociates ONLY the
    T-term cross-tier combine, so traces agree to float ulp, never more;
  * degenerate subsampling — `sample_frac == 1` draws NO extra
    randomness: the wrapped strategy's generator stream is preserved
    exactly;
  * plan parity — `solve_fleet` (sharded + chunk-streamed) reproduces
    `solve_redundancy_batched`'s loads/c on the paper fleet, with t*
    within the grid-refinement tolerance (NOT bit-for-bit: aggregate
    reassociation is a documented invariant);
  * tiered encode — `encode_fleet_tiered` over one tier is bit-identical
    to the flat in-kernel-PRNG pass (same key table, same scan order).
"""
import jax
import numpy as np
import pytest

from repro.api import Session, TrainData, make_strategy, run_sweep
from repro.core.delay_model import DeviceDelayParams
from repro.fleet import (FleetTopology, HierarchicalCFL,
                         encode_fleet_tiered, sample_tier_rounds,
                         solve_fleet)
from repro.kernels.encode import ops as encode_ops
from repro.plan.solver import PlanRequest, solve_redundancy_batched
from repro.sim.network import mega_fleet, paper_fleet, wireless_fleet

EPOCHS = 12
LR = 0.05
N, ELL, D = 12, 60, 40
STRATEGIES = ["uncoded", "cfl", "gradcode", "stochastic", "lowlatency"]


@pytest.fixture(scope="module")
def small():
    fleet = paper_fleet(0.2, 0.2, seed=1, n=N, d=D)
    wfleet = wireless_fleet(0.2, 0.2, nu_erasure=0.3, seed=0, n=N, d=D)
    data = TrainData.linreg(jax.random.PRNGKey(0), n=N, ell=ELL, d=D)
    return fleet, wfleet, data


def _base_for(name: str, data, epochs: int = EPOCHS):
    """One base strategy per scheme + which fleet it trains on."""
    c = int(0.3 * data.m)
    if name == "uncoded":
        return make_strategy("uncoded"), "paper"
    if name == "cfl":
        return make_strategy("cfl", key_seed=7, fixed_c=c), "paper"
    if name == "gradcode":
        return make_strategy("gradcode", r=3), "paper"
    if name == "stochastic":
        return make_strategy("stochastic", key_seed=7, fixed_c=c,
                             noise_multiplier=0.5, sample_frac=0.8,
                             rounds=epochs), "wireless"
    if name == "lowlatency":
        return make_strategy("lowlatency", key_seed=7, fixed_c=c,
                             chunks=4), "wireless"
    raise ValueError(name)


def _run_pair(name, small, topology):
    """(base report, hierarchical report) on identical data/fleet/seed."""
    fleet, wfleet, data = small
    base, which = _base_for(name, data)
    flt = fleet if which == "paper" else wfleet
    solo = Session(strategy=base, fleet=flt, lr=LR, epochs=EPOCHS,
                   seed=3).run(data, rng=np.random.default_rng(3))
    hier = make_strategy("hierarchical", base=base, topology=topology)
    rep = Session(strategy=hier, fleet=flt, lr=LR, epochs=EPOCHS,
                  seed=3).run(data, rng=np.random.default_rng(3))
    return solo, rep


# ---------------------------------------------------------------------------
# tier equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", STRATEGIES)
def test_single_tier_is_bit_exact(name, small):
    """One all-client tier: the hierarchy is the flat round bit-for-bit
    (mask of ones multiplies exactly, the 1-term combine is identity)."""
    solo, rep = _run_pair(name, small, FleetTopology.uniform(N, 1))
    np.testing.assert_array_equal(rep.nmse, solo.nmse)
    np.testing.assert_array_equal(rep.times, solo.times)
    np.testing.assert_array_equal(rep.epoch_durations,
                                  solo.epoch_durations)
    assert rep.label == f"hier[{solo.label}]"


@pytest.mark.parametrize("name", STRATEGIES)
def test_permuted_tiers_match_to_ulp(name, small):
    """A permuted 3-tier partition reassociates only the cross-tier sum:
    traces track the flat run to float tolerance across training."""
    rng = np.random.default_rng(11)
    tier_of = rng.permutation(np.arange(N) % 3)
    topo = FleetTopology.from_assignment(tier_of)
    solo, rep = _run_pair(name, small, topo)
    np.testing.assert_allclose(rep.nmse, solo.nmse, rtol=1e-3, atol=1e-6)
    # durations never touch the gradient path: identical draws, identical
    # clocks
    np.testing.assert_array_equal(rep.epoch_durations,
                                  solo.epoch_durations)


def test_full_participation_preserves_generator_stream(small):
    """sample_frac == 1 everywhere: NO gate draws — the wrapped
    strategy's stream (and the caller's rng position) is untouched."""
    fleet, _, data = small
    base, _ = _base_for("cfl", data)
    hier = make_strategy("hierarchical", base=base,
                         topology=FleetTopology.uniform(N, 3))
    state = hier.plan(fleet, data)

    rng_h, rng_b = np.random.default_rng(5), np.random.default_rng(5)
    sched_h = hier.sample_epochs(state, fleet, EPOCHS, rng_h)
    sched_b = base.sample_epochs(state.base, fleet, EPOCHS, rng_b)
    for key, val in sched_b.arrivals.items():
        np.testing.assert_array_equal(sched_h.arrivals[key], val)
    np.testing.assert_array_equal(sched_h.arrivals["tier_gate"],
                                  np.ones((EPOCHS, N), dtype=np.float32))
    assert rng_h.standard_normal() == rng_b.standard_normal()


def test_subsampled_gates_are_unbiased_and_training_converges(small):
    fleet, _, data = small
    topo = FleetTopology.uniform(N, 3, sample_frac=0.5)
    gates = topo.sample_gates(4000, np.random.default_rng(0))
    assert gates.shape == (4000, N)
    # gate in {0, 1/frac}: E[gate] == 1 per client
    np.testing.assert_allclose(gates.mean(axis=0), 1.0, atol=0.06)

    base, _ = _base_for("cfl", data)
    hier = make_strategy("hierarchical", base=base, topology=topo)
    rep = Session(strategy=hier, fleet=fleet, lr=LR, epochs=30,
                  seed=1).run(data, rng=np.random.default_rng(1))
    assert rep.nmse[-1] < 0.5 * rep.nmse[0]
    assert rep.extras["n_tiers"] == 3
    assert rep.extras["expected_participants"] == pytest.approx(N * 0.5)


def test_hierarchical_runs_through_run_sweep(small):
    """Sweep lanes over the wrapper equal fresh solo runs bit-for-bit
    (the run_sweep contract, now including the stacked gate tensor)."""
    fleet, _, data = small
    topo = FleetTopology.uniform(N, 3, sample_frac=0.8)
    sessions = [
        Session(strategy=make_strategy(
            "hierarchical",
            base=make_strategy("cfl", key_seed=7,
                               fixed_c=int(0.3 * data.m)),
            topology=topo), fleet=fleet, lr=lr, epochs=EPOCHS, seed=s)
        for s, lr in ((0, 0.05), (1, 0.03))]
    reports = run_sweep(sessions, data)
    for sess, rep in zip(sessions, reports):
        solo = sess.run(data, rng=np.random.default_rng(sess.seed))
        np.testing.assert_array_equal(rep.nmse, solo.nmse)
        np.testing.assert_array_equal(rep.epoch_durations,
                                      solo.epoch_durations)


def test_engine_keys_separate_topologies(small):
    fleet, _, data = small
    base, _ = _base_for("cfl", data)
    h2 = HierarchicalCFL(base=base, topology=FleetTopology.uniform(N, 2))
    h3 = HierarchicalCFL(base=base, topology=FleetTopology.uniform(N, 3))
    k2 = h2.engine_key(h2.plan(fleet, data))
    k3 = h3.engine_key(h3.plan(fleet, data))
    assert k2 != k3
    # participation values gate operands only — same compiled engine
    h3f = HierarchicalCFL(
        base=base, topology=FleetTopology.uniform(N, 3, sample_frac=0.5))
    assert h3f.engine_key(h3f.plan(fleet, data)) == k3


# ---------------------------------------------------------------------------
# topology + registry
# ---------------------------------------------------------------------------

def test_topology_validation():
    with pytest.raises(ValueError, match="non-empty"):
        FleetTopology(tier_of=np.array([], dtype=np.int32),
                      sample_frac=np.array([1.0]))
    with pytest.raises(ValueError, match="dense"):
        FleetTopology(tier_of=np.array([0, 2]),
                      sample_frac=np.array([1.0, 1.0]))
    with pytest.raises(ValueError, match="empty tiers"):
        FleetTopology(tier_of=np.array([0, 0, 2, 2]),
                      sample_frac=np.array([1.0, 1.0, 1.0]))
    with pytest.raises(ValueError, match="sample_frac"):
        FleetTopology.uniform(4, 2, sample_frac=0.0)
    with pytest.raises(ValueError, match="n_tiers"):
        FleetTopology.uniform(4, 5)
    with pytest.raises(ValueError, match="budget"):
        FleetTopology.uniform(4, 2).with_round_budget(0)

    topo = FleetTopology.uniform(10, 3)
    assert topo.n == 10 and topo.n_tiers == 3 and not topo.subsampled
    members = topo.tier_members()
    assert sorted(np.concatenate(members).tolist()) == list(range(10))
    assert all(np.all(np.diff(m) > 0) for m in members)
    capped = topo.with_round_budget(5)
    np.testing.assert_allclose(capped.sample_frac, 0.5)
    assert capped.subsampled and capped.structure_key() == (10, 3)


def test_registry_constructs_hierarchical(small):
    _, _, data = small
    topo = FleetTopology.uniform(N, 3)
    for name in ("hierarchical", "hier", "fleet"):
        strat = make_strategy(name, base=make_strategy("uncoded"),
                              topology=topo)
        assert isinstance(strat, HierarchicalCFL)
        assert strat.label.startswith("hier[")

    class NoHook:
        label = "nohook"

    with pytest.raises(TypeError, match="tiered_contributions"):
        make_strategy("hierarchical", base=NoHook(), topology=topo)
    with pytest.raises(TypeError, match="FleetTopology"):
        make_strategy("hierarchical", base=make_strategy("uncoded"),
                      topology="3 tiers please")


def test_topology_fleet_size_mismatch(small):
    fleet, _, data = small
    hier = make_strategy("hierarchical", base=make_strategy("uncoded"),
                         topology=FleetTopology.uniform(N + 1, 2))
    with pytest.raises(ValueError, match="topology covers"):
        hier.plan(fleet, data)


# ---------------------------------------------------------------------------
# sharded fleet planning
# ---------------------------------------------------------------------------

def _paper_request(**kw):
    fleet = paper_fleet(0.2, 0.2, seed=0, n=24, d=40)
    rng = np.random.default_rng(2)
    data_sizes = rng.integers(40, 81, size=24)
    return PlanRequest(edge=fleet.edge, server=fleet.server,
                       data_sizes=data_sizes, **kw)


def test_solve_fleet_matches_batched_solver():
    req = _paper_request(c_up=400)
    batched = solve_redundancy_batched([req], eps_rel=1e-6)[0]
    sharded = solve_fleet(req, eps_rel=1e-6)
    np.testing.assert_array_equal(sharded.loads, batched.loads)
    assert sharded.c == batched.c
    assert sharded.t_star == pytest.approx(batched.t_star, rel=1e-4)
    np.testing.assert_allclose(sharded.p_return, batched.p_return,
                               rtol=1e-6, atol=1e-9)
    assert sharded.expected_agg >= req.m * (1.0 - 1e-9)


def test_solve_fleet_weighted_partial_objectives():
    """srv_weight + edge_chunks flow through the sharded evaluator."""
    req = _paper_request(srv_weight=0.5, edge_chunks=4, fixed_c=64)
    batched = solve_redundancy_batched([req], eps_rel=1e-6)[0]
    sharded = solve_fleet(req, eps_rel=1e-6)
    np.testing.assert_array_equal(sharded.loads, batched.loads)
    assert sharded.c == batched.c == 64
    assert sharded.t_star == pytest.approx(batched.t_star, rel=1e-4)


def test_solve_fleet_scales_past_the_oracle_ceiling():
    """A fleet far beyond the reference oracle's n ceiling plans fine
    (chunk-streamed), and the plan respects every device cap."""
    fleet = mega_fleet(20_000, d=16, seed=0)
    rng = np.random.default_rng(1)
    data_sizes = rng.integers(2, 9, size=20_000)
    req = PlanRequest(edge=fleet.edge, server=fleet.server,
                      data_sizes=data_sizes, c_up=256)
    plan = solve_fleet(req, eps_rel=1e-2)
    assert plan.loads.shape == (20_000,)
    assert np.all(plan.loads <= data_sizes)
    assert plan.expected_agg >= req.m * (1.0 - 1e-6)


# ---------------------------------------------------------------------------
# reference-oracle guards
# ---------------------------------------------------------------------------

def test_reference_oracle_rejects_fleet_scale():
    from repro.plan.reference import (_MAX_ORACLE_N, _oracle_chunk,
                                      optimal_loads_loop)
    n = _MAX_ORACLE_N + 1
    params = DeviceDelayParams(a=np.ones(n), mu=np.ones(n),
                               tau=np.zeros(n), p=np.zeros(n))
    with pytest.raises(ValueError, match="solve_fleet"):
        optimal_loads_loop(params, np.full(n, 4), t=1.0)
    # the adaptive chunk keeps the (chunk, width) stack bounded
    assert _oracle_chunk(16, 4096) == 4096
    assert _oracle_chunk(4096, 4096, width=2 ** 22) == 4
    assert _oracle_chunk(4096, 4096, width=2 ** 26) == 1


def test_reference_oracle_chunking_is_equivalent():
    """Chunk boundaries never change the argmax (guard regression)."""
    from repro.plan.reference import optimal_loads_loop
    fleet = paper_fleet(0.2, 0.2, seed=3, n=6, d=20)
    caps = np.array([5, 9, 13, 7, 11, 8])
    t = float(np.max(fleet.edge.mean_total(caps)))
    loads_a, vals_a = optimal_loads_loop(fleet.edge, caps, t, chunk=3)
    loads_b, vals_b = optimal_loads_loop(fleet.edge, caps, t, chunk=4096)
    np.testing.assert_array_equal(loads_a, loads_b)
    np.testing.assert_array_equal(vals_a, vals_b)


def test_partial_reference_oracle_guard():
    from repro.plan.reference import _MAX_ORACLE_N
    from repro.plan.reference_schemes import optimal_loads_partial_loop
    n = _MAX_ORACLE_N + 1
    params = DeviceDelayParams(a=np.ones(n), mu=np.ones(n),
                               tau=np.zeros(n), p=np.zeros(n))
    with pytest.raises(ValueError, match="solve_fleet"):
        optimal_loads_partial_loop(params, np.full(n, 4), 1.0, chunks=4)


# ---------------------------------------------------------------------------
# tiered streamed encoding
# ---------------------------------------------------------------------------

def _encode_problem(n=6, ell=5, d=8):
    key = jax.random.PRNGKey(9)
    kx, ky, kw, kf = jax.random.split(key, 4)
    xs = jax.random.normal(kx, (n, ell, d))
    ys = jax.random.normal(ky, (n, ell))
    weights = jax.random.uniform(kw, (n, ell), minval=0.5, maxval=1.5)
    return kf, xs, ys, weights


def test_encode_tiered_single_tier_is_bit_identical():
    kf, xs, ys, weights = _encode_problem()
    c = 4
    x_flat, y_flat = encode_ops.encode_fleet_prng(kf, xs, ys, weights, c)
    x_t, y_t = encode_fleet_tiered(kf, xs, ys, weights, c,
                                   FleetTopology.uniform(6, 1))
    np.testing.assert_array_equal(np.asarray(x_t), np.asarray(x_flat))
    np.testing.assert_array_equal(np.asarray(y_t), np.asarray(y_flat))


def test_encode_tiered_partition_matches_to_ulp():
    kf, xs, ys, weights = _encode_problem()
    c = 4
    x_flat, y_flat = encode_ops.encode_fleet_prng(kf, xs, ys, weights, c)
    topo = FleetTopology.from_assignment(np.array([2, 0, 1, 0, 2, 1]))
    x_t, y_t = encode_fleet_tiered(kf, xs, ys, weights, c, topo)
    np.testing.assert_allclose(np.asarray(x_t), np.asarray(x_flat),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_flat),
                               rtol=1e-5, atol=1e-6)


def test_encode_tiered_validates_fleet_size():
    kf, xs, ys, weights = _encode_problem()
    with pytest.raises(ValueError, match="topology covers"):
        encode_fleet_tiered(kf, xs, ys, weights, 4,
                            FleetTopology.uniform(7, 2))


# ---------------------------------------------------------------------------
# fleet generation + O(participants) round scheduling
# ---------------------------------------------------------------------------

def test_mega_fleet_stays_finite():
    """The tiled ladder never underflows — every device keeps a positive,
    finite rate at sizes where the raw §IV ladder is denormal."""
    fleet = mega_fleet(5000, d=16, seed=0)
    for vec in (fleet.edge.a, fleet.edge.mu, fleet.edge.tau):
        assert np.all(np.isfinite(vec)) and np.all(vec > 0)
    # bounded heterogeneity: the ladder spans at most the §IV 24 rungs
    spread = fleet.edge.a.max() / fleet.edge.a.min()
    assert spread <= (1.0 / 0.8) ** 23 * 1.0001
    with pytest.raises(TypeError, match="unexpected"):
        mega_fleet(100, nonsense_knob=3)


def test_sample_tier_rounds_budget_and_shapes():
    n, budget, epochs = 3000, 100, 6
    fleet = mega_fleet(n, d=16, seed=0)
    topo = FleetTopology.uniform(n, 8).with_round_budget(budget)
    rng = np.random.default_rng(4)
    stats = sample_tier_rounds(topo, fleet.edge, np.full(n, 5), epochs,
                               rng)
    assert stats.durations.shape == (epochs,)
    assert stats.tier_max.shape == (epochs, 8)
    assert stats.participants.shape == (epochs, 8)
    assert np.all(stats.durations >= stats.tier_max.max(axis=1) - 1e-12)
    # expected participants per epoch == budget; allow generous slack
    per_epoch = stats.participants.sum(axis=1)
    assert 0.3 * budget < per_epoch.mean() < 3 * budget


def test_sample_tier_rounds_full_participation_and_validation():
    n = 30
    fleet = mega_fleet(n, d=16, seed=1)
    topo = FleetTopology.uniform(n, 3)
    stats = sample_tier_rounds(topo, fleet.edge, np.full(n, 4), 3,
                               np.random.default_rng(0))
    np.testing.assert_array_equal(stats.participants,
                                  np.full((3, 3), 10))
    assert np.all(stats.durations > 0)

    with pytest.raises(ValueError, match="loads"):
        sample_tier_rounds(topo, fleet.edge, np.full(n + 1, 4), 3,
                           np.random.default_rng(0))
    other = mega_fleet(n + 1, d=16, seed=1)
    with pytest.raises(ValueError, match="edge params"):
        sample_tier_rounds(topo, other.edge, np.full(n, 4), 3,
                           np.random.default_rng(0))
