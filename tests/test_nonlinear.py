"""Tests for the non-linear layer: `CodedFedL` (arXiv:2007.03273), the
RFF feature map, and the MEC delay objective in the batched planner.

Three layers of guarantees, mirroring `tests/test_schemes.py`:

  * construction parity — `rff_map` matches its float64 NumPy oracle and
    approximates the Gaussian kernel; the MEC grid objective reproduces
    the scalar oracle in `plan/reference_schemes.py` (loads identical,
    t* within 1e-3 rel — both sides solved at eps_rel=1e-4, since at the
    default grid resolution interior loads can shift by one purely from
    t* rounding);
  * degenerate equivalence — `CodedFedL(d_feat=None)` IS `CodedFL`
    bit-for-bit from the same key (identity feature map, base delay
    model, same plan group);
  * composition — the strategy runs unmodified under `Session`,
    `run_sweep` (lanes bit-equal to solo), the serving engine (prefix
    parity), and `HierarchicalCFL` (single-tier exactness).

Plus the executable-docs gate's extraction unit tests (`scripts/
check_docs.py` is a CI stage; its block parser is load-bearing).
"""
import importlib.util
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a deterministic fallback

from benchmarks.perf_trend import classify
from repro.api import Session, TrainData, make_strategy, run_sweep
from repro.core.delay_model import mec_total_cdf, sample_total_mec
from repro.data import (classification_dataset, one_vs_rest_targets,
                        rff_map, rff_map_reference)
from repro.fleet import FleetTopology
from repro.plan import PlanRequest, solve_redundancy_batched
from repro.plan.reference_schemes import solve_codedfedl_reference
from repro.schemes import CodedFedL
from repro.serving import ConvergenceCriterion, FedServeEngine
from repro.sim.network import wireless_fleet

from test_schemes import _random_fleet

N, ELL, D_RAW, D_FEAT = 12, 60, 6, 32
LR = 0.3
EPOCHS = 40


@pytest.fixture(scope="module")
def kernel_small():
    """Classification fixture: wireless fleet + RFF-space reference head."""
    fleet = wireless_fleet(0.2, 0.2, nu_erasure=0.3, seed=0, n=N, d=D_FEAT)
    xs, labels = classification_dataset(jax.random.PRNGKey(2), N, ELL, D_RAW,
                                        n_classes=2, centers=16, gamma=2.0)
    ys = one_vs_rest_targets(labels, 1)
    strat = make_strategy("codedfedl", key_seed=7, d_feat=D_FEAT,
                          rff_gamma=2.0 / D_RAW, fixed_c=int(0.3 * N * ELL))
    phi = np.asarray(strat.features(TrainData(
        xs=xs, ys=ys, beta_true=jnp.zeros(D_FEAT))), np.float64)
    beta_ref, *_ = np.linalg.lstsq(phi.reshape(-1, D_FEAT),
                                   np.asarray(ys, np.float64).ravel(),
                                   rcond=None)
    data = TrainData(xs=xs, ys=ys,
                     beta_true=jnp.asarray(beta_ref, jnp.float32))
    return fleet, data, strat


@pytest.fixture(scope="module")
def linreg_small():
    """Linear fixture where d_raw == d_feat, so CodedFL and kernel-mode
    CodedFedL train the same model width from the same TrainData."""
    fleet = wireless_fleet(0.2, 0.2, nu_erasure=0.3, seed=0, n=N, d=40)
    data = TrainData.linreg(jax.random.PRNGKey(0), n=N, ell=ELL, d=40)
    return fleet, data


# ---------------------------------------------------------------------------
# the RFF feature map
# ---------------------------------------------------------------------------

def test_rff_map_deterministic_and_shaped():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 5, D_RAW))
    key = jax.random.PRNGKey(1)
    z1 = rff_map(x, D_FEAT, key, gamma=0.7)
    z2 = rff_map(x, D_FEAT, key, gamma=0.7)
    assert z1.shape == (3, 5, D_FEAT)
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))
    z3 = rff_map(x, D_FEAT, jax.random.PRNGKey(2), gamma=0.7)
    assert np.abs(np.asarray(z1) - np.asarray(z3)).max() > 1e-3
    # unit diagonal: z(x).z(x) = (2/D) * sum(cos^2 + sin^2) = 1 exactly
    np.testing.assert_allclose(
        np.sum(np.asarray(z1, np.float64) ** 2, axis=-1), 1.0, rtol=1e-5)


def test_rff_map_matches_float64_oracle():
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (40, D_RAW)))
    key = jax.random.PRNGKey(4)
    got = np.asarray(rff_map(x, D_FEAT, key, gamma=1.3), np.float64)
    ref = rff_map_reference(x, D_FEAT, key, gamma=1.3)
    np.testing.assert_allclose(got, ref, atol=5e-6)


def test_rff_inner_products_approximate_gaussian_kernel():
    d_feat = 4096
    gamma = 0.5
    u, v = np.asarray(jax.random.normal(jax.random.PRNGKey(5), (2, 8, 4)))
    zu = rff_map_reference(u, d_feat, jax.random.PRNGKey(6), gamma=gamma)
    zv = rff_map_reference(v, d_feat, jax.random.PRNGKey(6), gamma=gamma)
    approx = np.sum(zu * zv, axis=-1)
    exact = np.exp(-gamma * np.sum((u - v) ** 2, axis=-1))
    # error ~ 1/sqrt(d_feat); 0.05 is ~3 sigma at 4096 features
    np.testing.assert_allclose(approx, exact, atol=0.05)


def test_rff_map_validates_feature_count():
    x = np.zeros((2, 3))
    with pytest.raises(ValueError, match="even"):
        rff_map(x, 7, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="even"):
        rff_map(x, 0, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="even"):
        CodedFedL(key=jax.random.PRNGKey(0), d_feat=9)


# ---------------------------------------------------------------------------
# the MEC delay model + planner objective
# ---------------------------------------------------------------------------

def test_mec_cdf_monotone_bounded_and_shifted():
    edge, _ = _random_fleet(np.random.default_rng(7), 6)
    ell = np.array([10.0, 25.0, 0.0, 15.0, 30.0, 8.0])
    ts = np.linspace(0.0, 20.0, 60)
    prev = np.zeros(6)
    for t in ts:
        cur = mec_total_cdf(edge, ell, t)
        assert np.all((cur >= 0.0) & (cur <= 1.0))
        assert np.all(cur >= prev - 1e-12)       # monotone in t
        prev = cur
    # nothing returns before the deterministic shift (compute floor
    # a*ell plus two uplink slots)
    shift = edge.a * ell + 2.0 * edge.tau
    t_lo = 0.5 * shift[np.nonzero(ell)].min()
    early = mec_total_cdf(edge, ell, t_lo)
    assert np.all(early[np.nonzero(ell)] == 0.0)
    # a zero-load device has nothing to compute or send: done at t >= 0
    assert early[2] == 1.0


def test_mec_sampler_matches_cdf():
    edge, _ = _random_fleet(np.random.default_rng(11), 4)
    ell = np.array([12.0, 30.0, 20.0, 6.0])
    rng = np.random.default_rng(0)
    draws = np.stack([sample_total_mec(edge, ell, rng)
                      for _ in range(4000)])          # (trials, n)
    for t in (np.quantile(draws, 0.3), np.quantile(draws, 0.7)):
        emp = (draws <= t).mean(axis=0)
        np.testing.assert_allclose(emp, mec_total_cdf(edge, ell, t),
                                   atol=0.03)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(2, 8), ell=st.integers(8, 60),
       mode=st.sampled_from(["free", "fixed"]), seed=st.integers(0, 10**6))
def test_mec_objective_matches_reference(n, ell, mode, seed):
    """MEC grid solve == scalar oracle (loads exact, t* 1e-3) — both at
    eps_rel=1e-4 so interior loads can't shift from t* rounding."""
    rng = np.random.default_rng(seed)
    edge, server = _random_fleet(rng, n)
    sizes = rng.integers(ell // 2 + 1, ell + 1, size=n)
    m = int(sizes.sum())
    kw = {"fixed_c": int(rng.integers(m // 10 + 1, m + 1))} \
        if mode == "fixed" else \
        {"c_up": int(rng.integers(m // 10 + 1, m + 1))}
    ref = solve_codedfedl_reference(edge, server, sizes, eps_rel=1e-4, **kw)
    new = solve_redundancy_batched(
        [PlanRequest(edge, server, sizes, mec_comm=True, **kw)],
        eps_rel=1e-4)[0]
    np.testing.assert_allclose(new.t_star, ref.t_star, rtol=1e-3)
    np.testing.assert_array_equal(new.loads, ref.loads)
    assert new.c == ref.c


def test_mixed_mec_batch_matches_solo():
    """Base and MEC requests in ONE batched call solve exactly as they do
    alone (the static flag groups them; neither perturbs the other)."""
    rng = np.random.default_rng(13)
    edge, server = _random_fleet(rng, 6)
    sizes = np.full(6, 40)
    reqs = [
        PlanRequest(edge, server, sizes, c_up=100),
        PlanRequest(edge, server, sizes, c_up=100, mec_comm=True),
        PlanRequest(edge, server, sizes, fixed_c=60, mec_comm=True),
    ]
    batch = solve_redundancy_batched(reqs)
    for req, got in zip(reqs, batch):
        solo = solve_redundancy_batched([req])[0]
        assert got.t_star == solo.t_star
        np.testing.assert_array_equal(got.loads, solo.loads)
        assert got.c == solo.c
    # the MEC law is a different CDF: same fleet, different return
    # probabilities (t* may still land on the same grid point)
    assert np.abs(batch[1].p_return - batch[0].p_return).max() > 0


def test_mec_comm_rejects_edge_chunks():
    rng = np.random.default_rng(0)
    edge, server = _random_fleet(rng, 3)
    with pytest.raises(ValueError, match="mec_comm"):
        PlanRequest(edge, server, np.full(3, 10), mec_comm=True,
                    edge_chunks=4)


# ---------------------------------------------------------------------------
# degenerate equivalence with CodedFL
# ---------------------------------------------------------------------------

def test_codedfedl_identity_map_degenerates_to_cfl(linreg_small):
    """d_feat=None: identity features, base delay model — same plan, same
    parity, bit-identical trace from the same key."""
    fleet, data = linreg_small
    c = int(0.3 * data.m)
    key = jax.random.PRNGKey(5)
    cfl = Session(strategy=make_strategy("cfl", key=key, fixed_c=c),
                  fleet=fleet, lr=0.05, epochs=80)
    cfedl = Session(strategy=CodedFedL(key=key, fixed_c=c),
                    fleet=fleet, lr=0.05, epochs=80)
    st_c, st_f = cfl.plan(data), cfedl.plan(data)
    assert st_c.plan.t_star == st_f.plan.t_star
    np.testing.assert_array_equal(st_c.plan.loads, st_f.plan.loads)
    np.testing.assert_array_equal(np.asarray(st_c.x_parity),
                                  np.asarray(st_f.x_parity))
    r_c = cfl.run(data, rng=np.random.default_rng(3), state=st_c)
    r_f = cfedl.run(data, rng=np.random.default_rng(3), state=st_f)
    np.testing.assert_array_equal(r_f.nmse, r_c.nmse)
    np.testing.assert_array_equal(r_f.times, r_c.times)
    np.testing.assert_array_equal(r_f.epoch_durations, r_c.epoch_durations)
    assert r_f.setup_time == r_c.setup_time


# ---------------------------------------------------------------------------
# registry + end-to-end kernel training
# ---------------------------------------------------------------------------

def test_registry_constructs_codedfedl():
    s = make_strategy("codedfedl", key_seed=1, d_feat=16)
    assert isinstance(s, CodedFedL) and s.d_feat == 16
    alias = make_strategy("cfedl", key_seed=1, d_feat=16)
    assert isinstance(alias, CodedFedL)
    with pytest.raises(ValueError, match="key"):
        make_strategy("codedfedl", d_feat=16)


def test_kernel_run_trains_and_reports(kernel_small):
    fleet, data, strat = kernel_small
    rep = Session(strategy=strat, fleet=fleet, lr=LR, epochs=EPOCHS).run(
        data, rng=np.random.default_rng(0))
    assert np.all(np.isfinite(rep.nmse))
    assert rep.final_nmse() < rep.nmse[0]
    assert rep.extras["d_feat"] == D_FEAT
    assert rep.extras["mec_comm"] == 1.0      # feature map => MEC model
    assert rep.extras["t_star"] > 0
    # the harvested head classifies better than chance on its own
    # training tiles (sanity, not the benchmark's held-out gate)
    phi = np.asarray(strat.features(data), np.float64).reshape(-1, D_FEAT)
    acc = np.mean((phi @ np.asarray(rep.beta, np.float64) > 0)
                  == (np.asarray(data.ys).ravel() > 0))
    assert acc > 0.6


# ---------------------------------------------------------------------------
# composition: sweep, serving, hierarchy
# ---------------------------------------------------------------------------

def test_codedfedl_sweeps_bit_equal_to_solo(linreg_small):
    """Mixed cfl/cfedl sweep: every lane bit-equal to its solo run (the
    kernel lane buckets separately — its operand is the feature stack)."""
    fleet, data = linreg_small
    c = int(0.25 * data.m)
    sessions = [
        Session(strategy=make_strategy("cfl", key_seed=5, fixed_c=c),
                fleet=fleet, lr=0.05, epochs=25, seed=1),
        Session(strategy=make_strategy("cfedl", key_seed=5, fixed_c=c,
                                       d_feat=data.d, rff_gamma=0.05),
                fleet=fleet, lr=0.05, epochs=25, seed=2),
        Session(strategy=make_strategy("cfedl", key_seed=9, fixed_c=c,
                                       d_feat=data.d, rff_gamma=0.05),
                fleet=fleet, lr=0.05, epochs=25, seed=3),
    ]
    reports = run_sweep(sessions, data)
    for sess, rep in zip(sessions, reports):
        solo = sess.run(data, rng=np.random.default_rng(sess.seed))
        np.testing.assert_array_equal(rep.nmse, solo.nmse)
        np.testing.assert_array_equal(rep.times, solo.times)


def test_codedfedl_serves_prefix_of_solo(kernel_small):
    fleet, data, strat = kernel_small
    sess = Session(strategy=strat, fleet=fleet, lr=LR, epochs=EPOCHS,
                   seed=21)
    engine = FedServeEngine(data, lane_width=2, chunk=10,
                            criterion=ConvergenceCriterion(nmse_target=0.0))
    [rep] = engine.serve([sess])
    solo = sess.run(data, rng=np.random.default_rng(sess.seed))
    t = rep.extras["serve_exit_epoch"]
    np.testing.assert_array_equal(rep.nmse, solo.nmse[:t + 1])
    np.testing.assert_array_equal(rep.times, solo.times[:t + 1])
    # kernel lanes get the plateau exit tightened in (serve_convergence)
    assert strat.serve_convergence(
        None, ConvergenceCriterion(nmse_target=0.0)).rel_delta is not None


def test_hierarchical_single_tier_codedfedl(kernel_small):
    fleet, data, strat = kernel_small
    solo = Session(strategy=strat, fleet=fleet, lr=LR, epochs=20,
                   seed=3).run(data, rng=np.random.default_rng(3))
    hier = make_strategy("hierarchical", base=strat,
                         topology=FleetTopology.uniform(N, 1))
    rep = Session(strategy=hier, fleet=fleet, lr=LR, epochs=20,
                  seed=3).run(data, rng=np.random.default_rng(3))
    np.testing.assert_array_equal(rep.nmse, solo.nmse)
    np.testing.assert_array_equal(rep.times, solo.times)


# ---------------------------------------------------------------------------
# the executable-docs gate + perf-trend coverage
# ---------------------------------------------------------------------------

def _load_check_docs():
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "scripts", "check_docs.py")
    spec = importlib.util.spec_from_file_location("check_docs", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_docs_extracts_and_skips_blocks():
    cd = _load_check_docs()
    text = ("intro\n"
            "```python\nprint('runnable')\n```\n"
            "prose\n"
            "```python no-run\nraise SystemExit(1)\n```\n"
            "```python\nsessions = [Session(strategy=..., lr=lr)]\n```\n"
            "```bash\necho not python\n```\n")
    blocks = cd.extract_blocks(text)
    assert len(blocks) == 3                      # bash fence ignored
    (l1, i1, c1), (l2, i2, c2), (l3, i3, c3) = blocks
    assert l1 == 2 and cd.should_skip(i1, c1) is None
    assert "no-run" in cd.should_skip(i2, c2)
    assert "placeholder" in cd.should_skip(i3, c3)


def test_check_docs_example_table_is_complete():
    """Every examples/*.py has a deliberate CI-budget entry (a missing
    entry runs arg-less with only a notice — keep the table exhaustive)."""
    cd = _load_check_docs()
    ex_dir = os.path.join(cd.REPO, "examples")
    present = {f for f in os.listdir(ex_dir) if f.endswith(".py")}
    assert present == set(cd.EXAMPLE_ARGS)


def test_perf_trend_classifies_nonlinear_gates():
    assert classify("gates.coded_accuracy") == "higher"
    assert classify("gates.uncoded_accuracy_equal_time") == "higher"
    assert classify("gates.coded_final_nmse") == "lower"
