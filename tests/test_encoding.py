"""Tests for the §III-A distributed random linear encoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a deterministic fallback

from repro.core.encoding import encode_client, encode_fleet, generator_matrix


def test_generator_matrix_stats():
    key = jax.random.PRNGKey(0)
    g = generator_matrix(key, 2000, 64, kind="normal")
    assert g.shape == (2000, 64)
    # E[G^T G] / c -> I (the law-of-large-numbers identity behind Eq. 18)
    gram = (g.T @ g) / 2000
    np.testing.assert_allclose(np.asarray(gram), np.eye(64), atol=0.12)


def test_generator_matrix_bernoulli():
    key = jax.random.PRNGKey(1)
    g = generator_matrix(key, 1000, 32, kind="bernoulli")
    assert set(np.unique(np.asarray(g))) <= {-1.0, 1.0}
    gram = (g.T @ g) / 1000
    np.testing.assert_allclose(np.asarray(gram), np.eye(32), atol=0.15)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_generator_matrix_bernoulli_dtype_regression(dtype):
    """Regression: kind="bernoulli" must honor the requested float dtype and
    produce exactly +-1 values (jax.random.rademacher defaults to int32 —
    an int generator would silently upcast the whole encoding matmul)."""
    g = generator_matrix(jax.random.PRNGKey(3), 32, 16, kind="bernoulli",
                         dtype=dtype)
    assert g.dtype == dtype
    assert jnp.issubdtype(g.dtype, jnp.floating)
    vals = set(np.unique(np.asarray(g, dtype=np.float32)))
    assert vals <= {-1.0, 1.0}


def test_generator_matrix_unknown_kind():
    with pytest.raises(ValueError):
        generator_matrix(jax.random.PRNGKey(0), 4, 4, kind="nope")


def test_encode_client_matches_matrix_form():
    key = jax.random.PRNGKey(2)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ell, d, c = 20, 8, 12
    x = jax.random.normal(k1, (ell, d))
    y = jax.random.normal(k2, (ell,))
    w = jax.random.uniform(k3, (ell,), minval=0.1, maxval=1.0)
    g = generator_matrix(k4, c, ell)
    par = encode_client(g, w, x, y)
    np.testing.assert_allclose(np.asarray(par.x_parity),
                               np.asarray(g) @ np.diag(np.asarray(w)) @ np.asarray(x),
                               rtol=2e-5)
    np.testing.assert_allclose(np.asarray(par.y_parity),
                               np.asarray(g) @ (np.asarray(w) * np.asarray(y)),
                               rtol=2e-5)


def test_encode_fleet_is_sum_of_clients():
    """Composite parity == implicit encoding of the full dataset (Eq. 10-12)."""
    key = jax.random.PRNGKey(3)
    n, ell, d, c = 5, 16, 6, 10
    xs = jax.random.normal(key, (n, ell, d))
    ys = jax.random.normal(jax.random.fold_in(key, 1), (n, ell))
    ws = jnp.ones((n, ell))
    kx = jax.random.PRNGKey(9)
    xp, yp = encode_fleet(kx, xs, ys, ws, c)
    assert xp.shape == (c, d) and yp.shape == (c,)
    # manual per-client encoding with the same fold pattern
    keys = jax.random.split(kx, n)
    acc_x = np.zeros((c, d), dtype=np.float32)
    acc_y = np.zeros((c,), dtype=np.float32)
    for i in range(n):
        g = generator_matrix(keys[i], c, ell, dtype=xs.dtype)
        acc_x += np.asarray(g @ (ws[i][:, None] * xs[i]))
        acc_y += np.asarray(g @ (ws[i] * ys[i]))
    np.testing.assert_allclose(np.asarray(xp), acc_x, rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(yp), acc_y, rtol=2e-4, atol=1e-5)


def test_encode_fleet_kernel_path_matches_reference():
    """The streamed kernels/encode fleet path draws the SAME per-client
    generators as the scan reference and produces the same composite."""
    key = jax.random.PRNGKey(4)
    n, ell, d, c = 3, 24, 10, 12
    xs = jax.random.normal(key, (n, ell, d))
    ys = jax.random.normal(jax.random.fold_in(key, 1), (n, ell))
    ws = jax.random.uniform(jax.random.fold_in(key, 2), (n, ell),
                            minval=0.2, maxval=1.0)
    kx = jax.random.PRNGKey(17)
    xp_ref, yp_ref = encode_fleet(kx, xs, ys, ws, c)
    xp_k, yp_k = encode_fleet(kx, xs, ys, ws, c, use_kernel=True)
    np.testing.assert_allclose(np.asarray(xp_k), np.asarray(xp_ref),
                               rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(yp_k), np.asarray(yp_ref),
                               rtol=2e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 4), ell=st.integers(1, 12),
       d=st.integers(1, 9), c=st.integers(1, 8))
def test_encode_fleet_shapes(n, ell, d, c):
    key = jax.random.PRNGKey(n * 1000 + ell * 100 + d * 10 + c)
    xs = jax.random.normal(key, (n, ell, d))
    ys = jnp.ones((n, ell))
    ws = jnp.ones((n, ell))
    xp, yp = encode_fleet(key, xs, ys, ws, c)
    assert xp.shape == (c, d) and yp.shape == (c,)
    assert np.all(np.isfinite(np.asarray(xp)))


def test_parity_hides_raw_data():
    """c << ell: parity rows are rank-deficient projections — a server cannot
    reconstruct X from (X~, y~) without G (privacy argument, §III-A)."""
    key = jax.random.PRNGKey(5)
    ell, d, c = 64, 32, 4
    x = jax.random.normal(key, (ell, d))
    g = generator_matrix(jax.random.fold_in(key, 1), c, ell)
    par = encode_client(g, jnp.ones(ell), x, jnp.zeros(ell))
    assert np.linalg.matrix_rank(np.asarray(par.x_parity)) <= c < ell
