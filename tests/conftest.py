"""Pytest configuration: make `src/` and the tests dir importable.

Lets `pytest` work from the repo root without PYTHONPATH=src, and lets test
modules import sibling helpers (e.g. `_hyp`, the hypothesis fallback shim).
"""
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(_HERE), "src")

for path in (_HERE, _SRC):
    if path not in sys.path:
        sys.path.insert(0, path)
