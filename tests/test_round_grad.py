"""Tests for the fused round-gradient path (`repro.kernels.round_grad`
and the `grad_path` plumbing through `repro.core.aggregation` and every
strategy).

Four layers of guarantees:

  * kernel parity — each Pallas variant (masked, coded single-launch,
    tier-masked) matches its pure-jnp oracle in interpret mode across
    ragged/odd shapes, forced-small tiles, zero-weight rows, the
    `w=None` and `c == 0` degenerate cases, and a scalar parity weight;
  * packing — `packed_row_indices` bucket-pads the systematic support
    and the padding rows carry weight zero (exact-zero contributions);
  * session parity — every strategy's `grad_path="fused"` trace matches
    its `grad_path="reference"` trace to rtol 1e-3 / atol 1e-6 with
    bit-identical durations, flat and tiered, and the deprecated
    `CodedFL.use_kernel=True` shim is bitwise the fused default;
  * reference stability — `grad_path="reference"` lowers to exactly the
    pre-fusion expressions (`array_equal` against hand-written jnp).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Session, TrainData, make_strategy
from repro.core import aggregation, cfl
from repro.fleet import FleetTopology
from repro.kernels.round_grad import ops as rg_ops
from repro.kernels.round_grad import ref as rg_ref
from repro.sim.network import paper_fleet, wireless_fleet

EPOCHS = 10
LR = 0.05
N, ELL, D = 12, 60, 40


def _rand(shape, seed, positive=False):
    key = jax.random.PRNGKey(seed)
    if positive:
        return jax.random.uniform(key, shape)
    return jax.random.normal(key, shape)


# ---------------------------------------------------------------------------
# kernel parity vs the jnp oracles (interpret mode on CPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,d,bm", [(1, 1, 8), (7, 5, 3), (64, 16, 64),
                                    (130, 33, 32), (300, 41, 128)])
def test_masked_matches_ref(m, d, bm):
    x = _rand((m, d), m + d)
    y = _rand((m,), m + d + 1)
    w = _rand((m,), m + d + 2, positive=True)
    beta = _rand((d,), m + d + 3)
    got = rg_ops.masked_round_gradient(x, y, w, beta, block_m=bm,
                                       force_interpret=True)
    want = rg_ref.masked_round_gradient(x, y, w, beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_masked_default_weights_are_ones():
    x, y, beta = _rand((33, 7), 0), _rand((33,), 1), _rand((7,), 2)
    got = rg_ops.masked_round_gradient(x, y, None, beta, block_m=16,
                                       force_interpret=True)
    want = rg_ref.masked_round_gradient(x, y, jnp.ones_like(y), beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_masked_zero_weight_rows_drop_out():
    """Rows at weight 0 contribute exactly nothing — the packed layout's
    validity-mask contract."""
    x, y, beta = _rand((40, 6), 3), _rand((40,), 4), _rand((6,), 5)
    w = np.ones(40, dtype=np.float32)
    w[13:] = 0.0
    got = rg_ops.masked_round_gradient(x, y, jnp.asarray(w), beta,
                                       block_m=16, force_interpret=True)
    want = rg_ops.masked_round_gradient(x[:13], y[:13], jnp.ones(13),
                                        beta, block_m=16,
                                        force_interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("ms,mp,d,bm", [(37, 11, 8, 16), (64, 64, 16, 32),
                                        (5, 129, 12, 64)])
def test_coded_single_launch_matches_ref(ms, mp, d, bm):
    x = _rand((ms, d), 10)
    y = _rand((ms,), 11)
    w = _rand((ms,), 12, positive=True)
    xp = _rand((mp, d), 13)
    yp = _rand((mp,), 14)
    wp = _rand((mp,), 15, positive=True)
    beta = _rand((d,), 16)
    got = rg_ops.coded_round_gradient(x, y, w, xp, yp, wp, beta,
                                      block_m=bm, force_interpret=True)
    want = rg_ref.coded_round_gradient(x, y, w, xp, yp, wp, beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_coded_scalar_parity_weight_broadcasts():
    x, y = _rand((20, 5), 20), _rand((20,), 21)
    w = _rand((20,), 22, positive=True)
    xp, yp = _rand((9, 5), 23), _rand((9,), 24)
    beta = _rand((5,), 25)
    got = rg_ops.coded_round_gradient(x, y, w, xp, yp,
                                      jnp.asarray(0.25), beta,
                                      block_m=8, force_interpret=True)
    want = rg_ref.coded_round_gradient(x, y, w, xp, yp,
                                       jnp.full((9,), 0.25), beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_coded_empty_parity_falls_back_to_masked():
    """c == 0: the parity block is (0, d) and cannot be block-fetched;
    the ops wrapper must route to the masked variant."""
    x, y = _rand((24, 6), 30), _rand((24,), 31)
    w = _rand((24,), 32, positive=True)
    beta = _rand((6,), 33)
    got = rg_ops.coded_round_gradient(
        x, y, w, jnp.zeros((0, 6)), jnp.zeros((0,)), jnp.asarray(1.0),
        beta, block_m=8, force_interpret=True)
    want = rg_ops.masked_round_gradient(x, y, w, beta, block_m=8,
                                        force_interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,d,t,bm", [(50, 9, 1, 16), (64, 8, 3, 32),
                                      (77, 12, 4, 16)])
def test_tier_masked_matches_ref_and_tier_reduce(m, d, t, bm):
    x = _rand((m, d), 40)
    y = _rand((m,), 41)
    w = _rand((m,), 42, positive=True)
    beta = _rand((d,), 43)
    masks = (jax.random.uniform(jax.random.PRNGKey(44), (t, m)) < 0.5
             ).astype(x.dtype)
    got = rg_ops.tier_masked_round_gradient(x, y, w, masks, beta,
                                            block_m=bm,
                                            force_interpret=True)
    want = rg_ref.tier_masked_round_gradient(x, y, w, masks, beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # the oracle itself is the tier_reduce contraction the tiered
    # reference path uses
    contrib = (x @ beta - y) * w
    via_reduce = aggregation.tier_reduce(contrib, x, masks)
    np.testing.assert_allclose(np.asarray(want), np.asarray(via_reduce),
                               rtol=1e-5, atol=1e-5)


def test_tier_single_tier_row_is_flat_masked():
    """A ones mask with T == 1 reproduces the flat masked launch bitwise
    (same tile, same accumulation order) — the single-tier-bit-exact
    contract the hierarchy layer relies on."""
    x, y = _rand((48, 10), 50), _rand((48,), 51)
    w = _rand((48,), 52, positive=True)
    beta = _rand((10,), 53)
    tier = rg_ops.tier_masked_round_gradient(
        x, y, w, jnp.ones((1, 48)), beta, block_m=16,
        force_interpret=True)
    flat = rg_ops.masked_round_gradient(x, y, w, beta, block_m=16,
                                        force_interpret=True)
    np.testing.assert_array_equal(np.asarray(tier[0]), np.asarray(flat))


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------

def test_packed_row_indices_bucket_pads():
    load = np.zeros(2000, dtype=np.float32)
    support = np.arange(0, 1400)
    load[support] = 1.0
    idx, valid = cfl.packed_row_indices(load)
    assert idx.shape == (3 * cfl.PACK_BLOCK,)  # 1400 -> 1536
    np.testing.assert_array_equal(idx[:1400], support)
    np.testing.assert_array_equal(valid[:1400], True)
    np.testing.assert_array_equal(valid[1400:], False)
    np.testing.assert_array_equal(idx[1400:], 0)  # padding stays in-range


def test_packed_row_indices_empty_support():
    idx, valid = cfl.packed_row_indices(np.zeros(100))
    assert idx.shape == (cfl.PACK_MIN,)
    assert not valid.any()


def test_fused_device_state_is_memoized():
    fleet = paper_fleet(0.2, 0.2, seed=1, n=N, d=D)
    data = TrainData.linreg(jax.random.PRNGKey(0), n=N, ell=ELL, d=D)
    strat = make_strategy("cfl", key_seed=7, fixed_c=int(0.3 * data.m))
    state = strat.plan(fleet, data)
    dev1 = cfl.fused_coded_device_state(state, data)
    dev2 = cfl.fused_coded_device_state(state, data)
    assert dev1 is dev2
    assert cfl.fused_coded_device_state(state, data, parity_rows=True) \
        is not dev1


def test_fused_device_state_dense_fallback(monkeypatch):
    """Near-full supports skip packing: the dict reuses the shared
    data_device_keys names (x/y/row_client — replicated, not stacked,
    across sweep lanes) with the load mask as the base row weight, so
    every dense lane of a nu-ladder sweep shares one engine bucket."""
    fleet = paper_fleet(0.2, 0.2, seed=1, n=N, d=D)
    data = TrainData.linreg(jax.random.PRNGKey(0), n=N, ell=ELL, d=D)
    strat = make_strategy("cfl", key_seed=7, fixed_c=int(0.3 * data.m))
    state = strat.plan(fleet, data)

    monkeypatch.setattr(cfl, "PACK_DENSE_FRAC", 0.0)
    state._fused_dev = None
    dense = cfl.fused_coded_device_state(state, data)
    assert {"x", "y", "row_client", "sys_w"} <= set(dense)
    assert "sys_x" not in dense
    assert dense["x"].shape == (data.m, data.d)
    np.testing.assert_array_equal(
        np.asarray(dense["sys_w"]),
        np.asarray(state.load_mask).reshape(data.m))

    monkeypatch.setattr(cfl, "PACK_DENSE_FRAC", float("inf"))
    state._fused_dev = None
    packed = cfl.fused_coded_device_state(state, data)
    assert "sys_x" in packed and "x" not in packed


# ---------------------------------------------------------------------------
# session parity: fused vs reference, all strategies
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small():
    fleet = paper_fleet(0.2, 0.2, seed=1, n=N, d=D)
    wfleet = wireless_fleet(0.2, 0.2, nu_erasure=0.3, seed=0, n=N, d=D)
    data = TrainData.linreg(jax.random.PRNGKey(0), n=N, ell=ELL, d=D)
    return fleet, wfleet, data


CASES = ["uncoded", "cfl", "cfl_c0", "gradcode", "scfl", "scfl_rho",
         "lowlat", "cfedl_rff", "cfedl_id"]


def _case(name: str, data):
    c = int(0.3 * data.m)
    if name == "uncoded":
        return make_strategy("uncoded"), "paper"
    if name == "cfl":
        return make_strategy("cfl", key_seed=7, fixed_c=c), "paper"
    if name == "cfl_c0":
        return make_strategy("cfl", key_seed=7, fixed_c=0), "paper"
    if name == "gradcode":
        return make_strategy("gradcode", r=3), "paper"
    if name == "scfl":
        return make_strategy("stochastic", key_seed=7, fixed_c=c,
                             noise_multiplier=0.5, rounds=EPOCHS), \
            "wireless"
    if name == "scfl_rho":
        return make_strategy("stochastic", key_seed=7, fixed_c=c,
                             noise_multiplier=0.5, sample_frac=0.8,
                             rounds=EPOCHS), "wireless"
    if name == "lowlat":
        return make_strategy("lowlatency", key_seed=7, fixed_c=c,
                             chunks=4), "wireless"
    if name == "cfedl_rff":
        # d_feat == data.d so nmse-vs-beta_true stays well defined
        return make_strategy("codedfedl", key_seed=7, fixed_c=c,
                             d_feat=D, rff_gamma=0.05), "paper"
    if name == "cfedl_id":
        return make_strategy("codedfedl", key_seed=7, fixed_c=c), "paper"
    raise ValueError(name)


def _run(strategy, flt, data, seed=3):
    return Session(strategy=strategy, fleet=flt, lr=LR, epochs=EPOCHS,
                   seed=seed).run(data, rng=np.random.default_rng(seed))


def _assert_trace_parity(fused, ref):
    np.testing.assert_array_equal(fused.epoch_durations,
                                  ref.epoch_durations)
    np.testing.assert_array_equal(fused.times, ref.times)
    np.testing.assert_allclose(fused.nmse, ref.nmse, rtol=1e-3, atol=1e-6)


# The small-fleet plans load nearly every row, so the natural layout is
# the dense fallback; pinning PACK_DENSE_FRAC exercises the packed
# layout through the same engines.
LAYOUTS = {"packed": float("inf"), "dense": 0.0}


@pytest.mark.parametrize("layout", sorted(LAYOUTS))
@pytest.mark.parametrize("name", CASES)
def test_fused_matches_reference_flat(name, layout, small, monkeypatch):
    monkeypatch.setattr(cfl, "PACK_DENSE_FRAC", LAYOUTS[layout])
    fleet, wfleet, data = small
    strat, which = _case(name, data)
    flt = fleet if which == "paper" else wfleet
    assert strat.grad_path == aggregation.FUSED  # fused is the default
    fused = _run(strat, flt, data)
    ref = _run(dataclasses.replace(strat,
                                   grad_path=aggregation.REFERENCE),
               flt, data)
    _assert_trace_parity(fused, ref)


@pytest.mark.parametrize("layout", sorted(LAYOUTS))
@pytest.mark.parametrize("name", ["cfl", "scfl_rho", "lowlat"])
def test_fused_matches_reference_tiered(name, layout, small, monkeypatch):
    monkeypatch.setattr(cfl, "PACK_DENSE_FRAC", LAYOUTS[layout])
    fleet, wfleet, data = small
    strat, which = _case(name, data)
    flt = fleet if which == "paper" else wfleet
    topo = FleetTopology.uniform(N, 3)
    fused = _run(make_strategy("hierarchical", base=strat, topology=topo),
                 flt, data)
    ref = _run(make_strategy(
        "hierarchical", topology=topo,
        base=dataclasses.replace(strat,
                                 grad_path=aggregation.REFERENCE)),
        flt, data)
    _assert_trace_parity(fused, ref)


def test_use_kernel_shim_is_fused_bitwise(small):
    """Deprecated `use_kernel=True` must route to the fused path and
    reproduce the fused default exactly (same engine, same trace)."""
    fleet, _, data = small
    c = int(0.3 * data.m)
    default = _run(make_strategy("cfl", key_seed=7, fixed_c=c),
                   fleet, data)
    shim = _run(make_strategy("cfl", key_seed=7, fixed_c=c,
                              use_kernel=True), fleet, data)
    np.testing.assert_array_equal(shim.nmse, default.nmse)
    np.testing.assert_array_equal(shim.epoch_durations,
                                  default.epoch_durations)


def test_resolve_grad_path_validates():
    assert aggregation.resolve_grad_path("fused") == aggregation.FUSED
    assert aggregation.resolve_grad_path("reference") == \
        aggregation.REFERENCE
    assert aggregation.resolve_grad_path(
        "reference", use_kernel=True) == aggregation.FUSED
    with pytest.raises(ValueError):
        aggregation.resolve_grad_path("pallas")


# ---------------------------------------------------------------------------
# reference stability: grad_path="reference" IS the pre-fusion math
# ---------------------------------------------------------------------------

def test_reference_round_gradient_is_pre_fusion_expression():
    x, y = _rand((50, 8), 60), _rand((50,), 61)
    w = _rand((50,), 62, positive=True)
    beta = _rand((8,), 63)
    resid = x @ beta - y
    np.testing.assert_array_equal(
        np.asarray(aggregation.round_gradient(x, y, beta)),
        np.asarray(resid @ x))
    np.testing.assert_array_equal(
        np.asarray(aggregation.round_gradient(x, y, beta, w=w)),
        np.asarray((resid * w) @ x))


def test_reference_coded_gradient_is_pre_fusion_expression():
    x, y = _rand((40, 6), 70), _rand((40,), 71)
    w = _rand((40,), 72, positive=True)
    xp, yp = _rand((15, 6), 73), _rand((15,), 74)
    wp = jnp.full((15,), 1.0 / 15)
    beta = _rand((6,), 75)
    want = ((x @ beta - y) * w) @ x + ((xp @ beta - yp) * wp) @ xp
    got = aggregation.coded_round_gradient(x, y, w, xp, yp, wp, beta)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gram_parity_gradient_matches_two_pass():
    """The Gram-folded Eq. 18 term equals the two-pass parity gradient
    up to float reassociation."""
    xp, yp = _rand((30, 7), 80), _rand((30,), 81)
    beta = _rand((7,), 82)
    gram, gramy = aggregation.parity_gram(xp, yp)
    got = aggregation.gram_parity_gradient(gram, gramy, beta,
                                           jnp.asarray(30.0))
    want = ((xp @ beta - yp) / 30.0) @ xp
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
