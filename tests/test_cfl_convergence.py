"""Integration tests: the full CFL protocol + wall-clock simulation converge
and reproduce the paper's qualitative claims (scaled down for CI speed)."""
import jax
import numpy as np
import pytest

from repro.core import cfl
from repro.sim import simulator as S
from repro.sim.network import paper_fleet
from repro.sim.simulator import coding_gain


@pytest.fixture(scope="module")
def small_problem():
    fleet = paper_fleet(0.2, 0.2, seed=1, n=12, d=60)
    key = jax.random.PRNGKey(0)
    xs, ys, beta_true = S.generate_linreg(key, n=12, ell=80, d=60)
    return fleet, xs, ys, beta_true


def test_uncoded_converges(small_problem):
    fleet, xs, ys, bt = small_problem
    res = S.run_uncoded(fleet, xs, ys, bt, lr=0.05, epochs=250,
                        rng=np.random.default_rng(0))
    assert res.final_nmse() < 1e-2
    assert np.all(np.diff(res.times) > 0)


def test_cfl_converges_and_is_faster(small_problem):
    fleet, xs, ys, bt = small_problem
    m = xs.shape[0] * xs.shape[1]
    res_u = S.run_uncoded(fleet, xs, ys, bt, lr=0.05, epochs=250,
                          rng=np.random.default_rng(0))
    res_c = S.run_cfl(fleet, xs, ys, bt, lr=0.05, epochs=250,
                      rng=np.random.default_rng(0), key=jax.random.PRNGKey(1),
                      fixed_c=int(0.3 * m), include_upload_delay=False)
    assert res_c.final_nmse() < 2e-2
    tgt = 1e-1
    g = coding_gain(res_u, res_c, tgt)
    assert g > 1.0, f"coding gain {g} should exceed 1 under heterogeneity"


def test_cfl_epoch_deadline_is_tstar(small_problem):
    fleet, xs, ys, bt = small_problem
    m = xs.shape[0] * xs.shape[1]
    res_c = S.run_cfl(fleet, xs, ys, bt, lr=0.05, epochs=5,
                      rng=np.random.default_rng(2), key=jax.random.PRNGKey(1),
                      fixed_c=int(0.2 * m), include_upload_delay=False)
    # all CFL epochs take exactly t*: the tail is clipped (paper Fig. 3)
    assert np.allclose(res_c.epoch_durations, res_c.epoch_durations[0])


def test_uncoded_epochs_have_straggler_tail(small_problem):
    fleet, xs, ys, bt = small_problem
    res_u = S.run_uncoded(fleet, xs, ys, bt, lr=0.05, epochs=60,
                          rng=np.random.default_rng(3))
    durs = res_u.epoch_durations
    assert durs.max() > 1.25 * np.median(durs), "expected a straggler tail"


def test_upload_delay_accounting(small_problem):
    fleet, xs, ys, bt = small_problem
    m = xs.shape[0] * xs.shape[1]
    kw = dict(lr=0.05, epochs=3, key=jax.random.PRNGKey(1),
              fixed_c=int(0.2 * m))
    with_up = S.run_cfl(fleet, xs, ys, bt, rng=np.random.default_rng(4),
                        include_upload_delay=True, **kw)
    without = S.run_cfl(fleet, xs, ys, bt, rng=np.random.default_rng(4),
                        include_upload_delay=False, **kw)
    assert with_up.setup_time > 0
    assert with_up.times[0] == pytest.approx(with_up.setup_time)
    assert without.times[0] == 0.0
    # uplink accounting includes the one-time parity shipment
    assert with_up.uplink_bits_total > 3 * 12 * 2 * fleet.packet_bits


def test_delta_zero_degenerates_to_deadline_uncoded(small_problem):
    fleet, xs, ys, bt = small_problem
    res = S.run_cfl(fleet, xs, ys, bt, lr=0.05, epochs=3,
                    rng=np.random.default_rng(5), key=jax.random.PRNGKey(1),
                    fixed_c=0, include_upload_delay=True)
    assert res.setup_time == 0.0
    assert res.final_nmse() < 1.0  # still makes progress from received grads


def test_setup_state_consistency(small_problem):
    fleet, xs, ys, bt = small_problem
    m = xs.shape[0] * xs.shape[1]
    state = cfl.setup(jax.random.PRNGKey(0), xs, ys, fleet.edge, fleet.server,
                      fixed_c=int(0.25 * m))
    assert state.c == int(0.25 * m)
    assert state.x_parity.shape == (state.c, xs.shape[-1])
    # load mask rows sum to the plan loads
    np.testing.assert_array_equal(
        np.asarray(state.load_mask.sum(axis=1), dtype=np.int64),
        state.plan.loads)
    # weights: processed points carry sqrt(1-p_i) <= 1, punctured exactly 1
    w = np.asarray(state.weights)
    lm = np.asarray(state.load_mask).astype(bool)
    assert np.all(w[~lm] == 1.0)
    assert np.all(w[lm] <= 1.0)
