"""Tests for the sharding rules, mesh helpers, and a miniature end-to-end
sharded lower+compile on the host mesh (1 device) — the same code path the
512-device dry-run exercises."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding as SH
from repro.launch.mesh import data_axes, make_host_mesh
from repro.launch.steps import make_decode_step, make_train_step
from repro.models import transformer as T
from repro.optim.optimizers import make_optimizer

# lock the device count BEFORE any test imports repro.launch.dryrun (which
# sets xla_force_host_platform_device_count=512 for the real dry-run)
_ = jax.devices()


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(model_axis=1)


def _specs(cfg, mesh, fsdp=False):
    params = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    sh = SH.param_shardings(cfg, mesh, params, fsdp=fsdp)
    return params, sh


def test_param_rules_dense(mesh):
    cfg = get_config("granite-8b")
    params, sh = _specs(cfg, mesh)
    # embed sharded over model on vocab; wq over model on out dim
    assert sh["embed"].spec == P("model", None)
    assert sh["blocks"]["attn"]["wq"].spec == P(None, None, "model")
    assert sh["blocks"]["attn"]["wo"].spec == P(None, "model", None)
    assert sh["blocks"]["mlp"]["w_down"].spec == P(None, "model", None)
    # norms replicated
    assert sh["blocks"]["attn_norm"]["scale"].spec == P(None, None)


def test_param_rules_moe_expert_parallel(mesh):
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    params, sh = _specs(cfg, mesh, fsdp=True)
    # experts over model; FSDP over data on the D dim
    assert sh["moe_blocks"]["moe"]["w_gate"].spec[1] == "model"
    assert sh["moe_blocks"]["moe"]["w_gate"].spec[2] == "data"
    assert sh["moe_blocks"]["moe"]["router"].spec == P(None, None, None)


def test_param_rules_mamba(mesh):
    cfg = get_config("mamba2-1.3b")
    params, sh = _specs(cfg, mesh, fsdp=False)
    # no-FSDP: mamba weights replicated (packed boundaries, DESIGN.md 6b.3)
    assert sh["blocks"]["mixer"]["w_in"].spec == P(None, None, None)
    params, sh = _specs(cfg, mesh, fsdp=True)
    assert sh["blocks"]["mixer"]["w_in"].spec[1] == "data"


def test_divisibility_guard(mesh):
    """Dims that don't divide the axis fall back to replication."""
    cfg = get_config("whisper-tiny")  # 6 heads, hd 64 -> 384-dim projections
    params, sh = _specs(cfg, mesh)
    for leaf_sh in jax.tree.leaves(sh):
        assert leaf_sh is not None  # every leaf got a sharding


def test_batch_shardings(mesh):
    cfg = get_config("granite-8b")
    b = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
         "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    sh = SH.batch_shardings(cfg, mesh, b)
    assert sh["tokens"].spec[0] is not None  # batch over data axes
    assert sh["pos"].spec == P()


def test_cache_shardings_kv_and_ssm(mesh):
    dense = get_config("codeqwen1.5-7b")
    cache = jax.eval_shape(lambda: T.init_cache(dense, 4, 32))
    sh = SH.cache_shardings(dense, mesh, cache)
    assert len(sh["attn"]["k"].spec) == 5
    ssm = get_config("mamba2-1.3b")
    cache = jax.eval_shape(lambda: T.init_cache(ssm, 4, 32))
    sh = SH.cache_shardings(ssm, mesh, cache)
    assert len(sh["mamba"]["ssm"].spec) == 5


def test_zero1_shards_moments_of_replicated_params(mesh):
    cfg = get_config("mamba2-1.3b")
    params = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    p_sh = SH.param_shardings(cfg, mesh, params, fsdp=False)
    opt = make_optimizer("adamw", 1e-3)
    opt_sds = jax.eval_shape(opt.init, params)
    o_sh = SH.opt_state_shardings(mesh, p_sh, opt_sds, zero1=True)
    # the stacked (48, ...) w_in moment gets its L dim data-sharded
    spec = o_sh.mu["blocks"]["mixer"]["w_in"].spec
    assert "data" in spec


def test_mini_sharded_train_step_compiles_and_runs(mesh):
    """End-to-end: jit with shardings on the host mesh, real execution."""
    cfg = get_config("granite-8b").reduced()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    p_sh = SH.param_shardings(cfg, mesh, jax.eval_shape(lambda: params))
    opt = make_optimizer("adamw", 1e-3)
    opt_state = opt.init(params)
    o_sh = SH.opt_state_shardings(mesh, p_sh,
                                  jax.eval_shape(lambda: opt_state))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "targets": jnp.ones((2, 16), jnp.int32)}
    b_sh = SH.batch_shardings(cfg, mesh, jax.eval_shape(lambda: batch))
    step = jax.jit(make_train_step(cfg, opt, compute_dtype=jnp.float32,
                                   remat=False),
                   in_shardings=(p_sh, o_sh, b_sh))
    with mesh:
        params2, opt2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_mini_sharded_decode_step(mesh):
    cfg = get_config("zamba2-1.2b").reduced()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    cache = T.init_cache(cfg, 2, 32)
    c_sh = SH.cache_shardings(cfg, mesh, jax.eval_shape(lambda: cache))
    step = jax.jit(make_decode_step(cfg, compute_dtype=jnp.float32))
    with mesh:
        logits, cache2 = step(params,
                              {"token": jnp.ones((2, 1), jnp.int32),
                               "pos": jnp.asarray(0, jnp.int32)}, cache)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_data_axes():
    m1 = make_host_mesh(model_axis=1)
    assert data_axes(m1) == ("data",)


def test_base_arch_name():
    assert SH.base_arch_name("granite-8b-sw8192") == "granite-8b"
    assert SH.base_arch_name("mamba2-1.3b") == "mamba2-1.3b"


def test_optimize_config_shape_aware():
    from repro.launch.dryrun import optimize_config
    dense = get_config("granite-8b")
    t = optimize_config(dense, "train")
    d = optimize_config(dense, "decode")
    assert t.attn_impl == "repeat" and t.softmax_dtype == "bf16"
    assert d.attn_impl == "grouped"  # repeat regresses decode (§Perf)
    llama4 = optimize_config(get_config("llama4-maverick-400b-a17b"),
                             "train")
    assert llama4.attn_seq_shard == "head"      # 40 heads % 16 != 0
    assert llama4.moe.capacity_factor == 1.25
    mamba = optimize_config(get_config("mamba2-1.3b"), "decode")
    assert mamba.ssm.head_shard
