"""Hypothesis compatibility shim for environments without `hypothesis`.

The container this repo targets does not ship `hypothesis`, and a bare
`from hypothesis import ...` used to crash the WHOLE pytest collection with
a ModuleNotFoundError.  Importing from this module instead gives you:

  * the real `given` / `settings` / strategies when hypothesis is installed
    (install via requirements-dev.txt for full shrinking/fuzzing power);
  * otherwise a minimal deterministic fallback that runs each property test
    over a fixed-seed sample of the declared strategy space.

Only the tiny strategy surface this repo uses is emulated: `integers`,
`floats`, `sampled_from`, keyword-style `@given`, and `@settings` with
`max_examples` / `deadline`.
"""
from __future__ import annotations

try:  # real hypothesis if available
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic stand-in
    import functools
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rnd: random.Random):
            return self._draw(rnd)

    class st:  # noqa: N801 - mimics `hypothesis.strategies` module
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda r: seq[r.randrange(len(seq))])

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args):
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", _DEFAULT_EXAMPLES))
                rnd = random.Random(0xC0DEDFED)  # fixed seed: reproducible
                for _ in range(n):
                    kwargs = {k: s.draw(rnd) for k, s in strats.items()}
                    fn(*args, **kwargs)
            # pytest must not see the wrapped signature, or it would treat
            # the strategy parameters as fixtures
            del wrapper.__wrapped__
            wrapper._hypothesis_fallback = True
            return wrapper
        return deco

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
