"""Parity + property tests for the batched grid planner (`repro.plan`).

The oracle is the seed's scalar stack, preserved verbatim in
`repro.plan.reference`.  Randomized fleets avoid the full-saturation corner
(parity budget ~ 0 with target m): there t* sits on the CDF-saturation
asymptote where the reference's answer is an artifact of float64 rounding,
and the solvers agree on loads but only loosely on t* (covered separately
by `test_fixed_c_zero_saturating_regime`).
"""
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a deterministic fallback

from repro.core.delay_model import DeviceDelayParams, total_cdf
from repro.core.redundancy import RedundancyPlan, solve_redundancy
from repro.plan import PlanRequest, solve_redundancy_batched
from repro.plan.reference import solve_redundancy_reference


def _random_fleet(rng: np.random.Generator, n: int):
    a = rng.uniform(1e-3, 5e-2, n)
    mu = (2.0 / a) * rng.uniform(0.5, 2.0, n)
    tau = rng.uniform(1e-3, 5e-2, n)
    p = rng.uniform(0.0, 0.3, n)
    edge = DeviceDelayParams(a, mu, tau, p)
    sa = np.array([a.min() / 10.0])
    server = DeviceDelayParams(sa, 2.0 / sa, np.zeros(1), np.zeros(1))
    return edge, server


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 8), ell=st.integers(8, 60),
       mode=st.sampled_from(["free", "fixed"]), seed=st.integers(0, 10**6))
def test_batched_solver_matches_reference(n, ell, mode, seed):
    """Property parity: grid solver == seed bisection on randomized fleets
    (t* to 1e-3 relative, loads and c exactly)."""
    rng = np.random.default_rng(seed)
    edge, server = _random_fleet(rng, n)
    sizes = rng.integers(ell // 2 + 1, ell + 1, size=n)
    m = int(sizes.sum())
    # keep the parity budget >= 10% of m: avoids the saturation asymptote
    kw = {"fixed_c": int(rng.integers(m // 10 + 1, m + 1))} \
        if mode == "fixed" else \
        {"c_up": int(rng.integers(m // 10 + 1, m + 1))}
    ref = solve_redundancy_reference(edge, server, sizes, eps_rel=1e-4, **kw)
    new = solve_redundancy_batched(
        [PlanRequest(edge, server, sizes, **kw)], eps_rel=1e-4)[0]
    np.testing.assert_allclose(new.t_star, ref.t_star, rtol=1e-3)
    np.testing.assert_array_equal(new.loads, ref.loads)
    assert new.c == ref.c
    np.testing.assert_allclose(new.p_return, ref.p_return,
                               rtol=1e-3, atol=1e-6)
    np.testing.assert_allclose(new.expected_agg, ref.expected_agg, rtol=1e-3)
    assert new.loads_cap_total == ref.loads_cap_total == m


def test_batched_matches_single_calls():
    """One batched call over heterogeneous requests == per-request solves."""
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(6):
        edge, server = _random_fleet(rng, 6)
        sizes = np.full(6, 40 + 4 * i)
        kw = {"fixed_c": 30 + 10 * i} if i % 2 else {"c_up": 60 + 10 * i}
        reqs.append(PlanRequest(edge, server, sizes, **kw))
    batch = solve_redundancy_batched(reqs)
    for req, got in zip(reqs, batch):
        one = solve_redundancy_batched([req])[0]
        np.testing.assert_allclose(got.t_star, one.t_star, rtol=1e-9)
        np.testing.assert_array_equal(got.loads, one.loads)
        assert got.c == one.c
        np.testing.assert_allclose(got.p_return, one.p_return,
                                   rtol=1e-9, atol=1e-12)


def test_shim_routes_to_grid_solver():
    """`core.redundancy.solve_redundancy` is a thin shim over the batched
    solver: identical plan fields for the same request."""
    rng = np.random.default_rng(3)
    edge, server = _random_fleet(rng, 5)
    sizes = np.full(5, 50)
    shim = solve_redundancy(edge, server, sizes, fixed_c=80)
    direct = solve_redundancy_batched(
        [PlanRequest(edge, server, sizes, fixed_c=80)])[0]
    assert shim.t_star == direct.t_star
    np.testing.assert_array_equal(shim.loads, direct.loads)
    assert shim.c == direct.c and shim.expected_agg == direct.expected_agg


def test_fixed_c_zero_saturating_regime():
    """fixed_c = 0 (delta = 0): every device must saturate, the deadline is
    finite, and the loads equal the caps (matching the reference's loads
    even though t* sits on the saturation asymptote)."""
    rng = np.random.default_rng(7)
    edge, server = _random_fleet(rng, 4)
    sizes = np.full(4, 30)
    ref = solve_redundancy_reference(edge, server, sizes, fixed_c=0)
    new = solve_redundancy_batched(
        [PlanRequest(edge, server, sizes, fixed_c=0)])[0]
    assert new.c == 0 and np.isfinite(new.t_star) and new.t_star > 0
    np.testing.assert_array_equal(new.loads, sizes)
    np.testing.assert_array_equal(new.loads, ref.loads)
    assert new.expected_agg >= sizes.sum()


def test_p_return_consistent_with_total_cdf():
    """p_return must be bit-identical to total_cdf at (loads, t*): the
    Eq.-17 weights sqrt(1 - p) amplify any last-ulp drift when p ~ 1."""
    rng = np.random.default_rng(11)
    edge, server = _random_fleet(rng, 6)
    sizes = np.full(6, 40)
    plan = solve_redundancy_batched(
        [PlanRequest(edge, server, sizes, c_up=100)])[0]
    np.testing.assert_array_equal(
        plan.p_return[:-1], total_cdf(edge, plan.loads, plan.t_star))


def test_infeasible_batch_raises():
    """A fleet that cannot reach the target must raise (legacy contract),
    naming the offending request."""
    edge = DeviceDelayParams(a=np.full(2, 1e12), mu=np.full(2, 1e-12),
                             tau=np.ones(2), p=np.full(2, 0.99))
    server = DeviceDelayParams(a=np.array([1e12]), mu=np.array([1e-12]),
                               tau=np.zeros(1), p=np.zeros(1))
    with pytest.raises(RuntimeError):
        solve_redundancy_batched(
            [PlanRequest(edge, server, np.full(2, 10), c_up=5, t_hi=1.0)])


def test_plan_request_validates_server():
    edge, server = _random_fleet(np.random.default_rng(0), 3)
    with pytest.raises(ValueError):  # two servers
        PlanRequest(edge, edge, np.full(3, 10))
    comm_server = DeviceDelayParams(np.ones(1), np.ones(1), np.ones(1),
                                    np.zeros(1))
    with pytest.raises(ValueError):  # server with a communication leg
        PlanRequest(edge, comm_server, np.full(3, 10))
    with pytest.raises(ValueError):  # data_sizes shape mismatch
        PlanRequest(edge, server, np.full(4, 10))


def test_plan_sweep_batches_coded_sessions():
    """api.plan_sweep: one batched solve across a Session sweep produces
    states identical to per-session planning (same plan, same parity)."""
    import jax

    from repro.api import CodedFL, Session, TrainData, plan_sweep
    from repro.sim.network import paper_fleet

    fleet = paper_fleet(0.2, 0.2, seed=0, n=8, d=40)
    data = TrainData.linreg(jax.random.PRNGKey(0), n=8, ell=20, d=40)
    sessions = [
        Session(strategy=CodedFL(key=jax.random.PRNGKey(5), fixed_c=c),
                fleet=fleet, lr=0.01, epochs=3)
        for c in (8, 24, 40)
    ]
    states = plan_sweep(sessions, data)
    for sess, state in zip(sessions, states):
        solo = sess.plan(data)
        assert state.plan.t_star == solo.plan.t_star
        np.testing.assert_array_equal(state.plan.loads, solo.plan.loads)
        assert state.plan.c == solo.plan.c
        np.testing.assert_allclose(np.asarray(state.x_parity),
                                   np.asarray(solo.x_parity))
        # and the planned state runs end-to-end
        rep = sess.run(data, rng=np.random.default_rng(0), state=state)
        assert np.all(np.isfinite(rep.nmse))


def test_redundancy_plan_delta_guard():
    """Satellite fix: loads_cap_total is required and delta raises a clear
    error instead of ZeroDivisionError when it is not positive."""
    with pytest.raises(TypeError):
        RedundancyPlan(loads=np.array([1]), c=1, t_star=1.0,
                       p_return=np.array([1.0, 1.0]), expected_agg=1.0)
    plan = RedundancyPlan(loads=np.array([1]), c=1, t_star=1.0,
                          p_return=np.array([1.0, 1.0]), expected_agg=1.0,
                          loads_cap_total=0)
    with pytest.raises(ValueError, match="loads_cap_total"):
        plan.delta
    ok = RedundancyPlan(loads=np.array([1]), c=2, t_star=1.0,
                        p_return=np.array([1.0, 1.0]), expected_agg=1.0,
                        loads_cap_total=8)
    assert ok.delta == 0.25
