"""Tests for the batched sweep engine (`repro.api.run_sweep`).

The load-bearing guarantee: a sweep lane is the SAME computation as a solo
`Session.run` — same planning, same per-lane generator draw order, and a
per-lane training program that is bit-for-bit identical at any lane count
(the engine iterates lanes with `lax.map` inside a `shard_map` precisely so
no batched lowering can perturb last-ulp arithmetic).  Every comparison
here is exact (`assert_array_equal`), not approximate.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.api import (Session, TrainData, make_strategy, plan_sweep,
                       run_sweep)
from repro.api.session import _ENGINE_CACHE, _static_strategy_key
from repro.sim.network import paper_fleet, wireless_fleet

EPOCHS = 25
LR = 0.05


@pytest.fixture(scope="module")
def small():
    fleet = paper_fleet(0.2, 0.2, seed=1, n=12, d=40)
    wfleet = wireless_fleet(0.2, 0.2, nu_erasure=0.3, seed=0, n=12, d=40)
    data = TrainData.linreg(jax.random.PRNGKey(0), n=12, ell=60, d=40)
    return fleet, wfleet, data


def _sessions_for(name: str, small, epochs: int = EPOCHS):
    """A small sweep per strategy, lanes differing in value-only knobs."""
    fleet, wfleet, data = small
    c = int(0.3 * data.m)
    if name == "uncoded":
        return [Session(strategy=make_strategy("uncoded"), fleet=fleet,
                        lr=lr, epochs=epochs) for lr in (0.05, 0.03)]
    if name == "cfl":
        return [Session(strategy=make_strategy("cfl", key_seed=seed,
                                               fixed_c=c),
                        fleet=fleet, lr=LR, epochs=epochs)
                for seed in (7, 8, 9)]
    if name == "gradcode":
        return [Session(strategy=make_strategy("gradcode", r=3),
                        fleet=fleet, lr=lr, epochs=epochs)
                for lr in (0.05, 0.04)]
    if name == "stochastic":
        return [Session(strategy=make_strategy(
            "stochastic", key_seed=7, fixed_c=c, noise_multiplier=sigma,
            sample_frac=0.8, rounds=epochs),
            fleet=wfleet, lr=LR, epochs=epochs) for sigma in (0.0, 0.5, 1.0)]
    if name == "lowlatency":
        return [Session(strategy=make_strategy(
            "lowlatency", key_seed=seed, fixed_c=c, chunks=4),
            fleet=wfleet, lr=LR, epochs=epochs) for seed in (7, 11)]
    raise ValueError(name)


def _assert_lane_equals_solo(sweep_reports, sessions, data):
    """Bit-for-bit: traces, clocks, and extras match fresh solo runs."""
    for sess, rep in zip(sessions, sweep_reports):
        solo = sess.run(data, rng=np.random.default_rng(sess.seed))
        np.testing.assert_array_equal(rep.nmse, solo.nmse)
        np.testing.assert_array_equal(rep.times, solo.times)
        np.testing.assert_array_equal(rep.epoch_durations,
                                      solo.epoch_durations)
        assert rep.label == solo.label
        assert rep.setup_time == solo.setup_time
        assert rep.uplink_bits_total == solo.uplink_bits_total
        assert set(rep.extras) == set(solo.extras)
        for k, v in rep.extras.items():
            np.testing.assert_array_equal(np.asarray(v),
                                          np.asarray(solo.extras[k]))


# ---------------------------------------------------------------------------
# per-lane bit-parity with solo runs, all five registered strategies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "name", ["uncoded", "cfl", "gradcode", "stochastic", "lowlatency"])
def test_sweep_lanes_bit_equal_solo(small, name):
    """The property, for every registered strategy: each sweep lane's NMSE
    trace, clock, and extras are bit-equal to a solo `Session.run` with
    the same per-lane generator."""
    _, _, data = small
    sessions = _sessions_for(name, small)
    reports = run_sweep(sessions, data)
    _assert_lane_equals_solo(reports, sessions, data)


def test_stochastic_sweep_preserves_privacy_extras(small):
    """Per-lane TraceReport.extras survive batching — including the DP
    accounting fields (`epsilon_spent`, `epsilon_schedule`)."""
    _, _, data = small
    sessions = _sessions_for("stochastic", small)
    reports = run_sweep(sessions, data)
    eps = [rep.extras["epsilon_spent"] for rep in reports]
    assert eps[0] == np.inf  # sigma = 0 lane: unbounded budget
    assert np.isfinite(eps[1]) and np.isfinite(eps[2])
    assert eps[1] > eps[2]  # more noise, less epsilon spent
    for rep in reports:
        assert rep.extras["epsilon_schedule"].shape == (EPOCHS,)
        assert rep.privacy_budget() is not None


# ---------------------------------------------------------------------------
# mixed-bucket sweeps: heterogeneous strategies and shapes in one call
# ---------------------------------------------------------------------------

def test_mixed_bucket_sweep(small):
    """One run_sweep over five strategy classes AND two parity-budget
    shapes: the bucketing path must split lanes by static structure +
    shapes and still reproduce every solo trace bit-for-bit."""
    fleet, wfleet, data = small
    c1, c2 = int(0.2 * data.m), int(0.4 * data.m)
    sessions = [
        Session(strategy=make_strategy("uncoded"), fleet=fleet, lr=LR,
                epochs=EPOCHS),
        Session(strategy=make_strategy("cfl", key_seed=7, fixed_c=c1),
                fleet=fleet, lr=LR, epochs=EPOCHS),
        Session(strategy=make_strategy("cfl", key_seed=7, fixed_c=c2),
                fleet=fleet, lr=LR, epochs=EPOCHS),
        Session(strategy=make_strategy("gradcode", r=3), fleet=fleet,
                lr=LR, epochs=EPOCHS),
        Session(strategy=make_strategy("stochastic", key_seed=7, fixed_c=c1,
                                       noise_multiplier=0.5),
                fleet=wfleet, lr=LR, epochs=EPOCHS),
        Session(strategy=make_strategy("lowlatency", key_seed=7, fixed_c=c1,
                                       chunks=4),
                fleet=wfleet, lr=LR, epochs=EPOCHS),
    ]
    reports = run_sweep(sessions, data)
    assert len(reports) == len(sessions)
    _assert_lane_equals_solo(reports, sessions, data)


def test_value_only_knobs_share_one_engine(small):
    """Lanes differing only in declared value-only knobs (lr, PRNG key,
    noise level) form ONE bucket: exactly one new engine entry appears."""
    _, _, data = small
    sessions = _sessions_for("stochastic", small)
    states = plan_sweep(sessions, data)
    before = len(_ENGINE_CACHE)
    run_sweep(sessions, data, states=states)
    new = len(_ENGINE_CACHE) - before
    assert new <= 1  # 0 when an earlier test already compiled this bucket


def test_run_sweep_validates_lengths(small):
    fleet, _, data = small
    sessions = [Session(strategy=make_strategy("uncoded"), fleet=fleet,
                        lr=LR, epochs=5)]
    with pytest.raises(ValueError, match="states"):
        run_sweep(sessions, data, states=[])
    with pytest.raises(ValueError, match="generators"):
        run_sweep(sessions, data, rngs=[])


# ---------------------------------------------------------------------------
# engine-cache keying: full static strategy structure, not just engine_key
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _ScaledUncoded:
    """Regression vehicle: a static field (`scale`) steers the traced
    engine, but `engine_key` FORGETS it — the historical failure mode for
    sessions cloned via `dataclasses.replace`."""

    scale: float = 1.0
    label: str = "scaled"

    def plan(self, fleet, data):
        return {"n": data.n}

    def sample_epochs(self, state, fleet, epochs, rng):
        from repro.api import EpochSchedule
        return EpochSchedule(
            durations=np.ones(epochs),
            arrivals={"epoch": np.zeros(epochs, np.float32)})

    def device_state(self, state, data):
        return {"x": data.xs.reshape(data.m, data.d),
                "y": data.ys.reshape(data.m)}

    def round_contributions(self, state, dev, beta, arrivals):
        resid = dev["x"] @ beta - dev["y"]
        return self.scale * (resid @ dev["x"])  # static use of `scale`

    def uplink_bits(self, state, fleet, epochs):
        return 0.0

    def engine_key(self, state):
        return ()  # deliberately incomplete


def test_replaced_static_field_never_shares_engine(small):
    """Two sessions produced by `dataclasses.replace` with different
    static strategy fields must compile DIFFERENT engines, even when the
    strategy's own `engine_key` under-reports."""
    fleet, _, data = small
    s1 = Session(strategy=_ScaledUncoded(scale=1.0), fleet=fleet, lr=LR,
                 epochs=10)
    rep1 = s1.run(data)
    s2 = dataclasses.replace(
        s1, strategy=dataclasses.replace(s1.strategy, scale=0.25))
    rep2 = s2.run(data)
    # a shared engine would have baked scale=1.0 into s2's trace
    assert not np.array_equal(rep1.nmse, rep2.nmse)
    assert set(s1._engines) != set(s2._engines)
    # the quarter-scale engine really computes a quarter-scale first step
    g_full = np.asarray(_ScaledUncoded(1.0).round_contributions(
        None, s1.strategy.device_state(None, data),
        jnp.zeros(data.d), {}))
    g_quarter = np.asarray(s2.strategy.round_contributions(
        None, s2.strategy.device_state(None, data), jnp.zeros(data.d), {}))
    np.testing.assert_allclose(0.25 * g_full, g_quarter, rtol=1e-6)


def test_static_key_excludes_label_and_value_fields(small):
    """`label` and declared `engine_value_fields` never fragment buckets;
    trace-steering fields always do."""
    a = make_strategy("stochastic", key_seed=7, noise_multiplier=0.2,
                      label="lane_a")
    b = make_strategy("stochastic", key_seed=9, noise_multiplier=0.9,
                      label="lane_b")
    assert _static_strategy_key(a) == _static_strategy_key(b)
    c = dataclasses.replace(a, sample_frac=0.5)  # traced 1/(c*rho) changes
    assert _static_strategy_key(a) != _static_strategy_key(c)


# ---------------------------------------------------------------------------
# lane mesh helpers (repro.launch)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(n_lanes=st.integers(1, 40))
def test_lane_mesh_size_divides(n_lanes):
    from repro.launch.mesh import lane_mesh_size
    k = lane_mesh_size(n_lanes)
    assert 1 <= k <= max(1, len(jax.devices()))
    assert n_lanes % k == 0


def test_lane_specs_layout():
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import lane_specs
    tree = {"a": np.zeros((4, 3, 2)), "b": np.zeros(4)}
    specs = lane_specs(tree)
    assert specs["a"] == P("lanes", None, None)
    assert specs["b"] == P("lanes")
