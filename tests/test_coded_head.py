"""Tests for the exact coded-head bridge (CFL on frozen-backbone features)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.fed.coded_head import extract_features, train_coded_head
from repro.sim.network import paper_fleet


def test_extract_features_vmaps_backbone():
    def backbone(x):  # (ell, d_in) -> (ell, d_out)
        return jnp.tanh(x @ jnp.ones((4, 3)))

    xs = jnp.ones((5, 7, 4))
    f = extract_features(backbone, xs)
    assert f.shape == (5, 7, 3)


def test_coded_head_trains_and_beats_uncoded_wallclock():
    n, ell, d = 10, 40, 24
    fleet = paper_fleet(0.25, 0.25, seed=3, n=n, d=d)
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    feats = jax.random.normal(k1, (n, ell, d))
    beta_true = jax.random.normal(k2, (d,))
    ys = jnp.einsum("nld,d->nl", feats, beta_true) \
        + 0.05 * jax.random.normal(k3, (n, ell))
    out = train_coded_head(fleet, None, feats, ys, beta_true, lr=0.05,
                           epochs=250, key=jax.random.PRNGKey(1),
                           rng=np.random.default_rng(0),
                           fixed_c=int(0.3 * n * ell))
    assert out["cfl"].final_nmse() < 5e-2
    # same epoch count, coded deadline < uncoded straggler-wait
    assert out["cfl"].times[-1] - out["cfl"].setup_time \
        < out["uncoded"].times[-1]
