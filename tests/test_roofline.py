"""Tests for the HLO-graph roofline parser (trip-count correction)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_graph import module_stats, parse_computations
from repro.roofline.analysis import active_params, dominant_term, model_flops


def _compile(f, *sds):
    return jax.jit(f).lower(*sds).compile().as_text()


def test_scan_trip_count_correction():
    def f(x, ws):
        def step(c, w):
            return c @ w, None
        return jax.lax.scan(step, x, ws)[0]

    hlo = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                   jax.ShapeDtypeStruct((9, 64, 64), jnp.float32))
    st = module_stats(hlo)
    np.testing.assert_allclose(st["flops"], 9 * 2 * 64 ** 3, rtol=1e-6)


def test_nested_scan():
    def g(x, ws):
        def outer(c, grp):
            def inner(cc, w):
                return cc @ w, None
            return jax.lax.scan(inner, c, grp)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    hlo = _compile(g, jax.ShapeDtypeStruct((32, 32), jnp.float32),
                   jax.ShapeDtypeStruct((4, 6, 32, 32), jnp.float32))
    st = module_stats(hlo)
    np.testing.assert_allclose(st["flops"], 24 * 2 * 32 ** 3, rtol=1e-6)


def test_grad_counts_fwd_and_bwd():
    def f(x, ws):
        def step(c, w):
            return c @ w, None
        return jnp.sum(jax.lax.scan(step, x, ws)[0])

    hlo = _compile(jax.grad(f, argnums=1),
                   jax.ShapeDtypeStruct((48, 48), jnp.float32),
                   jax.ShapeDtypeStruct((5, 48, 48), jnp.float32))
    st = module_stats(hlo)
    # fwd (1x) + bwd (2x) matmuls
    np.testing.assert_allclose(st["flops"], 3 * 5 * 2 * 48 ** 3, rtol=1e-6)


def test_plain_matmul_no_loop():
    def f(a, b):
        return a @ b

    hlo = _compile(f, jax.ShapeDtypeStruct((128, 64), jnp.float32),
                   jax.ShapeDtypeStruct((64, 32), jnp.float32))
    st = module_stats(hlo)
    np.testing.assert_allclose(st["flops"], 2 * 128 * 64 * 32, rtol=1e-6)


def test_bytes_positive_and_finite():
    def f(a, b):
        return jnp.tanh(a @ b)

    hlo = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                   jax.ShapeDtypeStruct((64, 64), jnp.float32))
    st = module_stats(hlo)
    assert st["bytes"] > 0 and np.isfinite(st["bytes"])


def test_parse_computations_handles_tuple_types():
    def f(x, ws):
        def step(c, w):
            return c @ w, c
        return jax.lax.scan(step, x, ws)

    hlo = _compile(f, jax.ShapeDtypeStruct((16, 16), jnp.float32),
                   jax.ShapeDtypeStruct((3, 16, 16), jnp.float32))
    comps = parse_computations(hlo)
    assert len(comps) >= 2  # entry + while body/cond at least
    ops = {i.op for instrs in comps.values() for i in instrs}
    assert "while" in ops and "dot" in ops


def test_model_flops_moe_counts_active_only():
    from repro.configs import get_config
    moe = get_config("phi3.5-moe-42b-a6.6b")
    n_act = active_params(moe)
    # ~6.6B active (paper card); allow generous band
    assert 4e9 < n_act < 9e9
    dense_equiv = 16 / 2 * n_act  # all-expert count would be much larger
    total_moe_mlp = 32 * 16 * 3 * 4096 * 6400
    assert n_act < total_moe_mlp  # sanity: active << total


def test_model_flops_shapes():
    from repro.configs import get_config
    cfg = get_config("granite-8b")
    t = model_flops(cfg, "train_4k")
    p = model_flops(cfg, "prefill_32k")
    d = model_flops(cfg, "decode_32k")
    assert t > p > d
    # train: 6*N*D vs prefill 2*N*D with equal token counts => ratio 3
    np.testing.assert_allclose(t / p, 3.0, rtol=1e-6)


def test_dominant_term():
    assert dominant_term({"t_compute": 3.0, "t_memory": 1.0,
                          "t_collective": 2.0}) == "compute"
    assert dominant_term({"t_compute": 0.0, "t_memory": 1.0,
                          "t_collective": 2.0}) == "collective"


def test_collectives_detected_under_mesh():
    from jax.sharding import NamedSharding, PartitionSpec as P
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("data",))

    def f(x):
        return jax.lax.with_sharding_constraint(
            x.sum(axis=0, keepdims=True), NamedSharding(mesh, P()))

    # trivial mesh: may or may not emit collectives; just verify parser
    # doesn't crash on sharded modules
    with mesh:
        hlo = jax.jit(f).lower(
            jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile().as_text()
    st = module_stats(hlo)
    assert "collectives" in st
