"""Autotuner tests: cache round-trip/versioning, roofline pruning,
deterministic winner selection, `block="auto"` bit-parity across every
kernel entry point, and the perf-trend trajectory gate.

Kernel-touching tests use tiny shapes whose buckets do NOT collide with
the committed `src/repro/tune/defaults.json` entries, and the user cache
is redirected to a tmpdir via $REPRO_TUNE_CACHE_DIR — so `block="auto"`
cold-miss behaviour is actually exercised.
"""
from __future__ import annotations

import json
import os
import sys

import jax
import numpy as np
import pytest

from repro.kernels import common as kcommon
from repro.kernels.coded_grad import coded_grad as _cg
from repro.kernels.coded_grad import ops as cg_ops
from repro.kernels.encode import encode as _en
from repro.kernels.encode import ops as en_ops
from repro.tune import cache as tc
from repro.tune import tuner

# the benchmarks package lives at the repo root, outside src/
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)
from benchmarks import perf_trend  # noqa: E402


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    """User tile cache redirected to a fresh tmpdir (initially empty)."""
    monkeypatch.setenv(tc.CACHE_ENV, str(tmp_path))
    return tc.TileCache(tc.user_cache_path())


# ---------------------------------------------------------------------------
# cache: keys, round-trip, versioning, fallback order
# ---------------------------------------------------------------------------

def test_bucket_shape_pow2_ceiling():
    assert tc.bucket_shape((936, 300, 500)) == (1024, 512, 512)
    assert tc.bucket_shape((1024,)) == (1024,)
    assert tc.bucket_shape((1, 3)) == (1, 4)


def test_cache_key_separates_family_backend_bucket():
    k1 = tc.cache_key("encode", (936, 300, 500), "cpu")
    assert k1 == "encode|cpu|1024x512x512"
    assert tc.cache_key("encode", (1000, 400, 510), "cpu") == k1  # same bucket
    assert tc.cache_key("encode", (936, 300, 500), "tpu") != k1
    assert tc.cache_key("encode_prng", (936, 300, 500), "cpu") != k1


def test_cache_round_trip(tmp_cache):
    tmp_cache.store("encode", (64, 48, 32), "cpu", (32, 32, 32),
                    {"us": 12.5})
    ent = tmp_cache.lookup("encode", (64, 48, 32), "cpu")
    assert ent["block"] == [32, 32, 32] and ent["us"] == 12.5
    # same bucket, different concrete shape -> same entry
    assert tmp_cache.lookup("encode", (50, 40, 30), "cpu") == ent
    assert tc.lookup_block("encode", (64, 48, 32), "cpu") == (32, 32, 32)


def test_cache_store_merges(tmp_cache):
    tmp_cache.store("encode", (64, 48, 32), "cpu", (32, 32, 32))
    tmp_cache.store("coded_grad", (96, 12), "cpu", (64,))
    assert tc.lookup_block("encode", (64, 48, 32), "cpu") == (32, 32, 32)
    assert tc.lookup_block("coded_grad", (96, 12), "cpu") == (64,)


def test_cache_version_mismatch_invalidates(tmp_cache):
    key = tc.cache_key("encode", (64, 48, 32), "cpu")
    os.makedirs(os.path.dirname(tmp_cache.path), exist_ok=True)
    with open(tmp_cache.path, "w") as f:
        json.dump({"version": tc.CACHE_VERSION + 1,
                   "entries": {key: {"block": [8, 8, 8]}}}, f)
    # stale-version file reads as empty ...
    assert tc.lookup_block("encode", (64, 48, 32), "cpu") is None
    # ... and the first store drops its entries wholesale
    tmp_cache.store("coded_grad", (96, 12), "cpu", (64,))
    with open(tmp_cache.path) as f:
        payload = json.load(f)
    assert payload["version"] == tc.CACHE_VERSION
    assert key not in payload["entries"]


def test_committed_defaults_cover_ci_shapes():
    """The in-repo defaults.json must hit for every CPU CI shape — this
    is what makes `block="auto"` tuned on fresh checkouts/CI runners."""
    from repro.tune.families import CI_SHAPES

    for family, shapes in CI_SHAPES.items():
        for shape in shapes:
            ent = tc._load_entries(tc.defaults_path()).get(
                tc.cache_key(family, shape, "cpu"))
            assert ent is not None, (family, shape)
            want_len = 1 if family in ("coded_grad", "round_grad") else 3
            assert len(ent["block"]) == want_len, (family, shape)


def test_user_cache_wins_over_defaults(tmp_cache):
    # (936, 300, 500) IS in the committed defaults; a user entry shadows it
    repo_block = tc.lookup_block("encode", (936, 300, 500), "cpu")
    assert repo_block is not None
    tmp_cache.store("encode", (936, 300, 500), "cpu", (128, 128, 128))
    assert tc.lookup_block("encode", (936, 300, 500), "cpu") == \
        (128, 128, 128)


# ---------------------------------------------------------------------------
# tuner: pruning + deterministic winner (stubbed terms/measure)
# ---------------------------------------------------------------------------

def test_prune_keeps_within_slack_of_best():
    cands = [(256,), (512,), (1024,), (2048,)]
    bounds = [10.0, 19.9, 20.1, 100.0]
    survivors, pruned = tuner.prune(cands, bounds, slack=2.0)
    assert survivors == [(256,), (512,)]
    assert pruned == [(1024,), (2048,)]
    assert sorted(survivors + pruned) == sorted(cands)


def test_prune_zero_bound_keeps_everything():
    """A degenerate lowering with a 0 roofline bound must not collapse
    the slack band and prune every positive-bound candidate."""
    cands = [(256,), (512,), (1024,)]
    survivors, pruned = tuner.prune(cands, [0.0, 5.0, 9.0], slack=2.0)
    assert survivors == cands
    assert pruned == []


def test_roofline_bound_is_binding_term():
    assert tuner.roofline_bound({"t_compute": 2.0, "t_memory": 5.0}) == 5.0
    assert tuner.roofline_bound({"t_compute": 7.0, "t_memory": 5.0}) == 7.0


def test_autotune_measures_only_survivors():
    """A candidate dominated under the roofline model is pruned without
    ever being executed."""
    measured = []

    def terms_fn(block):
        # (512,) gets a 10x-worse lower bound -> pruned at slack=2
        bad = block == (512,)
        return {"t_compute": 10.0 if bad else 1.0, "t_memory": 0.0}

    def measure_fn(block):
        measured.append(block)
        return 100.0

    res = tuner.autotune("coded_grad", (512, 16), slack=2.0,
                         backend="cpu", store=False,
                         terms_fn=terms_fn, measure_fn=measure_fn)
    assert (512,) in res.pruned
    assert (512,) not in measured
    assert measured  # survivors were measured
    # every pruned candidate is provably dominated under the model
    best = min(res.bounds_us)
    for cand, bound in zip(res.candidates, res.bounds_us):
        assert (cand in res.pruned) == (bound > 2.0 * best)


def test_autotune_winner_deterministic_with_ties():
    """Equal measurements -> the EARLIEST candidate in enumeration order
    wins, and a rerun reproduces it exactly."""
    def terms_fn(block):
        return {"t_compute": 1.0, "t_memory": 1.0}

    def measure_fn(block):
        return 42.0  # all tied

    first = tuner.autotune("coded_grad", (512, 16), backend="cpu",
                           store=False, terms_fn=terms_fn,
                           measure_fn=measure_fn)
    again = tuner.autotune("coded_grad", (512, 16), backend="cpu",
                           store=False, terms_fn=terms_fn,
                           measure_fn=measure_fn)
    assert first.block == again.block == first.candidates[0]


def test_autotune_picks_fastest_and_persists(tmp_cache):
    def terms_fn(block):
        return {"t_compute": 1.0, "t_memory": 1.0}

    def measure_fn(block):
        return 10.0 if block == (512,) else 50.0

    res = tuner.autotune("coded_grad", (512, 16), backend="cpu",
                         cache=tmp_cache, terms_fn=terms_fn,
                         measure_fn=measure_fn)
    assert res.block == (512,)
    assert tc.lookup_block("coded_grad", (512, 16), "cpu") == (512,)


def test_candidate_terms_block_sensitive():
    """Real dry-run lowerings: smaller tiles re-stream resident operands
    once per grid step, so the roofline memory term must grow as tiles
    shrink (this is the signal pruning relies on)."""
    from repro.tune.families import FAMILIES

    fam = FAMILIES["coded_grad"]
    shape = (1024, 64)
    b_small = tuner.roofline_bound(
        tuner.candidate_terms(fam, shape, (256,)))
    b_whole = tuner.roofline_bound(
        tuner.candidate_terms(fam, shape, (1024,)))
    assert b_small > b_whole


# ---------------------------------------------------------------------------
# block="auto" bit-parity across every kernel entry point
# ---------------------------------------------------------------------------

def _encode_args(c=64, ell=48, d=32, seed=0):
    key = jax.random.PRNGKey(seed)
    return (jax.random.normal(key, (c, ell)),
            jax.random.uniform(jax.random.fold_in(key, 1), (ell,)),
            jax.random.normal(jax.random.fold_in(key, 2), (ell, d)))


def _fleet_args(n=3, ell=16, d=8, seed=0):
    key = jax.random.PRNGKey(seed)
    return (jax.random.normal(key, (n, ell, d)),
            jax.random.normal(jax.random.fold_in(key, 1), (n, ell)),
            jax.random.uniform(jax.random.fold_in(key, 2), (n, ell)))


def test_encode_parity_auto_cold_miss_is_default(tmp_cache):
    g, w, x = _encode_args()
    np.testing.assert_array_equal(
        np.asarray(en_ops.encode_parity(g, w, x, block="auto")),
        np.asarray(en_ops.encode_parity(g, w, x, block=_en.DEFAULT_BLOCK)))


def test_encode_parity_auto_hit_uses_stored_tile(tmp_cache):
    g, w, x = _encode_args()
    tile = (32, 16, 16)
    tmp_cache.store("encode", (64, 48, 32), kcommon.backend(), tile)
    assert kcommon.resolve_block("encode", (64, 48, 32), "auto",
                                 _en.DEFAULT_BLOCK) == tile
    np.testing.assert_array_equal(
        np.asarray(en_ops.encode_parity(g, w, x, block="auto")),
        np.asarray(en_ops.encode_parity(g, w, x, block=tile)))


def test_encode_fleet_auto_parity(tmp_cache):
    xs, ys, ws = _fleet_args()
    c = 32
    keys = jax.random.split(jax.random.PRNGKey(5), xs.shape[0])
    cold_a = en_ops.encode_fleet(keys, xs, ys, ws, c, block="auto")
    cold_d = en_ops.encode_fleet(keys, xs, ys, ws, c,
                                 block=_en.DEFAULT_BLOCK)
    for a, b in zip(cold_a, cold_d):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    tile = (32, 8, 16)
    tmp_cache.store("encode", (c, xs.shape[1], xs.shape[2]),
                    kcommon.backend(), tile)
    hit_a = en_ops.encode_fleet(keys, xs, ys, ws, c, block="auto")
    hit_e = en_ops.encode_fleet(keys, xs, ys, ws, c, block=tile)
    for a, b in zip(hit_a, hit_e):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_encode_parity_prng_auto_parity(tmp_cache):
    _, w, x = _encode_args()
    c, key = 64, jax.random.PRNGKey(9)
    np.testing.assert_array_equal(
        np.asarray(en_ops.encode_parity_prng(key, w, x, c, block="auto")),
        np.asarray(en_ops.encode_parity_prng(key, w, x, c,
                                             block=_en.DEFAULT_BLOCK)))
    tile = (32, 16, 16)
    tmp_cache.store("encode_prng", (c, x.shape[0], x.shape[1]),
                    kcommon.backend(), tile)
    np.testing.assert_array_equal(
        np.asarray(en_ops.encode_parity_prng(key, w, x, c, block="auto")),
        np.asarray(en_ops.encode_parity_prng(key, w, x, c, block=tile)))


def test_encode_fleet_prng_auto_parity(tmp_cache):
    xs, ys, ws = _fleet_args()
    c, key = 32, jax.random.PRNGKey(3)
    cold_a = en_ops.encode_fleet_prng(key, xs, ys, ws, c, block="auto")
    cold_d = en_ops.encode_fleet_prng(key, xs, ys, ws, c,
                                      block=_en.DEFAULT_BLOCK)
    for a, b in zip(cold_a, cold_d):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lsq_gradient_auto_parity(tmp_cache):
    key = jax.random.PRNGKey(2)
    a = jax.random.normal(key, (96, 12))
    y = jax.random.normal(jax.random.fold_in(key, 1), (96,))
    beta = jax.random.normal(jax.random.fold_in(key, 2), (12,))
    np.testing.assert_array_equal(
        np.asarray(cg_ops.lsq_gradient(a, y, beta, block_m="auto")),
        np.asarray(cg_ops.lsq_gradient(a, y, beta,
                                       block_m=_cg.DEFAULT_BLOCK_M)))
    tmp_cache.store("coded_grad", (96, 12), kcommon.backend(), (64,))
    # 1-d tile families resolve to a plain int
    assert kcommon.resolve_block("coded_grad", (96, 12), "auto",
                                 _cg.DEFAULT_BLOCK_M) == 64
    np.testing.assert_array_equal(
        np.asarray(cg_ops.lsq_gradient(a, y, beta, block_m="auto")),
        np.asarray(cg_ops.lsq_gradient(a, y, beta, block_m=64)))


# ---------------------------------------------------------------------------
# perf-trend trajectory gate
# ---------------------------------------------------------------------------

def _bench_payload(us=1000.0, speedup=10.0):
    return {"schema": 1, "benchmark": "kernels",
            "gates": {"best_encode_tuned_speedup_x": speedup},
            "records": [{"name": "kernels/encode_auto", "us_per_call": us,
                         "derived": ""}]}


def _write(dirpath, payload):
    os.makedirs(dirpath, exist_ok=True)
    with open(os.path.join(dirpath, "BENCH_kernels.json"), "w") as f:
        json.dump(payload, f)


def test_perf_trend_classify_directions():
    assert perf_trend.classify("kernels/encode.us_per_call") == "lower"
    assert perf_trend.classify("gates.best_speedup_x") == "higher"
    assert perf_trend.classify("gates.sessions_per_s") == "higher"
    assert perf_trend.classify("gates.n_clients") is None


def test_perf_trend_classify_suffix_only_for_underscore_patterns():
    """'_s' must match only as a suffix: counts like n_samples stay
    ungated instead of being silently gated lower-is-better."""
    assert perf_trend.classify("gates.n_samples") is None
    assert perf_trend.classify("gates.n_sessions") is None
    assert perf_trend.classify("gates.wall_s") == "lower"


def test_perf_trend_identical_passes(tmp_path):
    base, new = str(tmp_path / "b"), str(tmp_path / "n")
    _write(base, _bench_payload())
    _write(new, _bench_payload())
    assert perf_trend.main(["--baseline-dir", base,
                            "--new-dir", new]) == 0


def test_perf_trend_detects_regressions(tmp_path):
    """A synthetic regression (timing 3x worse, gate halved) must fail."""
    base, new = str(tmp_path / "b"), str(tmp_path / "n")
    _write(base, _bench_payload(us=1000.0, speedup=10.0))
    _write(new, _bench_payload(us=3000.0, speedup=5.0))
    result = perf_trend.compare(perf_trend.load_bench_dir(base),
                                perf_trend.load_bench_dir(new),
                                tol=0.60, gate_tol=0.25)
    assert len(result["regressions"]) == 2
    assert perf_trend.main(["--baseline-dir", base,
                            "--new-dir", new]) == 1


def test_perf_trend_band_absorbs_noise(tmp_path):
    """Worsening WITHIN the band (timing +40% < 60%, gate -10% < 25%)
    passes; improvements always pass."""
    base, new = str(tmp_path / "b"), str(tmp_path / "n")
    _write(base, _bench_payload(us=1000.0, speedup=10.0))
    _write(new, _bench_payload(us=1400.0, speedup=9.0))
    assert perf_trend.main(["--baseline-dir", base,
                            "--new-dir", new]) == 0
    _write(new, _bench_payload(us=100.0, speedup=100.0))
    assert perf_trend.main(["--baseline-dir", base,
                            "--new-dir", new]) == 0


def test_perf_trend_env_tolerance_and_skip(tmp_path, monkeypatch):
    base, new = str(tmp_path / "b"), str(tmp_path / "n")
    _write(base, _bench_payload(us=1000.0))
    _write(new, _bench_payload(us=3000.0))
    # widening the timing band past the 3x regression -> pass
    monkeypatch.setenv("PERF_TREND_TOL", "5.0")
    assert perf_trend.main(["--baseline-dir", base,
                            "--new-dir", new]) == 0
    monkeypatch.delenv("PERF_TREND_TOL")
    # ... or skipping the metric by glob
    monkeypatch.setenv("PERF_TREND_SKIP", "kernels/encode_auto*")
    assert perf_trend.main(["--baseline-dir", base,
                            "--new-dir", new]) == 0


def test_perf_trend_missing_baseline_is_ok(tmp_path):
    empty, new = str(tmp_path / "b"), str(tmp_path / "n")
    os.makedirs(empty)
    _write(new, _bench_payload())
    assert perf_trend.main(["--baseline-dir", empty,
                            "--new-dir", new]) == 0


def test_perf_trend_baseline_nested_inside_new_dir(tmp_path):
    """CI layout: --new-dir is the workspace root and the baseline dir
    sits INSIDE it.  The new-dir scan must skip the baseline's own
    files, or it diffs the baseline against itself and a real
    regression passes silently."""
    root = str(tmp_path)
    base = os.path.join(root, "perf_baseline")
    _write(base, _bench_payload(us=1000.0, speedup=10.0))
    _write(root, _bench_payload(us=5000.0, speedup=2.0))  # regressed run
    new = perf_trend.load_bench_dir(root, exclude=base)
    assert new["kernels"]["records"][0]["us_per_call"] == 5000.0
    assert perf_trend.main(["--baseline-dir", base,
                            "--new-dir", root]) == 1


def test_perf_trend_recurses_into_artifact_subdirs(tmp_path):
    """Artifact downloads nest files under bench-<run>/ subdirs."""
    base = str(tmp_path / "b")
    _write(os.path.join(base, "bench-41"), _bench_payload(us=1000.0))
    new = str(tmp_path / "n")
    _write(new, _bench_payload(us=5000.0))
    assert perf_trend.main(["--baseline-dir", base,
                            "--new-dir", new]) == 1
