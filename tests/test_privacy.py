"""Tests for the `repro.privacy` subsystem: the Rényi-DP accountant for
the subsampled Gaussian mechanism and the batched noise calibration.

Four layers of guarantees:

  * oracle parity — the jitted accountant reproduces the float64 NumPy
    oracle (`repro.privacy.reference`) to <= 1e-6 relative, and at
    `sample_frac == 1` both match the Gaussian mechanism's closed-form
    RDP `alpha / (2 sigma^2)` exactly;
  * DP structure (property tests) — epsilon is monotone in rounds and in
    1/noise, and subsampling only amplifies privacy
    (epsilon(rho < 1) <= epsilon(rho = 1));
  * calibration — `calibrate_noise` round-trips through the oracle's
    `epsilon_spent` to <= 1e-3 relative, batched targets solve exactly
    like solo ones, and infeasible targets raise;
  * integration — `StochasticCodedFL(epsilon_target=...)` calibrates at
    construction, trains end-to-end under `Session`, and surfaces the
    cumulative epsilon trajectory on `TraceReport.extras`.
"""
import jax
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a deterministic fallback

from repro.api import Session, TrainData, make_strategy
from repro.plan import effective_srv_weight, srv_weight_for_epsilon
from repro.privacy import (DEFAULT_ORDERS, calibrate_noise,
                           epsilon_schedule, epsilon_spent)
from repro.privacy.reference import (epsilon_spent_reference,
                                     gaussian_rdp_closed_form,
                                     rdp_sgm_reference)
from repro.schemes import StochasticCodedFL
from repro.sim.network import wireless_fleet


# ---------------------------------------------------------------------------
# oracle parity
# ---------------------------------------------------------------------------

def test_reference_rdp_matches_gaussian_closed_form_at_q1():
    """q = 1 collapses the binomial sum to alpha / (2 sigma^2) exactly."""
    for sigma in (0.5, 1.0, 1.3, 4.0):
        rdp = rdp_sgm_reference(sigma, 1.0)
        closed = gaussian_rdp_closed_form(sigma, DEFAULT_ORDERS)
        np.testing.assert_allclose(rdp, closed, rtol=1e-6)


@settings(max_examples=12, deadline=None)
@given(sigma=st.floats(0.3, 8.0), q=st.floats(0.02, 1.0),
       rounds=st.integers(1, 2000), dexp=st.integers(3, 8))
def test_accountant_matches_reference(sigma, q, rounds, dexp):
    """Jitted accountant == float64 NumPy oracle, <= 1e-6 relative."""
    delta = 10.0 ** -dexp
    got = epsilon_spent(sigma, q, rounds, delta)
    want = epsilon_spent_reference(sigma, q, rounds, delta)
    assert abs(got - want) <= 1e-6 * max(want, 1e-12)


def test_zero_noise_is_infinite_epsilon():
    assert np.isinf(epsilon_spent(0.0, 1.0, 10, 1e-5))
    assert np.all(np.isinf(epsilon_schedule(0.0, 0.5, 7, 1e-5)))


def test_epsilon_spent_broadcasts():
    sigmas = np.array([0.8, 1.6, 3.2])
    out = epsilon_spent(sigmas, 0.9, 200, 1e-5)
    assert out.shape == (3,)
    for s, e in zip(sigmas, out):
        assert e == pytest.approx(epsilon_spent(float(s), 0.9, 200, 1e-5))


# ---------------------------------------------------------------------------
# DP structure: monotonicity + subsampling amplification
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(sigma=st.floats(0.4, 6.0), q=st.floats(0.05, 1.0),
       t1=st.integers(1, 500), extra=st.integers(1, 500))
def test_epsilon_monotone_in_rounds(sigma, q, t1, extra):
    e1 = epsilon_spent(sigma, q, t1, 1e-5)
    e2 = epsilon_spent(sigma, q, t1 + extra, 1e-5)
    assert e2 >= e1 - 1e-12
    sched = epsilon_schedule(sigma, q, 20, 1e-5)
    assert np.all(np.diff(sched) >= -1e-12)


@settings(max_examples=10, deadline=None)
@given(sigma=st.floats(0.4, 6.0), q=st.floats(0.05, 1.0),
       factor=st.floats(1.05, 4.0), rounds=st.integers(1, 500))
def test_epsilon_monotone_in_inverse_noise(sigma, q, factor, rounds):
    """More noise can only shrink the budget spent."""
    e_lo = epsilon_spent(sigma * factor, q, rounds, 1e-5)
    e_hi = epsilon_spent(sigma, q, rounds, 1e-5)
    assert e_lo <= e_hi + 1e-12


@settings(max_examples=10, deadline=None)
@given(sigma=st.floats(0.4, 6.0), q=st.floats(0.02, 0.999),
       rounds=st.integers(1, 500))
def test_subsampling_amplification(sigma, q, rounds):
    """epsilon(rho < 1) <= epsilon(rho = 1)."""
    assert epsilon_spent(sigma, q, rounds, 1e-5) \
        <= epsilon_spent(sigma, 1.0, rounds, 1e-5) + 1e-12


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(target=st.floats(0.2, 30.0), q=st.floats(0.05, 1.0),
       rounds=st.integers(1, 1000))
def test_calibration_roundtrip_vs_oracle(target, q, rounds):
    """calibrate_noise then the ORACLE's epsilon_spent hits the target
    within 1e-3 relative, without ever overspending it."""
    sigma = calibrate_noise(target, delta=1e-5, rounds=rounds,
                            sample_frac=q)
    back = epsilon_spent_reference(sigma, q, rounds, 1e-5)
    assert back <= target * (1.0 + 1e-3)
    assert abs(back - target) <= 1e-3 * target


def test_calibration_batched_matches_solo():
    targets = np.array([0.5, 1.0, 2.0, 8.0, 32.0])
    batch = calibrate_noise(targets, delta=1e-5, rounds=300,
                            sample_frac=0.8)
    solo = [calibrate_noise(float(t), delta=1e-5, rounds=300,
                            sample_frac=0.8) for t in targets]
    np.testing.assert_array_equal(batch, np.array(solo))


def test_calibration_infeasible_target_raises():
    with pytest.raises(RuntimeError, match="achievable floor"):
        calibrate_noise(1e-5, delta=1e-5, rounds=10)


def test_calibration_input_validation():
    with pytest.raises(ValueError):
        calibrate_noise(-1.0, delta=1e-5, rounds=10)
    with pytest.raises(ValueError):
        calibrate_noise(1.0, delta=2.0, rounds=10)
    with pytest.raises(ValueError):
        calibrate_noise(1.0, delta=1e-5, rounds=0)
    with pytest.raises(ValueError):
        epsilon_spent(1.0, sample_frac=0.0, rounds=10)


def test_srv_weight_for_epsilon_matches_calibration():
    targets = np.array([1.0, 4.0, 16.0])
    w = srv_weight_for_epsilon(targets, delta=1e-5, rounds=200,
                               sample_frac=0.8)
    sigma = calibrate_noise(targets, delta=1e-5, rounds=200,
                            sample_frac=0.8)
    np.testing.assert_allclose(w, 0.8 / (1.0 + sigma ** 2), rtol=1e-12)
    # scalar form
    assert srv_weight_for_epsilon(4.0, rounds=200, sample_frac=0.8) \
        == pytest.approx(effective_srv_weight(
            calibrate_noise(4.0, rounds=200, sample_frac=0.8), 0.8))


# ---------------------------------------------------------------------------
# strategy integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small():
    fleet = wireless_fleet(0.2, 0.2, nu_erasure=0.3, seed=0, n=12, d=40)
    data = TrainData.linreg(jax.random.PRNGKey(0), n=12, ell=60, d=40)
    return fleet, data


def test_epsilon_target_construction_calibrates():
    strat = StochasticCodedFL(key=jax.random.PRNGKey(1), fixed_c=100,
                              epsilon_target=4.0, delta=1e-5, rounds=50,
                              sample_frac=0.8)
    sigma = calibrate_noise(4.0, delta=1e-5, rounds=50, sample_frac=0.8)
    assert strat.noise_multiplier == pytest.approx(sigma)
    assert strat.srv_weight == pytest.approx(
        effective_srv_weight(sigma, 0.8))


def test_epsilon_target_strategy_survives_replace():
    """dataclasses.replace re-runs __post_init__ with BOTH epsilon_target
    and the already-calibrated noise set; that must not be a conflict."""
    import dataclasses
    s = StochasticCodedFL(key=jax.random.PRNGKey(1), fixed_c=100,
                          epsilon_target=4.0, rounds=50, sample_frac=0.8)
    s2 = dataclasses.replace(s, label="renamed")
    assert s2.noise_multiplier == s.noise_multiplier
    # changing a budget field with stale noise IS a conflict...
    with pytest.raises(ValueError, match="noise_multiplier=None"):
        dataclasses.replace(s, rounds=100)
    # ...and recalibrates when the caller clears the noise explicitly
    s3 = dataclasses.replace(s, rounds=100, noise_multiplier=None)
    assert s3.noise_multiplier == pytest.approx(
        calibrate_noise(4.0, delta=1e-5, rounds=100, sample_frac=0.8))


def test_epsilon_target_validation():
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="not both"):
        StochasticCodedFL(key=key, epsilon_target=1.0, rounds=10,
                          noise_multiplier=0.5)
    with pytest.raises(ValueError, match="rounds"):
        StochasticCodedFL(key=key, epsilon_target=1.0)
    # omitting both keeps the documented 0.5 default
    assert StochasticCodedFL(key=key).noise_multiplier == 0.5


def test_epsilon_target_trains_and_reports(small):
    """Acceptance path: construct by budget, train end-to-end, read the
    cumulative epsilon off TraceReport.extras."""
    fleet, data = small
    epochs = 30
    strat = make_strategy("stochastic", key_seed=7,
                          fixed_c=int(0.3 * data.m), epsilon_target=8.0,
                          delta=1e-5, rounds=epochs, sample_frac=0.8,
                          include_upload_delay=False)
    rep = Session(strategy=strat, fleet=fleet, lr=0.05,
                  epochs=epochs).run(data, rng=np.random.default_rng(0))

    assert np.all(np.isfinite(rep.nmse))
    assert rep.final_nmse() < rep.nmse[0]
    eps, delta = rep.privacy_budget()
    assert delta == 1e-5
    assert eps <= 8.0 * (1.0 + 1e-3)
    assert eps == pytest.approx(8.0, rel=1e-3)
    assert rep.extras["epsilon_target"] == 8.0
    sched = rep.extras["epsilon_schedule"]
    assert sched.shape == (epochs,)
    assert np.all(np.diff(sched) >= 0.0) and sched[-1] == eps
    assert rep.extras["accounting_rounds"] == epochs


def test_manual_noise_with_horizon_reports_spend(small):
    """rounds= alone prices a manually chosen noise level."""
    fleet, data = small
    strat = StochasticCodedFL(key=jax.random.PRNGKey(3),
                              fixed_c=int(0.3 * data.m),
                              noise_multiplier=1.5, sample_frac=0.5,
                              rounds=20, include_upload_delay=False)
    rep = Session(strategy=strat, fleet=fleet, lr=0.05,
                  epochs=20).run(data, rng=np.random.default_rng(0))
    eps, _ = rep.privacy_budget()
    assert eps == pytest.approx(
        epsilon_spent_reference(1.5, 0.5, 20, 1e-5), rel=1e-6)
    assert "epsilon_target" not in rep.extras


def test_no_horizon_reports_no_budget(small):
    fleet, data = small
    strat = StochasticCodedFL(key=jax.random.PRNGKey(3),
                              fixed_c=int(0.3 * data.m),
                              noise_multiplier=0.5,
                              include_upload_delay=False)
    rep = Session(strategy=strat, fleet=fleet, lr=0.05,
                  epochs=10).run(data, rng=np.random.default_rng(0))
    assert rep.privacy_budget() is None
    assert "epsilon_schedule" not in rep.extras
