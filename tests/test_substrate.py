"""Tests for the substrate: optimizers, checkpointing, data partitioning,
federated trainer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a deterministic fallback

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data.partition import partition_iid, partition_noniid
from repro.data.synthetic import token_batches
from repro.optim.optimizers import (adamw, apply_updates, make_optimizer,
                                    sgd)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quadratic_params():
    return {"w": jnp.array([3.0, -2.0]), "b": jnp.array([1.5])}


def _quadratic_grads(params):
    return jax.grad(
        lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2))(params)


@pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.05, momentum=0.9),
                                 adamw(0.3)])
def test_optimizers_minimize_quadratic(opt):
    params = _quadratic_params()
    state = opt.init(params)
    for _ in range(200):
        grads = _quadratic_grads(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    norm = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(params))
    assert norm < 0.15


def test_adamw_bf16_states():
    opt = adamw(1e-2, state_dtype=jnp.bfloat16)
    params = _quadratic_params()
    state = opt.init(params)
    assert all(m.dtype == jnp.bfloat16 for m in jax.tree.leaves(state.mu))
    grads = _quadratic_grads(params)
    updates, state = opt.update(grads, state, params)
    assert all(bool(jnp.all(jnp.isfinite(u)))
               for u in jax.tree.leaves(updates))


def test_weight_decay_shrinks_params():
    opt = adamw(1e-2, weight_decay=0.5)
    params = {"w": jnp.ones(4)}
    state = opt.init(params)
    zero_grads = {"w": jnp.zeros(4)}
    p = params
    for _ in range(10):
        updates, state = opt.update(zero_grads, state, p)
        p = apply_updates(p, updates)
    assert float(jnp.max(jnp.abs(p["w"]))) < 1.0


def test_make_optimizer_rejects_unknown():
    with pytest.raises(ValueError):
        make_optimizer("lion", 1e-3)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "c": jnp.asarray(3, jnp.int32)}}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, tree)
    save_checkpoint(d, 12, tree)
    assert latest_step(d) == 12
    step, restored = restore_checkpoint(d, template=tree)
    assert step == 12
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(d, template={"a": jnp.ones((3, 3))})


def test_checkpoint_missing_leaf_rejected(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"a": jnp.ones(2)})
    with pytest.raises(KeyError):
        restore_checkpoint(d, template={"a": jnp.ones(2), "b": jnp.ones(2)})


def test_checkpoint_no_dir():
    with pytest.raises(FileNotFoundError):
        restore_checkpoint("/nonexistent/dir")


# ---------------------------------------------------------------------------
# partitioning
# ---------------------------------------------------------------------------

def test_partition_iid_covers_everything():
    rng = np.random.default_rng(0)
    parts = partition_iid(103, 7, rng)
    allidx = np.concatenate(parts)
    assert len(allidx) == 103
    assert len(np.unique(allidx)) == 103


@settings(max_examples=10, deadline=None)
@given(n_clients=st.integers(2, 10), alpha=st.floats(0.05, 10.0))
def test_partition_noniid_covers_everything(n_clients, alpha):
    rng = np.random.default_rng(1)
    labels = rng.integers(0, 5, size=200)
    parts = partition_noniid(labels, n_clients, alpha, rng)
    allidx = np.concatenate([p for p in parts if len(p)])
    assert len(allidx) == 200
    assert len(np.unique(allidx)) == 200


def test_partition_noniid_skew_increases_as_alpha_drops():
    rng = np.random.default_rng(2)
    labels = rng.integers(0, 10, size=5000)

    def skew(alpha):
        parts = partition_noniid(labels, 8, alpha, np.random.default_rng(3))
        # mean per-client label entropy (lower = more skewed)
        ents = []
        for p in parts:
            if len(p) == 0:
                continue
            _, counts = np.unique(labels[p], return_counts=True)
            q = counts / counts.sum()
            ents.append(-(q * np.log(q)).sum())
        return np.mean(ents)

    assert skew(0.05) < skew(100.0)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_token_batches_shapes_and_range():
    it = token_batches(0, batch=4, seq_len=32, vocab=100)
    b = next(it)
    assert b["tokens"].shape == (4, 32) and b["targets"].shape == (4, 32)
    assert int(b["tokens"].max()) < 100 and int(b["tokens"].min()) >= 0
    # targets are next-token shifted
    b2 = next(it)
    assert b2["tokens"].shape == (4, 32)


# ---------------------------------------------------------------------------
# federated trainer
# ---------------------------------------------------------------------------

def test_fed_setup_and_round():
    from repro.fed import FedConfig, fed_setup
    from repro.fed.trainer import round_weights
    from repro.sim.network import paper_fleet

    fleet = paper_fleet(0.2, 0.2, seed=0, n=8, d=100)
    cfg = FedConfig(n_clients=8, sequences_per_client=16,
                    target_sequences=64)
    state = fed_setup(fleet.edge, cfg)
    assert state.plan.t_star > 0
    assert state.plan.loads.sum() >= 0
    assert np.all(state.plan.loads <= 16)
    # expected return covers the target
    assert state.plan.expected_agg >= 64 * 0.999

    rng = np.random.default_rng(0)
    batch_clients = np.repeat(np.arange(8), 4)
    w, dt = round_weights(state, rng, batch_clients)
    assert w.shape == (32,)
    assert dt == pytest.approx(state.plan.t_star)
    # weights are 0 (dropped) or 1/p (importance-scaled)
    nz = w[w > 0]
    assert np.all(nz >= 1.0)


def test_min_return_prob_gates_scheduling_and_clips_weights():
    """FedConfig.min_return_prob: clients below the floor are never
    scheduled, and 1/p_i importance weights are clipped at the floor."""
    from repro.core.delay_model import DeviceDelayParams
    from repro.core.redundancy import RedundancyPlan
    from repro.fed.trainer import (
        FedState, presample_round_weights, round_weights)

    edge = DeviceDelayParams(a=np.array([1e-3, 1e-3]),
                             mu=np.array([100.0, 100.0]),
                             tau=np.array([0.01, 0.01]),
                             p=np.array([0.1, 0.1]))
    plan = RedundancyPlan(loads=np.array([8, 8]), c=0, t_star=1e9,
                          p_return=np.array([0.9, 1e-5, 1.0]),
                          expected_agg=16.0, loads_cap_total=16)
    state = FedState(plan=plan, p_return=np.array([0.9, 1e-5]), edge=edge,
                     min_return_prob=1e-3)
    rng = np.random.default_rng(0)
    batch_clients = np.array([0, 0, 1, 1])
    for _ in range(20):
        w, _ = round_weights(state, rng, batch_clients)
        assert np.all(w[2:] == 0.0), "below-floor client must never land"
        assert np.all(w[:2] <= 1.0 / 1e-3 + 1e-9)  # clip bounds the weight

    # pre-sampled weights replay the exact same generator stream
    w_seq = [round_weights(state, np.random.default_rng(5), batch_clients)[0]
             for _ in range(1)]
    pre = presample_round_weights(state, np.random.default_rng(5), 1)
    np.testing.assert_array_equal(pre[0][batch_clients], w_seq[0])


def test_fed_round_unbiasedness():
    """E[masked weighted sum] == plain sum over many arrival draws."""
    from repro.fed import FedConfig, fed_setup
    from repro.fed.trainer import round_weights
    from repro.sim.network import paper_fleet

    fleet = paper_fleet(0.3, 0.3, seed=1, n=6, d=50)
    cfg = FedConfig(n_clients=6, sequences_per_client=8, target_sequences=24)
    state = fed_setup(fleet.edge, cfg)
    rng = np.random.default_rng(1)
    batch_clients = np.repeat(np.arange(6), 2)
    vals = np.arange(12, dtype=np.float64) + 1.0
    est = np.zeros(12)
    trials = 4000
    for _ in range(trials):
        w, _ = round_weights(state, rng, batch_clients)
        est += w * vals
    est /= trials
    # sequences from scheduled clients (load > 0) must be unbiased
    scheduled = state.plan.loads[batch_clients] > 0
    np.testing.assert_allclose(est[scheduled], vals[scheduled], rtol=0.12)


def test_fed_lm_training_reduces_loss():
    from repro.configs import get_config
    from repro.fed import FedConfig, fed_setup
    from repro.fed.trainer import round_weights
    from repro.launch.steps import make_fed_train_step
    from repro.models import transformer as T
    from repro.optim.optimizers import make_optimizer
    from repro.sim.network import paper_fleet

    cfg = get_config("granite-8b").reduced()
    n_clients, per_client = 4, 2
    B = n_clients * per_client
    fleet = paper_fleet(0.1, 0.1, seed=0, n=n_clients, d=64)
    fcfg = FedConfig(n_clients=n_clients, sequences_per_client=per_client,
                     target_sequences=B)
    state = fed_setup(fleet.edge, fcfg)

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer("adamw", 3e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_fed_train_step(cfg, opt))
    from repro.data.synthetic import token_batches
    it = token_batches(0, batch=B, seq_len=16, vocab=cfg.vocab)
    rng = np.random.default_rng(0)
    batch_clients = np.repeat(np.arange(n_clients), per_client)
    losses = []
    batch = next(it)
    for r in range(10):
        w, _ = round_weights(state, rng, batch_clients)
        params, opt_state, m = step(params, opt_state, batch,
                                    jnp.asarray(w, jnp.float32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
