"""Tests for the always-on serving engine (`repro.serving.FedServeEngine`).

The load-bearing guarantee extends the sweep engine's: a served lane is
the SAME computation as a solo `Session.run` truncated at the reported
exit epoch — same planning, same identity-keyed randomness, and a
while-loop body built from the same `make_epoch_step` program the scan
engine traces.  Every trace comparison here is exact
(`assert_array_equal`), never approximate, and admission order must be
unobservable in any per-session result.
"""
import jax
import numpy as np
import pytest

from repro.api import Session, TrainData, make_strategy
from repro.serving import (ConvergenceCriterion, FedServeEngine,
                           poisson_arrivals)
from repro.sim.network import paper_fleet, wireless_fleet

EPOCHS = 25
LR = 0.05
STRATEGIES = ["uncoded", "cfl", "gradcode", "stochastic", "lowlatency"]


@pytest.fixture(scope="module")
def small():
    fleet = paper_fleet(0.2, 0.2, seed=1, n=12, d=40)
    wfleet = wireless_fleet(0.2, 0.2, nu_erasure=0.3, seed=0, n=12, d=40)
    data = TrainData.linreg(jax.random.PRNGKey(0), n=12, ell=60, d=40)
    return fleet, wfleet, data


def _sessions_for(name: str, small, epochs: int = EPOCHS):
    """Per-strategy serve workloads; distinct per-session seeds so
    arrival-order tests can tell the sessions apart."""
    fleet, wfleet, data = small
    c = int(0.3 * data.m)
    if name == "uncoded":
        return [Session(strategy=make_strategy("uncoded"), fleet=fleet,
                        lr=lr, epochs=epochs, seed=10 + i)
                for i, lr in enumerate((0.05, 0.03))]
    if name == "cfl":
        return [Session(strategy=make_strategy("cfl", key_seed=seed,
                                               fixed_c=c),
                        fleet=fleet, lr=LR, epochs=epochs, seed=20 + seed)
                for seed in (7, 8, 9)]
    if name == "gradcode":
        return [Session(strategy=make_strategy("gradcode", r=3),
                        fleet=fleet, lr=lr, epochs=epochs, seed=30 + i)
                for i, lr in enumerate((0.05, 0.04))]
    if name == "stochastic":
        return [Session(strategy=make_strategy(
            "stochastic", key_seed=7, fixed_c=c, noise_multiplier=sigma,
            sample_frac=0.8, rounds=epochs),
            fleet=wfleet, lr=LR, epochs=epochs, seed=40 + i)
            for i, sigma in enumerate((0.0, 0.5))]
    if name == "lowlatency":
        return [Session(strategy=make_strategy(
            "lowlatency", key_seed=seed, fixed_c=c, chunks=4),
            fleet=wfleet, lr=LR, epochs=epochs, seed=50 + seed)
            for seed in (7, 11)]
    raise ValueError(name)


def _assert_prefix_of_solo(report, session, data):
    """Bit-for-bit: the served trace is the solo trace truncated at the
    reported exit epoch, with the exit point on extras."""
    solo = session.run(data, rng=np.random.default_rng(session.seed))
    t = report.extras["serve_exit_epoch"]
    assert 0 <= t <= session.epochs
    assert report.nmse.shape == (t + 1,)
    np.testing.assert_array_equal(report.nmse, solo.nmse[:t + 1])
    np.testing.assert_array_equal(report.times, solo.times[:t + 1])
    np.testing.assert_array_equal(report.epoch_durations,
                                  solo.epoch_durations[:t])
    assert report.label == solo.label
    assert report.setup_time == solo.setup_time
    return solo, t


# ---------------------------------------------------------------------------
# full-budget serving == solo runs, all five registered strategies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", STRATEGIES)
def test_serve_full_budget_equals_solo(small, name):
    """With the default (disabled) criterion a served session runs its
    whole fixed epoch count and reproduces the solo report exactly —
    trace, clock, uplink pricing, and every strategy extra."""
    _, _, data = small
    sessions = _sessions_for(name, small)
    engine = FedServeEngine(data, lane_width=2, chunk=10)
    reports = engine.serve(sessions)
    for sess, rep in zip(sessions, reports):
        solo, t = _assert_prefix_of_solo(rep, sess, data)
        assert t == sess.epochs
        assert rep.extras["serve_converged"] is False
        assert rep.uplink_bits_total == solo.uplink_bits_total
        for k, v in solo.extras.items():
            np.testing.assert_array_equal(np.asarray(rep.extras[k]),
                                          np.asarray(v))
        assert set(rep.extras) - set(solo.extras) == {
            "serve_exit_epoch", "serve_converged", "serve_uid"}


@pytest.mark.parametrize("name", STRATEGIES)
def test_serve_early_exit_prefix_parity(small, name):
    """An NMSE-target early exit stops the lane at the FIRST epoch the
    solo trace crosses the target, and the served trace is bit-for-bit
    that solo prefix."""
    _, _, data = small
    target = 0.35
    sessions = _sessions_for(name, small)
    engine = FedServeEngine(
        data, lane_width=2, chunk=7,
        criterion=ConvergenceCriterion(nmse_target=target))
    reports = engine.serve(sessions)
    assert any(r.extras["serve_exit_epoch"] < s.epochs
               for r, s in zip(reports, sessions))
    for sess, rep in zip(sessions, reports):
        solo, t = _assert_prefix_of_solo(rep, sess, data)
        if rep.extras["serve_converged"]:
            hit = np.nonzero(solo.nmse[1:] <= target)[0]
            assert hit.size and int(hit[0]) + 1 == t
        else:
            assert t == sess.epochs
            assert not np.any(solo.nmse[1:] <= target)


def test_relative_plateau_exit(small):
    """The rel_delta clause fires when one epoch moves NMSE by less than
    the relative threshold; min_epochs holds it off before that."""
    fleet, _, data = small
    sess = Session(strategy=make_strategy("uncoded"), fleet=fleet,
                   lr=0.01, epochs=60, seed=3)
    engine = FedServeEngine(
        data, lane_width=2, chunk=16,
        criterion=ConvergenceCriterion(rel_delta=5e-2, min_epochs=5))
    [rep] = engine.serve([sess])
    solo, t = _assert_prefix_of_solo(rep, sess, data)
    assert rep.extras["serve_converged"] and 5 <= t < sess.epochs
    rel = np.abs(np.diff(solo.nmse)) / solo.nmse[:-1]
    assert rel[t - 1] <= 5e-2  # the epoch that tripped it
    assert not np.any(rel[4:t - 1] <= 5e-2)  # and none eligible before


# ---------------------------------------------------------------------------
# admission-order independence
# ---------------------------------------------------------------------------

def test_arrival_order_independent_traces(small):
    """Permuting the arrival interleaving of a mixed workload must leave
    every per-session report bit-identical: randomness is keyed on each
    session's stable identity, never on admission order."""
    fleet, wfleet, data = small
    c1, c2 = int(0.2 * data.m), int(0.4 * data.m)
    sessions = [
        Session(strategy=make_strategy("uncoded"), fleet=fleet, lr=LR,
                epochs=EPOCHS, seed=60),
        Session(strategy=make_strategy("cfl", key_seed=7, fixed_c=c1),
                fleet=fleet, lr=LR, epochs=EPOCHS, seed=61),
        Session(strategy=make_strategy("cfl", key_seed=7, fixed_c=c2),
                fleet=fleet, lr=LR, epochs=EPOCHS, seed=62),
        Session(strategy=make_strategy("lowlatency", key_seed=7, fixed_c=c1,
                                       chunks=4),
                fleet=wfleet, lr=LR, epochs=EPOCHS, seed=63),
    ]
    arrivals = [0.0, 1.0, 2.0, 3.0]

    def run(order):
        engine = FedServeEngine(data, lane_width=2, chunk=9)
        uids = engine.submit_many([sessions[i] for i in order],
                                  arrivals=[arrivals[i] for i in order])
        engine.drain()
        reports = [engine._done[u] for u in uids]
        return {order[k]: reports[k] for k in range(len(order))}

    base = run([0, 1, 2, 3])
    perm = run([3, 0, 2, 1])
    for i in range(len(sessions)):
        np.testing.assert_array_equal(base[i].nmse, perm[i].nmse)
        np.testing.assert_array_equal(base[i].epoch_durations,
                                      perm[i].epoch_durations)
        assert base[i].extras["serve_exit_epoch"] == \
            perm[i].extras["serve_exit_epoch"]
        _assert_prefix_of_solo(base[i], sessions[i], data)


# ---------------------------------------------------------------------------
# slot churn: converged lanes free capacity for the queue
# ---------------------------------------------------------------------------

def test_churn_more_sessions_than_slots(small):
    """Six same-bucket sessions through two lane slots: every session
    completes with solo parity in ONE group, finished lanes being
    swapped out for pending arrivals."""
    fleet, _, data = small
    c = int(0.3 * data.m)
    sessions = [Session(strategy=make_strategy("cfl", key_seed=7, fixed_c=c),
                        fleet=fleet, lr=LR, epochs=EPOCHS, seed=70 + i)
                for i in range(6)]
    arrivals = poisson_arrivals(6, 0.5, np.random.default_rng(0))
    engine = FedServeEngine(
        data, lane_width=2, chunk=6,
        criterion=ConvergenceCriterion(nmse_target=0.35))
    reports = engine.serve(sessions, arrivals=list(arrivals))
    assert len(reports) == 6 and engine.n_groups == 1
    assert engine.n_active == 0 and engine.n_pending == 0
    for sess, rep in zip(sessions, reports):
        _assert_prefix_of_solo(rep, sess, data)
        assert rep.extras["serve_converged"]


# ---------------------------------------------------------------------------
# epsilon-budget exhaustion + schedule truncation (StochasticCodedFL)
# ---------------------------------------------------------------------------

def test_epsilon_budget_exhaustion_caps_epochs(small):
    """A DP-budgeted stochastic session stops at its accounting horizon:
    `serve_convergence` caps the epoch budget at `rounds`, and the run is
    a solo prefix of exactly that length."""
    _, wfleet, data = small
    c = int(0.3 * data.m)
    rounds = 10
    sess = Session(strategy=make_strategy(
        "stochastic", key_seed=7, fixed_c=c, epsilon_target=5.0,
        delta=1e-5, sample_frac=0.8, rounds=rounds),
        fleet=wfleet, lr=LR, epochs=EPOCHS, seed=80)
    engine = FedServeEngine(data, lane_width=2, chunk=8)
    [rep] = engine.serve([sess])
    _, t = _assert_prefix_of_solo(rep, sess, data)
    assert t == rounds
    assert rep.extras["serve_converged"] is False  # budget, not convergence
    assert rep.extras["accounting_rounds"] == rounds
    assert len(rep.extras["epsilon_schedule"]) == rounds


def test_epsilon_schedule_truncated_on_early_exit(small):
    """When convergence beats the accounting horizon, the reported
    cumulative epsilon schedule (and the spend) truncate to the epochs
    actually served."""
    _, wfleet, data = small
    c = int(0.3 * data.m)
    sess = Session(strategy=make_strategy(
        "stochastic", key_seed=7, fixed_c=c, noise_multiplier=0.5,
        sample_frac=0.8, rounds=EPOCHS),
        fleet=wfleet, lr=LR, epochs=EPOCHS, seed=81)
    engine = FedServeEngine(
        data, lane_width=2, chunk=8,
        criterion=ConvergenceCriterion(nmse_target=0.5))
    [rep] = engine.serve([sess])
    solo, t = _assert_prefix_of_solo(rep, sess, data)
    assert rep.extras["serve_converged"] and 0 < t < EPOCHS
    full = np.asarray(solo.extras["epsilon_schedule"])
    cut = np.asarray(rep.extras["epsilon_schedule"])
    assert cut.shape == (t,)
    np.testing.assert_array_equal(cut, full[:t])
    assert rep.extras["epsilon_spent"] == float(full[t - 1])
    assert rep.extras["accounting_rounds"] == t
    assert rep.privacy_budget() is not None


# ---------------------------------------------------------------------------
# scheduler/criterion unit behavior
# ---------------------------------------------------------------------------

def test_criterion_validation():
    with pytest.raises(ValueError, match="min_epochs"):
        ConvergenceCriterion(min_epochs=0)
    with pytest.raises(ValueError, match="max_epochs"):
        ConvergenceCriterion(max_epochs=-1)
    assert ConvergenceCriterion(max_epochs=10).budget(25) == 10
    assert ConvergenceCriterion().budget(25) == 25
    with pytest.raises(ValueError, match="rate"):
        poisson_arrivals(4, 0.0, np.random.default_rng(0))


def test_duplicate_uid_rejected(small):
    fleet, _, data = small
    sess = Session(strategy=make_strategy("uncoded"), fleet=fleet, lr=LR,
                   epochs=5, seed=0)
    engine = FedServeEngine(data, lane_width=2, chunk=4)
    engine.submit(sess, uid=5)
    with pytest.raises(ValueError, match="duplicate"):
        engine.submit(sess, uid=5)


def test_serve_engine_programs_are_cached(small):
    """Two engines over the same workload shape share compiled programs
    through the process-wide engine cache."""
    from repro.api.session import _ENGINE_CACHE
    fleet, _, data = small
    sess = Session(strategy=make_strategy("uncoded"), fleet=fleet, lr=LR,
                   epochs=EPOCHS, seed=90)
    FedServeEngine(data, lane_width=2, chunk=10).serve([sess])
    before = len(_ENGINE_CACHE)
    FedServeEngine(data, lane_width=2, chunk=10).serve([sess])
    assert len(_ENGINE_CACHE) == before


# ---------------------------------------------------------------------------
# bounded (LRU) engine cache
# ---------------------------------------------------------------------------

def test_engine_cache_lru_semantics(monkeypatch):
    """`cache_engine` is a capped LRU: hits refresh recency, inserts past
    the cap (env-overridable) evict the least-recently-used entry."""
    from repro.api.session import _ENGINE_CACHE, cache_engine

    saved = dict(_ENGINE_CACHE)
    _ENGINE_CACHE.clear()
    try:
        monkeypatch.setenv("REPRO_ENGINE_CACHE_MAX", "2")
        builds = []

        def make(tag):
            def build():
                builds.append(tag)
                return tag
            return build

        assert cache_engine(("k", 1), make("e1")) == "e1"
        assert cache_engine(("k", 2), make("e2")) == "e2"
        # hit: no rebuild, refreshes ("k", 1) to most-recent
        assert cache_engine(("k", 1), make("e1b")) == "e1"
        assert builds == ["e1", "e2"]
        # insert past the cap: evicts ("k", 2), the LRU entry
        assert cache_engine(("k", 3), make("e3")) == "e3"
        assert list(_ENGINE_CACHE) == [("k", 1), ("k", 3)]
        # the evicted key rebuilds
        assert cache_engine(("k", 2), make("e2b")) == "e2b"
        assert builds == ["e1", "e2", "e3", "e2b"]

        # a nonsense override falls back to the default cap (>= 1 floor)
        monkeypatch.setenv("REPRO_ENGINE_CACHE_MAX", "not-a-number")
        from repro.api.session import engine_cache_max
        assert engine_cache_max() == 64
        monkeypatch.setenv("REPRO_ENGINE_CACHE_MAX", "-5")
        assert engine_cache_max() == 1
    finally:
        _ENGINE_CACHE.clear()
        _ENGINE_CACHE.update(saved)


def test_engine_cache_eviction_never_breaks_inflight_buckets(
        monkeypatch, small):
    """Regression: with the cache capped at ONE entry, a mixed workload
    whose buckets evict each other's engines mid-serve must still finish
    every session with a bit-exact solo-prefix trace — lane groups pin
    their own step_fn at creation, so eviction only costs rebuilds."""
    from repro.api.session import _ENGINE_CACHE

    fleet, _, data = small
    saved = dict(_ENGINE_CACHE)
    _ENGINE_CACHE.clear()
    try:
        monkeypatch.setenv("REPRO_ENGINE_CACHE_MAX", "1")
        c = int(0.3 * data.m)
        sessions = [
            Session(strategy=make_strategy("uncoded"), fleet=fleet,
                    lr=LR, epochs=EPOCHS, seed=70),
            Session(strategy=make_strategy("cfl", key_seed=7, fixed_c=c),
                    fleet=fleet, lr=LR, epochs=EPOCHS, seed=71),
            Session(strategy=make_strategy("uncoded"), fleet=fleet,
                    lr=0.03, epochs=EPOCHS, seed=72),
            Session(strategy=make_strategy("cfl", key_seed=8, fixed_c=c),
                    fleet=fleet, lr=LR, epochs=EPOCHS, seed=73),
        ]
        engine = FedServeEngine(data, lane_width=2, chunk=7)
        reports = engine.serve(sessions)
        assert engine.n_groups >= 2      # >= 2 buckets under a 1-entry cap
        assert len(_ENGINE_CACHE) <= 1   # the cap held throughout
        for rep, sess in zip(reports, sessions):
            _assert_prefix_of_solo(rep, sess, data)
    finally:
        _ENGINE_CACHE.clear()
        _ENGINE_CACHE.update(saved)
