"""Per-architecture smoke tests: reduced family-preserving variants
(<= 2 layers, d_model <= 512, <= 4 experts) run one forward + one train step
on CPU, asserting output shapes and no NaNs.  The FULL configs are exercised
compile-only by the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.optim.optimizers import make_optimizer

ARCHS = list_archs()


def _batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    b = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
    }
    if cfg.vlm:
        b["patches"] = 0.1 * jax.random.normal(
            ks[2], (B, cfg.vlm.n_patches, cfg.vlm.d_vision))
    if cfg.encdec:
        b["frames"] = 0.1 * jax.random.normal(
            ks[2], (B, cfg.encdec.n_frames, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_limits(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    # the reduced variant keeps the family
    assert cfg.arch_type == get_config(arch).arch_type


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    batch = _batch(cfg, jax.random.fold_in(key, 1))
    logits, aux = T.forward_train(cfg, params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits))), "NaN/Inf in logits"
    if cfg.moe:
        assert bool(jnp.isfinite(aux["moe_aux_loss"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    opt = make_optimizer("adamw", 1e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, compute_dtype=jnp.float32,
                                   remat=False))
    batch = _batch(cfg, jax.random.fold_in(key, 1))
    params2, opt_state2, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed and stayed finite
    delta = jax.tree.reduce(
        lambda acc, x: acc + float(jnp.sum(jnp.abs(x))),
        jax.tree.map(lambda a, b: (a - b).astype(jnp.float32),
                     params, params2), 0.0)
    assert delta > 0
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree.leaves(params2))


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_decreases_over_few_steps(arch):
    """Overfit one tiny batch for 8 steps: loss must drop (training works)."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = T.init_params(cfg, key)
    opt = make_optimizer("adamw", 3e-3)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, compute_dtype=jnp.float32,
                                   remat=False))
    batch = _batch(cfg, jax.random.fold_in(key, 1))
    first = last = None
    for i in range(8):
        params, opt_state, metrics = step(params, opt_state, batch)
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        last = loss
    assert last < first, f"loss did not decrease: {first} -> {last}"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_cache_roundtrip(arch):
    """prefill + decode_step logits == full-forward logits (exactness)."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(3)
    params = T.init_params(cfg, key)
    B, S = 2, 12
    batch = _batch(cfg, jax.random.fold_in(key, 1), B=B, S=S + 1)
    toks = batch["tokens"]
    pre = dict(batch)
    pre["tokens"] = toks[:, :S]
    pre.pop("targets")
    full, _ = T.forward_train(cfg, params, batch)
    pl_, cache = T.prefill(cfg, params, pre, compute_dtype=jnp.float32,
                           cache_len=S + 4)
    np.testing.assert_allclose(np.asarray(pl_[:, 0]),
                               np.asarray(full[:, S - 1]), rtol=2e-3,
                               atol=2e-3)
    dl, _ = T.decode_step(cfg, params,
                          {"token": toks[:, S:S + 1],
                           "pos": jnp.asarray(S, jnp.int32)},
                          cache, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(dl[:, 0]), np.asarray(full[:, S]),
                               rtol=2e-3, atol=2e-3)
