"""Continuous-batching engine tests: slot management, per-slot positions,
and exactness vs a straight prefill+decode of the same prompt."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-8b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _greedy_reference(cfg, params, prompt, n_new, max_seq):
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, cache = T.prefill(cfg, params, {"tokens": toks},
                              compute_dtype=jnp.float32, cache_len=max_seq)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    tok = out[-1]
    for _ in range(n_new - 1):
        logits, cache = T.decode_step(
            cfg, params, {"token": jnp.asarray([[tok]], jnp.int32),
                          "pos": jnp.asarray(pos, jnp.int32)},
            cache, compute_dtype=jnp.float32)
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
        pos += 1
    return out


def test_single_request_matches_reference(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, 12).astype(np.int32)
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=32)
    done = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=6)])
    assert len(done) == 1
    ref = _greedy_reference(cfg, params, prompt, 6, 32)
    assert done[0].out_tokens == ref


def test_continuous_batching_more_requests_than_slots(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab,
                                               8 + i).astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=32)
    done = eng.run(reqs)
    assert len(done) == 5
    assert sorted(r.uid for r in done) == [0, 1, 2, 3, 4]
    for r in done:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)


def test_interleaved_slots_are_isolated(setup):
    """Two concurrent requests must produce the same tokens as when run
    alone — slot caches must not leak into each other."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, cfg.vocab, 10).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab, 14).astype(np.int32)

    solo1 = _greedy_reference(cfg, params, p1, 5, 40)
    solo2 = _greedy_reference(cfg, params, p2, 5, 40)

    eng = ServeEngine(cfg, params, n_slots=2, max_seq=40)
    done = eng.run([Request(uid=1, prompt=p1, max_new_tokens=5),
                    Request(uid=2, prompt=p2, max_new_tokens=5)])
    by_uid = {r.uid: r.out_tokens for r in done}
    assert by_uid[1] == solo1
    assert by_uid[2] == solo2


def test_oversized_head_does_not_starve_queue(setup):
    """Head-of-line regression: a request whose prompt can never fit in
    the cache must be rejected — not admitted (cache overflow) and not
    left blocking the queue head while admissible requests starve."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    big = Request(uid=0, prompt=rng.integers(0, cfg.vocab,
                                             64).astype(np.int32),
                  max_new_tokens=4)
    ok = [Request(uid=i, prompt=rng.integers(0, cfg.vocab,
                                             8).astype(np.int32),
                  max_new_tokens=4) for i in (1, 2)]
    eng = ServeEngine(cfg, params, n_slots=1, max_seq=32)
    assert not eng.fits(big) and all(eng.fits(r) for r in ok)
    assert not eng.try_admit(big)
    done = eng.run([big] + ok, max_steps=200)
    assert sorted(r.uid for r in done) == [1, 2]  # big rejected, rest served
    assert big.out_tokens == [] and big.slot is None
    for r in done:
        assert len(r.out_tokens) == 4


def test_run_admits_past_momentarily_blocked_head(setup):
    """With one slot busy, admission must keep scanning the queue rather
    than spin on the head: every queued request still completes."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab,
                                               6 + 2 * i).astype(np.int32),
                    max_new_tokens=3) for i in range(4)]
    eng = ServeEngine(cfg, params, n_slots=1, max_seq=32)
    done = eng.run(reqs, max_steps=200)
    assert sorted(r.uid for r in done) == [0, 1, 2, 3]


def test_ssm_engine(setup):
    cfg = get_config("mamba2-1.3b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 9).astype(np.int32)
    eng = ServeEngine(cfg, params, n_slots=2, max_seq=24)
    done = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=4)])
    ref = _greedy_reference(cfg, params, prompt, 4, 24)
    assert done[0].out_tokens == ref
