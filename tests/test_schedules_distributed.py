"""Tests for LR schedules, gradient clipping, and the multi-host bootstrap's
single-process paths."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.distributed import (initialize_distributed, is_coordinator,
                                      sync_hosts, validate_mesh_capacity)
from repro.optim.schedules import (clip_by_global_norm, constant,
                                   cosine_with_warmup, global_norm)


def test_constant_schedule():
    s = constant(3e-4)
    assert float(s(0)) == pytest.approx(3e-4)
    assert float(s(10_000)) == pytest.approx(3e-4)


def test_cosine_with_warmup_shape():
    s = cosine_with_warmup(1.0, warmup_steps=10, total_steps=110,
                           final_frac=0.1)
    assert float(s(0)) == 0.0
    assert float(s(5)) == pytest.approx(0.5)
    assert float(s(10)) == pytest.approx(1.0, abs=1e-6)
    mid = float(s(60))
    assert 0.1 < mid < 1.0
    assert float(s(110)) == pytest.approx(0.1, abs=1e-6)
    # monotone decay after warmup
    vals = [float(s(t)) for t in range(10, 111, 10)]
    assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


def test_global_norm_and_clip():
    g = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(9) * 4.0}
    n = float(global_norm(g))
    assert n == pytest.approx(np.sqrt(4 * 9 + 9 * 16))
    clipped, pre = clip_by_global_norm(g, max_norm=1.0)
    assert float(pre) == pytest.approx(n)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # under the threshold: unchanged
    small = {"a": jnp.ones(2) * 0.1}
    same, _ = clip_by_global_norm(small, max_norm=10.0)
    np.testing.assert_allclose(np.asarray(same["a"]),
                               np.asarray(small["a"]), rtol=1e-6)


def test_distributed_noop_without_cluster_env(monkeypatch):
    monkeypatch.delenv("COORDINATOR_ADDRESS", raising=False)
    monkeypatch.delenv("JAX_PLATFORMS", raising=False)
    assert initialize_distributed() is False
    assert is_coordinator()
    sync_hosts()  # no-op single process


def test_validate_mesh_capacity_raises_on_host():
    with pytest.raises(RuntimeError):
        validate_mesh_capacity()  # host has 1 device, mesh wants 256
