"""Per-kernel allclose tests vs the pure-jnp oracles (interpret mode on CPU),
with hypothesis shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a deterministic fallback

from repro.kernels.coded_grad import ops as cg_ops
from repro.kernels.encode import ops as en_ops
from repro.kernels.ssd import ops as ssd_ops
from repro.models.ssm import ssd_chunk_reference, ssd_chunked


# ---------------------------------------------------------------------------
# coded_grad: fused A^T(A beta - y)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,d", [(1, 1), (7, 3), (64, 8), (937, 500),
                                 (1024, 512), (2048, 128)])
def test_coded_grad_matches_ref(m, d):
    key = jax.random.PRNGKey(m * 1000 + d)
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.random.normal(k1, (m, d))
    y = jax.random.normal(k2, (m,))
    beta = jax.random.normal(k3, (d,))
    got = cg_ops.lsq_gradient(a, y, beta)
    want = cg_ops.reference(a, y, beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4 * np.abs(want).max())


@pytest.mark.parametrize("block_m", [32, 128, 1024])
def test_coded_grad_block_sweep(block_m):
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (300, 64))
    y = jax.random.normal(jax.random.fold_in(key, 1), (300,))
    beta = jax.random.normal(jax.random.fold_in(key, 2), (64,))
    got = cg_ops.lsq_gradient(a, y, beta, block_m=block_m)
    want = cg_ops.reference(a, y, beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                               atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 200), d=st.integers(1, 64),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_coded_grad_property(m, d, dtype):
    key = jax.random.PRNGKey(m * 100 + d)
    a = jax.random.normal(key, (m, d), dtype=dtype)
    y = jax.random.normal(jax.random.fold_in(key, 1), (m,), dtype=dtype)
    beta = jax.random.normal(jax.random.fold_in(key, 2), (d,), dtype=dtype)
    got = cg_ops.lsq_gradient(a, y, beta, block_m=64)
    want = cg_ops.reference(a.astype(jnp.float32), y.astype(jnp.float32),
                            beta.astype(jnp.float32))
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want), rtol=tol,
                               atol=tol * max(1.0, float(np.abs(want).max())))


# ---------------------------------------------------------------------------
# encode: fused G (W X)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c,l,d", [(1, 1, 1), (17, 33, 65), (936, 300, 500),
                                   (128, 256, 128)])
def test_encode_matches_ref(c, l, d):
    key = jax.random.PRNGKey(c + l + d)
    g = jax.random.normal(key, (c, l))
    w = jax.random.uniform(jax.random.fold_in(key, 1), (l,))
    x = jax.random.normal(jax.random.fold_in(key, 2), (l, d))
    got = en_ops.encode_parity(g, w, x)
    want = en_ops.reference(g, w, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                               atol=2e-4 * np.abs(want).max())


@pytest.mark.parametrize("block", [(32, 32, 32), (128, 128, 128),
                                   (64, 128, 32)])
def test_encode_block_sweep(block):
    key = jax.random.PRNGKey(5)
    g = jax.random.normal(key, (100, 70))
    w = jax.random.uniform(jax.random.fold_in(key, 1), (70,))
    x = jax.random.normal(jax.random.fold_in(key, 2), (70, 50))
    got = en_ops.encode_parity(g, w, x, block=block)
    want = en_ops.reference(g, w, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                               atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(c=st.integers(1, 64), l=st.integers(1, 64), d=st.integers(1, 64))
def test_encode_property(c, l, d):
    key = jax.random.PRNGKey(c * 10000 + l * 100 + d)
    g = jax.random.normal(key, (c, l))
    w = jax.random.uniform(jax.random.fold_in(key, 1), (l,))
    x = jax.random.normal(jax.random.fold_in(key, 2), (l, d))
    got = en_ops.encode_parity(g, w, x, block=(16, 16, 16))
    want = en_ops.reference(g, w, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                               atol=1e-3)


def test_encode_fleet_matches_explicit_generator_oracle():
    """The streamed fleet path (no generator stack materialized) equals the
    explicit (n, c, ell) generator-stack oracle drawn with the same keys."""
    from repro.core.encoding import generator_matrix

    key = jax.random.PRNGKey(21)
    n, ell, d, c = 3, 20, 9, 11
    xs = jax.random.normal(key, (n, ell, d))
    ys = jax.random.normal(jax.random.fold_in(key, 1), (n, ell))
    ws = jax.random.uniform(jax.random.fold_in(key, 2), (n, ell),
                            minval=0.2, maxval=1.0)
    keys = jax.random.split(jax.random.PRNGKey(33), n)
    got_x, got_y = en_ops.encode_fleet(keys, xs, ys, ws, c,
                                       block=(16, 16, 16))
    gs = jnp.stack([generator_matrix(k, c, ell, dtype=xs.dtype)
                    for k in keys])
    want_x, want_y = en_ops.reference_fleet(gs, ws, xs, ys)
    np.testing.assert_allclose(np.asarray(got_x), np.asarray(want_x),
                               rtol=2e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=2e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# encode: in-kernel threefry PRNG variant (no materialized generator)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c,l", [(1, 1), (5, 9), (33, 7), (64, 48),
                                 (128, 100)])
@pytest.mark.parametrize("kind", ["normal", "bernoulli"])
def test_prng_generator_bit_equals_host_prng(c, l, kind):
    """The in-kernel tile generator replays the HOST PRNG exactly: the
    oracle over all tiles is bit-identical to `generator_matrix` (odd
    sizes exercise jax's zero-padded counter pairing)."""
    from repro.core.encoding import generator_matrix

    key = jax.random.PRNGKey(c * 100 + l)
    want = generator_matrix(key, c, l, kind=kind)
    got = en_ops.generator_values(key, c, l, kind=kind)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block", [(16, 16, 16), (32, 64, 16),
                                   (128, 128, 128)])
def test_encode_prng_matches_host_path(block):
    """Fused in-kernel-generator encode == the host-PRNG kernel path fed
    the materialized G (same bits, matmul-tiling rounding only)."""
    key = jax.random.PRNGKey(11)
    c, l, d = 60, 45, 33
    w = jax.random.uniform(jax.random.fold_in(key, 1), (l,))
    x = jax.random.normal(jax.random.fold_in(key, 2), (l, d))
    from repro.core.encoding import generator_matrix
    g = generator_matrix(key, c, l)
    want = en_ops.reference(g, w, x)
    got = en_ops.encode_parity_prng(key, w, x, c, block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                               atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(c=st.integers(1, 48), l=st.integers(1, 48), d=st.integers(1, 32))
def test_encode_prng_property(c, l, d):
    key = jax.random.PRNGKey(c * 10000 + l * 100 + d)
    w = jax.random.uniform(jax.random.fold_in(key, 1), (l,))
    x = jax.random.normal(jax.random.fold_in(key, 2), (l, d))
    from repro.core.encoding import generator_matrix
    g = generator_matrix(key, c, l)
    want = en_ops.reference(g, w, x)
    got = en_ops.encode_parity_prng(key, w, x, c, block=(16, 16, 16))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                               atol=1e-3)


@pytest.mark.parametrize("kind", ["normal", "bernoulli"])
def test_encode_fleet_prng_matches_host_fleet(kind):
    """The streamed in-kernel-PRNG fleet encoder equals the host fleet
    encoder: the per-client `jax.random.split` layout is shared, so both
    paths draw the same G_i."""
    from repro.core import encoding

    key = jax.random.PRNGKey(29)
    n, ell, d, c = 4, 21, 10, 15
    xs = jax.random.normal(key, (n, ell, d))
    ys = jax.random.normal(jax.random.fold_in(key, 1), (n, ell))
    ws = jax.random.uniform(jax.random.fold_in(key, 2), (n, ell),
                            minval=0.2, maxval=1.0)
    want_x, want_y = encoding.encode_fleet(key, xs, ys, ws, c, kind=kind)
    got_x, got_y = en_ops.encode_fleet_prng(key, xs, ys, ws, c, kind=kind,
                                            block=(16, 16, 16))
    np.testing.assert_allclose(np.asarray(got_x), np.asarray(want_x),
                               rtol=2e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=2e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# ssd: intra-chunk state-space dual kernel
# ---------------------------------------------------------------------------

def _ssd_inputs(key, B, nc, Q, H, P, N):
    ks = jax.random.split(key, 5)
    xc = jax.random.normal(ks[0], (B, nc, Q, H, P))
    dtc = jax.nn.softplus(jax.random.normal(ks[1], (B, nc, Q, H)))
    da = (-jnp.abs(jax.random.normal(ks[2], (B, nc, Q, H))) * 0.1
          ).astype(jnp.float32)
    bc = jax.random.normal(ks[3], (B, nc, Q, H, N))
    cc = jax.random.normal(ks[4], (B, nc, Q, H, N))
    return xc, dtc, da, bc, cc


@pytest.mark.parametrize("B,nc,Q,H,P,N", [
    (1, 1, 8, 1, 4, 4), (2, 3, 32, 4, 16, 8), (1, 2, 128, 2, 64, 32),
])
def test_ssd_chunk_matches_ref(B, nc, Q, H, P, N):
    xc, dtc, da, bc, cc = _ssd_inputs(jax.random.PRNGKey(B + Q + H), B, nc,
                                      Q, H, P, N)
    y1, s1 = ssd_ops.ssd_chunk(xc, dtc, da, bc, cc)
    y0, s0 = ssd_chunk_reference(xc, dtc, da, bc, cc)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=1e-4,
                               atol=1e-4 * max(1.0, float(np.abs(y0).max())))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s0), rtol=1e-4,
                               atol=1e-4 * max(1.0, float(np.abs(s0).max())))


def test_ssd_chunked_with_kernel_end_to_end():
    """ssd_chunked(use_kernel=True) == ssd_chunked(use_kernel=False)."""
    key = jax.random.PRNGKey(7)
    B, S, H, P, G, N = 2, 64, 4, 8, 2, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    b = jax.random.normal(ks[3], (B, S, G, N))
    c = jax.random.normal(ks[4], (B, S, G, N))
    y0, h0 = ssd_chunked(x, dt, a, b, c, chunk=16, use_kernel=False)
    y1, h1 = ssd_chunked(x, dt, a, b, c, chunk=16, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), rtol=1e-4,
                               atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(Q=st.sampled_from([8, 16, 32]), H=st.integers(1, 3),
       P=st.sampled_from([4, 8]), N=st.sampled_from([4, 8]))
def test_ssd_property(Q, H, P, N):
    xc, dtc, da, bc, cc = _ssd_inputs(jax.random.PRNGKey(Q * H + P + N),
                                      1, 2, Q, H, P, N)
    y1, s1 = ssd_ops.ssd_chunk(xc, dtc, da, bc, cc)
    y0, s0 = ssd_chunk_reference(xc, dtc, da, bc, cc)
    assert np.all(np.isfinite(np.asarray(y1)))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=1e-4,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s0), rtol=1e-4,
                               atol=1e-3)


# ---------------------------------------------------------------------------
# flash_attn: causal online-softmax attention
# ---------------------------------------------------------------------------

from repro.kernels.flash_attn import ops as fa_ops


@pytest.mark.parametrize("B,H,S,D,bq,bk", [
    (1, 2, 64, 16, 16, 16), (2, 4, 128, 32, 32, 64), (1, 1, 256, 64, 64, 64),
    (1, 2, 96, 16, 32, 48),
])
def test_flash_attn_matches_ref(B, H, S, D, bq, bk):
    key = jax.random.PRNGKey(B + H + S)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    out = fa_ops.causal_attention(q, k, v, block_q=bq, block_k=bk)
    want = fa_ops.reference(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attn_bf16():
    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 32), dtype=jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 64, 32), dtype=jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 64, 32), dtype=jnp.bfloat16)
    out = fa_ops.causal_attention(q, k, v, block_q=32, block_k=32)
    want = fa_ops.reference(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), rtol=5e-2, atol=5e-2)


def test_flash_attn_rejects_non_divisible():
    q = jnp.zeros((1, 1, 100, 16))
    with pytest.raises(ValueError):
        fa_ops.causal_attention(q, q, q, block_q=64, block_k=64)


@settings(max_examples=6, deadline=None)
@given(S=st.sampled_from([32, 64, 128]), D=st.sampled_from([8, 16]),
       bq=st.sampled_from([16, 32]))
def test_flash_attn_property(S, D, bq):
    key = jax.random.PRNGKey(S * D)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, S, D))
    k = jax.random.normal(ks[1], (1, 2, S, D))
    v = jax.random.normal(ks[2], (1, 2, S, D))
    out = fa_ops.causal_attention(q, k, v, block_q=bq, block_k=bq)
    want = fa_ops.reference(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
