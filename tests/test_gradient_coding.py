"""Tests for the gradient-coding baseline (paper ref [5] comparator)."""
import jax
import numpy as np
import pytest

from repro.core import gradient_coding as GC
from repro.core import aggregation
from repro.sim import simulator as S
from repro.sim.network import paper_fleet


def test_make_plan_groups():
    plan = GC.make_plan(12, 3)
    assert plan.r == 3
    assert len(plan.groups) == 12
    _, counts = np.unique(plan.groups, return_counts=True)
    assert np.all(counts == 3)
    assert plan.tolerated_stragglers_per_group == 2


def test_make_plan_rejects_non_divisor():
    with pytest.raises(ValueError):
        GC.make_plan(10, 3)


def test_group_gradients_partition_full_gradient():
    key = jax.random.PRNGKey(0)
    xs, ys, bt = S.generate_linreg(key, n=8, ell=10, d=6)
    plan = GC.make_plan(8, 2)
    beta = jax.random.normal(jax.random.PRNGKey(1), (6,))
    gg = GC.group_gradients(xs, ys, beta, plan)
    full = aggregation.uncoded_full_gradient(xs, ys, beta)
    np.testing.assert_allclose(np.asarray(gg.sum(axis=0)), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_epoch_time_decreases_with_replication():
    """More replication => min-over-group-members => shorter group waits,
    but each member computes r x more; with compute-dominated delays the
    net can go either way — assert only that the mechanics hold: r=1
    equals the uncoded max, and all times are positive/finite."""
    fleet = paper_fleet(0.2, 0.2, seed=0, n=12, d=50)
    rng = np.random.default_rng(0)
    t1 = [GC.epoch_time(fleet, GC.make_plan(12, 1), 50, rng)
          for _ in range(50)]
    t3 = [GC.epoch_time(fleet, GC.make_plan(12, 3), 50, rng)
          for _ in range(50)]
    assert all(np.isfinite(t1)) and all(np.isfinite(t3))
    assert min(t1 + t3) > 0


def test_vectorized_sample_epochs_matches_legacy_loop():
    """Satellite regression: the `np.minimum.at` group reduction in
    `GradientCodingFL.sample_epochs` reproduces the seed's per-client
    Python loop trace-identically (same generator draws, same epoch
    durations, bit for bit)."""
    from repro.api import GradientCodingFL, TrainData
    from repro.core.delay_model import sample_total

    fleet = paper_fleet(0.2, 0.2, seed=0, n=12, d=50)
    data = TrainData(*[jax.numpy.asarray(v) for v in
                       S.generate_linreg(jax.random.PRNGKey(0),
                                         n=12, ell=30, d=50)])
    strat = GradientCodingFL(r=3)
    state = strat.plan(fleet, data)
    epochs = 40

    sched = strat.sample_epochs(state, fleet, epochs,
                                np.random.default_rng(7))

    # the seed's loop, verbatim (per-epoch sampling + per-client min scan)
    rng = np.random.default_rng(7)
    loads = np.full(fleet.edge.n, state.plan.r * state.ell)
    legacy = np.empty(epochs)
    for e in range(epochs):
        t_i = sample_total(fleet.edge, loads, rng)
        per_group = np.full(state.n_groups, np.inf)
        for i, g in enumerate(state.plan.groups):
            per_group[g] = min(per_group[g], t_i[i])
        legacy[e] = float(per_group.max())

    np.testing.assert_array_equal(sched.durations, legacy)
    assert sched.arrivals["group_ok"].shape == (epochs, state.n_groups)
    assert np.all(sched.arrivals["group_ok"] == 1.0)


def test_gradient_coding_converges():
    fleet = paper_fleet(0.2, 0.2, seed=1, n=12, d=60)
    key = jax.random.PRNGKey(0)
    xs, ys, bt = S.generate_linreg(key, n=12, ell=80, d=60)
    res = GC.run_gradient_coding(fleet, xs, ys, bt, lr=0.05, epochs=200,
                                 rng=np.random.default_rng(0), r=3)
    assert res.final_nmse() < 1e-2
    assert res.setup_time > 0  # raw-data sharing cost is accounted
