"""Unit + property tests for the §II-A delay model."""
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a deterministic fallback

from repro.core.delay_model import (DeviceDelayParams, compute_cdf,
                                    sample_total, total_cdf)


def _fleet(n=4, seed=0):
    rng = np.random.default_rng(seed)
    return DeviceDelayParams(
        a=rng.uniform(1e-3, 1e-1, n),
        mu=rng.uniform(10.0, 1000.0, n),
        tau=rng.uniform(0.01, 2.0, n),
        p=rng.uniform(0.0, 0.3, n),
    )


def test_mean_total_matches_eq8():
    params = _fleet()
    ell = np.array([10, 20, 0, 5])
    expected = ell * (params.a + 1.0 / params.mu) + 2 * params.tau / (1 - params.p)
    np.testing.assert_allclose(params.mean_total(ell), expected)


def test_mean_total_server_has_no_comm_leg():
    server = DeviceDelayParams(a=np.array([1e-3]), mu=np.array([2000.0]),
                               tau=np.zeros(1), p=np.zeros(1))
    np.testing.assert_allclose(server.mean_total(np.array([100])),
                               100 * (1e-3 + 1 / 2000.0))


def test_compute_cdf_is_shifted_exponential():
    params = _fleet(1)
    ell = 50
    shift = ell * params.a[0]
    assert compute_cdf(params, ell, shift * 0.99)[0] == 0.0
    gamma = params.mu[0] / ell
    t = shift + 3.0 / gamma
    np.testing.assert_allclose(compute_cdf(params, ell, t)[0],
                               1 - np.exp(-3.0), rtol=1e-12)


def test_total_cdf_monotone_in_t():
    params = _fleet()
    ell = np.array([10, 20, 30, 5])
    ts = np.linspace(0.0, 20.0, 50)
    vals = np.stack([total_cdf(params, ell, t) for t in ts])
    assert np.all(np.diff(vals, axis=0) >= -1e-12)


def test_total_cdf_limits():
    params = _fleet()
    ell = np.full(4, 10)
    assert np.all(total_cdf(params, ell, 0.0) == 0.0)
    big_t = float(np.max(params.mean_total(ell))) * 50
    assert np.all(total_cdf(params, ell, big_t) > 0.999)


def test_total_cdf_matches_empirical():
    params = _fleet(3, seed=1)
    ell = np.array([40, 5, 100])
    rng = np.random.default_rng(2)
    samples = sample_total(params, ell, rng, size=40000)
    for t in [0.5, 2.0, 8.0]:
        emp = (samples <= t).mean(axis=0)
        ana = total_cdf(params, ell, t)
        np.testing.assert_allclose(emp, ana, atol=0.01)


def test_zero_load_is_comm_only():
    params = _fleet(1)
    # with ell = 0, T = (N_d + N_u) tau; at t = 2 tau: P = P(K = 2) = (1-p)^2
    t = 2 * params.tau[0] + 1e-9
    np.testing.assert_allclose(total_cdf(params, 0, t)[0],
                               (1 - params.p[0]) ** 2, rtol=1e-9)


def test_sampler_zero_load_no_nan():
    params = _fleet()
    rng = np.random.default_rng(0)
    s = sample_total(params, np.zeros(4), rng, size=100)
    assert np.all(np.isfinite(s)) and np.all(s > 0)


@settings(max_examples=25, deadline=None)
@given(
    a=st.floats(1e-4, 1e-1), mu=st.floats(1.0, 1e4),
    tau=st.floats(1e-3, 5.0), p=st.floats(0.0, 0.45),
    ell=st.integers(0, 500), t=st.floats(0.0, 100.0),
)
def test_cdf_is_probability(a, mu, tau, p, ell, t):
    params = DeviceDelayParams(a=np.array([a]), mu=np.array([mu]),
                               tau=np.array([tau]), p=np.array([p]))
    v = total_cdf(params, ell, t)[0]
    assert 0.0 <= v <= 1.0 + 1e-12


def test_invalid_params_rejected():
    with pytest.raises(ValueError):
        DeviceDelayParams(a=np.ones(2), mu=np.ones(2), tau=np.ones(2),
                          p=np.array([0.1, 1.0]))
    with pytest.raises(ValueError):
        DeviceDelayParams(a=np.ones(2), mu=np.ones(3), tau=np.ones(2),
                          p=np.ones(2) * 0.1)
