"""Tests for the lightweight collective-bytes parser (launch.hlo_stats) and
the end-to-end launch drivers' CLI paths."""
from repro.launch.hlo_stats import collective_bytes, _shape_bytes


def test_shape_bytes_simple():
    assert _shape_bytes("bf16[4,8]{1,0}") == 4 * 8 * 2
    assert _shape_bytes("f32[10]{0}") == 40
    assert _shape_bytes("(f32[2,2]{1,0}, bf16[4]{0})") == 16 + 8
    assert _shape_bytes("pred[]") == 0 or _shape_bytes("pred[]") == 1


def test_collective_bytes_counts_kinds():
    hlo = """
  %ar = f32[16,128]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add
  %ag.1 = bf16[4,256]{1,0} all-gather(%y), dimensions={0}
  %a2a = f32[8,8]{1,0} all-to-all(%z), dimensions={0}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 128 * 4
    assert out["all-gather"] == 4 * 256 * 2
    assert out["all-to-all"] == 8 * 8 * 4
    assert out["n_all-reduce"] == 1


def test_collective_bytes_async_pairs_counted_once():
    hlo = """
  %ags = bf16[64]{0} all-gather-start(%y), dimensions={0}
  %agd = bf16[64]{0} all-gather-done(%ags)
"""
    out = collective_bytes(hlo)
    assert out.get("all-gather", 0) == 128
    assert out.get("n_all-gather", 0) == 1


def test_collective_bytes_empty():
    assert collective_bytes("ENTRY %main { ROOT %c = f32[] constant(0) }") \
        == {}


def test_train_driver_cli_plain():
    from repro.launch import train
    rc = train.main(["--arch", "granite-8b", "--reduced", "--steps", "2",
                     "--batch", "2", "--seq", "16", "--log-every", "1"])
    assert rc == 0


def test_train_driver_cli_federated_with_ckpt(tmp_path):
    from repro.launch import train
    from repro.checkpoint import latest_step
    d = str(tmp_path / "ck")
    rc = train.main(["--arch", "minitron-4b", "--reduced", "--steps", "2",
                     "--batch", "4", "--seq", "16", "--federated",
                     "--n-clients", "2", "--ckpt-dir", d,
                     "--ckpt-every", "2"])
    assert rc == 0
    assert latest_step(d) == 2


def test_serve_driver_cli():
    from repro.launch import serve
    rc = serve.main(["--arch", "whisper-tiny", "--reduced", "--batch", "2",
                     "--prompt-len", "8", "--new-tokens", "2"])
    assert rc == 0
