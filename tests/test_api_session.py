"""Tests for the unified Strategy/Session API (repro.api).

The load-bearing guarantee: `Session`'s single scan-jitted epoch engine
reproduces the legacy per-epoch Python loops EXACTLY — same NumPy generator
draw order, same arrival masks, same fp32 gradient arithmetic — so the
legacy reference loops are reimplemented here (from the seed code) and the
new engine is checked against them trace-for-trace.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a deterministic fallback

from repro.api import (CodedFL, GradientCodingFL, Session, TraceReport,
                       TrainData, UncodedFL, coding_gain, convergence_time)
from repro.core import aggregation, cfl
from repro.core.delay_model import sample_total
from repro.sim.network import paper_fleet


@pytest.fixture(scope="module")
def small():
    fleet = paper_fleet(0.2, 0.2, seed=1, n=12, d=60)
    data = TrainData.linreg(jax.random.PRNGKey(0), n=12, ell=80, d=60)
    return fleet, data


# ---------------------------------------------------------------------------
# legacy reference loops (per-epoch Python, host-synced — the seed code)
# ---------------------------------------------------------------------------

def _legacy_run_uncoded(fleet, data, lr, epochs, rng):
    xs, ys, beta_true = data.xs, data.ys, data.beta_true
    n, ell, d = xs.shape
    m = n * ell
    beta = jnp.zeros(d, dtype=xs.dtype)
    full_load = np.full(n, ell)
    errs = [float(aggregation.nmse(beta, beta_true))]
    durs = []
    for _ in range(epochs):
        t_i = sample_total(fleet.edge, full_load, rng)
        durs.append(float(np.max(t_i)))
        g = aggregation.uncoded_full_gradient(xs, ys, beta)
        beta = aggregation.gd_update(beta, g, lr, m)
        errs.append(float(aggregation.nmse(beta, beta_true)))
    return np.array(errs), np.array(durs)


def _legacy_run_cfl(fleet, data, lr, epochs, rng, key, fixed_c,
                    server_always_returns=False):
    xs, ys, beta_true = data.xs, data.ys, data.beta_true
    n, ell, d = xs.shape
    m = n * ell
    state = cfl.setup(key, xs, ys, fleet.edge, fleet.server, fixed_c=fixed_c)
    plan = state.plan
    t_star = plan.t_star

    upload_bits = state.parity_upload_bits()
    packets = np.ceil(upload_bits / fleet.packet_bits)
    retrans = rng.geometric(1.0 - fleet.edge.p, size=n)
    upload_time = float(np.max(packets * retrans
                               * (fleet.packet_bits / fleet.link_rates))) \
        if state.c > 0 else 0.0

    beta = jnp.zeros(d, dtype=xs.dtype)
    errs = [float(aggregation.nmse(beta, beta_true))]
    for _ in range(epochs):
        t_i = sample_total(fleet.edge, plan.loads, rng)
        received = jnp.asarray((t_i <= t_star) & (plan.loads > 0),
                               dtype=xs.dtype)
        if server_always_returns or state.c == 0:
            par_ok = jnp.asarray(1.0, dtype=xs.dtype)
        else:
            t_srv = sample_total(fleet.server, np.array([state.c]), rng)[0]
            par_ok = jnp.asarray(float(t_srv <= t_star), dtype=xs.dtype)
        g = cfl.epoch_gradient(state, xs, ys, beta, received, par_ok)
        beta = aggregation.gd_update(beta, g, lr, m)
        errs.append(float(aggregation.nmse(beta, beta_true)))
    return np.array(errs), upload_time, t_star


# ---------------------------------------------------------------------------
# trace parity: scan-jitted Session == legacy per-epoch loop
# ---------------------------------------------------------------------------

def test_uncoded_session_matches_legacy_trace(small):
    fleet, data = small
    errs, durs = _legacy_run_uncoded(fleet, data, lr=0.05, epochs=100,
                                     rng=np.random.default_rng(0))
    session = Session(strategy=UncodedFL(), fleet=fleet, lr=0.05, epochs=100)
    rep = session.run(data, rng=np.random.default_rng(0))
    np.testing.assert_allclose(rep.nmse, errs, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(rep.epoch_durations, durs)  # identical draws
    np.testing.assert_allclose(rep.times[1:], np.cumsum(durs))


def test_cfl_session_matches_legacy_trace(small):
    """grad_path="reference" pinned: this is the bit-stability contract
    against the pre-fusion per-epoch loop (tight rtol); the fused
    default is checked separately below at its documented tolerance."""
    fleet, data = small
    c = int(0.3 * data.m)
    errs, upload, t_star = _legacy_run_cfl(
        fleet, data, lr=0.05, epochs=100, rng=np.random.default_rng(0),
        key=jax.random.PRNGKey(1), fixed_c=c)
    session = Session(
        strategy=CodedFL(key=jax.random.PRNGKey(1), fixed_c=c,
                         grad_path="reference"),
        fleet=fleet, lr=0.05, epochs=100)
    rep = session.run(data, rng=np.random.default_rng(0))
    np.testing.assert_allclose(rep.nmse, errs, rtol=1e-4, atol=1e-7)
    assert rep.setup_time == pytest.approx(upload)
    assert rep.times[0] == pytest.approx(upload)  # upload delay included
    np.testing.assert_allclose(rep.epoch_durations, t_star)

    # fused default: same legacy trace at the fused path's tolerance
    fused = Session(
        strategy=CodedFL(key=jax.random.PRNGKey(1), fixed_c=c),
        fleet=fleet, lr=0.05, epochs=100).run(
            data, rng=np.random.default_rng(0))
    np.testing.assert_allclose(fused.nmse, errs, rtol=1e-3, atol=1e-6)
    np.testing.assert_array_equal(fused.epoch_durations,
                                  rep.epoch_durations)


def test_cfl_shim_equals_direct_session(small):
    """The deprecated run_cfl entry point is the same computation."""
    from repro.sim.simulator import run_cfl
    fleet, data = small
    c = int(0.2 * data.m)
    shim = run_cfl(fleet, data.xs, data.ys, data.beta_true, lr=0.05,
                   epochs=40, rng=np.random.default_rng(3),
                   key=jax.random.PRNGKey(2), fixed_c=c,
                   include_upload_delay=False)
    direct = Session(
        strategy=CodedFL(key=jax.random.PRNGKey(2), fixed_c=c,
                         include_upload_delay=False),
        fleet=fleet, lr=0.05, epochs=40).run(
            data, rng=np.random.default_rng(3))
    np.testing.assert_array_equal(shim.nmse, direct.nmse)
    np.testing.assert_array_equal(shim.times, direct.times)
    assert shim.uplink_bits_total == direct.uplink_bits_total
    assert isinstance(shim, TraceReport)


def test_gradcoding_session_matches_legacy_trace(small):
    from repro.core.gradient_coding import run_gradient_coding
    fleet, data = small
    rep = Session(strategy=GradientCodingFL(r=3), fleet=fleet, lr=0.05,
                  epochs=60).run(data, rng=np.random.default_rng(0))
    shim = run_gradient_coding(fleet, data.xs, data.ys, data.beta_true,
                               lr=0.05, epochs=60,
                               rng=np.random.default_rng(0), r=3)
    np.testing.assert_array_equal(rep.nmse, shim.nmse)
    assert rep.setup_time > 0
    assert rep.times[0] == pytest.approx(rep.setup_time)
    # waiting for every group => gradient is exact => same NMSE trajectory
    # as synchronous uncoded FL (only the clock differs)
    unc = Session(strategy=UncodedFL(), fleet=fleet, lr=0.05,
                  epochs=60).run(data, rng=np.random.default_rng(0))
    np.testing.assert_allclose(rep.nmse, unc.nmse, rtol=2e-4, atol=1e-7)


# ---------------------------------------------------------------------------
# gradient-coding exact recovery (property)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(n_groups=st.integers(1, 4), r=st.integers(1, 3),
       d=st.integers(1, 10))
def test_gradcoding_recovers_exact_full_gradient(n_groups, r, d):
    """When every group has >= 1 non-straggler returner, the decoded
    gradient equals the exact full gradient (no LLN approximation)."""
    n = n_groups * r
    data = TrainData.linreg(jax.random.PRNGKey(n + 10 * r + 100 * d),
                            n=n, ell=6, d=d)
    fleet = paper_fleet(0.1, 0.1, seed=0, n=n, d=d)
    strat = GradientCodingFL(r=r)
    state = strat.plan(fleet, data)
    dev = strat.device_state(state, data)
    beta = jax.random.normal(jax.random.PRNGKey(0), (d,))
    g = strat.round_contributions(
        state, dev, beta,
        {"group_ok": jnp.ones(state.n_groups, dtype=jnp.float32)})
    full = aggregation.uncoded_full_gradient(data.xs, data.ys, beta)
    np.testing.assert_allclose(np.asarray(g), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_gradcoding_partial_groups_drop_cleanly():
    """A straggling group contributes nothing; the rest stay exact."""
    data = TrainData.linreg(jax.random.PRNGKey(0), n=6, ell=5, d=4)
    fleet = paper_fleet(0.1, 0.1, seed=0, n=6, d=4)
    strat = GradientCodingFL(r=2)
    state = strat.plan(fleet, data)
    dev = strat.device_state(state, data)
    beta = jnp.zeros(4)
    ok = jnp.asarray([1.0, 0.0, 1.0], dtype=jnp.float32)
    g = strat.round_contributions(state, dev, beta, {"group_ok": ok})
    mask = np.repeat(np.asarray(ok), 2)  # fractional repetition: r=2
    per_client = aggregation.client_partial_gradients(
        data.xs, data.ys, jnp.ones(data.xs.shape[:2]), beta)
    expect = np.einsum("nd,n->d", np.asarray(per_client), mask)
    np.testing.assert_allclose(np.asarray(g), expect, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Session mechanics
# ---------------------------------------------------------------------------

def test_session_engine_cache_reused_across_runs(small):
    fleet, data = small
    session = Session(strategy=UncodedFL(), fleet=fleet, lr=0.05, epochs=20)
    session.run(data, rng=np.random.default_rng(0))
    assert len(session._engines) == 1
    session.run(data, rng=np.random.default_rng(1))
    assert len(session._engines) == 1  # same shapes -> no retrace


def test_session_default_seed_reproducible(small):
    fleet, data = small
    session = Session(strategy=UncodedFL(), fleet=fleet, lr=0.05, epochs=20,
                      seed=7)
    a = session.run(data)
    b = session.run(data)
    np.testing.assert_array_equal(a.nmse, b.nmse)
    np.testing.assert_array_equal(a.epoch_durations, b.epoch_durations)


def test_report_helpers(small):
    fleet, data = small
    rep_u = Session(strategy=UncodedFL(), fleet=fleet, lr=0.05,
                    epochs=150).run(data)
    rep_c = Session(strategy=CodedFL(key=jax.random.PRNGKey(1),
                                     fixed_c=int(0.3 * data.m),
                                     include_upload_delay=False),
                    fleet=fleet, lr=0.05, epochs=150).run(data)
    tgt = 1e-1
    assert convergence_time(rep_u, tgt) > 0
    assert np.isfinite(convergence_time(rep_c, tgt))
    assert coding_gain(rep_u, rep_c, tgt) > 1.0
    assert rep_c.epochs == 150
    assert 0 < rep_c.epochs_to(tgt) <= 151
    assert rep_u.uplink_bits_total > 0


def test_custom_strategy_plugs_in(small):
    """The protocol is open: a user-defined scheme runs unmodified."""
    fleet, data = small

    class HalfFleetFL:
        """Toy scheme: only even-indexed clients ever report."""
        label = "half"

        def plan(self, fleet, data):
            return {"n": data.n}

        def sample_epochs(self, state, fleet, epochs, rng):
            from repro.api import EpochSchedule
            mask = np.zeros((epochs, state["n"]), np.float32)
            mask[:, ::2] = 1.0
            return EpochSchedule(durations=np.ones(epochs),
                                 arrivals={"received": mask})

        def device_state(self, state, data):
            return {"xs": data.xs, "ys": data.ys}

        def round_contributions(self, state, dev, beta, arrivals):
            xs, ys = dev["xs"], dev["ys"]
            partials = aggregation.client_partial_gradients(
                xs, ys, jnp.ones(xs.shape[:2], xs.dtype), beta)
            return jnp.einsum("nd,n->d", partials, arrivals["received"])

        def uplink_bits(self, state, fleet, epochs):
            return 0.0

        def engine_key(self, state):
            return ()

    rep = Session(strategy=HalfFleetFL(), fleet=fleet, lr=0.05,
                  epochs=80).run(data)
    assert rep.label == "half"
    assert rep.final_nmse() < 1.0  # half the gradient still descends
    np.testing.assert_allclose(rep.epoch_durations, 1.0)
