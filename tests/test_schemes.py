"""Tests for the `repro.schemes` subsystem: the stochastic (arXiv:2201.10092)
and low-latency wireless (arXiv:2011.06223) strategies and their objective
evaluators in the batched grid planner.

Three layers of guarantees:

  * solver parity — the grid solver's weighted-server and partial-return
    objectives reproduce the NumPy scalar oracles in
    `repro.plan.reference_schemes` (loads identical, t* within 1e-3 rel);
  * degenerate equivalence — each scheme's neutral setting
    (noise = 0 & rho = 1; chunks = 1) reproduces `CodedFL` trace-for-trace
    from the same seed and key;
  * end-to-end — both schemes run unmodified under `Session`, batch their
    solves through `plan_sweep`, and surface their knobs on
    `TraceReport.extras`.
"""
import jax
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a deterministic fallback

from repro.api import Session, TrainData, make_strategy, plan_sweep
from repro.core.delay_model import (DeviceDelayParams, partial_cdf,
                                    total_cdf)
from repro.plan import PlanRequest, solve_redundancy_batched
from repro.plan.reference_schemes import (chunk_cdf_loop,
                                          solve_lowlatency_reference,
                                          solve_stochastic_reference,
                                          stochastic_noise_scale)
from repro.schemes import LowLatencyCFL, StochasticCodedFL
from repro.sim.network import wireless_fleet


def _random_fleet(rng: np.random.Generator, n: int):
    a = rng.uniform(1e-3, 5e-2, n)
    mu = (2.0 / a) * rng.uniform(0.5, 2.0, n)
    tau = rng.uniform(1e-3, 5e-2, n)
    p = rng.uniform(0.0, 0.3, n)
    edge = DeviceDelayParams(a, mu, tau, p)
    sa = np.array([a.min() / 10.0])
    server = DeviceDelayParams(sa, 2.0 / sa, np.zeros(1), np.zeros(1))
    return edge, server


@pytest.fixture(scope="module")
def small():
    fleet = wireless_fleet(0.2, 0.2, nu_erasure=0.3, seed=0, n=12, d=40)
    data = TrainData.linreg(jax.random.PRNGKey(0), n=12, ell=60, d=40)
    return fleet, data


# ---------------------------------------------------------------------------
# solver parity vs the NumPy oracles
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(n=st.integers(2, 8), ell=st.integers(8, 60),
       w=st.floats(0.3, 1.0), mode=st.sampled_from(["free", "fixed"]),
       seed=st.integers(0, 10**6))
def test_stochastic_objective_matches_reference(n, ell, w, mode, seed):
    """Weighted-server grid solve == scalar oracle (loads exact, t* 1e-3)."""
    rng = np.random.default_rng(seed)
    edge, server = _random_fleet(rng, n)
    sizes = rng.integers(ell // 2 + 1, ell + 1, size=n)
    m = int(sizes.sum())
    kw = {"fixed_c": int(rng.integers(m // 10 + 1, m + 1))} \
        if mode == "fixed" else \
        {"c_up": int(rng.integers(m // 10 + 1, m + 1))}
    ref = solve_stochastic_reference(edge, server, sizes, w,
                                     eps_rel=1e-4, **kw)
    new = solve_redundancy_batched(
        [PlanRequest(edge, server, sizes, srv_weight=w, **kw)],
        eps_rel=1e-4)[0]
    np.testing.assert_allclose(new.t_star, ref.t_star, rtol=1e-3)
    np.testing.assert_array_equal(new.loads, ref.loads)
    assert new.c == ref.c
    np.testing.assert_allclose(new.expected_agg, ref.expected_agg, rtol=1e-3)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(2, 8), ell=st.integers(8, 60),
       chunks=st.sampled_from([2, 4, 8]),
       mode=st.sampled_from(["free", "fixed"]), seed=st.integers(0, 10**6))
def test_lowlatency_objective_matches_reference(n, ell, chunks, mode, seed):
    """Partial-return grid solve == scalar oracle (loads exact, t* 1e-3)."""
    rng = np.random.default_rng(seed)
    edge, server = _random_fleet(rng, n)
    sizes = rng.integers(ell // 2 + 1, ell + 1, size=n)
    m = int(sizes.sum())
    kw = {"fixed_c": int(rng.integers(m // 10 + 1, m + 1))} \
        if mode == "fixed" else \
        {"c_up": int(rng.integers(m // 10 + 1, m + 1))}
    ref = solve_lowlatency_reference(edge, server, sizes, chunks,
                                     eps_rel=1e-4, **kw)
    new = solve_redundancy_batched(
        [PlanRequest(edge, server, sizes, edge_chunks=chunks, **kw)],
        eps_rel=1e-4)[0]
    np.testing.assert_allclose(new.t_star, ref.t_star, rtol=1e-3)
    np.testing.assert_array_equal(new.loads, ref.loads)
    assert new.c == ref.c


def test_mixed_objective_batch_matches_solo():
    """CFL / weighted / partial requests in ONE batched call solve exactly
    as they do alone (weights are per-request inputs; chunked requests
    group separately) — and srv_weight=1 stays bit-identical to base."""
    rng = np.random.default_rng(4)
    edge, server = _random_fleet(rng, 6)
    sizes = np.full(6, 40)
    reqs = [
        PlanRequest(edge, server, sizes, c_up=100),
        PlanRequest(edge, server, sizes, c_up=100, srv_weight=0.5),
        PlanRequest(edge, server, sizes, c_up=100, edge_chunks=4),
        PlanRequest(edge, server, sizes, fixed_c=60, srv_weight=0.8),
    ]
    batch = solve_redundancy_batched(reqs)
    for req, got in zip(reqs, batch):
        solo = solve_redundancy_batched([req])[0]
        assert got.t_star == solo.t_star
        np.testing.assert_array_equal(got.loads, solo.loads)
        assert got.c == solo.c
    # srv_weight=1.0 multiplies by exactly 1.0: bit-identical to the plain
    # request even when batched next to discounted ones
    plain = solve_redundancy_batched([PlanRequest(edge, server, sizes,
                                                  c_up=100)])[0]
    assert batch[0].t_star == plain.t_star


def test_weaker_server_weight_raises_deadline():
    """A discounted parity row buys less aggregate return, so the same
    fleet needs a later deadline (and the edge carries more load)."""
    rng = np.random.default_rng(5)
    edge, server = _random_fleet(rng, 6)
    sizes = np.full(6, 40)
    full = solve_redundancy_batched(
        [PlanRequest(edge, server, sizes, c_up=120, srv_weight=1.0)])[0]
    half = solve_redundancy_batched(
        [PlanRequest(edge, server, sizes, c_up=120, srv_weight=0.4)])[0]
    assert half.t_star >= full.t_star


def test_plan_request_validates_new_fields():
    rng = np.random.default_rng(0)
    edge, server = _random_fleet(rng, 3)
    with pytest.raises(ValueError, match="srv_weight"):
        PlanRequest(edge, server, np.full(3, 10), srv_weight=1.5)
    with pytest.raises(ValueError, match="edge_chunks"):
        PlanRequest(edge, server, np.full(3, 10), edge_chunks=0)


# ---------------------------------------------------------------------------
# partial-return delay model
# ---------------------------------------------------------------------------

def test_partial_cdf_chunks_one_is_total_cdf():
    edge, _ = _random_fleet(np.random.default_rng(2), 5)
    ell = np.array([10, 20, 0, 15, 30])
    np.testing.assert_array_equal(partial_cdf(edge, ell, 1.5, 1)[:, 0],
                                  total_cdf(edge, ell, 1.5))


def test_partial_cdf_monotone_and_matches_loop():
    edge, _ = _random_fleet(np.random.default_rng(3), 6)
    ell = np.array([12, 25, 7, 30, 18, 9])
    pc = partial_cdf(edge, ell, 1.1, 8)
    assert pc.shape == (6, 8)
    # later chunks cover more work: completion probability non-increasing
    assert np.all(np.diff(pc, axis=1) <= 1e-15)
    # more time helps every chunk
    assert np.all(partial_cdf(edge, ell, 2.2, 8) >= pc - 1e-15)
    np.testing.assert_allclose(pc, chunk_cdf_loop(edge, ell, 1.1, 8),
                               rtol=1e-12, atol=1e-15)


# ---------------------------------------------------------------------------
# degenerate equivalence with CodedFL
# ---------------------------------------------------------------------------

def test_stochastic_degenerates_to_cfl(small):
    """noise = 0, rho = 1: same plan, same parity bits, same trace."""
    fleet, data = small
    c = int(0.3 * data.m)
    key = jax.random.PRNGKey(5)
    cfl = Session(strategy=make_strategy("cfl", key=key, fixed_c=c),
                  fleet=fleet, lr=0.05, epochs=80)
    scfl = Session(strategy=StochasticCodedFL(key=key, fixed_c=c,
                                              noise_multiplier=0.0,
                                              sample_frac=1.0),
                   fleet=fleet, lr=0.05, epochs=80)
    st_c, st_s = cfl.plan(data), scfl.plan(data)
    assert st_c.plan.t_star == st_s.plan.t_star
    np.testing.assert_array_equal(st_c.plan.loads, st_s.plan.loads)
    np.testing.assert_array_equal(np.asarray(st_c.x_parity),
                                  np.asarray(st_s.x_parity))
    r_c = cfl.run(data, rng=np.random.default_rng(3), state=st_c)
    r_s = scfl.run(data, rng=np.random.default_rng(3), state=st_s)
    np.testing.assert_allclose(r_s.nmse, r_c.nmse, rtol=1e-5, atol=1e-8)
    assert r_s.setup_time == r_c.setup_time


def test_lowlatency_chunks_one_degenerates_to_cfl(small):
    """chunks = 1 (all-or-nothing): same plan, same parity, same trace."""
    fleet, data = small
    c = int(0.3 * data.m)
    key = jax.random.PRNGKey(5)
    cfl = Session(strategy=make_strategy("cfl", key=key, fixed_c=c),
                  fleet=fleet, lr=0.05, epochs=80)
    ll = Session(strategy=LowLatencyCFL(key=key, fixed_c=c, chunks=1),
                 fleet=fleet, lr=0.05, epochs=80)
    st_c, st_l = cfl.plan(data), ll.plan(data)
    assert st_c.plan.t_star == st_l.plan.t_star
    np.testing.assert_array_equal(np.asarray(st_c.x_parity),
                                  np.asarray(st_l.x_parity))
    r_c = cfl.run(data, rng=np.random.default_rng(3), state=st_c)
    r_l = ll.run(data, rng=np.random.default_rng(3), state=st_l)
    np.testing.assert_allclose(r_l.nmse, r_c.nmse, rtol=1e-5, atol=1e-8)
    assert r_l.setup_time == r_c.setup_time


# ---------------------------------------------------------------------------
# scheme semantics
# ---------------------------------------------------------------------------

def test_noise_scale_matches_reference(small):
    fleet, data = small
    strat = StochasticCodedFL(key=jax.random.PRNGKey(1), fixed_c=100,
                              noise_multiplier=0.7)
    state = strat.plan(fleet, data)
    from repro.core.redundancy import systematic_weights
    w = np.stack(systematic_weights(
        state.plan, np.full(data.n, data.ell, dtype=np.int64)))
    ref_x, ref_y = stochastic_noise_scale(np.asarray(data.xs),
                                          np.asarray(data.ys), w, 0.7)
    np.testing.assert_allclose(state.noise_scale_x, ref_x, rtol=1e-3)
    np.testing.assert_allclose(state.noise_scale_y, ref_y, rtol=1e-3)
    assert state.noise_scale_x > 0 and state.noise_scale_y > 0


def test_noise_knob_degrades_accuracy(small):
    """The privacy/accuracy tradeoff is visible: heavy noise ends at a
    worse NMSE than no noise, and the knob is surfaced on the report."""
    fleet, data = small
    c = int(0.3 * data.m)

    def run(noise):
        sess = Session(strategy=StochasticCodedFL(
            key=jax.random.PRNGKey(5), fixed_c=c, noise_multiplier=noise),
            fleet=fleet, lr=0.05, epochs=120)
        return sess.run(data, rng=np.random.default_rng(0))

    clean, noisy = run(0.0), run(2.0)
    assert noisy.extras["noise_multiplier"] == 2.0
    # sigma = 2 => srv_weight = 1/(1+4) = 0.2 < 1.0 = clean's
    assert noisy.extras["srv_weight"] < clean.extras["srv_weight"]
    assert np.all(np.isfinite(noisy.nmse))
    assert noisy.final_nmse() > clean.final_nmse()


def test_stochastic_subsampling_unbiased(small):
    """E over the round mask of the subsampled parity gradient equals the
    full parity gradient (the 1/rho inverse-probability weighting)."""
    fleet, data = small
    strat = StochasticCodedFL(key=jax.random.PRNGKey(2), fixed_c=150,
                              noise_multiplier=0.0, sample_frac=0.5)
    state = strat.plan(fleet, data)
    dev = strat.device_state(state, data)
    beta = jax.random.normal(jax.random.PRNGKey(3), (data.d,))
    rng = np.random.default_rng(0)
    c = state.c
    acc = np.zeros(data.d)
    trials = 300
    full = np.asarray(strat.round_contributions(
        state, dev, beta,
        {"received": np.zeros(data.n, np.float32),
         "parity_mask": np.ones(c, np.float32),
         "parity_ok": np.float32(1.0)}))
    # full mask at rho=0.5 is scaled by 1/rho: undo for the expectation
    full = full * strat.sample_frac
    for _ in range(trials):
        mask = (rng.random(c) < 0.5).astype(np.float32)
        acc += np.asarray(strat.round_contributions(
            state, dev, beta,
            {"received": np.zeros(data.n, np.float32),
             "parity_mask": mask, "parity_ok": np.float32(1.0)}))
    mean = acc / trials
    # MC error ~ 1/sqrt(300): loose 15% tolerance on the gradient norm
    assert np.linalg.norm(mean - full) < 0.15 * np.linalg.norm(full)


def test_lowlatency_partial_rows_track_chunks(small):
    """Row masking matches the chunk map: exactly the rows of completed
    chunks contribute, punctured rows never do."""
    fleet, data = small
    strat = LowLatencyCFL(key=jax.random.PRNGKey(2), fixed_c=100, chunks=4)
    state = strat.plan(fleet, data)
    dev = strat.device_state(state, data)
    beta = jax.random.normal(jax.random.PRNGKey(0), (data.d,))
    done = np.zeros(data.n, np.float32)
    done[0] = 2.0  # client 0 finished 2 of 4 chunks
    g = np.asarray(strat.round_contributions(
        state, dev, beta, {"chunks_done": done,
                           "parity_ok": np.float32(0.0)}))
    # manual: rows of client 0 with chunk id < 2
    rc = state.row_chunk[0]
    rows = np.flatnonzero(rc < 2)
    x0 = np.asarray(data.xs[0])[rows]
    y0 = np.asarray(data.ys[0])[rows]
    resid = x0 @ np.asarray(beta) - y0
    np.testing.assert_allclose(g, resid @ x0, rtol=1e-4, atol=1e-4)


def test_wireless_fleet_heterogeneous_erasures():
    fleet = wireless_fleet(0.2, 0.2, nu_erasure=0.4, seed=0, n=16, d=50)
    assert len(np.unique(fleet.edge.p)) > 1
    assert fleet.edge.p.min() >= 0.02 and fleet.edge.p.max() <= 0.3
    homo = wireless_fleet(0.2, 0.2, nu_erasure=0.0, seed=0, n=16, d=50)
    np.testing.assert_allclose(homo.edge.p, 0.3)


# ---------------------------------------------------------------------------
# end-to-end under Session / plan_sweep
# ---------------------------------------------------------------------------

def test_schemes_run_under_session_and_plan_sweep(small):
    """Both schemes run unmodified under `Session`, and `plan_sweep`
    batches their allocation solves with CFL's into one call, producing
    states identical to solo planning."""
    fleet, data = small
    c = int(0.25 * data.m)
    sessions = [
        Session(strategy=make_strategy("uncoded"),
                fleet=fleet, lr=0.05, epochs=30),
        Session(strategy=make_strategy("cfl", key_seed=5, fixed_c=c),
                fleet=fleet, lr=0.05, epochs=30),
        Session(strategy=make_strategy("stochastic", key_seed=5, fixed_c=c,
                                       noise_multiplier=0.5,
                                       sample_frac=0.7),
                fleet=fleet, lr=0.05, epochs=30),
        Session(strategy=make_strategy("lowlatency", key_seed=5, fixed_c=c,
                                       chunks=4),
                fleet=fleet, lr=0.05, epochs=30),
    ]
    states = plan_sweep(sessions, data)
    for sess, state in zip(sessions[1:], states[1:]):
        solo = sess.plan(data)
        assert state.plan.t_star == solo.plan.t_star
        np.testing.assert_array_equal(state.plan.loads, solo.plan.loads)
    for sess, state in zip(sessions, states):
        rep = sess.run(data, rng=np.random.default_rng(0), state=state)
        assert np.all(np.isfinite(rep.nmse))
        assert rep.final_nmse() < rep.nmse[0]
    # knobs surfaced
    rep = sessions[2].run(data, rng=np.random.default_rng(0),
                          state=states[2])
    assert rep.extras["noise_multiplier"] == 0.5
    rep = sessions[3].run(data, rng=np.random.default_rng(0),
                          state=states[3])
    assert rep.extras["chunks"] == 4.0
