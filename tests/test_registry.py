"""Tests for the `repro.api.make_strategy` registry."""
import jax
import numpy as np
import pytest

from repro.api import (CodedFL, GradientCodingFL, UncodedFL,
                       available_strategies, make_strategy,
                       register_strategy)
from repro.schemes import LowLatencyCFL, StochasticCodedFL


def test_builtin_names_construct_the_right_classes():
    assert isinstance(make_strategy("uncoded"), UncodedFL)
    assert isinstance(make_strategy("cfl", key_seed=1, fixed_c=10), CodedFL)
    assert isinstance(make_strategy("gradcode", r=2), GradientCodingFL)
    assert isinstance(make_strategy("stochastic", key_seed=1),
                      StochasticCodedFL)
    assert isinstance(make_strategy("lowlatency", key_seed=1), LowLatencyCFL)


def test_aliases_resolve():
    assert isinstance(make_strategy("scfl", key_seed=1), StochasticCodedFL)
    assert isinstance(make_strategy("lowlat", key_seed=1), LowLatencyCFL)


def test_kwargs_pass_through():
    s = make_strategy("stochastic", key_seed=3, fixed_c=42,
                      noise_multiplier=0.25, sample_frac=0.5)
    assert s.fixed_c == 42 and s.noise_multiplier == 0.25
    assert s.sample_frac == 0.5
    ll = make_strategy("lowlatency", key_seed=3, chunks=16)
    assert ll.chunks == 16


def test_key_seed_equals_explicit_key():
    a = make_strategy("cfl", key_seed=9, fixed_c=5)
    b = make_strategy("cfl", key=jax.random.PRNGKey(9), fixed_c=5)
    np.testing.assert_array_equal(np.asarray(a.key), np.asarray(b.key))


def test_missing_key_raises_instead_of_silent_default():
    """Key-carrying strategies must not silently share a default key."""
    with pytest.raises(ValueError, match="PRNG key"):
        make_strategy("cfl", fixed_c=10)
    with pytest.raises(ValueError, match="PRNG key"):
        make_strategy("stochastic")


def test_key_seed_rejected_for_keyless_and_double_key():
    with pytest.raises(ValueError, match="key_seed"):
        make_strategy("uncoded", key_seed=1)
    with pytest.raises(ValueError, match="key_seed"):
        make_strategy("cfl", key=jax.random.PRNGKey(0), key_seed=1,
                      fixed_c=5)


def test_unknown_name_lists_available():
    with pytest.raises(ValueError, match="unknown strategy"):
        make_strategy("nope")
    names = available_strategies()
    for expected in ("uncoded", "cfl", "gradcode", "stochastic",
                     "lowlatency"):
        assert expected in names


def test_register_custom_strategy():
    class MyScheme:
        label = "mine"

        def __init__(self, knob=1):
            self.knob = knob

    register_strategy("myscheme", MyScheme)
    s = make_strategy("myscheme", knob=7)
    assert isinstance(s, MyScheme) and s.knob == 7
    assert "myscheme" in available_strategies()


def test_register_rejects_builtin_names_and_aliases():
    """Built-ins and their aliases cannot be shadowed by user schemes."""
    with pytest.raises(ValueError, match="built-in"):
        register_strategy("cfl", object)
    with pytest.raises(ValueError, match="built-in"):
        register_strategy("scfl", object)  # alias of "stochastic"
