"""Tests for gradient computation and deadline-masked aggregation (Eqs. 18-19)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # hypothesis, or a deterministic fallback

from repro.core import aggregation as agg


def _data(key, n=6, ell=40, d=16):
    k1, k2, k3 = jax.random.split(key, 3)
    xs = jax.random.normal(k1, (n, ell, d))
    beta_true = jax.random.normal(k2, (d,))
    ys = jnp.einsum("nld,d->nl", xs, beta_true) + 0.1 * jax.random.normal(k3, (n, ell))
    return xs, ys, beta_true


def test_uncoded_gradient_matches_flat():
    xs, ys, _ = _data(jax.random.PRNGKey(0))
    beta = jnp.zeros(xs.shape[-1])
    g = agg.uncoded_full_gradient(xs, ys, beta)
    x_flat = np.asarray(xs).reshape(-1, xs.shape[-1])
    y_flat = np.asarray(ys).reshape(-1)
    np.testing.assert_allclose(np.asarray(g),
                               x_flat.T @ (x_flat @ np.asarray(beta) - y_flat),
                               rtol=1e-4, atol=1e-4)


def test_partial_gradients_respect_load_mask():
    xs, ys, _ = _data(jax.random.PRNGKey(1))
    n, ell, d = xs.shape
    loads = np.array([0, 10, 40, 25, 1, 39])
    mask = jnp.asarray(np.arange(ell)[None, :] < loads[:, None], dtype=xs.dtype)
    beta = jax.random.normal(jax.random.PRNGKey(2), (d,))
    partials = agg.client_partial_gradients(xs, ys, mask, beta)
    for i in range(n):
        xi = np.asarray(xs[i, :loads[i]])
        yi = np.asarray(ys[i, :loads[i]])
        expect = xi.T @ (xi @ np.asarray(beta) - yi) if loads[i] else np.zeros(d)
        np.testing.assert_allclose(np.asarray(partials[i]), expect,
                                   rtol=1e-4, atol=1e-4)


def test_full_coverage_sum_equals_total():
    """mask=all received + no parity => combine == uncoded full gradient."""
    xs, ys, _ = _data(jax.random.PRNGKey(3))
    n, ell, d = xs.shape
    beta = jax.random.normal(jax.random.PRNGKey(4), (d,))
    partials = agg.client_partial_gradients(xs, ys, jnp.ones((n, ell)), beta)
    combined = agg.combine(partials, jnp.ones(n), jnp.zeros(d), jnp.asarray(0.0))
    np.testing.assert_allclose(np.asarray(combined),
                               np.asarray(agg.uncoded_full_gradient(xs, ys, beta)),
                               rtol=1e-4, atol=1e-4)


def test_parity_gradient_lln():
    """(1/c) X~^T (X~ b - y~) -> X^T W^2 (X b - y) as c grows (Eq. 18)."""
    key = jax.random.PRNGKey(5)
    xs, ys, _ = _data(key, n=4, ell=30, d=8)
    n, ell, d = xs.shape
    w = jax.random.uniform(jax.random.PRNGKey(6), (n, ell), minval=0.2, maxval=1.0)
    beta = jax.random.normal(jax.random.PRNGKey(7), (d,))

    from repro.core.encoding import encode_fleet
    errs = []
    target = None
    x_flat = np.asarray(xs).reshape(-1, d)
    y_flat = np.asarray(ys).reshape(-1)
    w_flat = np.asarray(w).reshape(-1)
    resid = x_flat @ np.asarray(beta) - y_flat
    target = x_flat.T @ (w_flat ** 2 * resid)
    for c in [200, 2000, 20000]:
        xp, yp = encode_fleet(jax.random.PRNGKey(8), xs, ys, w, c)
        g = np.asarray(agg.parity_gradient(xp, yp, beta))
        errs.append(np.linalg.norm(g - target) / np.linalg.norm(target))
    assert errs[0] > errs[1] > errs[2]
    assert errs[2] < 0.05


def test_epoch_gradient_unbiased_monte_carlo():
    """E over (G, arrival masks) of the CFL gradient ~= full gradient."""
    key = jax.random.PRNGKey(9)
    xs, ys, _ = _data(key, n=5, ell=20, d=6)
    n, ell, d = xs.shape
    rngs = np.random.default_rng(0)
    beta = jax.random.normal(jax.random.PRNGKey(10), (d,))

    # synthetic plan: each device processes first half; P(return) = 0.6
    loads = np.full(n, ell // 2)
    p_ret = 0.6
    w = np.ones((n, ell), dtype=np.float32)
    w[:, :ell // 2] = np.sqrt(1 - p_ret)

    from repro.core.encoding import encode_fleet

    full = np.asarray(agg.uncoded_full_gradient(xs, ys, beta))
    acc = np.zeros(d)
    trials = 300
    c = 600
    mask_load = jnp.asarray(np.arange(ell)[None, :] < loads[:, None],
                            dtype=xs.dtype)
    for t in range(trials):
        xp, yp = encode_fleet(jax.random.PRNGKey(100 + t), xs, ys,
                              jnp.asarray(w), c)
        received = jnp.asarray(rngs.random(n) < p_ret, dtype=xs.dtype)
        partials = agg.client_partial_gradients(xs, ys, mask_load, beta)
        g_par = agg.parity_gradient(xp, yp, beta)
        g = agg.combine(partials, received, g_par, jnp.asarray(1.0))
        acc += np.asarray(g)
    acc /= trials
    rel = np.linalg.norm(acc - full) / np.linalg.norm(full)
    assert rel < 0.08, rel


def test_gd_update_direction():
    xs, ys, beta_true = _data(jax.random.PRNGKey(11))
    beta = jnp.zeros(xs.shape[-1])
    g = agg.uncoded_full_gradient(xs, ys, beta)
    m = xs.shape[0] * xs.shape[1]
    beta2 = agg.gd_update(beta, g, 0.01, m)
    assert agg.nmse(beta2, beta_true) < agg.nmse(beta, beta_true)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 5), ell=st.integers(2, 16), d=st.integers(1, 12))
def test_combine_linear_in_masks(n, ell, d):
    """combine() is linear in the arrival masks (property)."""
    key = jax.random.PRNGKey(n + 10 * ell + 100 * d)
    partials = jax.random.normal(key, (n, d))
    g_par = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    r1 = np.asarray(jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5, (n,)),
                    dtype=np.float32)
    full = agg.combine(partials, jnp.ones(n), g_par, jnp.asarray(1.0))
    part = agg.combine(partials, jnp.asarray(r1), g_par, jnp.asarray(1.0))
    rest = agg.combine(partials, jnp.asarray(1.0 - r1), g_par, jnp.asarray(0.0))
    np.testing.assert_allclose(np.asarray(part) + np.asarray(rest),
                               np.asarray(full), rtol=1e-4, atol=1e-4)
