"""Tests for the expected-return optimizer and the two-step redundancy solve."""
import numpy as np
import pytest

from repro.core.delay_model import DeviceDelayParams, total_cdf
from repro.core.redundancy import solve_redundancy, systematic_weights
from repro.core.returns import expected_return, optimal_loads
from repro.sim.network import paper_fleet


def test_expected_return_bounded_by_load():
    fleet = paper_fleet(0.2, 0.2, seed=0)
    for ell in [1, 50, 300]:
        r = expected_return(fleet.edge, ell, 10.0)
        assert np.all(r >= 0) and np.all(r <= ell)


def test_expected_return_concave_shape():
    """Paper Fig. 1: E[R(t; ell)] rises ~linearly then collapses to ~0."""
    fleet = paper_fleet(0.2, 0.2, seed=0)
    fastest = int(np.argmin(fleet.edge.a))
    t = 5.0
    loads = np.arange(0, 301)
    vals = np.array([expected_return(fleet.edge, l, t)[fastest] for l in loads])
    peak = int(np.argmax(vals))
    assert 0 < peak  # an interior or boundary-right optimum exists
    # small loads: near-linear growth (return prob ~ 1)
    assert vals[1] > 0.9
    # beyond the peak the expected return decays (or stays flat at the cap)
    if peak < 300:
        assert vals[-1] <= vals[peak]


def test_optimal_loads_match_bruteforce():
    fleet = paper_fleet(0.3, 0.1, seed=3)
    caps = np.full(24, 120)
    t = 4.0
    loads, vals = optimal_loads(fleet.edge, caps, t)
    for i in range(0, 24, 5):  # spot-check a few devices exactly
        grid = np.array([expected_return(fleet.edge, l, t)[i]
                         for l in range(0, 121)])
        assert np.argmax(grid) == loads[i]
        np.testing.assert_allclose(grid.max(), vals[i], rtol=1e-12)


def test_solve_redundancy_meets_target():
    fleet = paper_fleet(0.2, 0.2, seed=1)
    sizes = np.full(24, 300)
    m = int(sizes.sum())
    plan = solve_redundancy(fleet.edge, fleet.server, sizes, c_up=m // 4)
    assert plan.expected_agg >= m
    assert 0 < plan.c <= m // 4
    assert np.all(plan.loads >= 0) and np.all(plan.loads <= 300)
    assert plan.t_star > 0
    # aggregate return at t* computed from scratch agrees
    agg = float(np.sum(plan.loads * total_cdf(fleet.edge, plan.loads,
                                              plan.t_star)))
    agg += plan.c * total_cdf(fleet.server, plan.c, plan.t_star)[0]
    assert agg >= m * 0.999


def test_more_redundancy_shrinks_deadline():
    """Larger parity budget => smaller epoch deadline t* (paper Fig. 2)."""
    fleet = paper_fleet(0.2, 0.2, seed=1)
    sizes = np.full(24, 300)
    m = int(sizes.sum())
    t_stars = [solve_redundancy(fleet.edge, fleet.server, sizes,
                                fixed_c=int(d * m)).t_star
               for d in (0.07, 0.13, 0.28)]
    assert t_stars[0] > t_stars[1] > t_stars[2]


def test_fixed_c_respected():
    fleet = paper_fleet(0.1, 0.1, seed=2)
    sizes = np.full(24, 300)
    plan = solve_redundancy(fleet.edge, fleet.server, sizes, fixed_c=500)
    assert plan.c == 500
    assert abs(plan.delta - 500 / 7200) < 1e-12


def test_homogeneous_fleet_balanced_loads():
    """No heterogeneity => all devices get (near-)equal optimal loads."""
    fleet = paper_fleet(0.0, 0.0, seed=5)
    sizes = np.full(24, 300)
    plan = solve_redundancy(fleet.edge, fleet.server, sizes, c_up=1000)
    assert plan.loads.max() - plan.loads.min() <= 2


def test_weights_eq17():
    fleet = paper_fleet(0.2, 0.2, seed=1)
    sizes = np.full(24, 300)
    plan = solve_redundancy(fleet.edge, fleet.server, sizes, c_up=2000)
    ws = systematic_weights(plan, sizes)
    probs = total_cdf(fleet.edge, plan.loads, plan.t_star)
    for i, w in enumerate(ws):
        k = plan.loads[i]
        np.testing.assert_allclose(w[:k], np.sqrt(1 - probs[i]), rtol=1e-9)
        np.testing.assert_allclose(w[k:], 1.0)
        assert np.all((0 <= w) & (w <= 1))


def test_infeasible_target_raises():
    # Exercise the divergence guard: links with p ~ 1 need hundreds of
    # retransmissions, beyond the analytic CDF's supported regime (p <= 0.5),
    # so the aggregate return plateaus below m and the solver must abort
    # rather than loop forever.
    edge = DeviceDelayParams(a=np.full(2, 1e12), mu=np.full(2, 1e-12),
                             tau=np.ones(2), p=np.full(2, 0.99))
    server = DeviceDelayParams(a=np.array([1e12]), mu=np.array([1e-12]),
                               tau=np.zeros(1), p=np.zeros(1))
    with pytest.raises(RuntimeError):
        solve_redundancy(edge, server, np.full(2, 10), c_up=5, t_hi=1.0)
