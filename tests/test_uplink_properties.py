"""Property tests for `Strategy.uplink_bits` across all five strategies.

The contract every scheme's communication accounting must satisfy:

  * non-negative for any epoch count, zero epochs included;
  * affine in `epochs`: uplink(e) = intercept + slope * e with a constant
    per-epoch slope >= 0 (no hidden super-linear terms);
  * the intercept is the ONE-TIME share/upload term and must match the
    scheme's `setup_time` semantics — a scheme that reports setup wall
    time (parity upload, raw-data sharing) must charge one-time bits, and
    a scheme with no setup must charge none.
"""
import jax
import numpy as np
from _hyp import given, settings, st  # hypothesis, or a deterministic fallback

from repro.api import TrainData, make_strategy
from repro.sim.network import wireless_fleet

N, ELL, D = 12, 40, 30


_SETUP = {}


def _setup():
    # module-level memo instead of a fixture: the _hyp fallback's @given
    # wrapper cannot receive pytest fixtures
    if not _SETUP:
        _SETUP["fleet"] = wireless_fleet(0.2, 0.2, nu_erasure=0.3, seed=0,
                                         n=N, d=D)
        _SETUP["data"] = TrainData.linreg(jax.random.PRNGKey(0),
                                          n=N, ell=ELL, d=D)
    return _SETUP["fleet"], _SETUP["data"]


def _strategies():
    c = int(0.25 * N * ELL)
    return [
        make_strategy("uncoded"),
        make_strategy("cfl", key_seed=3, fixed_c=c),
        make_strategy("gradcode", r=3),
        make_strategy("stochastic", key_seed=3, fixed_c=c,
                      noise_multiplier=0.5, sample_frac=0.8),
        make_strategy("lowlatency", key_seed=3, fixed_c=c, chunks=4),
    ]


_STATES = {}


def _planned(strategy, fleet, data):
    key = strategy.label
    if key not in _STATES:
        _STATES[key] = strategy.plan(fleet, data)
    return _STATES[key]


@settings(max_examples=10, deadline=None)
@given(e1=st.integers(0, 200), e2=st.integers(0, 200))
def test_uplink_bits_nonnegative_and_affine(e1, e2):
    fleet, data = _setup()
    for strategy in _strategies():
        state = _planned(strategy, fleet, data)
        b0 = strategy.uplink_bits(state, fleet, 0)
        b1 = strategy.uplink_bits(state, fleet, e1)
        b2 = strategy.uplink_bits(state, fleet, e2)
        assert b0 >= 0 and b1 >= 0 and b2 >= 0, strategy.label
        # affine: b(e) = b0 + slope * e, same slope everywhere
        if e1 > 0:
            slope1 = (b1 - b0) / e1
            assert slope1 >= 0, strategy.label
            np.testing.assert_allclose(
                b2, b0 + slope1 * e2, rtol=1e-12,
                err_msg=f"{strategy.label}: uplink_bits not affine in epochs")


def test_one_time_term_matches_setup_time_semantics():
    """intercept > 0 <=> the schedule reports a one-time setup cost."""
    fleet, data = _setup()
    for strategy in _strategies():
        state = _planned(strategy, fleet, data)
        b0 = strategy.uplink_bits(state, fleet, 0)
        sched = strategy.sample_epochs(state, fleet, 2,
                                       np.random.default_rng(0))
        if sched.setup_time > 0:
            assert b0 > 0, \
                f"{strategy.label}: setup time without one-time uplink bits"
        else:
            assert b0 == 0, \
                f"{strategy.label}: one-time uplink bits without setup time"


def test_coded_one_time_term_is_parity_upload():
    """For the three coded schemes the intercept is exactly the summed
    per-client parity upload."""
    fleet, data = _setup()
    coded = {s.label: s for s in _strategies()}
    for label in ("cfl", "scfl", "lowlat"):
        strategy = coded[label]
        state = _planned(strategy, fleet, data)
        b0 = strategy.uplink_bits(state, fleet, 0)
        np.testing.assert_allclose(
            b0, float(np.sum(state.parity_upload_bits())), rtol=1e-12)
