"""Micro-benchmark: scan-jitted `Session` vs the legacy per-epoch loop.

The old `run_cfl` re-entered Python every epoch, dispatched a handful of
separate jitted calls, and forced a host<->device sync per epoch
(`float(nmse)`), which dominated wall time at the paper's small d=500.  The
Session engine pre-samples all delay tensors and runs the entire trace in
one `jax.lax.scan` over a flat (m, d) data layout, syncing once per run.

Both paths share the SAME one-time protocol setup (redundancy optimization
+ parity encoding, identical work in either) so the reported epochs/sec
measures the training engines themselves on the §IV config (n=24, d=500).

    PYTHONPATH=src python -m benchmarks.perf_session [--epochs 300]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import CodedFL, Session, TrainData
from repro.core import aggregation, cfl
from repro.core.delay_model import sample_total
from repro.sim.network import paper_fleet

from .common import D, ELL, LR, M, N_DEVICES, emit


def legacy_epochs_cfl(fleet, state: cfl.CFLState, data: TrainData,
                      lr: float, epochs: int, rng: np.random.Generator):
    """The seed repo's per-epoch Python loop (host-synced every epoch)."""
    xs, ys, beta_true = data.xs, data.ys, data.beta_true
    n, ell, d = xs.shape
    m = n * ell
    plan = state.plan
    t_star = plan.t_star
    # one-time parity-upload retransmission draw (part of the legacy
    # generator stream, drawn before the epoch loop)
    rng.geometric(1.0 - fleet.edge.p, size=n)
    beta = jnp.zeros(d, dtype=xs.dtype)
    errs = [float(aggregation.nmse(beta, beta_true))]
    for _ in range(epochs):
        t_i = sample_total(fleet.edge, plan.loads, rng)
        received = jnp.asarray((t_i <= t_star) & (plan.loads > 0),
                               dtype=xs.dtype)
        t_srv = sample_total(fleet.server, np.array([state.c]), rng)[0]
        par_ok = jnp.asarray(float(t_srv <= t_star), dtype=xs.dtype)
        g = cfl.epoch_gradient(state, xs, ys, beta, received, par_ok)
        beta = aggregation.gd_update(beta, g, lr, m)
        errs.append(float(aggregation.nmse(beta, beta_true)))  # host sync
    return np.array(errs)


def main(epochs: int = 300, delta: float = 0.28) -> None:
    fleet = paper_fleet(0.2, 0.2, seed=0)
    data = TrainData.linreg(jax.random.PRNGKey(0), N_DEVICES, ELL, D)
    c = int(delta * M)

    session = Session(strategy=CodedFL(key=jax.random.PRNGKey(1), fixed_c=c,
                                       include_upload_delay=False),
                      fleet=fleet, lr=LR, epochs=epochs)
    # one-time protocol setup, shared by both paths
    t0 = time.perf_counter()
    state = session.plan(data)
    jax.block_until_ready(state.x_parity)
    t_plan = time.perf_counter() - t0

    # warmup both paths (jit compilation)
    session.run(data, rng=np.random.default_rng(0), state=state)
    legacy_epochs_cfl(fleet, state, data, LR, 5, np.random.default_rng(0))

    t0 = time.perf_counter()
    rep = session.run(data, rng=np.random.default_rng(1), state=state)
    t_scan = time.perf_counter() - t0

    t0 = time.perf_counter()
    errs = legacy_epochs_cfl(fleet, state, data, LR, epochs,
                             np.random.default_rng(1))
    t_loop = time.perf_counter() - t0

    # sanity: both paths compute the same trajectory
    np.testing.assert_allclose(rep.nmse, errs, rtol=1e-3, atol=1e-6)

    eps_scan = epochs / t_scan
    eps_loop = epochs / t_loop
    speedup = eps_scan / eps_loop
    emit("perf_session/setup_once", t_plan * 1e6,
         f"plan+encode={t_plan:.2f}s (shared by both paths)")
    emit("perf_session/scan_jitted", t_scan * 1e6 / epochs,
         f"epochs_per_sec={eps_scan:.0f}")
    emit("perf_session/legacy_loop", t_loop * 1e6 / epochs,
         f"epochs_per_sec={eps_loop:.0f}")
    emit("perf_session/speedup", 0.0,
         f"scan_over_loop={speedup:.1f}x;epochs={epochs};n={N_DEVICES};d={D}")
    print(f"\nscan-jitted Session: {eps_scan:.0f} epochs/s | "
          f"legacy Python loop: {eps_loop:.0f} epochs/s | "
          f"speedup {speedup:.1f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=300)
    ap.add_argument("--delta", type=float, default=0.28)
    main(**vars(ap.parse_args()))
