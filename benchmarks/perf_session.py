"""Micro-benchmark: planning + training engines, new vs the seed's stack.

Planning (`CodedFL.plan` = redundancy solve + parity encoding) used to
dominate Session wall time: ~4s on the §IV config vs ~0.3s for the whole
scan-jitted training trace.  Two sections quantify the replacement:

  * plan_single — one §IV fixed-c plan: the seed's scalar stack (bisection
    with one CDF call per integer load, `repro.plan.reference`, plus the
    stack-then-sum encoder) vs the batched grid solver + streamed encoder.
  * plan_sweep16 — a 16-point fixed-c sweep planned in ONE
    `solve_redundancy_batched` call vs 16 sequential legacy solves
    (legacy cost = 16x the measured single solve).

The training section is unchanged: the scan-jitted `Session` engine vs the
seed's per-epoch Python loop (host-synced every epoch), sharing one
protocol setup.

  * epoch — the fused round-gradient path (`grad_path="fused"`: packed
    systematic rows + Gram-folded parity, see `repro.kernels.round_grad`)
    vs the reference expressions (`grad_path="reference"`), identical
    Session/plan/schedule otherwise.  Both traces must agree to
    rtol 1e-3 / atol 1e-6 with bit-identical durations.

    PYTHONPATH=src python -m benchmarks.perf_session [--epochs 300]
    PYTHONPATH=src python -m benchmarks.perf_session --smoke   # CI budget
    PYTHONPATH=src python -m benchmarks.perf_session --smoke --epoch

`--smoke` runs only the new planner (no multi-second legacy baselines) and
asserts plan latencies stay under fixed budgets, so planner regressions
fail CI instead of silently eating sweep time.  `--smoke --epoch` runs
only the epoch section and asserts fused >= $EPOCH_SMOKE_MIN_SPEEDUP
(default 1.3) x reference epochs/sec on the §IV shapes
(`BENCH_epoch.json`).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import CodedFL, Session, TrainData
from repro.core import aggregation, cfl
from repro.core.delay_model import sample_total
from repro.core.encoding import generator_matrix
from repro.plan import PlanRequest, solve_redundancy_batched
from repro.sim.network import paper_fleet

from .common import D, ELL, LR, M, N_DEVICES, dump_bench, emit

# --smoke budgets (seconds, warm): generous multiples of the measured warm
# latencies (~0.1s single / ~1.8s sweep on the dev box) so CI noise does not
# flake, while a return of the 4s-per-plan stack still fails loudly.
SMOKE_SINGLE_BUDGET_S = 1.0
SMOKE_SWEEP_BUDGET_S = 5.0


def legacy_encode_fleet(key, xs, ys, weights, c):
    """The seed's stack-then-sum fleet encoder (kept here as baseline)."""
    n = xs.shape[0]
    keys = jax.random.split(key, n)

    def one(k, x, y, w):
        g = generator_matrix(k, c, x.shape[0], dtype=x.dtype)
        return g @ (w[:, None] * x), g @ (w * y)

    xps, yps = jax.vmap(one)(keys, xs, ys, weights)
    return jnp.sum(xps, axis=0), jnp.sum(yps, axis=0)


def legacy_epochs_cfl(fleet, state: cfl.CFLState, data: TrainData,
                      lr: float, epochs: int, rng: np.random.Generator):
    """The seed repo's per-epoch Python loop (host-synced every epoch)."""
    xs, ys, beta_true = data.xs, data.ys, data.beta_true
    n, ell, d = xs.shape
    m = n * ell
    plan = state.plan
    t_star = plan.t_star
    # one-time parity-upload retransmission draw (part of the legacy
    # generator stream, drawn before the epoch loop)
    rng.geometric(1.0 - fleet.edge.p, size=n)
    beta = jnp.zeros(d, dtype=xs.dtype)
    errs = [float(aggregation.nmse(beta, beta_true))]
    for _ in range(epochs):
        t_i = sample_total(fleet.edge, plan.loads, rng)
        received = jnp.asarray((t_i <= t_star) & (plan.loads > 0),
                               dtype=xs.dtype)
        t_srv = sample_total(fleet.server, np.array([state.c]), rng)[0]
        par_ok = jnp.asarray(float(t_srv <= t_star), dtype=xs.dtype)
        g = cfl.epoch_gradient(state, xs, ys, beta, received, par_ok)
        beta = aggregation.gd_update(beta, g, lr, m)
        errs.append(float(aggregation.nmse(beta, beta_true)))  # host sync
    return np.array(errs)


def bench_planning(fleet, data: TrainData, session: Session, c: int,
                   smoke: bool) -> cfl.CFLState:
    """Plan-latency section; returns the planned state for the train bench."""
    sizes = np.full(N_DEVICES, ELL, dtype=np.int64)
    req = PlanRequest(edge=fleet.edge, server=fleet.server, data_sizes=sizes,
                      fixed_c=c)
    sweep_reqs = [PlanRequest(edge=fleet.edge, server=fleet.server,
                              data_sizes=sizes, fixed_c=int(delta * M))
                  for delta in np.linspace(0.05, 0.5, 16)]

    # warm up the jitted solver + encoder for both batch shapes
    solve_redundancy_batched([req])
    solve_redundancy_batched(sweep_reqs)
    state = session.plan(data)
    jax.block_until_ready(state.x_parity)

    t0 = time.perf_counter()
    solve_redundancy_batched([req])
    t_solve = time.perf_counter() - t0

    t0 = time.perf_counter()
    state = session.plan(data)
    jax.block_until_ready(state.x_parity)
    t_plan = time.perf_counter() - t0

    t0 = time.perf_counter()
    sweep_plans = solve_redundancy_batched(sweep_reqs)
    t_sweep = time.perf_counter() - t0
    assert len(sweep_plans) == 16 and all(p.c > 0 for p in sweep_plans)

    if smoke:
        emit("perf_session/plan_single_new", t_plan * 1e6,
             f"solve={t_solve*1e3:.0f}ms;budget={SMOKE_SINGLE_BUDGET_S}s")
        emit("perf_session/plan_sweep16_new", t_sweep * 1e6,
             f"budget={SMOKE_SWEEP_BUDGET_S}s")
        # artifact FIRST: a budget regression is exactly when the measured
        # values must survive into the uploaded BENCH_perf.json
        dump_bench("perf", gates={
            "plan_single_s": round(t_plan, 4),
            "plan_single_budget_s": SMOKE_SINGLE_BUDGET_S,
            "plan_solve_s": round(t_solve, 4),
            "plan_sweep16_s": round(t_sweep, 4),
            "plan_sweep16_budget_s": SMOKE_SWEEP_BUDGET_S,
        })
        assert t_plan < SMOKE_SINGLE_BUDGET_S, \
            f"single plan {t_plan:.2f}s over budget {SMOKE_SINGLE_BUDGET_S}s"
        assert t_sweep < SMOKE_SWEEP_BUDGET_S, \
            f"16-pt sweep {t_sweep:.2f}s over budget {SMOKE_SWEEP_BUDGET_S}s"
        return state

    # --- legacy baselines: the seed's scalar solve + stack-then-sum encode
    from repro.plan.reference import solve_redundancy_reference
    t0 = time.perf_counter()
    plan_ref = solve_redundancy_reference(fleet.edge, fleet.server, sizes,
                                          fixed_c=c)
    t_solve_ref = time.perf_counter() - t0

    from repro.core.redundancy import systematic_weights
    w_ref = jnp.asarray(np.stack(systematic_weights(plan_ref, sizes)),
                        dtype=data.xs.dtype)
    legacy_encode_fleet(session.strategy.key, data.xs, data.ys, w_ref, c)
    t0 = time.perf_counter()
    xp, _ = legacy_encode_fleet(session.strategy.key, data.xs, data.ys,
                                w_ref, c)
    jax.block_until_ready(xp)
    t_enc_ref = time.perf_counter() - t0
    t_plan_ref = t_solve_ref + t_enc_ref

    # sanity: the shimmed plan matches the seed algorithm.  At the default
    # eps_rel=1e-3 both solvers stop within tolerance of the true crossing
    # but at slightly different deadlines, so an integer load may shift by
    # one point; the strict identical-loads parity is enforced at tighter
    # eps in tests/test_plan_solver.py.
    plan_new = state.plan
    np.testing.assert_allclose(plan_new.t_star, plan_ref.t_star, rtol=1e-3)
    assert np.max(np.abs(plan_new.loads - plan_ref.loads)) <= 1
    assert plan_new.c == plan_ref.c

    emit("perf_session/plan_single", t_plan * 1e6,
         f"legacy={t_plan_ref:.2f}s(solve={t_solve_ref:.2f}+"
         f"enc={t_enc_ref:.2f});new={t_plan:.2f}s;"
         f"speedup={t_plan_ref / t_plan:.1f}x")
    emit("perf_session/plan_sweep16", t_sweep * 1e6,
         f"legacy_est={16 * t_solve_ref:.1f}s(16 solves);"
         f"new_batched={t_sweep:.2f}s;"
         f"speedup={16 * t_solve_ref / t_sweep:.1f}x")
    print(f"plan: legacy {t_plan_ref:.2f}s -> new {t_plan:.2f}s "
          f"({t_plan_ref / t_plan:.1f}x) | 16-pt sweep: "
          f"{16 * t_solve_ref:.1f}s -> {t_sweep:.2f}s "
          f"({16 * t_solve_ref / t_sweep:.1f}x, one batched call)")
    return state


def _timed_runs(session: Session, data: TrainData, state, reps: int) -> tuple:
    """Warm a session's engine, then best-of-`reps` wall time for one
    full `run` (schedule sampling + scan execution), plus the report."""
    session.run(data, rng=np.random.default_rng(0), state=state)
    best, report = np.inf, None
    for _ in range(reps):
        t0 = time.perf_counter()
        report = session.run(data, rng=np.random.default_rng(1), state=state)
        best = min(best, time.perf_counter() - t0)
    return best, report


def bench_epoch(data: TrainData, session: Session, state: cfl.CFLState,
                gate: bool, reps: int = 3) -> None:
    """Fused vs reference round-gradient path, same plan and schedule.

    Times whole `Session.run` calls (epochs/sec as a user sees them, host
    schedule sampling included) on the §IV CodedFL config.  The two
    traces are asserted equivalent (rtol 1e-3 / atol 1e-6, durations
    bit-identical) BEFORE any perf gate, and the artifact is written
    before the speedup assert so a regression still uploads its numbers.
    """
    epochs = session.epochs
    floor = float(os.environ.get("EPOCH_SMOKE_MIN_SPEEDUP", "1.3"))
    reference = dataclasses.replace(
        session,
        strategy=dataclasses.replace(session.strategy,
                                     grad_path=aggregation.REFERENCE))

    t_fused, rep_fused = _timed_runs(session, data, state, reps)
    t_ref, rep_ref = _timed_runs(reference, data, state, reps)

    # correctness first: identical schedules, equivalent trajectories
    np.testing.assert_array_equal(rep_fused.epoch_durations,
                                  rep_ref.epoch_durations)
    np.testing.assert_allclose(rep_fused.nmse, rep_ref.nmse,
                               rtol=1e-3, atol=1e-6)

    eps_fused = epochs / t_fused
    eps_ref = epochs / t_ref
    speedup = eps_fused / eps_ref
    emit("perf_session/epoch_fused", t_fused * 1e6 / epochs,
         f"epochs_per_sec={eps_fused:.0f}")
    emit("perf_session/epoch_reference", t_ref * 1e6 / epochs,
         f"epochs_per_sec={eps_ref:.0f}")
    emit("perf_session/epoch_fused_speedup", 0.0,
         f"fused_over_reference={speedup:.2f}x;floor={floor};"
         f"epochs={epochs};m={M};d={D}")
    print(f"epoch: fused {eps_fused:.0f} epochs/s | reference "
          f"{eps_ref:.0f} epochs/s | speedup {speedup:.2f}x "
          f"(floor {floor}x)")
    if gate:
        dump_bench("epoch", gates={
            "epoch_fused_epochs_per_sec": round(eps_fused, 1),
            "epoch_reference_epochs_per_sec": round(eps_ref, 1),
            "epoch_fused_speedup": round(speedup, 3),
            "epoch_min_speedup": floor,
        })
        assert speedup >= floor, \
            f"fused epoch path {speedup:.2f}x < required {floor}x"


def main(epochs: int = 300, delta: float = 0.28, smoke: bool = False,
         epoch: bool = False) -> None:
    fleet = paper_fleet(0.2, 0.2, seed=0)
    data = TrainData.linreg(jax.random.PRNGKey(0), N_DEVICES, ELL, D)
    c = int(delta * M)

    session = Session(strategy=CodedFL(key=jax.random.PRNGKey(1), fixed_c=c,
                                       include_upload_delay=False),
                      fleet=fleet, lr=LR, epochs=epochs)

    if smoke and epoch:  # epoch-smoke CI stage: fused-vs-reference gate only
        state = session.plan(data)
        bench_epoch(data, session, state, gate=True)
        print("perf_session --smoke --epoch OK (fused floor held)")
        return

    # --- planning section --------------------------------------------------
    state = bench_planning(fleet, data, session, c, smoke)
    if smoke:
        print("perf_session --smoke OK (plan budgets held)")
        return

    # --- training engines (shared setup) -----------------------------------
    # warmup both paths (jit compilation)
    session.run(data, rng=np.random.default_rng(0), state=state)
    legacy_epochs_cfl(fleet, state, data, LR, 5, np.random.default_rng(0))

    t0 = time.perf_counter()
    rep = session.run(data, rng=np.random.default_rng(1), state=state)
    t_scan = time.perf_counter() - t0

    t0 = time.perf_counter()
    errs = legacy_epochs_cfl(fleet, state, data, LR, epochs,
                             np.random.default_rng(1))
    t_loop = time.perf_counter() - t0

    # sanity: both paths compute the same trajectory
    np.testing.assert_allclose(rep.nmse, errs, rtol=1e-3, atol=1e-6)

    eps_scan = epochs / t_scan
    eps_loop = epochs / t_loop
    speedup = eps_scan / eps_loop
    emit("perf_session/scan_jitted", t_scan * 1e6 / epochs,
         f"epochs_per_sec={eps_scan:.0f}")
    emit("perf_session/legacy_loop", t_loop * 1e6 / epochs,
         f"epochs_per_sec={eps_loop:.0f}")
    emit("perf_session/speedup", 0.0,
         f"scan_over_loop={speedup:.1f}x;epochs={epochs};n={N_DEVICES};d={D}")
    print(f"\nscan-jitted Session: {eps_scan:.0f} epochs/s | "
          f"legacy Python loop: {eps_loop:.0f} epochs/s | "
          f"speedup {speedup:.1f}x")

    # --- fused vs reference round-gradient path (informational here) -------
    bench_epoch(data, session, state, gate=False)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=300)
    ap.add_argument("--delta", type=float, default=0.28)
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI mode: new planner only, assert budgets")
    ap.add_argument("--epoch", action="store_true",
                    help="with --smoke: run only the fused-vs-reference "
                         "epoch section and gate EPOCH_SMOKE_MIN_SPEEDUP")
    main(**vars(ap.parse_args()))
