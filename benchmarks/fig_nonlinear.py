"""CodedFedL non-linear benchmark: RFF kernel classification vs linear.

The CodedFedL scenario (arXiv:2007.03273): clients hold raw inputs whose
class boundaries are non-linear (`repro.data.classification_dataset`'s
RBF-network teacher), push them through the shared random-Fourier-feature
map, and CFL-train a least-squares one-vs-rest head in feature space
under the MEC delay model.  Three comparisons:

  * **coded vs uncoded at equal wall-clock** — the headline gate: the
    coded run's deadline-t* epochs buy more gradient steps per second
    than the uncoded straggler-wait, so at the coded run's finish time
    its test accuracy must be at least the uncoded head's.  The
    equal-time uncoded head comes from a re-run at the epoch count that
    fits in the coded wall-clock budget (prefix-identical draws, so it
    IS the full run's trajectory truncated).
  * **kernel vs best-linear** — the non-linearity gate: the GD-trained
    feature-space head must beat the closed-form least-squares head on
    the RAW inputs (the best any linear model could do), otherwise the
    kernel machinery isn't earning its keep.
  * **Pallas encode parity** — the feature-space parity encode with
    `use_kernel=True` (tuned `block="auto"` tiles) must match the XLA
    path, so the accelerated encode composes with the new strategy.

    PYTHONPATH=src python -m benchmarks.fig_nonlinear [--epochs 600]
    PYTHONPATH=src python -m benchmarks.fig_nonlinear --smoke   # CI gate

`--smoke` runs one small configuration and writes the gate values
(`coded_accuracy`, `uncoded_accuracy_equal_time`, `linear_accuracy`) to
BENCH_nonlinear.json for the perf-trend trajectory.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Session, TrainData, make_strategy
from repro.data import classification_dataset, one_vs_rest_targets
from repro.sim.network import wireless_fleet

from .common import Timer, dump_bench, emit

# §V-style configuration, scaled to CI: binary labels from a 32-centre
# RBF teacher in 6 raw dimensions, 256 Fourier features, 12 clients.
N_DEVICES = 12
ELL_TRAIN = 100
ELL_TEST = 50
D_RAW = 6
D_FEAT = 256
CENTERS = 32
TEACHER_GAMMA = 2.0
DATA_SEED = 2
LR = 0.5
DELTA = 0.3


def make_problem(seed: int = DATA_SEED):
    """Train/test split of one teacher's data + the strategy that maps it.

    Returns (data, strategy, phi_test (n*ell_te, D), y_test (n*ell_te,)).
    `data.beta_true` is the feature-space least-squares reference head, so
    the NMSE trace measures distance to the kernel regressor.
    """
    key = jax.random.PRNGKey(seed)
    xs, labels = classification_dataset(
        key, N_DEVICES, ELL_TRAIN + ELL_TEST, D_RAW,
        n_classes=2, centers=CENTERS, gamma=TEACHER_GAMMA)
    y = one_vs_rest_targets(labels, 1)
    xs_tr, xs_te = xs[:, :ELL_TRAIN], xs[:, ELL_TRAIN:]
    y_tr, y_te = y[:, :ELL_TRAIN], y[:, ELL_TRAIN:]

    strategy = make_strategy(
        "codedfedl", key_seed=7, d_feat=D_FEAT,
        rff_gamma=TEACHER_GAMMA / D_RAW,
        fixed_c=int(DELTA * N_DEVICES * ELL_TRAIN))
    dummy = TrainData(xs=xs_tr, ys=y_tr, beta_true=jnp.zeros(D_FEAT))
    phi_tr = np.asarray(strategy.features(dummy),
                        np.float64).reshape(-1, D_FEAT)
    beta_ref, *_ = np.linalg.lstsq(
        phi_tr, np.asarray(y_tr, np.float64).reshape(-1), rcond=None)
    data = TrainData(xs=xs_tr, ys=y_tr,
                     beta_true=jnp.asarray(beta_ref, jnp.float32))
    phi_te = np.asarray(
        strategy.features(TrainData(xs=xs_te, ys=y_te,
                                    beta_true=jnp.zeros(D_FEAT))),
        np.float64).reshape(-1, D_FEAT)
    return data, strategy, phi_te, np.asarray(y_te, np.float64).reshape(-1)


def sign_accuracy(phi: np.ndarray, beta: np.ndarray,
                  y: np.ndarray) -> float:
    return float(np.mean((phi @ np.asarray(beta, np.float64) > 0)
                         == (y > 0)))


def best_linear_accuracy(data: TrainData, phi_te_y: tuple) -> float:
    """Closed-form least-squares head on the RAW inputs — the ceiling for
    any linear model, trained or not (affine: a bias column is added)."""
    phi_te, y_te = phi_te_y
    del phi_te  # the linear head never sees the feature space
    key = jax.random.PRNGKey(DATA_SEED)
    xs, labels = classification_dataset(
        key, N_DEVICES, ELL_TRAIN + ELL_TEST, D_RAW,
        n_classes=2, centers=CENTERS, gamma=TEACHER_GAMMA)
    y = np.asarray(one_vs_rest_targets(labels, 1), np.float64)
    X = np.asarray(xs, np.float64)
    Xtr = X[:, :ELL_TRAIN].reshape(-1, D_RAW)
    Xte = X[:, ELL_TRAIN:].reshape(-1, D_RAW)
    ytr = y[:, :ELL_TRAIN].reshape(-1)
    b, *_ = np.linalg.lstsq(np.c_[Xtr, np.ones(len(Xtr))], ytr, rcond=None)
    pred = np.c_[Xte, np.ones(len(Xte))] @ b
    return float(np.mean((pred > 0) == (y_te > 0)))


def equal_time_epochs(uncoded_rep, t_budget: float) -> int:
    """Largest epoch count whose cumulative uncoded wall-clock fits in
    `t_budget` (the coded run's finish time)."""
    cum = np.cumsum(uncoded_rep.epoch_durations)
    return int(np.searchsorted(cum, t_budget, side="right"))


def run_pair(fleet, data, strategy, epochs: int, seed: int = 0):
    """Coded run + full uncoded run + uncoded re-run at equal wall-clock.

    The uncoded arm trains the SAME feature-space objective (pre-mapped
    inputs), so the only difference is the epoch protocol."""
    coded = Session(strategy=strategy, fleet=fleet, lr=LR,
                    epochs=epochs).run(data,
                                       rng=np.random.default_rng(seed))
    feat_data = TrainData(xs=strategy.features(data), ys=data.ys,
                          beta_true=data.beta_true)
    base = Session(strategy=make_strategy("uncoded"), fleet=fleet, lr=LR,
                   epochs=epochs)
    uncoded = base.run(feat_data, rng=np.random.default_rng(seed))
    e_eq = equal_time_epochs(uncoded, coded.times[-1])
    # prefix-identical draws: the truncated run IS the full trajectory
    # at epoch e_eq, harvested through the engine's final-beta slot
    eq = Session(strategy=make_strategy("uncoded"), fleet=fleet, lr=LR,
                 epochs=e_eq).run(feat_data,
                                  rng=np.random.default_rng(seed))
    assert np.array_equal(np.asarray(eq.nmse),
                          np.asarray(uncoded.nmse[:e_eq + 1])), \
        "equal-time uncoded re-run diverged from the full trajectory"
    return coded, uncoded, eq


def encode_kernel_parity(fleet, data, strategy) -> float:
    """Max |Pallas - XLA| over the feature-space parity encode."""
    import dataclasses
    plain = strategy.plan(fleet, data)
    kern = dataclasses.replace(strategy, use_kernel=True)
    accel = kern.plan_with(fleet, data, plain.plan)
    return float(jnp.max(jnp.abs(accel.x_parity - plain.x_parity)))


# ---------------------------------------------------------------------------
# smoke mode (CI)
# ---------------------------------------------------------------------------

def smoke(epochs: int = 300) -> None:
    fleet = wireless_fleet(0.3, 0.3, nu_erasure=0.3, seed=0,
                           n=N_DEVICES, d=D_FEAT)
    data, strategy, phi_te, y_te = make_problem()

    with Timer() as t:
        coded, uncoded, eq = run_pair(fleet, data, strategy, epochs)
    acc_coded = sign_accuracy(phi_te, coded.beta, y_te)
    acc_eq = sign_accuracy(phi_te, eq.beta, y_te)
    acc_lin = best_linear_accuracy(data, (phi_te, y_te))
    enc_err = encode_kernel_parity(fleet, data, strategy)

    emit("fig_nonlinear/smoke_pair", t.us / (3 * epochs),
         f"coded_acc={acc_coded:.4f};eq_time_acc={acc_eq:.4f};"
         f"eq_epochs={eq.epochs};t_coded={coded.times[-1]:.0f}s")
    emit("fig_nonlinear/encode_kernel_parity", 0.0,
         f"max_abs_err={enc_err:.3e}")
    gates = {"coded_accuracy": round(acc_coded, 4),
             "uncoded_accuracy_equal_time": round(acc_eq, 4),
             "linear_accuracy": round(acc_lin, 4),
             "equal_time_epochs": eq.epochs,
             "coded_final_nmse": coded.final_nmse(),
             "encode_kernel_max_err": enc_err}
    try:
        assert np.all(np.isfinite(coded.nmse)), "coded trace has NaNs"
        assert coded.final_nmse() < coded.nmse[0], \
            "coded kernel head does not descend"
        assert acc_coded >= acc_eq, \
            f"coded head ({acc_coded:.4f}) lost to the uncoded head at " \
            f"equal wall-clock ({acc_eq:.4f})"
        assert acc_coded > acc_lin + 0.02, \
            f"kernel head ({acc_coded:.4f}) does not beat the best " \
            f"linear model ({acc_lin:.4f}) — feature map is not earning"
        assert enc_err < 1e-3, \
            f"Pallas feature-encode diverged from XLA by {enc_err:.3e}"
    finally:
        dump_bench("nonlinear", gates=gates)
    print("fig_nonlinear --smoke OK (coded >= equal-time uncoded, "
          "kernel > linear, encode parity)")


# ---------------------------------------------------------------------------
# full mode
# ---------------------------------------------------------------------------

def main(epochs: int = 600) -> None:
    fleet = wireless_fleet(0.3, 0.3, nu_erasure=0.3, seed=0,
                           n=N_DEVICES, d=D_FEAT)
    data, strategy, phi_te, y_te = make_problem()

    with Timer() as t:
        coded, uncoded, eq = run_pair(fleet, data, strategy, epochs)
    acc_coded = sign_accuracy(phi_te, coded.beta, y_te)
    acc_full = sign_accuracy(phi_te, uncoded.beta, y_te)
    acc_eq = sign_accuracy(phi_te, eq.beta, y_te)
    acc_lin = best_linear_accuracy(data, (phi_te, y_te))
    emit("fig_nonlinear/head_to_head", t.us / (3 * epochs),
         f"coded_acc={acc_coded:.4f};uncoded_full={acc_full:.4f};"
         f"uncoded_equal_time={acc_eq:.4f};linear={acc_lin:.4f};"
         f"eq_epochs={eq.epochs};t_coded={coded.times[-1]:.0f}s;"
         f"t_uncoded={uncoded.times[-1]:.0f}s")
    assert acc_coded >= acc_eq
    assert acc_coded > acc_lin

    # accuracy vs feature width: more Fourier features approximate the
    # teacher kernel better (monotone up to estimation noise)
    import dataclasses
    for d_feat in (32, 128, 512):
        strat = dataclasses.replace(strategy, d_feat=d_feat)
        dummy = TrainData(xs=data.xs, ys=data.ys,
                          beta_true=jnp.zeros(d_feat))
        phi = np.asarray(strat.features(dummy),
                         np.float64).reshape(-1, d_feat)
        beta_ref, *_ = np.linalg.lstsq(
            phi, np.asarray(data.ys, np.float64).reshape(-1), rcond=None)
        dd = TrainData(xs=data.xs, ys=data.ys,
                       beta_true=jnp.asarray(beta_ref, jnp.float32))
        rep = Session(strategy=strat, fleet=fleet, lr=LR,
                      epochs=epochs).run(dd, rng=np.random.default_rng(0))
        xs_te_raw = classification_dataset(
            jax.random.PRNGKey(DATA_SEED), N_DEVICES,
            ELL_TRAIN + ELL_TEST, D_RAW, n_classes=2, centers=CENTERS,
            gamma=TEACHER_GAMMA)[0][:, ELL_TRAIN:]
        pte = np.asarray(
            strat.features(TrainData(xs=xs_te_raw, ys=jnp.zeros(
                xs_te_raw.shape[:2]), beta_true=jnp.zeros(d_feat))),
            np.float64).reshape(-1, d_feat)
        acc = sign_accuracy(pte, rep.beta, y_te)
        emit(f"fig_nonlinear/width_{d_feat}", 0.0,
             f"accuracy={acc:.4f};final_nmse={rep.final_nmse():.3f};"
             f"t_star={rep.epoch_durations[0]:.2f}s")
        assert np.all(np.isfinite(rep.nmse))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=600)
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI mode: one configuration, assert gates")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        main(epochs=args.epochs)
