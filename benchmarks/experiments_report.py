"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
the recorded dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.experiments_report [--optimized]
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.roofline.analysis import model_flops


def _max_term(st):
    coll = sum(v for k, v in st["corrected_collectives"].items()
               if not k.startswith("n_"))
    return (st["corrected_flops"] / PEAK_FLOPS_BF16,
            st["corrected_bytes"] / HBM_BW, coll / ICI_BW)


def dryrun_table(runs: dict, mesh: str) -> str:
    lines = ["| arch | shape | compile s | params | args GB/dev | "
             "HLO flops/dev | coll bytes/dev |",
             "|---|---|---|---|---|---|---|"]
    for key in sorted(runs):
        a, s, m = key.split("|")
        if m != mesh:
            continue
        st = runs[key]
        if not st.get("ok"):
            lines.append(f"| {a} | {s} | FAILED | | | | |")
            continue
        coll = sum(v for k, v in st["corrected_collectives"].items()
                   if not k.startswith("n_"))
        lines.append(
            f"| {a} | {s} | {st['compile_s']} | {st['n_params']/1e9:.2f}B | "
            f"{(st['memory']['argument_size'] or 0)/1e9:.2f} | "
            f"{st['corrected_flops']:.2e} | {coll:.2e} |")
    return "\n".join(lines)


def roofline_table(runs: dict, mesh: str = "16x16") -> str:
    lines = ["| arch | shape | t_comp s | t_mem s | t_coll s | dominant | "
             "MODEL_FLOPS/dev | useful | fits 16G |",
             "|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(runs):
        a, s, m = key.split("|")
        if m != mesh or not runs[key].get("ok"):
            continue
        st = runs[key]
        tc, tm, tx = _max_term(st)
        dom = max((("compute", tc), ("memory", tm), ("collective", tx)),
                  key=lambda kv: kv[1])[0]
        cfg = get_config(a.split("-sw")[0])
        mf = model_flops(cfg, s) / st["n_devices"]
        ratio = mf / st["corrected_flops"] if st["corrected_flops"] else 0
        gb = (st["memory"]["argument_size"] or 0) / 1e9
        fits = "yes" if gb < 16 else "NO"
        lines.append(f"| {a} | {s} | {tc:.3e} | {tm:.3e} | {tx:.3e} | "
                     f"{dom} | {mf:.2e} | {ratio:.2f} | {fits} ({gb:.1f}G) |")
    return "\n".join(lines)


def before_after(base: dict, opt: dict, mesh: str = "16x16") -> str:
    lines = ["| arch | shape | baseline max-term s | optimized max-term s | "
             "speedup |", "|---|---|---|---|---|"]
    tot_b = tot_o = 0.0
    for key in sorted(base):
        a, s, m = key.split("|")
        if m != mesh or key not in opt:
            continue
        if not (base[key].get("ok") and opt[key].get("ok")):
            continue
        mb = max(_max_term(base[key]))
        mo = max(_max_term(opt[key]))
        tot_b += mb
        tot_o += mo
        lines.append(f"| {a} | {s} | {mb:.3e} | {mo:.3e} | {mb/mo:.2f}x |")
    lines.append(f"| **sum** | | **{tot_b:.1f}** | **{tot_o:.1f}** | "
                 f"**{tot_b/tot_o:.2f}x** |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--base", default="dryrun_results.json")
    ap.add_argument("--opt", default="dryrun_optimized.json")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "perf"])
    args = ap.parse_args()
    base = json.load(open(args.base))
    try:
        opt = json.load(open(args.opt))
    except FileNotFoundError:
        opt = None

    if args.section in ("all", "dryrun"):
        print("### Single-pod mesh 16x16 (256 chips)\n")
        print(dryrun_table(base["runs"], "16x16"))
        print("\n### Multi-pod mesh 2x16x16 (512 chips)\n")
        print(dryrun_table(base["runs"], "2x16x16"))
        print("\nSkips:", base.get("skips", {}))
    if args.section in ("all", "roofline"):
        print("\n### Roofline (single-pod, paper-faithful baseline)\n")
        print(roofline_table(base["runs"]))
    if args.section in ("all", "perf") and opt:
        print("\n### Baseline vs optimized (single-pod)\n")
        print(before_after(base["runs"], opt["runs"]))


if __name__ == "__main__":
    main()
