"""Micro-benchmark: the batched sweep engine vs the per-session loop.

A §IV heterogeneity sweep — 16 `CodedFL` sessions at the paper's delta
over a ladder of (nu_comp, nu_link) fleets — executed two ways:

  * per-session loop — the seed behavior this PR replaces: every Session
    owned a PRIVATE engine cache, so a 16-session sweep paid 16 separate
    traces + XLA compiles of the same scan program before running 16
    sequential host-dispatched scans.  (Reproduced here by clearing the
    now-shared engine cache between runs.)
  * `run_sweep` — ONE compiled computation for the whole sweep: the lanes
    share a single shape bucket, compile once, and execute sharded over
    the lane mesh (`launch.mesh.make_lane_mesh`; 4 host devices in CI).

Both paths do identical host-side work (one batched `plan_sweep` solve,
per-lane epoch sampling with per-lane generators), and their per-lane
traces are bit-for-bit equal — asserted here on top of the dedicated
tests — so the timing difference is purely engine architecture.

    PYTHONPATH=src python -m benchmarks.perf_sweep [--epochs 600]
    PYTHONPATH=src python -m benchmarks.perf_sweep --smoke   # CI gate

`--smoke` runs the 16-session sweep at reduced epochs, asserts the
batched path beats the per-session loop by the SPEEDUP_FLOOR (3x), and
writes BENCH_sweep.json (records + gate values) for the CI artifact
upload.
"""
from __future__ import annotations

import os

# a lane mesh needs >1 host device: default to one per physical core (CI's
# workflow env pins 4 and wins when set).  Must happen before jax
# initializes.
os.environ.setdefault(
    "XLA_FLAGS",
    f"--xla_force_host_platform_device_count={os.cpu_count() or 1}")

import argparse
import time

import jax
import numpy as np

from repro.api import Session, TrainData, make_strategy, plan_sweep, run_sweep
from repro.api import session as session_mod
from repro.sim.network import paper_fleet

from .common import D, ELL, LR, M, N_DEVICES, dump_bench, emit

SWEEP_LANES = 16
DELTA = 0.28
SPEEDUP_FLOOR = 3.0  # acceptance gate: batched >= 3x the per-session loop


def sweep_sessions(epochs: int):
    """The §IV heterogeneity frontier: one fleet per (nu, nu) level, all
    lanes sharing shapes (same n, d, parity budget) => ONE engine bucket."""
    nus = np.linspace(0.0, 0.375, SWEEP_LANES)
    return [
        Session(strategy=make_strategy("cfl", key_seed=100 + i,
                                       fixed_c=int(DELTA * M),
                                       include_upload_delay=False,
                                       label=f"cfl_nu={nu:.3f}"),
                fleet=paper_fleet(float(nu), float(nu), seed=0),
                lr=LR, epochs=epochs, seed=i)
        for i, nu in enumerate(nus)
    ]


def main(epochs: int = 600, smoke: bool = False) -> None:
    data = TrainData.linreg(jax.random.PRNGKey(0), N_DEVICES, ELL, D)
    sessions = sweep_sessions(epochs)

    t0 = time.perf_counter()
    states = plan_sweep(sessions, data)  # ONE batched solve, 16 fleets
    t_plan = time.perf_counter() - t0
    emit("perf_sweep/plan_sweep16", t_plan * 1e6 / len(sessions),
         f"sessions={len(sessions)};one_batched_solve={t_plan:.2f}s")

    # --- per-session loop (seed behavior: a fresh trace+compile per
    # Session — private engine caches) -------------------------------------
    t0 = time.perf_counter()
    loop_reports = []
    for sess, state in zip(sessions, states):
        session_mod._ENGINE_CACHE.clear()  # what per-Session caching cost
        loop_reports.append(
            sess.run(data, rng=np.random.default_rng(sess.seed),
                     state=state))
    t_loop = time.perf_counter() - t0

    # --- batched sweep engine: one compile, lanes sharded over the mesh ---
    session_mod._ENGINE_CACHE.clear()  # cold, same as the loop above
    t0 = time.perf_counter()
    sweep_reports = run_sweep(sessions, data, states=states)
    t_sweep = time.perf_counter() - t0

    # warm repeat: engine execution only (compile amortized away)
    t0 = time.perf_counter()
    run_sweep(sessions, data, states=states)
    t_sweep_warm = time.perf_counter() - t0

    # parity spot-check on top of tests/test_run_sweep.py: the two paths
    # must be the same computation, or the comparison is meaningless
    for a, b in zip(loop_reports, sweep_reports):
        np.testing.assert_array_equal(a.nmse, b.nmse)

    speedup = t_loop / t_sweep
    from repro.launch.mesh import lane_mesh_size
    n_mesh = lane_mesh_size(len(sessions))
    emit("perf_sweep/per_session_loop", t_loop * 1e6 / len(sessions),
         f"total={t_loop:.2f}s;compiles={len(sessions)}")
    emit("perf_sweep/run_sweep_cold", t_sweep * 1e6 / len(sessions),
         f"total={t_sweep:.2f}s;compiles=1;mesh_devices={n_mesh}")
    emit("perf_sweep/run_sweep_warm", t_sweep_warm * 1e6 / len(sessions),
         f"total={t_sweep_warm:.2f}s")
    emit("perf_sweep/speedup", 0.0,
         f"batched_over_loop={speedup:.1f}x;floor={SPEEDUP_FLOOR}x;"
         f"lanes={len(sessions)};epochs={epochs}")
    print(f"\n16-session §IV sweep: per-session loop {t_loop:.2f}s -> "
          f"run_sweep {t_sweep:.2f}s cold / {t_sweep_warm:.2f}s warm "
          f"({speedup:.1f}x, one compiled computation, "
          f"{n_mesh}-device lane mesh)")

    if smoke:
        # artifact FIRST: a regression is exactly when the measured values
        # must survive into the uploaded BENCH_sweep.json
        try:
            assert speedup >= SPEEDUP_FLOOR, \
                f"batched sweep only {speedup:.2f}x over the per-session " \
                f"loop (floor {SPEEDUP_FLOOR}x)"
        finally:
            dump_bench("sweep", gates={
                "lanes": len(sessions),
                "epochs": epochs,
                "mesh_devices": n_mesh,
                "plan_sweep_s": round(t_plan, 4),
                "per_session_loop_s": round(t_loop, 4),
                "run_sweep_cold_s": round(t_sweep, 4),
                "run_sweep_warm_s": round(t_sweep_warm, 4),
                "speedup": round(speedup, 2),
                "speedup_floor": SPEEDUP_FLOOR,
            })
        print("perf_sweep --smoke OK (speedup floor held)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=600)
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI mode: reduced epochs, assert the "
                         "speedup floor, write BENCH_sweep.json")
    args = ap.parse_args()
    main(epochs=150 if args.smoke and args.epochs == 600 else args.epochs,
         smoke=args.smoke)
