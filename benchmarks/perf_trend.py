"""Perf-trajectory gate: compare this run's BENCH_*.json against the
previous run's artifacts and FAIL LOUDLY on regression.

Closes the loop the artifacts were built for: every smoke stage records
its timings and gate values machine-readably (`common.dump_bench`), the
CI workflow downloads the previous successful run's artifacts into
$PERF_BASELINE_DIR, and this module diffs every metric with a tolerance
band — so a perf regression fails the build instead of drifting
silently across PRs.

Metric direction is classified from the name:

  * lower-is-better:  *_us / us_per_call, *_s, *time*, *latency*,
                      *nmse*, *bytes*, *budget*, *growth*
  * higher-is-better: *speedup*, *ratio*, *_x, *per_sec*, *throughput*
  * unknown names are reported but never gated.

Tolerances are env-tunable so flaky CPU runners widen the band without
code edits:

  * PERF_TREND_TOL       relative band for timing records (default 0.60:
                         a timing must worsen >60% to fail — shared CI
                         runners are noisy)
  * PERF_TREND_GATE_TOL  band for gate values (default 0.25 — gate
                         values are ratios/budgets, far more stable)
  * PERF_TREND_SKIP      comma-separated fnmatch globs of metric names
                         to exclude (e.g. 'kernels/flash*,*ref_jnp')

Usage:
    python -m benchmarks.perf_trend --baseline-dir perf_baseline [--new-dir .]

Pure stdlib — importable without jax (tests exercise it directly).
"""
from __future__ import annotations

import argparse
import fnmatch
import glob
import json
import os
import sys

# Patterns starting with "_" match only as a name suffix ("_s" must not
# swallow counts like n_samples); the rest match anywhere in the name.
# "growth" covers scaling-cost ratios (e.g. the fleet smoke's
# subsample_cost_growth: wall time at 10x the fleet over wall time at 1x
# — sublinear scheduling keeps it near 1, linear scheduling near 10).
LOWER_BETTER = ("_us", "us_per_call", "_s", "time", "latency", "nmse",
                "bytes", "budget", "growth")
HIGHER_BETTER = ("speedup", "ratio", "_x", "per_sec", "throughput",
                 "sessions_per", "epochs_per", "accuracy")


def _matches(low: str, pat: str) -> bool:
    return low.endswith(pat) if pat.startswith("_") else pat in low


def classify(name: str) -> str | None:
    """'lower' | 'higher' | None (ungated) from the metric name."""
    low = name.lower()
    if any(_matches(low, pat) for pat in HIGHER_BETTER):
        return "higher"
    if any(_matches(low, pat) for pat in LOWER_BETTER):
        return "lower"
    return None


def load_bench_dir(path: str, exclude: str | None = None) -> dict[str, dict]:
    """{benchmark name: payload} for every BENCH_*.json under `path`
    (recursive — artifact downloads nest files in per-run subdirs).

    Files under `exclude` are skipped: in CI the new dir is the workspace
    root and the baseline dir sits inside it, so without the exclusion
    the baseline's own files would overwrite the fresh run's entries and
    the trend gate would diff the baseline against itself."""
    excl = os.path.realpath(exclude) + os.sep if exclude else None
    out: dict[str, dict] = {}
    for f in sorted(glob.glob(os.path.join(path, "**", "BENCH_*.json"),
                              recursive=True)):
        if excl and os.path.realpath(f).startswith(excl):
            continue
        try:
            with open(f) as fh:
                payload = json.load(fh)
        except (OSError, json.JSONDecodeError):
            continue
        name = payload.get("benchmark") or \
            os.path.basename(f)[len("BENCH_"):-len(".json")]
        out[name] = payload
    return out


def _metrics(payload: dict) -> dict[str, float]:
    """Flatten one BENCH payload to {metric name: value}."""
    out: dict[str, float] = {}
    for rec in payload.get("records", []):
        name, val = rec.get("name"), rec.get("us_per_call")
        if name is not None and isinstance(val, (int, float)):
            out[f"{name}.us_per_call"] = float(val)
    for key, val in (payload.get("gates") or {}).items():
        if isinstance(val, (int, float)) and not isinstance(val, bool):
            out[f"gates.{key}"] = float(val)
    return out


def compare(baseline: dict[str, dict], new: dict[str, dict],
            tol: float, gate_tol: float,
            skip: tuple[str, ...] = ()) -> dict:
    """Diff every shared metric; returns {regressions, checked, notes}."""
    regressions: list[str] = []
    notes: list[str] = []
    checked = 0
    for bench, base_payload in sorted(baseline.items()):
        if bench not in new:
            notes.append(f"NOTE: baseline benchmark '{bench}' missing "
                         f"from the new run (renamed or removed stage?)")
            continue
        base_m = _metrics(base_payload)
        new_m = _metrics(new[bench])
        for name, old in sorted(base_m.items()):
            full = f"{bench}:{name}"
            if any(fnmatch.fnmatch(full, pat) or
                   fnmatch.fnmatch(name, pat) for pat in skip):
                continue
            if name not in new_m:
                notes.append(f"NOTE: {full} missing from the new run")
                continue
            cur = new_m[name]
            kind = classify(name)
            band = gate_tol if name.startswith("gates.") else tol
            delta = (cur - old) / abs(old) if old else 0.0
            checked += 1
            line = f"{full}: {old:.4g} -> {cur:.4g} ({delta:+.1%})"
            if kind == "lower" and old > 0 and cur > old * (1.0 + band):
                regressions.append(f"REGRESSION {line} [band +{band:.0%}]")
            elif kind == "higher" and old > 0 and cur < old * (1.0 - band):
                regressions.append(f"REGRESSION {line} [band -{band:.0%}]")
            elif kind is None:
                notes.append(f"ungated: {line}")
    return {"regressions": regressions, "checked": checked,
            "notes": notes}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m benchmarks.perf_trend")
    ap.add_argument("--baseline-dir", required=True,
                    help="previous run's BENCH_*.json artifacts")
    ap.add_argument("--new-dir", default=os.environ.get("BENCH_DIR", "."),
                    help="this run's BENCH_*.json (default $BENCH_DIR/.)")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("PERF_TREND_TOL", "0.60")))
    ap.add_argument("--gate-tol", type=float,
                    default=float(os.environ.get("PERF_TREND_GATE_TOL",
                                                 "0.25")))
    args = ap.parse_args(argv)
    skip = tuple(p.strip() for p in
                 os.environ.get("PERF_TREND_SKIP", "").split(",")
                 if p.strip())

    baseline = load_bench_dir(args.baseline_dir)
    new = load_bench_dir(args.new_dir, exclude=args.baseline_dir)
    if not baseline:
        print(f"perf-trend: no baseline artifacts under "
              f"{args.baseline_dir!r} — nothing to compare")
        return 0
    if not new:
        print(f"perf-trend: no new BENCH_*.json under {args.new_dir!r} — "
              f"run the smoke stages first")
        return 1

    result = compare(baseline, new, args.tol, args.gate_tol, skip)
    for note in result["notes"]:
        print(note)
    print(f"perf-trend: {result['checked']} metrics compared "
          f"(timing band +{args.tol:.0%}, gate band {args.gate_tol:.0%}, "
          f"{len(baseline)} baseline benchmarks)")
    if result["regressions"]:
        print(f"\nPERF TREND FAILED — {len(result['regressions'])} "
              f"regression(s) vs the previous run:", file=sys.stderr)
        for line in result["regressions"]:
            print(f"  {line}", file=sys.stderr)
        print("\n(widen the band via PERF_TREND_TOL / PERF_TREND_GATE_TOL"
              " or exclude a metric via PERF_TREND_SKIP if this is"
              " runner noise)", file=sys.stderr)
        return 1
    print("perf-trend OK: no regressions beyond the tolerance band")
    return 0


if __name__ == "__main__":
    sys.exit(main())
