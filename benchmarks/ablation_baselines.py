"""Ablation: CFL vs uncoded FL vs gradient coding (paper ref [5]) at the
§IV setting — the three-way comparison the paper motivates in §I."""
from __future__ import annotations

import jax
import numpy as np

from repro.core.gradient_coding import run_gradient_coding
from repro.sim import simulator as S
from repro.sim.network import paper_fleet
from repro.sim.simulator import convergence_time

from .common import LR, M, Timer, emit, problem

TARGET = 1e-3


def main(epochs: int = 1000, nu: float = 0.2) -> None:
    xs, ys, beta_true = problem(0)
    fleet = paper_fleet(nu, nu, seed=0)

    with Timer() as t:
        res_u = S.run_uncoded(fleet, xs, ys, beta_true, lr=LR, epochs=epochs,
                              rng=np.random.default_rng(0))
    tu = convergence_time(res_u, TARGET)
    emit("ablation/uncoded", t.us / epochs, f"t_conv={tu:.0f}s")

    with Timer() as t:
        res_c = S.run_cfl(fleet, xs, ys, beta_true, lr=LR, epochs=epochs,
                          rng=np.random.default_rng(0),
                          key=jax.random.PRNGKey(7), fixed_c=int(0.28 * M),
                          include_upload_delay=False)
    tc = convergence_time(res_c, TARGET)
    emit("ablation/cfl_delta=0.28", t.us / epochs,
         f"t_conv={tc:.0f}s;gain_vs_uncoded={tu/tc:.2f}")

    for r in (2, 3):
        with Timer() as t:
            res_g = run_gradient_coding(fleet, xs, ys, beta_true, lr=LR,
                                        epochs=epochs,
                                        rng=np.random.default_rng(0), r=r)
        tg = convergence_time(res_g, TARGET)
        emit(f"ablation/gradcode_r={r}", t.us / epochs,
             f"t_conv={tg:.0f}s;gain_vs_uncoded={tu/tg:.2f};"
             f"raw_data_shared_bits={res_g.uplink_bits_total:.2e}")


if __name__ == "__main__":
    main()
