"""Ablation: CFL vs uncoded FL vs gradient coding (paper ref [5]) at the
§IV setting — the three-way comparison the paper motivates in §I, plus the
`server_always_returns` ablation.  Every arm is one `Session` configuration
over the same data; gradient coding runs through the same engine as CFL
instead of a bespoke script loop.
"""
from __future__ import annotations

import numpy as np

from repro.api import GradientCodingFL, Session, convergence_time
from repro.sim.network import paper_fleet

from .common import LR, Timer, cfl_session, emit, problem, uncoded_session

TARGET = 1e-3


def main(epochs: int = 1000, nu: float = 0.2) -> None:
    data = problem(0)
    fleet = paper_fleet(nu, nu, seed=0)

    with Timer() as t:
        res_u = uncoded_session(fleet, epochs).run(
            data, rng=np.random.default_rng(0))
    tu = convergence_time(res_u, TARGET)
    emit("ablation/uncoded", t.us / epochs, f"t_conv={tu:.0f}s")

    with Timer() as t:
        res_c = cfl_session(fleet, epochs, delta=0.28).run(
            data, rng=np.random.default_rng(0))
    tc = convergence_time(res_c, TARGET)
    emit("ablation/cfl_delta=0.28", t.us / epochs,
         f"t_conv={tc:.0f}s;gain_vs_uncoded={tu/tc:.2f}")

    # ablation: the server's parity gradient always lands by the deadline
    with Timer() as t:
        res_s = cfl_session(fleet, epochs, delta=0.28,
                            server_always_returns=True).run(
            data, rng=np.random.default_rng(0))
    ts = convergence_time(res_s, TARGET)
    emit("ablation/cfl_server_always_returns", t.us / epochs,
         f"t_conv={ts:.0f}s;gain_vs_uncoded={tu/ts:.2f}")

    for r in (2, 3):
        with Timer() as t:
            res_g = Session(strategy=GradientCodingFL(r=r), fleet=fleet,
                            lr=LR, epochs=epochs).run(
                data, rng=np.random.default_rng(0))
        tg = convergence_time(res_g, TARGET)
        emit(f"ablation/gradcode_r={r}", t.us / epochs,
             f"t_conv={tg:.0f}s;gain_vs_uncoded={tu/tg:.2f};"
             f"raw_data_shared_bits={res_g.uplink_bits_total:.2e}")


if __name__ == "__main__":
    main()
