"""Beyond-paper experiment: CFL under non-iid client data.

The paper trains on iid N(0,1) features and lists non-iid data as future
work (§V).  CFL's unbiasedness argument (Eqs. 18-19) never uses the data
distribution — the weights w_ik depend only on DELAY statistics — so the
estimate should stay unbiased under arbitrary client skew.  We test the
claim: each client's features get a client-specific anisotropic scaling
(condition number up to `skew`), making local gradients heavily biased
toward each client's own geometry.

On the Session API the whole experiment is: same two Session configs as the
iid benchmarks, different `TrainData`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import TrainData, coding_gain
from repro.sim.network import paper_fleet

from .common import (
    D, ELL, N_DEVICES, Timer, cfl_session, emit, uncoded_session)

TARGET = 1e-3


def noniid_problem(key, skew: float) -> TrainData:
    """Client i's features ~ N(0, diag(s_i)) with log-uniform s_i spectra."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    xs = jax.random.normal(k1, (N_DEVICES, ELL, D), dtype=jnp.float32)
    # per-client anisotropic scaling (different random spectrum per client)
    scales = jnp.exp(jax.random.uniform(
        k4, (N_DEVICES, 1, D), minval=-0.5 * np.log(skew),
        maxval=0.5 * np.log(skew)))
    xs = xs * scales
    beta = jax.random.normal(k2, (D,), dtype=jnp.float32)
    ys = jnp.einsum("nld,d->nl", xs, beta) \
        + jax.random.normal(k3, (N_DEVICES, ELL), dtype=jnp.float32)
    return TrainData(xs=xs, ys=ys, beta_true=beta)


def main(epochs: int = 1200, skews=(1.0, 4.0, 16.0)) -> None:
    fleet = paper_fleet(0.2, 0.2, seed=0)
    sess_u = uncoded_session(fleet, epochs)
    sess_c = cfl_session(fleet, epochs, delta=0.28)
    for skew in skews:
        data = noniid_problem(jax.random.PRNGKey(0), skew)
        with Timer() as t:
            res_u = sess_u.run(data, rng=np.random.default_rng(0))
            res_c = sess_c.run(data, rng=np.random.default_rng(0))
        g = coding_gain(res_u, res_c, TARGET)
        emit(f"noniid/skew={skew}", t.us / (2 * epochs),
             f"final_nmse_cfl={res_c.final_nmse():.3e};"
             f"final_nmse_uncoded={res_u.final_nmse():.3e};"
             f"gain={g:.2f}")


if __name__ == "__main__":
    main()
