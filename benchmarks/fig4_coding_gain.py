"""Paper Fig. 4: coding gain (uncoded/coded convergence-time ratio to
NMSE <= 3e-4) across heterogeneity levels, at the per-level optimal delta.

One uncoded `Session` per heterogeneity level plus a delta sweep of
`CodedFL` sessions.  Every (level, delta) redundancy problem across ALL
levels is solved in ONE batched planner call (`plan_sweep` batches across
fleets), and the full 18-session grid TRAINS as one `run_sweep` call —
each fixed delta's lanes share one compiled engine across all three
heterogeneity levels.
"""
from __future__ import annotations

import numpy as np

from repro.api import coding_gain, convergence_time, plan_sweep, run_sweep
from repro.sim.network import paper_fleet

from .common import (
    TARGET_NMSE, Timer, cfl_session, emit, problem, uncoded_session)


def main(epochs: int = 1400,
         levels=((0.0, 0.0), (0.1, 0.1), (0.2, 0.2)),
         deltas=(0.07, 0.13, 0.28, 0.4, 0.5)) -> None:
    data = problem(0)
    fleets = {lv: paper_fleet(*lv, seed=0) for lv in levels}
    sessions, index = [], {}
    for lv in levels:
        index[lv] = len(sessions)
        sessions.append(uncoded_session(fleets[lv], epochs))
        sessions.extend(cfl_session(fleets[lv], epochs, d) for d in deltas)

    with Timer() as t:
        states = plan_sweep(sessions, data)  # one solve across all levels
    emit("fig4/plan_sweep", t.us / len(sessions),
         f"sessions={len(sessions)};levels={len(levels)}")

    with Timer() as t:  # the whole (level, delta) grid in one computation
        reports = run_sweep(sessions, data,
                            rngs=[np.random.default_rng(0)
                                  for _ in sessions],
                            states=states)
    emit("fig4/run_sweep", t.us / (len(sessions) * epochs),
         f"sessions={len(sessions)}")

    for nu_c, nu_l in levels:
        base = index[(nu_c, nu_l)]
        res_u = reports[base]
        best_gain, best_delta = -np.inf, None
        for k, delta in enumerate(deltas, start=1):
            g = coding_gain(res_u, reports[base + k], TARGET_NMSE)
            if np.isfinite(g) and g > best_gain:
                best_gain, best_delta = g, delta
        emit(f"fig4/gain_nu=({nu_c},{nu_l})", 0.0,
             f"best_gain={best_gain:.2f};best_delta={best_delta};"
             f"t_conv_uncoded={convergence_time(res_u, TARGET_NMSE):.0f}s")


if __name__ == "__main__":
    main()
