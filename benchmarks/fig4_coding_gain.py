"""Paper Fig. 4: coding gain (uncoded/coded convergence-time ratio to
NMSE <= 3e-4) across heterogeneity levels, at the per-level optimal delta.

One uncoded `Session` per heterogeneity level plus a delta sweep of
`CodedFL` sessions — the engine is traced once per level and reused across
the sweep (same shapes, same static structure).
"""
from __future__ import annotations

import numpy as np

from repro.api import coding_gain, convergence_time
from repro.sim.network import paper_fleet

from .common import TARGET_NMSE, Timer, cfl_session, emit, problem, \
    uncoded_session


def main(epochs: int = 1400,
         levels=((0.0, 0.0), (0.1, 0.1), (0.2, 0.2)),
         deltas=(0.07, 0.13, 0.28, 0.4, 0.5)) -> None:
    data = problem(0)
    for nu_c, nu_l in levels:
        fleet = paper_fleet(nu_c, nu_l, seed=0)
        with Timer() as t:
            res_u = uncoded_session(fleet, epochs).run(
                data, rng=np.random.default_rng(0))
            best_gain, best_delta = -np.inf, None
            for delta in deltas:
                res_c = cfl_session(fleet, epochs, delta).run(
                    data, rng=np.random.default_rng(0))
                g = coding_gain(res_u, res_c, TARGET_NMSE)
                if np.isfinite(g) and g > best_gain:
                    best_gain, best_delta = g, delta
        emit(f"fig4/gain_nu=({nu_c},{nu_l})",
             t.us / (epochs * (len(deltas) + 1)),
             f"best_gain={best_gain:.2f};best_delta={best_delta};"
             f"t_conv_uncoded={convergence_time(res_u, TARGET_NMSE):.0f}s")


if __name__ == "__main__":
    main()
