"""Privacy-utility frontier: (epsilon, delta)-DP budget vs final NMSE for
stochastic coded FL.

The `repro.privacy` subsystem end-to-end: a whole grid of epsilon targets
is calibrated to noise multipliers in ONE batched
`repro.privacy.calibrate_noise` solve, every resulting
`StochasticCodedFL` session plans through ONE batched `plan_sweep` grid
solve (the targets differ only in the epsilon-parameterized
`srv_weight`), the whole frontier TRAINS as one batched `run_sweep`
computation (noise is a value-only knob, so every lane shares one
compiled engine), and each run reports its composed epsilon spend on
`TraceReport.extras` — the frontier is read back from the reports, not
recomputed.

Gates:
  * calibration round-trips against the float64 NumPy oracle
    (`repro.privacy.reference.epsilon_spent_reference`) within 1e-3
    relative, and the reported spend never exceeds the target;
  * the frontier is monotone: a LARGER epsilon budget (less privacy,
    less noise) must not converge to a WORSE NMSE floor.

    PYTHONPATH=src python -m benchmarks.fig_privacy [--epochs 400]
    PYTHONPATH=src python -m benchmarks.fig_privacy --smoke   # CI gate

`--smoke` runs a three-point frontier on a small fleet, asserts the
calibration budget/round-trip/monotonicity gates, and writes the
`BENCH_privacy.json` artifact.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.api import Session, TrainData, make_strategy, plan_sweep, run_sweep
from repro.plan import effective_srv_weight
from repro.privacy import calibrate_noise
from repro.privacy.reference import epsilon_spent_reference
from repro.sim.network import wireless_fleet

from .common import LR, Timer, dump_bench, emit, problem

DELTA = 1e-5
SAMPLE_FRAC = 0.8
ROUNDTRIP_RTOL = 1e-3  # calibration vs the float64 oracle
# --smoke budget (seconds, warm): generous multiple of the measured warm
# batched-calibration latency (~5ms on the dev box) so CI noise does not
# flake, while a regression to per-target host solving still fails loudly.
SMOKE_CALIBRATE_BUDGET_S = 2.0


def _scfl_sessions(fleet, data, epochs: int, eps_grid, sigmas, lr: float,
                   include_baseline: bool = True):
    """One SCFL session per calibrated target (+ a noise-free baseline).

    Accounting fields ride on each strategy (rounds = epochs), so every
    report carries its own epsilon spend.
    """
    c = int(0.3 * data.m)
    sessions = [
        Session(strategy=make_strategy(
            "stochastic", key_seed=7, fixed_c=c,
            noise_multiplier=float(s), sample_frac=SAMPLE_FRAC,
            include_upload_delay=False, delta=DELTA, rounds=epochs,
            label=f"scfl_eps={e:g}"),
            fleet=fleet, lr=lr, epochs=epochs)
        for e, s in zip(eps_grid, sigmas)]
    if include_baseline:
        sessions.append(Session(strategy=make_strategy(
            "stochastic", key_seed=7, fixed_c=c, noise_multiplier=0.0,
            sample_frac=SAMPLE_FRAC, include_upload_delay=False,
            delta=DELTA, rounds=epochs, label="scfl_eps=inf"),
            fleet=fleet, lr=lr, epochs=epochs))
    return sessions


def _check_roundtrip(eps_grid, sigmas, epochs: int) -> float:
    """Max relative round-trip error vs the float64 NumPy oracle."""
    worst = 0.0
    for e, s in zip(eps_grid, sigmas):
        back = epsilon_spent_reference(float(s), SAMPLE_FRAC, epochs,
                                       DELTA)
        rel = abs(back - e) / e
        assert back <= e * (1.0 + ROUNDTRIP_RTOL), \
            f"calibrated noise OVERSPENDS the budget: {back} > {e}"
        assert rel <= ROUNDTRIP_RTOL, \
            f"calibration round-trip off by {rel:.2e} (target {e})"
        worst = max(worst, rel)
    return worst


def _check_frontier(eps_grid, finals, slack: float) -> None:
    """Larger epsilon budget (less noise) must not be worse, up to slack."""
    for (e1, f1), (e2, f2) in zip(zip(eps_grid, finals),
                                  list(zip(eps_grid, finals))[1:]):
        assert f2 <= f1 * slack, \
            f"frontier not monotone: eps {e1} -> {f1:.3e} but " \
            f"eps {e2} -> {f2:.3e}"


def _run_frontier(fleet, data, epochs: int, eps_grid, lr: float = LR):
    sigmas = np.asarray(calibrate_noise(
        np.asarray(eps_grid, dtype=np.float64), delta=DELTA,
        rounds=epochs, sample_frac=SAMPLE_FRAC))
    sessions = _scfl_sessions(fleet, data, epochs, eps_grid, sigmas, lr)
    states = plan_sweep(sessions, data)   # ONE batched allocation solve
    reps = run_sweep(sessions, data,      # ONE batched training computation
                     rngs=[np.random.default_rng(0) for _ in sessions],
                     states=states)
    for rep in reps:
        eps_spent, delta = rep.privacy_budget()
        emit(f"fig_privacy/{rep.label}", 0.0,
             f"final_nmse={rep.final_nmse():.3e};"
             f"noise={rep.extras['noise_multiplier']:.4g};"
             f"srv_weight={rep.extras['srv_weight']:.4g};"
             f"eps_spent={eps_spent:.4g};delta={delta:g}")
        assert np.all(np.isfinite(rep.nmse)), f"{rep.label}: NaN in trace"
        sched = rep.extras["epsilon_schedule"]
        assert sched.shape == (epochs,) and float(sched[-1]) == eps_spent
        # the zero-noise baseline's schedule is all inf (diff undefined)
        if np.isfinite(eps_spent):
            assert np.all(np.diff(sched) >= 0.0), \
                f"{rep.label}: epsilon schedule not monotone"
    return sigmas, reps


def smoke() -> None:
    fleet = wireless_fleet(0.2, 0.2, nu_erasure=0.3, seed=0, n=12, d=40)
    data = TrainData.linreg(jax.random.PRNGKey(0), n=12, ell=60, d=40)
    epochs = 40
    eps_grid = (1.0, 4.0, 16.0)

    # warm the jitted calibration solve, then hold it to a latency budget
    calibrate_noise(np.asarray(eps_grid), delta=DELTA, rounds=epochs,
                    sample_frac=SAMPLE_FRAC)
    t0 = time.perf_counter()
    sigmas = np.asarray(calibrate_noise(
        np.asarray(eps_grid), delta=DELTA, rounds=epochs,
        sample_frac=SAMPLE_FRAC))
    t_cal = time.perf_counter() - t0
    emit("fig_privacy/smoke_calibrate_batched", t_cal * 1e6 / len(eps_grid),
         f"targets={len(eps_grid)};budget={SMOKE_CALIBRATE_BUDGET_S}s")
    # the artifact is written even when a gate trips — a regression is
    # exactly when the measured values must survive into BENCH_privacy.json
    gates = {"calibrate_batched_s": round(t_cal, 4),
             "calibrate_budget_s": SMOKE_CALIBRATE_BUDGET_S,
             "roundtrip_rtol": ROUNDTRIP_RTOL}
    try:
        assert t_cal < SMOKE_CALIBRATE_BUDGET_S, \
            f"batched calibration {t_cal:.2f}s over budget " \
            f"{SMOKE_CALIBRATE_BUDGET_S}s"

        worst_rt = _check_roundtrip(eps_grid, sigmas, epochs)
        gates["roundtrip_max_rel"] = worst_rt
        emit("fig_privacy/smoke_roundtrip", 0.0,
             f"max_rel={worst_rt:.2e};rtol={ROUNDTRIP_RTOL}")

        _, reps = _run_frontier(fleet, data, epochs, eps_grid, lr=0.05)
        finals = [rep.final_nmse() for rep in reps]
        gates["final_nmse"] = {rep.label: rep.final_nmse() for rep in reps}
        _check_frontier(list(eps_grid) + [np.inf], finals, slack=1.10)
    finally:
        dump_bench("privacy", gates=gates)
    print("fig_privacy --smoke OK (calibration budget, round-trip, "
          "monotone frontier)")


def main(epochs: int = 400) -> None:
    data = problem(0)
    fleet = wireless_fleet(0.2, 0.2, nu_erasure=0.3, seed=0)
    eps_grid = (0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0)

    with Timer() as t:
        sigmas, reps = _run_frontier(fleet, data, epochs, eps_grid)
    emit("fig_privacy/frontier_plan+run", t.us / len(reps),
         f"sessions={len(reps)};eps_grid={eps_grid}")
    emit("fig_privacy/srv_weights", 0.0,
         ";".join(f"eps={e:g}:w={effective_srv_weight(s, SAMPLE_FRAC):.3g}"
                  for e, s in zip(eps_grid, sigmas)))
    _check_roundtrip(eps_grid, sigmas, epochs)
    finals = [rep.final_nmse() for rep in reps]
    _check_frontier(list(eps_grid) + [np.inf], finals, slack=1.02)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=400)
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI mode: three-point frontier, assert "
                         "gates, write BENCH_privacy.json")
    args = vars(ap.parse_args())
    if args.pop("smoke"):
        smoke()
    else:
        main(**args)
