"""Micro-benchmark: the always-on serving engine vs a per-session loop.

A mixed production workload — 16 sessions over three shape buckets
(CFL at two parity budgets + uncoded) arriving on a Poisson trace —
executed two ways:

  * per-session loop — the seed behavior: each arriving session is a
    fresh solo `Session.run` (private engine caches reproduced by
    clearing the shared cache between runs), full fixed epoch count,
    strictly sequential.
  * `FedServeEngine` — continuous session batching: arrivals admit into
    warm shape-bucketed lane slots, every bucket advances as ONE
    compiled chunked `lax.while_loop`, and the convergence predicate
    exits each lane the epoch it converges, freeing the slot for the
    next arrival.

Every completed session's served trace is asserted bit-for-bit
PREFIX-equal to its solo run up to the reported exit epoch, so the
throughput difference is purely engine architecture + early exit.

    PYTHONPATH=src python -m benchmarks.perf_serve [--epochs 400]
    PYTHONPATH=src python -m benchmarks.perf_serve --smoke   # CI gate

`--smoke` reduces epochs, asserts the serve path clears the
SPEEDUP_FLOOR (2x sessions/sec), and writes BENCH_serve.json for the CI
artifact upload.
"""
from __future__ import annotations

import os

# a lane mesh needs >1 host device: default to one per physical core (CI's
# workflow env pins 4 and wins when set).  Must happen before jax
# initializes.
os.environ.setdefault(
    "XLA_FLAGS",
    f"--xla_force_host_platform_device_count={os.cpu_count() or 1}")

import argparse
import time

import jax
import numpy as np

from repro.api import Session, TrainData, make_strategy
from repro.api import session as session_mod
from repro.serving import (ConvergenceCriterion, FedServeEngine,
                           poisson_arrivals)
from repro.sim.network import paper_fleet

from .common import D, ELL, LR, M, N_DEVICES, dump_bench, emit

N_SESSIONS = 16
ARRIVAL_RATE = 0.05      # sessions per epoch-unit of virtual time
NMSE_TARGET = 0.35       # serve-time convergence criterion (hit ~epoch 65
                         # at the paper lr; the fixed-budget baseline pays
                         # the full epoch count for the same answer)
SPEEDUP_FLOOR = 2.0      # acceptance gate: serve >= 2x sessions/sec


def serve_sessions(epochs: int):
    """16 mixed-shape sessions over THREE engine buckets: 8 CFL at the
    paper's delta, 4 CFL at a fatter parity budget, 4 uncoded."""
    fleet = paper_fleet(0.2, 0.2, seed=0)
    c1, c2 = int(0.28 * M), int(0.5 * M)
    sessions = []
    for i in range(N_SESSIONS):
        if i % 4 in (0, 1):
            strat = make_strategy("cfl", key_seed=100 + i, fixed_c=c1,
                                  include_upload_delay=False,
                                  label=f"cfl_d28_{i}")
        elif i % 4 == 2:
            strat = make_strategy("cfl", key_seed=100 + i, fixed_c=c2,
                                  include_upload_delay=False,
                                  label=f"cfl_d50_{i}")
        else:
            strat = make_strategy("uncoded")
        sessions.append(Session(strategy=strat, fleet=fleet, lr=LR,
                                epochs=epochs, seed=i))
    return sessions


def main(epochs: int = 400, smoke: bool = False) -> None:
    from repro.api import plan_sweep

    data = TrainData.linreg(jax.random.PRNGKey(0), N_DEVICES, ELL, D)
    sessions = serve_sessions(epochs)
    arrivals = poisson_arrivals(N_SESSIONS, ARRIVAL_RATE,
                                np.random.default_rng(0))
    chunk = max(epochs // 4, 1)

    # planning is identical host work on both paths (one batched solve);
    # hoist it so the timed sections compare engine architecture only
    t0 = time.perf_counter()
    states = plan_sweep(sessions, data)
    t_plan = time.perf_counter() - t0
    emit("perf_serve/plan_sweep16", t_plan * 1e6 / N_SESSIONS,
         f"sessions={N_SESSIONS};one_batched_solve={t_plan:.2f}s")

    # --- per-session loop (seed behavior: each arrival is a fresh solo
    # run — private engine caches, full fixed epoch count) -----------------
    t0 = time.perf_counter()
    solo_reports = []
    for sess, state in zip(sessions, states):
        session_mod._ENGINE_CACHE.clear()  # what per-Session caching cost
        solo_reports.append(sess.run(data,
                                     rng=np.random.default_rng(sess.seed),
                                     state=state))
    t_loop = time.perf_counter() - t0

    # --- always-on serving engine -----------------------------------------
    session_mod._ENGINE_CACHE.clear()  # cold, same as the loop above
    crit = ConvergenceCriterion(nmse_target=NMSE_TARGET)
    engine = FedServeEngine(data, lane_width=4, chunk=chunk,
                            criterion=crit)
    t0 = time.perf_counter()
    serve_reports = engine.serve(sessions, arrivals=list(arrivals),
                                 states=states)
    t_serve_cold = time.perf_counter() - t0

    # steady state: an always-on engine compiles its bucket programs once
    # at warm-up and then serves traffic indefinitely — the gated
    # throughput is this regime (programs warm in the process-wide cache,
    # all per-request admission work still paid)
    engine = FedServeEngine(data, lane_width=4, chunk=chunk,
                            criterion=crit)
    t0 = time.perf_counter()
    engine.serve(sessions, arrivals=list(arrivals), states=states)
    t_serve = time.perf_counter() - t0

    # parity: every served trace is the solo trace truncated at the
    # reported exit epoch — or the throughput comparison is meaningless
    exits = []
    for solo, rep in zip(solo_reports, serve_reports):
        t_exit = rep.extras["serve_exit_epoch"]
        exits.append(t_exit)
        np.testing.assert_array_equal(rep.nmse, solo.nmse[:t_exit + 1])
        np.testing.assert_array_equal(rep.epoch_durations,
                                      solo.epoch_durations[:t_exit])

    speedup = t_loop / t_serve
    loop_rate = N_SESSIONS / t_loop
    serve_rate = N_SESSIONS / t_serve
    emit("perf_serve/per_session_loop", t_loop * 1e6 / N_SESSIONS,
         f"total={t_loop:.2f}s;sessions_per_s={loop_rate:.2f}")
    emit("perf_serve/fed_serve_cold", t_serve_cold * 1e6 / N_SESSIONS,
         f"total={t_serve_cold:.2f}s;"
         f"sessions_per_s={N_SESSIONS / t_serve_cold:.2f};"
         f"buckets={engine.n_groups};steps={engine.steps}")
    emit("perf_serve/fed_serve_steady", t_serve * 1e6 / N_SESSIONS,
         f"total={t_serve:.2f}s;sessions_per_s={serve_rate:.2f}")
    emit("perf_serve/speedup", 0.0,
         f"serve_over_loop={speedup:.1f}x;floor={SPEEDUP_FLOOR}x;"
         f"sessions={N_SESSIONS};epochs={epochs};"
         f"mean_exit_epoch={np.mean(exits):.0f}")
    print(f"\n{N_SESSIONS}-session Poisson workload: per-session loop "
          f"{t_loop:.2f}s ({loop_rate:.2f} sess/s) -> serve engine "
          f"{t_serve_cold:.2f}s cold / {t_serve:.2f}s steady-state "
          f"({speedup:.1f}x, {engine.n_groups} buckets, mean exit epoch "
          f"{np.mean(exits):.0f}/{epochs})")

    if smoke:
        # artifact FIRST: a regression is exactly when the measured values
        # must survive into the uploaded BENCH_serve.json
        try:
            assert speedup >= SPEEDUP_FLOOR, \
                f"serve engine only {speedup:.2f}x over the per-session " \
                f"loop (floor {SPEEDUP_FLOOR}x)"
        finally:
            dump_bench("serve", gates={
                "sessions": N_SESSIONS,
                "epochs": epochs,
                "buckets": engine.n_groups,
                "nmse_target": NMSE_TARGET,
                "mean_exit_epoch": round(float(np.mean(exits)), 1),
                "plan_sweep_s": round(t_plan, 4),
                "per_session_loop_s": round(t_loop, 4),
                "fed_serve_cold_s": round(t_serve_cold, 4),
                "fed_serve_steady_s": round(t_serve, 4),
                "sessions_per_s_loop": round(loop_rate, 3),
                "sessions_per_s_serve": round(serve_rate, 3),
                "speedup": round(speedup, 2),
                "speedup_floor": SPEEDUP_FLOOR,
            })
        print("perf_serve --smoke OK (speedup floor held)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=400)
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI mode: reduced epochs, assert the "
                         "speedup floor, write BENCH_serve.json")
    args = ap.parse_args()
    main(epochs=240 if args.smoke and args.epochs == 400 else args.epochs,
         smoke=args.smoke)
