"""Fleet-scale smoke benchmark: the `repro.fleet` layer end to end.

Three sections, each gated in `--smoke` mode:

  * plan-scale — `solve_fleet` plans an n = 100k-client `mega_fleet`
    redundancy problem (sharded over the forced host-device mesh,
    chunk-streamed within each shard) under a hard wall-time budget, and
    the resulting loads are validated against the per-device caps.
  * tiered encode — `encode_fleet_tiered` streams a tier-partitioned
    composite parity through the in-kernel-PRNG path at the fleet-scale
    per-client shapes (tiny ell/d), asserts the tuned-tile cache HITS on
    that bucket (the committed `tune/defaults.json` must cover it — no
    cold miss on CI), and checks the T-tier result against the flat
    single-pass encode.
  * subsample sublinearity — `sample_tier_rounds` under a fixed
    `with_round_budget` participant budget is timed at n = 10k and
    n = 100k; O(participants) scheduling keeps the wall-time ratio near
    1 while linear scheduling would pay ~10x.  The ratio is gated as
    `subsample_cost_growth` (lower is better; see perf_trend).

    PYTHONPATH=src python -m benchmarks.perf_fleet [--n 100000]
    PYTHONPATH=src python -m benchmarks.perf_fleet --smoke   # CI gate

`--smoke` asserts the gates and writes BENCH_plan_scale.json for the CI
artifact upload (consumed by the perf-trend stage across PRs).
"""
from __future__ import annotations

import os

# the sharded fleet solve wants >1 host device: default to one per
# physical core (CI's workflow env wins when set).  Must happen before
# jax initializes.
os.environ.setdefault(
    "XLA_FLAGS",
    f"--xla_force_host_platform_device_count={os.cpu_count() or 1}")

import argparse
import time

import jax
import numpy as np

from repro.fleet import (FleetTopology, encode_fleet_tiered,
                         sample_tier_rounds, solve_fleet)
from repro.kernels.encode import ops as encode_ops
from repro.plan.solver import PlanRequest
from repro.sim.network import mega_fleet
from repro.tune.cache import lookup_block

from .common import dump_bench, emit

FLEET_N = 100_000        # planned fleet size (the acceptance scale)
FLEET_D = 32             # per-point feature dim at fleet scale
POINTS_LO, POINTS_HI = 4, 16   # per-client shard sizes (caps)
C_UP = 4096              # server parity-row cap (bounds the L_srv axis)
PLAN_EPS_REL = 1e-2      # smoke-grade deadline tolerance
PLAN_WALL_BUDGET_S = 120.0     # hard CPU-CI budget for the 100k solve

ENC_CLIENTS = 256        # tiered-encode section: clients per pass
ENC_TIERS = 4
ENC_ELL = 8              # -> encode_prng bucket (128, 8, 32), covered
ENC_C = 128              # by the committed tune/defaults.json

SAMPLE_BUDGET = 512      # expected participants per round (both scales)
SAMPLE_TIERS = 16
SAMPLE_EPOCHS = 48
GROWTH_CEIL = 3.0        # wall ratio at 10x fleet; linear would be ~10


def bench_plan(n: int) -> tuple[float, dict]:
    """Time one sharded fleet solve; returns (wall_s, gate values)."""
    fleet = mega_fleet(n, d=FLEET_D, seed=0)
    rng = np.random.default_rng(1)
    data_sizes = rng.integers(POINTS_LO, POINTS_HI + 1, size=n)
    req = PlanRequest(edge=fleet.edge, server=fleet.server,
                      data_sizes=data_sizes, c_up=C_UP)

    t0 = time.perf_counter()
    plan = solve_fleet(req, eps_rel=PLAN_EPS_REL)
    wall = time.perf_counter() - t0

    assert plan.loads.shape == (n,)
    assert np.all(plan.loads <= data_sizes), "plan exceeds device caps"
    assert plan.expected_agg >= req.m * (1.0 - 1e-6), \
        f"plan misses the return target: {plan.expected_agg} < {req.m}"
    emit("perf_fleet/solve_fleet", wall * 1e6,
         f"n={n};devices={len(jax.devices())};t_star={plan.t_star:.3f};"
         f"c={plan.c};eps_rel={PLAN_EPS_REL}")
    return wall, {"fleet_n": n, "plan_wall_s": round(wall, 2),
                  "plan_wall_budget_s": PLAN_WALL_BUDGET_S,
                  "plan_c": plan.c, "plan_t_star": round(plan.t_star, 4)}


def bench_encode() -> tuple[float, bool]:
    """Time the tiered streamed encode at fleet-scale per-client shapes;
    returns (us_per_pass, tile_cache_hit)."""
    key = jax.random.PRNGKey(3)
    kx, ky, kw, kf = jax.random.split(key, 4)
    xs = jax.random.normal(kx, (ENC_CLIENTS, ENC_ELL, FLEET_D))
    ys = jax.random.normal(ky, (ENC_CLIENTS, ENC_ELL))
    weights = jax.random.uniform(kw, (ENC_CLIENTS, ENC_ELL),
                                 minval=0.5, maxval=1.5)
    topo = FleetTopology.uniform(ENC_CLIENTS, ENC_TIERS)

    cache_hit = lookup_block(
        "encode_prng", (ENC_C, ENC_ELL, FLEET_D)) is not None

    x_t, y_t = encode_fleet_tiered(kf, xs, ys, weights, ENC_C, topo)
    x_flat, y_flat = encode_ops.encode_fleet_prng(kf, xs, ys, weights,
                                                  ENC_C)
    np.testing.assert_allclose(np.asarray(x_t), np.asarray(x_flat),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_flat),
                               rtol=1e-4, atol=1e-4)

    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        x_t, y_t = encode_fleet_tiered(kf, xs, ys, weights, ENC_C, topo)
    jax.block_until_ready((x_t, y_t))
    us = (time.perf_counter() - t0) * 1e6 / reps
    emit("perf_fleet/encode_tiered", us,
         f"clients={ENC_CLIENTS};tiers={ENC_TIERS};c={ENC_C};"
         f"ell={ENC_ELL};d={FLEET_D};tile_cache_hit={cache_hit}")
    return us, cache_hit


def bench_subsample(n_small: int, n_large: int) -> tuple[float, dict]:
    """Wall-time growth of budgeted round scheduling at 10x the fleet."""
    def run(n: int) -> float:
        fleet = mega_fleet(n, d=FLEET_D, seed=0)
        rng = np.random.default_rng(2)
        loads = rng.integers(POINTS_LO, POINTS_HI + 1, size=n)
        topo = FleetTopology.uniform(
            n, SAMPLE_TIERS).with_round_budget(SAMPLE_BUDGET)
        best = np.inf
        for _ in range(2):  # best-of-2: the gate is a ratio of small walls
            t0 = time.perf_counter()
            stats = sample_tier_rounds(topo, fleet.edge, loads,
                                       SAMPLE_EPOCHS, rng)
            best = min(best, time.perf_counter() - t0)
        expect = SAMPLE_BUDGET * SAMPLE_EPOCHS
        assert stats.total_participants < 4 * expect, \
            f"budget not honored: {stats.total_participants} participants"
        return best

    t_small = run(n_small)
    t_large = run(n_large)
    growth = t_large / max(t_small, 1e-9)
    emit("perf_fleet/subsample_small", t_small * 1e6,
         f"n={n_small};budget={SAMPLE_BUDGET};epochs={SAMPLE_EPOCHS}")
    emit("perf_fleet/subsample_large", t_large * 1e6,
         f"n={n_large};budget={SAMPLE_BUDGET};epochs={SAMPLE_EPOCHS}")
    emit("perf_fleet/subsample_growth", 0.0,
         f"wall_ratio_at_10x_fleet={growth:.2f};ceil={GROWTH_CEIL}")
    return growth, {"subsample_budget": SAMPLE_BUDGET,
                    "subsample_small_s": round(t_small, 4),
                    "subsample_large_s": round(t_large, 4),
                    "subsample_cost_growth": round(growth, 3),
                    "subsample_growth_ceil": GROWTH_CEIL}


def main(n: int = FLEET_N, smoke: bool = False) -> None:
    plan_wall, plan_gates = bench_plan(n)
    enc_us, cache_hit = bench_encode()
    growth, sub_gates = bench_subsample(max(n // 10, 1000), n)

    print(f"\nfleet smoke: {n}-client plan {plan_wall:.1f}s "
          f"({len(jax.devices())} shards), tiered encode "
          f"{enc_us / 1e3:.1f}ms/pass (cache hit: {cache_hit}), "
          f"budgeted-round growth at 10x fleet {growth:.2f}x")

    if smoke:
        # artifact FIRST: a regression is exactly when the measured
        # values must survive into the uploaded BENCH_plan_scale.json
        try:
            assert plan_wall <= PLAN_WALL_BUDGET_S, \
                f"fleet solve took {plan_wall:.1f}s " \
                f"(budget {PLAN_WALL_BUDGET_S}s)"
            assert cache_hit, \
                "encode_prng tile cache MISSED the fleet bucket " \
                f"({ENC_C}, {ENC_ELL}, {FLEET_D}) — regenerate " \
                "tune/defaults.json (python -m repro.tune --ci-defaults)"
            assert growth <= GROWTH_CEIL, \
                f"budgeted round scheduling grew {growth:.2f}x at 10x " \
                f"the fleet (ceiling {GROWTH_CEIL}x — should be ~flat)"
        finally:
            dump_bench("plan_scale", gates={
                **plan_gates,
                "encode_tiered_us": round(enc_us, 1),
                **sub_gates,
            })
        print("perf_fleet --smoke OK (wall budget, tile cache, "
              "sublinearity held)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=FLEET_N,
                    help="planned fleet size")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: assert the gates, write "
                         "BENCH_plan_scale.json")
    args = ap.parse_args()
    main(n=args.n, smoke=args.smoke)
