"""Four-way coding-scheme comparison: uncoded / CFL / stochastic CFL /
low-latency wireless CFL on a heterogeneous wireless fleet.

The first benchmark exercising the `repro.schemes` subsystem end-to-end:
every configuration is a `Session` built by `make_strategy`, EVERY
allocation solve in a sweep — base CFL, weighted-server stochastic,
partial-return low-latency — batches through one `plan_sweep` call into
`repro.plan.solve_redundancy_batched`, and every sweep TRAINS as one
batched `run_sweep` computation (per-lane traces bit-identical to solo
runs).

Sections (full mode):
  * four-way head-to-head at one redundancy point;
  * redundancy sweep for the three coded schemes with a
    monotone-in-redundancy convergence gate (more parity budget must not
    slow wall-clock convergence);
  * the stochastic scheme's noise/accuracy knob (final NMSE vs sigma);
  * the low-latency scheme across link-heterogeneity levels.

    PYTHONPATH=src python -m benchmarks.fig_schemes [--epochs 600]
    PYTHONPATH=src python -m benchmarks.fig_schemes --smoke   # CI gate

`--smoke` runs a single small configuration per scheme and asserts (a) the
warm batched planning latency stays under budget and (b) both new schemes
produce finite, descending NMSE traces — so a broken objective evaluator
or scheme regression fails CI in seconds.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.api import (Session, TrainData, convergence_time, make_strategy,
                       plan_sweep, run_sweep)
from repro.sim.network import wireless_fleet

from .common import (Timer, cfl_session, dump_bench, emit, lowlat_session,
                     problem, scfl_session, uncoded_session)

# --smoke budgets (seconds, warm): generous multiples of the measured warm
# latencies so CI noise does not flake, while a regression to per-request
# host solving still fails loudly.
SMOKE_PLAN_BUDGET_S = 5.0


def _run_all(sessions, data, seed=0):
    """One batched plan + one batched training computation."""
    return run_sweep(sessions, data,
                     rngs=[np.random.default_rng(seed) for _ in sessions])


# ---------------------------------------------------------------------------
# smoke mode (CI)
# ---------------------------------------------------------------------------

def smoke() -> None:
    fleet = wireless_fleet(0.2, 0.2, nu_erasure=0.3, seed=0, n=12, d=40)
    data = TrainData.linreg(jax.random.PRNGKey(0), n=12, ell=60, d=40)
    c = int(0.3 * data.m)

    def sessions():
        return [
            Session(strategy=make_strategy("uncoded"),
                    fleet=fleet, lr=0.05, epochs=40),
            Session(strategy=make_strategy("cfl", key_seed=7, fixed_c=c),
                    fleet=fleet, lr=0.05, epochs=40),
            Session(strategy=make_strategy("stochastic", key_seed=7,
                                           fixed_c=c, noise_multiplier=0.5,
                                           sample_frac=0.8),
                    fleet=fleet, lr=0.05, epochs=40),
            Session(strategy=make_strategy("lowlatency", key_seed=7,
                                           fixed_c=c, chunks=8),
                    fleet=fleet, lr=0.05, epochs=40),
        ]

    plan_sweep(sessions(), data)  # warm up the jitted solvers + encoders
    t0 = time.perf_counter()
    sess = sessions()
    states = plan_sweep(sess, data)
    t_plan = time.perf_counter() - t0
    emit("fig_schemes/smoke_plan_sweep", t_plan * 1e6 / len(sess),
         f"sessions={len(sess)};budget={SMOKE_PLAN_BUDGET_S}s")
    # the artifact is written even when a gate trips — a regression is
    # exactly when the measured values must survive into BENCH_schemes.json
    gates = {"plan_sweep_s": round(t_plan, 4),
             "plan_sweep_budget_s": SMOKE_PLAN_BUDGET_S,
             "final_nmse": {}}
    try:
        assert t_plan < SMOKE_PLAN_BUDGET_S, \
            f"batched scheme planning {t_plan:.2f}s over budget " \
            f"{SMOKE_PLAN_BUDGET_S}s"
        reps = run_sweep(sess, data,
                         rngs=[np.random.default_rng(0) for _ in sess],
                         states=states)
        for rep in reps:
            emit(f"fig_schemes/smoke_{rep.label}", 0.0,
                 f"final_nmse={rep.final_nmse():.3e};"
                 f"t_star={rep.epoch_durations[0]:.3f}s")
            gates["final_nmse"][rep.label] = rep.final_nmse()
            assert np.all(np.isfinite(rep.nmse)), \
                f"{rep.label}: NaN in trace"
            if rep.label in ("scfl", "lowlat"):
                assert rep.final_nmse() < rep.nmse[0], \
                    f"{rep.label}: trace does not descend"
    finally:
        dump_bench("schemes", gates=gates)
    print("fig_schemes --smoke OK (plan budget held, NMSE finite)")


# ---------------------------------------------------------------------------
# full mode
# ---------------------------------------------------------------------------

def main(epochs: int = 600, delta: float = 0.28,
         noise: float = 0.5, chunks: int = 8) -> None:
    data = problem(0)
    fleet = wireless_fleet(0.2, 0.2, nu_erasure=0.3, seed=0)
    target = 1e-3

    # --- four-way head-to-head --------------------------------------------
    sessions = [
        uncoded_session(fleet, epochs),
        cfl_session(fleet, epochs, delta),
        scfl_session(fleet, epochs, delta, noise_multiplier=noise,
                     sample_frac=0.8),
        lowlat_session(fleet, epochs, delta, chunks=chunks),
    ]
    with Timer() as t:
        reps = _run_all(sessions, data)
    for rep in reps:
        emit(f"fig_schemes/{rep.label}", t.us / len(reps) / epochs,
             f"final_nmse={rep.final_nmse():.3e};"
             f"t_star={rep.epoch_durations[0]:.2f}s;"
             f"t_conv_{target}={convergence_time(rep, target):.0f}s;"
             f"extras={rep.extras}")

    # --- redundancy sweep: convergence must be monotone in delta ----------
    deltas = (0.07, 0.13, 0.28)
    makers = {"cfl": cfl_session,
              "scfl": lambda f, e, d: scfl_session(
                  f, e, d, noise_multiplier=noise, sample_frac=0.8),
              "lowlat": lambda f, e, d: lowlat_session(
                  f, e, d, chunks=chunks)}
    # the stochastic scheme converges to a privacy-noise NMSE floor, so its
    # monotonicity gate uses a target above that floor
    targets = {"cfl": target, "scfl": 2e-2, "lowlat": target}
    sweep = [mk(fleet, epochs, d) for mk in makers.values()
             for d in deltas]
    with Timer() as t:
        reps = _run_all(sweep, data)  # 9 allocation solves, batched
    emit("fig_schemes/sweep_plan+run", t.us / len(sweep),
         f"sessions={len(sweep)};deltas={deltas}")
    for name, chunk in zip(makers, np.split(np.arange(len(sweep)), 3)):
        times = [convergence_time(reps[i], targets[name]) for i in chunk]
        finite = np.all(np.isfinite(times))
        mono = all(t2 <= t1 * 1.02 for t1, t2 in zip(times, times[1:]))
        emit(f"fig_schemes/monotone_{name}", 0.0,
             f"target={targets[name]};t_conv={['%.0f' % x for x in times]};"
             f"monotone={mono}")
        assert finite, f"{name}: non-finite convergence time in sweep"
        assert mono, \
            f"{name}: convergence time not monotone in redundancy: {times}"

    # --- stochastic noise/accuracy knob -----------------------------------
    sigmas = (0.0, 0.5, 1.0)
    sweep = [scfl_session(fleet, epochs, delta, noise_multiplier=s,
                          label=f"scfl_sigma={s}") for s in sigmas]
    reps = _run_all(sweep, data)
    finals = [rep.final_nmse() for rep in reps]
    emit("fig_schemes/noise_knob", 0.0,
         ";".join(f"sigma={s}:final={f:.3e}" for s, f in zip(sigmas, finals)))
    assert all(np.isfinite(finals))
    assert finals[-1] > finals[0], \
        "privacy noise should cost accuracy (NMSE floor)"

    # --- low-latency scheme vs link heterogeneity -------------------------
    fleets = {nu: wireless_fleet(0.2, 0.2, nu_erasure=nu, seed=0)
              for nu in (0.0, 0.45)}
    sweep = [lowlat_session(f, epochs, delta, chunks=chunks,
                            label=f"lowlat_nu={nu}")
             for nu, f in fleets.items()]
    reps = _run_all(sweep, data)
    for rep in reps:
        emit(f"fig_schemes/{rep.label}", 0.0,
             f"final_nmse={rep.final_nmse():.3e};"
             f"t_star={rep.epoch_durations[0]:.2f}s;"
             f"t_conv_{target}={convergence_time(rep, target):.0f}s")
        assert np.all(np.isfinite(rep.nmse))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=600)
    ap.add_argument("--delta", type=float, default=0.28)
    ap.add_argument("--noise", type=float, default=0.5)
    ap.add_argument("--chunks", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI mode: single point, assert budgets")
    args = vars(ap.parse_args())
    if args.pop("smoke"):
        smoke()
    else:
        main(**args)
