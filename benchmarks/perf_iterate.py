"""§Perf hillclimb harness: lower one (arch x shape) variant on the
single-pod mesh and print the three roofline terms.

    PYTHONPATH=src python -m benchmarks.perf_iterate \
        --arch granite-8b --shape train_4k \
        --set attn_impl=repeat --set moe.capacity_factor=1.25 \
        [--fsdp] [--tag label]

Each --set does a dataclasses.replace on the ArchConfig (dotted fields hit
the nested specs).  Output: one CSV row per run, appended to
perf_iterations.csv for the EXPERIMENTS.md §Perf log.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import sys


def apply_sets(cfg, sets):
    for kv in sets:
        path, val = kv.split("=", 1)
        try:
            val = json.loads(val)
        except json.JSONDecodeError:
            pass
        parts = path.split(".")
        if len(parts) == 1:
            cfg = dataclasses.replace(cfg, **{parts[0]: val})
        else:
            sub = getattr(cfg, parts[0])
            sub = dataclasses.replace(sub, **{parts[1]: val})
            cfg = dataclasses.replace(cfg, **{parts[0]: sub})
    return cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--fsdp", action="store_true",
                    help="force FSDP param sharding for this arch")
    ap.add_argument("--remat", default="full", choices=["full", "save_ar"])
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--csv", default="perf_iterations.csv")
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.launch import dryrun
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    from repro.launch.mesh import make_production_mesh
    from repro.launch import sharding as SH

    cfg = get_config(args.arch)
    cfg = dryrun._maybe_sliding_window(cfg, args.shape)
    cfg = apply_sets(cfg, args.set)
    if args.fsdp:
        SH.FSDP_ARCHS.add(SH.base_arch_name(cfg.name))

    mesh = make_production_mesh(multi_pod=False)
    stats = dryrun.lower_one(cfg, args.shape, mesh, remat=args.remat,
                             zero1=args.zero1)
    coll = sum(v for k, v in stats["corrected_collectives"].items()
               if not k.startswith("n_"))
    t_c = stats["corrected_flops"] / PEAK_FLOPS_BF16
    t_m = stats["corrected_bytes"] / HBM_BW
    t_x = coll / ICI_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    gb = (stats["memory"]["argument_size"] or 0) / 1e9
    row = (f"{args.arch},{args.shape},{args.tag or ';'.join(args.set) or 'baseline'},"
           f"{t_c:.4e},{t_m:.4e},{t_x:.4e},{dom},{gb:.2f}")
    print("arch,shape,variant,t_compute,t_memory,t_collective,dominant,args_gb")
    print(row)
    with open(args.csv, "a") as f:
        f.write(row + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
