"""Paper Fig. 5: coding gain vs communication load across a delta sweep at
heterogeneity (0.4, 0.4) — more parity converges faster but ships more bits."""
from __future__ import annotations

import jax
import numpy as np

from repro.sim import simulator as S
from repro.sim.network import paper_fleet
from repro.sim.simulator import coding_gain, convergence_time

from .common import LR, M, Timer, emit, problem

TARGET = 1.8e-4  # the paper's Fig.-5 target NMSE


def main(epochs: int = 1600, deltas=(0.07, 0.13, 0.16, 0.28, 0.4),
         nu: float = 0.4) -> None:
    xs, ys, beta_true = problem(0)
    fleet = paper_fleet(nu, nu, seed=0)
    with Timer() as t:
        res_u = S.run_uncoded(fleet, xs, ys, beta_true, lr=LR, epochs=epochs,
                              rng=np.random.default_rng(0))
    t_u = convergence_time(res_u, TARGET)
    # communication up to the convergence point only
    epochs_to_conv = int(np.searchsorted(res_u.times, t_u))
    bits_u = epochs_to_conv * 24 * 2 * fleet.packet_bits
    emit("fig5/uncoded", t.us / epochs, f"t_conv={t_u:.0f}s;bits={bits_u:.3e}")

    for delta in deltas:
        with Timer() as t:
            res_c = S.run_cfl(fleet, xs, ys, beta_true, lr=LR, epochs=epochs,
                              rng=np.random.default_rng(0),
                              key=jax.random.PRNGKey(7),
                              fixed_c=int(delta * M),
                              include_upload_delay=False)
        g = coding_gain(res_u, res_c, TARGET)
        t_c = convergence_time(res_c, TARGET)
        ep_c = int(np.searchsorted(res_c.times, t_c))
        # every device ships c rows of (d+1) floats (+10% header), once
        parity_bits = 24 * int(delta * M) * (500 + 1) * 32 * 1.1
        bits_c = parity_bits + ep_c * 24 * 2 * fleet.packet_bits
        emit(f"fig5/cfl_delta={delta}", t.us / epochs,
             f"gain={g:.2f};t_conv={t_c:.0f}s;"
             f"comm_load_ratio={bits_c / bits_u:.2f}")


if __name__ == "__main__":
    main()
