"""Paper Fig. 5: coding gain vs communication load across a delta sweep at
heterogeneity (0.4, 0.4) — more parity converges faster but ships more bits.

Migrated to the Session API: the uplink accounting comes straight from each
strategy's `uplink_bits` (via `TraceReport.uplink_bits_total`) prorated to
the convergence epoch.  The delta sweep's redundancy planning happens in
ONE batched solver call (`plan_sweep`) and the training in one
`run_sweep` computation.
"""
from __future__ import annotations

import numpy as np

from repro.api import coding_gain, convergence_time, plan_sweep, run_sweep
from repro.sim.network import paper_fleet

from .common import (
    N_DEVICES, Timer, cfl_session, emit, problem, uncoded_session)

TARGET = 1.8e-4  # the paper's Fig.-5 target NMSE


def main(epochs: int = 1600, deltas=(0.07, 0.13, 0.16, 0.28, 0.4),
         nu: float = 0.4) -> None:
    data = problem(0)
    fleet = paper_fleet(nu, nu, seed=0)
    per_epoch_bits = N_DEVICES * 2 * fleet.packet_bits  # model down + grad up

    sessions = [uncoded_session(fleet, epochs)] + \
        [cfl_session(fleet, epochs, d) for d in deltas]
    with Timer() as t:
        states = plan_sweep(sessions, data)  # one batched redundancy solve
    emit("fig5/plan_sweep", t.us / len(sessions),
         f"sessions={len(sessions)}")

    with Timer() as t:  # one batched training computation for every curve
        reports = run_sweep(sessions, data,
                            rngs=[np.random.default_rng(0)
                                  for _ in sessions],
                            states=states)
    emit("fig5/run_sweep", t.us / (len(sessions) * epochs),
         f"sessions={len(sessions)}")

    res_u = reports[0]
    t_u = convergence_time(res_u, TARGET)
    # communication up to the convergence point only
    epochs_to_conv = int(np.searchsorted(res_u.times, t_u))
    bits_u = epochs_to_conv * per_epoch_bits
    emit("fig5/uncoded", 0.0, f"t_conv={t_u:.0f}s;bits={bits_u:.3e}")

    for delta, res_c in zip(deltas, reports[1:]):
        g = coding_gain(res_u, res_c, TARGET)
        t_c = convergence_time(res_c, TARGET)
        ep_c = int(np.searchsorted(res_c.times, t_c))
        # one-time parity shipment from the strategy's own accounting,
        # plus the per-epoch traffic up to the convergence point
        parity_bits = res_c.uplink_bits_total - res_c.epochs * per_epoch_bits
        bits_c = parity_bits + ep_c * per_epoch_bits
        emit(f"fig5/cfl_delta={delta}", 0.0,
             f"gain={g:.2f};t_conv={t_c:.0f}s;"
             f"comm_load_ratio={bits_c / bits_u:.2f}")


if __name__ == "__main__":
    main()
