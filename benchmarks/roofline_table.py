"""Roofline tables (EXPERIMENTS.md §Roofline).

Three sections:

  * coded-kernel attainment — measured wall time vs the roofline lower
    bound for the coded Pallas kernels (`kernels/encode`,
    `kernels/coded_grad`, `kernels/round_grad`) at default and tuned
    (`repro.tune` cache) tiles.  Always printed: it needs only the
    local backend.  On CPU the kernels run in interpret mode, so
    attainment is honest-but-tiny (the bound models TPU-class
    hardware); what the column is FOR is comparing tiles against each
    other and watching the trajectory.
  * round-gradient fusion — the epoch hot loop's bytes model before
    (reference: two passes over X for the systematic block plus two
    over the parity block) and after fusion (one pass over the PACKED
    systematic rows plus the (d, d) Gram term), with the implied
    roofline speedup and the measured one-call speedup on the local
    backend.
  * dry-run mesh table — three terms per (arch x shape) from the
    recorded dry-run, single-pod mesh, with the MODEL_FLOPS/HLO_FLOPs
    useful-compute ratio and the dominant bottleneck.  Skipped with a
    notice when `dryrun_results.json` is absent.
"""
from __future__ import annotations

import json
import os

from repro.configs import get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.roofline.analysis import model_flops

RESULTS = os.environ.get("DRYRUN_RESULTS", "dryrun_results.json")

# Paper §IV shapes: cheap enough to measure inline even at interpret
# speed, and bucket-identical to the committed defaults.json entries.
ATTAINMENT_SHAPES = {
    "encode": [(936, 300, 500)],
    "coded_grad": [(936, 500)],
    "round_grad": [(5632, 500)],
}

# §IV epoch-gradient operating point for the fusion section: m full rows,
# k packed rows (bucket-padded systematic support), c parity rows.
FUSION_SHAPE = {"m": 7200, "k": 5632, "c": 2016, "d": 500}


def coded_kernel_rows(iters: int = 3, shapes: dict | None = None):
    """Measured-vs-roofline attainment per (family, shape, tile)."""
    import jax

    from repro.kernels.common import backend
    from repro.tune.cache import lookup_block
    from repro.tune.families import FAMILIES
    from repro.tune.tuner import candidate_terms, measure, roofline_bound

    out = []
    for fam_name, shape_list in sorted((shapes or ATTAINMENT_SHAPES).items()):
        fam = FAMILIES[fam_name]
        for shape in shape_list:
            blocks = [("default", tuple(fam.default_block))]
            tuned = lookup_block(fam_name, shape)
            if tuned is not None and tuned != blocks[0][1]:
                blocks.append(("tuned", tuned))
            for label, block in blocks:
                bound_us = roofline_bound(
                    candidate_terms(fam, shape, block)) * 1e6
                fn, _ = fam.bind(shape, block)
                us = measure(jax.jit(fn), fam.make_args(shape),
                             iters=iters)
                out.append({
                    "family": fam_name, "shape": shape, "label": label,
                    "block": block, "bound_us": bound_us,
                    "measured_us": us,
                    "attainment": bound_us / us if us else 0.0,
                    "backend": backend(),
                })
    return out


def round_grad_fusion_rows(iters: int = 5, shape: dict | None = None):
    """Bytes model + measured wall for the epoch gradient pre/post fusion.

    reference: `resid = X beta - y` then `(w . resid) X` — two sweeps
    over the full (m, d) block — plus the same two sweeps over the
    (c, d) parity block (Eq. 18).  fused: ONE sweep over the (k, d)
    packed systematic rows plus the Gram-folded parity term
    `(G beta - b) / c`, which reads (d, d) instead of (c, d) twice.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import aggregation
    from repro.kernels.common import backend
    from repro.tune.tuner import measure

    s = dict(FUSION_SHAPE, **(shape or {}))
    m, k, c, d = s["m"], s["k"], s["c"], s["d"]
    bytes_ref = 4 * (2 * m * d + 2 * c * d)
    bytes_fused = 4 * (k * d + d * d)

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    x = jax.random.normal(ks[0], (m, d))
    y = jax.random.normal(ks[1], (m,))
    w = (jax.random.uniform(ks[2], (m,)) < k / m).astype(x.dtype)
    xp = jax.random.normal(ks[3], (c, d))
    yp = jax.random.normal(ks[4], (c,))
    beta = jax.random.normal(ks[5], (d,))
    xk = x[:k]
    yk = y[:k]
    wk = w[:k]
    gram, gramy = aggregation.parity_gram(xp, yp)

    def reference(x, y, w, xp, yp, beta):
        resid = x @ beta - y
        g_sys = (resid * w) @ x
        g_par = ((xp @ beta - yp) / c) @ xp
        return g_sys + g_par

    def fused(xk, yk, wk, gram, gramy, beta):
        g_sys = aggregation.round_gradient(xk, yk, beta, w=wk,
                                           path=aggregation.FUSED)
        g_par = aggregation.gram_parity_gradient(
            gram, gramy, beta, jnp.asarray(float(c), x.dtype))
        return g_sys + g_par

    us_ref = measure(jax.jit(reference), (x, y, w, xp, yp, beta),
                     iters=iters)
    us_fused = measure(jax.jit(fused), (xk, yk, wk, gram, gramy, beta),
                       iters=iters)
    return [
        {"label": "reference_2pass", "bytes": bytes_ref,
         "bound_us": bytes_ref / HBM_BW * 1e6, "measured_us": us_ref},
        {"label": "fused_1pass", "bytes": bytes_fused,
         "bound_us": bytes_fused / HBM_BW * 1e6, "measured_us": us_fused},
        {"label": "fusion_speedup", "bytes": 0,
         "bound_us": bytes_ref / bytes_fused,
         "measured_us": us_ref / us_fused if us_fused else 0.0},
    ], backend()


def rows(results_path: str = RESULTS, mesh: str = "16x16"):
    with open(results_path) as f:
        data = json.load(f)
    out = []
    for key, st in sorted(data["runs"].items()):
        arch, shape, m = key.split("|")
        if m != mesh or not st.get("ok"):
            continue
        base = arch.split("-sw")[0]
        cfg = get_config(base)
        coll = sum(v for k, v in st["corrected_collectives"].items()
                   if not k.startswith("n_"))
        t_c = st["corrected_flops"] / PEAK_FLOPS_BF16
        t_m = st["corrected_bytes"] / HBM_BW
        t_x = coll / ICI_BW
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                  key=lambda kv: kv[1])[0]
        mf = model_flops(cfg, shape)
        mf_dev = mf / st["n_devices"]
        ratio = mf_dev / st["corrected_flops"] if st["corrected_flops"] else 0
        out.append({
            "arch": arch, "shape": shape,
            "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
            "dominant": dom,
            "model_flops_per_dev": mf_dev,
            "useful_ratio": ratio,
            "hlo_flops": st["corrected_flops"],
            "hlo_bytes": st["corrected_bytes"],
            "coll_bytes": coll,
            "args_gb": (st["memory"]["argument_size"] or 0) / 1e9,
        })
    return out


def main() -> None:
    coded = coded_kernel_rows()
    print("family,shape,tile,label,bound_us,measured_us,attainment,"
          "backend")
    for r in coded:
        shape = "x".join(str(s) for s in r["shape"])
        tile = "x".join(str(b) for b in r["block"])
        print(f"{r['family']},{shape},{tile},{r['label']},"
              f"{r['bound_us']:.2f},{r['measured_us']:.0f},"
              f"{r['attainment']:.2e},{r['backend']}")

    fusion, bk = round_grad_fusion_rows()
    print("round_grad_fusion,label,bytes,bound_us_or_x,measured_us_or_x,"
          "backend")
    for r in fusion:
        print(f"round_grad_fusion,{r['label']},{r['bytes']},"
              f"{r['bound_us']:.2f},{r['measured_us']:.1f},{bk}")

    try:
        table = rows()
    except FileNotFoundError:
        print(f"# dryrun section skipped: {RESULTS} not found "
              f"(run repro.launch.dryrun to record it)")
        return
    print("arch,shape,t_compute_s,t_memory_s,t_collective_s,dominant,"
          "useful_ratio,args_gb_per_dev")
    for r in table:
        print(f"{r['arch']},{r['shape']},{r['t_compute']:.4e},"
              f"{r['t_memory']:.4e},{r['t_collective']:.4e},"
              f"{r['dominant']},{r['useful_ratio']:.3f},"
              f"{r['args_gb']:.2f}")


if __name__ == "__main__":
    main()
