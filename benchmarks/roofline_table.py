"""Roofline table (EXPERIMENTS.md §Roofline): three terms per
(arch x shape) from the recorded dry-run, single-pod mesh, with the
MODEL_FLOPS/HLO_FLOPs useful-compute ratio and the dominant bottleneck."""
from __future__ import annotations

import json
import os

from repro.configs import get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.roofline.analysis import model_flops

RESULTS = os.environ.get("DRYRUN_RESULTS", "dryrun_results.json")


def rows(results_path: str = RESULTS, mesh: str = "16x16"):
    with open(results_path) as f:
        data = json.load(f)
    out = []
    for key, st in sorted(data["runs"].items()):
        arch, shape, m = key.split("|")
        if m != mesh or not st.get("ok"):
            continue
        base = arch.split("-sw")[0]
        cfg = get_config(base)
        coll = sum(v for k, v in st["corrected_collectives"].items()
                   if not k.startswith("n_"))
        t_c = st["corrected_flops"] / PEAK_FLOPS_BF16
        t_m = st["corrected_bytes"] / HBM_BW
        t_x = coll / ICI_BW
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                  key=lambda kv: kv[1])[0]
        mf = model_flops(cfg, shape)
        mf_dev = mf / st["n_devices"]
        ratio = mf_dev / st["corrected_flops"] if st["corrected_flops"] else 0
        out.append({
            "arch": arch, "shape": shape,
            "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
            "dominant": dom,
            "model_flops_per_dev": mf_dev,
            "useful_ratio": ratio,
            "hlo_flops": st["corrected_flops"],
            "hlo_bytes": st["corrected_bytes"],
            "coll_bytes": coll,
            "args_gb": (st["memory"]["argument_size"] or 0) / 1e9,
        })
    return out


def main() -> None:
    table = rows()
    print("arch,shape,t_compute_s,t_memory_s,t_collective_s,dominant,"
          "useful_ratio,args_gb_per_dev")
    for r in table:
        print(f"{r['arch']},{r['shape']},{r['t_compute']:.4e},"
              f"{r['t_memory']:.4e},{r['t_collective']:.4e},"
              f"{r['dominant']},{r['useful_ratio']:.3f},"
              f"{r['args_gb']:.2f}")


if __name__ == "__main__":
    main()
