"""Paper Fig. 2: NMSE-vs-wall-clock convergence for a redundancy sweep at
heterogeneity (0.2, 0.2), benchmarked against the least-squares bound.

Each curve is one `Session`: uncoded FL plus a fixed-`c` sweep of
`CodedFL` strategies over the same data and delay seed.  The whole sweep's
redundancy planning happens in ONE batched solver call (`plan_sweep`) and
the whole sweep TRAINS as one batched computation (`run_sweep`) — per-lane
traces are bit-identical to solo runs.
"""
from __future__ import annotations

import numpy as np

from repro.api import TrainData, convergence_time, plan_sweep, run_sweep
from repro.sim.network import paper_fleet

from .common import D, Timer, cfl_session, emit, problem, uncoded_session


def ls_bound(data: TrainData) -> float:
    """NMSE of the closed-form least-squares estimator (the paper's bound)."""
    x = np.asarray(data.xs).reshape(-1, D)
    y = np.asarray(data.ys).reshape(-1)
    bhat, *_ = np.linalg.lstsq(x, y, rcond=None)
    bt = np.asarray(data.beta_true)
    return float(np.sum((bhat - bt) ** 2) / np.sum(bt ** 2))


def main(epochs: int = 1200, deltas=(0.0, 0.07, 0.13, 0.16, 0.28)) -> None:
    data = problem(0)
    fleet = paper_fleet(0.2, 0.2, seed=0)
    bound = ls_bound(data)
    emit("fig2/ls_bound_nmse", 0.0, f"nmse={bound:.3e}")

    cfl_deltas = [d for d in deltas if d != 0.0]
    sessions = [uncoded_session(fleet, epochs)] + \
        [cfl_session(fleet, epochs, d, include_upload_delay=True,
                     key_seed=100) for d in cfl_deltas]
    with Timer() as t:
        states = plan_sweep(sessions, data)  # one batched redundancy solve
    emit("fig2/plan_sweep", t.us / len(sessions),
         f"sessions={len(sessions)}")

    with Timer() as t:  # one batched training computation for every curve
        reports = run_sweep(sessions, data,
                            rngs=[np.random.default_rng(0)
                                  for _ in sessions],
                            states=states)
    emit("fig2/run_sweep", t.us / (len(sessions) * epochs),
         f"sessions={len(sessions)}")

    res_u = reports[0]
    emit("fig2/uncoded", 0.0,
         f"final_nmse={res_u.final_nmse():.3e};"
         f"t_conv_1e-3={convergence_time(res_u, 1e-3):.0f}s;"
         f"t_conv_3e-4={convergence_time(res_u, 3e-4):.0f}s")

    for delta, res_c in zip(cfl_deltas, reports[1:]):
        emit(f"fig2/cfl_delta={delta}", 0.0,
             f"t_star={res_c.epoch_durations[0]:.2f}s;"
             f"setup={res_c.setup_time:.0f}s;"
             f"final_nmse={res_c.final_nmse():.3e};"
             f"t_conv_1e-3={convergence_time(res_c, 1e-3):.0f}s;"
             f"t_conv_3e-4={convergence_time(res_c, 3e-4):.0f}s")


if __name__ == "__main__":
    main()
