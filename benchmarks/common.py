"""Shared setup for the paper-figure benchmarks (§IV configuration).

All training benchmarks run through the unified Strategy/Session API
(`repro.api`): each figure is a set of `Session` configurations over the
same `TrainData`, executed by the single scan-jitted epoch engine.
Strategies are constructed by name through `repro.api.make_strategy` —
benchmarks never hand-build strategy dataclasses.
"""
from __future__ import annotations

import json
import os
import platform
import time

import jax
from repro.api import Session, TrainData, make_strategy

N_DEVICES = 24
ELL = 300
D = 500
LR = 0.0085
M = N_DEVICES * ELL
TARGET_NMSE = 3e-4  # paper Fig. 4 convergence criterion


def problem(seed: int = 0) -> TrainData:
    return TrainData.linreg(jax.random.PRNGKey(seed),
                            n=N_DEVICES, ell=ELL, d=D)


def uncoded_session(fleet, epochs: int) -> Session:
    return Session(strategy=make_strategy("uncoded"), fleet=fleet, lr=LR,
                   epochs=epochs)


def cfl_session(fleet, epochs: int, delta: float,
                include_upload_delay: bool = False,
                server_always_returns: bool = False,
                key_seed: int = 7, redundancy_plan=None) -> Session:
    strategy = make_strategy(
        "cfl", key_seed=key_seed, fixed_c=int(delta * M),
        include_upload_delay=include_upload_delay,
        server_always_returns=server_always_returns,
        label=f"cfl_delta={delta}", redundancy_plan=redundancy_plan)
    return Session(strategy=strategy, fleet=fleet, lr=LR, epochs=epochs)


def scfl_session(fleet, epochs: int, delta: float,
                 noise_multiplier: float = 0.5, sample_frac: float = 1.0,
                 include_upload_delay: bool = False,
                 key_seed: int = 7, label: str | None = None) -> Session:
    strategy = make_strategy(
        "stochastic", key_seed=key_seed, fixed_c=int(delta * M),
        noise_multiplier=noise_multiplier, sample_frac=sample_frac,
        include_upload_delay=include_upload_delay,
        label=label or f"scfl_delta={delta}_sigma={noise_multiplier}")
    return Session(strategy=strategy, fleet=fleet, lr=LR, epochs=epochs)


def lowlat_session(fleet, epochs: int, delta: float, chunks: int = 8,
                   include_upload_delay: bool = False,
                   key_seed: int = 7, label: str | None = None) -> Session:
    strategy = make_strategy(
        "lowlatency", key_seed=key_seed, fixed_c=int(delta * M),
        chunks=chunks, include_upload_delay=include_upload_delay,
        label=label or f"lowlat_delta={delta}_q={chunks}")
    return Session(strategy=strategy, fleet=fleet, lr=LR, epochs=epochs)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6


# Every emit() is also recorded here so CI smoke runs can persist the
# whole measurement set as a machine-readable artifact (dump_bench) —
# the perf trajectory is tracked across PRs instead of living in logs.
_RECORDS: list = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
    _RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                     "derived": derived})


def dump_bench(benchmark: str, gates: dict | None = None) -> str:
    """Write all records emitted so far to `BENCH_<benchmark>.json`.

    `gates` carries the hard-gated values (budgets, latencies, NMSE
    floors) as structured numbers next to the free-form records; the CI
    workflow uploads the files as artifacts.  Target directory defaults
    to the CWD and is overridable via $BENCH_DIR.
    """
    bench_dir = os.environ.get("BENCH_DIR", ".")
    os.makedirs(bench_dir, exist_ok=True)
    path = os.path.join(bench_dir, f"BENCH_{benchmark}.json")
    payload = {
        "schema": 1,
        "benchmark": benchmark,
        "generated_unix": round(time.time(), 1),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "gates": gates or {},
        "records": list(_RECORDS),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"bench artifact written: {path}")
    return path
