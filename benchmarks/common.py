"""Shared setup for the paper-figure benchmarks (§IV configuration).

All training benchmarks run through the unified Strategy/Session API
(`repro.api`): each figure is a set of `Session` configurations over the
same `TrainData`, executed by the single scan-jitted epoch engine.
"""
from __future__ import annotations

import time

import jax
from repro.api import CodedFL, Session, TrainData, UncodedFL

N_DEVICES = 24
ELL = 300
D = 500
LR = 0.0085
M = N_DEVICES * ELL
TARGET_NMSE = 3e-4  # paper Fig. 4 convergence criterion


def problem(seed: int = 0) -> TrainData:
    return TrainData.linreg(jax.random.PRNGKey(seed),
                            n=N_DEVICES, ell=ELL, d=D)


def uncoded_session(fleet, epochs: int) -> Session:
    return Session(strategy=UncodedFL(), fleet=fleet, lr=LR, epochs=epochs)


def cfl_session(fleet, epochs: int, delta: float,
                include_upload_delay: bool = False,
                server_always_returns: bool = False,
                key_seed: int = 7, redundancy_plan=None) -> Session:
    strategy = CodedFL(key=jax.random.PRNGKey(key_seed),
                       fixed_c=int(delta * M),
                       include_upload_delay=include_upload_delay,
                       server_always_returns=server_always_returns,
                       label=f"cfl_delta={delta}",
                       redundancy_plan=redundancy_plan)
    return Session(strategy=strategy, fleet=fleet, lr=LR, epochs=epochs)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
