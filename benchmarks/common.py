"""Shared setup for the paper-figure benchmarks (§IV configuration)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.sim import simulator as S
from repro.sim.network import paper_fleet

N_DEVICES = 24
ELL = 300
D = 500
LR = 0.0085
M = N_DEVICES * ELL
TARGET_NMSE = 3e-4  # paper Fig. 4 convergence criterion


def problem(seed: int = 0):
    key = jax.random.PRNGKey(seed)
    return S.generate_linreg(key, N_DEVICES, ELL, D)


def run_pair(nu_comp: float, nu_link: float, delta: float, epochs: int,
             seed: int = 0, include_upload_delay: bool = False,
             xs=None, ys=None, beta_true=None):
    """One (uncoded, coded) run pair sharing the same fleet + data."""
    fleet = paper_fleet(nu_comp, nu_link, seed=seed)
    if xs is None:
        xs, ys, beta_true = problem(seed)
    res_u = S.run_uncoded(fleet, xs, ys, beta_true, lr=LR, epochs=epochs,
                          rng=np.random.default_rng(seed))
    res_c = S.run_cfl(fleet, xs, ys, beta_true, lr=LR, epochs=epochs,
                      rng=np.random.default_rng(seed),
                      key=jax.random.PRNGKey(seed + 100),
                      fixed_c=int(delta * M),
                      include_upload_delay=include_upload_delay)
    return fleet, res_u, res_c


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
