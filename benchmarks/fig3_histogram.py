"""Paper Fig. 3: distribution of per-epoch completion time — uncoded FL
(wait for all m partial gradients) vs CFL (deadline t*, tail clipped)."""
from __future__ import annotations

import numpy as np

from repro.core.delay_model import sample_total
from repro.core.redundancy import solve_redundancy
from repro.sim.network import paper_fleet

from .common import ELL, M, N_DEVICES, Timer, emit


def main(delta: float = 0.13, draws: int = 20000) -> None:
    fleet = paper_fleet(0.2, 0.2, seed=0)
    rng = np.random.default_rng(0)
    full_load = np.full(N_DEVICES, ELL)

    with Timer() as t:
        samples = sample_total(fleet.edge, full_load, rng, size=draws)
        uncoded_epochs = samples.max(axis=1)
    q = np.quantile(uncoded_epochs, [0.5, 0.9, 0.99])
    emit("fig3/uncoded_epoch_time", t.us / draws,
         f"median={q[0]:.1f}s;p90={q[1]:.1f}s;p99={q[2]:.1f}s;"
         f"max={uncoded_epochs.max():.1f}s")

    plan = solve_redundancy(fleet.edge, fleet.server, full_load,
                            fixed_c=int(delta * M))
    # CFL: epoch always ends at t*; also report when the last *useful*
    # systematic gradient (m - c worth) arrives, mirroring the figure.
    with Timer() as t:
        s = sample_total(fleet.edge, plan.loads, rng, size=draws)
    active = plan.loads > 0
    t_last_arrival = np.where(s[:, active] <= plan.t_star,
                              s[:, active], 0.0).max(axis=1)
    q = np.quantile(t_last_arrival, [0.5, 0.9, 0.99])
    emit("fig3/cfl_epoch_time", t.us / draws,
         f"t_star={plan.t_star:.1f}s;deadline_clips_all=1;"
         f"last_arrival_median={q[0]:.1f}s;p99={q[2]:.1f}s")
    ratio = float(np.quantile(uncoded_epochs, 0.99) / plan.t_star)
    emit("fig3/tail_clipping", 0.0,
         f"p99_uncoded_over_tstar={ratio:.2f}")


if __name__ == "__main__":
    main()
