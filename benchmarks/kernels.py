"""Kernel microbenchmarks: jit'd wall time of the Pallas kernels (interpret
mode on CPU — correctness-representative, not TPU-representative) vs the
pure-jnp reference path at the paper's §IV shapes."""
from __future__ import annotations

import time

import jax

from repro.kernels.coded_grad import ops as cg_ops
from repro.kernels.encode import ops as en_ops

from .common import emit


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main() -> None:
    key = jax.random.PRNGKey(0)
    # paper shapes: composite parity c=936, d=500 (delta=0.13)
    c, d, ell = 936, 500, 300
    a = jax.random.normal(key, (c, d))
    y = jax.random.normal(jax.random.fold_in(key, 1), (c,))
    beta = jax.random.normal(jax.random.fold_in(key, 2), (d,))
    us_ref = _time(jax.jit(cg_ops.reference), a, y, beta)
    emit("kernels/coded_grad_ref_jnp", us_ref, f"shape={c}x{d}")
    us_k = _time(lambda *args: cg_ops.lsq_gradient(*args), a, y, beta)
    emit("kernels/coded_grad_pallas_interpret", us_k,
         "interpret=True (CPU validation mode; perf target is TPU)")

    g = jax.random.normal(key, (c, ell))
    w = jax.random.uniform(jax.random.fold_in(key, 3), (ell,))
    x = jax.random.normal(jax.random.fold_in(key, 4), (ell, d))
    us_ref = _time(jax.jit(en_ops.reference), g, w, x)
    emit("kernels/encode_ref_jnp", us_ref, f"shape={c}x{ell}x{d}")
    us_k = _time(lambda *args: en_ops.encode_parity(*args), g, w, x)
    emit("kernels/encode_pallas_interpret", us_k,
         "interpret=True (CPU validation mode; perf target is TPU)")

    from repro.kernels.flash_attn import ops as fa_ops
    q = jax.random.normal(key, (1, 4, 256, 64))
    kk = jax.random.normal(jax.random.fold_in(key, 5), (1, 4, 256, 64))
    vv = jax.random.normal(jax.random.fold_in(key, 6), (1, 4, 256, 64))
    us_ref = _time(jax.jit(fa_ops.reference), q, kk, vv)
    emit("kernels/flash_attn_ref_jnp", us_ref, "shape=B1xH4xS256xD64")
    us_k = _time(lambda *a: fa_ops.causal_attention(*a, block_q=64,
                                                    block_k=64), q, kk, vv)
    emit("kernels/flash_attn_pallas_interpret", us_k,
         "interpret=True (CPU validation mode; perf target is TPU)")


if __name__ == "__main__":
    main()
