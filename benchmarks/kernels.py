"""Kernel microbenchmarks: jit'd wall time of the Pallas kernels (interpret
mode on CPU — correctness-representative, not TPU-representative) vs the
pure-jnp reference path at the paper's §IV shapes, PLUS the tuned-vs-default
tile sweep at fleet-scale shapes.

The fleet sweep is the autotuner's proof of work: for each fleet-scale
shape it times the hard-coded default tile against `block="auto"` (the
persisted `repro.tune` cache, committed for CI shapes in
`src/repro/tune/defaults.json`) across `encode_parity`, the in-kernel
PRNG encoder, and `lsq_gradient`.  `--smoke` gates the best encode
speedup at >= $KERNELS_SMOKE_MIN_SPEEDUP (default 1.2) and writes
BENCH_kernels.json via `common.dump_bench` for the perf-trend stage.

    python -m benchmarks.kernels [--smoke]
    python -m benchmarks.run --only kernels
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax

from repro.kernels.coded_grad import ops as cg_ops
from repro.kernels.encode import ops as en_ops

from .common import dump_bench, emit

# (c, ell, d) composite-parity shapes at fleet scale: what the streamed
# encoder sees when n is 1e5+ and the parity budget c grows with it.
FLEET_ENCODE_SHAPES = [(2048, 512, 512)]
FLEET_GRAD_SHAPES = [(8192, 512)]


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _paper_shapes(iters: int) -> None:
    key = jax.random.PRNGKey(0)
    # paper shapes: composite parity c=936, d=500 (delta=0.13)
    c, d, ell = 936, 500, 300
    a = jax.random.normal(key, (c, d))
    y = jax.random.normal(jax.random.fold_in(key, 1), (c,))
    beta = jax.random.normal(jax.random.fold_in(key, 2), (d,))
    us_ref = _time(jax.jit(cg_ops.reference), a, y, beta, iters=iters)
    emit("kernels/coded_grad_ref_jnp", us_ref, f"shape={c}x{d}")
    us_k = _time(lambda *args: cg_ops.lsq_gradient(*args), a, y, beta,
                 iters=iters)
    emit("kernels/coded_grad_pallas_interpret", us_k,
         "interpret=True (CPU validation mode; perf target is TPU)")

    g = jax.random.normal(key, (c, ell))
    w = jax.random.uniform(jax.random.fold_in(key, 3), (ell,))
    x = jax.random.normal(jax.random.fold_in(key, 4), (ell, d))
    us_ref = _time(jax.jit(en_ops.reference), g, w, x, iters=iters)
    emit("kernels/encode_ref_jnp", us_ref, f"shape={c}x{ell}x{d}")
    us_k = _time(lambda *args: en_ops.encode_parity(*args), g, w, x,
                 iters=iters)
    emit("kernels/encode_pallas_interpret", us_k,
         "interpret=True (CPU validation mode; perf target is TPU)")

    from repro.kernels.flash_attn import ops as fa_ops
    q = jax.random.normal(key, (1, 4, 256, 64))
    kk = jax.random.normal(jax.random.fold_in(key, 5), (1, 4, 256, 64))
    vv = jax.random.normal(jax.random.fold_in(key, 6), (1, 4, 256, 64))
    us_ref = _time(jax.jit(fa_ops.reference), q, kk, vv, iters=iters)
    emit("kernels/flash_attn_ref_jnp", us_ref, "shape=B1xH4xS256xD64")
    us_k = _time(lambda *a: fa_ops.causal_attention(*a, block_q=64,
                                                    block_k=64), q, kk, vv,
                 iters=iters)
    emit("kernels/flash_attn_pallas_interpret", us_k,
         "interpret=True (CPU validation mode; perf target is TPU)")


def _fleet_sweep(iters: int) -> dict:
    """Tuned (block="auto") vs hard-coded default tiles at fleet scale.

    Returns the per-(kernel, shape) speedups; the best encode speedup is
    the smoke gate."""
    from repro.kernels.coded_grad.coded_grad import DEFAULT_BLOCK_M
    from repro.kernels.encode.encode import DEFAULT_BLOCK
    from repro.tune.cache import lookup_block

    key = jax.random.PRNGKey(7)
    speedups: dict[str, float] = {}

    for c, ell, d in FLEET_ENCODE_SHAPES:
        tag = f"{c}x{ell}x{d}"
        g = jax.random.normal(key, (c, ell))
        w = jax.random.uniform(jax.random.fold_in(key, 1), (ell,))
        x = jax.random.normal(jax.random.fold_in(key, 2), (ell, d))

        us_def = _time(lambda *a: en_ops.encode_parity(
            *a, block=DEFAULT_BLOCK), g, w, x, iters=iters)
        emit(f"kernels/encode_default_{tag}", us_def,
             f"block={DEFAULT_BLOCK}")
        tuned = lookup_block("encode", (c, ell, d))
        us_auto = _time(lambda *a: en_ops.encode_parity(
            *a, block="auto"), g, w, x, iters=iters)
        emit(f"kernels/encode_auto_{tag}", us_auto,
             f"block=auto -> {tuned or 'MISS (default)'}")
        speedups[f"encode_tuned_speedup_x_{tag}"] = us_def / us_auto

        pk = jax.random.PRNGKey(11)
        us_def = _time(lambda *a: en_ops.encode_parity_prng(
            *a, c, block=DEFAULT_BLOCK), pk, w, x, iters=iters)
        emit(f"kernels/encode_prng_default_{tag}", us_def,
             f"block={DEFAULT_BLOCK}")
        tuned = lookup_block("encode_prng", (c, ell, d))
        us_auto = _time(lambda *a: en_ops.encode_parity_prng(
            *a, c, block="auto"), pk, w, x, iters=iters)
        emit(f"kernels/encode_prng_auto_{tag}", us_auto,
             f"block=auto -> {tuned or 'MISS (default)'}")
        speedups[f"encode_prng_tuned_speedup_x_{tag}"] = us_def / us_auto

    for m, d in FLEET_GRAD_SHAPES:
        tag = f"{m}x{d}"
        a = jax.random.normal(key, (m, d))
        y = jax.random.normal(jax.random.fold_in(key, 3), (m,))
        beta = jax.random.normal(jax.random.fold_in(key, 4), (d,))
        us_def = _time(lambda *args: cg_ops.lsq_gradient(
            *args, block_m=DEFAULT_BLOCK_M), a, y, beta, iters=iters)
        emit(f"kernels/coded_grad_default_{tag}", us_def,
             f"block_m={DEFAULT_BLOCK_M}")
        tuned = lookup_block("coded_grad", (m, d))
        us_auto = _time(lambda *args: cg_ops.lsq_gradient(
            *args, block_m="auto"), a, y, beta, iters=iters)
        emit(f"kernels/coded_grad_auto_{tag}", us_auto,
             f"block_m=auto -> {tuned or 'MISS (default)'}")
        speedups[f"coded_grad_tuned_speedup_x_{tag}"] = us_def / us_auto

    return speedups


def main(smoke: bool = False) -> None:
    iters = 2 if smoke else 5
    gates: dict = {}
    try:
        _paper_shapes(iters)
        speedups = _fleet_sweep(iters)
        gates.update({k: round(v, 2) for k, v in speedups.items()})
        best_encode = max(v for k, v in speedups.items()
                          if k.startswith("encode"))
        gates["best_encode_tuned_speedup_x"] = round(best_encode, 2)
    finally:
        # artifact BEFORE the gate assert: a regression still records
        dump_bench("kernels", gates)
    if smoke:
        floor = float(os.environ.get("KERNELS_SMOKE_MIN_SPEEDUP", "1.2"))
        # SystemExit, not assert: the gate must survive `python -O`
        if best_encode < floor:
            raise SystemExit(
                f"tuned encode tiles beat defaults only {best_encode:.2f}x "
                f"(< {floor}x) — stale src/repro/tune/defaults.json or a "
                f"kernel/tuner regression")
        print(f"kernels smoke OK: tuned encode {best_encode:.2f}x "
              f">= {floor}x over default tiles")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: fewer iters + tuned-tile speedup gate")
    args = ap.parse_args()
    sys.exit(main(smoke=args.smoke))
