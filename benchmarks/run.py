"""Benchmark harness: one entry per paper figure + kernels + roofline.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig2,fig3,...]

Prints ``name,us_per_call,derived`` CSV lines (stdout); paper-claim
comparisons live in EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced epoch counts (CI-speed)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig2,fig3,fig4,fig5,"
                         "schemes,nonlinear,privacy,ablation,noniid,serve,"
                         "fleet,kernels,epoch,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    t0 = time.time()
    print("name,us_per_call,derived")

    if want("fig2"):
        from . import fig2_convergence
        fig2_convergence.main(epochs=400 if args.fast else 1200)
    if want("fig3"):
        from . import fig3_histogram
        fig3_histogram.main(draws=4000 if args.fast else 20000)
    if want("fig4"):
        from . import fig4_coding_gain
        fig4_coding_gain.main(epochs=500 if args.fast else 1400)
    if want("fig5"):
        from . import fig5_comm_load
        fig5_comm_load.main(epochs=600 if args.fast else 1600)
    if want("schemes"):
        from . import fig_schemes
        # 600 epochs in both modes: the monotone-convergence gates need the
        # slow-deadline (low-delta) runs to actually reach the target
        fig_schemes.main(epochs=600)
    if want("nonlinear"):
        from . import fig_nonlinear
        fig_nonlinear.main(epochs=300 if args.fast else 600)
    if want("privacy"):
        from . import fig_privacy
        fig_privacy.main(epochs=200 if args.fast else 400)
    if want("noniid"):
        from . import noniid
        noniid.main(epochs=600 if args.fast else 1200)
    if want("ablation"):
        from . import ablation_baselines
        ablation_baselines.main(epochs=600 if args.fast else 1000)
    if want("serve"):
        from . import perf_serve
        perf_serve.main(epochs=240 if args.fast else 400)
    if want("fleet"):
        from . import perf_fleet
        perf_fleet.main(n=perf_fleet.FLEET_N // 10 if args.fast
                        else perf_fleet.FLEET_N)
    if want("kernels"):
        from . import kernels
        kernels.main()
    if want("epoch"):
        from . import perf_session
        # fused-vs-reference round-gradient path (gated in CI epoch-smoke)
        perf_session.main(epochs=300, smoke=True, epoch=True)
    if want("roofline"):
        from . import roofline_table
        # always prints the coded-kernel attainment section; the dry-run
        # mesh section self-skips when dryrun_results.json is absent
        roofline_table.main()

    print(f"total,{(time.time() - t0) * 1e6:.0f},benchmark suite wall time")
    return 0


if __name__ == "__main__":
    sys.exit(main())
