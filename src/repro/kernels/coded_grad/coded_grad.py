"""Pallas TPU kernel: fused least-squares gradient g = A^T (A beta - y).

This is the server's per-epoch parity-gradient computation (Eq. 18) — the
hot spot of CFL: A = X~ (c x d composite parity), executed every epoch.

TPU adaptation (vs the paper's CPU/edge setting): a naive implementation
makes two HBM passes over A (r = A beta - y, then A^T r).  Fusing them
streams each (bm x d) row-block of A HBM->VMEM exactly once: the block
computes its residual slice on the MXU and immediately accumulates its
contribution A_blk^T r_blk into a VMEM-resident (d,) accumulator.  The grid
iterates over row-blocks sequentially (TPU grid semantics), so the
accumulator lives in the output block across iterations.

Arithmetic intensity doubles vs the two-pass form: 4cd FLOPs over cd loaded
elements instead of 2 x (2cd over cd) — the kernel is HBM-bound either way,
so halving bytes halves time.

beta and y are assumed to fit VMEM alongside one row-block: d <= ~8k fp32
(the paper uses d = 500), bm tuned so bm*d*4 bytes ~ 4 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 1024


def _kernel(a_ref, y_ref, beta_ref, out_ref):
    """Grid step i handles rows [i*bm, (i+1)*bm)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]                      # (bm, d)   VMEM
    beta = beta_ref[...]                # (1, d)    VMEM (row vector)
    y = y_ref[...]                      # (1, bm)
    # residual slice: (bm,) = A_blk @ beta - y_blk    (MXU matmul)
    r = jax.lax.dot_general(a, beta[0], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32) - y[0]
    # accumulate A_blk^T r : (d,)
    contrib = jax.lax.dot_general(r, a, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    out_ref[...] += contrib[None, :].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def lsq_gradient(a: jax.Array, y: jax.Array, beta: jax.Array,
                 block_m: int = DEFAULT_BLOCK_M,
                 interpret: bool = False) -> jax.Array:
    """g = A^T (A beta - y) with one HBM pass over A.

    a: (M, D), y: (M,), beta: (D,).  M is padded to a block multiple
    (zero rows contribute zero gradient).
    """
    m, d = a.shape
    bm = min(block_m, m)
    pad = (-m) % bm
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        y = jnp.pad(y, (0, pad))
    grid = (a.shape[0] // bm,)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),      # stream A blocks
            pl.BlockSpec((1, bm), lambda i: (0, i)),      # y slice
            pl.BlockSpec((1, d), lambda i: (0, 0)),       # beta resident
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (0, 0)),  # accumulator
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(a, y[None, :], beta[None, :])
    return out[0].astype(beta.dtype)
