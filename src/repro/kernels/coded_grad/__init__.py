from . import ops, ref
