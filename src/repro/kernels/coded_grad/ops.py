"""jit'd wrapper for the fused LSQ-gradient kernel.

On CPU (no TPU backend) the kernel body runs in interpret mode — same
lowering, Python-evaluated — so correctness is validated everywhere while
the BlockSpec tiling targets TPU VMEM.

`block_m="auto"` (the default) resolves the row-tile host-side against
the persisted tuning cache (family "coded_grad", shape bucket of
`(m, d)`, backend); a cold miss falls back to `DEFAULT_BLOCK_M`
bit-for-bit.  Resolution never autotunes — see `python -m repro.tune`.
"""
from __future__ import annotations

import jax

from repro.kernels.common import on_tpu, resolve_block

from . import coded_grad as _k
from . import ref as _ref


def lsq_gradient(a: jax.Array, y: jax.Array, beta: jax.Array,
                 block_m="auto",
                 force_interpret: bool = False) -> jax.Array:
    """Fused A^T(A beta - y); falls back to interpret mode off-TPU."""
    block_m = resolve_block("coded_grad", (a.shape[0], a.shape[1]),
                            block_m, _k.DEFAULT_BLOCK_M)
    return _k.lsq_gradient(a, y, beta, block_m=block_m,
                           interpret=force_interpret or not on_tpu())


reference = _ref.lsq_gradient
