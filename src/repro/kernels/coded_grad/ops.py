"""jit'd wrapper for the fused LSQ-gradient kernel.

On CPU (no TPU backend) the kernel body runs in interpret mode — same
lowering, Python-evaluated — so correctness is validated everywhere while
the BlockSpec tiling targets TPU VMEM.
"""
from __future__ import annotations

import jax

from . import coded_grad as _k
from . import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def lsq_gradient(a: jax.Array, y: jax.Array, beta: jax.Array,
                 block_m: int = _k.DEFAULT_BLOCK_M,
                 force_interpret: bool = False) -> jax.Array:
    """Fused A^T(A beta - y); falls back to interpret mode off-TPU."""
    return _k.lsq_gradient(a, y, beta, block_m=block_m,
                           interpret=force_interpret or not _on_tpu())


reference = _ref.lsq_gradient
