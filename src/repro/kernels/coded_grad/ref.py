"""Pure-jnp oracle for the fused least-squares gradient."""
import jax


def lsq_gradient(a: jax.Array, y: jax.Array, beta: jax.Array) -> jax.Array:
    """g = A^T (A beta - y).  a: (M, D), y: (M,), beta: (D,) -> (D,)."""
    r = a @ beta - y
    return a.T @ r
