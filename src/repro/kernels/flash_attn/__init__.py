from . import ops, ref
