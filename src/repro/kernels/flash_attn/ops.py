"""jit'd wrapper for the flash-attention kernel (interpret on CPU)."""
from __future__ import annotations

from repro.kernels.common import on_tpu

from . import flash_attn as _k
from . import ref as _ref


def causal_attention(q, k, v, block_q: int = _k.DEFAULT_BQ,
                     block_k: int = _k.DEFAULT_BK,
                     force_interpret: bool = False):
    return _k.causal_attention(q, k, v, block_q=block_q, block_k=block_k,
                               interpret=force_interpret or not on_tpu())


reference = _ref.causal_attention
