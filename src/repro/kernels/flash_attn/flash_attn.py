"""Pallas TPU kernel: causal flash attention (online softmax).

The §Perf A3 finding: plain attention materializes (B, H, S, T) score
tensors in HBM — at prefill_32k that is the dominant memory-term
contributor.  Flash attention streams (bq x d) query blocks and (bk x d)
KV blocks through VMEM, carrying the online-softmax state (running max m,
normalizer l, fp32 accumulator) in VMEM scratch across the sequential KV
grid axis; scores never touch HBM.

Grid: (B*H, S/bq, T/bk) — the KV axis is innermost, so the scratch carry
is valid under TPU's sequential grid semantics.  Causal blocks strictly
above the diagonal are skipped with pl.when (their loads are still
prefetched by the BlockSpec pipeline; on TPU the MXU work is what matters).

Block defaults 512x512: VMEM working set ~ (2*bk*d + bq*d) bf16
+ (bq*bk + 2*bq*d) fp32 ~ 2.6 MB at d=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, bq: int, bk: int, n_kv: int):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = q_idx * bq
    kv_start = kv_idx * bk

    @pl.when(kv_start <= q_start + bq - 1)  # any causal overlap
    def _update():
        q = q_ref[0].astype(jnp.float32)               # (bq, d)
        k = k_ref[0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                   # (bq, bk)
        iq = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        jk = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(jk <= iq, s, NEG_INF)

        m_prev = m_ref[...]                             # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                          # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kv_idx == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_q", "block_k", "interpret"))
def causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     block_q: int = DEFAULT_BQ, block_k: int = DEFAULT_BK,
                     interpret: bool = False) -> jax.Array:
    """q, k, v: (B, H, S, D) -> (B, H, S, D), causal."""
    B, H, S, D = q.shape
    T = k.shape[2]
    bq = min(block_q, S)
    bk = min(block_k, T)
    if S % bq or T % bk:
        raise ValueError(f"S={S} / T={T} must divide blocks ({bq}, {bk})")
    scale = 1.0 / (D ** 0.5)
    bh = B * H
    qf = q.reshape(bh, S, D)
    kf = k.reshape(bh, T, D)
    vf = v.reshape(bh, T, D)
    n_kv = T // bk

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bq=bq, bk=bk, n_kv=n_kv),
        grid=(bh, S // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),   # online-softmax accumulator
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running normalizer l
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D)
