"""Pure-jnp oracle: causal softmax attention (scores materialized)."""
import jax
import jax.numpy as jnp


def causal_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """q, k, v: (B, H, S, D) -> (B, H, S, D), causal, fp32 softmax."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    s = s / jnp.sqrt(d)
    S = q.shape[2]
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)
