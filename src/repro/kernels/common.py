"""Shared backend detection + tile resolution for the Pallas kernel ops.

Every `kernels/*/ops.py` wrapper needs the same two decisions:

  * which backend is live (TPU runs the compiled kernel, anything else
    runs interpret mode) — previously a copy-pasted `_on_tpu()` per
    subpackage, now the ONE `backend()` / `on_tpu()` pair, also reused
    by the autotuner's cache key (`repro.tune.cache`);
  * which tile to run with — `resolve_block` turns the `block="auto"`
    sentinel into a concrete tile by consulting the persisted tuning
    cache (`repro.tune.cache.lookup_block`), falling back to the
    kernel's hard-coded default on a cold miss.  Resolution is a pure
    host-side read: it NEVER autotunes implicitly — populating the
    cache is `python -m repro.tune`'s job (see API.md "The autotuning
    layer").
"""
from __future__ import annotations

import jax

AUTO = "auto"


def backend() -> str:
    """The live JAX backend name — also the tuning-cache key component."""
    return jax.default_backend()


def on_tpu() -> bool:
    return backend() == "tpu"


def resolve_block(family: str, shape: tuple[int, ...], block,
                  default):
    """Concrete tile for `block`: pass-through unless `block == "auto"`.

    `shape` is the kernel family's logical problem shape (e.g.
    `(c, ell, d)` for encode, `(m, d)` for coded_grad) — bucketed by the
    cache, so nearby shapes share an entry.  Shapes must be concrete by
    resolution time; inside a jit trace they are (shapes are static).
    Cold miss -> `default`, bit-for-bit the pre-autotuner behaviour.
    """
    if block != AUTO:
        return block
    from repro.tune.cache import lookup_block

    found = lookup_block(family, shape)
    if found is None:
        return default
    if isinstance(default, int):  # 1-d tile families (coded_grad)
        return int(found[0])
    return tuple(int(b) for b in found)
