"""Pure-jnp oracle for the SSD intra-chunk computation: re-exports the
model-side reference so kernel tests and the model stay in lockstep."""
from repro.models.ssm import ssd_chunk_reference

__all__ = ["ssd_chunk_reference"]
