"""Pallas TPU kernel: Mamba2 SSD intra-chunk block (arXiv:2405.21060).

Per (batch, chunk, head) the kernel computes, entirely in VMEM:

    y_diag[q, p] = sum_t  (C_q . B_t) * L[q, t] * (dt_t x_t)[p]
    state[p, n]  = sum_q  decay_out[q] * (dt_q x_q)[p] * B_q[n]

where L[q, t] = exp(cumsum(dA)_q - cumsum(dA)_t) for t <= q (the causal
decay kernel) and decay_out[q] = exp(cumsum_end - cumsum_q).

This is the flash-linear-attention layout adapted to the MXU: the (Q x Q)
score matrix C B^T and the (Q x P) output are matmuls; the decay mask is an
elementwise VPU op.  One grid step handles one (b, chunk, head): chunk
Q = 128..256 and headdim P = 64 keep the working set (~Q*(2N + P + Q) fp32)
well under VMEM.

The inter-chunk state recurrence stays in jax.lax.scan (O(S/Q) tiny steps) —
see repro.models.ssm.ssd_chunked, which calls this kernel for the heavy part.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, y_ref, state_ref):
    # refs are (1, 1, Q, 1, ...) blocks -> squeeze to chunk-local arrays
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0, :, 0]                             # (Q,)
    da = da_ref[0, 0, :, 0]                             # (Q,) fp32 decay logs
    b = b_ref[0, 0, :, 0, :].astype(jnp.float32)        # (Q, N)
    c = c_ref[0, 0, :, 0, :].astype(jnp.float32)        # (Q, N)

    xw = x * dt[:, None]                                # dt-weighted input
    cum = jnp.cumsum(da)                                # (Q,)
    # causal decay kernel L[q, t] = exp(cum_q - cum_t), t <= q
    diff = cum[:, None] - cum[None, :]
    q_idx = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 0)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 1)
    lmat = jnp.where(t_idx <= q_idx, jnp.exp(diff), 0.0)

    scores = jax.lax.dot(c, b.T, preferred_element_type=jnp.float32)
    y = jax.lax.dot(scores * lmat, xw,
                    preferred_element_type=jnp.float32)  # (Q, P)
    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)

    decay_out = jnp.exp(cum[-1] - cum)                  # (Q,)
    state = jax.lax.dot((xw * decay_out[:, None]).T, b,
                        preferred_element_type=jnp.float32)  # (P, N)
    state_ref[0, 0, 0, :, :] = state.astype(state_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(xc: jax.Array, dtc: jax.Array, da: jax.Array, bc: jax.Array,
              cc: jax.Array, interpret: bool = False):
    """Intra-chunk SSD.

    xc (B, nc, Q, H, P), dtc (B, nc, Q, H), da (B, nc, Q, H) fp32,
    bc/cc (B, nc, Q, H, N).
    Returns (y_diag (B, nc, Q, H, P) fp32, states (B, nc, H, P, N) fp32).
    """
    B, nc, Q, H, P = xc.shape
    N = bc.shape[-1]
    grid = (B, nc, H)

    y, states = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, n, h: (b, n, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, n, h: (b, n, 0, h)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, n, h: (b, n, 0, h)),
            pl.BlockSpec((1, 1, Q, 1, N), lambda b, n, h: (b, n, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1, N), lambda b, n, h: (b, n, 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, n, h: (b, n, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda b, n, h: (b, n, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(xc, dtc.astype(jnp.float32), da, bc, cc)
    return y, states
