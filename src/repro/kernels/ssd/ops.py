"""jit'd wrapper for the SSD intra-chunk kernel (interpret on CPU)."""
from __future__ import annotations

from repro.kernels.common import on_tpu

from . import ssd as _k
from . import ref as _ref


def ssd_chunk(xc, dtc, da, bc, cc, force_interpret: bool = False):
    return _k.ssd_chunk(xc, dtc, da, bc, cc,
                        interpret=force_interpret or not on_tpu())


reference = _ref.ssd_chunk_reference
