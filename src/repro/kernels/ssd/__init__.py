from . import ops, ref
