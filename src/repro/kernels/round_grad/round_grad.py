"""Pallas TPU kernels: one-pass fused ROUND gradients.

The epoch hot loop of every strategy is the masked round gradient

    g = (w * (X beta - y)) @ X

historically computed as two full passes over X (residual, then the
weighted back-contraction).  The kernels here stream each (bm x d)
row-block of X HBM->VMEM exactly once: the block forms its residual
slice on the MXU, applies the row-weight/arrival mask (a traced
operand, so one compiled launch serves every epoch), and immediately
accumulates its d-wide contribution into a VMEM-resident f32
accumulator.  Neither the (m,) residual nor any per-client (n, d)
stack is ever materialized.

Three variants share the block template of `kernels.coded_grad`:

  * `masked_round_gradient`   — the flat hot loop: one weighted block.
  * `coded_round_gradient`    — systematic + parity blocks fused into a
    single launch (grid = sys blocks ++ parity blocks; `pl.when`
    selects which operand a step reads, index maps are clamped so the
    inactive operand's prefetch stays in range).  Per-row parity
    weights absorb the 1/(c*rho) Eq.-18 normalization, so dynamic
    parity-subsampling masks (StochasticCodedFL) ride the same launch.
  * `tier_masked_round_gradient` — the fleet layer's `tier_reduce`:
    grid (blocks, T) with the row-block resident across the inner tier
    axis, one (1, d) accumulator row per tier.  The per-tier expression
    is the flat kernel's `r * w` further scaled by the tier mask, so a
    single-tier topology stays bit-for-bit equal to the flat kernel.

Accumulation order: row-blocks accumulate sequentially in grid order
(TPU grid semantics), each block's contribution being one f32 MXU
contraction over its bm rows.  That is the SAME order for all three
variants at equal block_m, which is what the fleet layer's bit-exact
single-tier contract relies on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 1024


def _accumulate(x, y, w, beta, out_ref):
    """out += ((x @ beta - y) * w) @ x for one (bm, d) block."""
    r = jax.lax.dot_general(x, beta, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32) - y
    contrib = jax.lax.dot_general(r * w, x, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    out_ref[...] += contrib[None, :].astype(out_ref.dtype)


def _masked_kernel(x_ref, y_ref, w_ref, beta_ref, out_ref):
    """Grid step i handles rows [i*bm, (i+1)*bm)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    _accumulate(x_ref[...], y_ref[...][0], w_ref[...][0],
                beta_ref[...][0], out_ref)


def _pad_rows(x, y, w, bm):
    """Zero-pad rows to a block multiple; pad weight 0 => exact-zero
    contribution, so padding never perturbs the accumulated sum."""
    pad = (-x.shape[0]) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        y = jnp.pad(y, (0, pad))
        w = jnp.pad(w, (0, pad))
    return x, y, w


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def masked_round_gradient(x: jax.Array, y: jax.Array, w: jax.Array,
                          beta: jax.Array, block_m: int = DEFAULT_BLOCK_M,
                          interpret: bool = False) -> jax.Array:
    """g = (w * (X beta - y)) @ X with one HBM pass over X.

    x: (M, D), y/w: (M,), beta: (D,).  M is padded to a block multiple
    (padding rides at weight 0).
    """
    m, d = x.shape
    bm = min(block_m, m)
    x, y, w = _pad_rows(x, y, w, bm)
    grid = (x.shape[0] // bm,)

    out = pl.pallas_call(
        _masked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),      # stream X blocks
            pl.BlockSpec((1, bm), lambda i: (0, i)),      # y slice
            pl.BlockSpec((1, bm), lambda i: (0, i)),      # w slice
            pl.BlockSpec((1, d), lambda i: (0, 0)),       # beta resident
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (0, 0)),  # accumulator
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(x, y[None, :], w[None, :], beta[None, :])
    return out[0].astype(beta.dtype)


def _coded_kernel(nsb, xs_ref, ys_ref, ws_ref, xp_ref, yp_ref, wp_ref,
                  beta_ref, out_ref):
    """Steps [0, nsb) stream systematic blocks, [nsb, nsb+npb) parity
    blocks; both accumulate into the same (1, d) output."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    beta = beta_ref[...][0]

    @pl.when(i < nsb)
    def _sys():
        _accumulate(xs_ref[...], ys_ref[...][0], ws_ref[...][0], beta,
                    out_ref)

    @pl.when(i >= nsb)
    def _par():
        _accumulate(xp_ref[...], yp_ref[...][0], wp_ref[...][0], beta,
                    out_ref)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def coded_round_gradient(x: jax.Array, y: jax.Array, w: jax.Array,
                         x_par: jax.Array, y_par: jax.Array,
                         w_par: jax.Array, beta: jax.Array,
                         block_m: int = DEFAULT_BLOCK_M,
                         interpret: bool = False) -> jax.Array:
    """g_sys + g_par in ONE launch: the systematic and parity row
    streams share the accumulator.  The index maps of the inactive
    operand are clamped to its last block, so every prefetch is in
    range regardless of which `pl.when` branch a step takes.
    """
    m, d = x.shape
    c = x_par.shape[0]
    bm = min(block_m, max(m, c))
    x, y, w = _pad_rows(x, y, w, bm)
    x_par, y_par, w_par = _pad_rows(x_par, y_par, w_par, bm)
    nsb = x.shape[0] // bm
    npb = x_par.shape[0] // bm
    last_s = nsb - 1
    kernel = functools.partial(_coded_kernel, nsb)

    out = pl.pallas_call(
        kernel,
        grid=(nsb + npb,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (jnp.minimum(i, last_s), 0)),
            pl.BlockSpec((1, bm), lambda i: (0, jnp.minimum(i, last_s))),
            pl.BlockSpec((1, bm), lambda i: (0, jnp.minimum(i, last_s))),
            pl.BlockSpec((bm, d), lambda i: (jnp.maximum(i - nsb, 0), 0)),
            pl.BlockSpec((1, bm), lambda i: (0, jnp.maximum(i - nsb, 0))),
            pl.BlockSpec((1, bm), lambda i: (0, jnp.maximum(i - nsb, 0))),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=interpret,
    )(x, y[None, :], w[None, :],
      x_par, y_par[None, :], w_par[None, :], beta[None, :])
    return out[0].astype(beta.dtype)


def _tier_kernel(x_ref, y_ref, w_ref, masks_ref, beta_ref, out_ref):
    """Grid (i, t): row-block i scaled by tier t's mask slice into the
    t-th accumulator row.  t is the fastest axis, so the (bm, d) block
    stays VMEM-resident across all T tiers, and each output row's first
    visit is at i == 0."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[...][0] * masks_ref[...][0]
    _accumulate(x_ref[...], y_ref[...][0], w, beta_ref[...][0], out_ref)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def tier_masked_round_gradient(x: jax.Array, y: jax.Array, w: jax.Array,
                               tier_masks: jax.Array, beta: jax.Array,
                               block_m: int = DEFAULT_BLOCK_M,
                               interpret: bool = False) -> jax.Array:
    """(T, d) tier partials: partial[t] = ((w * mask_t) * (X beta - y)) @ X
    with one HBM pass over X shared by all T tiers.

    tier_masks: (T, M) row masks.  With T == 1 and mask == 1.0 the
    per-block expression is bitwise the flat masked kernel's.
    """
    m, d = x.shape
    t = tier_masks.shape[0]
    bm = min(block_m, m)
    pad = (-m) % bm
    x, y, w = _pad_rows(x, y, w, bm)
    if pad:
        tier_masks = jnp.pad(tier_masks, ((0, 0), (0, pad)))
    grid = (x.shape[0] // bm, t)

    out = pl.pallas_call(
        _tier_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, t: (i, 0)),   # block resident
            pl.BlockSpec((1, bm), lambda i, t: (0, i)),   # over inner t
            pl.BlockSpec((1, bm), lambda i, t: (0, i)),
            pl.BlockSpec((1, bm), lambda i, t: (t, i)),   # tier mask slice
            pl.BlockSpec((1, d), lambda i, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=interpret,
    )(x, y[None, :], w[None, :], tier_masks, beta[None, :])
    return out.astype(beta.dtype)
