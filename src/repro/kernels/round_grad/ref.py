"""Pure-jnp oracles for the fused round-gradient kernels.

These are the verbatim reference expressions from `core.aggregation` —
the two-pass forms the strategies used before fusion — kept as the
bit-parity oracle the interpret-mode tests compare against.
"""
import jax
import jax.numpy as jnp


def masked_round_gradient(x: jax.Array, y: jax.Array, w: jax.Array,
                          beta: jax.Array) -> jax.Array:
    """g = (w * (X beta - y)) @ X.  x: (M, D), y/w: (M,), beta: (D,)."""
    resid = x @ beta - y
    return (resid * w) @ x


def coded_round_gradient(x: jax.Array, y: jax.Array, w: jax.Array,
                         x_par: jax.Array, y_par: jax.Array,
                         w_par: jax.Array, beta: jax.Array) -> jax.Array:
    """Systematic + parity blocks, streamed as two masked gradients."""
    w_par = jnp.broadcast_to(w_par, y_par.shape)
    return masked_round_gradient(x, y, w, beta) \
        + masked_round_gradient(x_par, y_par, w_par, beta)


def tier_masked_round_gradient(x: jax.Array, y: jax.Array, w: jax.Array,
                               tier_masks: jax.Array,
                               beta: jax.Array) -> jax.Array:
    """(T, d) tier partials — `aggregation.tier_reduce` semantics."""
    contrib = (x @ beta - y) * w
    return jax.lax.map(lambda mask: (contrib * mask) @ x, tier_masks)
