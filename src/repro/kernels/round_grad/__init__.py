from . import ops, ref
