"""jit'd wrappers for the fused round-gradient kernel family.

On CPU (no TPU backend) the kernel bodies run in interpret mode — same
lowering, Python-evaluated — so correctness is validated everywhere
while the BlockSpec tiling targets TPU VMEM.  The CPU *production* hot
path does not come through here: `core.aggregation`'s dispatchers keep
the fused path on jnp expressions off-TPU (see that module).

`block_m="auto"` (the default) resolves the row tile host-side against
the persisted tuning cache (family "round_grad", shape bucket of
`(m, d)`, backend); a cold miss falls back to `DEFAULT_BLOCK_M`
bit-for-bit.  Resolution never autotunes — see `python -m repro.tune`.
All three variants resolve against the SAME family and shape so the
flat, coded and tiered launches of one workload share a row tile — the
fleet layer's single-tier bit-exact contract needs equal block_m.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import on_tpu, resolve_block

from . import ref
from . import round_grad as _k


def _resolve(x, block_m):
    return resolve_block("round_grad", (x.shape[0], x.shape[1]),
                         block_m, _k.DEFAULT_BLOCK_M)


def masked_round_gradient(x: jax.Array, y: jax.Array, w: jax.Array | None,
                          beta: jax.Array, block_m="auto",
                          force_interpret: bool = False) -> jax.Array:
    """Fused (w * (X beta - y)) @ X; w=None means unweighted."""
    if w is None:
        w = jnp.ones_like(y)
    return _k.masked_round_gradient(
        x, y, w, beta, block_m=_resolve(x, block_m),
        interpret=force_interpret or not on_tpu())


def coded_round_gradient(x: jax.Array, y: jax.Array, w: jax.Array,
                         x_par: jax.Array, y_par: jax.Array,
                         w_par: jax.Array, beta: jax.Array, block_m="auto",
                         force_interpret: bool = False) -> jax.Array:
    """Systematic + parity blocks in one launch; w_par may be a scalar
    gate (broadcast to per-row parity weights).  An empty parity block
    (c == 0) degenerates to the flat masked kernel."""
    if x_par.shape[0] == 0:
        return masked_round_gradient(x, y, w, beta, block_m=block_m,
                                     force_interpret=force_interpret)
    w_par = jnp.broadcast_to(w_par, y_par.shape).astype(y_par.dtype)
    return _k.coded_round_gradient(
        x, y, w, x_par, y_par, w_par, beta, block_m=_resolve(x, block_m),
        interpret=force_interpret or not on_tpu())


def tier_masked_round_gradient(x: jax.Array, y: jax.Array,
                               w: jax.Array | None, tier_masks: jax.Array,
                               beta: jax.Array, block_m="auto",
                               force_interpret: bool = False) -> jax.Array:
    """(T, d) tier partials with one pass over X."""
    if w is None:
        w = jnp.ones_like(y)
    return _k.tier_masked_round_gradient(
        x, y, w, tier_masks, beta, block_m=_resolve(x, block_m),
        interpret=force_interpret or not on_tpu())
