"""Pallas TPU kernels: fused weighted parity encoding P = G (W X)  (Eq. 9).

The client-side one-time encoding multiplies the private generator matrix
G (c x ell) into the weighted local dataset.  The naive form materializes
W X (an ell x d HBM round-trip); the kernel fuses the diagonal scaling into
the matmul's RHS load, so X streams HBM->VMEM once and W X never exists in
HBM.

Tiling: grid (c/bc, d/bd, ell/bl) with an fp32 VMEM accumulator per (bc, bd)
output tile; the contraction dim ell is the innermost (sequential) grid axis
so the accumulator stays resident.  Tile sizes default to MXU-aligned 128s.

Two generator sources:

  * `encode_parity`      — G is an input: sampled on the host PRNG and
    materialized in HBM once per client (the original kernel).
  * `encode_parity_prng` — G never exists in memory AT ALL: each (bc, bl)
    generator tile is (re)generated inside the kernel from the client's
    PRNG key, fused straight into the matmul.  The in-kernel generator is
    counter-based threefry2x32 — the SAME hash, counter layout, and
    bits-to-float path as `jax.random.normal` / `jax.random.rademacher`
    on a legacy uint32 key pair — so the generated G is bit-identical to
    the host-PRNG path and the variant is a drop-in replacement
    (parity-tested in interpret mode against the host path in
    `tests/test_kernels.py`).  `pltpu.prng_random_bits` was considered
    and rejected: its raw bit stream cannot be replayed on the host (so
    no parity oracle) and it has no interpret-mode implementation in this
    JAX; the threefry tile generator below is plain jnp integer math that
    lowers on TPU and interprets on CPU.  `generator_values` exposes the
    tile math as a host-callable oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (128, 128, 128)  # (bc, bd, bl)


def _kernel(g_ref, w_ref, x_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = g_ref[...]                       # (bc, bl)
    w = w_ref[...]                       # (1, bl)
    x = x_ref[...]                       # (bl, bd)
    xw = x * w[0][:, None].astype(x.dtype)   # fused diagonal scaling
    out_ref[...] += jax.lax.dot(g, xw,
                                preferred_element_type=jnp.float32
                                ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def encode_parity(g: jax.Array, w: jax.Array, x: jax.Array,
                  block: tuple[int, int, int] = DEFAULT_BLOCK,
                  interpret: bool = False) -> jax.Array:
    """P = G @ (diag(w) X).  g: (C, L), w: (L,), x: (L, D) -> (C, D)."""
    c, ell = g.shape
    ell2, d = x.shape
    assert ell == ell2 and w.shape == (ell,)
    bc, bd, bl = block
    bc, bd, bl = min(bc, c), min(bd, d), min(bl, ell)
    pc, pd, pL = (-c) % bc, (-d) % bd, (-ell) % bl
    if pc or pL:
        g = jnp.pad(g, ((0, pc), (0, pL)))
    if pL or pd:
        x = jnp.pad(x, ((0, pL), (0, pd)))
    if pL:
        w = jnp.pad(w, (0, pL))
    grid = (g.shape[0] // bc, x.shape[1] // bd, g.shape[1] // bl)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, bl), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, bl), lambda i, j, k: (0, k)),
            pl.BlockSpec((bl, bd), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bc, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((g.shape[0], x.shape[1]), jnp.float32),
        interpret=interpret,
    )(g, w[None, :], x)
    return out[:c, :d].astype(x.dtype)


# ---------------------------------------------------------------------------
# In-kernel PRNG variant: counter-based threefry generator tiles
# ---------------------------------------------------------------------------

_THREEFRY_PARITY = np.uint32(0x1BD11BDA)


def _rotl(x: jax.Array, r: int) -> jax.Array:
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def _threefry2x32(k0, k1, x0, x1):
    """The threefry-2x32 hash on uint32 arrays — identical rounds, key
    schedule, and constants to `jax._src.prng.threefry2x32` (unrolled)."""
    rot_a = (13, 15, 26, 6)
    rot_b = (17, 29, 16, 24)
    ks = (k0, k1, k0 ^ k1 ^ _THREEFRY_PARITY)

    def four_rounds(x0, x1, rots):
        for r in rots:
            x0 = x0 + x1
            x1 = _rotl(x1, r)
            x1 = x0 ^ x1
        return x0, x1

    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for i, rots in enumerate((rot_a, rot_b, rot_a, rot_b, rot_a)):
        x0, x1 = four_rounds(x0, x1, rots)
        x0 = x0 + ks[(i + 1) % 3]
        x1 = x1 + ks[(i + 2) % 3] + jnp.uint32(i + 1)
    return x0, x1


def _threefry_bits_at(k0, k1, idx: jax.Array, size: int) -> jax.Array:
    """`jax.random.bits(key, (size,))`'s uint32 stream at flat positions
    `idx` — the split-half counter pairing of `threefry_2x32(key,
    iota(size))` evaluated pointwise, so a tile of the stream costs one
    hash per element instead of materializing all `size` counters."""
    half = (size + 1) // 2
    hi_half = idx >= half
    j = jnp.where(hi_half, idx - half, idx)
    cnt1 = j + half
    if size % 2:  # odd sizes pair the last low counter with the zero pad
        cnt1 = jnp.where(cnt1 == size, 0, cnt1)
    out0, out1 = _threefry2x32(jnp.uint32(k0), jnp.uint32(k1),
                               j.astype(jnp.uint32),
                               cnt1.astype(jnp.uint32))
    return jnp.where(hi_half, out1, out0)


def _bits_to_generator(bits: jax.Array, kind: str) -> jax.Array:
    """uint32 bits -> generator entries, replaying `jax.random`'s exact
    bits-to-float path (mantissa fill in [1, 2), shift to the target
    interval) so entries match the host generator bit-for-bit."""
    one_bits = jnp.uint32(np.float32(1.0).view(np.uint32))
    float_bits = (bits >> jnp.uint32(9)) | one_bits
    floats = jax.lax.bitcast_convert_type(float_bits, jnp.float32) \
        - jnp.float32(1.0)
    if kind == "normal":
        lo = np.nextafter(np.float32(-1.0), np.float32(0.0),
                          dtype=np.float32)
        u = jnp.maximum(jnp.float32(lo),
                        floats * (jnp.float32(1.0) - jnp.float32(lo))
                        + jnp.float32(lo))
        return jnp.asarray(np.float32(np.sqrt(2))) * jax.lax.erf_inv(u)
    if kind == "bernoulli":  # rademacher: +-1 from a fair bernoulli draw
        u = jnp.maximum(jnp.float32(0.0), floats)
        return jnp.where(u < jnp.float32(0.5), jnp.float32(1.0),
                         jnp.float32(-1.0))
    raise ValueError(f"unknown generator kind: {kind}")


def generator_values(key: jax.Array, c: int, ell: int,
                     kind: str = "normal") -> jax.Array:
    """Host oracle: the full (c, ell) generator the in-kernel tiles
    produce — bit-identical to `core.encoding.generator_matrix(key, ...)`
    (enforced in tests/test_kernels.py)."""
    idx = jnp.arange(c * ell, dtype=jnp.int32).reshape(c, ell)
    bits = _threefry_bits_at(key[0], key[1], idx, c * ell)
    return _bits_to_generator(bits, kind)


def _make_prng_kernel(c: int, ell: int, kind: str, block):
    bc, _, bl = block

    def kernel(key_ref, w_ref, x_ref, out_ref):
        i = pl.program_id(0)
        k = pl.program_id(2)

        @pl.when(k == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        # global (row, col) ids of this generator tile; padded cols fold
        # into later rows' flat indices, but their weights are zero-padded
        # so the spurious entries contribute exactly 0 (padded rows are
        # sliced off the output)
        rows = i * bc + jax.lax.broadcasted_iota(jnp.int32, (bc, bl), 0)
        cols = k * bl + jax.lax.broadcasted_iota(jnp.int32, (bc, bl), 1)
        bits = _threefry_bits_at(key_ref[0, 0], key_ref[0, 1],
                                 rows * ell + cols, c * ell)
        g = _bits_to_generator(bits, kind)

        w = w_ref[...]                            # (1, bl)
        x = x_ref[...]                            # (bl, bd)
        xw = x * w[0][:, None].astype(x.dtype)    # fused diagonal scaling
        out_ref[...] += jax.lax.dot(g, xw,
                                    preferred_element_type=jnp.float32
                                    ).astype(out_ref.dtype)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("c", "kind", "block", "interpret"))
def encode_parity_prng(key: jax.Array, w: jax.Array, x: jax.Array, c: int,
                       kind: str = "normal",
                       block: tuple[int, int, int] = DEFAULT_BLOCK,
                       interpret: bool = False) -> jax.Array:
    """P = G @ (diag(w) X) with G generated INSIDE the kernel.

    key: (2,) uint32 legacy PRNG key (one client's fold of the fleet key)
    w: (L,), x: (L, D) -> (C, D)

    The (c, ell) generator block is never materialized — each grid step
    regenerates its (bc, bl) tile from the key in VMEM/registers.  Entries
    are bit-identical to `generator_matrix(key, c, ell, kind)`.
    """
    ell, d = x.shape
    assert w.shape == (ell,)
    bc, bd, bl = block
    bc, bd, bl = min(bc, c), min(bd, d), min(bl, ell)
    pd, pL = (-d) % bd, (-ell) % bl
    if pL or pd:
        x = jnp.pad(x, ((0, pL), (0, pd)))
    if pL:
        w = jnp.pad(w, (0, pL))
    c_pad = c + ((-c) % bc)
    grid = (c_pad // bc, x.shape[1] // bd, x.shape[0] // bl)

    out = pl.pallas_call(
        _make_prng_kernel(c, ell, kind, (bc, bd, bl)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, bl), lambda i, j, k: (0, k)),
            pl.BlockSpec((bl, bd), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bc, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((c_pad, x.shape[1]), jnp.float32),
        interpret=interpret,
    )(key.reshape(1, 2).astype(jnp.uint32), w[None, :], x)
    return out[:c, :d].astype(x.dtype)
