"""Pallas TPU kernel: fused weighted parity encoding P = G (W X)  (Eq. 9).

The client-side one-time encoding multiplies the private generator matrix
G (c x ell) into the weighted local dataset.  The naive form materializes
W X (an ell x d HBM round-trip); the kernel fuses the diagonal scaling into
the matmul's RHS load, so X streams HBM->VMEM once and W X never exists in
HBM.

Tiling: grid (c/bc, d/bd, ell/bl) with an fp32 VMEM accumulator per (bc, bd)
output tile; the contraction dim ell is the innermost (sequential) grid axis
so the accumulator stays resident.  Tile sizes default to MXU-aligned 128s.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (128, 128, 128)  # (bc, bd, bl)


def _kernel(g_ref, w_ref, x_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = g_ref[...]                       # (bc, bl)
    w = w_ref[...]                       # (1, bl)
    x = x_ref[...]                       # (bl, bd)
    xw = x * w[0][:, None].astype(x.dtype)   # fused diagonal scaling
    out_ref[...] += jax.lax.dot(g, xw,
                                preferred_element_type=jnp.float32
                                ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def encode_parity(g: jax.Array, w: jax.Array, x: jax.Array,
                  block: tuple[int, int, int] = DEFAULT_BLOCK,
                  interpret: bool = False) -> jax.Array:
    """P = G @ (diag(w) X).  g: (C, L), w: (L,), x: (L, D) -> (C, D)."""
    c, ell = g.shape
    ell2, d = x.shape
    assert ell == ell2 and w.shape == (ell,)
    bc, bd, bl = block
    bc, bd, bl = min(bc, c), min(bd, d), min(bl, ell)
    pc, pd, pL = (-c) % bc, (-d) % bd, (-ell) % bl
    if pc or pL:
        g = jnp.pad(g, ((0, pc), (0, pL)))
    if pL or pd:
        x = jnp.pad(x, ((0, pL), (0, pd)))
    if pL:
        w = jnp.pad(w, (0, pL))
    grid = (g.shape[0] // bc, x.shape[1] // bd, g.shape[1] // bl)

    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, bl), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, bl), lambda i, j, k: (0, k)),
            pl.BlockSpec((bl, bd), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bc, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((g.shape[0], x.shape[1]), jnp.float32),
        interpret=interpret,
    )(g, w[None, :], x)
    return out[:c, :d].astype(x.dtype)
