"""jit'd wrappers for the fused parity-encoding kernels (interpret on CPU).

Every entry point accepts `block="auto"` (the default): the tile is
resolved host-side against the persisted tuning cache
(`repro.tune.cache`, keyed by `(family, shape bucket, backend)`) before
the jitted kernel is entered; a cold miss falls back to the hard-coded
`DEFAULT_BLOCK` bit-for-bit.  Resolution never autotunes — populate the
cache with `python -m repro.tune`.

Three entry points:

  * `encode_parity` — one client's P = G (W X) with the diagonal weighting
    fused into the matmul (the original kernel).
  * `encode_fleet`  — the whole fleet's composite parity in one streamed
    pass: per client, sample the private generator G_i, fuse the Eq.-17
    weighting into the parity matmul, and accumulate into the running
    (c, d+1) composite.  The streaming itself is shared with the reference
    path (`core.encoding.encode_fleet_streamed`) so both paths draw
    identical G_i; only the per-client matmul differs (Pallas here).
  * `encode_fleet_prng` — the fleet encoder with IN-KERNEL generators: no
    client ever materializes its (c, ell) G_i — each generator tile is
    regenerated inside the kernel from the client's key via the
    counter-based threefry tiles of `encode.encode_parity_prng`, drawing
    bit-identical entries to the host-PRNG paths above (same
    `jax.random.split` layout, same bits-to-float path).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import on_tpu, resolve_block

from . import encode as _k
from . import ref as _ref


def encode_parity(g: jax.Array, w: jax.Array, x: jax.Array,
                  block="auto",
                  force_interpret: bool = False) -> jax.Array:
    block = resolve_block("encode", (g.shape[0], g.shape[1], x.shape[1]),
                          block, _k.DEFAULT_BLOCK)
    return _k.encode_parity(g, w, x, block=block,
                            interpret=force_interpret or not on_tpu())


def encode_fleet(keys: jax.Array, xs: jax.Array, ys: jax.Array,
                 weights: jax.Array, c: int, kind: str = "normal",
                 block="auto",
                 force_interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Streamed fused fleet encoding: (X~ (c, d), y~ (c,)).

    keys: (n, 2) per-client PRNG keys (same split layout as
          `core.encoding.encode_fleet`, so both paths draw identical G_i)
    xs: (n, ell, d), ys: (n, ell), weights: (n, ell)
    """
    block = resolve_block("encode", (c, xs.shape[1], xs.shape[2]),
                          block, _k.DEFAULT_BLOCK)
    return _encode_fleet_jit(keys, xs, ys, weights, c, kind, block,
                             force_interpret)


@partial(jax.jit, static_argnames=("c", "kind", "block", "force_interpret"))
def _encode_fleet_jit(keys, xs, ys, weights, c, kind, block,
                      force_interpret):
    from repro.core.encoding import encode_fleet_streamed

    return encode_fleet_streamed(
        keys, xs, ys, weights, c, kind,
        partial(encode_parity, block=block, force_interpret=force_interpret))


def encode_parity_prng(key: jax.Array, w: jax.Array, x: jax.Array, c: int,
                       kind: str = "normal", block="auto",
                       force_interpret: bool = False) -> jax.Array:
    block = resolve_block("encode_prng", (c, x.shape[0], x.shape[1]),
                          block, _k.DEFAULT_BLOCK)
    return _k.encode_parity_prng(key, w, x, c, kind=kind, block=block,
                                 interpret=force_interpret or not on_tpu())


def encode_fleet_prng(key: jax.Array, xs: jax.Array, ys: jax.Array,
                      weights: jax.Array, c: int, kind: str = "normal",
                      block="auto", force_interpret: bool = False
                      ) -> tuple[jax.Array, jax.Array]:
    """Streamed fleet encoding with in-kernel generators: (X~, y~) with NO
    (c, ell) generator block ever materialized, per client or otherwise.

    key: the fleet key — split per client exactly like
         `core.encoding.encode_fleet`, so the drawn G_i (and therefore the
         composite parity, up to matmul-tiling rounding) match the
         host-PRNG paths.
    xs: (n, ell, d), ys: (n, ell), weights: (n, ell)
    """
    block = resolve_block("encode_prng", (c, xs.shape[1], xs.shape[2]),
                          block, _k.DEFAULT_BLOCK)
    return _encode_fleet_prng_jit(key, xs, ys, weights, c, kind, block,
                                  force_interpret)


def encode_fleet_prng_keys(keys: jax.Array, xs: jax.Array, ys: jax.Array,
                           weights: jax.Array, c: int, kind: str = "normal",
                           block="auto", force_interpret: bool = False
                           ) -> tuple[jax.Array, jax.Array]:
    """As `encode_fleet_prng`, with the per-client keys precomputed.

    The tier-by-tier entry (`repro.fleet.encode_fleet_tiered`) splits the
    fleet key ONCE and slices the (n, 2) key table per tier, so every
    client draws exactly the G_i it would draw in the flat streamed pass
    — a single all-client tier is bit-identical to `encode_fleet_prng`.
    """
    block = resolve_block("encode_prng", (c, xs.shape[1], xs.shape[2]),
                          block, _k.DEFAULT_BLOCK)
    return _encode_fleet_prng_keys_jit(keys, xs, ys, weights, c, kind,
                                       block, force_interpret)


@partial(jax.jit, static_argnames=("c", "kind", "block", "force_interpret"))
def _encode_fleet_prng_keys_jit(keys, xs, ys, weights, c, kind, block,
                                force_interpret):
    n, ell, d = xs.shape
    xa = jnp.concatenate([xs, ys[..., None]], axis=-1)  # labels ride along

    def one(acc, inp):
        k, x, w = inp
        p = encode_parity_prng(k, w, x, c, kind=kind, block=block,
                               force_interpret=force_interpret)
        return acc + p, None

    acc, _ = jax.lax.scan(one, jnp.zeros((c, d + 1), dtype=xs.dtype),
                          (keys, xa, weights))
    return acc[:, :d], acc[:, d]


@partial(jax.jit, static_argnames=("c", "kind", "block", "force_interpret"))
def _encode_fleet_prng_jit(key, xs, ys, weights, c, kind, block,
                           force_interpret):
    keys = jax.random.split(key, xs.shape[0])
    return _encode_fleet_prng_keys_jit(keys, xs, ys, weights, c, kind,
                                       block, force_interpret)


generator_values = _k.generator_values
reference = _ref.encode_parity
reference_fleet = _ref.encode_fleet
