"""jit'd wrapper for the fused parity-encoding kernel (interpret on CPU)."""
from __future__ import annotations

import jax

from . import encode as _k
from . import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def encode_parity(g: jax.Array, w: jax.Array, x: jax.Array,
                  block=_k.DEFAULT_BLOCK,
                  force_interpret: bool = False) -> jax.Array:
    return _k.encode_parity(g, w, x, block=block,
                            interpret=force_interpret or not _on_tpu())


reference = _ref.encode_parity
