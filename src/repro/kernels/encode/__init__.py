from . import ops, ref
