"""Pure-jnp oracles for the fused weighted parity encoding."""
import jax
import jax.numpy as jnp


def encode_parity(g: jax.Array, w: jax.Array, x: jax.Array) -> jax.Array:
    """P = G @ (diag(w) X).  g: (C, L), w: (L,), x: (L, D) -> (C, D)."""
    return g @ (w[:, None] * x)


def encode_fleet(gs: jax.Array, ws: jax.Array, xs: jax.Array,
                 ys: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Composite parity from an EXPLICIT generator stack (test oracle only).

    gs: (n, c, ell), ws: (n, ell), xs: (n, ell, d), ys: (n, ell)
    -> (X~ (c, d), y~ (c,)) = (sum_i G_i W_i X_i, sum_i G_i W_i y_i)
    """
    xp = jnp.einsum("ncl,nl,nld->cd", gs, ws, xs)
    yp = jnp.einsum("ncl,nl,nl->c", gs, ws, ys)
    return xp, yp
