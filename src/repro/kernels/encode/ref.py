"""Pure-jnp oracle for the fused weighted parity encoding."""
import jax
import jax.numpy as jnp


def encode_parity(g: jax.Array, w: jax.Array, x: jax.Array) -> jax.Array:
    """P = G @ (diag(w) X).  g: (C, L), w: (L,), x: (L, D) -> (C, D)."""
    return g @ (w[:, None] * x)
