"""Two-stage tier aggregation, numerically equal to flat aggregation.

The flat path every built-in strategy runs is one masked contraction

    g = (contrib * w) @ x                      # (m,) * (m,) @ (m, d)

over the client-major row axis.  The hierarchical path computes the SAME
contraction per tier with one-hot row masks and then combines tiers:

    g_t = (contrib * w * mask_t) @ x           # tier partial, full width
    g   = sum_t g_t                            # cross-tier combine

Because `mask_t` is exactly 0.0/1.0, every masked-out row contributes an
exact ±0.0 term, and the per-row accumulation ORDER of the contraction is
unchanged — each tier partial equals the flat contraction with the other
tiers' terms replaced by zeros.  The only reassociation the hierarchy
introduces is the final T-term outer sum, so:

  * a single-tier topology is bit-for-bit identical to the flat path;
  * a T-tier topology differs from flat by at most the reassociation of
    T partial sums (documented-ulp; see tests/test_fleet.py).

The implementations live in `repro.core.aggregation` so strategy modules
can reach them without importing this package (`repro.fleet.__init__`
pulls in the api layer); this module is the fleet-facing surface.
"""
from repro.core.aggregation import cross_tier_combine, tier_reduce

__all__ = ["tier_reduce", "cross_tier_combine"]
