"""Fleet-scale streamed parity encoding, tier by tier.

Each edge tier streams its OWN partial composite parity through the
in-kernel-PRNG Pallas path (`kernels.encode.ops.encode_fleet_prng_keys`):
no client's (c, ell) generator block ever materializes — generator tiles
are regenerated inside the kernel from the client's key via counter-based
threefry — and no single pass ever holds more than one tier's client
shards.  The cloud then combines the T tier partials.

Key layout: the fleet key is split ONCE into the (n, 2) per-client key
table (exactly `core.encoding.encode_fleet`'s layout) and each tier
slices its members' rows, so every client draws the same G_i it would in
the flat pass regardless of the tier partition.  Consequences:

  * a single all-client tier is bit-for-bit identical to
    `encode_fleet_prng(key, ...)` (same scan, same order);
  * a T-tier partition reassociates only the cross-client accumulation
    (per-tier partial sums + a T-term combine), mirroring the
    tier-aggregation ulp contract of `fleet.aggregate`.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.encode import ops as encode_ops

from .topology import FleetTopology


def encode_fleet_tiered(key: jax.Array, xs: jax.Array, ys: jax.Array,
                        weights: jax.Array, c: int,
                        topology: FleetTopology, kind: str = "normal",
                        block="auto", force_interpret: bool = False
                        ) -> Tuple[jax.Array, jax.Array]:
    """Composite parity (X~ (c, d), y~ (c,)), encoded tier by tier.

    key:      the fleet key (split per client internally — see module
              docstring for the layout contract)
    xs: (n, ell, d), ys: (n, ell), weights: (n, ell)
    c:        parity rows
    topology: tier partition; members stream in ascending client order
              within each tier
    """
    if topology.n != xs.shape[0]:
        raise ValueError(
            f"topology covers {topology.n} clients but xs has "
            f"{xs.shape[0]}")
    keys = jax.random.split(key, topology.n)
    x_par = y_par = None
    for members in topology.tier_members():
        idx = jnp.asarray(members)
        x_t, y_t = encode_ops.encode_fleet_prng_keys(
            keys[idx], xs[idx], ys[idx], weights[idx], c, kind=kind,
            block=block, force_interpret=force_interpret)
        if x_par is None:
            x_par, y_par = x_t, y_t
        else:  # cross-tier combine: the only reassociation vs the flat pass
            x_par, y_par = x_par + x_t, y_par + y_t
    return x_par, y_par
