"""Fleet topology: clients grouped under edge tiers, MEC-style.

The contract (documented in API.md "The fleet layer"):

  * `tier_of[i]` is the edge tier client `i` reports to; tier ids are
    dense in `[0, n_tiers)` and every tier is non-empty.
  * `sample_frac[t]` is the probability that a tier-`t` client
    participates in any given round.  Participation gates are
    inverse-probability weighted (`indicator / sample_frac`), so the
    tier-reduced gradient stays an unbiased estimate of the full
    aggregate — exactly `StochasticCodedFL`'s rho-weighting, applied per
    client instead of per parity row.
  * `sample_frac == 1` everywhere draws NO extra randomness (the gates
    are constant 1.0), which is what keeps the degenerate hierarchical
    run on the same generator stream as its flat base strategy.

Topologies are host-side metadata: nothing here touches jax.
"""
from __future__ import annotations

import dataclasses
from typing import Hashable, List

import numpy as np


@dataclasses.dataclass(frozen=True)
class FleetTopology:
    """Tier assignment + per-tier participation for `n` clients.

    tier_of:     (n,) int32 tier id per client, dense in [0, n_tiers)
    sample_frac: (n_tiers,) per-round participation probability in (0, 1]
    """

    tier_of: np.ndarray
    sample_frac: np.ndarray

    def __post_init__(self):
        tier_of = np.asarray(self.tier_of, dtype=np.int32)
        frac = np.atleast_1d(np.asarray(self.sample_frac, dtype=np.float64))
        object.__setattr__(self, "tier_of", tier_of)
        object.__setattr__(self, "sample_frac", frac)
        if tier_of.ndim != 1 or tier_of.size == 0:
            raise ValueError("tier_of must be a non-empty (n,) vector")
        n_tiers = frac.shape[0]
        if tier_of.min() < 0 or tier_of.max() >= n_tiers:
            raise ValueError(
                f"tier ids must be dense in [0, {n_tiers}); got range "
                f"[{tier_of.min()}, {tier_of.max()}]")
        sizes = np.bincount(tier_of, minlength=n_tiers)
        if np.any(sizes == 0):
            raise ValueError(
                f"every tier must own at least one client; empty tiers: "
                f"{np.flatnonzero(sizes == 0).tolist()}")
        if np.any(frac <= 0.0) or np.any(frac > 1.0):
            raise ValueError(
                f"sample_frac must be in (0, 1] per tier, got {frac}")

    # -- structure ----------------------------------------------------------

    @property
    def n(self) -> int:
        return int(self.tier_of.shape[0])

    @property
    def n_tiers(self) -> int:
        return int(self.sample_frac.shape[0])

    @property
    def subsampled(self) -> bool:
        """True iff any tier participates at less than full strength."""
        return bool(np.any(self.sample_frac < 1.0))

    def tier_sizes(self) -> np.ndarray:
        return np.bincount(self.tier_of, minlength=self.n_tiers)

    def tier_members(self) -> List[np.ndarray]:
        """Client indices per tier, in ascending client order."""
        order = np.argsort(self.tier_of, kind="stable")
        return np.split(order, np.cumsum(self.tier_sizes())[:-1])

    def structure_key(self) -> Hashable:
        """Hashable digest of the tier STRUCTURE (not the participation
        values — those only gate operand values, never the trace)."""
        return (self.n, self.n_tiers)

    # -- constructors -------------------------------------------------------

    @classmethod
    def uniform(cls, n: int, n_tiers: int,
                sample_frac: float = 1.0) -> "FleetTopology":
        """Contiguous equal-size tiers (the MEC cell layout: clients are
        assigned to the geographically nearest edge node, block by block)."""
        if not (1 <= n_tiers <= n):
            raise ValueError(f"need 1 <= n_tiers <= n, got {n_tiers}, {n}")
        tier_of = (np.arange(n) * n_tiers) // n
        return cls(tier_of=tier_of.astype(np.int32),
                   sample_frac=np.full(n_tiers, float(sample_frac)))

    @classmethod
    def from_assignment(cls, tier_of: np.ndarray,
                        sample_frac=1.0) -> "FleetTopology":
        """Arbitrary (e.g. permuted) assignment; scalar `sample_frac`
        broadcasts over tiers."""
        tier_of = np.asarray(tier_of, dtype=np.int32)
        n_tiers = int(tier_of.max()) + 1 if tier_of.size else 0
        frac = np.broadcast_to(
            np.asarray(sample_frac, dtype=np.float64), (n_tiers,)).copy()
        return cls(tier_of=tier_of, sample_frac=frac)

    def with_round_budget(self, budget: int) -> "FleetTopology":
        """Cap the EXPECTED participants per round at `budget` clients.

        Per-tier `sample_frac` = min(1, budget / n), so the expected round
        cost is O(budget) however large the fleet grows — the sublinearity
        knob `benchmarks/perf_fleet.py` gates.
        """
        if budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        frac = min(1.0, float(budget) / float(self.n))
        return dataclasses.replace(
            self, sample_frac=np.full(self.n_tiers, frac))

    # -- per-round gates ----------------------------------------------------

    def tier_masks(self, ell: int) -> np.ndarray:
        """(n_tiers, n*ell) float32 one-hot row masks over the flat
        client-major (m,) layout every built-in strategy uses."""
        row_tier = np.repeat(self.tier_of, ell)
        return (np.arange(self.n_tiers)[:, None]
                == row_tier[None, :]).astype(np.float32)

    def sample_gates(self, epochs: int,
                     rng: np.random.Generator) -> np.ndarray:
        """(epochs, n) inverse-probability participation gates.

        gate[e, i] = 1{client i participates in round e} / sample_frac of
        its tier — `E[gate] == 1` per client, so gated tier reduction is
        unbiased.  All-ones (and NO generator draws) when every tier has
        `sample_frac == 1`, keeping the degenerate case on the base
        strategy's exact stream.
        """
        if not self.subsampled:
            return np.ones((epochs, self.n), dtype=np.float32)
        frac = self.sample_frac[self.tier_of]                    # (n,)
        draws = rng.random((epochs, self.n))
        return np.asarray((draws < frac) / frac, dtype=np.float32)
