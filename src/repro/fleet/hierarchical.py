"""`HierarchicalCFL` — the two-stage edge→cloud wrapper strategy.

Wraps ANY strategy implementing the `tiered_contributions` hook (all five
built-ins do) and runs its gradient round hierarchically over a
`FleetTopology`:

  1. **edge stage** — per-tier weighted reduce (`core.aggregation.
     tier_reduce`): each edge node computes its tier's partial as the
     full-width masked contraction, so the partial equals the flat
     contraction restricted to that tier bit-for-bit;
  2. **cloud stage** — `cross_tier_combine` sums the T tier partials (the
     only reassociation the hierarchy introduces) and adds the wrapped
     strategy's server-side term (parity gradients live at the server and
     never traverse an edge tier).

Per-round client subsampling rides on the same path: the topology's
inverse-probability gates (`FleetTopology.sample_gates`, the
`StochasticCodedFL` rho-weighting applied per client) multiply into the
tier masks, so a subsampled round's aggregate stays an unbiased estimate
of the full one and `sample_frac == 1` degenerates to the ungated masks
bit-for-bit — with NO extra generator draws, keeping the degenerate run
on the base strategy's exact arrival stream.

The wrapper is a first-class `Strategy`: it runs through `Session`,
`run_sweep` (lanes bucket by the BASE strategy's full static structure
plus the tier structure — see `engine_key`), `plan_sweep` (the base's
batched-planning hooks are forwarded when present) and the serving
engine.  Construct directly or via
`make_strategy("hierarchical", base=..., topology=...)`.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, ClassVar, Dict, Hashable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.session import _static_strategy_key
from repro.api.strategy import EpochSchedule, TrainData
from repro.core.aggregation import cross_tier_combine

from .topology import FleetTopology

if TYPE_CHECKING:  # annotation-only: keeps fleet free of sim imports
    from repro.sim.network import FleetSpec


@dataclasses.dataclass
class HierState:
    """The wrapped strategy's state plus the (validated) topology."""

    base: Any
    topology: FleetTopology


# Optional hooks forwarded verbatim to the wrapped strategy WHEN it has
# them, so `hasattr` on the wrapper mirrors `hasattr` on the base — the
# capability check `api.plan_sweep` keys on.  (`plan_with` is a real
# method below: it must re-wrap the base state in a HierState.)
_FORWARDED = frozenset({"plan_request", "redundancy_plan"})


@dataclasses.dataclass(frozen=True)
class HierarchicalCFL:
    """Hierarchical edge→cloud wrapper around any tiered-capable strategy.

    base:     the wrapped strategy; must implement `tiered_contributions`
    topology: tier assignment + per-tier participation (`FleetTopology`)
    label:    display label (default: "hier[<base label>]")
    """

    base: Any
    topology: FleetTopology
    label: str = ""

    # the wrapper adds no primitive knobs of its own; its static identity
    # (base structure + tier structure) is carried by `engine_key`
    engine_value_fields: ClassVar[frozenset] = frozenset()

    def __post_init__(self):
        if not hasattr(self.base, "tiered_contributions"):
            raise TypeError(
                f"{type(self.base).__name__} does not implement the "
                "tiered_contributions hook and cannot run hierarchically "
                "(see the Strategy optional-hooks contract)")
        if not isinstance(self.topology, FleetTopology):
            raise TypeError(
                f"topology must be a FleetTopology, got "
                f"{type(self.topology).__name__}")
        if not self.label:
            object.__setattr__(self, "label", f"hier[{self.base.label}]")

    def __getattr__(self, name: str):
        if name in _FORWARDED:
            return getattr(object.__getattribute__(self, "base"), name)
        raise AttributeError(
            f"{type(self).__name__!r} object has no attribute {name!r}")

    # -- planning -----------------------------------------------------------

    def _check_fleet(self, n: int) -> None:
        if self.topology.n != n:
            raise ValueError(
                f"topology covers {self.topology.n} clients but the fleet "
                f"has {n}")

    def plan(self, fleet: "FleetSpec", data: TrainData) -> HierState:
        self._check_fleet(data.n)
        return HierState(base=self.base.plan(fleet, data),
                         topology=self.topology)

    def plan_with(self, fleet: "FleetSpec", data: TrainData,
                  plan) -> HierState:
        """Batched-planning hook: wrap the base's pre-solved state."""
        self._check_fleet(data.n)
        return HierState(base=self.base.plan_with(fleet, data, plan),
                         topology=self.topology)

    # -- epoch sampling -----------------------------------------------------

    def sample_epochs(self, state: HierState, fleet: "FleetSpec",
                      epochs: int, rng: np.random.Generator) -> EpochSchedule:
        """Base draws FIRST, then the participation gates — so at
        `sample_frac == 1` (no gate draws) the generator stream is the
        base strategy's exactly.

        Durations remain the base's: subsampling shortens realized rounds
        (fewer stragglers), so reported wall clock is conservative; the
        O(participants) scheduling path is `repro.fleet.sample_tier_rounds`.
        """
        sched = self.base.sample_epochs(state.base, fleet, epochs, rng)
        arrivals = dict(sched.arrivals)
        arrivals["tier_gate"] = state.topology.sample_gates(epochs, rng)
        return dataclasses.replace(sched, arrivals=arrivals)

    def sweep_inputs(self, state: HierState, fleet: "FleetSpec",
                     epochs: int, rng: np.random.Generator) -> EpochSchedule:
        """One sweep lane's inputs: the base lane tensors plus the
        `(epochs, n)` gate tensor (which stacks across lanes sharing the
        fleet size); draws are exactly `sample_epochs`."""
        sample = getattr(self.base, "sweep_inputs", self.base.sample_epochs)
        sched = sample(state.base, fleet, epochs, rng)
        arrivals = dict(sched.arrivals)
        arrivals["tier_gate"] = state.topology.sample_gates(epochs, rng)
        return dataclasses.replace(sched, arrivals=arrivals)

    # -- engine hooks -------------------------------------------------------

    @property
    def data_device_keys(self) -> frozenset:
        """The base's data-pure operands plus the wrapper's row→client
        index (pure function of the data shape).  `tier_masks` is
        topology-derived and stays per-lane."""
        base_keys = getattr(self.base, "data_device_keys", frozenset())
        return frozenset(base_keys) | {"hier_row_client"}

    def device_state(self, state: HierState,
                     data: TrainData) -> Dict[str, jax.Array]:
        dev = dict(self.base.device_state(state.base, data))
        topo = state.topology
        dev["tier_masks"] = jnp.asarray(topo.tier_masks(data.ell),
                                        dtype=data.xs.dtype)
        dev["hier_row_client"] = jnp.repeat(
            jnp.arange(data.n, dtype=jnp.int32), data.ell)
        return dev

    def round_contributions(self, state: HierState,
                            dev: Dict[str, jax.Array], beta: jax.Array,
                            arrivals: Dict[str, jax.Array]) -> jax.Array:
        # fold the per-client IP gates into the tier masks (exact identity
        # at sample_frac == 1: every gate is literally 1.0), then run the
        # base's tiered round and combine edge partials at the cloud
        gate = arrivals["tier_gate"][dev["hier_row_client"]]      # (m,)
        masks = dev["tier_masks"] * gate[None, :]                 # (T, m)
        partials, server = self.base.tiered_contributions(
            state.base, dev, beta, arrivals, masks)
        out = cross_tier_combine(partials)
        if server is not None:
            out = out + server
        return out

    def engine_key(self, state: HierState) -> Hashable:
        """The wrapper's own fields are non-primitive, so the module-level
        static key only sees the class — push the BASE's full static
        structure (plus its own engine key and the tier structure) here so
        hierarchies over different bases / tier counts never share a
        compiled engine."""
        return ("hier", _static_strategy_key(self.base),
                self.base.engine_key(state.base),
                self.topology.structure_key())

    def uplink_bits(self, state: HierState, fleet: "FleetSpec",
                    epochs: int) -> float:
        return self.base.uplink_bits(state.base, fleet, epochs)

    # -- optional hooks that re-wrap state ----------------------------------

    def serve_convergence(self, state: HierState, criterion):
        hook = getattr(self.base, "serve_convergence", None)
        return criterion if hook is None else hook(state.base, criterion)

    def report_extras(self, state: HierState) -> Dict[str, Any]:
        extras_fn = getattr(self.base, "report_extras", None)
        extras = dict(extras_fn(state.base)) if extras_fn is not None else {}
        topo = state.topology
        extras["n_tiers"] = int(topo.n_tiers)
        extras["tier_sample_frac_min"] = float(topo.sample_frac.min())
        extras["expected_participants"] = float(
            np.sum(topo.sample_frac[topo.tier_of]))
        return extras
