"""Fleet-scale round scheduling: O(participants) per round.

The epoch engine pre-samples a DENSE (epochs, n) arrival tensor — the
right substrate for training traces, but linear in fleet size per round.
A production scheduler over 1e5+ clients with per-tier subsampling only
ever touches the sampled participants: `sample_tier_rounds` draws, per
round and per tier, a Binomial participant count, picks that many member
indices, and samples delays for THOSE devices only — so the per-round
cost is O(expected participants), independent of n.  This is the
sublinearity `benchmarks/perf_fleet.py` gates (wall time at a fixed
round budget growing far slower than the fleet).

Semantics notes (this is the scheduling/wall-clock path, not the
gradient path — the training engine's unbiased IP-weighted gates live in
`FleetTopology.sample_gates`):

  * participant indices are drawn WITH replacement within a tier
    (duplicates collapse; at sample_frac << 1 collisions are rare) —
    that is what keeps selection O(k) instead of O(n_tier);
  * a tier with sample_frac == 1 always includes all members;
  * round duration = max over tiers of the tier's straggler maximum
    (each edge node waits for its own slowest sampled client; the cloud
    waits for the slowest edge node).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.delay_model import DeviceDelayParams, sample_total

from .topology import FleetTopology


@dataclasses.dataclass(frozen=True)
class TierRoundStats:
    """Per-round scheduling statistics for a hierarchical fleet.

    durations:    (epochs,) round wall time (slowest tier's straggler)
    tier_max:     (epochs, T) per-tier straggler maximum (0 where a tier
                  sampled no participants)
    participants: (epochs, T) sampled participant counts per tier
    """

    durations: np.ndarray
    tier_max: np.ndarray
    participants: np.ndarray

    @property
    def total_participants(self) -> int:
        return int(self.participants.sum())


def sample_tier_rounds(topology: FleetTopology, edge: DeviceDelayParams,
                       loads: np.ndarray, epochs: int,
                       rng: np.random.Generator) -> TierRoundStats:
    """Sample `epochs` hierarchical rounds at O(participants) cost.

    topology: tier partition + per-tier sample_frac
    edge:     (n,) device delay parameters
    loads:    (n,) per-device assigned loads (e.g. `RedundancyPlan.loads`)
    """
    if edge.n != topology.n:
        raise ValueError(
            f"topology covers {topology.n} clients but edge params "
            f"describe {edge.n}")
    loads = np.asarray(loads)
    if loads.shape != (topology.n,):
        raise ValueError(
            f"loads must have shape ({topology.n},), got {loads.shape}")

    members = topology.tier_members()
    n_tiers = topology.n_tiers
    tier_max = np.zeros((epochs, n_tiers))
    participants = np.zeros((epochs, n_tiers), dtype=np.int64)

    for e in range(epochs):
        for t, mem in enumerate(members):
            frac = float(topology.sample_frac[t])
            if frac >= 1.0:
                idx = mem
            else:
                k = int(rng.binomial(mem.size, frac))
                if k == 0:
                    continue
                # with-replacement pick keeps selection O(k), not O(n_tier)
                idx = mem[rng.integers(0, mem.size, size=k)]
            sub = DeviceDelayParams(a=edge.a[idx], mu=edge.mu[idx],
                                    tau=edge.tau[idx], p=edge.p[idx])
            delays = sample_total(sub, loads[idx], rng)
            tier_max[e, t] = float(delays.max(initial=0.0))
            participants[e, t] = idx.size

    return TierRoundStats(durations=tier_max.max(axis=1),
                          tier_max=tier_max, participants=participants)
