"""`repro.fleet` — hierarchical edge→cloud aggregation at production scale.

The paper trains n = 24 devices against one server; the MEC follow-ups
(CodedFedL, arXiv:2007.03273; low-latency wireless CFL, arXiv:2011.06223)
organize production fleets into edge→cloud TIERS: each edge node
aggregates its clients' contributions before the central server combines
tiers.  This subsystem makes that topology first class and scales every
planning/encoding/scheduling path to 1e5+ clients:

  * `FleetTopology` — the tier assignment plus per-tier participation
    probabilities (`sample_frac`), with inverse-probability gate weights
    so subsampled rounds stay unbiased (the `StochasticCodedFL`
    rho-weighting applied per client instead of per parity row).
  * `HierarchicalCFL` — a `Strategy` wrapper turning ANY strategy that
    implements the `tiered_contributions` hook (all five built-ins do)
    into its two-stage hierarchical counterpart: per-tier weighted
    reduce, then cross-tier combine.  Runs unchanged through `Session`,
    `run_sweep` and the serving engine.
  * `solve_fleet` — the redundancy solve for fleets too large for the
    batched planner's one-device `(t_grid, n, L)` tensor: the device
    axis is sharded over the local mesh (`launch.mesh.make_shard_mesh`)
    and chunk-streamed per shard, so a 1e5-client plan solves without
    ever materializing the full expected-return tensor.
  * `encode_fleet_tiered` — composite-parity encoding routed tier by
    tier through the in-kernel-PRNG Pallas path (`encode_fleet_prng`):
    no generator block ever materializes, and each edge tier streams its
    own partial composite before the cross-tier combine.
  * `sample_tier_rounds` — fleet-scale round scheduling: per-epoch
    participant draws and per-tier straggler maxima at O(participants)
    cost, which is what makes subsampled round cost sublinear in n.

Benchmarked/gated by `benchmarks/perf_fleet.py` → `BENCH_plan_scale.json`.
"""
from .aggregate import cross_tier_combine, tier_reduce
from .encode import encode_fleet_tiered
from .hierarchical import HierarchicalCFL, HierState
from .plan import solve_fleet
from .rounds import TierRoundStats, sample_tier_rounds
from .topology import FleetTopology

__all__ = [
    "FleetTopology",
    "HierarchicalCFL",
    "HierState",
    "solve_fleet",
    "encode_fleet_tiered",
    "tier_reduce",
    "cross_tier_combine",
    "sample_tier_rounds",
    "TierRoundStats",
]
