"""`solve_fleet` — the redundancy solve at fleet scale (1e5+ clients).

`repro.plan.solve_redundancy_batched` evaluates the full `(t_grid, n, L)`
expected-return tensor on ONE device per deadline probe; at n = 1e5 that
tensor (and its K-term retransmission mixture) no longer fits a sane
working set.  This module solves the SAME problem (identical per-device
expressions, identical monotone grid refinement) with the device axis

  * SHARDED over the local mesh (`launch.mesh.make_shard_mesh`): each
    shard owns n/D devices and `lax.psum` reassembles the aggregate best
    return, and
  * CHUNK-STREAMED within each shard: a `lax.scan` over fixed-size device
    chunks evaluates `(t_grid, chunk, L)` slabs, so peak memory is
    O(t_grid * chunk * L) per device regardless of n.

Invariants vs the batched solver (asserted by tests/test_fleet.py):

  * per-device expected returns are evaluated by the SAME expressions in
    the same float64 dtype (no float32 scout at fleet scale — the scout's
    saturation pathology is exactly what giant fleets hit);
  * the chosen loads are each device's independent argmax at t*, so they
    match the batched solver's loads exactly whenever t* agrees;
  * the aggregate return is reassociated (chunk partial sums + a psum
    tree instead of one flat sum), so t* may differ from the batched
    solver by the grid-refinement tolerance — NOT bit-for-bit.  Padded
    devices carry cap 0 and contribute exactly 0.0, as in the batched
    solver.

The load axis is bucketed to a power of two (floor 8) instead of the
batched solver's 64-wide bucket: fleet-scale clients hold small shards
(the whole point of coding over many weak devices), so a tight L keeps
the slab small.  `srv_weight` and `edge_chunks` behave exactly as in
`PlanRequest`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.delay_model import total_cdf
from repro.core.redundancy import RedundancyPlan
from repro.plan.solver import (GRID_POINTS, MAX_DOUBLINGS, MAX_ROUNDS,
                               PlanRequest, _k_terms)

# Device-chunk length of the streamed evaluation: one slab is
# (GRID_POINTS, CHUNK, L) float64.
CHUNK = 4096


def _pow2_bucket(value: int, floor: int = 8) -> int:
    out = floor
    while out < value:
        out *= 2
    return out


@functools.partial(jax.jit,
                   static_argnames=("edge_chunks", "n_shards", "chunk"))
def _solve_fleet_grid(a, mu, tau, p, caps, srv_a, srv_mu, srv_w, srv_cap,
                      target, t_hi0, eps_rel, ell_e, ell_s, ks, frac, *,
                      edge_chunks=1, n_shards=1, chunk=CHUNK):
    """Sharded single-request grid solve.  All inputs float64.

    a/mu/tau/p/caps: (n_pad,) edge params, n_pad = n_shards * k * chunk
    srv_*/target/t_hi0/eps_rel: scalars   ell_e: (L,)  ell_s: (Ls,)
    ks: (K,) retransmission counts        frac: (T,) refinement fractions

    Returns (t_star, loads (n_pad,), s_load, agg, feasible).  The
    per-device expressions mirror `repro.plan.solver._solve_grid` term for
    term (shifted-exp CDF, negative-binomial mixture with the pmf_total
    saturation snap, partial-return chunking) — only the reduction over
    devices is restructured into chunk partials + a psum tree.
    """
    from repro.launch.mesh import make_shard_mesh

    mesh = make_shard_mesh()
    dtype = a.dtype
    n_k = ks.shape[0]
    snap_tol = 1e-13

    s_ok = ell_s <= srv_cap                                      # (Ls,)

    def _shifted_exp_cdf(gamma_, s_):
        return jnp.where(
            s_ > 0.0,
            -jnp.expm1(-jnp.minimum(gamma_ * jnp.maximum(s_, 0.0), 700.0)),
            0.0)

    def server_returns(t):
        """Weighted server E[R(t; ell)].  t: (T',) -> (T', Ls)."""
        s = t[:, None] - ell_s[None, :] * srv_a
        cdf = _shifted_exp_cdf(srv_mu / jnp.maximum(ell_s, 1.0), s)
        cdf = jnp.where(ell_s > 0.0, cdf, (t[:, None] >= 0.0).astype(dtype))
        return jnp.where(s_ok[None, :], srv_w * ell_s * cdf, -jnp.inf)

    def chunk_returns(t, prm):
        """One device chunk's masked return grid.  t: (T',) ->
        (T', chunk, L) — the streamed slab of the batched solver's
        (t_grid, n, L) tensor."""
        a_c, mu_c, tau_c, p_c, caps_c = prm                     # (chunk,)
        shift = ell_e[None, :] * a_c[:, None]                   # (chunk, L)
        gamma = mu_c[:, None] / jnp.maximum(ell_e, 1.0)         # (chunk, L)
        load_ok = ell_e[None, :] <= caps_c[:, None]             # (chunk, L)
        has_comm = tau_c > 0.0                                  # (chunk,)
        pmf = (ks - 1.0) * p_c[:, None] ** (ks - 2.0) \
            * (1.0 - p_c[:, None]) ** 2                         # (chunk, K)
        pmf_total = jax.lax.fori_loop(
            0, n_k, lambda i, acc: acc + pmf[:, i],
            jnp.zeros_like(a_c))                                # (chunk,)
        snap_ok = pmf_total >= 1.0 - snap_tol

        def _load_cdf(t_res):
            """(T', chunk) residual times -> (T', chunk, L) per-load CDF."""
            if edge_chunks == 1:
                s = t_res[..., None] - shift[None]
                cdf = _shifted_exp_cdf(gamma[None], s)
            else:
                def add_q(j, acc):
                    fq = (jnp.asarray(j, dtype) + 1.0) / edge_chunks
                    s = t_res[..., None] - fq * shift[None]
                    return acc + _shifted_exp_cdf(gamma[None], s)
                cdf = jax.lax.fori_loop(
                    0, edge_chunks, add_q,
                    jnp.zeros(t_res.shape + (ell_e.shape[0],), dtype=dtype))
                cdf = cdf / edge_chunks
            return jnp.where(ell_e > 0.0, cdf,
                             (t_res[..., None] >= 0.0).astype(dtype))

        def add_k(i, acc):
            t_res = t[:, None] - ks[i] * tau_c[None, :]         # (T', chunk)
            return acc + pmf[None, :, i, None] * _load_cdf(t_res)

        mix = jax.lax.fori_loop(
            0, n_k, add_k,
            jnp.zeros(t.shape + (a_c.shape[0], ell_e.shape[0]),
                      dtype=dtype))
        mix = jnp.where(
            jnp.logical_and(mix >= pmf_total[None, :, None],
                            snap_ok[None, :, None]),
            jnp.ones((), dtype=dtype), mix)
        nocomm = _load_cdf(jnp.broadcast_to(t[:, None],
                                            t.shape + (a_c.shape[0],)))
        mix = jnp.where(has_comm[None, :, None], mix, nocomm)
        return jnp.where(load_ok[None], ell_e * mix, -jnp.inf)

    def solve(a_l, mu_l, tau_l, p_l, caps_l):
        """Per-shard body: full search over replicated control flow, with
        chunk-streamed local evaluation and psum'd aggregates."""
        # (n_chunks, 5, chunk): one scan step consumes one device chunk's
        # five parameter rows
        prm_stack = jnp.stack(
            [x.reshape(-1, chunk)
             for x in (a_l, mu_l, tau_l, p_l, caps_l)], axis=1)

        def local_best_sum(t):
            """Sum over this shard's devices of max-over-L return: (T',)."""
            def step(acc, prm_c):
                ev = chunk_returns(t, tuple(prm_c))     # (T', chunk, L)
                return acc + ev.max(axis=-1).sum(axis=-1), None
            out, _ = jax.lax.scan(step, jnp.zeros_like(t), prm_stack)
            return out

        def best_agg(t):
            """(T',) aggregate best return across the whole fleet."""
            edge = jax.lax.psum(local_best_sum(t), "shards")
            return edge + server_returns(t).max(axis=-1)

        # --- bracket expansion (scalar mirror of _solve_grid._search) ------
        agg0 = best_agg(t_hi0[None])[0]

        def b_cond(st):
            _, _, agg_c, i = st
            return jnp.logical_and(i < MAX_DOUBLINGS, agg_c < target)

        def b_body(st):
            t_hi_c, step, _, i = st
            t_new = t_hi_c + step
            return (t_new, 2.0 * step, best_agg(t_new[None])[0], i + 1)

        t_hi, _, agg_hi, _ = jax.lax.while_loop(
            b_cond, b_body, (t_hi0, t_hi0, agg0, jnp.asarray(0)))
        feasible = agg_hi >= target

        # --- monotone grid refinement --------------------------------------
        def _active(t_lo_c, t_hi_c):
            wide = (t_hi_c - t_lo_c) > eps_rel * jnp.maximum(t_hi_c, 1e-12)
            return jnp.logical_and(wide, feasible)

        def r_cond(st):
            t_lo_c, t_hi_c, r = st
            return jnp.logical_and(r < MAX_ROUNDS, _active(t_lo_c, t_hi_c))

        def r_body(st):
            t_lo_c, t_hi_c, r = st
            grid = t_lo_c + frac * (t_hi_c - t_lo_c)
            grid = grid.at[-1].set(t_hi_c)  # exact upper edge: invariant
            ok = best_agg(grid) >= target
            idx = jnp.argmax(ok)
            hi_new = grid[idx]
            lo_new = jnp.where(idx == 0, t_lo_c,
                               grid[jnp.maximum(idx - 1, 0)])
            act = _active(t_lo_c, t_hi_c)
            return (jnp.where(act, lo_new, t_lo_c),
                    jnp.where(act, hi_new, t_hi_c), r + 1)

        _, t_star, _ = jax.lax.while_loop(
            r_cond, r_body, (jnp.zeros_like(t_hi), t_hi, jnp.asarray(0)))

        # --- extraction at t* ----------------------------------------------
        def extract(_, prm_c):
            ev = chunk_returns(t_star[None], tuple(prm_c))[0]   # (chunk, L)
            loads_c = jnp.argmax(ev, axis=-1)
            best_c = jnp.take_along_axis(
                ev, loads_c[:, None], axis=-1)[:, 0]
            return None, (loads_c, best_c.sum())

        _, (loads_l, best_sums) = jax.lax.scan(extract, None, prm_stack)
        edge_best = jax.lax.psum(best_sums.sum(), "shards")
        sv = server_returns(t_star[None])[0]                    # (Ls,)
        s_load = jnp.argmax(sv)
        agg = edge_best + sv[s_load]
        return (loads_l.reshape(-1), t_star, s_load, agg, feasible)

    spec_n = P("shards")
    fn = shard_map(
        solve, mesh=mesh,
        in_specs=(spec_n,) * 5,
        out_specs=(spec_n, P(), P(), P(), P()),
        check_rep=False)
    return fn(a, mu, tau, p, caps)


def solve_fleet(request: PlanRequest, eps_rel: float = 1e-3,
                grid_points: int = GRID_POINTS,
                chunk: int = CHUNK) -> RedundancyPlan:
    """Solve one fleet-scale redundancy problem, sharded + streamed.

    Accepts the same `PlanRequest` as the batched solver (srv_weight and
    edge_chunks included) and returns the same `RedundancyPlan`; see the
    module docstring for the numerical invariants vs
    `solve_redundancy_batched`.
    """
    req = request
    n = req.edge.n
    n_shards = len(jax.devices())
    chunk = max(8, min(int(chunk), _pow2_bucket(n)))
    step = n_shards * chunk
    n_pad = -(-n // step) * step

    def pad(vec, fill):
        out = np.full(n_pad, fill, dtype=np.float64)
        out[:n] = vec
        return out

    a = pad(req.edge.a, 1.0)
    mu = pad(req.edge.mu, 1.0)
    tau = pad(req.edge.tau, 0.0)
    p = pad(req.edge.p, 0.0)
    caps = pad(req.data_sizes.astype(np.float64), 0.0)

    l_edge = _pow2_bucket(int(req.data_sizes.max()) + 1)
    l_srv = _pow2_bucket(req.server_cap + 1)
    n_k = _k_terms(float(req.edge.p.max()), tol=1e-12)
    frac = np.arange(1, grid_points + 1, dtype=np.float64) / grid_points
    t_hi0 = req.t_hi if req.t_hi is not None else req.default_t_hi()

    with jax.experimental.enable_x64():
        out = _solve_fleet_grid(
            a, mu, tau, p, caps,
            np.float64(req.server.a[0]), np.float64(req.server.mu[0]),
            np.float64(req.srv_weight), np.float64(req.server_cap),
            np.float64(req.m), np.float64(t_hi0), np.float64(eps_rel),
            np.arange(l_edge, dtype=np.float64),
            np.arange(l_srv, dtype=np.float64),
            np.arange(2, 2 + n_k, dtype=np.float64), frac,
            edge_chunks=int(req.edge_chunks), n_shards=n_shards,
            chunk=chunk)
        loads_pad, t_star, s_load, agg, feasible = \
            (np.asarray(o) for o in out)

    if not bool(feasible):
        raise RuntimeError(
            "cannot reach the aggregate expected return target — the "
            f"fleet cannot return the points in finite time: target "
            f"{req.m}, best achievable {float(agg):.1f}")

    dev_loads = loads_pad[:n].astype(np.int64)
    c = int(req.fixed_c) if req.fixed_c is not None else int(s_load)
    p_return = np.append(
        total_cdf(req.edge, dev_loads, float(t_star)),
        total_cdf(req.server, np.array([float(s_load)]), float(t_star)))
    return RedundancyPlan(loads=dev_loads, c=c, t_star=float(t_star),
                          p_return=p_return, expected_agg=float(agg),
                          loads_cap_total=req.m)
