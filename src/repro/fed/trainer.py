"""Straggler-aware federated training for arbitrary (non-linear) models.

The paper's exact parity-gradient identity needs a linear model + squared
loss (DESIGN.md §4), so for the assigned deep architectures we integrate the
*protocol-level* parts of CFL, which are model-agnostic:

  1. **Load allocation (Eqs. 14-16)** — each client's per-round microbatch
     ell*_i is chosen to maximize its expected return by the deadline, and
     the deadline t* is the smallest that covers the global batch in
     expectation.  Here a "data point" is one training sequence.
  2. **Deadline-masked aggregation** — per round, each client's sampled
     T_i <= t* decides whether its partial gradient lands; missing clients
     are compensated by inverse-probability (1/p_i) importance scaling so
     the aggregate stays unbiased (the FedSGD analogue of Eq. 19's
     bias-correction-by-weighting).

One jitted train step serves every round: client contributions enter as a
weighted per-sequence mask, so the backward pass is a single (masked) batch
gradient — exactly what the pjit data-parallel step computes, with clients
laid out along the `data` mesh axis.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.delay_model import DeviceDelayParams, sample_total, total_cdf
from repro.core.redundancy import RedundancyPlan
from repro.optim.optimizers import Optimizer, apply_updates


@dataclasses.dataclass(frozen=True)
class FedConfig:
    n_clients: int
    sequences_per_client: int       # local dataset size (in sequences)
    target_sequences: int           # global batch the server wants per round
    deadline_quantile: float = 1.0  # scale t* (1.0 = Eq. 16 deadline)
    min_return_prob: float = 1e-3   # clients below this are never scheduled
                                    # AND the importance-weight clip floor


@dataclasses.dataclass
class FedState:
    plan: RedundancyPlan
    p_return: np.ndarray            # (n,) Pr{T_i <= t*}
    edge: DeviceDelayParams
    min_return_prob: float          # from FedConfig (see round_weights)
    round_idx: int = 0
    wall_clock: float = 0.0


def fed_setup(edge: DeviceDelayParams, cfg: FedConfig) -> FedState:
    """Run the Eq. 14-16 load allocation over sequences-as-points.

    The server is modelled with zero capacity (no parity for non-linear
    models) by giving it an infinitesimal budget: redundancy c is forced
    to 0 and the aggregate-return target is the requested global batch.
    """
    server = DeviceDelayParams(a=np.array([1e-9]), mu=np.array([1e9]),
                               tau=np.zeros(1), p=np.zeros(1))
    sizes = np.full(cfg.n_clients, cfg.sequences_per_client, dtype=np.int64)
    # fixed_c = 0: pure load allocation, no parity (Eq. 16 with c == 0).
    # The achievable aggregate is sum(sizes); cap the target accordingly.
    target = min(cfg.target_sequences, int(sizes.sum()))
    # solve_redundancy targets m = sum(sizes); we want `target`, so feed
    # scaled sizes whose total is `target` as caps? No — caps must stay the
    # local dataset sizes.  Instead we bisect on t ourselves.
    plan = _solve_loads(edge, sizes, target)
    p = total_cdf(edge, plan.loads, plan.t_star)
    return FedState(plan=plan, p_return=p, edge=edge,
                    min_return_prob=cfg.min_return_prob)


def _solve_loads(edge: DeviceDelayParams, sizes: np.ndarray,
                 target: int) -> RedundancyPlan:
    from repro.core.returns import optimal_loads
    t_hi = float(np.max(edge.mean_total(sizes))) + 1.0
    loads, vals = optimal_loads(edge, sizes, t_hi)
    guard = 0
    while float(vals.sum()) < target:
        t_hi *= 2
        loads, vals = optimal_loads(edge, sizes, t_hi)
        guard += 1
        if guard > 60:
            raise RuntimeError("fleet cannot reach the target batch")
    t_lo = 0.0
    for _ in range(48):
        t_mid = 0.5 * (t_lo + t_hi)
        l_mid, v_mid = optimal_loads(edge, sizes, t_mid)
        if float(v_mid.sum()) >= target:
            t_hi, loads, vals = t_mid, l_mid, v_mid
        else:
            t_lo = t_mid
        if t_hi - t_lo < 1e-4 * max(t_hi, 1e-9):
            break
    probs = total_cdf(edge, loads, t_hi)
    return RedundancyPlan(loads=loads, c=0, t_star=float(t_hi),
                          p_return=np.append(probs, 1.0),
                          expected_agg=float(vals.sum()),
                          loads_cap_total=int(sizes.sum()))


def masked_loss(loss_per_seq_fn: Callable, params, batch: dict,
                seq_weights: jax.Array):
    """Weighted mean of per-sequence losses.

    loss_per_seq_fn(params, batch) -> (B,) per-sequence losses;
    seq_weights: (B,) — 0 for dropped/straggling sequences, 1/p_i for
    received ones (importance-scaled, unbiased)."""
    per_seq = loss_per_seq_fn(params, batch)
    denom = jnp.maximum(jnp.sum(seq_weights > 0), 1)
    return jnp.sum(per_seq * seq_weights) / denom


def _round_client_weights(state: FedState,
                          rng: np.random.Generator) -> np.ndarray:
    """One round's per-client importance weights: 0 (dropped) or 1/p_i.

    Clients whose return probability is below `state.min_return_prob`
    (FedConfig.min_return_prob) are never scheduled: their gradients are
    dropped even if the sampled delay lands, and the same floor clips the
    importance weights so a barely-returning client cannot blow up the
    aggregate with a near-infinite 1/p_i."""
    t_i = sample_total(state.edge, state.plan.loads, rng)
    scheduled = state.p_return >= state.min_return_prob
    received = (t_i <= state.plan.t_star) & (state.plan.loads > 0) & scheduled
    p = np.clip(state.p_return, state.min_return_prob, 1.0)
    return np.where(received, 1.0 / p, 0.0)            # unbiased masking


def round_weights(state: FedState, rng: np.random.Generator,
                  batch_clients: np.ndarray) -> tuple[np.ndarray, float]:
    """Sample one round's arrivals.

    batch_clients: (B,) client id of each sequence in the global batch
    (sequences are laid out client-major along the data axis).
    Returns (seq_weights (B,), round wall time = t*)."""
    w_client = _round_client_weights(state, rng)
    return w_client[batch_clients], float(state.plan.t_star)


def presample_round_weights(state: FedState, rng: np.random.Generator,
                            n_rounds: int) -> np.ndarray:
    """Pre-sample every round's per-client weights up front: (rounds, n).

    The Session-style analogue for the non-linear trainer: all delay
    randomness is drawn once (same generator order as per-round
    `round_weights` calls), so the training loop itself touches no NumPy
    sampling and per-round host work is a single array index."""
    return np.stack([_round_client_weights(state, rng)
                     for _ in range(n_rounds)])


def _apply_round(state: FedState, grad_fn, params, opt: Optimizer,
                 opt_state, batch: dict, seq_weights: np.ndarray):
    """Masked-gradient update for one round's (pre)sampled weights."""
    loss, grads = grad_fn(params, batch,
                          jnp.asarray(seq_weights, dtype=jnp.float32))
    updates, opt_state = opt.update(grads, opt_state, params)
    params = apply_updates(params, updates)
    state.round_idx += 1
    state.wall_clock += float(state.plan.t_star)
    return params, opt_state, float(loss)


def fed_round(state: FedState, grad_fn, params, opt: Optimizer, opt_state,
              batch: dict, batch_clients: np.ndarray,
              rng: np.random.Generator):
    """One synchronous round: sample arrivals, masked gradient, update."""
    w, _ = round_weights(state, rng, batch_clients)
    return _apply_round(state, grad_fn, params, opt, opt_state, batch, w)


def fed_train(state: FedState, grad_fn, params, opt: Optimizer,
              batches: Iterator[tuple[dict, np.ndarray]], n_rounds: int,
              seed: int = 0, log_every: int = 0):
    """Run n_rounds of federated training; returns (params, losses).

    All per-round arrival randomness is pre-sampled up front
    (`presample_round_weights`, same draw order as per-round sampling), so
    the loop body is pure model work — mirroring how `repro.api.Session`
    pre-samples delay tensors for the linear-model strategies."""
    rng = np.random.default_rng(seed)
    opt_state = opt.init(params)
    w_rounds = presample_round_weights(state, rng, n_rounds)  # (rounds, n)
    losses = []
    for r in range(n_rounds):
        batch, batch_clients = next(batches)
        params, opt_state, loss = _apply_round(
            state, grad_fn, params, opt, opt_state, batch,
            w_rounds[r][batch_clients])
        losses.append(loss)
        if log_every and (r + 1) % log_every == 0:
            print(f"round {r+1}: loss {loss:.4f} "
                  f"wall {state.wall_clock:.1f}s")
    return params, losses
