"""Federated substrate: straggler-aware load allocation + deadline-masked
aggregation for arbitrary models, and the exact coded-head path."""
from .trainer import (FedConfig, FedState, fed_round, fed_setup, fed_train,
                      presample_round_weights, round_weights)
from .coded_head import train_coded_head

__all__ = ["FedConfig", "FedState", "fed_setup", "fed_round", "fed_train",
           "round_weights", "presample_round_weights", "train_coded_head"]
