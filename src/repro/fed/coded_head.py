"""Exact CFL for a model's linear readout head.

The paper's parity-gradient identity holds whenever the trained parameters
enter linearly under squared loss.  For a deep network with a frozen
backbone this is exactly the last-layer (linear-probe) setting: client i
holds features Phi_i = f_theta(X_i) in R^{ell_i x d_feat} and regression
targets y_i; training the head beta solves min ||Phi beta - y||^2 — the
paper's problem verbatim, with Phi in place of X.

Two feature sources compose here:

  * an explicit frozen backbone from `repro.models` (`backbone_fn`), or
  * `CodedFedL`'s random-Fourier-feature map (`d_feat=...`), which turns
    the head into Gaussian-kernel regression on the raw inputs
    (arXiv:2007.03273) — no backbone weights needed.

Runs ride the Strategy/Session substrate (`UncodedFL` baseline,
`CodedFL` / `CodedFedL` coded head) and return `TraceReport`s, so the
full coded machinery — batched redundancy solve, private parity upload,
deadline-clipped epochs — trains the head with the paper's guarantees.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import numpy as np

from repro.api import Session, TrainData
from repro.api.report import TraceReport
from repro.api.strategy import CodedFL, UncodedFL
from repro.schemes import CodedFedL
from repro.sim.network import FleetSpec


def extract_features(backbone_fn: Callable, xs: jax.Array) -> jax.Array:
    """Apply a frozen backbone per client. xs (n, ell, ...) -> (n, ell, d)."""
    return jax.vmap(backbone_fn)(xs)


def train_coded_head(fleet: FleetSpec, backbone_fn: Optional[Callable],
                     xs: jax.Array, ys: jax.Array, beta_true: jax.Array,
                     lr: float, epochs: int, key: jax.Array,
                     rng: np.random.Generator,
                     fixed_c: Optional[int] = None,
                     include_upload_delay: bool = False,
                     uncoded_baseline: bool = True,
                     d_feat: Optional[int] = None,
                     rff_key: Optional[jax.Array] = None,
                     rff_gamma: float = 1.0
                     ) -> dict[str, TraceReport]:
    """Coded-train a linear head on (frozen-backbone or RFF) features.

    backbone_fn: maps one client's raw inputs (ell, ...) to features
    (ell, d_feat); None means features == inputs (pure linreg).
    d_feat/rff_key/rff_gamma: push the (backbone) features through
    `CodedFedL`'s shared RFF map and train the head in kernel space;
    `beta_true` is then replaced by the feature-space least-squares
    reference head, so the NMSE trace measures distance to the kernel
    regressor.
    Returns {"uncoded": TraceReport, "cfl" | "cfedl": TraceReport};
    the shared `rng` is consumed sequentially (uncoded first), matching
    the legacy `run_uncoded` + `run_cfl` draw order.
    """
    feats = extract_features(backbone_fn, xs) if backbone_fn is not None \
        else xs

    if d_feat is None:
        coded_key = "cfl"
        coded = CodedFL(key=key, fixed_c=fixed_c,
                        include_upload_delay=include_upload_delay)
        data = TrainData(xs=feats, ys=ys, beta_true=beta_true)
    else:
        coded_key = "cfedl"
        coded = CodedFedL(key=key, d_feat=d_feat, rff_key=rff_key,
                          rff_gamma=rff_gamma, fixed_c=fixed_c,
                          include_upload_delay=include_upload_delay)
        # feature-space reference head: the model trains in d_feat
        # dimensions, so NMSE must be measured against the kernel
        # regressor, not the raw-space beta_true
        phi = np.asarray(coded.features(
            TrainData(xs=feats, ys=ys, beta_true=beta_true)))
        beta_ref, *_ = np.linalg.lstsq(
            phi.reshape(-1, d_feat),
            np.asarray(ys, dtype=np.float64).reshape(-1), rcond=None)
        data = TrainData(xs=feats, ys=ys,
                         beta_true=jax.numpy.asarray(
                             beta_ref, dtype=feats.dtype))

    out: dict[str, TraceReport] = {}
    if uncoded_baseline:
        # the uncoded baseline waits for every straggler on the SAME
        # training problem: kernel-space runs pre-map the features so
        # both arms descend the same objective
        base_xs = data.xs if d_feat is None else coded.features(data)
        base = TrainData(xs=base_xs, ys=data.ys, beta_true=data.beta_true)
        out["uncoded"] = Session(strategy=UncodedFL(), fleet=fleet,
                                 lr=lr, epochs=epochs).run(base, rng=rng)
    out[coded_key] = Session(strategy=coded, fleet=fleet,
                             lr=lr, epochs=epochs).run(data, rng=rng)
    return out
