"""Exact CFL for a model's linear readout head.

The paper's parity-gradient identity holds whenever the trained parameters
enter linearly under squared loss.  For a deep network with a frozen
backbone this is exactly the last-layer (linear-probe) setting: client i
holds features Phi_i = f_theta(X_i) in R^{ell_i x d_feat} and regression
targets y_i; training the head beta solves min ||Phi beta - y||^2 — the
paper's problem verbatim, with Phi in place of X.

This is the bridge between the paper's technique and the assigned deep
architectures: any backbone from `repro.models` can produce the features;
the full CFL machinery (redundancy optimization, private parity upload,
deadline-clipped epochs) then trains the head with the paper's guarantees.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import numpy as np

from repro.core import cfl
from repro.sim.network import FleetSpec
from repro.sim.simulator import SimResult, run_cfl, run_uncoded


def extract_features(backbone_fn: Callable, xs: jax.Array) -> jax.Array:
    """Apply a frozen backbone per client. xs (n, ell, ...) -> (n, ell, d)."""
    return jax.vmap(backbone_fn)(xs)


def train_coded_head(fleet: FleetSpec, backbone_fn: Optional[Callable],
                     xs: jax.Array, ys: jax.Array, beta_true: jax.Array,
                     lr: float, epochs: int, key: jax.Array,
                     rng: np.random.Generator,
                     fixed_c: Optional[int] = None,
                     include_upload_delay: bool = False,
                     uncoded_baseline: bool = True
                     ) -> dict[str, SimResult]:
    """CFL-train a linear head on (frozen-backbone) features.

    backbone_fn: maps one client's raw inputs (ell, ...) to features
    (ell, d_feat); None means features == inputs (pure linreg).
    Returns {"cfl": SimResult, "uncoded": SimResult}.
    """
    feats = extract_features(backbone_fn, xs) if backbone_fn is not None else xs
    out = {}
    if uncoded_baseline:
        out["uncoded"] = run_uncoded(fleet, feats, ys, beta_true, lr=lr,
                                     epochs=epochs, rng=rng)
    out["cfl"] = run_cfl(fleet, feats, ys, beta_true, lr=lr, epochs=epochs,
                         rng=rng, key=key, fixed_c=fixed_c,
                         include_upload_delay=include_upload_delay)
    return out
