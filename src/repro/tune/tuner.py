"""Roofline-pruned tile search: enumerate, prune, measure, persist.

For one `(family, shape)` the tuner:

  1. enumerates the family's candidate tile grid (backend-aware);
  2. DRY-RUN lowers each candidate — `jax.jit(fn).lower(sds).compile()`
     over ShapeDtypeStructs, no arrays allocated — and feeds the HLO
     text to `roofline.analysis.roofline_terms`.  The bound
     `max(t_compute, t_memory)` is a lower limit on achievable time
     under the roofline model: a candidate whose bound exceeds
     `slack x` the best bound cannot win unless the model is off by
     more than `slack`, so it is pruned WITHOUT execution.  (Tile
     choice moves the memory term a lot — small tiles re-stream the
     resident operands once per grid step — while FLOPs stay constant,
     so the bound separates candidates sharply.);
  3. measures the survivors (median-free mean of `iters` timed calls
     after a warmup) and picks the winner deterministically: ties break
     toward the earlier candidate in enumeration order;
  4. persists the winner in the on-disk tile cache keyed by
     `(family, shape bucket, backend)` — `block="auto"` then serves it
     process-wide with zero measurement cost.

Autotuning is always EXPLICIT (this module or `python -m repro.tune`);
`block="auto"` only ever reads the cache.

`terms_fn` / `measure_fn` are injectable for tests (deterministic
winner selection and pruning proofs without compiling kernels).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Optional

import jax

from repro.kernels import common as kcommon
from repro.roofline.analysis import roofline_terms

from .cache import TileCache, bucket_shape, user_cache_path
from .families import FAMILIES

# How far a candidate's roofline lower bound may sit above the best
# candidate's before it is pruned unmeasured.  The slack absorbs the
# model's attainment gap (a kept candidate may run `slack x` above its
# bound and still beat a pruned one at its bound).
DEFAULT_SLACK = float(os.environ.get("REPRO_TUNE_PRUNE_SLACK", "8.0"))


@dataclasses.dataclass(frozen=True)
class TuneResult:
    family: str
    shape: tuple
    bucket: tuple
    backend: str
    block: tuple            # the winner
    us: float               # its measured time
    bound_us: float         # its roofline lower bound
    candidates: tuple       # full enumeration order
    bounds_us: tuple        # lower bound per candidate (same order)
    pruned: tuple           # candidates skipped by the roofline model
    measured: tuple         # (block, us) per survivor

    def meta(self, extra: Optional[dict] = None) -> dict:
        m = {"us": round(self.us, 1), "bound_us": round(self.bound_us, 3),
             "n_candidates": len(self.candidates),
             "n_pruned": len(self.pruned),
             "shape": list(self.shape), "jax": jax.__version__,
             "source": "measured"}
        m.update(extra or {})
        return m


def roofline_bound(terms: dict) -> float:
    """Achievable-time lower limit: the binding compute/memory term."""
    return max(terms["t_compute"], terms["t_memory"])


def candidate_terms(family, shape, block) -> dict:
    """Roofline terms from a dry-run lowering of one candidate (no
    arrays are materialized; interpret-mode lowerings off-TPU still
    carry the grid/tile structure, so bytes scale with grid steps)."""
    fn, sds = family.bind(shape, block)
    hlo = jax.jit(fn).lower(*sds).compile().as_text()
    return roofline_terms(hlo, 1)


def measure(fn, args, iters: int = 5) -> float:
    """Mean wall time (us) of `iters` calls after one warmup call."""
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def prune(candidates: list, bounds_us: list,
          slack: float = DEFAULT_SLACK) -> tuple[list, list]:
    """(survivors, pruned): keep candidates within `slack x` of the best
    roofline bound.  Every pruned candidate is dominated UNDER THE
    MODEL: its lower bound alone exceeds what the best candidate could
    take even running `slack x` above its own bound."""
    best = min(bounds_us)
    if best <= 0:
        # Degenerate lowering (zero roofline bound): the slack band would
        # collapse to 0 and prune every positive-bound candidate, so the
        # model can't rank anything — measure them all instead.
        return list(candidates), []
    survivors = [c for c, b in zip(candidates, bounds_us)
                 if b <= slack * best]
    pruned = [c for c, b in zip(candidates, bounds_us)
              if b > slack * best]
    return survivors, pruned


def autotune(family_name: str, shape: tuple, *,
             slack: float = DEFAULT_SLACK, iters: int = 5,
             backend: Optional[str] = None,
             cache: Optional[TileCache] = None, store: bool = True,
             terms_fn: Optional[Callable] = None,
             measure_fn: Optional[Callable] = None,
             verbose: bool = False) -> TuneResult:
    """Tune one `(family, shape)` and (by default) persist the winner."""
    family = FAMILIES[family_name]
    backend = backend or kcommon.backend()
    candidates = family.candidate_blocks(shape, backend)
    if terms_fn is None:
        def terms_fn(block):
            return candidate_terms(family, shape, block)
    bounds = [roofline_bound(terms_fn(b)) * 1e6 for b in candidates]
    survivors, pruned = prune(candidates, bounds, slack=slack)

    if measure_fn is None:
        def measure_fn(block):
            fn, _ = family.bind(shape, block)
            return measure(jax.jit(fn), family.make_args(shape),
                           iters=iters)
    timed = [(measure_fn(b), i, b) for i, b in enumerate(survivors)]
    best_us, _, winner = min(timed)  # ties -> earliest candidate

    result = TuneResult(
        family=family_name, shape=tuple(shape),
        bucket=bucket_shape(shape), backend=backend,
        block=tuple(winner), us=float(best_us),
        bound_us=float(bounds[candidates.index(winner)]),
        candidates=tuple(candidates), bounds_us=tuple(bounds),
        pruned=tuple(pruned),
        measured=tuple((b, float(us)) for us, _, b in timed))
    if verbose:
        print(f"tune {family_name} {shape} [{backend}]: "
              f"{len(candidates)} candidates, {len(pruned)} pruned, "
              f"winner {winner} at {best_us:.0f}us")
    if store:
        cache = cache or TileCache(user_cache_path())
        cache.store(family_name, shape, backend, winner, result.meta())
    return result


def tune_shapes(shapes: Optional[dict] = None, *,
                cache: Optional[TileCache] = None,
                slack: float = DEFAULT_SLACK, iters: int = 5,
                verbose: bool = True) -> list[TuneResult]:
    """Tune a `{family: [shape, ...]}` map (defaults to the CI set)."""
    from .families import CI_SHAPES

    shapes = shapes if shapes is not None else CI_SHAPES
    results = []
    for family_name, shape_list in shapes.items():
        for shape in shape_list:
            results.append(autotune(family_name, tuple(shape),
                                    slack=slack, iters=iters,
                                    cache=cache, verbose=verbose))
    return results
