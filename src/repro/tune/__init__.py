"""`repro.tune` — roofline-pruned Pallas tile autotuner + persisted cache.

Public surface:

  * `lookup_block`, `TileCache`, `bucket_shape` — the cache layer (pure
    stdlib; safe to import from kernel wrappers);
  * `autotune`, `tune_shapes`, `TuneResult` — the tuner (imports the
    kernel families lazily so `repro.tune.cache` stays light on the
    `block="auto"` hot path);
  * `FAMILIES`, `CI_SHAPES` — the kernel-family registry.

See API.md "The autotuning layer" for the cache key/layout and the
`block="auto"` contract.
"""
from .cache import (CACHE_VERSION, TileCache, bucket_shape, cache_key,
                    defaults_path, lookup_block, lookup_entry,
                    user_cache_path)

_LAZY = {
    "autotune": "tuner", "tune_shapes": "tuner", "TuneResult": "tuner",
    "candidate_terms": "tuner", "roofline_bound": "tuner",
    "prune": "tuner", "measure": "tuner",
    "FAMILIES": "families", "CI_SHAPES": "families",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib

        mod = importlib.import_module(f".{_LAZY[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = ["CACHE_VERSION", "TileCache", "bucket_shape", "cache_key",
           "defaults_path", "lookup_block", "lookup_entry",
           "user_cache_path", *_LAZY]
