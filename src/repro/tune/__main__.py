"""CLI: tune Pallas tiles and persist them.

    python -m repro.tune                         # tune the CI shape set
    python -m repro.tune --family encode --shape 2048x512x512
    python -m repro.tune --ci-defaults           # regenerate the committed
                                                 # src/repro/tune/defaults.json

Winners land in the user cache (`$REPRO_TUNE_CACHE_DIR/tiles.json`,
default `~/.cache/repro-tune/tiles.json`); `--ci-defaults` writes the
in-repo fallback instead (commit the result).  `block="auto"` consults
both — this CLI is the ONLY thing that ever autotunes.
"""
from __future__ import annotations

import argparse
import sys

from .cache import TileCache, defaults_path
from .families import CI_SHAPES, FAMILIES
from .tuner import DEFAULT_SLACK, tune_shapes


def _parse_shape(text: str) -> tuple:
    return tuple(int(v) for v in text.replace(",", "x").split("x"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.tune")
    ap.add_argument("--family", choices=sorted(FAMILIES), default=None,
                    help="tune one family (default: all)")
    ap.add_argument("--shape", default=None,
                    help="one shape, e.g. 2048x512x512 (requires --family)")
    ap.add_argument("--slack", type=float, default=DEFAULT_SLACK,
                    help="roofline pruning slack factor")
    ap.add_argument("--iters", type=int, default=5,
                    help="timed calls per surviving candidate")
    ap.add_argument("--ci-defaults", action="store_true",
                    help="tune the CI shape set into the committed "
                         "src/repro/tune/defaults.json")
    args = ap.parse_args(argv)

    if args.shape and not args.family:
        ap.error("--shape requires --family")
    if args.shape:
        shapes = {args.family: [_parse_shape(args.shape)]}
    elif args.family:
        shapes = {args.family: CI_SHAPES[args.family]}
    else:
        shapes = None  # the full CI set

    cache = TileCache(defaults_path()) if args.ci_defaults else None
    results = tune_shapes(shapes, cache=cache, slack=args.slack,
                          iters=args.iters, verbose=True)
    target = cache.path if cache else "user cache"
    print(f"{len(results)} entries written to {target}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
