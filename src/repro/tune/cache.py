"""Versioned on-disk tile cache: the persisted half of the autotuner.

Layout — one JSON file per location, schema:

    {"version": 1,
     "entries": {
       "<family>|<backend>|<bucket>": {
         "block": [2048, 512, 512],
         "us": 15431.0,             # measured winner time (audit trail)
         "bound_us": 3.8,           # its roofline lower bound
         "n_candidates": 36, "n_pruned": 29,
         "jax": "0.4.37", "source": "measured"
       }, ...}}

Lookup order (first hit wins):

  1. the user cache — `$REPRO_TUNE_CACHE_DIR/tiles.json`, defaulting to
     `~/.cache/repro-tune/tiles.json` (written by `python -m repro.tune`);
  2. the in-repo fallback `src/repro/tune/defaults.json`, committed with
     tuned entries for the CPU CI shapes so `block="auto"` hits on fresh
     checkouts and CI runners.

Shapes are BUCKETED before keying: each dim rounds up to the next power
of two, so nearby problem sizes share one tuned tile (the kernels clamp
tiles to actual dims, so an entry tuned at the bucket ceiling stays
valid for every shape inside the bucket).

A `version` mismatch invalidates a file wholesale — entries are never
reinterpreted across schema changes; `store()` always writes the current
version (dropping stale-version entries on the first write).
"""
from __future__ import annotations

import json
import os
from typing import Optional

CACHE_VERSION = 1
CACHE_ENV = "REPRO_TUNE_CACHE_DIR"
CACHE_FILENAME = "tiles.json"

# (abspath, mtime_ns) -> entries dict; re-read only when the file changes
_LOAD_MEMO: dict[tuple[str, int], dict] = {}


def _pow2ceil(v: int) -> int:
    return 1 if v <= 1 else 1 << (int(v) - 1).bit_length()


def bucket_shape(shape: tuple[int, ...]) -> tuple[int, ...]:
    """Power-of-two ceiling per dim — the cache's shape equivalence class."""
    return tuple(_pow2ceil(int(s)) for s in shape)


def cache_key(family: str, shape: tuple[int, ...], backend: str) -> str:
    bucket = "x".join(str(s) for s in bucket_shape(shape))
    return f"{family}|{backend}|{bucket}"


def user_cache_path() -> str:
    base = os.environ.get(CACHE_ENV) or os.path.join(
        os.path.expanduser("~"), ".cache", "repro-tune")
    return os.path.join(base, CACHE_FILENAME)


def defaults_path() -> str:
    """The committed in-repo fallback (CPU CI shapes)."""
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "defaults.json")


def _load_entries(path: str) -> dict:
    """Entries of one cache file; {} when absent or version-mismatched."""
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        return {}
    memo_key = (os.path.abspath(path), mtime)
    if memo_key in _LOAD_MEMO:
        return _LOAD_MEMO[memo_key]
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError):
        payload = {}
    entries = payload.get("entries", {}) \
        if payload.get("version") == CACHE_VERSION else {}
    _LOAD_MEMO[memo_key] = entries
    return entries


class TileCache:
    """One cache file (user cache, repo defaults, or a test tmpdir)."""

    def __init__(self, path: str):
        self.path = path

    def lookup(self, family: str, shape: tuple[int, ...],
               backend: str) -> Optional[dict]:
        return _load_entries(self.path).get(
            cache_key(family, shape, backend))

    def store(self, family: str, shape: tuple[int, ...], backend: str,
              block, meta: Optional[dict] = None) -> dict:
        """Merge one winner into the file (read-modify-write).

        Stale-version files are dropped wholesale on the first store —
        old-schema entries are never carried forward.
        """
        entries = dict(_load_entries(self.path))
        entry = {"block": [int(b) for b in
                           (block if isinstance(block, (tuple, list))
                            else (block,))]}
        entry.update(meta or {})
        entries[cache_key(family, shape, backend)] = entry
        os.makedirs(os.path.dirname(os.path.abspath(self.path)),
                    exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": CACHE_VERSION, "entries": entries},
                      f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.path)
        return entry


def lookup_entry(family: str, shape: tuple[int, ...],
                 backend: Optional[str] = None) -> Optional[dict]:
    """User cache first, then the committed repo defaults."""
    if backend is None:
        from repro.kernels import common as kcommon
        backend = kcommon.backend()
    for path in (user_cache_path(), defaults_path()):
        ent = _load_entries(path).get(cache_key(family, shape, backend))
        if ent is not None:
            return ent
    return None


def lookup_block(family: str, shape: tuple[int, ...],
                 backend: Optional[str] = None
                 ) -> Optional[tuple[int, ...]]:
    """The tuned tile for `(family, shape-bucket, backend)`, or None."""
    ent = lookup_entry(family, shape, backend)
    if ent is None or not ent.get("block"):
        return None
    return tuple(int(b) for b in ent["block"])
