"""Kernel-family descriptors: what the autotuner can tune.

A family packages everything the tuner needs to treat one Pallas kernel
generically:

  * `name`           — the cache-key family component;
  * `default_block`  — the hard-coded tile `block="auto"` falls back to;
  * `candidate_blocks(shape, backend)` — the tile grid to search.  On
    TPU candidates are filtered by a VMEM-footprint budget (resident
    tiles must fit alongside double-buffering headroom); off-TPU the
    kernels run in interpret mode where the only "memory" is host RAM,
    so the budget is generous and the grid reaches the whole-problem
    tile (fewest grid steps — exactly what interpret mode rewards);
  * `bind(shape, block)` — a pure array function + ShapeDtypeStructs,
    used both for the dry-run lowering (roofline pruning) and, with
    `make_args`, for measuring the survivors.

To add a family: implement the four members below and register the
instance in `FAMILIES` — `block="auto"` support in its ops wrapper is
then one `resolve_block(...)` call (see API.md "The autotuning layer").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import common as kcommon
from repro.kernels.coded_grad import coded_grad as _cg
from repro.kernels.encode import encode as _en
from repro.kernels.round_grad import round_grad as _rg

# Resident-tile budget on TPU: tiles for all operands + accumulator must
# sit in VMEM (~16 MB/core) with room for double buffering.
TPU_TILE_BYTES = 8 * 2 ** 20
# Interpret mode allocates host buffers — cap only pathological tiles.
HOST_TILE_BYTES = 512 * 2 ** 20


def _pow2_options(dim: int, floor: int = 128) -> list[int]:
    """{floor, 2*floor, ...} clipped to dim's power-of-two ceiling."""
    from .cache import _pow2ceil

    top = _pow2ceil(dim)
    opts, v = [], floor
    while v < top:
        opts.append(v)
        v *= 2
    opts.append(top)
    return opts


def _tile_budget(backend: str) -> int:
    return TPU_TILE_BYTES if backend == "tpu" else HOST_TILE_BYTES


class EncodeFamily:
    """`kernels/encode` dense variant: P = G (W X), tile (bc, bd, bl)."""

    name = "encode"
    default_block = _en.DEFAULT_BLOCK

    def candidate_blocks(self, shape, backend: str) -> list[tuple]:
        c, ell, d = shape
        budget = _tile_budget(backend)
        cands = []
        for bc in _pow2_options(c):
            for bd in _pow2_options(d):
                for bl in _pow2_options(ell):
                    tile_bytes = 4 * (bc * bl + bl * bd + bc * bd + bl)
                    if tile_bytes <= budget:
                        cands.append((bc, bd, bl))
        if self.default_block not in cands:
            cands.append(self.default_block)
        return cands

    def bind(self, shape, block):
        c, ell, d = shape
        interpret = not kcommon.on_tpu()

        def fn(g, w, x):
            return _en.encode_parity(g, w, x, block=block,
                                     interpret=interpret)

        sds = (jax.ShapeDtypeStruct((c, ell), jnp.float32),
               jax.ShapeDtypeStruct((ell,), jnp.float32),
               jax.ShapeDtypeStruct((ell, d), jnp.float32))
        return fn, sds

    def make_args(self, shape, seed: int = 0):
        c, ell, d = shape
        key = jax.random.PRNGKey(seed)
        return (jax.random.normal(key, (c, ell)),
                jax.random.uniform(jax.random.fold_in(key, 1), (ell,)),
                jax.random.normal(jax.random.fold_in(key, 2), (ell, d)))


class EncodePrngFamily(EncodeFamily):
    """`kernels/encode` in-kernel threefry variant (fleet-scale path:
    the generator never materializes, so tiles govern BOTH matmul grid
    overhead and how often generator tiles are re-hashed)."""

    name = "encode_prng"

    def bind(self, shape, block):
        c, ell, d = shape
        interpret = not kcommon.on_tpu()

        def fn(key, w, x):
            return _en.encode_parity_prng(key, w, x, c, block=block,
                                          interpret=interpret)

        sds = (jax.ShapeDtypeStruct((2,), jnp.uint32),
               jax.ShapeDtypeStruct((ell,), jnp.float32),
               jax.ShapeDtypeStruct((ell, d), jnp.float32))
        return fn, sds

    def make_args(self, shape, seed: int = 0):
        c, ell, d = shape
        key = jax.random.PRNGKey(seed)
        return (jax.random.PRNGKey(seed + 1),
                jax.random.uniform(jax.random.fold_in(key, 1), (ell,)),
                jax.random.normal(jax.random.fold_in(key, 2), (ell, d)))


class CodedGradFamily:
    """`kernels/coded_grad`: g = A^T (A beta - y), 1-d row tile (bm,)."""

    name = "coded_grad"
    default_block = (_cg.DEFAULT_BLOCK_M,)

    def candidate_blocks(self, shape, backend: str) -> list[tuple]:
        m, d = shape
        budget = _tile_budget(backend)
        cands = []
        for bm in _pow2_options(m, floor=256):
            # A tile + y slice + beta + (1, d) accumulator
            tile_bytes = 4 * (bm * d + bm + 2 * d)
            if tile_bytes <= budget:
                cands.append((bm,))
        if self.default_block not in cands:
            cands.append(self.default_block)
        return cands

    def bind(self, shape, block):
        m, d = shape
        interpret = not kcommon.on_tpu()

        def fn(a, y, beta):
            return _cg.lsq_gradient(a, y, beta, block_m=int(block[0]),
                                    interpret=interpret)

        sds = (jax.ShapeDtypeStruct((m, d), jnp.float32),
               jax.ShapeDtypeStruct((m,), jnp.float32),
               jax.ShapeDtypeStruct((d,), jnp.float32))
        return fn, sds

    def make_args(self, shape, seed: int = 0):
        m, d = shape
        key = jax.random.PRNGKey(seed)
        return (jax.random.normal(key, (m, d)),
                jax.random.normal(jax.random.fold_in(key, 1), (m,)),
                jax.random.normal(jax.random.fold_in(key, 2), (d,)))


class RoundGradFamily:
    """`kernels/round_grad` masked variant: g = (w . (X beta - y)) X in
    one sweep over X, 1-d row tile (bm,).  The coded and tier-masked
    variants resolve against the SAME family/shape (their row streams
    are identical), so one tuned tile serves all three launches."""

    name = "round_grad"
    default_block = (_rg.DEFAULT_BLOCK_M,)

    def candidate_blocks(self, shape, backend: str) -> list[tuple]:
        m, d = shape
        budget = _tile_budget(backend)
        cands = []
        for bm in _pow2_options(m, floor=256):
            # X tile + y/w slices + beta + (1, d) accumulator
            tile_bytes = 4 * (bm * d + 2 * bm + 2 * d)
            if tile_bytes <= budget:
                cands.append((bm,))
        if self.default_block not in cands:
            cands.append(self.default_block)
        return cands

    def bind(self, shape, block):
        m, d = shape
        interpret = not kcommon.on_tpu()

        def fn(x, y, w, beta):
            return _rg.masked_round_gradient(x, y, w, beta,
                                             block_m=int(block[0]),
                                             interpret=interpret)

        sds = (jax.ShapeDtypeStruct((m, d), jnp.float32),
               jax.ShapeDtypeStruct((m,), jnp.float32),
               jax.ShapeDtypeStruct((m,), jnp.float32),
               jax.ShapeDtypeStruct((d,), jnp.float32))
        return fn, sds

    def make_args(self, shape, seed: int = 0):
        m, d = shape
        key = jax.random.PRNGKey(seed)
        return (jax.random.normal(key, (m, d)),
                jax.random.normal(jax.random.fold_in(key, 1), (m,)),
                jax.random.uniform(jax.random.fold_in(key, 2), (m,)),
                jax.random.normal(jax.random.fold_in(key, 3), (d,)))


FAMILIES = {f.name: f for f in
            (EncodeFamily(), EncodePrngFamily(), CodedGradFamily(),
             RoundGradFamily())}

# The shapes `python -m repro.tune --ci-defaults` tunes and commits to
# `defaults.json`: the paper's §IV composite-parity shapes, the
# fleet-scale shapes `benchmarks/kernels.py` sweeps in CI, and the
# hierarchical-fleet per-tier encode shapes `benchmarks/perf_fleet.py`
# streams (many clients with tiny per-client shards: small ell/d, so
# `block="auto"` never cold-misses on the fleet smoke stage).
CI_SHAPES: dict[str, list[tuple]] = {
    "encode": [(936, 300, 500), (2048, 512, 512),
               (128, 8, 32), (256, 16, 64)],
    "encode_prng": [(936, 300, 500), (2048, 512, 512),
                    (128, 8, 32), (256, 16, 64)],
    "coded_grad": [(936, 500), (8192, 512)],
    # packed §IV systematic block (5524 -> 5632 bucket-padded rows) and
    # the fleet-scale row stream
    "round_grad": [(5632, 500), (8192, 512)],
}
