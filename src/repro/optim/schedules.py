"""Learning-rate schedules + global-norm gradient clipping (pure JAX)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def constant(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_with_warmup(peak_lr: float, warmup_steps: int,
                       total_steps: int, final_frac: float = 0.1) -> Callable:
    """Linear warmup then cosine decay to final_frac * peak."""
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps)
                     / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    """Returns (clipped grads, pre-clip norm)."""
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm
