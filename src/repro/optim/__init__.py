"""Optimizers in pure JAX: SGD(+momentum), Adam/AdamW with fp32 or bf16
moment states (bf16 for the >100B configs so optimizer memory fits HBM)."""
from .optimizers import (OptState, adamw, init_opt_state, sgd,
                         apply_updates, make_optimizer)

__all__ = ["OptState", "adamw", "sgd", "init_opt_state", "apply_updates",
           "make_optimizer"]
