"""Minimal optimizer library (no external deps).

API mirrors optax loosely: an optimizer is a pair of pure functions
(init(params) -> state, update(grads, state, params) -> (updates, state)).
`make_optimizer(name, ...)` builds one from a config string.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Optional[dict]      # first moment / momentum (None for plain SGD)
    nu: Optional[dict]      # second moment (Adam only)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[dict], OptState]
    update: Callable[[dict, OptState, dict], tuple[dict, OptState]]


def _zeros_like_dtype(params: dict, dtype) -> dict:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype),
                        params)


def sgd(lr: float, momentum: float = 0.0,
        state_dtype=None) -> Optimizer:
    def init(params):
        mu = _zeros_like_dtype(params, state_dtype) if momentum else None
        return OptState(jnp.zeros((), jnp.int32), mu, None)

    def update(grads, state, params, lr_scale=1.0):
        step_lr = lr * lr_scale
        if momentum:
            mu = jax.tree.map(
                lambda m, g: (momentum * m.astype(jnp.float32)
                              + g.astype(jnp.float32)).astype(m.dtype),
                state.mu, grads)
            updates = jax.tree.map(
                lambda m: -step_lr * m.astype(jnp.float32), mu)
        else:
            mu = None
            updates = jax.tree.map(
                lambda g: -step_lr * g.astype(jnp.float32), grads)
        return updates, OptState(state.step + 1, mu, None)

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, state_dtype=None) -> Optimizer:
    """AdamW.  `state_dtype=jnp.bfloat16` halves optimizer memory — used for
    the 123B/400B dry-run configs (DESIGN.md §5)."""
    def init(params):
        return OptState(jnp.zeros((), jnp.int32),
                        _zeros_like_dtype(params, state_dtype),
                        _zeros_like_dtype(params, state_dtype))

    def update(grads, state, params, lr_scale=1.0):
        step = state.step + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t
        step_lr = lr * lr_scale

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mhat = m32 / c1
            vhat = v32 / c2
            u = -step_lr * (mhat / (jnp.sqrt(vhat) + eps)
                            + weight_decay * p.astype(jnp.float32))
            return u, m32.astype(m.dtype), v32.astype(v.dtype)

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return updates, OptState(step, mu, nu)

    return Optimizer(init, update)


def apply_updates(params: dict, updates: dict) -> dict:
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def init_opt_state(opt: Optimizer, params: dict) -> OptState:
    return opt.init(params)


def make_optimizer(name: str, lr: float, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
