"""mamba2-1.3b [arXiv:2405.21060]

48 Mamba2 (SSD) layers, d_model 2048, attention-free, ssm_state 128,
vocab 50280.  d_ff = 0: no separate MLP (the mixer has expand=2).
"""
from .base import ArchConfig, SSMSpec, register

CONFIG = register(ArchConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,          # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMSpec(d_state=128, headdim=64, n_groups=1, expand=2),
    source="arXiv:2405.21060",
))
