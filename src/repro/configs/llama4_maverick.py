"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-Scout-17B-16E family]

48L, d_model 5120, 40 heads (GQA kv=8), per-expert d_ff 8192,
vocab 202048, 128 experts top-1.  MoE on every other layer (the Llama-4
interleave) puts the total at ~400B with ~17B active per token.
"""
from .base import ArchConfig, MoESpec, register

CONFIG = register(ArchConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    rope_theta=5e5,
    moe=MoESpec(n_experts=128, top_k=1, every=2),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))
