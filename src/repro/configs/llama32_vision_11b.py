"""llama-3.2-vision-11b [hf:meta-llama/Llama-3.2-11B-Vision]

40 decoder layers, d_model 4096, 32 heads (GQA kv=8), d_ff 14336,
vocab 128256, with gated cross-attention blocks over vision patch
embeddings every 5th layer.  The ViT tower is a stub: input_specs()
provides precomputed (n_patches, d_vision) embeddings.
"""
from .base import ArchConfig, VLMSpec, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-11b",
    arch_type="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    rope_theta=5e5,
    vlm=VLMSpec(cross_every=5, n_patches=1601, d_vision=4096),
    source="hf:meta-llama/Llama-3.2-11B-Vision",
))
