"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407]

88L, d_model 12288, 96 heads (GQA kv=8), d_ff 28672, vocab 32768.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mistral-large-123b",
    arch_type="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    rope_theta=1e6,
    head_dim=128,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
))
