"""Architecture config schema, registry, and input specs for the four
assigned input shapes.

Every assigned architecture registers an `ArchConfig` via `register()`;
`get_config(name)` / `list_archs()` drive `--arch <id>` selection in the
launchers.  `reduced()` returns the family-preserving smoke-test variant
(<= 2 layers, d_model <= 512, <= 4 experts) used by per-arch CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# input shapes (assigned)
# ---------------------------------------------------------------------------

INPUT_SHAPES: dict[str, dict] = {
    "train_4k": {"seq_len": 4_096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32_768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32_768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524_288, "global_batch": 1, "kind": "decode"},
}


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    every: int = 1          # MoE FFN on every `every`-th layer (1 = all)
    group_size: int = 2048
    capacity_factor: float = 2.0


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_state: int
    headdim: int = 64
    n_groups: int = 1
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256
    head_shard: bool = False   # shard SSD heads over the model mesh axis

    def n_heads(self, d_model: int) -> int:
        return self.expand * d_model // self.headdim


@dataclasses.dataclass(frozen=True)
class HybridSpec:
    """Zamba2-style: Mamba2 backbone with a weight-shared attention+MLP
    block applied every `attn_every` layers."""
    attn_every: int = 6


@dataclasses.dataclass(frozen=True)
class VLMSpec:
    """Llama-3.2-Vision-style: cross-attention layers interleaved every
    `cross_every` decoder layers; the vision tower is a stub that provides
    (n_patches, d_vision) precomputed patch embeddings."""
    cross_every: int = 5
    n_patches: int = 1601
    d_vision: int = 4096


@dataclasses.dataclass(frozen=True)
class EncDecSpec:
    """Whisper-style encoder-decoder; the audio frontend is a stub that
    provides (n_frames, d_model) precomputed frame embeddings."""
    n_enc_layers: int = 4
    n_frames: int = 1500
    max_decode_len: int = 448


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    rope_theta: float = 1e6
    norm: str = "rms"                 # rms | ln
    act: str = "swiglu"               # swiglu | gelu
    attn_bias: bool = False           # qwen-style qkv bias
    attn_impl: str = "grouped"        # grouped | repeat (see layers.gqa_*)
    softmax_dtype: str = "f32"        # f32 | bf16 attention-score dtype
    fused_proj: bool = False          # pack wk+wv and w_gate+w_up (1 bwd AR)
    attn_seq_shard: bool = False      # shard scores' query-seq dim on model
    sliding_window: Optional[int] = None   # sub-quadratic attention variant
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    hybrid: Optional[HybridSpec] = None
    vlm: Optional[VLMSpec] = None
    encdec: Optional[EncDecSpec] = None
    tie_embeddings: bool = False
    source: str = ""                  # citation bracket from the assignment

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def supports_shape(self, shape_name: str) -> bool:
        """long_500k needs sub-quadratic attention (SSM/hybrid natively, or a
        sliding-window variant — the dry-run applies one for full-attention
        archs, see DESIGN.md §4).  Shapes beyond an enc-dec model's real max
        decode length exercise the backbone only (noted in DESIGN.md)."""
        if shape_name == "long_500k":
            return (self.arch_type in ("ssm", "hybrid")
                    or self.sliding_window is not None)
        return True

    def with_sliding_window(self, window: int = 8192) -> "ArchConfig":
        """The sub-quadratic variant used for long_500k on full-attention
        archs (rolling KV cache of `window` slots)."""
        return dataclasses.replace(
            self, name=f"{self.name}-sw{window}", sliding_window=window)

    def reduced(self) -> "ArchConfig":
        """Family-preserving smoke variant: <=2 layers, d_model<=512,
        <=4 experts, small vocab."""
        d_model = min(self.d_model, 256)
        n_kv = min(self.n_kv_heads, 2) if self.n_kv_heads else 0
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        if n_heads:
            n_heads = (n_heads // n_kv) * n_kv or n_kv
        repl = {
            "n_layers": min(self.n_layers, 2),
            "d_model": d_model,
            "n_heads": n_heads,
            "n_kv_heads": n_kv,
            "d_ff": min(self.d_ff, 512) if self.d_ff else 0,
            "vocab": min(self.vocab, 512),
            "head_dim": 64,
            "sliding_window": 64 if self.sliding_window else None,
        }
        if self.moe:
            repl["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2), group_size=64,
                every=min(self.moe.every, 2))
        if self.ssm:
            repl["ssm"] = dataclasses.replace(
                self.ssm, d_state=min(self.ssm.d_state, 16), headdim=32,
                chunk=16)
        if self.hybrid:
            repl["hybrid"] = dataclasses.replace(self.hybrid, attn_every=2)
        if self.vlm:
            repl["vlm"] = dataclasses.replace(
                self.vlm, cross_every=2, n_patches=16, d_vision=d_model)
        if self.encdec:
            repl["encdec"] = dataclasses.replace(
                self.encdec, n_enc_layers=2, n_frames=24, max_decode_len=64)
        return dataclasses.replace(self, name=self.name + "-reduced", **repl)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch config: {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # import the config modules for their registration side effects
    from repro import configs as _c  # noqa: F401
    _c.load_all()


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; never allocate)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape_name: str,
                token_dtype=jnp.int32) -> dict:
    """ShapeDtypeStruct pytree for every model input of the given shape.

    train:   {tokens (B, S), targets (B, S)}  [+ modality stubs]
    prefill: {tokens (B, S)}                  [+ modality stubs]
    decode:  {token (B, 1), pos scalar}; the cache spec comes from the model
             via `repro.models.transformer.cache_specs`.
    """
    spec = INPUT_SHAPES[shape_name]
    B, S = spec["global_batch"], spec["seq_len"]
    kind = spec["kind"]
    sds = jax.ShapeDtypeStruct
    out: dict = {}
    if kind == "train":
        out["tokens"] = sds((B, S), token_dtype)
        out["targets"] = sds((B, S), token_dtype)
    elif kind == "prefill":
        out["tokens"] = sds((B, S), token_dtype)
    else:  # decode
        out["token"] = sds((B, 1), token_dtype)
        out["pos"] = sds((), jnp.int32)
    if cfg.vlm is not None:
        out["patches"] = sds((B, cfg.vlm.n_patches, cfg.vlm.d_vision),
                             jnp.bfloat16)
    if cfg.encdec is not None:
        out["frames"] = sds((B, cfg.encdec.n_frames, cfg.d_model),
                            jnp.bfloat16)
    return out
