"""minitron-4b (pruned nemotron) [arXiv:2407.14679]

32L, d_model 3072, 24 heads (GQA kv=8), d_ff 9216, vocab 256000.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minitron-4b",
    arch_type="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab=256000,
    rope_theta=1e4,
    head_dim=128,
    source="arXiv:2407.14679",
))
