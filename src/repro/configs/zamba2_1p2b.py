"""zamba2-1.2b [arXiv:2411.15242]

38 Mamba2 layers, d_model 2048, ssm_state 64, plus ONE weight-shared
attention+MLP block (32 heads, MHA kv=32, d_ff 8192) applied every 6 layers,
vocab 32000.
"""
from .base import ArchConfig, HybridSpec, SSMSpec, register

CONFIG = register(ArchConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    rope_theta=1e4,
    ssm=SSMSpec(d_state=64, headdim=64, n_groups=1, expand=2),
    hybrid=HybridSpec(attn_every=6),
    source="arXiv:2411.15242",
))
