"""Assigned architecture configs (+ the paper's own linreg workload).

Importing this package loads every config module so `get_config`/`list_archs`
see all registrations."""
import importlib

from .base import (ArchConfig, EncDecSpec, HybridSpec, INPUT_SHAPES, MoESpec,
                   SSMSpec, VLMSpec, get_config, input_specs, list_archs,
                   register)

_MODULES = [
    "phi35_moe", "codeqwen15_7b", "granite_8b", "zamba2_1p2b", "mamba2_1p3b",
    "llama4_maverick", "llama32_vision_11b", "mistral_large_123b",
    "minitron_4b", "whisper_tiny", "lm_100m",
]

# the ten assigned architectures (lm-100m is an examples-only extra)
ASSIGNED = [
    "phi3.5-moe-42b-a6.6b", "codeqwen1.5-7b", "granite-8b", "zamba2-1.2b",
    "mamba2-1.3b", "llama4-maverick-400b-a17b", "llama-3.2-vision-11b",
    "mistral-large-123b", "minitron-4b", "whisper-tiny",
]

_loaded = False


def load_all() -> None:
    global _loaded
    if _loaded:
        return
    _loaded = True
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")


__all__ = ["ASSIGNED", "ArchConfig", "EncDecSpec", "HybridSpec",
           "INPUT_SHAPES", "MoESpec", "SSMSpec", "VLMSpec", "get_config",
           "input_specs", "list_archs", "register", "load_all"]
