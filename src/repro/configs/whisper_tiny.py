"""whisper-tiny [arXiv:2212.04356]

Encoder-decoder, 4 layers each, d_model 384, 6 heads (MHA kv=6),
d_ff 1536, vocab 51865.  LayerNorm + GELU (Whisper flavor).  The
mel-spectrogram + conv frontend is a stub: input_specs() provides
precomputed (n_frames=1500, d_model) frame embeddings.  The real decoder
caps at 448 positions; the 32k/500k decode shapes exercise the backbone
only (DESIGN.md §4).
"""
from .base import ArchConfig, EncDecSpec, register

CONFIG = register(ArchConfig(
    name="whisper-tiny",
    arch_type="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    norm="ln",
    act="gelu",
    rope_theta=1e4,
    encdec=EncDecSpec(n_enc_layers=4, n_frames=1500, max_decode_len=448),
    source="arXiv:2212.04356",
))
