"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct]

32L, d_model 4096, 32 heads (GQA kv=8), per-expert d_ff 6400, vocab 32064,
16 experts top-2 on every layer.  ~42B total params, ~6.6B active.
"""
from .base import ArchConfig, MoESpec, register

CONFIG = register(ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab=32064,
    rope_theta=1e4,
    moe=MoESpec(n_experts=16, top_k=2, every=1),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
))
