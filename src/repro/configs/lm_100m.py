"""lm-100m: the ~100M-param end-to-end training example config (not part of
the assigned pool; used by examples/train_lm.py as the paper-scale driver).
12L, d_model 768, 12 heads (GQA kv=4), d_ff 3072, vocab 32768 => ~135M total
(~85M non-embedding)."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="lm-100m",
    arch_type="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=3072,
    vocab=32768,
    head_dim=64,
    rope_theta=1e4,
    source="examples",
))
