"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B]

32L, d_model 4096, 32 heads (GQA kv=32 — i.e. MHA), d_ff 13440,
vocab 92416.  Qwen1.5 flavor: QKV bias enabled.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="codeqwen1.5-7b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    rope_theta=1e6,
    attn_bias=True,
    source="hf:Qwen/CodeQwen1.5-7B",
))
