"""Float64 NumPy oracle for the Rényi-DP accountant.

Mirrors `repro.privacy.accountant` loop-by-loop in plain NumPy +
`math.lgamma` — per-order binomial sums with an explicit log-sum-exp, the
same hybrid order grid (exact subsampled RDP at the small integer orders,
the unsubsampled Gaussian bound at the large ones), and the same improved
RDP -> (epsilon, delta) conversion.  Two jobs only:

  * parity oracle for the jitted accountant and the batched calibration
    round-trip (tests/test_privacy.py: epsilon within 1e-6 relative,
    calibration round-trip within 1e-3 relative);
  * the closed-form anchor: at `sample_frac == 1` the subsampled RDP
    curve must equal the Gaussian mechanism's `alpha / (2 sigma^2)`
    exactly (<= 1e-6 relative), which pins the binomial expansion to the
    textbook closed form.

Nothing in the production path imports this module.
"""
from __future__ import annotations

import math

import numpy as np

from .accountant import DEFAULT_ORDERS, LARGE_ORDERS, SMALL_ORDERS


def gaussian_rdp_closed_form(noise_multiplier: float,
                             orders: np.ndarray) -> np.ndarray:
    """Unsubsampled Gaussian mechanism RDP: alpha / (2 sigma^2)."""
    orders = np.asarray(orders, dtype=np.float64)
    return orders / (2.0 * float(noise_multiplier) ** 2)


def rdp_sgm_reference(noise_multiplier: float,
                      sample_frac: float) -> np.ndarray:
    """Per-round RDP at every `DEFAULT_ORDERS` order (scalar inputs).

    Small integer orders: the exact subsampled-Gaussian binomial sum,
    accumulated in log space with an explicit running log-sum-exp.  Large
    orders: the Gaussian upper bound (see `accountant` module docs).
    """
    sigma = float(noise_multiplier)
    q = float(sample_frac)
    if sigma <= 0.0:
        return np.full(DEFAULT_ORDERS.shape, np.inf)

    rdp = []
    for alpha_f in SMALL_ORDERS:
        alpha = int(alpha_f)
        log_terms = []
        for k in range(alpha + 1):
            log_binom = (math.lgamma(alpha + 1.0) - math.lgamma(k + 1.0)
                         - math.lgamma(alpha - k + 1.0))
            if q < 1.0:
                log_w = log_binom + k * math.log(q) \
                    + (alpha - k) * math.log1p(-q)
            elif k < alpha:
                continue  # (1-q)^(alpha-k) == 0 kills every k < alpha
            else:
                log_w = 0.0
            log_terms.append(log_w + k * (k - 1) / (2.0 * sigma * sigma))
        peak = max(log_terms)
        log_a = peak + math.log(
            sum(math.exp(t - peak) for t in log_terms))
        rdp.append(log_a / (alpha - 1.0))
    return np.concatenate([
        np.array(rdp, dtype=np.float64),
        gaussian_rdp_closed_form(sigma, LARGE_ORDERS)])


def epsilon_from_rdp_reference(rdp_per_round: np.ndarray, rounds: int,
                               delta: float) -> float:
    """Compose and convert: min over orders of the improved conversion."""
    best = np.inf
    for alpha, rdp in zip(DEFAULT_ORDERS, rdp_per_round):
        eps = (rounds * rdp + math.log1p(-1.0 / alpha)
               - (math.log(delta) + math.log(alpha)) / (alpha - 1.0))
        best = min(best, eps)
    return max(best, 0.0)


def epsilon_spent_reference(noise_multiplier: float, sample_frac: float,
                            rounds: int, delta: float) -> float:
    """Scalar float64 mirror of `repro.privacy.epsilon_spent`."""
    return epsilon_from_rdp_reference(
        rdp_sgm_reference(noise_multiplier, sample_frac), rounds, delta)
