"""Inverse privacy calibration: epsilon target -> noise multiplier.

`calibrate_noise(epsilon_target, delta, rounds, sample_frac)` finds the
SMALLEST noise multiplier whose composed budget (per
`repro.privacy.accountant.epsilon_spent`) stays within the target — the
knob users actually hold ("train to epsilon 2 at delta 1e-5"), with the
accountant's forward map inverted numerically.

The solve follows `repro.plan.solver._solve_grid`'s shape: epsilon is
strictly decreasing in sigma, so a bracket-expansion phase (doubling
steps) finds a feasible upper end, then monotone grid refinement shrinks
the bracket by `GRID_POINTS` per round until it is `eps_rel`-relative
tight.  Everything is batched: the epsilon evaluation is one fused
(B, S, A, K) tensor expression over the whole sigma grid of every request
at once, so an entire epsilon-sweep (`benchmarks/fig_privacy.py`, or
`repro.plan.srv_weight_for_epsilon` feeding a `plan_sweep`) calibrates in
ONE jitted call.

The returned sigma sits at the bracket's feasible end, so the calibration
is conservative by construction — `epsilon_spent(sigma) <= epsilon_target`
— while the tight bracket keeps the round-trip within 1e-3 relative of
the target (enforced against the float64 NumPy oracle in
tests/test_privacy.py).  Targets below the order grid's achievable floor
(~5e-4 at delta = 1e-5; see `accountant.DEFAULT_ORDERS`) raise
RuntimeError, mirroring the planner's infeasible-fleet contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .accountant import (_eps_from_total_rdp, _rdp_all_orders, _validate)

GRID_POINTS = 16    # sigma-grid resolution per refinement round
MAX_ROUNDS = 24     # refinement cap (16^24 of dynamic range)
MAX_DOUBLINGS = 60  # bracket-expansion cap (matches repro.plan.solver)


@jax.jit
def _calibrate_grid(target, delta, rounds, q, sig_hi0, eps_rel, frac):
    """Batched grid-then-polish solve for the minimal feasible sigma.

    target/delta/rounds/q: (B,) float64    sig_hi0: (B,) initial bracket
    eps_rel: scalar relative sigma tolerance    frac: (S,) grid fractions

    Returns (sigma, eps_at_sigma, feasible).  epsilon(sigma) is evaluated
    for a whole (B, S) sigma grid per refinement round — one fused tensor
    expression, never a per-request host loop.
    """
    def eps_at(sig):                                         # (B, S')
        rdp = _rdp_all_orders(sig, q[:, None]) \
            * rounds[:, None, None]
        return _eps_from_total_rdp(rdp, delta[:, None])

    # --- bracket expansion: grow sig_hi until eps(sig_hi) <= target ------
    eps0 = eps_at(sig_hi0[:, None])[:, 0]

    def b_cond(st):
        _, _, eps, i = st
        return jnp.logical_and(i < MAX_DOUBLINGS, jnp.any(eps > target))

    def b_body(st):
        hi, step, eps, i = st
        need = eps > target
        hi_new = jnp.where(need, hi + step, hi)
        step = jnp.where(need, 2.0 * step, step)
        eps_new = jnp.where(need, eps_at(hi_new[:, None])[:, 0], eps)
        return hi_new, step, eps_new, i + 1

    sig_hi, _, eps_hi, _ = jax.lax.while_loop(
        b_cond, b_body, (sig_hi0, sig_hi0, eps0, jnp.asarray(0)))
    feasible = eps_hi <= target

    # --- monotone grid refinement on sigma -------------------------------
    sig_lo = jnp.zeros_like(sig_hi)

    def _active(lo, hi):
        wide = (hi - lo) > eps_rel * jnp.maximum(hi, 1e-30)
        return jnp.logical_and(wide, feasible)

    def r_cond(st):
        lo, hi, r = st
        return jnp.logical_and(r < MAX_ROUNDS, jnp.any(_active(lo, hi)))

    def r_body(st):
        lo, hi, r = st
        grid = lo[:, None] + frac[None, :] * (hi - lo)[:, None]
        grid = grid.at[:, -1].set(hi)  # exact upper edge: invariant
        ok = eps_at(grid) <= target[:, None]
        idx = jnp.argmax(ok, axis=1)   # first feasible grid point
        hi_new = jnp.take_along_axis(grid, idx[:, None], axis=1)[:, 0]
        lo_prev = jnp.take_along_axis(
            grid, jnp.maximum(idx - 1, 0)[:, None], axis=1)[:, 0]
        lo_new = jnp.where(idx == 0, lo, lo_prev)
        act = _active(lo, hi)
        return (jnp.where(act, lo_new, lo),
                jnp.where(act, hi_new, hi), r + 1)

    _, sigma, _ = jax.lax.while_loop(
        r_cond, r_body, (sig_lo, sig_hi, jnp.asarray(0)))
    return sigma, eps_at(sigma[:, None])[:, 0], feasible


def calibrate_noise(epsilon_target, delta=1e-5, rounds=1, sample_frac=1.0,
                    eps_rel: float = 1e-6):
    """Smallest noise multiplier with epsilon_spent <= epsilon_target.

    All four budget arguments broadcast; array targets calibrate a whole
    epsilon-sweep in one batched jitted solve.  Scalars in -> float out.
    Raises RuntimeError when a target sits below the order grid's
    achievable epsilon floor (no finite noise reaches it).
    """
    _validate(sample_frac, rounds, delta)
    tgt = np.asarray(epsilon_target, dtype=np.float64)
    if np.any(tgt <= 0.0):
        raise ValueError(f"epsilon_target must be > 0, got {tgt}")
    args = np.broadcast_arrays(
        tgt, np.asarray(delta, dtype=np.float64),
        np.asarray(rounds, dtype=np.float64),
        np.asarray(sample_frac, dtype=np.float64))
    shape = args[0].shape
    flat = [np.ascontiguousarray(a).reshape(-1) for a in args]
    frac = np.arange(1, GRID_POINTS + 1, dtype=np.float64) / GRID_POINTS

    with jax.experimental.enable_x64():
        sigma, eps, feasible = (np.asarray(o) for o in _calibrate_grid(
            flat[0], flat[1], flat[2], flat[3],
            np.ones_like(flat[0]), np.float64(eps_rel), frac))

    if not feasible.all():
        bad = np.flatnonzero(~feasible)
        detail = "; ".join(
            f"target epsilon {flat[0][j]:.2e} (delta {flat[1][j]:.0e}, "
            f"rounds {flat[2][j]:.0f}): best achievable {eps[j]:.2e}"
            for j in bad)
        raise RuntimeError(
            "epsilon target below the accountant's achievable floor — no "
            f"finite noise multiplier reaches it: {detail}")

    out = sigma.reshape(shape)
    return float(out) if out.ndim == 0 else out
