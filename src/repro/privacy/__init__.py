"""Privacy accounting subsystem: (epsilon, delta)-DP semantics for the
stochastic coded-FL noise knob.

Forward direction (`repro.privacy.accountant`): a Rényi-DP accountant for
the subsampled Gaussian mechanism prices `rounds` training releases at
`(noise_multiplier, sample_frac)` as a composed (epsilon, delta) budget —
`epsilon_spent` (vectorized over whole sweeps) and `epsilon_schedule`
(the per-round cumulative trajectory `StochasticCodedFL` surfaces on
`TraceReport.extras`).

Inverse direction (`repro.privacy.calibrate`): `calibrate_noise` turns an
epsilon target back into the smallest adequate noise multiplier via a
vectorized, jitted grid-then-polish solve in the style of
`repro.plan._solve_grid`, so an entire epsilon-sweep calibrates in one
batched call.

`repro.privacy.reference` holds the float64 NumPy oracle both directions
are tested against (and nothing in the production path imports it).
"""
from .accountant import (DEFAULT_ORDERS, epsilon_schedule, epsilon_spent)
from .calibrate import calibrate_noise

__all__ = [
    "DEFAULT_ORDERS", "calibrate_noise", "epsilon_schedule",
    "epsilon_spent",
]
