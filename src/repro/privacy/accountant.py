"""Rényi-DP accountant for the subsampled Gaussian mechanism.

Maps `StochasticCodedFL`'s two knobs — `noise_multiplier` (Gaussian noise
std relative to the coded data's RMS) and `sample_frac` (per-round
Bernoulli parity-row sampling rate) — to a composed (epsilon, delta)
privacy budget over training, making the ROADMAP's "bare noise knob"
quantitative.  The model is the standard DP-SGD-style accountant shape
(Mironov 2017; Mironov-Talwar-Zhang 2019): each training round is one
release of a Poisson-subsampled Gaussian mechanism with sampling
probability `q = sample_frac` and noise multiplier `sigma =
noise_multiplier`; rounds compose additively in the RDP domain and the
total converts to (epsilon, delta) at the end.

Order grid (`DEFAULT_ORDERS`), a deliberate hybrid:

  * **integer orders 2..64** — the exact subsampled-Gaussian RDP via the
    binomial expansion (log-domain, stable at q = 1):

        A_alpha = sum_k C(alpha,k) (1-q)^(alpha-k) q^k e^(k(k-1)/(2 sigma^2))
        rdp(alpha) = log(A_alpha) / (alpha - 1)

  * **large orders 80..4096** — bounded by the UNSUBSAMPLED Gaussian RDP
    `alpha / (2 sigma^2)`.  Valid because subsampling only lowers RDP
    (A is a Binomial(alpha, q) expectation of a convex increasing
    function of k, so A(q) <= A(1)), and near-tight in this repo's
    high-`sample_frac` regime (SCFL samples most parity rows every
    round, unlike DP-SGD's tiny minibatch rates).  The large orders
    extend the achievable epsilon floor down to ~5e-4 at delta = 1e-5
    without a (B, S, A, 4096)-wide binomial tensor.

Every candidate order yields a VALID (epsilon, delta) bound, so the min
over the grid is valid; capping the grid only makes the answer
conservative.  RDP -> (epsilon, delta) uses the improved conversion
(Balle et al. 2020, the one production accountants ship):

    epsilon = min_alpha [ rdp(alpha) + log1p(-1/alpha)
                          - (log(delta) + log(alpha)) / (alpha - 1) ]

All arithmetic runs in float64 under a scoped `enable_x64` (the same
pattern as `repro.plan.solver`); the float64 NumPy oracle in
`repro.privacy.reference` mirrors these expressions loop-by-loop and the
two must agree to <= 1e-6 relative (tests/test_privacy.py).

The inverse problem — `calibrate_noise(epsilon_target, ...)` — lives in
`repro.privacy.calibrate` as a vectorized, jitted grid-then-polish solve.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

# Exact-subsampled integer orders (binomial sum over k = 0..alpha).
SMALL_ORDERS = np.arange(2, 65, dtype=np.float64)
# Gaussian-bounded large orders: push the epsilon floor down for
# tight-privacy calibrations while keeping the k axis at 65 entries.
LARGE_ORDERS = np.array([80.0, 96.0, 128.0, 192.0, 256.0, 384.0, 512.0,
                         768.0, 1024.0, 1536.0, 2048.0, 3072.0, 4096.0])
DEFAULT_ORDERS = np.concatenate([SMALL_ORDERS, LARGE_ORDERS])

_KS = np.arange(0, int(SMALL_ORDERS[-1]) + 1, dtype=np.float64)
# log C(alpha, k) for the small integer orders; -inf marks k > alpha so
# logsumexp drops those terms exactly.
_LOG_BINOM = np.full((SMALL_ORDERS.size, _KS.size), -np.inf)
for _i, _alpha in enumerate(SMALL_ORDERS):
    for _k in range(int(_alpha) + 1):
        _LOG_BINOM[_i, _k] = (math.lgamma(_alpha + 1.0)
                              - math.lgamma(_k + 1.0)
                              - math.lgamma(_alpha - _k + 1.0))


def _rdp_all_orders(sigma, q):
    """Per-round RDP at every `DEFAULT_ORDERS` order (traceable).

    sigma, q: broadcast-compatible float arrays -> (..., A).  sigma == 0
    produces non-finite garbage here; callers mask it to +inf (zero noise
    means no privacy).
    """
    sig2 = (sigma * sigma)[..., None, None]
    logq = jnp.log(q)[..., None, None]
    # 0 * log(0) -> 0 by convention: at q == 1 the k < alpha terms carry
    # log(1-q) = -inf and vanish, while the k == alpha term (coefficient
    # exactly 0) takes the where's 0 branch — reproducing the pure
    # Gaussian RDP alpha / (2 sigma^2) exactly.
    log1mq = jnp.where(q < 1.0, jnp.log1p(-q), -jnp.inf)[..., None, None]
    coef = SMALL_ORDERS[:, None] - _KS[None, :]
    terms = (_LOG_BINOM + _KS * logq
             + jnp.where(coef > 0.0, coef * log1mq, 0.0)
             + _KS * (_KS - 1.0) / (2.0 * sig2))
    log_a = jax.scipy.special.logsumexp(terms, axis=-1)       # (..., As)
    rdp_small = log_a / (SMALL_ORDERS - 1.0)
    rdp_large = LARGE_ORDERS / (2.0 * sig2[..., 0])           # (..., Al)
    return jnp.concatenate([rdp_small, rdp_large], axis=-1)


def _eps_from_total_rdp(rdp_total, delta):
    """Improved RDP -> (epsilon, delta) conversion, min over the grid.

    rdp_total: (..., A) composed RDP;  delta: (...,) broadcastable.
    """
    a = DEFAULT_ORDERS
    eps = (rdp_total + jnp.log1p(-1.0 / a)
           - (jnp.log(delta)[..., None] + jnp.log(a)) / (a - 1.0))
    return jnp.maximum(jnp.min(eps, axis=-1), 0.0)


@jax.jit
def _epsilon_spent_grid(sigma, q, rounds, delta):
    """epsilon for broadcast (sigma, q, rounds, delta) arrays."""
    rdp = _rdp_all_orders(sigma, q) * rounds[..., None]
    return jnp.where(sigma > 0.0, _eps_from_total_rdp(rdp, delta), jnp.inf)


@jax.jit
def _epsilon_schedule_grid(sigma, q, round_grid, delta):
    """Cumulative epsilon after each round in `round_grid` (scalars in)."""
    rdp = _rdp_all_orders(sigma, q)                           # (A,)
    total = round_grid[:, None] * rdp[None, :]                # (T, A)
    eps = _eps_from_total_rdp(
        total, jnp.broadcast_to(delta, round_grid.shape))
    return jnp.where(sigma > 0.0, eps, jnp.inf)


def _validate(sample_frac, rounds, delta) -> None:
    sample_frac = np.asarray(sample_frac, dtype=np.float64)
    if np.any(sample_frac <= 0.0) or np.any(sample_frac > 1.0):
        raise ValueError(
            f"sample_frac must be in (0, 1], got {sample_frac}")
    rounds = np.asarray(rounds)
    if np.any(rounds < 1):
        raise ValueError(f"rounds must be >= 1, got {rounds}")
    delta = np.asarray(delta, dtype=np.float64)
    if np.any(delta <= 0.0) or np.any(delta >= 1.0):
        raise ValueError(f"delta must be in (0, 1), got {delta}")


def epsilon_spent(noise_multiplier, sample_frac=1.0, rounds=1,
                  delta=1e-5):
    """Composed (epsilon, delta)-DP cost of `rounds` subsampled-Gaussian
    releases at noise `noise_multiplier` and sampling rate `sample_frac`.

    All four arguments broadcast, so a whole (sigma, q, T) sweep prices in
    one vectorized call; scalars in -> Python float out.  Zero noise costs
    epsilon = +inf.
    """
    _validate(sample_frac, rounds, delta)
    nm = np.asarray(noise_multiplier, dtype=np.float64)
    if np.any(nm < 0.0):
        raise ValueError(f"noise_multiplier must be >= 0, got {nm}")
    args = np.broadcast_arrays(
        nm, np.asarray(sample_frac, dtype=np.float64),
        np.asarray(rounds, dtype=np.float64),
        np.asarray(delta, dtype=np.float64))
    with jax.experimental.enable_x64():
        out = np.asarray(_epsilon_spent_grid(*args))
    return float(out) if out.ndim == 0 else out


def epsilon_schedule(noise_multiplier, sample_frac=1.0, rounds=1,
                     delta=1e-5) -> np.ndarray:
    """(rounds,) cumulative epsilon spent after rounds 1..rounds.

    The per-round trajectory `StochasticCodedFL.report_extras` surfaces on
    `TraceReport.extras["epsilon_schedule"]`.  Scalar arguments only (one
    strategy's accounting; sweeps vectorize through `epsilon_spent`).
    """
    _validate(sample_frac, rounds, delta)
    nm = float(noise_multiplier)
    if nm < 0.0:
        raise ValueError(f"noise_multiplier must be >= 0, got {nm}")
    grid = np.arange(1, int(rounds) + 1, dtype=np.float64)
    with jax.experimental.enable_x64():
        out = np.asarray(_epsilon_schedule_grid(
            np.float64(nm), np.float64(sample_frac), grid,
            np.float64(delta)))
    return out
