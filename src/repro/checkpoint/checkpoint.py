"""msgpack-based checkpointing for arbitrary pytrees of jnp arrays.

Layout: <dir>/step_<n>.msgpack, each file a self-contained flat map
{path -> {dtype, shape, raw bytes}} plus the saved step.  Restore rebuilds
into a caller-supplied pytree template (so shardings/dtypes are re-applied
by the caller) or into plain numpy when no template is given.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    payload = {
        "step": step,
        "arrays": {
            k: {"dtype": str(v.dtype), "shape": list(v.shape),
                "data": v.tobytes()}
            for k, v in flat.items()
        },
    }
    path = os.path.join(ckpt_dir, f"step_{step:08d}.msgpack")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)  # atomic publish
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for name in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)\.msgpack", name))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, template: Any = None,
                       step: Optional[int] = None) -> tuple[int, Any]:
    """Returns (step, tree).  With a template, leaves are cast to the
    template's dtypes and validated against its shapes."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.msgpack")
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    arrays = {
        k: np.frombuffer(v["data"], dtype=np.dtype(v["dtype"]))
        .reshape(v["shape"])
        for k, v in payload["arrays"].items()
    }
    if template is None:
        return payload["step"], arrays

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for pth, leaf in leaves_with_path:
        key = _SEP.join(_path_str(p) for p in pth)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return payload["step"], jax.tree_util.tree_unflatten(treedef, out)
