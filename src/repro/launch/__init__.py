"""Launchers: production mesh, per-arch sharding rules, multi-pod dry-run,
training and serving drivers."""
