"""Multi-host bootstrap for the production pod(s).

On a real v5e deployment every host runs the same entry point; this module
wires `jax.distributed.initialize` from the standard launcher environment
(GKE/JobSet or `gcloud compute tpus tpu-vm ssh --worker=all`) and validates
that the global device count matches the requested mesh before any
computation starts.

    # per-host entry (same command on all hosts):
    python -m repro.launch.train --arch granite-8b --distributed ...

Environment (auto-detected on TPU VMs; explicit for CPU/GPU clusters):
    COORDINATOR_ADDRESS   host:port of process 0
    NUM_PROCESSES         total process count
    PROCESS_ID            this process's rank
"""
from __future__ import annotations

import os
from typing import Optional

import jax


def initialize_distributed(coordinator: Optional[str] = None,
                           num_processes: Optional[int] = None,
                           process_id: Optional[int] = None,
                           auto: bool = False) -> bool:
    """Initialize the JAX distributed runtime if a cluster env is present.

    Returns True when multi-process mode is active.  Explicit signals only:
    either a coordinator address (argument or COORDINATOR_ADDRESS env) or
    `auto=True` on a TPU VM, where jax.distributed.initialize() self-
    discovers the slice topology.  (Do NOT sniff TPU_SKIP_MDS_QUERY — jax
    sets it itself during platform probing.)  Safe no-op otherwise."""
    coordinator = coordinator or os.environ.get("COORDINATOR_ADDRESS")
    num_processes = num_processes or _int_env("NUM_PROCESSES")
    process_id = process_id if process_id is not None else _int_env(
        "PROCESS_ID")
    if coordinator is None and not auto:
        return False
    if coordinator is None:
        jax.distributed.initialize()  # TPU-VM auto-detection
    else:
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=num_processes,
                                   process_id=process_id)
    return jax.process_count() > 1


def _int_env(name: str) -> Optional[int]:
    v = os.environ.get(name)
    return int(v) if v is not None else None


def validate_mesh_capacity(*, multi_pod: bool = False) -> None:
    """Fail fast if the cluster doesn't provide the production chip count."""
    from .mesh import MULTI_POD_SHAPE, SINGLE_POD_SHAPE
    import numpy as np
    want = int(np.prod(MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE))
    have = jax.device_count()
    if have != want:
        raise RuntimeError(
            f"mesh needs {want} devices, cluster exposes {have}; "
            f"for a dry run use repro.launch.dryrun (placeholder devices)")


def is_coordinator() -> bool:
    return jax.process_index() == 0


def sync_hosts(name: str = "barrier") -> None:
    """Cross-host barrier (e.g. before checkpoint publish)."""
    if jax.process_count() > 1:
        # tiny all-reduce doubles as a barrier
        import jax.numpy as jnp
        x = jnp.ones(())
        jax.block_until_ready(
            jax.pmap(lambda v: jax.lax.psum(v, "i"), axis_name="i")(
                x[None]))
