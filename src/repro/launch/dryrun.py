import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with 512 placeholder host devices standing in for the
chips.  No arrays are ever allocated: params/opt-state/batch/caches are all
ShapeDtypeStructs via jax.eval_shape.

For each combination we record:
  * memory_analysis()  — bytes per device (proves the sharding fits),
  * cost_analysis()    — HLO FLOPs / bytes for the roofline terms,
  * collective bytes   — parsed from the compiled HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
     collective-permute operand sizes).

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]

Results accumulate into the JSON so the full 10x4x2 sweep can run
incrementally.
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (ASSIGNED, INPUT_SHAPES, get_config,
                           input_specs)
from repro.configs.base import ArchConfig
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_shardings, cache_shardings,
                                   opt_state_shardings, param_shardings,
                                   replicated)
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step)
from repro.models import transformer as T
from repro.optim.optimizers import make_optimizer

# >40B models run bf16 optimizer moments so state fits HBM (DESIGN.md §5)
from repro.launch.sharding import FSDP_ARCHS


# winning §Perf recipes per architecture family (EXPERIMENTS.md §Perf):
# applied by --optimized to record the beyond-paper-optimized table next to
# the paper-faithful baseline.
import dataclasses as _dc


def optimize_config(cfg: ArchConfig, kind: str = "train") -> ArchConfig:
    """kind: train | prefill | decode.  The repeat-KV attention recipe only
    pays off for full-sequence passes; at decode it would materialize the
    R-fold repeated KV cache (measured 2-9x regression), so decode keeps the
    grouped path."""
    repl: dict = {}
    if kind in ("train", "prefill") and cfg.n_heads             and cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        repl["attn_impl"] = "repeat"
        repl["softmax_dtype"] = "bf16"
        if cfg.n_heads % 16 != 0 and cfg.n_heads > 16:
            # heads don't divide the model axis: pad-shard the score head
            # dim explicitly or SPMD replicates the (B,H,S,T) tensor
            repl["attn_seq_shard"] = "head"
    if cfg.ssm is not None:
        repl["ssm"] = _dc.replace(cfg.ssm, head_shard=True)
    if cfg.moe is not None:
        repl["moe"] = _dc.replace(cfg.moe, capacity_factor=1.25)
    return _dc.replace(cfg, **repl) if repl else cfg


def _maybe_sliding_window(cfg: ArchConfig, shape_name: str) -> ArchConfig:
    """long_500k on a full-attention arch runs the sliding-window variant."""
    if shape_name == "long_500k" and not cfg.supports_shape("long_500k"):
        if cfg.arch_type in ("dense", "moe", "vlm"):
            return cfg.with_sliding_window(8192)
    return cfg


def plan_combinations(archs, shapes):
    """All (arch, shape, effective_cfg) combos that lower; skips recorded."""
    combos, skips = [], []
    for a in archs:
        base = get_config(a)
        for s in shapes:
            cfg = _maybe_sliding_window(base, s)
            if cfg.supports_shape(s):
                combos.append((a, s, cfg))
            else:
                skips.append((a, s, "no sub-quadratic attention variant"))
    return combos, skips


def lower_one(cfg: ArchConfig, shape_name: str, mesh,
              opt_name: str = "adamw", remat="full", zero1: bool = False):
    """Lower + compile one (arch, shape) on `mesh`; returns stats dict."""
    spec = INPUT_SHAPES[shape_name]
    kind = spec["kind"]
    batch_sds = input_specs(cfg, shape_name)

    params_sds = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0),
                              dtype=jnp.bfloat16))
    p_sh = param_shardings(cfg, mesh, params_sds)
    b_sh = batch_shardings(cfg, mesh, batch_sds)

    t0 = time.time()
    if kind == "train":
        from repro.launch.sharding import base_arch_name
        state_dtype = jnp.bfloat16 if base_arch_name(cfg.name) in FSDP_ARCHS \
            else jnp.float32
        opt = make_optimizer(opt_name, 1e-4, state_dtype=state_dtype)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        o_sh = opt_state_shardings(mesh, p_sh, opt_sds, zero1=zero1)
        step = make_train_step(
            cfg, opt, remat=remat if remat != "full" else True)
        jitted = jax.jit(step,
                         in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, replicated(mesh, {"loss": 0.0, "moe_aux_loss": 0.0}
                                                               if cfg.moe else {"loss": 0.0})),
                         donate_argnums=(0, 1))
        with mesh:
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
    elif kind == "prefill":
        step = make_prefill_step(cfg)
        cache_sds = jax.eval_shape(
            lambda: T.init_cache(cfg, spec["global_batch"], spec["seq_len"],
                                 dtype=jnp.bfloat16))
        c_sh = cache_shardings(cfg, mesh, cache_sds)
        logits_sds = jax.ShapeDtypeStruct(
            (spec["global_batch"], 1, cfg.vocab), jnp.float32)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                         out_shardings=(batch_shardings(cfg, mesh,
                                                        logits_sds), c_sh))
        with mesh:
            lowered = jitted.lower(params_sds, batch_sds)
    else:  # decode
        step = make_decode_step(cfg)
        cache_sds = jax.eval_shape(
            lambda: T.init_cache(cfg, spec["global_batch"], spec["seq_len"],
                                 dtype=jnp.bfloat16))
        c_sh = cache_shardings(cfg, mesh, cache_sds)
        logits_sds = jax.ShapeDtypeStruct(
            (spec["global_batch"], 1, cfg.vocab), jnp.float32)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh),
                         out_shardings=(batch_shardings(cfg, mesh,
                                                        logits_sds), c_sh),
                         donate_argnums=(2,))
        with mesh:
            lowered = jitted.lower(params_sds, batch_sds, cache_sds)

    compiled = lowered.compile()
    dt = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    coll = hlo_stats.collective_bytes(hlo_text)
    # trip-count-corrected totals (XLA cost_analysis counts scan bodies once)
    from repro.roofline.hlo_graph import module_stats
    corrected = module_stats(hlo_text)
    n_params = sum(x.size for x in jax.tree.leaves(params_sds))
    stats = {
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": mesh.devices.size,
        "n_params": int(n_params),
        "compile_s": round(dt, 1),
        "flops": float(cost.get("flops", -1.0)),
        "hlo_bytes": float(cost.get("bytes accessed", -1.0)),
        "collective_bytes": coll,
        "corrected_flops": corrected["flops"],
        "corrected_bytes": corrected["bytes"],
        "corrected_collectives": corrected["collectives"],
        "memory": {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size": getattr(mem,
                                           "generated_code_size_in_bytes",
                                           None),
        },
    }
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=[None] + list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf winning recipes (separate table)")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args(argv)

    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    try:
        with open(args.out) as f:
            results = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        results = {"runs": {}, "skips": {}}

    combos, skips = plan_combinations(archs, shapes)
    for a, s, why in skips:
        results["skips"][f"{a}|{s}"] = why
        print(f"SKIP {a} x {s}: {why}")

    n_fail = 0
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "x".join(str(x) for x in mesh.devices.shape)
        for a, s, cfg in combos:
            key = f"{a}|{s}|{mesh_name}"
            if key in results["runs"] and results["runs"][key].get("ok"):
                print(f"CACHED {key}")
                continue
            print(f"RUN {key} ...", flush=True)
            try:
                kind = INPUT_SHAPES[s]["kind"]
                run_cfg = optimize_config(cfg, kind) if args.optimized \
                    else cfg
                stats = lower_one(run_cfg, s, mesh,
                                  remat="save_ar" if args.optimized
                                  else "full",
                                  zero1=args.optimized)
                stats["ok"] = True
                results["runs"][key] = stats
                gb = (stats["memory"]["argument_size"] or 0) / 1e9
                print(f"  ok: {stats['compile_s']}s compile, "
                      f"{stats['flops']:.3e} flops, "
                      f"args {gb:.2f} GB/dev, "
                      f"coll {sum(stats['collective_bytes'].values()):.3e} B")
            except Exception as e:  # noqa: BLE001 — record and continue
                n_fail += 1
                results["runs"][key] = {"ok": False, "error": str(e)[:2000]}
                print(f"  FAIL: {e}")
                traceback.print_exc()
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    print(f"done; {n_fail} failures")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
