"""Serving driver: batched prefill + autoregressive decode with KV/SSM
caches.

  python -m repro.launch.serve --arch granite-8b --reduced \\
      --batch 4 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import transformer as T


def greedy_generate(cfg, params, prompt: jax.Array, new_tokens: int,
                    extra: dict, compute_dtype=jnp.float32):
    """Greedy decode; returns (tokens (B, S+new), per-step seconds)."""
    B, S = prompt.shape
    cache_len = S + new_tokens
    prefill_step = jax.jit(make_prefill_step(cfg, compute_dtype,
                                             cache_len=cache_len))
    decode = jax.jit(make_decode_step(cfg, compute_dtype))

    batch = {"tokens": prompt, **extra}
    t0 = time.perf_counter()
    logits, cache = prefill_step(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    toks = [prompt]
    step_times = []
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    for i in range(new_tokens):
        toks.append(tok)
        t0 = time.perf_counter()
        logits, cache = decode(params,
                               {"token": tok,
                                "pos": jnp.asarray(S + i, jnp.int32)},
                               cache)
        jax.block_until_ready(logits)
        step_times.append(time.perf_counter() - t0)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(toks, axis=1), t_prefill, step_times


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    prompt = jax.random.randint(jax.random.fold_in(key, 1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    extra = {}
    if cfg.vlm:
        extra["patches"] = 0.1 * jax.random.normal(
            jax.random.fold_in(key, 2),
            (args.batch, cfg.vlm.n_patches, cfg.vlm.d_vision))
    if cfg.encdec:
        extra["frames"] = 0.1 * jax.random.normal(
            jax.random.fold_in(key, 2),
            (args.batch, cfg.encdec.n_frames, cfg.d_model))

    out, t_prefill, steps = greedy_generate(cfg, params, prompt,
                                            args.new_tokens, extra)
    per_tok = float(np.median(steps))
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new_tokens}")
    print(f"prefill {t_prefill*1e3:.1f} ms; decode median "
          f"{per_tok*1e3:.2f} ms/token "
          f"({args.batch/per_tok:.1f} tok/s aggregate)")
    assert out.shape == (args.batch, args.prompt_len + args.new_tokens)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab)))
    print("output token range OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
