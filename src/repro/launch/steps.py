"""jit-able step functions (train / prefill / decode) shared by the real
drivers and the multi-pod dry-run."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.optim.optimizers import Optimizer, apply_updates


def make_train_step(cfg: ArchConfig, opt: Optimizer,
                    compute_dtype=jnp.bfloat16,
                    remat: bool = True, clip_norm: float = 0.0,
                    lr_schedule: Callable | None = None) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    clip_norm > 0 enables global-norm gradient clipping; lr_schedule(step)
    scales the optimizer's base lr (repro.optim.schedules)."""

    def train_step(params, opt_state, batch):
        def lf(p):
            return T.loss_fn(cfg, p, batch, compute_dtype=compute_dtype,
                             remat=remat)

        (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(params)
        metrics = {"loss": loss}
        if clip_norm > 0:
            from repro.optim.schedules import clip_by_global_norm
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            metrics["grad_norm"] = gnorm
        scale = (lr_schedule(opt_state.step) if lr_schedule is not None
                 else 1.0)
        updates, opt_state = opt.update(grads, opt_state, params,
                                        lr_scale=scale)
        params = apply_updates(params, updates)
        if "moe_aux_loss" in aux:
            metrics["moe_aux_loss"] = aux["moe_aux_loss"]
        return params, opt_state, metrics

    return train_step


def make_fed_train_step(cfg: ArchConfig, opt: Optimizer,
                        compute_dtype=jnp.float32,
                        remat: bool = False) -> Callable:
    """Deadline-masked federated step: per-sequence weights (0 for dropped
    clients, 1/p for received) make the aggregate unbiased (repro.fed)."""

    def step(params, opt_state, batch, seq_weights):
        def lf(p):
            logits, aux = T.forward_train(cfg, p, batch,
                                          compute_dtype=compute_dtype,
                                          remat=remat)
            targets = batch["targets"]
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(logp, targets[..., None],
                                       axis=-1)[..., 0]
            per_seq = jnp.mean(nll, axis=-1)              # (B,)
            denom = jnp.maximum(jnp.sum(seq_weights > 0), 1)
            loss = jnp.sum(per_seq * seq_weights) / denom
            if "moe_aux_loss" in aux:
                loss = loss + 0.01 * aux["moe_aux_loss"]
            return loss

        loss, grads = jax.value_and_grad(lf)(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss}

    return step


def make_prefill_step(cfg: ArchConfig, compute_dtype=jnp.bfloat16,
                      cache_len: int | None = None) -> Callable:
    def prefill_step(params, batch):
        return T.prefill(cfg, params, batch, compute_dtype=compute_dtype,
                         cache_len=cache_len)
    return prefill_step


def make_decode_step(cfg: ArchConfig, compute_dtype=jnp.bfloat16) -> Callable:
    def serve_step(params, batch, cache):
        return T.decode_step(cfg, params, batch, cache,
                             compute_dtype=compute_dtype)
    return serve_step
