"""Production mesh construction.

Single pod: 16 x 16 = 256 chips over ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips over ("pod", "data", "model").

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run must set XLA_FLAGS before the first jax call.
TPU v5e constants (roofline): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import jax

# TPU v5e per-chip hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link

SINGLE_POD_SHAPE = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD_SHAPE = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> jax.sharding.Mesh:
    """A tiny mesh over whatever devices exist (CPU tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def lane_mesh_size(n_lanes: int) -> int:
    """Device count for a sweep's lane axis: the largest divisor of
    `n_lanes` that fits the local device count.

    Divisibility is required (lanes are split evenly across the mesh by
    `shard_map`), and an even split keeps every lane's per-device program
    identical — the sweep engine's bit-for-bit-with-solo guarantee rides
    on it.  A 16-lane sweep on the CI topology (4 host devices) uses all
    4; a 5-lane sweep uses 1.
    """
    if n_lanes < 1:
        raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
    return next(k for k in range(min(len(jax.devices()), n_lanes), 0, -1)
                if n_lanes % k == 0)


def make_lane_mesh(n_lanes: int) -> jax.sharding.Mesh:
    """1-d mesh over the lane (batch-of-sessions) axis of a sweep.

    The sweep engine (`repro.api.run_sweep`) shards its stacked per-lane
    operands over this mesh; each device runs its lanes' scans locally, so
    the mesh size never changes any lane's arithmetic.
    """
    k = lane_mesh_size(n_lanes)
    return jax.sharding.Mesh(jax.devices()[:k], ("lanes",))


def make_shard_mesh() -> jax.sharding.Mesh:
    """1-d mesh over ALL local devices for tensor-sharded solves.

    Unlike the lane mesh (whose size adapts to the lane count), the shard
    mesh always spans every local device: `repro.fleet.solve_fleet`
    splits one problem's DEVICE axis across it, so more devices means a
    smaller per-device slab of the `(t_grid, n, L)` expected-return
    tensor, not more lanes.
    """
    return jax.sharding.Mesh(jax.devices(), ("shards",))


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The batch-parallel axes of a mesh (includes 'pod' when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
