"""Production mesh construction.

Single pod: 16 x 16 = 256 chips over ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips over ("pod", "data", "model").

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run must set XLA_FLAGS before the first jax call.
TPU v5e constants (roofline): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import jax

# TPU v5e per-chip hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link

SINGLE_POD_SHAPE = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD_SHAPE = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> jax.sharding.Mesh:
    """A tiny mesh over whatever devices exist (CPU tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The batch-parallel axes of a mesh (includes 'pod' when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
