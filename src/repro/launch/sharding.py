"""Per-architecture sharding rules.

Name-pattern rules over the parameter tree produce PartitionSpecs:

  * tensor parallel over "model": attention QKV/O output dims, MLP hidden,
    vocab/embedding, MoE expert dim (expert parallel);
  * FSDP over "data" for the >40B configs (phi3.5-moe, mistral-large,
    llama4-maverick): the non-model-sharded major dim of every large matrix
    is sharded over the data axis and all-gathered per layer inside the
    scan body; optimizer states inherit the param specs (bf16 states for
    these configs — see repro.optim);
  * Mamba mixer params stay replicated over "model" (packed projection
    boundaries do not align with shard boundaries; the models are <2B —
    revisiting this is a recorded §Perf hillclimb candidate);
  * batch (and KV caches' batch dim) over ("pod", "data"); KV head dim over
    "model" when n_kv_heads is divisible, else head_dim over "model".

Multi-pod: parameters are replicated across pods (the "pod" axis only
carries batch parallelism); gradient all-reduce crosses the pod axis.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from .mesh import data_axes

# configs large enough to need parameter (ZeRO-3 style) sharding over data
FSDP_ARCHS = {"phi3.5-moe-42b-a6.6b", "mistral-large-123b",
              "llama4-maverick-400b-a17b"}


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return n % _axis_size(mesh, axis) == 0


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(cfg: ArchConfig, mesh: Mesh, path: str,
               shape: tuple[int, ...], fsdp: bool) -> P:
    """PartitionSpec for one parameter leaf (name-pattern rules)."""
    dd = "data" if fsdp else None  # FSDP shards the complementary dim
    leaf = path.split("/")[-1]
    stacked = path.split("/")[0] in (
        "blocks", "moe_blocks", "cross_blocks", "enc_blocks")
    lead = (None,) if stacked else ()

    def spec(*axes):
        out = lead + axes
        # drop axes that don't divide
        fixed = []
        for dim, ax in zip(shape, out):
            if ax is None:
                fixed.append(None)
            elif isinstance(ax, str):
                fixed.append(ax if dim % _axis_size(mesh, ax) == 0 else None)
            else:  # tuple of axes
                size = int(np.prod([_axis_size(mesh, a) for a in ax]))
                fixed.append(ax if dim % size == 0 else None)
        return P(*fixed)

    # --- embeddings / head -------------------------------------------------
    if path == "embed":
        return spec("model", dd)
    if path == "lm_head":
        return spec(dd, "model")

    # --- MoE ----------------------------------------------------------------
    if "/moe/" in path or path.endswith("/router"):
        if leaf == "router":
            return spec(None, None)
        if leaf in ("w_gate", "w_up"):      # (E, D, F): expert parallel
            return spec("model", dd, None)
        if leaf == "w_down":                # (E, F, D)
            return spec("model", dd, None)

    # --- attention ----------------------------------------------------------
    if leaf in ("wq",):
        return spec(dd, "model")
    if leaf in ("wk", "wv", "wkv"):
        return spec(dd, "model")
    if leaf == "wo":
        return spec("model", dd)
    if leaf in ("bq", "bk", "bv", "bkv"):
        return spec("model")

    # --- dense MLP ----------------------------------------------------------
    if leaf in ("w_gate", "w_up", "w_gu"):
        return spec(dd, "model")
    if leaf == "w_down":
        return spec("model", dd)

    # --- mamba mixer -----------------------------------------------------
    # packed projection boundaries do not align with model-axis shards, so
    # tensor parallelism is off; under FSDP the big matrices still shard
    # over data (§Perf iteration C1).
    if leaf in ("w_in", "w_out"):
        return spec(dd, None)
    if leaf == "conv_w":
        return spec(None, dd)
    # norms, biases, gates, a_log, ... -> replicated
    return P(*([None] * len(shape)))


def base_arch_name(name: str) -> str:
    """Strip variant suffixes (e.g. '-sw8192') to recover the base arch."""
    return name.split("-sw")[0]


def param_shardings(cfg: ArchConfig, mesh: Mesh, params_shape: Any,
                    fsdp: Optional[bool] = None) -> Any:
    fsdp = base_arch_name(cfg.name) in FSDP_ARCHS if fsdp is None else fsdp

    def one(path, leaf):
        spec = param_spec(cfg, mesh, _path_str(path), leaf.shape, fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_shardings(cfg: ArchConfig, mesh: Mesh, batch_shape: Any) -> Any:
    """tokens/targets (B, S) over batch axes; modality stubs likewise;
    decode pos is replicated."""
    baxes = data_axes(mesh)
    bsize = int(np.prod([mesh.shape[a] for a in baxes]))

    def one(path, leaf):
        name = _path_str(path)
        if name == "pos" or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        b = leaf.shape[0]
        first = baxes if b % bsize == 0 else (
            ("data",) if b % _axis_size(mesh, "data") == 0 else None)
        return NamedSharding(mesh, P(first, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map_with_path(one, batch_shape)


def cache_shardings(cfg: ArchConfig, mesh: Mesh, cache_shape: Any) -> Any:
    """KV caches (L, B, T, G, hd): batch over data axes; heads over model
    when divisible, else head_dim over model.  SSM state (L, B, H, P, N):
    heads over model.  Conv cache (L, B, K, C): channels over model."""
    baxes = data_axes(mesh)
    bsize = int(np.prod([mesh.shape[a] for a in baxes]))

    def bspec(b):
        if b % bsize == 0:
            return baxes
        if b % _axis_size(mesh, "data") == 0:
            return ("data",)
        return None

    def one(path, leaf):
        name = _path_str(path)
        shp = leaf.shape
        if "mamba" in name and name.endswith("ssm"):
            # (L, B, H, P, N)
            h_ax = "model" if _div(shp[2], mesh, "model") else None
            return NamedSharding(mesh, P(None, bspec(shp[1]), h_ax, None,
                                         None))
        if "mamba" in name and name.endswith("conv"):
            # (L, B, K, C)
            c_ax = "model" if _div(shp[3], mesh, "model") else None
            return NamedSharding(mesh, P(None, bspec(shp[1]), None, c_ax))
        # attention / cross KV: (L, B, T, G, hd)
        g, hd = shp[3], shp[4]
        if _div(g, mesh, "model"):
            return NamedSharding(mesh, P(None, bspec(shp[1]), None, "model",
                                         None))
        if _div(hd, mesh, "model"):
            return NamedSharding(mesh, P(None, bspec(shp[1]), None, None,
                                         "model"))
        return NamedSharding(mesh, P(None, bspec(shp[1]), None, None, None))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def opt_state_shardings(mesh: Mesh, param_sh: Any, opt_state_shape: Any,
                        zero1: bool = False) -> Any:
    """Optimizer moments inherit the param specs; step is replicated.

    zero1=True (ZeRO-1): moments of fully-replicated params are sharded
    over `data` on their first divisible dim — optimizer memory drops
    n_data-fold without the per-scan-iteration weight gathers that full
    FSDP costs on stacked layer params (§Perf iteration C2)."""
    def like(ps, leaf):
        if zero1 and all(a is None for a in ps.spec):
            for i, dim in enumerate(leaf.shape):
                if dim % _axis_size(mesh, "data") == 0 and dim > 1:
                    spec = [None] * len(leaf.shape)
                    spec[i] = "data"
                    return NamedSharding(mesh, P(*spec))
        return ps

    step_sh = NamedSharding(mesh, P())
    mu = opt_state_shape.mu
    nu = opt_state_shape.nu
    from repro.optim.optimizers import OptState
    return OptState(
        step=step_sh,
        mu=None if mu is None else jax.tree.map(like, param_sh, mu),
        nu=None if nu is None else jax.tree.map(like, param_sh, nu),
    )


def replicated(mesh: Mesh, tree: Any) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def lane_specs(tree: Any) -> Any:
    """PartitionSpecs splitting every leaf's leading axis over "lanes".

    The layout of the sweep engine's stacked operands (`repro.api.
    run_sweep`): axis 0 is the session lane, everything behind it is
    per-lane state and stays unsharded.
    """
    return jax.tree.map(
        lambda leaf: P("lanes", *([None] * (leaf.ndim - 1))), tree)


def lane_shardings(mesh: Mesh, tree: Any) -> Any:
    """NamedShardings for `lane_specs` on a `make_lane_mesh` mesh."""
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        lane_specs(tree))
