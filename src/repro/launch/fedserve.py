"""Always-on federated serving driver: Poisson arrivals into the
continuous-batching `FedServeEngine`.

  python -m repro.launch.fedserve --sessions 16 --rate 0.5 \\
      --epochs 120 --nmse-target 3e-2

Builds a mixed workload (uncoded / CFL at two coding rates — three shape
buckets), submits it on a Poisson arrival trace over the engine's
virtual clock, and drains.  Prints per-session exit epochs plus
aggregate throughput in sessions/sec and epochs/sec of wall time.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def build_workload(fleet, m: int, n_sessions: int, epochs: int, lr: float,
                   base_seed: int = 100):
    """The benchmark's mixed-shape session list: ~half CFL at c1, a
    quarter CFL at c2, a quarter uncoded (three engine buckets)."""
    from repro.api import Session, make_strategy

    c1, c2 = int(0.3 * m), int(0.5 * m)
    sessions = []
    for i in range(n_sessions):
        if i % 4 in (0, 1):
            strat = make_strategy("cfl", fixed_c=c1, key_seed=7 + i)
        elif i % 4 == 2:
            strat = make_strategy("cfl", fixed_c=c2, key_seed=7 + i)
        else:
            strat = make_strategy("uncoded")
        sessions.append(Session(strategy=strat, fleet=fleet, lr=lr,
                                epochs=epochs, seed=base_seed + i))
    return sessions


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=16)
    ap.add_argument("--epochs", type=int, default=120)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson arrival rate (sessions per epoch-unit "
                         "of virtual time)")
    ap.add_argument("--lane-width", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=25)
    ap.add_argument("--nmse-target", type=float, default=0.0)
    ap.add_argument("--rel-delta", type=float, default=None)
    ap.add_argument("--min-epochs", type=int, default=1)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--n", type=int, default=12)
    ap.add_argument("--ell", type=int, default=60)
    ap.add_argument("--d", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax

    from repro.api import TrainData
    from repro.serving import (ConvergenceCriterion, FedServeEngine,
                               poisson_arrivals)
    from repro.sim.network import paper_fleet

    data = TrainData.linreg(jax.random.PRNGKey(args.seed), n=args.n,
                            ell=args.ell, d=args.d)
    fleet = paper_fleet(0.2, 0.2, seed=args.seed, n=args.n, d=args.d)
    sessions = build_workload(fleet, data.m, args.sessions, args.epochs,
                              args.lr)
    arrivals = poisson_arrivals(args.sessions, args.rate,
                                np.random.default_rng(args.seed))
    crit = ConvergenceCriterion(nmse_target=args.nmse_target,
                                rel_delta=args.rel_delta,
                                min_epochs=args.min_epochs)
    engine = FedServeEngine(data, lane_width=args.lane_width,
                            chunk=args.chunk, criterion=crit)

    t0 = time.perf_counter()
    reports = engine.serve(sessions, arrivals=arrivals)
    wall = time.perf_counter() - t0

    total_epochs = 0
    for arr, rep in zip(arrivals, reports):
        t_exit = rep.extras["serve_exit_epoch"]
        total_epochs += t_exit
        tag = "conv" if rep.extras["serve_converged"] else "budget"
        print(f"  uid={rep.extras['serve_uid']:3d} {rep.label:22s} "
              f"arrival={arr:7.1f} exit_epoch={t_exit:4d} ({tag}) "
              f"final_nmse={rep.final_nmse():.3e}")
    print(f"{len(reports)} sessions, {engine.n_groups} buckets, "
          f"{engine.steps} engine steps")
    print(f"wall {wall:.2f}s -> {len(reports) / wall:.2f} sessions/s, "
          f"{total_epochs / wall:.0f} epochs/s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
