"""Training driver.

Runs real training on the available devices (CPU here; the same step
functions lower for the production mesh in dryrun.py).  Supports plain
data-parallel training and the federated straggler-aware mode (deadline-
masked aggregation with the Eq. 14-16 load allocation).

  python -m repro.launch.train --arch lm-100m --steps 300 --batch 8 --seq 256
  python -m repro.launch.train --arch granite-8b --reduced --federated
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config, list_archs
from repro.data.synthetic import token_batches
from repro.launch.steps import make_fed_train_step, make_train_step
from repro.models import transformer as T
from repro.optim.optimizers import make_optimizer


def add_modality_stubs(batch: dict, cfg, key) -> dict:
    B = batch["tokens"].shape[0]
    if cfg.vlm:
        batch["patches"] = 0.1 * jax.random.normal(
            key, (B, cfg.vlm.n_patches, cfg.vlm.d_vision))
    if cfg.encdec:
        batch["frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.encdec.n_frames, cfg.d_model))
    return batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-100m", choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="train the family-preserving smoke variant")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--warmup", type=int, default=0,
                    help="cosine schedule warmup steps (0 = constant lr)")
    ap.add_argument("--clip-norm", type=float, default=0.0,
                    help="global-norm gradient clipping (0 = off)")
    ap.add_argument("--distributed", action="store_true",
                    help="initialize jax.distributed from the cluster env")
    ap.add_argument("--federated", action="store_true",
                    help="straggler-aware deadline-masked aggregation")
    ap.add_argument("--n-clients", type=int, default=8)
    ap.add_argument("--nu", type=float, default=0.2,
                    help="federated heterogeneity factor")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.distributed:
        from repro.launch.distributed import initialize_distributed
        multi = initialize_distributed()
        print(f"distributed: {jax.process_count()} processes "
              f"({'multi' if multi else 'single'}-host)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    opt = make_optimizer(args.optimizer, args.lr)
    opt_state = opt.init(params)
    it = token_batches(args.seed, batch=args.batch, seq_len=args.seq,
                       vocab=cfg.vocab)

    if args.federated:
        from repro.fed import FedConfig, fed_setup
        from repro.fed.trainer import round_weights
        from repro.sim.network import paper_fleet
        n_clients = min(args.n_clients, args.batch)
        if n_clients != args.n_clients:
            print(f"note: clamping n_clients to batch size ({n_clients})")
        args.n_clients = n_clients
        per_client = args.batch // args.n_clients
        fleet = paper_fleet(args.nu, args.nu, seed=args.seed,
                            n=args.n_clients, d=cfg.d_model)
        fstate = fed_setup(fleet.edge, FedConfig(
            n_clients=args.n_clients, sequences_per_client=per_client,
            target_sequences=args.batch))
        print(f"federated: t*={fstate.plan.t_star:.2f}s "
              f"loads={fstate.plan.loads.tolist()}")
        step = jax.jit(make_fed_train_step(cfg, opt))
        batch_clients = np.repeat(np.arange(args.n_clients), per_client)
        rng = np.random.default_rng(args.seed)
    else:
        schedule = None
        if args.warmup > 0:
            from repro.optim.schedules import cosine_with_warmup
            schedule = cosine_with_warmup(1.0, args.warmup, args.steps)
        step = jax.jit(make_train_step(cfg, opt, compute_dtype=jnp.float32,
                                       remat=False,
                                       clip_norm=args.clip_norm,
                                       lr_schedule=schedule))

    wall = 0.0
    losses = []
    t_start = time.time()
    for s in range(1, args.steps + 1):
        batch = add_modality_stubs(next(it), cfg, jax.random.fold_in(key, s))
        if args.federated:
            w, dt = round_weights(fstate, rng, batch_clients)
            params, opt_state, metrics = step(
                params, opt_state, batch, jnp.asarray(w, jnp.float32))
            wall += dt
        else:
            params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if s % args.log_every == 0:
            msg = (f"step {s:5d} loss {losses[-1]:.4f} "
                   f"({(time.time()-t_start)/s:.2f}s/step)")
            if args.federated:
                msg += f" sim_wall {wall:.0f}s"
            print(msg, flush=True)
        if args.ckpt_dir and s % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, s,
                            {"params": params, "opt": opt_state})
    print(f"final loss {np.mean(losses[-10:]):.4f} "
          f"(first 10: {np.mean(losses[:10]):.4f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
