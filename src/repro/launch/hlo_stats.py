"""HLO-text statistics: collective-communication bytes per op kind.

`cost_analysis()` does not expose collective bytes, so we parse the compiled
module text and sum the operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction.  Operand shapes
are read from the instruction's result type (for all-reduce the result equals
the operand; for all-gather the result is the gathered size — we count the
*result* bytes, a consistent upper proxy for wire traffic).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# e.g.:  %all-gather.3 = bf16[4,1024,512]{2,1,0} all-gather(...)
#        ROOT %tuple = (f32[...], bf16[...]) tuple(...)
_INSTR_RE = re.compile(
    r"=\s*(?P<type>\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"(?P<dtype>[a-z][a-z0-9]*)\[(?P<dims>[0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes across all shapes in a (possibly tuple) type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dtype")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result bytes per collective kind over the whole module.

    `-start`/`-done` async pairs are counted once (on `-start`; bare ops
    count normally)."""
    out: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # counted at -start
        m = _INSTR_RE.search(line)
        if not m:
            continue
        kind = m.group("kind").lower()
        out[kind] += _shape_bytes(m.group("type"))
        counts[kind] += 1
    result = dict(out)
    result.update({f"n_{k}": float(v) for k, v in counts.items()})
    return result
