"""Roofline analysis from compiled dry-run artifacts."""
from .hlo_graph import module_stats
from .analysis import roofline_terms, model_flops

__all__ = ["module_stats", "roofline_terms", "model_flops"]
