"""HLO-text computation-graph statistics with while-loop trip-count scaling.

XLA's `compiled.cost_analysis()` counts each while-loop body ONCE, so with
scan-over-layers the reported FLOPs/bytes are ~L x too small.  This module
re-derives the totals from the compiled module text:

  * computations are parsed into {instr name -> (op, result shape, attrs)};
  * while instructions get a trip count from their condition computation
    (jax lowers `lax.scan` to `while (i < L)` with a literal constant);
  * multipliers propagate ENTRY -> called computations (body x trip,
    condition x trip+1, call/conditional x 1);
  * dot FLOPs   = 2 * numel(result) * prod(contracting dims)  (per instr);
  * HBM bytes   ~ sum over non-fusion-internal instructions of
                  (operand bytes + result bytes) — a traffic proxy that
                  ignores in-place aliasing (documented in EXPERIMENTS.md);
  * collective bytes per kind, same multiplier scaling.

This is structural dry-run profiling: exact for FLOPs of matmul-dominated
models, a consistent proxy for memory traffic.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COMP_HDR = re.compile(
    r"^(?:ENTRY\s+)?%?(?P<name>[\w\.\-]+)\s*\(.*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*(?P<type>.+?)\s+"
    r"(?P<op>[\w\-]+)\((?P<args>.*?)\)(?P<attrs>.*)$")
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_CALLED = re.compile(
    r"(?:condition|body|to_apply|calls|branch_computations)=\{?%?"
    r"([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    type_str: str
    args: str
    attrs: str


def _shape_numel_bytes(type_str: str) -> tuple[int, int]:
    """(total elements, total bytes) over all shapes in a type string."""
    n_total, b_total = 0, 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        n_total += n
        b_total += n * _DTYPE_BYTES[dt]
    return n_total, b_total


def parse_computations(hlo: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur: list[Instr] | None = None
    cur_name = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip()) if "{" in line else None
            if m and "->" in line:
                cur_name, cur = m.group("name"), []
            continue
        if line.strip() == "}":
            comps[cur_name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            cur.append(Instr(m.group("name"), m.group("op"),
                             m.group("type"), m.group("args"),
                             m.group("attrs")))
    return comps


def _entry_name(hlo: str, comps: dict[str, list[Instr]]) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.MULTILINE)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: the computation that is not called by anyone
    called = set()
    for instrs in comps.values():
        for ins in instrs:
            for grp in _CALLED.findall(ins.attrs):
                for nm in re.split(r",\s*%?", grp):
                    called.add(nm)
    for name in comps:
        if name not in called:
            return name
    return next(iter(comps))


def _trip_count(cond: list[Instr]) -> int:
    """Extract N from `while (i < N)`-style conditions (1 if unknown)."""
    consts = {}
    for ins in cond:
        if ins.op == "constant":
            mm = re.search(r"(-?\d+)", ins.args)
            if mm:
                consts[ins.name] = int(mm.group(1))
    for ins in cond:
        if ins.op == "compare" and "direction=LT" in ins.attrs:
            for arg in re.findall(r"%([\w\.\-]+)", ins.args):
                if arg in consts:
                    return max(consts[arg], 1)
        if ins.op == "compare" and "direction=GT" in ins.attrs:
            for arg in re.findall(r"%([\w\.\-]+)", ins.args):
                if arg in consts:
                    return max(consts[arg], 1)
    return 1


def _dot_flops(ins: Instr, shapes: dict[str, str]) -> float:
    """2 * numel(result) * prod(lhs contracting dim sizes)."""
    n_res, _ = _shape_numel_bytes(ins.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    if not m:
        return 2.0 * n_res  # degenerate dot
    dims = [int(d) for d in m.group(1).split(",") if d]
    args = re.findall(r"%([\w\.\-]+)", ins.args)
    if not args:
        return 2.0 * n_res
    lhs_type = shapes.get(args[0], "")
    sm = _SHAPE.search(lhs_type)
    if not sm:
        return 2.0 * n_res
    lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
    k = 1
    for d in dims:
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    return 2.0 * n_res * k


def module_stats(hlo: str) -> dict:
    """Trip-count-corrected totals for the whole module.

    Returns {"flops", "bytes", "collectives": {kind: bytes, n_kind: count},
             "per_computation": {...}}."""
    comps = parse_computations(hlo)
    entry = _entry_name(hlo, comps)

    # computation name -> (root op, shapes map) for fusion-root inspection
    roots: dict[str, str] = {}
    all_shapes: dict[str, dict[str, str]] = {}
    for cname, instrs in comps.items():
        all_shapes[cname] = {i.name: i.type_str for i in instrs}
        roots[cname] = instrs[-1].op if instrs else ""

    def _hbm_bytes(ins: Instr, shapes: dict[str, str]) -> float:
        """HBM-traffic estimate for one instruction's write side.

        dynamic-update-slice writes in place: only the update operand's
        bytes move (counting the whole result would bill a scan's stacked
        output once per iteration).  Fusions rooted at a DUS likewise.
        bf16 dots that XLA:CPU upcasts to f32 are billed at bf16 (the MXU
        emits bf16; the f32 working copy is a host-backend artifact)."""
        if ins.op == "dynamic-update-slice":
            ops_ = re.findall(r"%([\w\.\-]+)", ins.args)
            if len(ops_) >= 2 and ops_[1] in shapes:
                _, b = _shape_numel_bytes(shapes[ops_[1]])
                return b
        if ins.op == "fusion":
            mc = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
            callee = mc.group(1) if mc else None
            if callee and callee in comps:
                # walk back through convert/bitcast wrappers to find a DUS
                # root: the fusion then writes only the update slice.
                cshapes = all_shapes.get(callee, {})
                cur = comps[callee][-1]
                depth = 0
                while cur.op in ("convert", "bitcast", "copy",
                                 "transpose", "reshape") and depth < 4:
                    ops_ = re.findall(r"%([\w\.\-]+)", cur.args)
                    nxt = next((i2 for i2 in comps[callee]
                                if ops_ and i2.name == ops_[0]), None)
                    if nxt is None:
                        break
                    cur = nxt
                    depth += 1
                if cur.op == "dynamic-update-slice":
                    ops_ = re.findall(r"%([\w\.\-]+)", cur.args)
                    if len(ops_) >= 2 and ops_[1] in cshapes:
                        n_upd, _ = _shape_numel_bytes(cshapes[ops_[1]])
                        # bill at the fusion RESULT's element size (an f32
                        # stacking buffer converted to bf16 is a CPU
                        # artifact; TPU stores the logical dtype)
                        n_res, b_res = _shape_numel_bytes(ins.type_str)
                        elem = b_res / max(n_res, 1)
                        return n_upd * elem
        _, b = _shape_numel_bytes(ins.type_str)
        if ins.op == "dot" and "f32[" in ins.type_str:
            ops_ = re.findall(r"%([\w\.\-]+)", ins.args)
            if ops_ and all("bf16[" in shapes.get(o, "")
                            for o in ops_ if o in shapes) \
                    and any(o in shapes for o in ops_):
                return b / 2
        return b

    # per-computation local stats
    local = {}
    whiles = {}          # comp -> list of (cond, body, trip)
    calls = defaultdict(list)   # comp -> list of (callee, kind)
    for cname, instrs in comps.items():
        shapes = all_shapes[cname]
        # parameters keep their declared type via the instr itself
        flops = 0.0
        bytes_ = 0.0
        coll = defaultdict(float)
        for ins in instrs:
            if ins.op == "dot":
                flops += _dot_flops(ins, shapes)
            base_op = ins.op.replace("-start", "").replace("-done", "")
            if base_op in COLLECTIVES and not ins.op.endswith("-done"):
                _, b = _shape_numel_bytes(ins.type_str)
                # XLA:CPU's all-reduce promoter upcasts bf16 all-reduces to
                # f32 (reduction computation renamed *_promoted); TPU keeps
                # bf16 on the wire, so count pre-promotion bytes.
                if "promoted" in ins.attrs and "f32" in ins.type_str:
                    b //= 2
                coll[base_op] += b
                coll[f"n_{base_op}"] += 1
            # HBM traffic proxy: results of "real" ops (skip metadata ops)
            if ins.op not in ("parameter", "constant", "tuple",
                              "get-tuple-element", "bitcast", "while",
                              "conditional", "call"):
                bytes_ += _hbm_bytes(ins, shapes)
            # called computations
            for grp in _CALLED.findall(ins.attrs):
                names = [n for n in re.split(r",\s*%?", grp) if n in comps]
                if ins.op == "while":
                    mcond = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
                    mbody = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
                    if mcond and mbody:
                        # prefer XLA's own known_trip_count annotation
                        mk = re.search(
                            r"known_trip_count[^0-9]*(\d+)", ins.attrs)
                        trip = (int(mk.group(1)) if mk else
                                _trip_count(comps.get(mcond.group(1), [])))
                        whiles.setdefault(cname, []).append(
                            (mcond.group(1), mbody.group(1), trip))
                    break
                if ins.op == "fusion":
                    # fusion-internal instrs are not HBM traffic; but count
                    # dots inside (CPU may keep dots in fusions)
                    for nm in names:
                        calls[cname].append((nm, "fusion"))
                else:
                    for nm in names:
                        calls[cname].append((nm, "call"))
        local[cname] = {"flops": flops, "bytes": bytes_, "coll": dict(coll)}

    # propagate multipliers from entry.  Two channels: `mult` flows through
    # every edge (FLOPs/collectives); `mult_b` stops at fusion edges —
    # fusion-internal instructions are registers/VMEM, not HBM traffic.
    # edges: (caller, callee, multiplier_factor, counts_for_bytes)
    edges: list[tuple[str, str, float, bool]] = []
    for c in comps:
        for cond, body, trip in whiles.get(c, []):
            edges.append((c, cond, trip + 1, True))
            edges.append((c, body, trip, True))
        for nm, kind in calls.get(c, []):
            edges.append((c, nm, 1.0, kind != "fusion"))

    # Kahn topological order over the computation DAG (callers first)
    indeg = defaultdict(int)
    out_edges = defaultdict(list)
    for a, b, k, by in edges:
        indeg[b] += 1
        out_edges[a].append((b, k, by))
    queue = [c for c in comps if indeg[c] == 0]
    topo = []
    while queue:
        c = queue.pop()
        topo.append(c)
        for b, k, by in out_edges[c]:
            indeg[b] -= 1
            if indeg[b] == 0:
                queue.append(b)

    mult = defaultdict(float)
    mult_b = defaultdict(float)
    mult[entry] = mult_b[entry] = 1.0
    for c in topo:
        for b, k, by in out_edges[c]:
            mult[b] += mult[c] * k
            if by:
                mult_b[b] += mult_b[c] * k

    total_flops = 0.0
    total_bytes = 0.0
    total_coll = defaultdict(float)
    per_comp = {}
    for cname, st in local.items():
        m = mult.get(cname, 0.0)
        if m == 0:
            continue
        total_flops += st["flops"] * m
        total_bytes += st["bytes"] * mult_b.get(cname, 0.0)
        for k, v in st["coll"].items():
            total_coll[k] += v * m
        if st["flops"] or st["coll"]:
            per_comp[cname] = {"mult": m, **st}
    return {"flops": total_flops, "bytes": total_bytes,
            "collectives": dict(total_coll), "entry": entry,
            "per_computation": per_comp}
