"""Three-term roofline from dry-run artifacts (EXPERIMENTS.md §Roofline).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

FLOP/byte/collective totals come from `hlo_graph.module_stats` (trip-count
corrected); the terms are per-device seconds assuming perfect balance
(the parsed module is the per-device partitioned program, so totals are
already per-device).  MODEL_FLOPS = 6 * N_active * tokens gives the
useful-compute ratio.
"""
from __future__ import annotations

from repro.configs.base import INPUT_SHAPES, ArchConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from .hlo_graph import module_stats


def active_params(cfg: ArchConfig) -> float:
    """Approximate active (per-token) parameter count, excluding embeddings.

    MoE counts top_k experts per MoE layer; the rest is dense."""
    d = cfg.d_model
    hd = cfg.hd if cfg.n_heads else 0
    n_attn = cfg.n_heads * hd
    n_kv = cfg.n_kv_heads * hd
    attn = d * (n_attn + 2 * n_kv) + n_attn * d
    mlp = 3 * d * cfg.d_ff if cfg.act == "swiglu" else 2 * d * cfg.d_ff
    if cfg.arch_type == "ssm":
        s = cfg.ssm
        h = s.n_heads(d)
        d_inner = h * s.headdim
        mix = d * (2 * d_inner + 2 * s.n_groups * s.d_state + h) + d_inner * d
        return cfg.n_layers * mix
    if cfg.arch_type == "hybrid":
        s = cfg.ssm
        h = s.n_heads(d)
        d_inner = h * s.headdim
        mix = d * (2 * d_inner + 2 * s.n_groups * s.d_state + h) + d_inner * d
        n_attn_apps = cfg.n_layers // cfg.hybrid.attn_every
        return cfg.n_layers * mix + n_attn_apps * (attn + mlp)
    if cfg.arch_type == "moe":
        every = cfg.moe.every
        n_moe = cfg.n_layers // every
        n_dense = cfg.n_layers - n_moe
        moe_mlp = cfg.moe.top_k * mlp
        return cfg.n_layers * attn + n_moe * moe_mlp + n_dense * mlp
    if cfg.arch_type == "vlm":
        # cross layers add cross-attn on top of self layers
        n_groups = cfg.n_layers // cfg.vlm.cross_every
        return cfg.n_layers * (attn + mlp)  # cross ~ self in cost
    if cfg.arch_type == "audio":
        enc = cfg.encdec.n_enc_layers * (attn + mlp)
        dec = cfg.n_layers * (2 * attn + mlp)  # self + cross
        return enc + dec
    return cfg.n_layers * (attn + mlp)


def model_flops(cfg: ArchConfig, shape_name: str) -> float:
    """6 * N_active * D-tokens for training; 2 * N_active * tokens for
    inference shapes (forward only)."""
    spec = INPUT_SHAPES[shape_name]
    n_act = active_params(cfg)
    if spec["kind"] == "train":
        tokens = spec["global_batch"] * spec["seq_len"]
        return 6.0 * n_act * tokens
    if spec["kind"] == "prefill":
        tokens = spec["global_batch"] * spec["seq_len"]
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * spec["global_batch"]


def roofline_terms(hlo_text: str, n_devices: int,
                   peak_flops: float = PEAK_FLOPS_BF16,
                   hbm_bw: float = HBM_BW,
                   ici_bw: float = ICI_BW) -> dict:
    """Per-device roofline seconds from the partitioned module text.

    The compiled module is already the per-device program, so its totals are
    per-device; `n_devices` is recorded for reference only."""
    st = module_stats(hlo_text)
    coll_bytes = sum(v for k, v in st["collectives"].items()
                     if not k.startswith("n_"))
    return {
        "flops": st["flops"],
        "bytes": st["bytes"],
        "collective_bytes": coll_bytes,
        "collectives": st["collectives"],
        "t_compute": st["flops"] / peak_flops,
        "t_memory": st["bytes"] / hbm_bw,
        "t_collective": coll_bytes / ici_bw,
        "n_devices": n_devices,
    }


def dominant_term(terms: dict) -> str:
    vals = {"compute": terms["t_compute"], "memory": terms["t_memory"],
            "collective": terms["t_collective"]}
    return max(vals, key=vals.get)
