"""The seed repo's host-side scalar planning stack, preserved verbatim.

This is the original two-step optimization exactly as it shipped before the
vectorized grid solver in `repro.plan.solver` replaced it: a Python loop that
calls the analytic CDF once per integer load per chunk, inside a 64-iteration
bisection on the epoch deadline.  It is kept for two jobs only:

  * the oracle in the planner parity tests (`tests/test_plan_solver.py`) —
    the grid solver must reproduce its `t*`, `loads`, and `c`;
  * the "legacy" baseline in `benchmarks/perf_session.py`'s plan-timing
    section, so the reported speedup is measured against the real seed
    algorithm rather than an already-vectorized stand-in.

Nothing in the production path imports this module.
"""
from __future__ import annotations

import numpy as np

from repro.core.delay_model import (K_MAX, DeviceDelayParams, _nbinom_pmf,
                                    compute_cdf)
from repro.core.redundancy import RedundancyPlan, _fleet_with_server


def total_cdf_loop(params: DeviceDelayParams, ell, t) -> np.ndarray:
    """The seed's Pr{T_i <= t}: one (n,)-shaped evaluation per call, with
    per-call comm/no-comm sub-fleet construction (since vectorized away in
    `core.delay_model.total_cdf`; kept verbatim so the baseline timing is
    the seed's, not the refactor's)."""
    ell = np.broadcast_to(np.asarray(ell, dtype=np.float64),
                          params.a.shape).copy()
    t = float(t)
    out = np.zeros(params.n, dtype=np.float64)

    comm = params.tau > 0
    # Server-style devices: compute-only.
    if np.any(~comm):
        out[~comm] = compute_cdf(
            DeviceDelayParams(params.a[~comm], params.mu[~comm],
                              params.tau[~comm], params.p[~comm]),
            ell[~comm], t)
    if np.any(comm):
        sub = DeviceDelayParams(params.a[comm], params.mu[comm],
                                params.tau[comm], params.p[comm])
        ks = np.arange(2, 2 + K_MAX, dtype=np.float64)  # (K,)
        pmf = _nbinom_pmf(sub.p[:, None], ks[None, :])  # (n_c, K)
        # residual time after k transmissions: s_k = t - k * tau_i
        t_resid = t - ks[None, :] * sub.tau[:, None]  # (n_c, K)
        shift = (ell[comm] * sub.a)[:, None]
        gamma = (sub.mu / np.maximum(ell[comm], 1.0))[:, None]
        s = t_resid - shift
        cdf_k = np.where(
            s > 0,
            -np.expm1(-np.minimum(gamma * np.maximum(s, 0.0), 700.0)),
            0.0)
        # ell == 0 rows: compute CDF is a step at zero
        zero_load = (ell[comm] <= 0)[:, None]
        cdf_k = np.where(zero_load, (t_resid >= 0).astype(np.float64), cdf_k)
        out[comm] = np.sum(pmf * cdf_k, axis=1)
    return out


def expected_return(params: DeviceDelayParams, ell, t) -> np.ndarray:
    """The seed's E[R_i(t; ell)] = ell * Pr{T_i <= t} (scalar-load calls)."""
    ell = np.broadcast_to(np.asarray(ell, dtype=np.float64), params.a.shape)
    return ell * total_cdf_loop(params, ell, t)


# The oracle builds a dense (chunk, n) eval stack per load chunk (and the
# bisection re-solves it ~70 times).  It exists for 24-device parity tests
# and seed-baseline timings, not fleet-scale planning — cap n explicitly
# (clear error instead of an OOM kill) and shrink the load chunk so the
# stack never exceeds _MAX_STACK_ELEMS float64 entries (128 MiB).
_MAX_ORACLE_N = 16_384
_MAX_STACK_ELEMS = 2 ** 24


def _oracle_chunk(n: int, chunk: int, width: int | None = None) -> int:
    """Adaptive load-chunk size for the reference grid searches.

    `width` is the per-load row width of the eval stack (defaults to n;
    the partial-return oracle passes n * chunks for its (n, Q, K)
    intermediates)."""
    if n > _MAX_ORACLE_N:
        raise ValueError(
            f"reference oracle supports at most {_MAX_ORACLE_N} devices, "
            f"got {n}: it is a scalar host-side baseline for parity tests, "
            "not a fleet-scale planner — use repro.plan.solver."
            "solve_redundancy_batched or repro.fleet.solve_fleet instead")
    width = n if width is None else width
    return max(1, min(chunk, _MAX_STACK_ELEMS // max(width, 1)))


def optimal_loads_loop(params: DeviceDelayParams, caps: np.ndarray, t: float,
                       chunk: int = 4096) -> tuple[np.ndarray, np.ndarray]:
    """The seed's per-integer-load grid search (one CDF call per load)."""
    caps = np.asarray(caps, dtype=np.int64)
    n = params.n
    chunk = _oracle_chunk(n, chunk)
    l_max = int(caps.max())
    best_val = np.zeros(n, dtype=np.float64)
    best_ell = np.zeros(n, dtype=np.int64)
    for lo in range(1, l_max + 1, chunk):
        hi = min(lo + chunk - 1, l_max)
        loads = np.arange(lo, hi + 1, dtype=np.float64)  # (L,)
        # E[R] for every device at every load in this chunk: (L, n)
        vals = np.stack([expected_return(params, l, t) for l in loads], axis=0)
        # mask loads above each device's cap
        mask = loads[:, None] <= caps[None, :]
        vals = np.where(mask, vals, -np.inf)
        idx = np.argmax(vals, axis=0)  # (n,)
        chunk_best = vals[idx, np.arange(n)]
        better = chunk_best > best_val
        best_val = np.where(better, chunk_best, best_val)
        best_ell = np.where(better, loads[idx].astype(np.int64), best_ell)
    return best_ell, best_val


def aggregate_return_loop(fleet: DeviceDelayParams, caps: np.ndarray,
                          t: float) -> tuple[float, np.ndarray, np.ndarray]:
    """max_load E[R(t)] plus the argmax loads and per-device return probs."""
    loads, vals = optimal_loads_loop(fleet, caps, t)
    probs = total_cdf_loop(fleet, loads, t)
    return float(np.sum(vals)), loads, probs


def solve_redundancy_reference(edge: DeviceDelayParams,
                               server: DeviceDelayParams,
                               data_sizes: np.ndarray, c_up: int | None = None,
                               eps_rel: float = 1e-3,
                               t_hi: float | None = None,
                               fixed_c: int | None = None) -> RedundancyPlan:
    """The seed's two-step optimization: bracket + 64-iteration bisection,
    re-solving every device's integer load at every probed deadline."""
    data_sizes = np.asarray(data_sizes, dtype=np.int64)
    m = int(data_sizes.sum())
    if c_up is None:
        c_up = m
    server_cap = int(fixed_c) if fixed_c is not None else int(c_up)
    fleet = _fleet_with_server(edge, server)
    caps = np.concatenate([data_sizes, [server_cap]])

    # --- bracket t*: find t_hi with E[R] >= m ------------------------------
    if t_hi is None:
        t_hi = float(np.max(fleet.mean_total(caps))) + 1.0
    t_lo = 0.0
    agg, loads, probs = aggregate_return_loop(fleet, caps, t_hi)
    guard = 0
    while agg < m:
        t_hi *= 2.0
        agg, loads, probs = aggregate_return_loop(fleet, caps, t_hi)
        guard += 1
        if guard > 60:
            raise RuntimeError(
                "cannot reach aggregate expected return m: the fleet cannot "
                f"return {m} points in finite time (best {agg:.1f})")

    # --- bisection on t (E[R] is nondecreasing in t) ------------------------
    for _ in range(64):
        t_mid = 0.5 * (t_lo + t_hi)
        agg_mid, loads_mid, probs_mid = aggregate_return_loop(fleet, caps, t_mid)
        if agg_mid >= m:
            t_hi, agg, loads, probs = t_mid, agg_mid, loads_mid, probs_mid
        else:
            t_lo = t_mid
        if (t_hi - t_lo) <= eps_rel * max(t_hi, 1e-12):
            break

    c = int(loads[-1]) if fixed_c is None else int(fixed_c)
    return RedundancyPlan(
        loads=loads[:-1].astype(np.int64),
        c=c,
        t_star=float(t_hi),
        p_return=probs,
        expected_agg=float(agg),
        loads_cap_total=m,
    )
