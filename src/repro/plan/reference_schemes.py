"""Host-side scalar reference solves for the `repro.schemes` strategies.

Mirrors `repro.plan.reference` for the follow-up coding schemes: the
stochastic-CFL weighted-server objective (arXiv:2201.10092), the
low-latency partial-return objective (arXiv:2011.06223), and the CodedFedL
MEC shifted-exponential objective (arXiv:2007.03273).  Same style as the
seed stack — NumPy float64, one analytic-CDF evaluation per integer load
per chunk, bracket + 64-iteration bisection on the deadline — and the same
two jobs only:

  * parity oracles for the batched grid solver's new objective evaluators
    (`tests/test_schemes.py` / `tests/test_nonlinear.py`: loads identical,
    t* within 1e-3 relative);
  * the calibrated-noise-scale oracle for `StochasticCodedFL`
    (`stochastic_noise_scale`).

Nothing in the production path imports this module.
"""
from __future__ import annotations

import numpy as np

from repro.core.delay_model import (K_MAX, DeviceDelayParams, _nbinom_pmf,
                                    mec_total_cdf)
from repro.core.redundancy import RedundancyPlan
from repro.plan.reference import (_oracle_chunk, optimal_loads_loop,
                                  total_cdf_loop)


# ---------------------------------------------------------------------------
# partial-return (low-latency wireless) edge objective
# ---------------------------------------------------------------------------

def chunk_cdf_loop(params: DeviceDelayParams, ell, t,
                   chunks: int) -> np.ndarray:
    """Pr{chunk q of assignment ell is done by t} — (n, chunks).

    Chunk q covers the first q*ell/chunks points: compute shift
    (q/chunks)*ell*a, stochastic rate mu/ell, shared retransmission
    mixture (the scalar mirror of `core.delay_model.partial_cdf`).
    """
    ell = np.broadcast_to(np.asarray(ell, dtype=np.float64),
                          params.a.shape).copy()
    t = float(t)
    fracs = np.arange(1, chunks + 1, dtype=np.float64) / chunks
    shift = fracs[None, :] * (ell * params.a)[:, None]          # (n, Q)
    gamma = (params.mu / np.maximum(ell, 1.0))[:, None, None]   # (n, 1, 1)

    comm = params.tau > 0
    s0 = t - shift
    base = np.where(
        s0 > 0,
        -np.expm1(-np.minimum(gamma[..., 0] * np.maximum(s0, 0.0), 700.0)),
        0.0)
    base = np.where((ell > 0)[:, None], base, (t >= 0.0))

    ks = np.arange(2, 2 + K_MAX, dtype=np.float64)
    pmf = _nbinom_pmf(params.p[:, None], ks[None, :])           # (n, K)
    t_resid = t - ks[None, :] * params.tau[:, None]             # (n, K)
    s = t_resid[:, None, :] - shift[:, :, None]                 # (n, Q, K)
    cdf_k = np.where(
        s > 0,
        -np.expm1(-np.minimum(gamma * np.maximum(s, 0.0), 700.0)),
        0.0)
    zero_load = (ell <= 0)[:, None, None]
    cdf_k = np.where(zero_load, (t_resid >= 0.0)[:, None, :], cdf_k)
    mix = np.sum(pmf[:, None, :] * cdf_k, axis=-1)
    return np.where(comm[:, None], mix, base)


def expected_partial_return(params: DeviceDelayParams, ell, t,
                            chunks: int) -> np.ndarray:
    """E[points uploaded by t] under Q-chunk partial uploads:
    (ell/Q) * sum_q Pr{chunk q done by t}  (scalar-load calls)."""
    ell = np.broadcast_to(np.asarray(ell, dtype=np.float64), params.a.shape)
    return (ell / chunks) * np.sum(chunk_cdf_loop(params, ell, t, chunks),
                                   axis=1)


def optimal_loads_partial_loop(params: DeviceDelayParams, caps: np.ndarray,
                               t: float, chunks: int,
                               chunk: int = 512
                               ) -> tuple[np.ndarray, np.ndarray]:
    """Per-integer-load grid search for the partial-return objective."""
    caps = np.asarray(caps, dtype=np.int64)
    n = params.n
    # the per-load intermediate is (n, Q, K)-shaped, so budget the load
    # chunk against n * chunks rather than n alone
    chunk = _oracle_chunk(n, chunk, width=n * max(chunks, 1))
    l_max = int(caps.max())
    best_val = np.zeros(n, dtype=np.float64)
    best_ell = np.zeros(n, dtype=np.int64)
    for lo in range(1, l_max + 1, chunk):
        hi = min(lo + chunk - 1, l_max)
        loads = np.arange(lo, hi + 1, dtype=np.float64)
        vals = np.stack([expected_partial_return(params, l, t, chunks)
                         for l in loads], axis=0)               # (L, n)
        mask = loads[:, None] <= caps[None, :]
        vals = np.where(mask, vals, -np.inf)
        idx = np.argmax(vals, axis=0)
        chunk_best = vals[idx, np.arange(n)]
        better = chunk_best > best_val
        best_val = np.where(better, chunk_best, best_val)
        best_ell = np.where(better, loads[idx].astype(np.int64), best_ell)
    return best_ell, best_val


# ---------------------------------------------------------------------------
# MEC shifted-exponential (CodedFedL) edge objective
# ---------------------------------------------------------------------------

def mec_expected_return(params: DeviceDelayParams, ell, t) -> np.ndarray:
    """E[points returned by t] under the MEC model: ell * Pr{T_i <= t}.

    `core.delay_model.mec_total_cdf` IS the float64 scalar formula (the
    production weights read it too), so the oracle reuses it directly —
    the independence being tested is the load-grid argmax + deadline
    bisection against the batched grid solver, not the CDF arithmetic.
    """
    ell = np.broadcast_to(np.asarray(ell, dtype=np.float64), params.a.shape)
    return ell * mec_total_cdf(params, ell, t)


def optimal_loads_mec_loop(params: DeviceDelayParams, caps: np.ndarray,
                           t: float, chunk: int = 512
                           ) -> tuple[np.ndarray, np.ndarray]:
    """Per-integer-load grid search for the MEC objective."""
    caps = np.asarray(caps, dtype=np.int64)
    n = params.n
    chunk = _oracle_chunk(n, chunk)
    l_max = int(caps.max())
    best_val = np.zeros(n, dtype=np.float64)
    best_ell = np.zeros(n, dtype=np.int64)
    for lo in range(1, l_max + 1, chunk):
        hi = min(lo + chunk - 1, l_max)
        loads = np.arange(lo, hi + 1, dtype=np.float64)
        grid = np.broadcast_to(loads[:, None], (loads.shape[0], n))
        vals = grid * mec_total_cdf(params, grid, t)         # (L, n)
        mask = loads[:, None] <= caps[None, :]
        vals = np.where(mask, vals, -np.inf)
        idx = np.argmax(vals, axis=0)
        chunk_best = vals[idx, np.arange(n)]
        better = chunk_best > best_val
        best_val = np.where(better, chunk_best, best_val)
        best_ell = np.where(better, loads[idx].astype(np.int64), best_ell)
    return best_ell, best_val


def solve_codedfedl_reference(edge: DeviceDelayParams,
                              server: DeviceDelayParams,
                              data_sizes: np.ndarray,
                              c_up: int | None = None,
                              fixed_c: int | None = None,
                              eps_rel: float = 1e-3,
                              t_hi: float | None = None) -> RedundancyPlan:
    """CodedFedL allocation oracle: MEC shifted-exponential edge objective,
    undiscounted all-or-nothing server.  Parity target: loads identical,
    t* within 1e-3 relative (the returned `p_return` is the base-model
    CDF from the shared scaffold — the parity tests compare loads/t* only;
    production MEC return probabilities come from
    `core.delay_model.mec_total_cdf`)."""
    def edge_loads(caps, t):
        return optimal_loads_mec_loop(edge, caps, t)
    return _solve_two_part(edge, server, data_sizes, edge_loads, 1.0,
                           c_up, fixed_c, eps_rel, t_hi)


# ---------------------------------------------------------------------------
# shared bisection scaffold (edge objective + weighted server, Eq. 16 style)
# ---------------------------------------------------------------------------

def _solve_two_part(edge: DeviceDelayParams, server: DeviceDelayParams,
                    data_sizes: np.ndarray, edge_loads_fn, srv_weight: float,
                    c_up: int | None, fixed_c: int | None,
                    eps_rel: float, t_hi: float | None) -> RedundancyPlan:
    """Bracket + 64-iteration bisection with separate edge/server objectives.

    edge_loads_fn(caps, t) -> (loads, vals); the server is always the
    all-or-nothing evaluator scaled by `srv_weight` in the aggregate.
    """
    data_sizes = np.asarray(data_sizes, dtype=np.int64)
    m = int(data_sizes.sum())
    if c_up is None:
        c_up = m
    server_cap = int(fixed_c) if fixed_c is not None else int(c_up)
    srv_caps = np.array([server_cap], dtype=np.int64)

    def aggregate(t):
        loads, vals = edge_loads_fn(data_sizes, t)
        if server_cap > 0:
            s_load, s_val = optimal_loads_loop(server, srv_caps, t)
        else:
            s_load, s_val = np.zeros(1, np.int64), np.zeros(1)
        agg = float(np.sum(vals)) + srv_weight * float(s_val[0])
        return agg, loads, int(s_load[0])

    if t_hi is None:
        edge_mean = float(np.max(edge.mean_total(data_sizes)))
        srv_mean = float(server.mean_total(np.array([server_cap]))[0])
        t_hi = max(edge_mean, srv_mean) + 1.0
    t_lo = 0.0
    agg, loads, s_load = aggregate(t_hi)
    guard = 0
    while agg < m:
        t_hi *= 2.0
        agg, loads, s_load = aggregate(t_hi)
        guard += 1
        if guard > 60:
            raise RuntimeError(
                "cannot reach aggregate expected return m: the fleet cannot "
                f"return {m} points in finite time (best {agg:.1f})")

    for _ in range(64):
        t_mid = 0.5 * (t_lo + t_hi)
        agg_mid, loads_mid, s_mid = aggregate(t_mid)
        if agg_mid >= m:
            t_hi, agg, loads, s_load = t_mid, agg_mid, loads_mid, s_mid
        else:
            t_lo = t_mid
        if (t_hi - t_lo) <= eps_rel * max(t_hi, 1e-12):
            break

    c = int(fixed_c) if fixed_c is not None else int(s_load)
    p_return = np.append(
        total_cdf_loop(edge, loads.astype(np.float64), t_hi),
        total_cdf_loop(server, np.array([float(s_load)]), t_hi))
    return RedundancyPlan(loads=loads.astype(np.int64), c=c, t_star=float(t_hi),
                          p_return=p_return, expected_agg=float(agg),
                          loads_cap_total=m)


def solve_stochastic_reference(edge: DeviceDelayParams,
                               server: DeviceDelayParams,
                               data_sizes: np.ndarray, srv_weight: float,
                               c_up: int | None = None,
                               fixed_c: int | None = None,
                               eps_rel: float = 1e-3,
                               t_hi: float | None = None) -> RedundancyPlan:
    """Stochastic-CFL allocation oracle: base all-or-nothing edge objective,
    server expected return discounted by `srv_weight` (the per-round
    subsampling + privacy-noise effective-rows factor)."""
    def edge_loads(caps, t):
        return optimal_loads_loop(edge, caps, t)
    return _solve_two_part(edge, server, data_sizes, edge_loads, srv_weight,
                           c_up, fixed_c, eps_rel, t_hi)


def solve_lowlatency_reference(edge: DeviceDelayParams,
                               server: DeviceDelayParams,
                               data_sizes: np.ndarray, chunks: int,
                               c_up: int | None = None,
                               fixed_c: int | None = None,
                               eps_rel: float = 1e-3,
                               t_hi: float | None = None) -> RedundancyPlan:
    """Low-latency wireless allocation oracle: Q-chunk partial-return edge
    objective, undiscounted all-or-nothing server."""
    def edge_loads(caps, t):
        return optimal_loads_partial_loop(edge, caps, t, chunks)
    return _solve_two_part(edge, server, data_sizes, edge_loads, 1.0,
                           c_up, fixed_c, eps_rel, t_hi)


# ---------------------------------------------------------------------------
# stochastic-CFL calibrated noise scale
# ---------------------------------------------------------------------------

def stochastic_noise_scale(xs: np.ndarray, ys: np.ndarray,
                           weights: np.ndarray,
                           noise_multiplier: float) -> tuple[float, float]:
    """Per-entry noise stds calibrated to the coded dataset's RMS.

    With iid N(0,1) generator rows, coded entry (r, k) of the composite
    parity X~ = sum_i G_i W_i X_i has variance sum_{i,row} w^2 x^2 over
    column k; the calibrated std is `noise_multiplier` times the RMS of
    that per-entry std across columns (and the single label column), so a
    multiplier of sigma yields a parity SNR of ~1/sigma independent of the
    data scale.  Float64 mirror of `StochasticCodedFL`'s calibration.
    """
    xs = np.asarray(xs, dtype=np.float64)
    ys = np.asarray(ys, dtype=np.float64)
    w2 = np.asarray(weights, dtype=np.float64) ** 2
    d = xs.shape[-1]
    var_x = float(np.sum(w2[..., None] * xs ** 2) / d)
    var_y = float(np.sum(w2 * ys ** 2))
    return (noise_multiplier * np.sqrt(var_x),
            noise_multiplier * np.sqrt(var_y))
