"""Vectorized, batched redundancy planning (paper §III-B, Eqs. 14-16).

The two-step optimization finds, per fleet, the integer load allocation
`ell*_i(t)` maximizing each device's expected return and the smallest epoch
deadline `t*` whose aggregate best return reaches the dataset size `m`.  The
legacy stack (`repro.plan.reference`) re-solved every device's load with one
analytic-CDF call per integer load inside a 64-iteration bisection — ~4s for
one §IV plan.  This module replaces it with a closed-over-grid formulation:

  * the full `(t_grid, n, L)` expected-return tensor is evaluated in one
    shot — loads axis, devices axis, and a batch of candidate deadlines all
    at once — so a deadline probe costs one fused tensor expression instead
    of `L` Python-level CDF calls;
  * `t*` is recovered by monotone grid refinement: each round evaluates the
    aggregate best return on a `GRID_POINTS`-wide deadline grid and shrinks
    the bracket by that factor, so the load problem is never re-solved
    per bisection step;
  * everything is batched over fleets: `solve_redundancy_batched` plans a
    whole delta/fleet sweep in ONE jitted call (`(B, n)` delay parameters,
    per-request caps and parity budgets may differ).

The objective is pluggable (the extension point the `repro.schemes`
subsystem builds on).  Two knobs on `PlanRequest` select the evaluator:

  * `srv_weight` scales the server's expected return in the aggregate —
    the stochastic-CFL discount (arXiv:2201.10092): a privacy-noised,
    per-round-subsampled parity row carries `srv_weight` effective rows.
    Only the VALUE is discounted; the server's completion probability is
    still evaluated at the full row load, so the chosen deadline stays
    feasible for every per-round sampling realization (conservative by
    design — see `repro.schemes.stochastic`).  Requests with different
    weights batch together (it is a `(B,)` input); `srv_weight == 1.0` is
    bit-identical to the base CFL objective.
  * `edge_chunks` switches the edge evaluator to the partial-return
    objective of low-latency wireless CFL (arXiv:2011.06223): a device
    assigned `ell` points uploads `Q` incremental chunks, and its expected
    return is `(ell/Q) * sum_q Pr{chunk q done by t}` — evaluated as `Q`
    shifted copies of the same `(t_grid, n, L)` tensor, so over-assignment
    still hurts through the `mu/ell` memory-access slowdown and the load
    allocation stays a nontrivial argmax.  `edge_chunks` is a static shape
    fact, so requests group by `(padded n, edge_chunks)`; `edge_chunks == 1`
    takes the base code path unchanged.
  * `mec_comm` switches the edge evaluator to the multi-access edge
    computing delay model of CodedFedL (arXiv:2007.03273): instead of the
    discrete retransmission mixture, each device's communication leg is a
    SHIFTED EXPONENTIAL (shift `2 tau`, rate `(1 - p) / (2 tau p)` —
    matching the base geometric model's minimum and mean), and the edge
    return is `ell * Pr{T_comp + T_comm <= t}` via the closed-form
    two-exponential convolution.  A static trace-time branch: requests
    group by `(padded n, edge_chunks, mec_comm)`; `mec_comm == False`
    leaves the base evaluator untouched, and devices with `p == 0` or
    `tau == 0` fall back to the deterministic-comm compute CDF exactly.

Numerics: the solver runs in float64 under a scoped `enable_x64` so its
loads/probabilities match the float64 NumPy reference to well below the
integer-argmax tie margin; parity is enforced by `tests/test_plan_solver.py`.

The edge devices use the negative-binomial retransmission mixture with an
adaptive truncation (`_k_terms`; never beyond the reference's `K_MAX`); the
server is modelled without a communication leg (`tau == 0`), which every
fleet in this repo satisfies and `PlanRequest` validates.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.delay_model import (DeviceDelayParams, K_MAX, mec_total_cdf,
                                    total_cdf)
from repro.core.redundancy import RedundancyPlan

GRID_POINTS = 16    # deadline-grid resolution per refinement round
MAX_ROUNDS = 24     # refinement cap: 16^24 of dynamic range, never binding
MAX_DOUBLINGS = 60  # bracket-expansion cap (matches the legacy guard)

# Shape buckets: pad the device and load axes up so randomized workloads hit
# a handful of compiled kernels instead of one per (n, cap) combination.
# Padded devices get cap 0 and contribute exactly 0.0 to the aggregate.
_N_BUCKET = 8
_L_BUCKET = 64


@dataclasses.dataclass(frozen=True)
class PlanRequest:
    """One redundancy-planning problem: a fleet plus a parity budget.

    edge:       delay params of the n client devices
    server:     delay params of the central server (tau == 0 required)
    data_sizes: (n,) local dataset sizes ell_i
    c_up:       max parity rows the server may receive (default: m)
    fixed_c:    force the coding redundancy (delta-sweep mode)
    t_hi:       optional initial deadline bracket override
    srv_weight: effective rows per parity row in the aggregate return
                (stochastic-CFL noise/subsampling discount; 1.0 = base CFL)
    edge_chunks: per-epoch partial-upload chunks per device (low-latency
                wireless objective; 1 = all-or-nothing base CFL)
    mec_comm:   model each device's communication leg as the CodedFedL
                shifted-exponential MEC link instead of the discrete
                retransmission mixture (False = base CFL)
    """

    edge: DeviceDelayParams
    server: DeviceDelayParams
    data_sizes: np.ndarray
    c_up: Optional[int] = None
    fixed_c: Optional[int] = None
    t_hi: Optional[float] = None
    srv_weight: float = 1.0
    edge_chunks: int = 1
    mec_comm: bool = False

    def __post_init__(self):
        object.__setattr__(
            self, "data_sizes", np.asarray(self.data_sizes, dtype=np.int64))
        if not (0.0 <= float(self.srv_weight) <= 1.0):
            raise ValueError(
                f"srv_weight must be in [0, 1], got {self.srv_weight}")
        if int(self.edge_chunks) < 1:
            raise ValueError(
                f"edge_chunks must be >= 1, got {self.edge_chunks}")
        if self.mec_comm and int(self.edge_chunks) > 1:
            raise ValueError(
                "mec_comm models whole-assignment uploads; combining it "
                "with edge_chunks > 1 partial uploads is not defined")
        if self.server.n != 1:
            raise ValueError("server params must describe exactly one device")
        if float(self.server.tau[0]) != 0.0:
            raise ValueError(
                "the grid solver models the server without a communication "
                "leg; got server tau > 0")
        if self.data_sizes.shape != (self.edge.n,):
            raise ValueError(
                f"data_sizes must have shape ({self.edge.n},), "
                f"got {self.data_sizes.shape}")

    @property
    def m(self) -> int:
        return int(self.data_sizes.sum())

    @property
    def server_cap(self) -> int:
        if self.fixed_c is not None:
            return int(self.fixed_c)
        return int(self.c_up) if self.c_up is not None else self.m

    def default_t_hi(self) -> float:
        """Initial bracket: slowest device's mean epoch time at full load."""
        edge_mean = float(np.max(self.edge.mean_total(self.data_sizes)))
        srv_mean = float(self.server.mean_total(
            np.array([self.server_cap]))[0])
        return max(edge_mean, srv_mean) + 1.0


@functools.partial(jax.jit, static_argnames=("search_f32", "edge_chunks",
                                             "mec_comm"))
def _solve_grid(a, mu, tau, p, srv_a, srv_mu, srv_w, caps, srv_cap, target,
                t_hi0, eps_rel, ell_e, ell_s, ks_search, ks_extract,
                mask_search, mask_extract, frac, *, search_f32=True,
                edge_chunks=1, mec_comm=False):
    """Batched grid solve.  All inputs float64 except integer caps.

    a/mu/tau/p: (B, n) edge delay params    srv_a/srv_mu: (B,) server params
    srv_w: (B,) server return weights (1.0 = base CFL objective)
    caps: (B, n) load caps                  srv_cap: (B,) parity budgets
    target: (B,) aggregate-return targets   t_hi0: (B,) initial brackets
    edge_chunks: static partial-return chunk count (1 = all-or-nothing)
    mec_comm: static flag — shifted-exponential MEC communication legs
              (CodedFedL) instead of the retransmission mixture
    ell_e: (L,) edge load grid 0..L-1       ell_s: (Ls,) server load grid
    ks_search:  (K,) retransmission counts for the deadline search (tail
                below ~1e-12: invisible to any eps_rel)
    ks_extract: (K',) counts for the final load/aggregate extraction (tail
                below half an ulp of 1.0: indistinguishable from the
                reference's full series, see _k_terms)
    mask_search/mask_extract: (B, K)/(B, K') 0/1 masks zeroing each row's
                series beyond ITS OWN truncation length — K is sized for
                the batch's worst-case p, and masked terms add exactly 0.0,
                so every request's plan is bit-identical whether it is
                solved alone or batched with higher-p requests
    frac: (T,) refinement fractions

    Return probabilities are NOT extracted here: the Eq.-17 weights
    sqrt(1 - Pr) amplify last-ulp differences when Pr ~ 1, so the host
    re-evaluates `core.delay_model.total_cdf` at the returned (loads, t*) —
    bit-identical to what every downstream consumer computes.

    The deadline search runs in two phases: a float32 scout (the exp-heavy
    hot path at half the memory traffic) followed by a float64 polish that
    re-brackets and re-refines from the scout's answer.  In healthy regimes
    the scout lands within ~1e-6 of the float64 crossing and the polish is
    one cheap verification round; in SATURATING regimes — parity budget so
    small that the aggregate approaches the target only as every CDF
    saturates — float32 saturates its exponentials earlier than float64
    would, so the scout under-estimates t* and the polish does the real
    work.  The final load/aggregate extraction always runs in float64.
    """
    has_comm = tau > 0.0                                        # (B, n)
    load_ok = ell_e[None, None, :] <= caps[..., None]           # (B, n, L)
    s_ok = ell_s[None, :] <= srv_cap[:, None]                   # (B, Ls)

    def _shifted_exp_cdf(gamma_, s_):
        return jnp.where(
            s_ > 0.0,
            -jnp.expm1(-jnp.minimum(gamma_ * jnp.maximum(s_, 0.0), 700.0)),
            0.0)

    def _make_returns(dtype, ks, k_mask):
        """Expected-return evaluators closing over params cast to `dtype`."""
        a_, mu_, tau_, p_ = (x.astype(dtype) for x in (a, mu, tau, p))
        srv_a_, srv_mu_ = srv_a.astype(dtype), srv_mu.astype(dtype)
        srv_w_ = srv_w.astype(dtype)
        ell_e_, ell_s_, ks_ = (x.astype(dtype) for x in (ell_e, ell_s, ks))
        pmf = (ks_ - 1.0) * p_[..., None] ** (ks_ - 2.0) \
            * (1.0 - p_[..., None]) ** 2                        # (B, n, K)
        pmf = pmf * k_mask.astype(dtype)[:, None, :]  # per-row truncation
        shift = ell_e_[None, None, :] * a_[..., None]           # (B, n, L)
        gamma = mu_[..., None] / jnp.maximum(ell_e_, 1.0)       # (B, n, L)
        s_shift = ell_s_[None, :] * srv_a_[:, None]             # (B, Ls)
        s_gamma = srv_mu_[:, None] / jnp.maximum(ell_s_, 1.0)   # (B, Ls)

        # truncated-series mass, accumulated in the same order as the
        # mixture loop below: when every kept CDF term saturates at exactly
        # 1.0 the mixture equals this bitwise, and snapping it to 1.0 makes
        # full saturation exact — which is also where the reference's
        # 64-term float64 sum rounds to 1.0 (the truncation tail is below
        # half an ulp of 1.0, see _k_terms).  The snap applies ONLY where
        # the kept mass really is ~1: for large p even the full K_MAX
        # series drops real mass (the reference plateaus below 1 there and
        # the infeasibility guard depends on us plateauing identically).
        pmf_total = jax.lax.fori_loop(
            0, ks.shape[0], lambda i, acc: acc + pmf[:, :, i],
            jnp.zeros(a.shape, dtype=dtype))                    # (B, n)
        snap_tol = 1e-4 if dtype == jnp.float32 else 1e-13
        snap_ok = pmf_total >= 1.0 - snap_tol                   # (B, n)

        def _load_cdf(t_res):
            """Per-load completion CDF at residual time `t_res` (B, T', n).

            edge_chunks == 1: Pr{the whole assignment ell is done} — the
            base all-or-nothing evaluator, code path unchanged.
            edge_chunks == Q > 1: the partial-return objective — the MEAN
            over q of Pr{chunk q (first q*ell/Q points) is done}, i.e. the
            expected FRACTION of the assignment uploaded by t.  Each chunk
            shifts the deterministic compute by (q/Q)*ell*a while the
            stochastic rate stays mu/ell (the memory-access slowdown scales
            with the full assignment), so over-assignment still hurts.
            Returns (B, T', n, L)."""
            if edge_chunks == 1:
                s = t_res[..., None] - shift[:, None, :, :]   # (B, T', n, L)
                cdf = _shifted_exp_cdf(gamma[:, None], s)
            else:
                def add_q(j, acc):
                    fq = (jnp.asarray(j, dtype) + 1.0) / edge_chunks
                    s = t_res[..., None] - fq * shift[:, None, :, :]
                    return acc + _shifted_exp_cdf(gamma[:, None], s)
                cdf = jax.lax.fori_loop(
                    0, edge_chunks, add_q,
                    jnp.zeros(t_res.shape + (ell_e.shape[0],), dtype=dtype))
                cdf = cdf / edge_chunks
            return jnp.where(ell_e_ > 0.0, cdf,
                             (t_res[..., None] >= 0.0).astype(dtype))

        def edge_returns_mec(t):
            """Masked MEC E[R_i(t; ell)] grid.  t: (B, T') -> (B, T', n, L).

            CodedFedL's delay model: T_comp is the base shifted
            exponential (shift ell*a, rate mu/ell) but the communication
            leg is ALSO a shifted exponential — shift `2 tau` (the
            erasure-free two-way transfer), rate
            `gm = (1 - p) / (2 tau p)`, chosen so the MEC link matches the
            base geometric retransmission model's minimum (2 tau) and mean
            excess (2 tau p / (1 - p)).  The completion CDF is the
            closed-form convolution of the two exponentials at residual
            `u = t - ell*a - 2 tau`:

                F(u) = 1 - (gm e^{-gc u} - gc e^{-gm u}) / (gm - gc)

            with the equal-rate limit `1 - (1 + g u) e^{-g u}` taken where
            the rates collide (within a relative tie margin, so the
            division never amplifies a catastrophic cancellation).
            Devices with `p == 0` or `tau == 0` have a DETERMINISTIC
            communication leg and fall back to the pure compute CDF at the
            same residual — bit-identical to the base evaluator when
            tau == 0 everywhere.  Monotone in t by construction.
            """
            gc = gamma                                          # (B, n, L)
            gm = (1.0 - p_) / jnp.maximum(2.0 * tau_ * p_, 1e-30)  # (B, n)
            gm_l = gm[:, :, None]                               # (B, n, 1)
            u = t[:, :, None, None] - shift[:, None, :, :] \
                - 2.0 * tau_[:, None, :, None]                  # (B,T',n,L)
            up = jnp.maximum(u, 0.0)
            e_c = jnp.exp(-jnp.minimum(gc[:, None] * up, 700.0))
            e_m = jnp.exp(-jnp.minimum(gm_l[:, None] * up, 700.0))
            denom = gm_l - gc                                   # (B, n, L)
            close = jnp.abs(denom) <= 1e-8 * jnp.maximum(gm_l, gc)
            safe = jnp.where(close, jnp.ones((), dtype=dtype), denom)
            f_neq = 1.0 - (gm_l[:, None] * e_c - gc[:, None] * e_m) \
                / safe[:, None]
            gbar = 0.5 * (gm_l + gc)
            arg = jnp.minimum(gbar[:, None] * up, 700.0)
            f_eq = -jnp.expm1(-arg) - arg * jnp.exp(-arg)
            cdf = jnp.where(close[:, None], f_eq, f_neq)
            cdf = jnp.where(u > 0.0, cdf, 0.0)
            # deterministic communication leg: pure compute CDF at u
            det = jnp.logical_or(p_ <= 0.0, tau_ <= 0.0)        # (B, n)
            cdf = jnp.where(det[:, None, :, None],
                            _shifted_exp_cdf(gc[:, None], u), cdf)
            cdf = jnp.where(ell_e_ > 0.0, cdf, (u >= 0.0).astype(dtype))
            return jnp.where(load_ok[:, None], ell_e_ * cdf, -jnp.inf)

        def edge_returns_base(t):
            """Masked E[R_i(t; ell)] grid.  t: (B, T') -> (B, T', n, L)."""
            def add_k(i, acc):
                t_res = t[:, :, None] - ks_[i] * tau_[:, None, :]
                return acc + pmf[:, None, :, i, None] * _load_cdf(t_res)
            mix = jax.lax.fori_loop(
                0, ks.shape[0], add_k,
                jnp.zeros(t.shape + (a.shape[1], ell_e.shape[0]),
                          dtype=dtype))
            mix = jnp.where(
                jnp.logical_and(mix >= pmf_total[:, None, :, None],
                                snap_ok[:, None, :, None]),
                jnp.ones((), dtype=dtype), mix)
            # tau == 0 devices have no retransmission mixture: compute CDF
            nocomm = _load_cdf(
                jnp.broadcast_to(t[:, :, None], t.shape + (a.shape[1],)))
            mix = jnp.where(has_comm[:, None, :, None], mix, nocomm)
            return jnp.where(load_ok[:, None], ell_e_ * mix, -jnp.inf)

        edge_returns = edge_returns_mec if mec_comm else edge_returns_base

        def server_returns(t):
            """Masked weighted server E[R(t; ell)].  (B, T') -> (B, T', Ls).

            The weight srv_w discounts every parity row's contribution to
            the aggregate (1.0 = base CFL, exact multiply-by-one)."""
            s = t[:, :, None] - s_shift[:, None, :]
            cdf = _shifted_exp_cdf(s_gamma[:, None], s)
            cdf = jnp.where(ell_s_ > 0.0, cdf,
                            (t[:, :, None] >= 0.0).astype(cdf.dtype))
            return jnp.where(s_ok[:, None],
                             srv_w_[:, None, None] * ell_s_ * cdf, -jnp.inf)

        def best_agg(t):
            """Aggregate best return.  t: (B, T') -> (B, T')."""
            return edge_returns(t).max(axis=-1).sum(axis=-1) \
                + server_returns(t).max(axis=-1)

        return edge_returns, server_returns, best_agg

    def _search(best_agg, t_lo0, t_hi0_, target_, eps_, frac_, step0_frac):
        """Bracket-expand then grid-refine.  Returns (t_lo, t_hi, feasible).

        Bracket expansion grows t_hi by a per-row step that doubles every
        iteration, starting at `step0_frac * t_hi`.  step0_frac=1 is the
        legacy pure doubling (cold start); the float64 polish passes
        step0_frac=eps so a last-ulp shortfall against the scout's bracket
        costs one eps-sized nudge instead of overshooting to 2x t*.
        """
        agg0 = best_agg(t_hi0_[:, None])[:, 0]

        def b_cond(st):
            _, _, agg, i = st
            return jnp.logical_and(i < MAX_DOUBLINGS, jnp.any(agg < target_))

        def b_body(st):
            t_hi, step, agg, i = st
            need = agg < target_
            t_new = jnp.where(need, t_hi + step, t_hi)
            step = jnp.where(need, 2.0 * step, step)
            agg_new = jnp.where(need, best_agg(t_new[:, None])[:, 0], agg)
            return t_new, step, agg_new, i + 1

        t_hi, _, agg_hi, _ = jax.lax.while_loop(
            b_cond, b_body,
            (t_hi0_, step0_frac * t_hi0_, agg0, jnp.asarray(0)))
        feasible = agg_hi >= target_

        # --- monotone grid refinement on t ---------------------------------
        def _active(t_lo, t_hi):
            wide = (t_hi - t_lo) > eps_ * jnp.maximum(t_hi, 1e-12)
            return jnp.logical_and(wide, feasible)

        def r_cond(st):
            t_lo, t_hi, r = st
            return jnp.logical_and(r < MAX_ROUNDS,
                                   jnp.any(_active(t_lo, t_hi)))

        def r_body(st):
            t_lo, t_hi, r = st
            grid = t_lo[:, None] + frac_[None, :] * (t_hi - t_lo)[:, None]
            grid = grid.at[:, -1].set(t_hi)  # exact upper edge: invariant
            ok = best_agg(grid) >= target_[:, None]
            idx = jnp.argmax(ok, axis=1)  # first grid point over the target
            hi_new = jnp.take_along_axis(grid, idx[:, None], axis=1)[:, 0]
            lo_prev = jnp.take_along_axis(
                grid, jnp.maximum(idx - 1, 0)[:, None], axis=1)[:, 0]
            lo_new = jnp.where(idx == 0, t_lo, lo_prev)
            act = _active(t_lo, t_hi)
            return (jnp.where(act, lo_new, t_lo),
                    jnp.where(act, hi_new, t_hi), r + 1)

        t_lo, t_hi, _ = jax.lax.while_loop(
            r_cond, r_body, (t_lo0, t_hi, jnp.asarray(0)))
        return t_lo, t_hi, feasible

    # --- phase 1: float32 scout --------------------------------------------
    step0 = jnp.ones((), dtype=t_hi0.dtype)
    if search_f32:
        f32 = jnp.float32
        _, _, best_agg32 = _make_returns(f32, ks_search, mask_search)
        lo32, hi32, _ = _search(
            best_agg32, jnp.zeros_like(t_hi0, dtype=f32), t_hi0.astype(f32),
            target.astype(f32), eps_rel.astype(f32), frac.astype(f32),
            jnp.ones((), dtype=f32))
        t_lo0, t_hi0 = lo32.astype(t_hi0.dtype), hi32.astype(t_hi0.dtype)
        step0 = eps_rel.astype(t_hi0.dtype)
    else:
        t_lo0 = jnp.zeros_like(t_hi0)

    # --- phase 2: float64 polish (re-brackets past the scout if needed) ----
    _, _, best_agg = _make_returns(a.dtype, ks_search, mask_search)
    _, t_star, feasible = _search(
        best_agg, t_lo0, t_hi0, target, eps_rel, frac, step0)

    # --- recover loads / aggregate at t* (float64, half-ulp tail) ----------
    edge_returns, server_returns, _ = _make_returns(a.dtype, ks_extract,
                                                    mask_extract)
    ev = edge_returns(t_star[:, None])[:, 0]                    # (B, n, L)
    loads = jnp.argmax(ev, axis=-1)                             # (B, n)
    best = jnp.take_along_axis(ev, loads[..., None], axis=-1)[..., 0]
    sv = server_returns(t_star[:, None])[:, 0]                  # (B, Ls)
    s_load = jnp.argmax(sv, axis=-1)                            # (B,)
    s_best = jnp.take_along_axis(sv, s_load[:, None], axis=1)[:, 0]
    agg = best.sum(axis=-1) + s_best

    return t_star, loads, s_load, agg, feasible


def _bucket(value: int, bucket: int) -> int:
    return max(bucket, -(-value // bucket) * bucket)


def _k_terms(p_max: float, tol: float = 5e-17) -> int:
    """Retransmission terms needed for a < `tol` negative-binomial tail.

    The reference truncates at K_MAX regardless of p; a tail below half an
    ulp of 1.0 makes the truncated series indistinguishable from the full
    one at saturation (see the pmf_total snap in `_solve_grid`) while
    keeping the §IV hot path cheap (p = 0.1 needs 24 terms, not 64).
    """
    ks = np.arange(2, 2 + K_MAX, dtype=np.float64)
    pmf = (ks - 1.0) * p_max ** (ks - 2.0) * (1.0 - p_max) ** 2
    tails = np.cumsum(pmf[::-1])[::-1]
    small = np.flatnonzero(tails < tol)
    k_eff = int(small[0]) + 1 if small.size else K_MAX
    return min(_bucket(k_eff, 8), K_MAX)


def solve_redundancy_batched(requests: Sequence[PlanRequest],
                             eps_rel: float = 1e-3,
                             grid_points: int = GRID_POINTS
                             ) -> list[RedundancyPlan]:
    """Plan a whole sweep of fleets/budgets in one vectorized solve.

    Requests are grouped by (padded device count, edge_chunks, mec_comm);
    each group runs as a single jitted `(B, n)` solve.  Mixed `fixed_c` /
    free-redundancy / `srv_weight` requests batch fine — budget and weight
    are per-request inputs; `edge_chunks` and `mec_comm` change the
    compiled evaluator, so those requests form their own groups.  Raises
    RuntimeError (like the legacy solver) if any request's fleet cannot
    reach its target.
    """
    requests = list(requests)
    plans: list[Optional[RedundancyPlan]] = [None] * len(requests)
    groups: dict[tuple[int, int, bool], list[int]] = {}
    for i, req in enumerate(requests):
        key = (_bucket(req.edge.n, _N_BUCKET), int(req.edge_chunks),
               bool(req.mec_comm))
        groups.setdefault(key, []).append(i)

    frac = np.arange(1, grid_points + 1, dtype=np.float64) / grid_points

    for (n_pad, edge_chunks, mec_comm), idxs in groups.items():
        grp = [requests[i] for i in idxs]
        b = len(grp)

        def pad(vec, fill):
            out = np.full(n_pad, fill, dtype=np.float64)
            out[:vec.shape[0]] = vec
            return out

        a = np.stack([pad(r.edge.a, 1.0) for r in grp])
        mu = np.stack([pad(r.edge.mu, 1.0) for r in grp])
        tau = np.stack([pad(r.edge.tau, 0.0) for r in grp])
        p = np.stack([pad(r.edge.p, 0.0) for r in grp])
        caps = np.stack([pad(r.data_sizes.astype(np.float64), 0.0)
                         for r in grp]).astype(np.int64)
        srv_a = np.array([r.server.a[0] for r in grp])
        srv_mu = np.array([r.server.mu[0] for r in grp])
        srv_w = np.array([float(r.srv_weight) for r in grp])
        srv_cap = np.array([r.server_cap for r in grp], dtype=np.int64)
        target = np.array([float(r.m) for r in grp])
        t_hi0 = np.array([r.t_hi if r.t_hi is not None else r.default_t_hi()
                          for r in grp])

        l_edge = _bucket(int(caps.max()) + 1, _L_BUCKET)
        l_srv = _bucket(int(srv_cap.max()) + 1, _L_BUCKET)
        # per-request truncation lengths, padded to the group max and
        # masked per row: plans are bit-identical solo vs batched
        k_search = [_k_terms(float(r.edge.p.max()), tol=1e-12) for r in grp]
        k_extract = [_k_terms(float(r.edge.p.max())) for r in grp]

        def k_mask(k_effs):
            mask = np.zeros((b, max(k_effs)), dtype=np.float64)
            for j, k_eff in enumerate(k_effs):
                mask[j, :k_eff] = 1.0
            return mask

        # float32 search resolves t* to ~1e-6 relative; honor tighter eps
        # requests by keeping the whole solve in float64
        search_f32 = eps_rel >= 1e-5

        with jax.experimental.enable_x64():
            out = _solve_grid(
                a, mu, tau, p, srv_a, srv_mu, srv_w, caps, srv_cap, target,
                t_hi0, np.float64(eps_rel),
                np.arange(l_edge, dtype=np.float64),
                np.arange(l_srv, dtype=np.float64),
                np.arange(2, 2 + max(k_search), dtype=np.float64),
                np.arange(2, 2 + max(k_extract), dtype=np.float64),
                k_mask(k_search), k_mask(k_extract), frac,
                search_f32=search_f32, edge_chunks=edge_chunks,
                mec_comm=mec_comm)
            t_star, loads, s_load, agg, feasible = \
                (np.asarray(o) for o in out)

        if not feasible.all():
            bad = np.flatnonzero(~feasible)
            detail = "; ".join(
                f"request {idxs[j]} (of the requests list): target "
                f"{target[j]:.0f}, best achievable {agg[j]:.1f}"
                for j in bad)
            raise RuntimeError(
                "cannot reach the aggregate expected return target — the "
                f"fleet cannot return the points in finite time: {detail}")

        for j, i in enumerate(idxs):
            req = requests[i]
            n = req.edge.n
            c = int(req.fixed_c) if req.fixed_c is not None \
                else int(s_load[j])
            dev_loads = loads[j, :n].astype(np.int64)
            # per-device return probs re-evaluated on the host: bit-identical
            # to every downstream total_cdf consumer (see _solve_grid docs);
            # mec groups read the matching MEC CDF (the server has no comm
            # leg, so its total_cdf is the same compute CDF either way)
            edge_cdf = mec_total_cdf if mec_comm else total_cdf
            p_return = np.append(
                edge_cdf(req.edge, dev_loads, float(t_star[j])),
                total_cdf(req.server, np.array([float(s_load[j])]),
                          float(t_star[j])))
            plans[i] = RedundancyPlan(
                loads=dev_loads,
                c=c,
                t_star=float(t_star[j]),
                p_return=p_return,
                expected_agg=float(agg[j]),
                loads_cap_total=req.m,
            )
    return plans
