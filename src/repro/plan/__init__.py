"""Batched redundancy-planning subsystem (paper §III-B).

`solve_redundancy_batched` evaluates the full `(t_grid, n, L)` expected-
return tensor in one jitted shot and plans a whole delta/fleet sweep per
call; `PlanRequest` describes one fleet + parity budget.  The objective is
pluggable (`srv_weight` / `edge_chunks` — the `repro.schemes` extension
points; see API.md "Adding an objective evaluator").  The legacy scalar
stack survives in `repro.plan.reference` for parity tests and benchmark
baselines, with the scheme objectives' oracles in
`repro.plan.reference_schemes`.  Single-fleet callers keep using the thin
shims `core.redundancy.solve_redundancy` / `core.cfl.setup`, which route
here.
"""
from .solver import (GRID_POINTS, MAX_DOUBLINGS, MAX_ROUNDS, PlanRequest,
                     solve_redundancy_batched)

__all__ = [
    "PlanRequest", "solve_redundancy_batched",
    "GRID_POINTS", "MAX_ROUNDS", "MAX_DOUBLINGS",
]
