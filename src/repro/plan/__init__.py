"""Batched redundancy-planning subsystem (paper §III-B).

`solve_redundancy_batched` evaluates the full `(t_grid, n, L)` expected-
return tensor in one jitted shot and plans a whole delta/fleet sweep per
call; `PlanRequest` describes one fleet + parity budget.  The objective is
pluggable (`srv_weight` / `edge_chunks` — the `repro.schemes` extension
points; see API.md "Adding an objective evaluator").  The legacy scalar
stack survives in `repro.plan.reference` for parity tests and benchmark
baselines, with the scheme objectives' oracles in
`repro.plan.reference_schemes`.  Single-fleet callers keep using the thin
shims `core.redundancy.solve_redundancy` / `core.cfl.setup`, which route
here.  `srv_weight_for_epsilon` parameterizes the stochastic-CFL server
weight by an (epsilon, delta)-DP budget (batched calibration through
`repro.privacy`), so privacy-utility sweeps batch like any other sweep.
"""
import numpy as np

from .solver import (GRID_POINTS, MAX_DOUBLINGS, MAX_ROUNDS, PlanRequest,
                     solve_redundancy_batched)

__all__ = [
    "PlanRequest", "solve_redundancy_batched",
    "GRID_POINTS", "MAX_ROUNDS", "MAX_DOUBLINGS",
    "effective_srv_weight", "srv_weight_for_epsilon",
]


def effective_srv_weight(noise_multiplier, sample_frac):
    """The stochastic-CFL server discount: rho / (1 + sigma^2).

    A parity row sampled with probability rho whose gradient carries noise
    power sigma^2 relative to signal is worth rho / (1 + sigma^2) clean
    rows of expected-return VALUE (`PlanRequest.srv_weight`).  Vectorized;
    the one place this formula lives (`StochasticCodedFL.srv_weight` and
    the epsilon-parameterized helper below both route here).
    """
    nm = np.asarray(noise_multiplier, dtype=np.float64)
    return np.asarray(sample_frac, dtype=np.float64) / (1.0 + nm * nm)


def srv_weight_for_epsilon(epsilon_target, delta=1e-5, rounds=1,
                           sample_frac=1.0):
    """epsilon-parameterized `PlanRequest.srv_weight`, vectorized.

    Calibrates the smallest noise multiplier meeting each (epsilon, delta,
    rounds) budget — array targets run as ONE batched
    `repro.privacy.calibrate_noise` solve — and returns the matching
    server weight(s), so a privacy-utility sweep builds its `PlanRequest`s
    (or `StochasticCodedFL(noise_multiplier=...)` strategies) without a
    per-point calibration loop and batches the allocation solves through
    `plan_sweep` as usual.
    """
    from repro.privacy import calibrate_noise
    sigma = calibrate_noise(epsilon_target, delta=delta, rounds=rounds,
                            sample_frac=sample_frac)
    return effective_srv_weight(sigma, sample_frac)
