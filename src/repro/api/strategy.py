"""The `Strategy` protocol: pluggable coding schemes for federated training.

A strategy answers two questions the paper's three hand-rolled loops used to
answer in copy-pasted epoch bodies:

  1. `plan(fleet, data)` — one-time host-side setup: load allocation,
     deadline, encoding.  Returns an opaque strategy state.
  2. `round_contributions(state, dev, beta, arrivals)` — given one epoch's
     arrival masks, produce the combined gradient estimate.  This is traced
     once into the `Session`'s `jax.lax.scan` body, so it must be
     jit-compatible and may read ONLY static structure (shapes, flags, the
     redundancy plan) from `state`; every array it consumes must flow in
     through `dev` (per-run device constants from `device_state`, including
     the strategy's preferred layout of the training data) or `arrivals`
     (per-epoch tensors from `sample_epochs`).

All three built-in strategies lay the data out flat — `x: (m, d)`,
`y: (m,)` with per-row client/group indices — so an epoch is two row-major
matvecs: `resid = x @ beta - y` then `(resid * row_weights) @ x`.
Leading-axis contractions are ~10x faster than the per-client batched
einsums on CPU, and the weighting vector is where each scheme's arrival
semantics live.

Between the two sits the delay machinery: `sample_epochs` pre-samples every
epoch's delays/arrivals up front on the host (tiny NumPy work, shape
`(epochs, n)`), preserving the exact draw order of the legacy per-epoch
loops so old and new entry points produce identical traces from the same
`np.random.Generator`.

Three first-class implementations ship here:

  * `UncodedFL`        — synchronous FL, wait for every straggler (Eq. 2).
  * `CodedFL`          — the paper's CFL protocol (wraps `core.cfl`).
  * `GradientCodingFL` — fractional-repetition gradient coding
                         (Tandon et al., the paper's ref [5]), previously
                         only reachable through a bespoke script loop.

New coding schemes drop in as one more class — no fourth epoch loop.  The
first two follow-ups (the stochastic and low-latency wireless variants in
PAPERS.md) live in `repro.schemes`; construct any scheme by name via
`repro.api.make_strategy`.
"""
from __future__ import annotations

import dataclasses
from typing import (
    TYPE_CHECKING, Any, ClassVar, Dict, Hashable, Optional, Protocol,
    runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, cfl
from repro.core.delay_model import sample_total
from repro.core.gradient_coding import GradCodingPlan, make_plan
from repro.core.redundancy import RedundancyPlan

if TYPE_CHECKING:  # annotation-only: avoids the sim -> api -> sim cycle
    from repro.sim.network import FleetSpec


@dataclasses.dataclass(frozen=True)
class TrainData:
    """The decentralized training problem: client-sharded linear regression.

    xs: (n, ell, d) client-resident features
    ys: (n, ell)    client-resident labels
    beta_true: (d,) ground truth (for the NMSE trace only)
    """

    xs: jax.Array
    ys: jax.Array
    beta_true: jax.Array

    @property
    def n(self) -> int:
        return int(self.xs.shape[0])

    @property
    def ell(self) -> int:
        return int(self.xs.shape[1])

    @property
    def d(self) -> int:
        return int(self.xs.shape[2])

    @property
    def m(self) -> int:
        return self.n * self.ell

    @property
    def model_dim(self) -> int:
        """Dimension of the trained model iterate (`beta_true.shape[0]`).

        Equal to `d` for the raw linear-regression workloads; differs when
        the strategy trains in a transformed space (e.g. `CodedFedL`'s
        random-Fourier-feature head, where `xs` holds raw inputs of width
        `d` but the model lives in the `d_feat`-wide feature space)."""
        return int(self.beta_true.shape[0])

    @classmethod
    def linreg(cls, key: jax.Array, n: int, ell: int, d: int,
               noise_std: float = 1.0) -> "TrainData":
        """Paper §IV data: X iid N(0,1), beta ~ N(0,1)^d, y = X beta + z."""
        k1, k2, k3 = jax.random.split(key, 3)
        xs = jax.random.normal(k1, (n, ell, d), dtype=jnp.float32)
        beta = jax.random.normal(k2, (d,), dtype=jnp.float32)
        zs = noise_std * jax.random.normal(k3, (n, ell), dtype=jnp.float32)
        ys = jnp.einsum("nld,d->nl", xs, beta) + zs
        return cls(xs=xs, ys=ys, beta_true=beta)


@dataclasses.dataclass
class EpochSchedule:
    """Pre-sampled per-epoch randomness for one full training run.

    durations: (epochs,) wall time of each epoch (host-side bookkeeping)
    arrivals:  dict of per-epoch tensors, each with leading dim `epochs`;
               becomes the xs of the Session's `lax.scan`
    setup_time: one-time setup wall time to report (0 if none)
    t0:        wall-clock offset at which epoch 0 starts
    """

    durations: np.ndarray
    arrivals: Dict[str, np.ndarray]
    setup_time: float = 0.0
    t0: float = 0.0


@runtime_checkable
class Strategy(Protocol):
    """Pluggable federated-training scheme (see module docstring)."""

    label: str

    def plan(self, fleet: "FleetSpec", data: TrainData) -> Any:
        """One-time host-side setup; returns the strategy state."""
        ...

    def sample_epochs(self, state: Any, fleet: "FleetSpec", epochs: int,
                      rng: np.random.Generator) -> EpochSchedule:
        """Pre-sample every epoch's delays/arrival masks (NumPy, host)."""
        ...

    def device_state(self, state: Any,
                     data: TrainData) -> Dict[str, jax.Array]:
        """Per-run device-resident constants fed to the scan as operands,
        including the strategy's preferred layout of the training data."""
        ...

    def round_contributions(self, state: Any, dev: Dict[str, jax.Array],
                            beta: jax.Array,
                            arrivals: Dict[str, jax.Array]) -> jax.Array:
        """One epoch's combined gradient estimate (jit/scan-traceable)."""
        ...

    def uplink_bits(self, state: Any, fleet: "FleetSpec",
                    epochs: int) -> float:
        """Total device->server bits for a run of `epochs` epochs."""
        ...

    def engine_key(self, state: Any) -> Hashable:
        """Static facts `round_contributions` branches on (cache key part)."""
        ...

    # Optional hooks (looked up with getattr, not part of the protocol):
    #   * report_extras(state) -> dict — scalar knobs/diagnostics copied
    #     onto TraceReport.extras (e.g. StochasticCodedFL's noise knob);
    #   * plan_request(fleet, data) -> repro.plan.PlanRequest and
    #     plan_with(fleet, data, plan) -> state — expose them to let
    #     `api.plan_sweep` batch the strategy's allocation solve with every
    #     other session's into one jitted grid solve;
    #   * sweep_inputs(state, fleet, epochs, rng) -> EpochSchedule — one
    #     sweep lane's per-epoch inputs for `api.run_sweep`.  Contract:
    #     every arrival tensor's shape is a function of the engine-static
    #     structure only (so lanes of one shape bucket stack), and the
    #     generator draw order is identical to `sample_epochs` (so sweep
    #     lanes are bit-for-bit equal to solo runs).  `run_sweep` falls
    #     back to `sample_epochs` when absent;
    #   * engine_value_fields: frozenset of dataclass field names that only
    #     feed operand VALUES (plan inputs, host-side sampling, report
    #     metadata) and never steer the traced engine.  The sweep engine
    #     keys its compiled-engine cache on every OTHER primitive field
    #     (plus `engine_key`), so declaring a field here lets lanes that
    #     differ only in that knob share one compiled engine; omitting a
    #     declaration is always safe, merely over-fragmenting buckets;
    #   * data_device_keys: frozenset of `device_state` keys whose arrays
    #     are pure functions of the TrainData alone (the flat training
    #     matrices, typically).  All lanes of one `run_sweep(sessions,
    #     data)` call see the same data, so the sweep engine ships ONE
    #     replicated copy of these operands instead of stacking them B
    #     times.  Omitting the declaration is always safe (everything is
    #     stacked per lane);
    #   * tiered_contributions(state, dev, beta, arrivals, tier_masks) ->
    #     ((T, d) tier partials, optional (d,) server term) — the
    #     hierarchical form of `round_contributions` consumed by
    #     `repro.fleet.HierarchicalCFL`: given (T, m) one-hot row masks
    #     over the flat client-major layout, return per-tier partials via
    #     `core.aggregation.tier_reduce` (full-width masked gemvs, so each
    #     partial matches the flat contraction bit-for-bit) plus any
    #     server-side term (parity gradients) that is NOT client-resident
    #     and therefore bypasses the edge tier.  Contract:
    #     `cross_tier_combine(partials) + server` must equal
    #     `round_contributions` exactly for a single all-ones tier mask
    #     and to T-term-reassociation ulp for any tier partition.
    #     Strategies without the hook cannot be wrapped hierarchically;
    #   * serve_convergence(state, criterion) -> criterion — the serving
    #     engine's convergence hook (`repro.serving.fed_engine`): given
    #     the engine's per-lane `ConvergenceCriterion`, return a
    #     (possibly tightened) criterion for this session.  The canonical
    #     use is budget exhaustion: `StochasticCodedFL` caps
    #     `max_epochs` at its DP accounting horizon so an
    #     epsilon-budgeted lane exits when the budget is spent instead
    #     of training past it.  Absent the hook, the engine's criterion
    #     applies unchanged.


# ---------------------------------------------------------------------------
# Uncoded synchronous FL
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class UncodedState:
    loads: np.ndarray  # (n,) full local dataset size per client


@dataclasses.dataclass(frozen=True)
class UncodedFL:
    """Synchronous uncoded FL: every epoch waits for all n clients (Eq. 2)."""

    label: str = "uncoded"
    grad_path: str = aggregation.FUSED

    # grad_path steers the traced engine; it stays OUT of
    # engine_value_fields so the engine cache keys on it automatically
    engine_value_fields: ClassVar[frozenset] = frozenset()
    # the flat training matrices are data-only: one replicated copy per sweep
    data_device_keys: ClassVar[frozenset] = frozenset({"x", "y"})

    def plan(self, fleet: "FleetSpec", data: TrainData) -> UncodedState:
        return UncodedState(loads=np.full(data.n, data.ell))

    def sample_epochs(self, state: UncodedState, fleet: "FleetSpec",
                      epochs: int, rng: np.random.Generator) -> EpochSchedule:
        durations = np.empty(epochs)
        # per-epoch host loop preserves the legacy generator draw order
        for e in range(epochs):
            t_i = sample_total(fleet.edge, state.loads, rng)
            durations[e] = float(np.max(t_i))  # wait for all stragglers
        return EpochSchedule(durations=durations,
                             arrivals={"epoch": np.zeros(epochs, np.float32)})

    def device_state(self, state: UncodedState,
                     data: TrainData) -> Dict[str, jax.Array]:
        return {"x": data.xs.reshape(data.m, data.d),
                "y": data.ys.reshape(data.m)}

    def round_contributions(self, state, dev, beta, arrivals):
        # exact full gradient (Eq. 2); both grad paths route through the
        # dispatcher — on CPU they are one and the same expression
        return aggregation.round_gradient(
            dev["x"], dev["y"], beta,
            path=aggregation.resolve_grad_path(self.grad_path))

    def tiered_contributions(self, state, dev, beta, arrivals, tier_masks):
        return aggregation.tiered_round_gradient(
            dev["x"], dev["y"], beta, None, tier_masks,
            path=aggregation.resolve_grad_path(self.grad_path)), None

    def uplink_bits(self, state: UncodedState, fleet: "FleetSpec",
                    epochs: int) -> float:
        return epochs * state.loads.shape[0] * 2 * fleet.packet_bits

    def engine_key(self, state: UncodedState) -> Hashable:
        return ()

    def sweep_inputs(self, state: UncodedState, fleet: "FleetSpec",
                     epochs: int, rng: np.random.Generator) -> EpochSchedule:
        """One sweep lane's inputs: the (epochs,) placeholder tensor stacks
        across any uncoded lanes; draws are exactly `sample_epochs`."""
        return self.sample_epochs(state, fleet, epochs, rng)


# ---------------------------------------------------------------------------
# Coded Federated Learning (the paper's protocol)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CodedFL:
    """CFL (paper §III): deadline t*, systematic + parity gradients.

    key:        PRNG key for the one-time private generator matrices
    fixed_c:    force the coding redundancy (delta-sweep mode) instead of
                running the Eq. 14-16 optimization
    c_up:       cap on the server's parity budget
    include_upload_delay: charge the one-time parity upload to the clock
    server_always_returns: ablation — parity gradient always lands
    use_kernel: DEPRECATED — folded into grad_path (True forces "fused");
                still routes the one-time parity ENCODE through Pallas
    redundancy_plan: pre-solved `RedundancyPlan` (one element of a
                `repro.plan.solve_redundancy_batched` sweep); `plan` then
                skips the solve and only encodes
    grad_path:  "fused" (default — packed one-pass round gradient, Gram
                parity) or "reference" (the verbatim pre-fusion epoch
                body, the bit-parity oracle)
    """

    key: jax.Array
    fixed_c: Optional[int] = None
    c_up: Optional[int] = None
    include_upload_delay: bool = True
    server_always_returns: bool = False
    use_kernel: bool = False
    generator: str = "normal"
    label: str = "cfl"
    redundancy_plan: Optional["RedundancyPlan"] = None
    grad_path: str = aggregation.FUSED

    def _grad_path(self) -> str:
        return aggregation.resolve_grad_path(self.grad_path,
                                             self.use_kernel)

    # knobs that only shape the plan / host-side sampling, never the traced
    # engine: lanes differing in them share one compiled sweep engine
    # (use_kernel stays keyed — it swaps the parity-gradient code path)
    engine_value_fields: ClassVar[frozenset] = frozenset(
        {"fixed_c", "c_up", "include_upload_delay", "server_always_returns",
         "generator"})
    # data-only operands (one replicated copy per sweep); the plan-derived
    # load mask and parity shards stay per-lane
    data_device_keys: ClassVar[frozenset] = frozenset(
        {"x", "y", "row_client"})

    def plan(self, fleet: "FleetSpec", data: TrainData) -> cfl.CFLState:
        return self.plan_with(fleet, data, self.redundancy_plan)

    # -- batched-planning hooks (see api.session.plan_sweep) ----------------

    def plan_request(self, fleet: "FleetSpec", data: TrainData):
        """The redundancy problem this strategy would solve in `plan`."""
        from repro.plan import PlanRequest
        return PlanRequest(edge=fleet.edge, server=fleet.server,
                           data_sizes=np.full(data.n, data.ell,
                                              dtype=np.int64),
                           c_up=self.c_up, fixed_c=self.fixed_c)

    def plan_with(self, fleet: "FleetSpec", data: TrainData,
                  plan: Optional["RedundancyPlan"]) -> cfl.CFLState:
        """`plan` with the redundancy solve already done (or None to solve)."""
        return cfl.setup(self.key, data.xs, data.ys, fleet.edge, fleet.server,
                         fixed_c=self.fixed_c, c_up=self.c_up,
                         generator=self.generator, use_kernel=self.use_kernel,
                         plan=plan)

    def sample_epochs(self, state: cfl.CFLState, fleet: "FleetSpec",
                      epochs: int, rng: np.random.Generator) -> EpochSchedule:
        plan = state.plan
        n = fleet.edge.n
        t_star = plan.t_star

        # One-time parity upload, drawn FIRST — the shared helper preserves
        # the legacy run_cfl generator order
        upload_time = cfl.sample_parity_upload_time(state, fleet, rng)

        received = np.empty((epochs, n), dtype=np.float32)
        parity_ok = np.empty(epochs, dtype=np.float32)
        for e in range(epochs):
            t_i = sample_total(fleet.edge, plan.loads, rng)
            received[e] = (t_i <= t_star) & (plan.loads > 0)
            if self.server_always_returns or state.c == 0:
                parity_ok[e] = 1.0
            else:
                t_srv = sample_total(fleet.server, np.array([state.c]), rng)[0]
                parity_ok[e] = float(t_srv <= t_star)

        return EpochSchedule(
            durations=np.full(epochs, t_star),
            arrivals={"received": received, "parity_ok": parity_ok},
            setup_time=upload_time,
            t0=upload_time if self.include_upload_delay else 0.0)

    def device_state(self, state: cfl.CFLState,
                     data: TrainData) -> Dict[str, jax.Array]:
        if self._grad_path() == aggregation.FUSED:
            return cfl.fused_coded_device_state(state, data)
        return cfl.coded_device_state(state, data)

    def round_contributions(self, state, dev, beta, arrivals):
        if self._grad_path() == aggregation.FUSED:
            # fused layout (packed support or dense fallback): the base
            # row weight carries the load support, parity is Gram-folded
            x, y, w0, client = aggregation.fused_sys_block(dev)
            w = w0 * arrivals["received"][client]
            if state.c == 0:
                return aggregation.round_gradient(
                    x, y, beta, w=w, path=aggregation.FUSED)
            return aggregation.fused_coded_gradient(
                dev, w, arrivals["parity_ok"], beta)
        resid = dev["x"] @ beta - dev["y"]
        # row weight = (point within client's systematic load) AND
        # (client's partial gradient arrived by t*)
        w = dev["w_sys"] * arrivals["received"][dev["row_client"]]
        g_sys = (resid * w) @ dev["x"]
        if state.c == 0:  # delta = 0 degenerates to uncoded FL w/ deadline
            return g_sys
        g_par = aggregation.parity_gradient(
            dev["x_parity"], dev["y_parity"], beta,
            use_kernel=self.use_kernel)
        return g_sys + arrivals["parity_ok"] * g_par

    def tiered_contributions(self, state, dev, beta, arrivals, tier_masks):
        # systematic partials reduce per edge tier; the parity gradient is
        # computed AT the server on the composite parity data, so it rides
        # as the server-side term and bypasses the tier stage entirely
        if self._grad_path() == aggregation.FUSED:
            x, y, w0, client = aggregation.fused_sys_block(dev)
            masks = aggregation.fused_tier_masks(dev, tier_masks)
            w = w0 * arrivals["received"][client]
            partials = aggregation.tiered_round_gradient(
                x, y, beta, w, masks, path=aggregation.FUSED)
            if state.c == 0:
                return partials, None
            g_par = aggregation.gram_parity_gradient(
                dev["par_gram"], dev["par_gramy"], beta, dev["par_c"])
            return partials, arrivals["parity_ok"] * g_par
        resid = dev["x"] @ beta - dev["y"]
        w = dev["w_sys"] * arrivals["received"][dev["row_client"]]
        partials = aggregation.tier_reduce(resid * w, dev["x"], tier_masks)
        if state.c == 0:
            return partials, None
        g_par = aggregation.parity_gradient(
            dev["x_parity"], dev["y_parity"], beta,
            use_kernel=self.use_kernel)
        return partials, arrivals["parity_ok"] * g_par

    def uplink_bits(self, state: cfl.CFLState, fleet: "FleetSpec",
                    epochs: int) -> float:
        return cfl.coded_uplink_bits(state, fleet, epochs)

    def engine_key(self, state: cfl.CFLState) -> Hashable:
        return (state.c > 0, self.use_kernel, self._grad_path())

    def sweep_inputs(self, state: cfl.CFLState, fleet: "FleetSpec",
                     epochs: int, rng: np.random.Generator) -> EpochSchedule:
        """One sweep lane's inputs: `received (epochs, n)` and
        `parity_ok (epochs,)` stack across every CFL lane sharing the fleet
        size; draws are exactly `sample_epochs` (upload first, then the
        per-epoch edge/server stream)."""
        return self.sample_epochs(state, fleet, epochs, rng)


# ---------------------------------------------------------------------------
# Gradient coding (Tandon et al., the paper's ref [5])
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GradCodingState:
    plan: GradCodingPlan
    n_groups: int
    ell: int            # local shard size (each client computes r * ell)
    share_bits: float   # per-client raw-data sharing cost (one-time)
    shard_time: float


@dataclasses.dataclass(frozen=True)
class GradientCodingFL:
    """Fractional-repetition gradient coding with replication factor r.

    Client i holds its whole group's data (r shards) and returns the
    group-sum gradient; an epoch ends when every group has >= 1 returner,
    at which point the server recovers the EXACT full gradient (no LLN
    approximation — contrast with CodedFL).
    """

    r: int
    label: str = "gradcode"
    grad_path: str = aggregation.FUSED

    # r shapes the plan (groups) only; the traced engine sees it through
    # `engine_key` (n_groups) and the arrival/device tensor shapes
    engine_value_fields: ClassVar[frozenset] = frozenset({"r"})
    # the flat matrices are data-only; row_group is plan-derived (per lane)
    data_device_keys: ClassVar[frozenset] = frozenset({"x", "y"})

    def plan(self, fleet: "FleetSpec", data: TrainData) -> GradCodingState:
        plan = make_plan(data.n, self.r)
        n_groups = int(plan.groups.max()) + 1
        # one-time cost: each client receives (r-1) shards of raw data from
        # its group peers (the privacy-relevant transfer CFL avoids)
        share_bits = (self.r - 1) * data.ell * (data.d + 1) * 32 * 1.1
        shard_time = float(np.max(share_bits / fleet.link_rates))
        return GradCodingState(plan=plan, n_groups=n_groups, ell=data.ell,
                               share_bits=share_bits, shard_time=shard_time)

    def sample_epochs(self, state: GradCodingState, fleet: "FleetSpec",
                      epochs: int, rng: np.random.Generator) -> EpochSchedule:
        n = fleet.edge.n
        # each client processes its whole group's data: r * ell points
        loads = np.full(n, state.plan.r * state.ell)
        t_all = np.empty((epochs, n))
        # the per-epoch host loop preserves the legacy generator draw order;
        # the group reduction below is vectorized across all epochs at once
        for e in range(epochs):
            t_all[e] = sample_total(fleet.edge, loads, rng)
        groups = np.asarray(state.plan.groups)
        per_group = np.full((epochs, state.n_groups), np.inf)
        np.minimum.at(per_group,
                      (np.arange(epochs)[:, None], groups[None, :]), t_all)
        # each epoch ends when the last group's first returner lands
        durations = per_group.max(axis=1)
        group_ok = np.ones((epochs, state.n_groups), dtype=np.float32)
        return EpochSchedule(durations=durations,
                             arrivals={"group_ok": group_ok},
                             setup_time=state.shard_time,
                             t0=state.shard_time)

    def device_state(self, state: GradCodingState,
                     data: TrainData) -> Dict[str, jax.Array]:
        row_group = jnp.repeat(
            jnp.asarray(state.plan.groups, dtype=jnp.int32), data.ell)
        return {"x": data.xs.reshape(data.m, data.d),
                "y": data.ys.reshape(data.m),
                "row_group": row_group}

    def round_contributions(self, state, dev, beta, arrivals):
        # groups with >= 1 returner contribute their exact group-sum
        # gradient (what the coded uploads decode to); with every group
        # reporting this is exactly the full gradient
        w = arrivals["group_ok"][dev["row_group"]]
        return aggregation.round_gradient(
            dev["x"], dev["y"], beta, w=w,
            path=aggregation.resolve_grad_path(self.grad_path))

    def tiered_contributions(self, state, dev, beta, arrivals, tier_masks):
        # every contribution is client-resident (the decoded group sums),
        # so the whole gradient reduces through the edge tiers
        w = arrivals["group_ok"][dev["row_group"]]
        return aggregation.tiered_round_gradient(
            dev["x"], dev["y"], beta, w, tier_masks,
            path=aggregation.resolve_grad_path(self.grad_path)), None

    def uplink_bits(self, state: GradCodingState, fleet: "FleetSpec",
                    epochs: int) -> float:
        n = fleet.edge.n
        return n * state.share_bits + epochs * n * 2 * fleet.packet_bits

    def engine_key(self, state: GradCodingState) -> Hashable:
        return (state.n_groups,)

    def sweep_inputs(self, state: GradCodingState, fleet: "FleetSpec",
                     epochs: int, rng: np.random.Generator) -> EpochSchedule:
        """One sweep lane's inputs: `group_ok (epochs, n_groups)` stacks
        across lanes with equal replication structure (n_groups is in
        `engine_key`, so mixed-r sweeps bucket apart); draws are exactly
        `sample_epochs`."""
        return self.sample_epochs(state, fleet, epochs, rng)
