"""`Session`: the single scan-jitted epoch engine behind every entry point,
plus `plan_sweep`, which batches the planning step across many sessions.

One `Session` replaces the three copy-pasted Python epoch loops that used to
live in `sim.simulator.run_uncoded` / `run_cfl`, `fed.trainer`, and the
gradient-coding script: the strategy pre-samples every epoch's
delays/arrivals up front on the host (NumPy, shape `(epochs, n)`), and the
whole training trace — gradient estimate, GD update, NMSE — executes in one
jitted `jax.lax.scan`.  The device is synced exactly once per run (to fetch
the final NMSE trace) instead of once per epoch, which is what dominated
wall time at small `d`.

Lifecycle:

    data    = TrainData.linreg(jax.random.PRNGKey(0), n=24, ell=300, d=500)
    fleet   = paper_fleet(0.2, 0.2, seed=0)
    session = Session(strategy=CodedFL(key=jax.random.PRNGKey(1),
                                       fixed_c=2016),
                      fleet=fleet, lr=0.0085, epochs=600)
    report  = session.run(data)          # -> TraceReport

Compiled engines are cached on the session keyed by the strategy's static
structure and the data/arrival shapes, so sweeps that reuse a session (or
re-run it with fresh randomness) pay for tracing once.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Dict, Hashable, List, Optional, \
    Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation

from .report import TraceReport
from .strategy import EpochSchedule, Strategy, TrainData

if TYPE_CHECKING:  # annotation-only: keeps the api layer free of sim imports
    from repro.sim.network import FleetSpec


@dataclasses.dataclass
class Session:
    """Runs one strategy over one fleet with a scan-jitted epoch engine.

    strategy: the coding scheme (UncodedFL / CodedFL / GradientCodingFL /
              any user Strategy)
    fleet:    delay + link parameters of the simulated fleet
    lr:       GD step size (Eq. 3)
    epochs:   number of training epochs per run
    seed:     default NumPy seed for delay sampling when `run` is not handed
              an explicit generator
    """

    strategy: Strategy
    fleet: "FleetSpec"
    lr: float
    epochs: int
    seed: int = 0

    def __post_init__(self):
        if self.epochs < 0:
            raise ValueError(f"epochs must be >= 0, got {self.epochs}")
        self._engines: Dict[Hashable, callable] = {}

    # -- engine ------------------------------------------------------------

    def _engine(self, state, data: TrainData,
                dev: Dict[str, jax.Array], arrivals: Dict[str, jax.Array]):
        key = (type(self.strategy).__name__,
               self.strategy.engine_key(state),
               float(self.lr), data.m, str(data.xs.dtype),
               tuple(sorted((k, v.shape) for k, v in dev.items())),
               tuple(sorted((k, v.shape) for k, v in arrivals.items())))
        fn = self._engines.get(key)
        if fn is not None:
            return fn

        strategy, lr, m, d = self.strategy, self.lr, data.m, data.d
        dtype = data.xs.dtype

        def engine(dev, beta_true, arr):
            # lr/m as on-device scalars: identical arithmetic to the legacy
            # eager `gd_update(beta, g, lr, m)` jitted call
            lr_s = jnp.asarray(lr, dtype=dtype)
            m_s = jnp.asarray(m, dtype=jnp.int32)
            beta0 = jnp.zeros(d, dtype=dtype)

            def step(beta, arr_t):
                g = strategy.round_contributions(state, dev, beta, arr_t)
                beta = aggregation.gd_update(beta, g, lr_s, m_s)
                return beta, aggregation.nmse(beta, beta_true)

            _, trace = jax.lax.scan(step, beta0, arr)
            nmse0 = aggregation.nmse(beta0, beta_true)
            return jnp.concatenate([nmse0[None], trace])

        fn = jax.jit(engine)
        self._engines[key] = fn
        return fn

    # -- public API --------------------------------------------------------

    def plan(self, data: TrainData):
        """Run the strategy's one-time setup (exposed so sweeps and
        benchmarks can amortize planning/encoding across runs)."""
        return self.strategy.plan(self.fleet, data)

    def run(self, data: TrainData,
            rng: Optional[np.random.Generator] = None,
            label: Optional[str] = None, state=None) -> TraceReport:
        """Plan (unless a pre-planned `state` is given), pre-sample, and
        execute the full training trace."""
        if rng is None:
            rng = np.random.default_rng(self.seed)
        if state is None:
            state = self.strategy.plan(self.fleet, data)
        sched: EpochSchedule = self.strategy.sample_epochs(
            state, self.fleet, self.epochs, rng)

        dev = self.strategy.device_state(state, data)
        arrivals = {k: jnp.asarray(v) for k, v in sched.arrivals.items()}
        engine = self._engine(state, data, dev, arrivals)
        nmse_trace = np.asarray(engine(dev, data.beta_true, arrivals))

        times = sched.t0 + np.concatenate(
            [[0.0], np.cumsum(sched.durations)])
        extras_fn = getattr(self.strategy, "report_extras", None)
        return TraceReport(
            times=times,
            nmse=nmse_trace,
            epoch_durations=np.asarray(sched.durations),
            label=label if label is not None else self.strategy.label,
            setup_time=sched.setup_time,
            uplink_bits_total=self.strategy.uplink_bits(
                state, self.fleet, self.epochs),
            extras=dict(extras_fn(state)) if extras_fn is not None else {})


def plan_sweep(sessions: Sequence[Session], data: TrainData) -> List[Any]:
    """Plan every session's strategy, solving all redundancy problems in ONE
    batched call.

    Strategies exposing the batched-planning hooks (`plan_request(fleet,
    data) -> repro.plan.PlanRequest` and `plan_with(fleet, data, plan) ->
    state`, e.g. `CodedFL`) have their Eq. 14-16 solves collected into a
    single `repro.plan.solve_redundancy_batched` invocation — a 16-point
    delta sweep pays for one vectorized solve instead of 16 scalar ones.
    Everything else (and strategies carrying a pre-solved
    `redundancy_plan`) falls back to its own `plan`.

    Returns one strategy state per session, in order; pass each to
    `Session.run(data, state=...)`.
    """
    states: List[Any] = [None] * len(sessions)
    batched: List[int] = []
    requests = []
    for i, sess in enumerate(sessions):
        strat = sess.strategy
        if hasattr(strat, "plan_request") and hasattr(strat, "plan_with") \
                and getattr(strat, "redundancy_plan", None) is None:
            requests.append(strat.plan_request(sess.fleet, data))
            batched.append(i)
    if requests:
        from repro.plan import solve_redundancy_batched
        plans = solve_redundancy_batched(requests)
        for i, plan in zip(batched, plans):
            states[i] = sessions[i].strategy.plan_with(
                sessions[i].fleet, data, plan)
    for i, sess in enumerate(sessions):
        if states[i] is None:
            states[i] = sess.plan(data)
    return states
