"""`Session` and the batched sweep engine.

One `Session` replaces the three copy-pasted Python epoch loops that used to
live in `sim.simulator.run_uncoded` / `run_cfl`, `fed.trainer`, and the
gradient-coding script: the strategy pre-samples every epoch's
delays/arrivals up front on the host (NumPy, shape `(epochs, n)`), and the
whole training trace — gradient estimate, GD update, NMSE — executes in one
jitted `jax.lax.scan`.  The device is synced exactly once per run (to fetch
the final NMSE trace) instead of once per epoch, which is what dominated
wall time at small `d`.

Since the sweep-engine refactor the scan body lives in a PURE BATCHED CORE:
a solo `Session.run` is a size-1 batch of the same compiled computation
that `run_sweep` uses to execute a whole sweep of sessions at once.  Lanes
(sessions) are grouped into shape buckets — same strategy static structure,
same operand shapes — and each bucket compiles ONE engine: a
`jax.lax.map` over the per-device lanes inside a `shard_map` over the lane
mesh (`repro.launch.mesh.make_lane_mesh`).  Every lane therefore executes
the exact same unbatched per-lane program whether it runs alone or in a
64-lane sweep, which is what makes the per-lane traces bit-for-bit equal
to solo runs (`tests/test_run_sweep.py`) — a `vmap` over lanes would not
be: XLA:CPU's batched/gemm lowerings change last-ulp results with the
batch size.

Lifecycle:

    data    = TrainData.linreg(jax.random.PRNGKey(0), n=24, ell=300, d=500)
    fleet   = paper_fleet(0.2, 0.2, seed=0)
    session = Session(strategy=CodedFL(key=jax.random.PRNGKey(1),
                                       fixed_c=2016),
                      fleet=fleet, lr=0.0085, epochs=600)
    report  = session.run(data)          # -> TraceReport

    # a whole sweep: one batched planning solve + one compiled engine
    # per shape bucket, sharded over the device mesh
    reports = run_sweep([session_a, session_b, ...], data)

Compiled engines are cached at MODULE level, keyed by the strategy's full
static structure (every primitive dataclass field that could steer the
trace, not just `engine_key`) plus the operand shapes and the lane count —
so sweeps, re-runs, and sessions cloned via `dataclasses.replace` share
compiled engines exactly when their traced computation is identical, and
never otherwise.
"""
from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict
from typing import (TYPE_CHECKING, Any, Callable, Dict, Hashable, List,
                    Optional, Sequence)

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import aggregation

from .report import TraceReport
from .strategy import EpochSchedule, Strategy, TrainData

if TYPE_CHECKING:  # annotation-only: keeps the api layer free of sim imports
    from repro.sim.network import FleetSpec

# Compiled sweep engines, shared by every Session in the process: one entry
# per (strategy static structure, operand shapes, lane count).  A 16-lane
# delta sweep compiles once per shape bucket instead of once per Session,
# and solo re-runs of equivalent sessions never retrace.  Each engine's
# closure pins its bucket's first strategy state (which can hold MB-scale
# parity arrays), so the cache is a BOUNDED LRU: least-recently-used
# entries evict once the cap is exceeded (fleet-scale bucketing — many
# topologies × shape buckets — would otherwise grow it for process
# lifetime).  Cap defaults to _ENGINE_CACHE_MAX; override per process
# with REPRO_ENGINE_CACHE_MAX.  All lookups go through `cache_engine`,
# shared with the serving engine (`repro.serving.fed_engine`).
_ENGINE_CACHE: "OrderedDict[Hashable, Callable]" = OrderedDict()
_ENGINE_CACHE_MAX = 64


def engine_cache_max() -> int:
    """Effective LRU capacity (env override, floor 1)."""
    try:
        return max(1, int(os.environ["REPRO_ENGINE_CACHE_MAX"]))
    except (KeyError, ValueError):
        return _ENGINE_CACHE_MAX


def cache_engine(key: Hashable, build: Callable[[], Callable]) -> Callable:
    """Fetch (or build) a compiled engine through the shared LRU.

    A hit refreshes the key's recency; a miss builds, inserts, and evicts
    least-recently-used entries past the cap.  Evicted engines keep
    working for holders of a direct reference (the serving engine's lane
    groups pin their own `step_fn`; sessions mirror engines in
    `_engines`), so eviction never breaks an in-flight bucket — it only
    forces the next cold lookup to recompile.
    """
    engine = _ENGINE_CACHE.get(key)
    if engine is not None:
        _ENGINE_CACHE.move_to_end(key)
        return engine
    engine = build()
    _ENGINE_CACHE[key] = engine
    cap = engine_cache_max()
    while len(_ENGINE_CACHE) > cap:
        _ENGINE_CACHE.popitem(last=False)
    return engine

_PRIMITIVES = (bool, int, float, str, bytes, type(None))


def _static_strategy_key(strategy: Strategy) -> Hashable:
    """Full static identity of a strategy's traced computation.

    Includes the class (module-qualified) and every primitive-valued
    dataclass field, EXCEPT `label` (display-only by protocol) and the
    fields the strategy declares in `engine_value_fields` — knobs that
    only change operand VALUES (plan inputs, host-side sampling, report
    metadata), never the traced engine.  Array-valued fields (PRNG keys,
    pre-solved plans) only ever feed operand values and are skipped.

    Keying on everything static by default means a strategy whose
    `engine_key` under-reports (the historical failure mode: clone a
    session via `dataclasses.replace` with a changed static field and
    silently reuse the old compiled engine) still never shares a compiled
    engine across trace-relevant differences.
    """
    cls = type(strategy)
    parts: List[Any] = [f"{cls.__module__}.{cls.__qualname__}"]
    skip = set(getattr(strategy, "engine_value_fields", ())) | {"label"}
    if dataclasses.is_dataclass(strategy):
        fields = [f.name for f in dataclasses.fields(strategy)]
    else:  # non-dataclass user strategies: their primitive attributes
        fields = sorted(k for k in getattr(strategy, "__dict__", {}))
    for name in fields:
        if name in skip:
            continue
        value = getattr(strategy, name)
        if isinstance(value, _PRIMITIVES):
            parts.append((name, type(value).__name__, value))
    return tuple(parts)


def _tree_shape_key(tree: Dict[str, Any]) -> Hashable:
    return tuple(sorted((k, tuple(v.shape), str(v.dtype))
                        for k, v in tree.items()))


def _bucket_key(strategy: Strategy, state: Any, data: TrainData,
                dev: Dict[str, jax.Array],
                arrivals: Dict[str, np.ndarray]) -> Hashable:
    """Sessions with equal keys run as lanes of one compiled engine."""
    return (_static_strategy_key(strategy),
            strategy.engine_key(state),
            data.m, data.d, data.model_dim, str(data.xs.dtype),
            _tree_shape_key(dev), _tree_shape_key(arrivals))


def make_epoch_step(strategy: Strategy, state: Any, m: int) -> Callable:
    """Build THE per-epoch training program for one strategy state.

    Returns `step(beta, dev, lr, beta_true, arr_t) -> (beta', nmse')`:
    one gradient round (`round_contributions`), one GD update (Eq. 3),
    one NMSE probe — exactly the body of the classic epoch loop.

    Every engine closes over this one function: the sweep engine's
    `lax.scan` body below (solo `Session.run` included, as a size-1
    sweep) and the serving engine's `lax.while_loop` body
    (`repro.serving.fed_engine`).  Sharing the program — not hoping two
    copies stay in sync — is what makes a served lane's trace
    bit-for-bit prefix-equal to the same session's fixed-epoch solo run.
    """
    m_s = jnp.asarray(m, dtype=jnp.int32)

    def step(beta: jax.Array, dev: Dict[str, jax.Array], lr: jax.Array,
             beta_true: jax.Array,
             arr_t: Dict[str, jax.Array]) -> tuple:
        g = strategy.round_contributions(state, dev, beta, arr_t)
        beta = aggregation.gd_update(beta, g, lr, m_s)
        return beta, aggregation.nmse(beta, beta_true)

    return step


def _build_engine(strategy: Strategy, state: Any, data: TrainData,
                  shared: Dict[str, jax.Array], args: tuple) -> Callable:
    """Compile the batched engine for one shape bucket.

    `shared` holds the lane-invariant device operands (the strategy's
    declared `data_device_keys` plus `beta_true`), replicated across the
    mesh instead of stacked B times — the training matrices are the bulk
    of the operand bytes and every lane reads the same ones.  `args` =
    (dev_lanes, arrivals, lr), every leaf stacked on a leading lane axis
    of size B.  The per-lane program is the classic solo scan engine;
    lanes are split over the lane mesh by `shard_map` and iterated per
    device with `jax.lax.map`, so each lane's arithmetic is identical at
    every B (the bit-for-bit guarantee — see module docstring).
    """
    from repro.launch.mesh import make_lane_mesh
    from repro.launch.sharding import lane_specs

    d, dtype = data.model_dim, data.xs.dtype
    n_lanes = jax.tree.leaves(args)[0].shape[0]
    mesh = make_lane_mesh(n_lanes)
    epoch_step = make_epoch_step(strategy, state, data.m)

    def lanes(shared_op, *lane_args):
        beta_true = shared_op.pop("beta_true")

        def lane(op):
            dev_lane, arr, lr = op
            dev = {**shared_op, **dev_lane}
            # lr rides in as a per-lane scalar operand: identical
            # arithmetic to the legacy closed-over constant
            beta0 = jnp.zeros(d, dtype=dtype)

            def step(beta, arr_t):
                return epoch_step(beta, dev, lr, beta_true, arr_t)

            beta_f, trace = jax.lax.scan(step, beta0, arr)
            nmse0 = aggregation.nmse(beta0, beta_true)
            return jnp.concatenate([nmse0[None], trace]), beta_f

        return jax.lax.map(lane, lane_args)

    replicated = jax.tree.map(lambda leaf: P(), shared)
    fn = shard_map(lanes, mesh=mesh,
                   in_specs=(replicated,) + tuple(
                       lane_specs(a) for a in args),
                   out_specs=(P("lanes"), P("lanes")))
    return jax.jit(fn)


def _execute_lanes(entries: Sequence[tuple],
                   data: TrainData) -> List[tuple]:
    """Run every (session, state, schedule) lane through the batched core.

    Lanes are grouped into shape buckets; each bucket stacks its operands,
    fetches (or compiles) its engine from the module cache and executes
    all its lanes in one sharded call.  Returns each lane's
    ((epochs+1,) NMSE trace, (model_dim,) final beta), in order.
    """
    devs: List[Dict[str, jax.Array]] = []
    arrs: List[Dict[str, np.ndarray]] = []
    buckets: Dict[Hashable, List[int]] = {}
    for i, (sess, state, sched) in enumerate(entries):
        dev = sess.strategy.device_state(state, data)
        arr = {k: np.asarray(v) for k, v in sched.arrivals.items()}
        devs.append(dev)
        arrs.append(arr)
        key = _bucket_key(sess.strategy, state, data, dev, arr)
        buckets.setdefault(key, []).append(i)

    dtype = data.xs.dtype
    results: List[Optional[tuple]] = [None] * len(entries)
    for key, idxs in buckets.items():
        b = len(idxs)
        sess0, state0, _ = entries[idxs[0]]
        # operands the strategy declares as pure functions of `data` are
        # lane-invariant within one call: pass ONE copy, replicated, and
        # stack only the genuinely per-lane state
        data_keys = set(getattr(sess0.strategy, "data_device_keys", ())) \
            & set(devs[idxs[0]])
        shared = {k: devs[idxs[0]][k] for k in data_keys}
        shared["beta_true"] = data.beta_true
        dev_b = {k: jnp.stack([devs[i][k] for i in idxs])
                 for k in devs[idxs[0]] if k not in data_keys}
        arr_b = {k: jnp.asarray(np.stack([arrs[i][k] for i in idxs]))
                 for k in arrs[idxs[0]]}
        lr_b = jnp.asarray(np.asarray([entries[i][0].lr for i in idxs]),
                           dtype=dtype)
        args = (dev_b, arr_b, lr_b)

        engine_key = (key, b)
        engine = cache_engine(
            engine_key,
            lambda: _build_engine(sess0.strategy, state0, data, shared,
                                  args))
        out_trace, out_beta = engine(shared, *args)
        out_trace, out_beta = np.asarray(out_trace), np.asarray(out_beta)
        for j, i in enumerate(idxs):
            results[i] = (out_trace[j], out_beta[j])
            # per-session mirror: introspection + lifetime of the session
            entries[i][0]._engines[engine_key] = engine
    return results  # type: ignore[return-value]


def _lane_report(session: "Session", state: Any, sched: EpochSchedule,
                 nmse_trace: np.ndarray,
                 label: Optional[str] = None,
                 beta: Optional[np.ndarray] = None) -> TraceReport:
    """Assemble the TraceReport for one lane — ONE code path for solo runs
    and sweep lanes, so their reports cannot drift."""
    times = sched.t0 + np.concatenate([[0.0], np.cumsum(sched.durations)])
    extras_fn = getattr(session.strategy, "report_extras", None)
    return TraceReport(
        times=times,
        nmse=nmse_trace,
        epoch_durations=np.asarray(sched.durations),
        label=label if label is not None else session.strategy.label,
        setup_time=sched.setup_time,
        uplink_bits_total=session.strategy.uplink_bits(
            state, session.fleet, session.epochs),
        extras=dict(extras_fn(state)) if extras_fn is not None else {},
        beta=beta)


@dataclasses.dataclass
class Session:
    """Runs one strategy over one fleet with a scan-jitted epoch engine.

    strategy: the coding scheme (UncodedFL / CodedFL / GradientCodingFL /
              any user Strategy)
    fleet:    delay + link parameters of the simulated fleet
    lr:       GD step size (Eq. 3)
    epochs:   number of training epochs per run
    seed:     default NumPy seed for delay sampling when `run` is not handed
              an explicit generator
    """

    strategy: Strategy
    fleet: "FleetSpec"
    lr: float
    epochs: int
    seed: int = 0

    def __post_init__(self):
        if self.epochs < 0:
            raise ValueError(f"epochs must be >= 0, got {self.epochs}")
        # local view into the shared module-level engine cache (see
        # _execute_lanes); compiled engines outlive any one session
        self._engines: Dict[Hashable, Callable] = {}

    # -- public API --------------------------------------------------------

    def plan(self, data: TrainData):
        """Run the strategy's one-time setup (exposed so sweeps and
        benchmarks can amortize planning/encoding across runs)."""
        return self.strategy.plan(self.fleet, data)

    def run(self, data: TrainData,
            rng: Optional[np.random.Generator] = None,
            label: Optional[str] = None, state=None) -> TraceReport:
        """Plan (unless a pre-planned `state` is given), pre-sample, and
        execute the full training trace — a size-1 batch of the shared
        sweep engine."""
        if rng is None:
            rng = np.random.default_rng(self.seed)
        if state is None:
            state = self.strategy.plan(self.fleet, data)
        sched: EpochSchedule = self.strategy.sample_epochs(
            state, self.fleet, self.epochs, rng)
        nmse_trace, beta = _execute_lanes([(self, state, sched)], data)[0]
        return _lane_report(self, state, sched, nmse_trace, label, beta=beta)


def plan_sweep(sessions: Sequence[Session], data: TrainData) -> List[Any]:
    """Plan every session's strategy, solving all redundancy problems in ONE
    batched call.

    Strategies exposing the batched-planning hooks (`plan_request(fleet,
    data) -> repro.plan.PlanRequest` and `plan_with(fleet, data, plan) ->
    state`, e.g. `CodedFL`) have their Eq. 14-16 solves collected into a
    single `repro.plan.solve_redundancy_batched` invocation — a 16-point
    delta sweep pays for one vectorized solve instead of 16 scalar ones.
    Everything else (and strategies carrying a pre-solved
    `redundancy_plan`) falls back to its own `plan`.

    Returns one strategy state per session, in order; pass each to
    `Session.run(data, state=...)` or all of them to
    `run_sweep(..., states=...)`.
    """
    states: List[Any] = [None] * len(sessions)
    batched: List[int] = []
    requests = []
    for i, sess in enumerate(sessions):
        strat = sess.strategy
        if hasattr(strat, "plan_request") and hasattr(strat, "plan_with") \
                and getattr(strat, "redundancy_plan", None) is None:
            requests.append(strat.plan_request(sess.fleet, data))
            batched.append(i)
    if requests:
        from repro.plan import solve_redundancy_batched
        plans = solve_redundancy_batched(requests)
        for i, plan in zip(batched, plans):
            states[i] = sessions[i].strategy.plan_with(
                sessions[i].fleet, data, plan)
    for i, sess in enumerate(sessions):
        if states[i] is None:
            states[i] = sess.plan(data)
    return states


def run_sweep(sessions: Sequence[Session], data: TrainData,
              rngs: Optional[Sequence[np.random.Generator]] = None,
              states: Optional[Sequence[Any]] = None) -> List[TraceReport]:
    """Execute a whole sweep of sessions as one batched computation.

    The three phases, each batched:

      1. planning — `plan_sweep` collects every session's allocation solve
         into one `repro.plan.solve_redundancy_batched` call (skipped for
         pre-planned `states`);
      2. sampling — each lane pre-samples its own epoch randomness on the
         host via the strategy's `sweep_inputs` hook (falling back to
         `sample_epochs`), with a PER-LANE generator so the draw order is
         identical to a solo `Session.run`;
      3. training — lanes are grouped into shape buckets (strategy static
         structure + operand shapes) and each bucket runs as ONE compiled
         engine, sharded over the lane mesh.

    Per-lane results — NMSE trace, wall-clock times, `TraceReport.extras`
    — are bit-for-bit identical to running each session solo with the
    same generator.

    rngs:   one generator per session (default: a fresh
            `np.random.default_rng(session.seed)` each, matching the solo
            `run` default)
    states: pre-planned strategy states (e.g. from `plan_sweep`, to time
            or amortize planning separately)
    """
    sessions = list(sessions)
    if states is None:
        states = plan_sweep(sessions, data)
    elif len(states) != len(sessions):
        raise ValueError(
            f"got {len(states)} states for {len(sessions)} sessions")
    if rngs is None:
        rngs = [np.random.default_rng(sess.seed) for sess in sessions]
    elif len(rngs) != len(sessions):
        raise ValueError(
            f"got {len(rngs)} generators for {len(sessions)} sessions")

    entries = []
    for sess, state, rng in zip(sessions, states, rngs):
        sample = getattr(sess.strategy, "sweep_inputs",
                         sess.strategy.sample_epochs)
        entries.append((sess, state,
                        sample(state, sess.fleet, sess.epochs, rng)))
    results = _execute_lanes(entries, data)
    return [_lane_report(sess, state, sched, trace, beta=beta)
            for (sess, state, sched), (trace, beta) in zip(entries, results)]
