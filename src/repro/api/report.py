"""Unified training-trace report returned by every `Session` run.

`TraceReport` supersedes the old `sim.simulator.SimResult` (which is now an
alias of this class).  It is strategy-agnostic: the same fields describe an
uncoded run, a CFL run, or a gradient-coding run, so downstream analysis
(convergence times, coding gains, comm-load ratios) never branches on which
strategy produced the trace.

This module deliberately imports nothing from the rest of `repro` so it can
be used from any layer without creating import cycles.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np


@dataclasses.dataclass
class TraceReport:
    """Trace of one simulated training run.

    times:           (epochs+1,) wall-clock at each model snapshot
    nmse:            (epochs+1,) NMSE at each snapshot
    epoch_durations: (epochs,)   per-epoch wall time
    label:           human-readable run tag ("uncoded", "cfl", ...)
    setup_time:      one-time setup wall time (parity upload / data sharing)
    uplink_bits_total: total bits moved device -> server over the whole run
    extras:          strategy-specific scalar knobs/diagnostics surfaced by
                     the optional `Strategy.report_extras(state)` hook
                     (e.g. StochasticCodedFL's noise_multiplier)
    beta:            final model iterate (model_dim,), or None for engines
                     predating the harvest — lets classification workloads
                     evaluate the trained model instead of only its NMSE
    """

    times: np.ndarray
    nmse: np.ndarray
    epoch_durations: np.ndarray
    label: str
    setup_time: float = 0.0
    uplink_bits_total: float = 0.0
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)
    beta: Optional[np.ndarray] = None

    def final_nmse(self) -> float:
        return float(self.nmse[-1])

    def privacy_budget(self):
        """(epsilon_spent, delta) when the strategy reported DP accounting
        (e.g. `StochasticCodedFL` with an accounting horizon), else None.

        The extras schema for privacy-accounting strategies:
        `epsilon_spent` (composed total), `delta`, `accounting_rounds`,
        `epsilon_schedule` ((rounds,) cumulative per-round epsilon), and
        `epsilon_target` when the noise was calibrated to a budget.
        """
        eps = self.extras.get("epsilon_spent")
        if eps is None:
            return None
        return float(eps), float(self.extras["delta"])

    @property
    def epochs(self) -> int:
        return int(self.epoch_durations.shape[0])

    def epochs_to(self, target_nmse: float) -> int:
        """Number of epochs until NMSE first reaches target (epochs+1 if never)."""
        hit = np.nonzero(self.nmse <= target_nmse)[0]
        return int(hit[0]) if hit.size else self.epochs + 1


def convergence_time(result: TraceReport, target_nmse: float) -> float:
    """First wall-clock time at which NMSE <= target (inf if never)."""
    hit = np.nonzero(result.nmse <= target_nmse)[0]
    return float(result.times[hit[0]]) if hit.size else float("inf")


def coding_gain(uncoded: TraceReport, coded: TraceReport,
                target_nmse: float) -> float:
    """Ratio of uncoded to coded convergence time (paper Figs. 4-5)."""
    tu = convergence_time(uncoded, target_nmse)
    tc = convergence_time(coded, target_nmse)
    return tu / tc
