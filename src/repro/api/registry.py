"""Strategy registry: construct any coding scheme by name.

`make_strategy("cfl", key_seed=7, fixed_c=2016)` replaces hand-constructed
strategy dataclasses in benchmarks/examples, and is the one place that
knows where every scheme lives — including the `repro.schemes` subsystem,
which is imported lazily so `repro.api` stays import-light.

Names: uncoded, cfl, gradcode, stochastic (alias scfl), lowlatency (alias
lowlat), codedfedl (alias cfedl), hierarchical (aliases hier, fleet — pass
base= and topology=, see `repro.fleet`).  Extra keyword arguments pass straight through to the strategy
dataclass; for key-carrying schemes, `key_seed=<int>` is accepted as a
convenience and turned into `key=jax.random.PRNGKey(key_seed)`.

User schemes join via `register_strategy("myscheme", MyStrategy)` (or as a
decorator, `@register_strategy("myscheme")`).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple, Type

_BUILTINS: Dict[str, Tuple[str, str]] = {
    "uncoded": ("repro.api.strategy", "UncodedFL"),
    "cfl": ("repro.api.strategy", "CodedFL"),
    "gradcode": ("repro.api.strategy", "GradientCodingFL"),
    "stochastic": ("repro.schemes", "StochasticCodedFL"),
    "lowlatency": ("repro.schemes", "LowLatencyCFL"),
    "codedfedl": ("repro.schemes", "CodedFedL"),
    "hierarchical": ("repro.fleet", "HierarchicalCFL"),
}
_ALIASES: Dict[str, str] = {"scfl": "stochastic", "lowlat": "lowlatency",
                            "cfedl": "codedfedl",
                            "hier": "hierarchical", "fleet": "hierarchical"}
_CUSTOM: Dict[str, Type] = {}


def available_strategies() -> Tuple[str, ...]:
    """Canonical registered names (aliases not included)."""
    return tuple(sorted(set(_BUILTINS) | set(_CUSTOM)))


def register_strategy(name: str, cls: Optional[Type] = None):
    """Register a user strategy class under `name` (callable or decorator).
    Built-in names and their aliases cannot be shadowed."""
    if name in _BUILTINS or name in _ALIASES:
        raise ValueError(
            f"cannot register {name!r}: it is a built-in strategy name or "
            "alias")

    def _register(c: Type) -> Type:
        _CUSTOM[name] = c
        return c
    return _register(cls) if cls is not None else _register


def make_strategy(name: str, **kwargs):
    """Construct a registered strategy by name (see module docstring)."""
    if name in _CUSTOM:  # custom names are exact (never alias-expanded)
        cls = _CUSTOM[name]
    elif (canonical := _ALIASES.get(name, name)) in _BUILTINS:
        module, attr = _BUILTINS[canonical]
        cls = getattr(importlib.import_module(module), attr)
    else:
        raise ValueError(
            f"unknown strategy {name!r}; available: "
            f"{', '.join(available_strategies())}")

    key_seed = kwargs.pop("key_seed", None)
    fields = {f.name for f in dataclasses.fields(cls)} \
        if dataclasses.is_dataclass(cls) else set()
    if key_seed is not None and ("key" not in fields or "key" in kwargs):
        raise ValueError(
            f"key_seed is only valid for key-carrying strategies without an "
            f"explicit key= argument (strategy {name!r})")
    if "key" in fields and "key" not in kwargs:
        if key_seed is None:
            # no silent default: two runs that both "forgot" the key must
            # not share generator/noise draws
            raise ValueError(
                f"strategy {name!r} needs a PRNG key: pass key=... or "
                "key_seed=<int>")
        import jax
        kwargs["key"] = jax.random.PRNGKey(key_seed)
    return cls(**kwargs)
