"""Unified Strategy/Session training surface (see API.md).

The `Strategy` protocol makes every coding scheme — uncoded FL, the paper's
CFL, gradient coding, and the `repro.schemes` follow-ups — a pluggable
class; the `Session` runner executes any of them through one scan-jitted
epoch engine and returns a unified `TraceReport`.  `make_strategy(name,
**kwargs)` constructs any registered scheme by name.
"""
from .registry import available_strategies, make_strategy, register_strategy
from .report import TraceReport, coding_gain, convergence_time
from .session import Session, make_epoch_step, plan_sweep, run_sweep
from .strategy import (CodedFL, EpochSchedule, GradientCodingFL, Strategy,
                       TrainData, UncodedFL)

__all__ = [
    "TraceReport", "coding_gain", "convergence_time",
    "Session", "plan_sweep", "run_sweep", "make_epoch_step",
    "Strategy", "TrainData", "EpochSchedule",
    "UncodedFL", "CodedFL", "GradientCodingFL",
    "make_strategy", "register_strategy", "available_strategies",
]
