"""Unified Strategy/Session training surface (see API.md).

The `Strategy` protocol makes every coding scheme — uncoded FL, the paper's
CFL, gradient coding, and future schemes — a pluggable class; the `Session`
runner executes any of them through one scan-jitted epoch engine and returns
a unified `TraceReport`.
"""
from .report import TraceReport, coding_gain, convergence_time
from .session import Session, plan_sweep
from .strategy import (CodedFL, EpochSchedule, GradientCodingFL, Strategy,
                       TrainData, UncodedFL)

__all__ = [
    "TraceReport", "coding_gain", "convergence_time",
    "Session", "plan_sweep",
    "Strategy", "TrainData", "EpochSchedule",
    "UncodedFL", "CodedFL", "GradientCodingFL",
]
