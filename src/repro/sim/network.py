"""Heterogeneous fleet generation with the paper's §IV constants.

n = 24 edge devices + 1 server.  Heterogeneity factors nu_comp, nu_link in
[0, 1) generate geometric ladders of MAC rates and link throughputs that are
randomly assigned to devices:

    MACR_i = (1 - nu_comp)^i * 1536 KMAC/s,      i = 0..23
    LINK_i = (1 - nu_link)^i * 216  kbit/s,      i = 0..23

Each training point costs d MACs => a_i = d / MACR_i seconds; memory access
overhead is 50% of the MAC time per point => mu_i = 2 / a_i points/sec.
The server's MAC rate is 10x the *fastest* edge device and it has no
communication leg.  Packets carry a d-vector of 32-bit floats + 10% header.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.delay_model import DeviceDelayParams

KMAC = 1e3  # the paper's MAC rates are given in KMAC/s


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """A generated fleet: edge + server delay params and bookkeeping."""

    edge: DeviceDelayParams
    server: DeviceDelayParams
    mac_rates: np.ndarray      # (n,) MACs/sec actually assigned
    link_rates: np.ndarray     # (n,) bits/sec actually assigned
    packet_bits: float         # uplink/downlink packet size (model/gradient)
    d: int
    nu_comp: float
    nu_link: float


def make_fleet(n: int, d: int, nu_comp: float, nu_link: float,
               rng: np.random.Generator,
               base_mac_kmacs: float = 1536.0,
               base_link_kbps: float = 216.0,
               erasure_p=0.1,
               server_speedup: float = 10.0,
               header_overhead: float = 0.10,
               bits_per_value: int = 32) -> FleetSpec:
    """Generate a fleet per §IV. `rng` drives the random ladder assignment.

    `erasure_p` may be a scalar (the paper's homogeneous wireless links) or
    an (n,) array of per-device erasure probabilities (the heterogeneous
    scenario of `wireless_fleet`).
    """
    ladder = np.arange(n)
    mac_rates = (1.0 - nu_comp) ** ladder * base_mac_kmacs * KMAC  # MAC/s
    link_rates = (1.0 - nu_link) ** ladder * base_link_kbps * 1e3  # bit/s
    mac_rates = rng.permutation(mac_rates)
    link_rates = rng.permutation(link_rates)

    a = d / mac_rates                        # sec per training point
    mu = 2.0 / a                             # 50% memory overhead => rate 2/a
    packet_bits = d * bits_per_value * (1.0 + header_overhead)
    tau = packet_bits / link_rates           # sec per packet
    p = np.broadcast_to(np.asarray(erasure_p, dtype=np.float64), (n,)).copy()

    edge = DeviceDelayParams(a=a, mu=mu, tau=tau, p=p)

    server_mac = server_speedup * mac_rates.max()
    a_s = np.array([d / server_mac])
    server = DeviceDelayParams(a=a_s, mu=2.0 / a_s, tau=np.zeros(1),
                               p=np.zeros(1))
    return FleetSpec(edge=edge, server=server, mac_rates=mac_rates,
                     link_rates=link_rates, packet_bits=packet_bits, d=d,
                     nu_comp=nu_comp, nu_link=nu_link)


def paper_fleet(nu_comp: float = 0.2, nu_link: float = 0.2,
                seed: int = 0, n: int = 24, d: int = 500) -> FleetSpec:
    """The exact §IV configuration (24 devices, d=500)."""
    return make_fleet(n=n, d=d, nu_comp=nu_comp, nu_link=nu_link,
                      rng=np.random.default_rng(seed))


def mega_fleet(n: int, d: int = 32, nu_comp: float = 0.2,
               nu_link: float = 0.2, seed: int = 0,
               ladder_period: int = 24, **kw) -> FleetSpec:
    """A fleet-scale (1e5+ clients) heterogeneous fleet.

    The §IV geometric ladders underflow long before fleet scale —
    `(1 - 0.2)^n` reaches denormal territory around n = 3000, giving
    devices with infinite epoch times.  Production fleets are better
    modelled as many devices drawn from a BOUNDED heterogeneity range, so
    the ladder exponent tiles modulo `ladder_period` (default: the
    paper's 24 rungs): every block of `ladder_period` clients spans the
    same §IV speed range, randomly assigned across the whole fleet.
    """
    rng = np.random.default_rng(seed)
    ladder = np.arange(n) % ladder_period
    mac = (1.0 - nu_comp) ** ladder
    link = (1.0 - nu_link) ** ladder
    # reuse make_fleet's §IV constants/derivations on the tiled ladders by
    # overriding its internal ladder: simplest is to inline the same math
    base_mac = kw.pop("base_mac_kmacs", 1536.0)
    base_link = kw.pop("base_link_kbps", 216.0)
    erasure_p = kw.pop("erasure_p", 0.1)
    server_speedup = kw.pop("server_speedup", 10.0)
    header_overhead = kw.pop("header_overhead", 0.10)
    bits_per_value = kw.pop("bits_per_value", 32)
    if kw:
        raise TypeError(f"unexpected arguments: {sorted(kw)}")
    mac_rates = rng.permutation(mac * base_mac * KMAC)
    link_rates = rng.permutation(link * base_link * 1e3)

    a = d / mac_rates
    mu = 2.0 / a
    packet_bits = d * bits_per_value * (1.0 + header_overhead)
    tau = packet_bits / link_rates
    p = np.broadcast_to(np.asarray(erasure_p, dtype=np.float64), (n,)).copy()
    edge = DeviceDelayParams(a=a, mu=mu, tau=tau, p=p)

    server_mac = server_speedup * mac_rates.max()
    a_s = np.array([d / server_mac])
    server = DeviceDelayParams(a=a_s, mu=2.0 / a_s, tau=np.zeros(1),
                               p=np.zeros(1))
    return FleetSpec(edge=edge, server=server, mac_rates=mac_rates,
                     link_rates=link_rates, packet_bits=packet_bits, d=d,
                     nu_comp=nu_comp, nu_link=nu_link)


def wireless_fleet(nu_comp: float = 0.2, nu_link: float = 0.2,
                   nu_erasure: float = 0.3, seed: int = 0,
                   n: int = 24, d: int = 500,
                   base_erasure_p: float = 0.3,
                   min_erasure_p: float = 0.02, **kw) -> FleetSpec:
    """Heterogeneous wireless fleet (the arXiv:2011.06223 scenario).

    On top of the §IV compute/link ladders, per-device erasure
    probabilities follow their own geometric ladder

        p_i = max((1 - nu_erasure)^i * base_erasure_p, min_erasure_p)

    randomly assigned to devices, so links differ in BOTH rate (tau_i) and
    reliability (p_i).  `nu_erasure = 0` recovers a homogeneous
    `base_erasure_p` fleet.
    """
    rng = np.random.default_rng(seed)
    ladder = (1.0 - nu_erasure) ** np.arange(n) * base_erasure_p
    p = rng.permutation(np.maximum(ladder, min_erasure_p))
    return make_fleet(n=n, d=d, nu_comp=nu_comp, nu_link=nu_link,
                      rng=rng, erasure_p=p, **kw)
