"""Wall-clock simulation of uncoded FL vs CFL (paper §IV).

Uncoded FL: every epoch the server waits for ALL n partial gradients
(synchronous full-batch GD) — epoch duration = max_i T_i, gradient exact.

CFL: the server waits exactly t*; clients whose sampled T_i <= t* contribute
their systematic partial gradients, the server contributes the parity
gradient if its own compute finished (device n+1 in Eq. 13); the combination
(Eqs. 18-19) is an approximately unbiased full-gradient estimate.

Both simulators share the same sampled-delay machinery so coding gain is an
apples-to-apples wall-clock ratio.  The gradient math runs jitted in JAX; the
delay sampling is NumPy (tiny: n=24 per epoch).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, cfl
from repro.core.delay_model import sample_total
from .network import FleetSpec


@dataclasses.dataclass
class SimResult:
    """Trace of one simulated training run."""

    times: np.ndarray        # (epochs+1,) wall-clock at each model snapshot
    nmse: np.ndarray         # (epochs+1,) NMSE at each snapshot
    epoch_durations: np.ndarray  # (epochs,) per-epoch wall time
    label: str
    setup_time: float = 0.0  # one-time parity upload wall time (CFL only)
    uplink_bits_total: float = 0.0  # total bits moved device->server

    def final_nmse(self) -> float:
        return float(self.nmse[-1])


def generate_linreg(key, n: int, ell: int, d: int, noise_std: float = 1.0):
    """Paper §IV data: X iid N(0,1), beta ~ N(0,1)^d, y = X beta + z."""
    k1, k2, k3 = jax.random.split(key, 3)
    xs = jax.random.normal(k1, (n, ell, d), dtype=jnp.float32)
    beta = jax.random.normal(k2, (d,), dtype=jnp.float32)
    zs = noise_std * jax.random.normal(k3, (n, ell), dtype=jnp.float32)
    ys = jnp.einsum("nld,d->nl", xs, beta) + zs
    return xs, ys, beta


def run_uncoded(fleet: FleetSpec, xs, ys, beta_true, lr: float,
                epochs: int, rng: np.random.Generator,
                label: str = "uncoded") -> SimResult:
    """Synchronous uncoded FL: wait for everyone each epoch."""
    n, ell, d = xs.shape
    m = n * ell
    beta = jnp.zeros(d, dtype=xs.dtype)
    full_load = np.full(n, ell)

    times = [0.0]
    errs = [float(aggregation.nmse(beta, beta_true))]
    durs = []
    t = 0.0
    for _ in range(epochs):
        t_i = sample_total(fleet.edge, full_load, rng)
        dur = float(np.max(t_i))  # wait for all stragglers
        g = aggregation.uncoded_full_gradient(xs, ys, beta)
        beta = aggregation.gd_update(beta, g, lr, m)
        t += dur
        times.append(t)
        durs.append(dur)
        errs.append(float(aggregation.nmse(beta, beta_true)))
    bits = epochs * n * 2 * fleet.packet_bits  # model down + gradient up
    return SimResult(np.array(times), np.array(errs), np.array(durs), label,
                     uplink_bits_total=bits)


def run_cfl(fleet: FleetSpec, xs, ys, beta_true, lr: float, epochs: int,
            rng: np.random.Generator, key: jax.Array,
            fixed_c: Optional[int] = None, c_up: Optional[int] = None,
            include_upload_delay: bool = True,
            server_always_returns: bool = False,
            use_kernel: bool = False, label: str = "cfl") -> SimResult:
    """Coded federated learning with the Eq. 14-16 redundancy plan."""
    n, ell, d = xs.shape
    m = n * ell
    state = cfl.setup(key, xs, ys, fleet.edge, fleet.server,
                      fixed_c=fixed_c, c_up=c_up, use_kernel=use_kernel)
    plan = state.plan
    t_star = plan.t_star

    # One-time parity upload: each device ships c rows of (d+1) floats over
    # its own link; devices upload in parallel so the fleet-level delay is
    # the slowest device (see DESIGN.md §7 note 1 — we report both regimes).
    upload_bits = state.parity_upload_bits()
    packets = np.ceil(upload_bits / fleet.packet_bits)
    # each packet is retransmitted Geometric(1-p) times
    retrans = rng.geometric(1.0 - fleet.edge.p, size=n)
    upload_time = float(np.max(packets * retrans * (fleet.packet_bits / fleet.link_rates))) \
        if state.c > 0 else 0.0

    beta = jnp.zeros(d, dtype=xs.dtype)
    t = upload_time if include_upload_delay else 0.0
    times = [t]
    errs = [float(aggregation.nmse(beta, beta_true))]
    durs = []
    for _ in range(epochs):
        t_i = sample_total(fleet.edge, plan.loads, rng)
        received = jnp.asarray((t_i <= t_star) & (plan.loads > 0),
                               dtype=xs.dtype)
        if server_always_returns or state.c == 0:
            par_ok = jnp.asarray(1.0, dtype=xs.dtype)
        else:
            t_srv = sample_total(fleet.server, np.array([state.c]), rng)[0]
            par_ok = jnp.asarray(float(t_srv <= t_star), dtype=xs.dtype)
        g = cfl.epoch_gradient(state, xs, ys, beta, received, par_ok,
                               use_kernel=use_kernel)
        beta = aggregation.gd_update(beta, g, lr, m)
        t += t_star
        times.append(t)
        durs.append(t_star)
        errs.append(float(aggregation.nmse(beta, beta_true)))
    bits = float(np.sum(upload_bits)) + epochs * n * 2 * fleet.packet_bits
    return SimResult(np.array(times), np.array(errs), np.array(durs), label,
                     setup_time=upload_time, uplink_bits_total=bits)


def convergence_time(result: SimResult, target_nmse: float) -> float:
    """First wall-clock time at which NMSE <= target (inf if never)."""
    hit = np.nonzero(result.nmse <= target_nmse)[0]
    return float(result.times[hit[0]]) if hit.size else float("inf")


def coding_gain(uncoded: SimResult, coded: SimResult,
                target_nmse: float) -> float:
    """Ratio of uncoded to coded convergence time (paper Figs. 4-5)."""
    tu = convergence_time(uncoded, target_nmse)
    tc = convergence_time(coded, target_nmse)
    return tu / tc
