"""Wall-clock simulation of uncoded FL vs CFL (paper §IV) — legacy surface.

This module is now a thin compatibility shim over the unified
Strategy/Session API in `repro.api` (see API.md for the migration table):

    run_uncoded(...)  ->  Session(strategy=UncodedFL(), ...).run(data)
    run_cfl(...)      ->  Session(strategy=CodedFL(...), ...).run(data)
    SimResult         ->  repro.api.TraceReport (alias)

The shims preserve the exact semantics AND the exact NumPy generator draw
order of the original per-epoch Python loops, so traces produced through
either surface are identical for the same seeds.  New code should construct
`Session`s directly: the Session pre-samples all per-epoch delay tensors up
front and runs the entire training trace in one jitted `jax.lax.scan`,
avoiding the per-epoch host<->device sync this module's old loops paid.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.api import (CodedFL, Session, TraceReport, TrainData, UncodedFL,
                       coding_gain, convergence_time)
from .network import FleetSpec

# Back-compat alias: SimResult was the old name of the unified trace report.
SimResult = TraceReport

__all__ = ["SimResult", "generate_linreg", "run_uncoded", "run_cfl",
           "convergence_time", "coding_gain"]


def generate_linreg(key, n: int, ell: int, d: int, noise_std: float = 1.0):
    """Paper §IV data: X iid N(0,1), beta ~ N(0,1)^d, y = X beta + z."""
    data = TrainData.linreg(key, n, ell, d, noise_std=noise_std)
    return data.xs, data.ys, data.beta_true


def run_uncoded(fleet: FleetSpec, xs, ys, beta_true, lr: float,
                epochs: int, rng: np.random.Generator,
                label: str = "uncoded") -> TraceReport:
    """Synchronous uncoded FL: wait for everyone each epoch."""
    session = Session(strategy=UncodedFL(label=label), fleet=fleet,
                      lr=lr, epochs=epochs)
    return session.run(TrainData(xs=xs, ys=ys, beta_true=beta_true), rng=rng)


def run_cfl(fleet: FleetSpec, xs, ys, beta_true, lr: float, epochs: int,
            rng: np.random.Generator, key: jax.Array,
            fixed_c: Optional[int] = None, c_up: Optional[int] = None,
            include_upload_delay: bool = True,
            server_always_returns: bool = False,
            use_kernel: bool = False, label: str = "cfl") -> TraceReport:
    """Coded federated learning with the Eq. 14-16 redundancy plan."""
    strategy = CodedFL(key=key, fixed_c=fixed_c, c_up=c_up,
                       include_upload_delay=include_upload_delay,
                       server_always_returns=server_always_returns,
                       use_kernel=use_kernel, label=label)
    session = Session(strategy=strategy, fleet=fleet, lr=lr, epochs=epochs)
    return session.run(TrainData(xs=xs, ys=ys, beta_true=beta_true), rng=rng)
