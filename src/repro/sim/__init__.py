"""Wall-clock simulation of heterogeneous federated fleets (paper §IV)."""
from .network import FleetSpec, make_fleet, paper_fleet
from .simulator import (SimResult, TraceReport, coding_gain,
                        convergence_time, run_cfl, run_uncoded)

__all__ = ["FleetSpec", "make_fleet", "paper_fleet", "SimResult",
           "TraceReport", "run_uncoded", "run_cfl", "convergence_time",
           "coding_gain"]
