"""Wall-clock simulation of heterogeneous federated fleets (paper §IV)."""
from .network import FleetSpec, make_fleet, paper_fleet
from .simulator import SimResult, run_uncoded, run_cfl, convergence_time, coding_gain

__all__ = ["FleetSpec", "make_fleet", "paper_fleet", "SimResult",
           "run_uncoded", "run_cfl", "convergence_time", "coding_gain"]
