"""Synthetic dataset generators.

* `linreg_dataset`: the paper §IV setup — X iid N(0,1), beta ~ N(0,1)^d,
  y = X beta + z with unit-variance noise (see DESIGN.md §7 note 3).
* `token_batches`: a deterministic, seeded LM token stream (Zipfian unigram
  + short-range induction structure so models have something learnable) used
  by the end-to-end training example and smoke tests.
* `classification_dataset`: an MNIST-class synthetic classification set —
  labels come from a random RBF-network teacher, so the decision regions
  are genuinely non-linear in the raw inputs and a Gaussian-kernel machine
  (the `repro.data.rff` feature map) separates what a linear model cannot.
  This is the CodedFedL (arXiv:2007.03273) workload.
"""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


def linreg_dataset(key: jax.Array, n_clients: int, ell: int, d: int,
                   noise_std: float = 1.0):
    """Returns (xs (n, ell, d), ys (n, ell), beta_true (d,))."""
    k1, k2, k3 = jax.random.split(key, 3)
    xs = jax.random.normal(k1, (n_clients, ell, d), dtype=jnp.float32)
    beta = jax.random.normal(k2, (d,), dtype=jnp.float32)
    zs = noise_std * jax.random.normal(k3, (n_clients, ell), dtype=jnp.float32)
    ys = jnp.einsum("nld,d->nl", xs, beta) + zs
    return xs, ys, beta


def classification_dataset(key: jax.Array, n_clients: int, ell: int, d: int,
                           n_classes: int = 10, centers: int = 32,
                           gamma: float = 1.0):
    """Client-sharded synthetic classification with non-linear class regions.

    Inputs are iid N(0, 1); labels come from a random RBF-network teacher:
    `score_c(x) = sum_j A[c, j] * exp(-gamma * ||x - z_j||^2 / d)` over
    `centers` random centers `z_j`, `label = argmax_c score_c(x)`.  The
    1/d scaling keeps the teacher kernel width O(1) as the squared
    distances concentrate around 2d, so an RFF map with
    `gamma_feat = gamma / d` approximates the matching Gaussian kernel.

    Returns `(xs (n, ell, d) float32, labels (n, ell) int32)`.
    """
    if n_classes < 2:
        raise ValueError(f"n_classes must be >= 2, got {n_classes}")
    k1, k2, k3 = jax.random.split(key, 3)
    xs = jax.random.normal(k1, (n_clients, ell, d), dtype=jnp.float32)
    zc = jax.random.normal(k2, (centers, d), dtype=jnp.float32)
    amp = jax.random.normal(k3, (n_classes, centers), dtype=jnp.float32)
    sq = (jnp.sum(xs**2, axis=-1, keepdims=True)
          - 2.0 * xs @ zc.T + jnp.sum(zc**2, axis=-1))      # (n, ell, C)
    feats = jnp.exp(-gamma * sq / d)
    labels = jnp.argmax(feats @ amp.T, axis=-1).astype(jnp.int32)
    return xs, labels


def one_vs_rest_targets(labels: jax.Array, cls: int) -> jax.Array:
    """±1 regression targets for the one-vs-rest head of class `cls` —
    least-squares on signed labels, the CodedFedL classification recipe."""
    return jnp.where(labels == cls, 1.0, -1.0).astype(jnp.float32)


def _zipf_probs(vocab: int, alpha: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return p / p.sum()


def token_batches(seed: int, batch: int, seq_len: int, vocab: int,
                  induction_prob: float = 0.3) -> Iterator[dict]:
    """Infinite iterator of {"tokens", "targets"} int32 batches.

    Sequences mix Zipfian unigram draws with copy-back ("induction") events
    so that even small models see decreasing loss within a few hundred steps.
    """
    rng = np.random.default_rng(seed)
    probs = _zipf_probs(vocab)
    while True:
        toks = rng.choice(vocab, size=(batch, seq_len + 1), p=probs)
        # induction: with prob p, token t copies token t - lag
        lag = rng.integers(2, 32)
        copy = rng.random((batch, seq_len + 1)) < induction_prob
        copy[:, :lag] = False
        idx = np.arange(seq_len + 1)
        shifted = toks[:, np.maximum(idx - lag, 0)]
        toks = np.where(copy, shifted, toks)
        yield {
            "tokens": jnp.asarray(toks[:, :-1], dtype=jnp.int32),
            "targets": jnp.asarray(toks[:, 1:], dtype=jnp.int32),
        }
