"""Random Fourier features (Rahimi & Recht) — the CodedFedL transform.

CodedFedL (arXiv:2007.03273) extends coded federated learning to
non-linear models by mapping raw inputs through a random Fourier feature
map and running kernel (least-squares) regression in the feature space —
the model stays linear-in-parameters, so the paper's parity-gradient
identity and the whole coded linear machinery apply unchanged.

The construction is the standard cos/sin pair for the Gaussian kernel
`k(u, v) = exp(-gamma * ||u - v||^2)`:

    W      ~ sqrt(2 * gamma) * N(0, I)      of shape (d, d_feat // 2)
    z(x)   = sqrt(2 / d_feat) * [cos(x W), sin(x W)]

so that `E[z(u) . z(v)] = k(u, v)` exactly, with the approximation error
decaying as `1/sqrt(d_feat)`.  The map is deterministic in `key`: clients
and server derive the SAME features from the shared key, which is what
lets the server encode parity over feature-mapped data it never saw raw.

`rff_map_reference` is the float64 NumPy oracle (same W draw, float64
math) used by `tests/test_nonlinear.py` for parity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _rff_weights(key: jax.Array, d: int, d_feat: int,
                 gamma: float) -> jax.Array:
    if d_feat < 2 or d_feat % 2:
        raise ValueError(
            f"d_feat must be a positive even number (cos/sin pairs), "
            f"got {d_feat}")
    return jnp.sqrt(2.0 * gamma) * jax.random.normal(
        key, (d, d_feat // 2), dtype=jnp.float32)


def rff_map(x: jax.Array, d_feat: int, key: jax.Array,
            gamma: float = 1.0) -> jax.Array:
    """Map `x (..., d)` to `(..., d_feat)` random Fourier features.

    Approximates the Gaussian kernel `exp(-gamma * ||u - v||^2)`:
    `z(u) . z(v)` is an unbiased estimate of it for any fixed pair.
    Deterministic in `(key, d_feat, gamma)` and the input width.
    """
    x = jnp.asarray(x)
    w = _rff_weights(key, int(x.shape[-1]), d_feat, gamma)
    proj = x @ w
    scale = jnp.sqrt(jnp.asarray(2.0 / d_feat, dtype=proj.dtype))
    return scale * jnp.concatenate([jnp.cos(proj), jnp.sin(proj)], axis=-1)


def rff_map_reference(x: np.ndarray, d_feat: int, key: jax.Array,
                      gamma: float = 1.0) -> np.ndarray:
    """Float64 NumPy oracle for `rff_map` (same jax weight draw, float64
    trig/matmul) — parity target for the float32 production path."""
    x = np.asarray(x, dtype=np.float64)
    w = np.asarray(_rff_weights(key, int(x.shape[-1]), d_feat, gamma),
                   dtype=np.float64)
    proj = x @ w
    return np.sqrt(2.0 / d_feat) * np.concatenate(
        [np.cos(proj), np.sin(proj)], axis=-1)
