"""Synthetic data pipelines: the paper's linreg generator, an LM token
stream with client partitioning for federated runs, the MNIST-class
classification generator, and the CodedFedL random-Fourier-feature map."""
from .partition import partition_iid, partition_noniid
from .rff import rff_map, rff_map_reference
from .synthetic import (classification_dataset, linreg_dataset,
                        one_vs_rest_targets, token_batches)

__all__ = ["linreg_dataset", "token_batches", "partition_iid",
           "partition_noniid", "classification_dataset",
           "one_vs_rest_targets", "rff_map", "rff_map_reference"]
