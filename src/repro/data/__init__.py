"""Synthetic data pipelines: the paper's linreg generator and an LM token
stream with client partitioning for federated runs."""
from .synthetic import linreg_dataset, token_batches
from .partition import partition_iid, partition_noniid

__all__ = ["linreg_dataset", "token_batches", "partition_iid",
           "partition_noniid"]
