"""Client data partitioning for federated simulation.

The paper notes FL data is non-iid ("data stored locally on a device does
not represent the population distribution").  We provide iid sharding and a
Dirichlet-skew partitioner (the standard FL non-iid benchmark protocol).
"""
from __future__ import annotations

import numpy as np


def partition_iid(n_items: int, n_clients: int,
                  rng: np.random.Generator) -> list[np.ndarray]:
    """Random equal split of item indices."""
    perm = rng.permutation(n_items)
    return [np.sort(s) for s in np.array_split(perm, n_clients)]


def partition_noniid(labels: np.ndarray, n_clients: int, alpha: float,
                     rng: np.random.Generator) -> list[np.ndarray]:
    """Dirichlet(alpha) label-skew partition.

    Small alpha => each client sees few classes (highly non-iid);
    alpha -> inf recovers iid.  Returns per-client index arrays.
    """
    classes = np.unique(labels)
    client_idx: list[list[int]] = [[] for _ in range(n_clients)]
    for cls in classes:
        idx = np.flatnonzero(labels == cls)
        rng.shuffle(idx)
        shares = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(shares)[:-1] * len(idx)).astype(int)
        for i, part in enumerate(np.split(idx, cuts)):
            client_idx[i].extend(part.tolist())
    return [np.sort(np.array(ix, dtype=np.int64)) for ix in client_idx]
