"""Expected-return metric and per-device load optimization (paper §III-B).

R_i(t; ell~) = ell~ * 1{T_i <= t}  (indicator return metric),
E[R_i(t; ell~)] = ell~ * Pr{T_i <= t},  concave in ell~ (paper Fig. 1).

Step 1 of the two-step optimization (Eqs. 14-15):

    ell*_i(t) = argmax_{0 <= ell~ <= ell_i}  E[R_i(t; ell~)]

ell~ is an integer number of training points; the per-device cap is the local
dataset size ell_i (or c_up for the server's parity budget).  Loads are small
(hundreds to a few thousand) so an exact vectorized grid search is both exact
and fast — the whole (L, n) expected-return grid is one `total_cdf` call, not
one call per integer load (the seed's loop survives as
`repro.plan.reference.optimal_loads_loop` for parity tests and benchmark
baselines; the batched multi-fleet solver lives in `repro.plan.solver`).
"""
from __future__ import annotations

import numpy as np

from .delay_model import DeviceDelayParams, total_cdf


def expected_return(params: DeviceDelayParams, ell, t) -> np.ndarray:
    """E[R_i(t; ell)] = ell * Pr{T_i <= t}, vectorized over devices and any
    leading batch of loads (scalar, (n,), or (..., n) — e.g. an (L, 1) column
    broadcasts to the full (L, n) load grid in one shot)."""
    ell = np.asarray(ell, dtype=np.float64)
    ell = np.broadcast_to(ell, np.broadcast_shapes(ell.shape, params.a.shape))
    return ell * total_cdf(params, ell, t)


def optimal_loads(params: DeviceDelayParams, caps: np.ndarray, t: float,
                  chunk: int = 4096) -> tuple[np.ndarray, np.ndarray]:
    """Exact integer argmax of E[R_i(t; ell)] over 0..caps[i] per device.

    Returns (ell_star (n,) int array, expected return at ell_star (n,)).

    Grid-searches all integer loads at once: each chunk evaluates an
    (L, n) expected-return matrix in ONE vectorized call.  Memory is
    chunked along the load axis so server caps of ~10^5 stay cheap.
    """
    caps = np.asarray(caps, dtype=np.int64)
    n = params.n
    l_max = int(caps.max())
    best_val = np.zeros(n, dtype=np.float64)
    best_ell = np.zeros(n, dtype=np.int64)
    for lo in range(1, l_max + 1, chunk):
        hi = min(lo + chunk - 1, l_max)
        loads = np.arange(lo, hi + 1, dtype=np.float64)  # (L,)
        # E[R] for every device at every load in this chunk: (L, n)
        vals = expected_return(params, loads[:, None], t)
        # mask loads above each device's cap
        mask = loads[:, None] <= caps[None, :]
        vals = np.where(mask, vals, -np.inf)
        idx = np.argmax(vals, axis=0)  # (n,)
        chunk_best = vals[idx, np.arange(n)]
        better = chunk_best > best_val
        best_val = np.where(better, chunk_best, best_val)
        best_ell = np.where(better, loads[idx].astype(np.int64), best_ell)
    return best_ell, best_val
