"""Compute & communication delay model (paper §II-A).

Per-device total round-trip time for one epoch:

    T_i = T_c_i + T_d_i + T_u_i                                   (Eq. 7)

* Compute:  T_c_i = ell*a_i + Exp(gamma_i),  gamma_i = mu_i / ell  (Eq. 4)
  (deterministic MAC time per point `a_i`, plus a stochastic memory-access
  component whose mean grows linearly with the assigned load `ell`).
* Communication:  T_d + T_u = (N_d + N_u) * tau_i, with N ~ Geometric(1-p)
  (number of transmissions until first success, Eq. 5-6).  N_d + N_u =: K has
  a negative-binomial distribution: Pr{K=k} = (k-1) p^{k-2} (1-p)^2, k>=2.

The server is modelled as device n+1 with *no* communication leg (the parity
data is already resident), i.e. T_{n+1} = T_c_{n+1} only.

Everything is expressed both as an analytic CDF (used by the redundancy
optimizer — Eqs. 14-16 need Pr{T_i <= t} exactly) and as a sampler (used by
the wall-clock simulator).  All functions are vectorized over devices.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# Number of retransmission terms kept in the negative-binomial series of the
# analytic CDF.  With p <= 0.5 the tail Pr{K > 2+K_MAX} is < p^K_MAX * K_MAX,
# i.e. negligible at 64 terms for any p used in the paper (p = 0.1).
K_MAX = 64


@dataclasses.dataclass(frozen=True)
class DeviceDelayParams:
    """Delay parameters for a fleet of devices (vectorized, shape (n,)).

    a:   seconds of deterministic compute per training point (d MACs / MAC rate)
    mu:  memory access rate (points/sec) for the stochastic compute component;
         gamma = mu / ell for an assigned load of ell points
    tau: seconds per packet on the device<->server link (x / (r_i W));
         tau = 0 disables the communication legs (used for the server)
    p:   packet erasure probability per transmission attempt
    """

    a: np.ndarray
    mu: np.ndarray
    tau: np.ndarray
    p: np.ndarray

    def __post_init__(self):
        for f in ("a", "mu", "tau", "p"):
            object.__setattr__(self, f, np.asarray(getattr(self, f), dtype=np.float64))
        n = self.a.shape[0]
        if not (self.mu.shape == self.tau.shape == self.p.shape == (n,)):
            raise ValueError("all delay parameter arrays must share shape (n,)")
        if np.any(self.p < 0) or np.any(self.p >= 1):
            raise ValueError("erasure probability must be in [0, 1)")

    @property
    def n(self) -> int:
        return int(self.a.shape[0])

    def mean_total(self, ell: np.ndarray) -> np.ndarray:
        """E[T_i] for an assigned load `ell` (Eq. 8); ell=0 => comm only."""
        ell = np.asarray(ell, dtype=np.float64)
        compute = ell * (self.a + 1.0 / self.mu)
        has_comm = self.tau > 0
        comm = np.where(has_comm, 2.0 * self.tau / (1.0 - self.p), 0.0)
        return compute + comm


def _nbinom_pmf(p: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Pr{N_d + N_u = k} = (k-1) p^(k-2) (1-p)^2 for k >= 2."""
    k = np.asarray(k, dtype=np.float64)
    return (k - 1.0) * np.power(p, k - 2.0) * (1.0 - p) ** 2


def compute_cdf(params: DeviceDelayParams, ell, t) -> np.ndarray:
    """Pr{T_c_i <= t} for assigned load ell (shifted exponential).

    ell = 0 means no compute: the CDF is a step at t = 0.
    Broadcasts (n,) devices against scalar-or-(n,) ell and scalar t.
    """
    ell = np.asarray(ell, dtype=np.float64)
    t = np.asarray(t, dtype=np.float64)
    shift = ell * params.a
    # gamma = mu / ell; ell == 0 rows are masked to a step function below.
    gamma = params.mu / np.maximum(ell, 1.0)
    s = t - shift
    cdf = np.where(s > 0, -np.expm1(-np.minimum(gamma * np.maximum(s, 0.0), 700.0)), 0.0)
    return np.where(ell > 0, cdf, (t >= 0).astype(np.float64))


def total_cdf(params: DeviceDelayParams, ell, t) -> np.ndarray:
    """Pr{T_i <= t}: negative-binomial mixture over retransmission counts.

    Pr{T <= t} = sum_{k>=2} Pr{K=k} * Pr{T_c <= t - k*tau}   (tau > 0)
               = Pr{T_c <= t}                                 (tau = 0, server)

    `ell` may be scalar, (n,), or carry leading batch axes (..., n) — e.g. an
    (L, n) grid of candidate loads — and the CDF is evaluated for the whole
    batch in one vectorized pass (this is what makes the load optimization a
    single tensor expression instead of one call per integer load).
    """
    ell = np.asarray(ell, dtype=np.float64)
    ell = np.broadcast_to(ell, np.broadcast_shapes(ell.shape, params.a.shape))
    t = float(t)

    comm = params.tau > 0
    # compute-only CDF, used directly for tau == 0 (server-style) devices
    base = compute_cdf(params, ell, t)  # (..., n)
    if not np.any(comm):
        return base

    ks = np.arange(2, 2 + K_MAX, dtype=np.float64)      # (K,)
    pmf = _nbinom_pmf(params.p[:, None], ks[None, :])   # (n, K)
    # residual time after k transmissions: s_k = t - k * tau_i
    t_resid = t - ks[None, :] * params.tau[:, None]     # (n, K)
    shift = (ell * params.a)[..., None]                 # (..., n, 1)
    gamma = (params.mu / np.maximum(ell, 1.0))[..., None]  # ell=0 masked below
    s = t_resid - shift                                 # (..., n, K)
    cdf_k = np.where(s > 0,
                     -np.expm1(-np.minimum(gamma * np.maximum(s, 0.0), 700.0)),
                     0.0)
    # ell == 0 rows: compute CDF is a step at zero -> 1 whenever t_resid >= 0
    zero_load = (ell <= 0)[..., None]
    cdf_k = np.where(zero_load, (t_resid >= 0).astype(np.float64), cdf_k)
    mix = np.sum(pmf * cdf_k, axis=-1)                  # (..., n)
    return np.where(comm, mix, base)


def partial_cdf(params: DeviceDelayParams, ell, t, chunks: int) -> np.ndarray:
    """Pr{chunk q of an assignment `ell` is done by t}, for q = 1..chunks.

    The low-latency wireless model (arXiv:2011.06223 as reproduced here):
    a device assigned `ell` points uploads `chunks` incremental partial
    results; chunk q covers its first q*ell/chunks points, so its compute
    shift is (q/chunks)*ell*a_i while the stochastic memory-access rate
    stays mu_i/ell (the slowdown scales with the FULL assignment — this is
    what keeps over-assignment costly and the load allocation nontrivial).
    The communication legs (retransmission mixture) are shared by every
    chunk exactly as in `total_cdf`.

    ell: (n,) assignments; t scalar.  Returns (n, chunks); `chunks == 1`
    reduces to `total_cdf` exactly.
    """
    ell = np.broadcast_to(np.asarray(ell, dtype=np.float64), params.a.shape)
    t = float(t)
    fracs = np.arange(1, chunks + 1, dtype=np.float64) / chunks    # (Q,)
    shift = fracs[None, :] * (ell * params.a)[:, None]             # (n, Q)
    gamma = (params.mu / np.maximum(ell, 1.0))[:, None, None]      # (n, 1, 1)

    comm = params.tau > 0
    # compute-only CDF (tau == 0, server-style devices)
    s0 = t - shift
    base = np.where(
        s0 > 0,
        -np.expm1(-np.minimum(gamma[..., 0] * np.maximum(s0, 0.0), 700.0)),
        0.0)
    base = np.where((ell > 0)[:, None], base, (t >= 0.0))
    if not np.any(comm):
        return base

    ks = np.arange(2, 2 + K_MAX, dtype=np.float64)       # (K,)
    pmf = _nbinom_pmf(params.p[:, None], ks[None, :])    # (n, K)
    t_resid = t - ks[None, :] * params.tau[:, None]      # (n, K)
    s = t_resid[:, None, :] - shift[:, :, None]          # (n, Q, K)
    cdf_k = np.where(
        s > 0,
        -np.expm1(-np.minimum(gamma * np.maximum(s, 0.0), 700.0)),
        0.0)
    zero_load = (ell <= 0)[:, None, None]
    cdf_k = np.where(zero_load, (t_resid >= 0.0)[:, None, :], cdf_k)
    mix = np.sum(pmf[:, None, :] * cdf_k, axis=-1)       # (n, Q)
    return np.where(comm[:, None], mix, base)


def mec_total_cdf(params: DeviceDelayParams, ell, t) -> np.ndarray:
    """Pr{T_i <= t} under the CodedFedL MEC delay model (arXiv:2007.03273).

    The compute leg is the base shifted exponential (shift ell*a, rate
    mu/ell); the communication leg is ALSO a shifted exponential — shift
    `2 tau` (the erasure-free two-way transfer) and rate
    `gm = (1 - p) / (2 tau p)`, matching the geometric retransmission
    model's minimum and mean.  The total CDF is the closed-form
    convolution of the two exponentials at residual u = t - ell*a - 2 tau:

        F(u) = 1 - (gm e^{-gc u} - gc e^{-gm u}) / (gm - gc)

    with the equal-rate limit `1 - (1 + g u) e^{-g u}` where the rates
    collide, and the pure compute CDF at the same residual for devices
    whose communication leg is deterministic (`p == 0` or `tau == 0` —
    the latter makes this bit-identical to `compute_cdf`, i.e. the server).

    This is the float64 host mirror of the `mec_comm` evaluator in
    `repro.plan._solve_grid`, term for term — the Eq.-17 weights
    sqrt(1 - p_return) must see the SAME probabilities the solver
    optimized.  `ell` broadcasts as in `total_cdf`.
    """
    ell = np.asarray(ell, dtype=np.float64)
    ell = np.broadcast_to(ell, np.broadcast_shapes(ell.shape, params.a.shape))
    t = float(t)

    shift = ell * params.a
    gc = params.mu / np.maximum(ell, 1.0)
    gm = (1.0 - params.p) / np.maximum(2.0 * params.tau * params.p, 1e-30)
    u = t - shift - 2.0 * params.tau
    up = np.maximum(u, 0.0)
    e_c = np.exp(-np.minimum(gc * up, 700.0))
    e_m = np.exp(-np.minimum(gm * up, 700.0))
    denom = gm - gc
    close = np.abs(denom) <= 1e-8 * np.maximum(gm, gc)
    safe = np.where(close, 1.0, denom)
    f_neq = 1.0 - (gm * e_c - gc * e_m) / safe
    gbar = 0.5 * (gm + gc)
    arg = np.minimum(gbar * up, 700.0)
    f_eq = -np.expm1(-arg) - arg * np.exp(-arg)
    cdf = np.where(close, f_eq, f_neq)
    cdf = np.where(u > 0.0, cdf, 0.0)
    det = np.logical_or(params.p <= 0.0, params.tau <= 0.0)
    cdf_det = np.where(
        u > 0.0, -np.expm1(-np.minimum(gc * up, 700.0)), 0.0)
    cdf = np.where(det, cdf_det, cdf)
    return np.where(ell > 0, cdf, (u >= 0.0).astype(np.float64))


def sample_total_mec(params: DeviceDelayParams, ell,
                     rng: np.random.Generator,
                     size: Optional[int] = None) -> np.ndarray:
    """Draw T_i under the MEC delay model (see `mec_total_cdf`).

    Same compute draw as `sample_total`; the communication leg replaces
    the two geometric transmission-count draws with ONE exponential
    excess over the deterministic `2 tau` floor.  Always consumes exactly
    two generator draws per device per call (compute + comm excess), so
    the draw order is load- and parameter-independent.
    """
    ell = np.broadcast_to(np.asarray(ell, dtype=np.float64), params.a.shape)
    shape = (params.n,) if size is None else (size, params.n)
    shift = ell * params.a
    with np.errstate(divide="ignore", invalid="ignore"):
        scale = np.where(ell > 0, ell / params.mu, 0.0)
    t_c = shift + rng.exponential(1.0, size=shape) * scale
    comm = params.tau > 0
    stochastic = np.logical_and(comm, params.p > 0)
    gm = (1.0 - params.p) / np.maximum(2.0 * params.tau * params.p, 1e-30)
    excess = rng.exponential(1.0, size=shape) / gm
    t_comm = np.where(comm, 2.0 * params.tau, 0.0) \
        + np.where(stochastic, excess, 0.0)
    return t_c + t_comm


def sample_total(params: DeviceDelayParams, ell, rng: np.random.Generator,
                 size: Optional[int] = None) -> np.ndarray:
    """Draw T_i for every device.  Returns (n,) or (size, n)."""
    ell = np.broadcast_to(np.asarray(ell, dtype=np.float64), params.a.shape)
    shape = (params.n,) if size is None else (size, params.n)
    shift = ell * params.a
    with np.errstate(divide="ignore", invalid="ignore"):
        scale = np.where(ell > 0, ell / params.mu, 0.0)  # mean of Exp(gamma)
    t_c = shift + rng.exponential(1.0, size=shape) * scale
    # communication: two independent geometric draws (down + up)
    comm = params.tau > 0
    p = np.where(comm, params.p, 0.0)
    n_d = rng.geometric(1.0 - p, size=shape)
    n_u = rng.geometric(1.0 - p, size=shape)
    t_comm = np.where(comm, (n_d + n_u) * params.tau, 0.0)
    return t_c + t_comm
