"""Gradient computation & straggler-masked aggregation (paper §II, §III-D).

Two gradient sources arrive at the server each epoch:

  * systematic partial gradients, computed by client i over its first
    ell*_i local points:  g_i = X_i[:l]^T (X_i[:l] beta - y_i[:l]);
    only the subset with T_i <= t* arrives (mask),
  * the parity gradient the server computes preemptively on the composite
    parity data:  g_par = (1/c) X~^T (X~ beta - y~)            (Eq. 18)
    which approximates sum_i sum_k w_ik^2 x_ik^T (x_ik beta - y_ik).

Their sum is an (approximately) unbiased estimate of the full gradient
X^T (X beta - y) (Eqs. 18-19).  All ops are jit-compatible; the mask is a
traced operand so one compiled step serves every epoch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# The grad_path knob.  Every strategy computes its round gradient through
# the dispatchers below; `path` picks between
#
#   REFERENCE — the verbatim historical two-pass expressions (the
#     bit-parity oracle: traces are bit-identical to the pre-fusion
#     epoch bodies), and
#   FUSED     — the one-pass hot path.  On TPU this launches the
#     `kernels.round_grad` Pallas family (one HBM sweep over X, masks
#     as traced operands).  Off-TPU, where Pallas runs interpreted, the
#     fused win comes from the *operand layout* instead — strategies
#     feed packed systematic rows and Gram-folded parity (see
#     `core.cfl.fused_coded_device_state`) — and the dispatchers keep
#     the reference jnp expressions, so CPU fused and reference
#     gradients are bit-identical on identical operands.
#
# Dispatch on `path`/backend is host-side at trace time: one compiled
# engine per path, no runtime branching.
# --------------------------------------------------------------------------

FUSED = "fused"
REFERENCE = "reference"
GRAD_PATHS = (FUSED, REFERENCE)


def resolve_grad_path(path: str, use_kernel: bool = False) -> str:
    """Validate a strategy's `grad_path`, folding in the deprecated
    `use_kernel` flag (use_kernel=True forces the fused path)."""
    if path not in GRAD_PATHS:
        raise ValueError(
            f"grad_path must be one of {GRAD_PATHS}, got {path!r}")
    return FUSED if use_kernel else path


def _fused_kernels():
    """TPU only: the Pallas round-gradient entry points (None off-TPU)."""
    from repro.kernels.common import on_tpu
    if not on_tpu():
        return None
    from repro.kernels.round_grad import ops as rg_ops
    return rg_ops


def round_gradient(x: jax.Array, y: jax.Array, beta: jax.Array,
                   w: jax.Array | None = None,
                   path: str = REFERENCE) -> jax.Array:
    """g = (w * (X beta - y)) @ X — the flat round gradient.

    The reference expression contracts the leading (row-major
    contiguous) axis both times, exactly as every strategy's epoch body
    historically wrote it; on TPU the fused path computes the same sum
    in one HBM pass."""
    rg_ops = _fused_kernels() if path == FUSED else None
    if rg_ops is not None:
        return rg_ops.masked_round_gradient(x, y, w, beta)
    resid = x @ beta - y
    if w is None:
        return resid @ x
    return (resid * w) @ x


def coded_round_gradient(x: jax.Array, y: jax.Array, w: jax.Array,
                         x_par: jax.Array, y_par: jax.Array,
                         w_par: jax.Array, beta: jax.Array,
                         path: str = REFERENCE) -> jax.Array:
    """Systematic + parity round gradient with per-row parity weights
    (Eq. 18's 1/(c*rho) normalization folded into w_par).  On TPU the
    fused path is a single two-stream Pallas launch."""
    rg_ops = _fused_kernels() if path == FUSED else None
    if rg_ops is not None:
        return rg_ops.coded_round_gradient(x, y, w, x_par, y_par, w_par,
                                           beta)
    g_sys = round_gradient(x, y, beta, w=w)
    g_par = ((x_par @ beta - y_par) * w_par) @ x_par
    return g_sys + g_par


def tiered_round_gradient(x: jax.Array, y: jax.Array, beta: jax.Array,
                          w: jax.Array | None, tier_masks: jax.Array,
                          path: str = REFERENCE) -> jax.Array:
    """(T, d) tier partials of the masked round gradient — the fleet
    layer's edge stage.  Reference path: residual once + `tier_reduce`
    (the pre-fusion expression).  Fused path on TPU: one pass over X
    shared by all tiers; the per-tier expression matches the flat
    kernel at T == 1, preserving the single-tier bit-exact contract."""
    rg_ops = _fused_kernels() if path == FUSED else None
    if rg_ops is not None:
        return rg_ops.tier_masked_round_gradient(x, y, w, tier_masks, beta)
    resid = x @ beta - y
    contrib = resid if w is None else resid * w
    return tier_reduce(contrib, x, tier_masks)


@jax.jit
def parity_gram(x_par: jax.Array, y_par: jax.Array):
    """Normal-equation factors of the parity block, computed ONCE at
    plan time: G = X~^T X~ (d, d) and b = y~ X~ (d,).  Eq. 18 then
    collapses to (G beta - b) / c — zero passes over the (c, d) parity
    rows per epoch."""
    return x_par.T @ x_par, y_par @ x_par


def gram_parity_gradient(gram: jax.Array, gramy: jax.Array,
                         beta: jax.Array, c_norm) -> jax.Array:
    """(G beta - b) / c_norm == Eq. 18 through precomputed Gram factors."""
    return (gram @ beta - gramy) / c_norm


def fused_sys_block(dev: dict) -> tuple:
    """(x, y, base_w, client_ids) of the fused systematic block.

    Resolves both layouts `core.cfl.fused_coded_device_state` emits:
    the packed one (plan-support rows under per-lane "sys_*" keys) and
    the dense fallback (full rows under the shared "x"/"y"/"row_client"
    names — replicated, not stacked, across sweep lanes — with the load
    mask as the per-lane base weight).  Trace-time dispatch: the layout
    is part of the engine's shape bucket, never a runtime branch."""
    if "sys_x" in dev:
        return (dev["sys_x"], dev["sys_y"], dev["sys_w"],
                dev["sys_client"])
    return dev["x"], dev["y"], dev["sys_w"], dev["row_client"]


def fused_tier_masks(dev: dict, tier_masks: jax.Array) -> jax.Array:
    """(T, m) tier row masks gathered to the fused layout's rows: packed
    layouts select their support columns, the dense fallback uses the
    full-width masks as-is."""
    if "sys_rows" in dev:
        return jnp.take(tier_masks, dev["sys_rows"], axis=1)
    return tier_masks


def fused_coded_gradient(dev: dict, w: jax.Array, parity_gate,
                         beta: jax.Array, rho: float = 1.0) -> jax.Array:
    """The static-parity fused round: packed systematic rows through
    `round_gradient` (one-pass kernel on TPU) + the Gram-folded parity
    matvec, gated by the scalar parity arrival.  Consumes either fused
    device layout of `core.cfl.fused_coded_device_state`.

    The Eq.-18 divisor c rides along as the `par_c` OPERAND (never a
    trace constant): the Gram factors erased c from the operand shapes,
    so engines are shared across parity budgets and the divisor must
    stay a value.  `rho` is an engine-keyed static (StochasticCodedFL's
    sample_frac); rho == 1.0 multiplies exactly."""
    x, y, _, _ = fused_sys_block(dev)
    g_sys = round_gradient(x, y, beta, w=w, path=FUSED)
    g_par = gram_parity_gradient(dev["par_gram"], dev["par_gramy"], beta,
                                 dev["par_c"] * rho)
    return g_sys + parity_gate * g_par


@jax.jit
def client_partial_gradients(xs: jax.Array, ys: jax.Array,
                             load_mask: jax.Array, beta: jax.Array) -> jax.Array:
    """Per-client partial gradients over their systematic loads.

    xs: (n, ell, d), ys: (n, ell)
    load_mask: (n, ell) 1.0 for the points each client actually processes
               (its first ell*_i points), 0.0 for punctured points
    Returns (n, d) per-client partial gradients.
    """
    resid = (jnp.einsum("nld,d->nl", xs, beta) - ys) * load_mask
    return jnp.einsum("nld,nl->nd", xs, resid)


@partial(jax.jit, static_argnames=("use_kernel",))
def parity_gradient(x_par: jax.Array, y_par: jax.Array, beta: jax.Array,
                    use_kernel: bool = False) -> jax.Array:
    """(1/c) X~^T (X~ beta - y~)  — the server's redundant gradient (Eq. 18)."""
    c = x_par.shape[0]
    if use_kernel:
        from repro.kernels.coded_grad import ops as cg_ops
        # block_m="auto" default: row tile from the repro.tune cache
        g = cg_ops.lsq_gradient(x_par, y_par, beta)
    else:
        # (resid @ X) == (X.T @ resid) but contracts the leading (row-major
        # contiguous) axis — ~6x faster on CPU, bit-identical values
        g = (x_par @ beta - y_par) @ x_par
    return g / c


@jax.jit
def combine(partial_grads: jax.Array, received: jax.Array,
            g_parity: jax.Array, parity_received: jax.Array) -> jax.Array:
    """Deadline-masked combination of both gradient sources (Eq. 18 + 19).

    partial_grads: (n, d) per-client systematic gradients
    received: (n,) {0,1} mask — client i's gradient arrived by t*
    g_parity: (d,) parity gradient
    parity_received: scalar {0,1} — the server's own parity computation
                     finished by t* (device n+1 in Eq. 13)
    """
    g_sys = jnp.einsum("nd,n->d", partial_grads, received)
    return g_sys + parity_received * g_parity


def tier_reduce(contrib: jax.Array, x: jax.Array,
                tier_masks: jax.Array) -> jax.Array:
    """Per-tier weighted reduce: (T, m) row masks × (m,) contrib × (m, d) x
    → (T, d) tier partials (the edge stage of `repro.fleet`'s hierarchy).

    Each tier partial is the FULL-WIDTH masked gemv `(contrib * mask) @ x`:
    masked-out rows contribute exact ±0.0 terms, so the per-row
    accumulation order of the flat contraction is unchanged and each
    partial equals the flat contraction restricted to its tier
    bit-for-bit.  `lax.map` keeps tiers sequential (like the lane
    engine's per-lane map) so the per-tier expression graph is the flat
    graph, merely masked.
    """
    return jax.lax.map(lambda mask: (contrib * mask) @ x, tier_masks)


def cross_tier_combine(tier_partials: jax.Array) -> jax.Array:
    """(T, d) tier partials → (d,) server aggregate.

    The ONLY floating-point reassociation the hierarchy introduces: a
    T-term sequential sum over tiers (fori_loop, matching the order an
    edge→cloud uplink delivers them).  T == 1 is the identity, which is
    what makes a single-tier topology bit-for-bit equal to the flat path.
    """
    def body(t, acc):
        return acc + tier_partials[t]
    return jax.lax.fori_loop(1, tier_partials.shape[0], body,
                             tier_partials[0])


@jax.jit
def uncoded_full_gradient(xs: jax.Array, ys: jax.Array, beta: jax.Array) -> jax.Array:
    """Baseline uncoded FL gradient: every client, every point (Eq. 2).

    Computed over the flattened (m, d) layout: leading-axis contractions
    lower to fast row-major matvecs (the batched einsum is ~10x slower on
    CPU for the §IV shapes)."""
    x = xs.reshape(-1, xs.shape[-1])
    resid = x @ beta - ys.reshape(-1)
    return resid @ x


@jax.jit
def gd_update(beta: jax.Array, grad: jax.Array, lr: float, m: int) -> jax.Array:
    """beta <- beta - (mu/m) * grad  (Eq. 3)."""
    return beta - (lr / m) * grad


def nmse(beta_hat: jax.Array, beta_true: jax.Array) -> jax.Array:
    """Normalized mean-square error ||b^ - b||^2 / ||b||^2 (paper §IV)."""
    return jnp.sum((beta_hat - beta_true) ** 2) / jnp.sum(beta_true ** 2)
