"""Gradient computation & straggler-masked aggregation (paper §II, §III-D).

Two gradient sources arrive at the server each epoch:

  * systematic partial gradients, computed by client i over its first
    ell*_i local points:  g_i = X_i[:l]^T (X_i[:l] beta - y_i[:l]);
    only the subset with T_i <= t* arrives (mask),
  * the parity gradient the server computes preemptively on the composite
    parity data:  g_par = (1/c) X~^T (X~ beta - y~)            (Eq. 18)
    which approximates sum_i sum_k w_ik^2 x_ik^T (x_ik beta - y_ik).

Their sum is an (approximately) unbiased estimate of the full gradient
X^T (X beta - y) (Eqs. 18-19).  All ops are jit-compatible; the mask is a
traced operand so one compiled step serves every epoch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@jax.jit
def client_partial_gradients(xs: jax.Array, ys: jax.Array,
                             load_mask: jax.Array, beta: jax.Array) -> jax.Array:
    """Per-client partial gradients over their systematic loads.

    xs: (n, ell, d), ys: (n, ell)
    load_mask: (n, ell) 1.0 for the points each client actually processes
               (its first ell*_i points), 0.0 for punctured points
    Returns (n, d) per-client partial gradients.
    """
    resid = (jnp.einsum("nld,d->nl", xs, beta) - ys) * load_mask
    return jnp.einsum("nld,nl->nd", xs, resid)


@partial(jax.jit, static_argnames=("use_kernel",))
def parity_gradient(x_par: jax.Array, y_par: jax.Array, beta: jax.Array,
                    use_kernel: bool = False) -> jax.Array:
    """(1/c) X~^T (X~ beta - y~)  — the server's redundant gradient (Eq. 18)."""
    c = x_par.shape[0]
    if use_kernel:
        from repro.kernels.coded_grad import ops as cg_ops
        # block_m="auto" default: row tile from the repro.tune cache
        g = cg_ops.lsq_gradient(x_par, y_par, beta)
    else:
        # (resid @ X) == (X.T @ resid) but contracts the leading (row-major
        # contiguous) axis — ~6x faster on CPU, bit-identical values
        g = (x_par @ beta - y_par) @ x_par
    return g / c


@jax.jit
def combine(partial_grads: jax.Array, received: jax.Array,
            g_parity: jax.Array, parity_received: jax.Array) -> jax.Array:
    """Deadline-masked combination of both gradient sources (Eq. 18 + 19).

    partial_grads: (n, d) per-client systematic gradients
    received: (n,) {0,1} mask — client i's gradient arrived by t*
    g_parity: (d,) parity gradient
    parity_received: scalar {0,1} — the server's own parity computation
                     finished by t* (device n+1 in Eq. 13)
    """
    g_sys = jnp.einsum("nd,n->d", partial_grads, received)
    return g_sys + parity_received * g_parity


def tier_reduce(contrib: jax.Array, x: jax.Array,
                tier_masks: jax.Array) -> jax.Array:
    """Per-tier weighted reduce: (T, m) row masks × (m,) contrib × (m, d) x
    → (T, d) tier partials (the edge stage of `repro.fleet`'s hierarchy).

    Each tier partial is the FULL-WIDTH masked gemv `(contrib * mask) @ x`:
    masked-out rows contribute exact ±0.0 terms, so the per-row
    accumulation order of the flat contraction is unchanged and each
    partial equals the flat contraction restricted to its tier
    bit-for-bit.  `lax.map` keeps tiers sequential (like the lane
    engine's per-lane map) so the per-tier expression graph is the flat
    graph, merely masked.
    """
    return jax.lax.map(lambda mask: (contrib * mask) @ x, tier_masks)


def cross_tier_combine(tier_partials: jax.Array) -> jax.Array:
    """(T, d) tier partials → (d,) server aggregate.

    The ONLY floating-point reassociation the hierarchy introduces: a
    T-term sequential sum over tiers (fori_loop, matching the order an
    edge→cloud uplink delivers them).  T == 1 is the identity, which is
    what makes a single-tier topology bit-for-bit equal to the flat path.
    """
    def body(t, acc):
        return acc + tier_partials[t]
    return jax.lax.fori_loop(1, tier_partials.shape[0], body,
                             tier_partials[0])


@jax.jit
def uncoded_full_gradient(xs: jax.Array, ys: jax.Array, beta: jax.Array) -> jax.Array:
    """Baseline uncoded FL gradient: every client, every point (Eq. 2).

    Computed over the flattened (m, d) layout: leading-axis contractions
    lower to fast row-major matvecs (the batched einsum is ~10x slower on
    CPU for the §IV shapes)."""
    x = xs.reshape(-1, xs.shape[-1])
    resid = x @ beta - ys.reshape(-1)
    return resid @ x


@jax.jit
def gd_update(beta: jax.Array, grad: jax.Array, lr: float, m: int) -> jax.Array:
    """beta <- beta - (mu/m) * grad  (Eq. 3)."""
    return beta - (lr / m) * grad


def nmse(beta_hat: jax.Array, beta_true: jax.Array) -> jax.Array:
    """Normalized mean-square error ||b^ - b||^2 / ||b||^2 (paper §IV)."""
    return jnp.sum((beta_hat - beta_true) ** 2) / jnp.sum(beta_true ** 2)
