"""Core CFL building blocks: delay model, redundancy optimization, encoding,
straggler-masked aggregation, and the protocol orchestrator."""
from .delay_model import DeviceDelayParams, compute_cdf, total_cdf, sample_total
from .returns import expected_return, optimal_loads
from .redundancy import RedundancyPlan, solve_redundancy, systematic_weights
from .encoding import ClientParity, generator_matrix, encode_client, encode_fleet
from .aggregation import (client_partial_gradients, parity_gradient, combine,
                          uncoded_full_gradient, gd_update, nmse)
from .cfl import CFLState, setup, epoch_gradient

__all__ = [
    "DeviceDelayParams", "compute_cdf", "total_cdf", "sample_total",
    "expected_return", "optimal_loads",
    "RedundancyPlan", "solve_redundancy", "systematic_weights",
    "ClientParity", "generator_matrix", "encode_client", "encode_fleet",
    "client_partial_gradients", "parity_gradient", "combine",
    "uncoded_full_gradient", "gd_update", "nmse",
    "CFLState", "setup", "epoch_gradient",
]
