"""Coded Federated Learning protocol orchestrator (paper §III).

Ties the pieces together in the order the protocol runs:

  1. The server collects delay statistics (a_i, mu_i, tau_i, p_i) and local
     dataset sizes, runs the two-step redundancy optimization (Eqs. 14-16)
     and broadcasts (c, ell*_i, Pr{T_i >= t*}) to the clients.
  2. Each client builds its weight vector (Eq. 17), draws a private G_i and
     uploads parity (G_i W_i X_i, G_i W_i y_i) once.  The server sums them
     into the composite parity dataset.
  3. Per epoch: clients compute partial gradients over their first ell*_i
     points; the server preemptively computes the parity gradient, waits
     until t*, and combines whatever arrived (Eqs. 18-19).

This module holds protocol state; wall-clock behaviour (sampling T_i,
deciding who made the deadline) lives in `repro.sim`.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from . import aggregation, encoding
from .delay_model import DeviceDelayParams
from .redundancy import RedundancyPlan, solve_redundancy, systematic_weights

if TYPE_CHECKING:  # annotation-only: core must not import sim at runtime
    from repro.sim.network import FleetSpec


def parity_upload_bits(n: int, c: int, d: int, bits_per_value: int = 32,
                       header_overhead: float = 0.10) -> np.ndarray:
    """Bits each of n clients uploads for its (c, d+1) parity shard — the
    ONE copy of this accounting, shared by every coded scheme's state."""
    per_client = c * (d + 1) * bits_per_value * (1.0 + header_overhead)
    return np.full(n, per_client)


def sample_parity_upload_time(state, fleet: "FleetSpec",
                              rng: np.random.Generator) -> float:
    """One-time parity-upload wall time for any coded-scheme state (needs
    `.parity_upload_bits()` and `.c`): each device ships its shard over its
    own link; devices upload in parallel so the fleet-level delay is the
    slowest one.  The geometric retransmission draw happens even when
    c == 0, preserving the legacy generator order of every entry point."""
    upload_bits = state.parity_upload_bits()
    packets = np.ceil(upload_bits / fleet.packet_bits)
    retrans = rng.geometric(1.0 - fleet.edge.p, size=fleet.edge.n)
    if state.c == 0:
        return 0.0
    return float(np.max(
        packets * retrans * (fleet.packet_bits / fleet.link_rates)))


def coded_uplink_bits(state, fleet: "FleetSpec", epochs: int,
                      packets_per_epoch: int = 2) -> float:
    """Total device->server bits for a coded scheme: the one-time parity
    upload plus `packets_per_epoch` packets per device per epoch (CFL and
    the stochastic scheme use 2; chunked partial uploads pass chunks+1)."""
    n = fleet.edge.n
    return float(np.sum(state.parity_upload_bits())) \
        + epochs * n * packets_per_epoch * fleet.packet_bits


# Packed row counts are padded up to a bucket multiple so sessions with
# nearby plans (e.g. the nu-ladder sweeps) land in the same engine shape
# bucket instead of fragmenting one compiled program per plan.  Padding
# rows replicate row 0 at weight 0.0 — exact-zero contributions, and the
# index stays valid for arrival/tier-mask gathers.
PACK_BLOCK = 512
PACK_MIN = 64
# Above this support density packing is skipped: dropping <15% of rows
# saves almost no bandwidth, while the per-plan packed row count would
# fragment sweeps into one compiled engine per plan AND force the bulk
# (k, d) feature block to be stacked per lane.  The dense fallback keeps
# the full (m, d) rows under the shared data_device_keys names, so every
# dense lane of a sweep shares one engine and ONE replicated copy of X.
PACK_DENSE_FRAC = 0.85


def packed_row_indices(load_flat: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Row indices of the plan's systematic support, bucket-padded.

    load_flat: (m,) flattened load mask.  Returns (idx, valid): int32
    indices of length ceil(k / PACK_BLOCK) * PACK_BLOCK (min PACK_MIN)
    where k rows have load > 0, and the bool validity mask that becomes
    the packed layout's base row weight."""
    keep = np.flatnonzero(np.asarray(load_flat) > 0).astype(np.int32)
    k = int(keep.size)
    target = max(PACK_MIN, PACK_BLOCK * -(-k // PACK_BLOCK)) if k \
        else PACK_MIN
    idx = np.zeros(target, dtype=np.int32)
    idx[:k] = keep
    valid = np.arange(target) < k
    return idx, valid


def parity_gram_factors(state) -> tuple[jax.Array, jax.Array]:
    """Memoized (G, b) = (X~^T X~, y~ X~) for one protocol state — the
    plan-time half of the Gram-folded Eq. 18 (see
    `aggregation.parity_gram`).  Cached on the state instance so every
    engine build over the same plan reuses one factorization."""
    cached = getattr(state, "_parity_gram", None)
    if cached is None:
        cached = aggregation.parity_gram(state.x_parity, state.y_parity)
        state._parity_gram = cached
    return cached


def fused_coded_device_state(state, data, x: jax.Array | None = None,
                             parity_rows: bool = False) -> dict:
    """Scan-engine operands for the FUSED gradient path: systematic rows
    packed to the plan's support (zero-load rows dropped host-side, the
    count bucket-padded at weight 0) and the parity block folded to its
    Gram factors.  At the paper's §IV operating point this cuts the
    per-epoch row stream ~23% and removes both parity passes entirely.

    The packed keys deliberately do NOT overlap `coded_device_state`'s
    data_device_keys ("x"/"y"/"row_client"): every packed operand is
    plan-derived and must stay per-lane in sweeps.  When the support is
    DENSE (padded count >= PACK_DENSE_FRAC * m) packing is skipped and
    the dict uses the shared names instead — full rows with the load
    mask as `sys_w` — so nu-ladder sweep lanes whose plans load nearly
    everything land in ONE engine bucket with one replicated X (consume
    via `aggregation.fused_sys_block`, which resolves both layouts).

    x: override feature matrix (m, d_feat) — CodedFedL's RFF features.
    parity_rows: also ship the raw parity shards (schemes with dynamic
    per-row parity masks, e.g. StochasticCodedFL at rho < 1, need the
    rows themselves, not just the Gram factors).

    The packed operands are memoized on the state instance (keyed by the
    data/x object identities, which the tuple keeps alive) so repeated
    `Session.run` calls over one plan skip the host-side gathers.
    """
    x_arg = x
    cached = getattr(state, "_fused_dev", None)
    if cached is not None and cached[0] is data and cached[1] is x_arg \
            and cached[2] == parity_rows:
        return cached[3]
    n, ell = data.n, data.ell
    if x is None:
        x = data.xs.reshape(data.m, data.d)
    y = data.ys.reshape(data.m)
    load_flat = np.asarray(state.load_mask).reshape(data.m)
    idx, valid = packed_row_indices(load_flat)
    row_client = np.repeat(np.arange(n, dtype=np.int32), ell)
    if idx.size >= PACK_DENSE_FRAC * data.m:
        # dense fallback: full rows, load mask as the base row weight —
        # bit-identical systematic sums to the reference path
        dev = {"x": x, "y": y,
               "row_client": jnp.asarray(row_client),
               "sys_w": jnp.asarray(load_flat, dtype=x.dtype)}
    else:
        jidx = jnp.asarray(idx)
        dev = {"sys_x": jnp.take(x, jidx, axis=0),
               "sys_y": jnp.take(y, jidx),
               "sys_w": jnp.asarray(valid, dtype=x.dtype),
               "sys_client": jnp.asarray(row_client[idx]),
               "sys_rows": jidx}
    if state.c > 0:
        gram, gramy = parity_gram_factors(state)
        dev["par_gram"] = gram
        dev["par_gramy"] = gramy
        # Eq.-18 divisor as an OPERAND: the (d, d) Gram factors erased c
        # from the operand shapes, so one compiled engine serves every
        # parity budget — the divisor must be a value, not a constant
        dev["par_c"] = jnp.asarray(float(state.c), dtype=x.dtype)
        if parity_rows:
            dev["x_parity"] = state.x_parity
            dev["y_parity"] = state.y_parity
    state._fused_dev = (data, x_arg, parity_rows, dev)
    return dev


def coded_device_state(state, data) -> dict:
    """The scan-engine operands every coded scheme shares: flat (m, d)
    data layout, systematic load mask, per-row client ids, parity shards.
    `state` needs `.load_mask`/`.x_parity`/`.y_parity`; `data` is a
    `repro.api.TrainData` (duck-typed — core does not import api).
    Schemes with extra operands (e.g. LowLatencyCFL's row_chunk) add them
    on top of this dict."""
    n, ell = data.n, data.ell
    row_client = jnp.repeat(jnp.arange(n, dtype=jnp.int32), ell)
    return {"x": data.xs.reshape(data.m, data.d),
            "y": data.ys.reshape(data.m),
            "w_sys": state.load_mask.reshape(data.m),
            "row_client": row_client,
            "x_parity": state.x_parity,
            "y_parity": state.y_parity}


@dataclasses.dataclass
class CFLState:
    """Frozen protocol state after setup (one-time encoding done)."""

    plan: RedundancyPlan
    weights: jax.Array        # (n, ell) Eq.-17 weight diagonals
    load_mask: jax.Array      # (n, ell) 1.0 on each client's processed points
    x_parity: jax.Array       # (c, d) composite parity features
    y_parity: jax.Array       # (c,)   composite parity labels
    edge: DeviceDelayParams
    server: DeviceDelayParams

    @property
    def c(self) -> int:
        return int(self.x_parity.shape[0])

    def parity_upload_bits(self, bits_per_value: int = 32,
                           header_overhead: float = 0.10) -> np.ndarray:
        """Bits each client uploads for its parity shard (one-time cost)."""
        return parity_upload_bits(self.edge.n, self.c,
                                  int(self.x_parity.shape[1]),
                                  bits_per_value, header_overhead)


def setup(key: jax.Array, xs: jax.Array, ys: jax.Array,
          edge: DeviceDelayParams, server: DeviceDelayParams,
          fixed_c: int | None = None, c_up: int | None = None,
          generator: str = "normal", use_kernel: bool = False,
          plan: RedundancyPlan | None = None) -> CFLState:
    """Run steps 1-2 of the protocol (optimization + one-time encoding).

    xs: (n, ell, d) client-resident features, ys: (n, ell) labels.
    fixed_c: sweep mode — force the coding redundancy instead of optimizing.
    plan: pre-solved redundancy plan (e.g. one element of a
          `repro.plan.solve_redundancy_batched` sweep); skips the solve and
          runs only the encoding step.
    """
    n, ell, _ = xs.shape
    data_sizes = np.full(n, ell, dtype=np.int64)
    if plan is None:
        plan = solve_redundancy(edge, server, data_sizes,
                                c_up=c_up, fixed_c=fixed_c)

    w_list = systematic_weights(plan, data_sizes)
    weights = jnp.asarray(np.stack(w_list), dtype=xs.dtype)  # (n, ell)
    load_mask = jnp.asarray(
        np.arange(ell)[None, :] < plan.loads[:, None], dtype=xs.dtype)

    if plan.c > 0:
        x_par, y_par = encoding.encode_fleet(
            key, xs, ys, weights, plan.c, kind=generator, use_kernel=use_kernel)
    else:  # delta = 0 degenerates to uncoded FL with deadline t*
        x_par = jnp.zeros((0, xs.shape[-1]), dtype=xs.dtype)
        y_par = jnp.zeros((0,), dtype=xs.dtype)

    return CFLState(plan=plan, weights=weights, load_mask=load_mask,
                    x_parity=x_par, y_parity=y_par, edge=edge, server=server)


def epoch_gradient(state: CFLState, xs: jax.Array, ys: jax.Array,
                   beta: jax.Array, received: jax.Array,
                   parity_received: jax.Array,
                   use_kernel: bool = False) -> jax.Array:
    """One epoch's combined gradient estimate given arrival masks."""
    partials = aggregation.client_partial_gradients(xs, ys, state.load_mask, beta)
    if state.c > 0:
        g_par = aggregation.parity_gradient(
            state.x_parity, state.y_parity, beta, use_kernel=use_kernel)
    else:
        g_par = jnp.zeros_like(beta)
        parity_received = jnp.asarray(0.0, dtype=beta.dtype)
    return aggregation.combine(partials, received, g_par, parity_received)
