"""Coded Federated Learning protocol orchestrator (paper §III).

Ties the pieces together in the order the protocol runs:

  1. The server collects delay statistics (a_i, mu_i, tau_i, p_i) and local
     dataset sizes, runs the two-step redundancy optimization (Eqs. 14-16)
     and broadcasts (c, ell*_i, Pr{T_i >= t*}) to the clients.
  2. Each client builds its weight vector (Eq. 17), draws a private G_i and
     uploads parity (G_i W_i X_i, G_i W_i y_i) once.  The server sums them
     into the composite parity dataset.
  3. Per epoch: clients compute partial gradients over their first ell*_i
     points; the server preemptively computes the parity gradient, waits
     until t*, and combines whatever arrived (Eqs. 18-19).

This module holds protocol state; wall-clock behaviour (sampling T_i,
deciding who made the deadline) lives in `repro.sim`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import aggregation, encoding
from .delay_model import DeviceDelayParams
from .redundancy import RedundancyPlan, solve_redundancy, systematic_weights


@dataclasses.dataclass
class CFLState:
    """Frozen protocol state after setup (one-time encoding done)."""

    plan: RedundancyPlan
    weights: jax.Array        # (n, ell) Eq.-17 weight diagonals
    load_mask: jax.Array      # (n, ell) 1.0 on each client's processed points
    x_parity: jax.Array       # (c, d) composite parity features
    y_parity: jax.Array       # (c,)   composite parity labels
    edge: DeviceDelayParams
    server: DeviceDelayParams

    @property
    def c(self) -> int:
        return int(self.x_parity.shape[0])

    def parity_upload_bits(self, bits_per_value: int = 32,
                           header_overhead: float = 0.10) -> np.ndarray:
        """Bits each client uploads for its parity shard (one-time cost)."""
        d = self.x_parity.shape[1]
        per_client = self.c * (d + 1) * bits_per_value * (1.0 + header_overhead)
        return np.full(self.edge.n, per_client)


def setup(key: jax.Array, xs: jax.Array, ys: jax.Array,
          edge: DeviceDelayParams, server: DeviceDelayParams,
          fixed_c: int | None = None, c_up: int | None = None,
          generator: str = "normal", use_kernel: bool = False,
          plan: RedundancyPlan | None = None) -> CFLState:
    """Run steps 1-2 of the protocol (optimization + one-time encoding).

    xs: (n, ell, d) client-resident features, ys: (n, ell) labels.
    fixed_c: sweep mode — force the coding redundancy instead of optimizing.
    plan: pre-solved redundancy plan (e.g. one element of a
          `repro.plan.solve_redundancy_batched` sweep); skips the solve and
          runs only the encoding step.
    """
    n, ell, _ = xs.shape
    data_sizes = np.full(n, ell, dtype=np.int64)
    if plan is None:
        plan = solve_redundancy(edge, server, data_sizes,
                                c_up=c_up, fixed_c=fixed_c)

    w_list = systematic_weights(plan, data_sizes)
    weights = jnp.asarray(np.stack(w_list), dtype=xs.dtype)  # (n, ell)
    load_mask = jnp.asarray(
        np.arange(ell)[None, :] < plan.loads[:, None], dtype=xs.dtype)

    if plan.c > 0:
        x_par, y_par = encoding.encode_fleet(
            key, xs, ys, weights, plan.c, kind=generator, use_kernel=use_kernel)
    else:  # delta = 0 degenerates to uncoded FL with deadline t*
        x_par = jnp.zeros((0, xs.shape[-1]), dtype=xs.dtype)
        y_par = jnp.zeros((0,), dtype=xs.dtype)

    return CFLState(plan=plan, weights=weights, load_mask=load_mask,
                    x_parity=x_par, y_parity=y_par, edge=edge, server=server)


def epoch_gradient(state: CFLState, xs: jax.Array, ys: jax.Array,
                   beta: jax.Array, received: jax.Array,
                   parity_received: jax.Array,
                   use_kernel: bool = False) -> jax.Array:
    """One epoch's combined gradient estimate given arrival masks."""
    partials = aggregation.client_partial_gradients(xs, ys, state.load_mask, beta)
    if state.c > 0:
        g_par = aggregation.parity_gradient(
            state.x_parity, state.y_parity, beta, use_kernel=use_kernel)
    else:
        g_par = jnp.zeros_like(beta)
        parity_received = jnp.asarray(0.0, dtype=beta.dtype)
    return aggregation.combine(partials, received, g_par, parity_received)
