"""Client-side random linear encoding of training data (paper §III-A, Eqs. 9-12).

Each client i draws a private generator matrix G_i in R^{c x ell_i} with iid
N(0,1) entries (Bernoulli(1/2) +-1 also supported) and a diagonal weight
matrix W_i (Eq. 17), then uploads only

    X~_i = G_i W_i X_i,      y~_i = G_i W_i y_i.

The server sums the client parities into the composite parity dataset
(X~, y~) = (sum_i X~_i, sum_i y~_i) = (G W X, G W y) — a distributed encoding
of the full decentralized dataset in which G, W, X, y all stay unknown to the
server.  Puncturing (w=1 rows that the client never processes locally) is
implicit in the weight vector.

Encoding is a batched matmul; the Pallas path in `repro.kernels.encode`
fuses generator sampling + diagonal scaling + matmul accumulation end-to-end,
streamed one client at a time.  This module is the pure-JAX reference path
used by default on CPU; its fleet encoder streams clients through a
`lax.scan` accumulation so the (n, c, d) parity stack never materializes.

The `use_kernel` branches call the kernel ops at their `block="auto"`
default, so tiles come from the persisted autotuner cache
(`repro.tune`) — every consumer (CFL setup, the scheme strategies, the
sweep and serving engines) inherits tuned tiles with zero plumbing.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ClientParity:
    """Parity shards produced by one client."""

    x_parity: jax.Array  # (c, d)
    y_parity: jax.Array  # (c,)


def generator_matrix(key: jax.Array, c: int, ell: int,
                     kind: str = "normal", dtype=jnp.float32) -> jax.Array:
    """Random generator matrix G in R^{c x ell}."""
    if kind == "normal":
        return jax.random.normal(key, (c, ell), dtype=dtype)
    if kind == "bernoulli":
        # +-1 with prob 1/2 each: E[G^T G]/c = I still holds.
        return jax.random.rademacher(key, (c, ell), dtype=dtype)
    raise ValueError(f"unknown generator kind: {kind}")


@partial(jax.jit, static_argnames=("use_kernel",))
def encode_client(g: jax.Array, w: jax.Array, x: jax.Array, y: jax.Array,
                  use_kernel: bool = False) -> ClientParity:
    """(X~, y~) = (G W X, G W y) for one client.

    g: (c, ell)   private generator matrix
    w: (ell,)     diagonal of the weight matrix (Eq. 17)
    x: (ell, d)   local features
    y: (ell,)     local labels
    """
    if use_kernel:
        from repro.kernels.encode import ops as encode_ops
        xp = encode_ops.encode_parity(g, w, x)
    else:
        xp = g @ (w[:, None] * x)
    yp = g @ (w * y)
    return ClientParity(x_parity=xp, y_parity=yp)


def encode_fleet_streamed(keys: jax.Array, xs: jax.Array, ys: jax.Array,
                          weights: jax.Array, c: int, kind: str,
                          client_encode) -> tuple[jax.Array, jax.Array]:
    """Shared streaming core behind both fleet encoders.

    Clients are streamed through a `lax.scan` accumulation: one (c, ell)
    generator and one (c, d+1) accumulator live at a time — never the
    (n, c, ell) generator stack or the (n, c, d) parity stack (peak memory
    matters for large-c sweeps).  The labels ride along as an extra feature
    column so X~ and y~ come out of one fused `client_encode(g, w, x)` call
    per client (pure matmul here, Pallas kernel in `repro.kernels.encode`).
    """
    n, ell, d = xs.shape
    xa = jnp.concatenate([xs, ys[..., None]], axis=-1)  # (n, ell, d+1)

    def one(acc, inp):
        k, x, w = inp
        g = generator_matrix(k, c, ell, kind=kind, dtype=xs.dtype)
        return acc + client_encode(g, w, x), None

    acc, _ = jax.lax.scan(one, jnp.zeros((c, d + 1), dtype=xs.dtype),
                          (keys, xa, weights))
    return acc[:, :d], acc[:, d]


@partial(jax.jit, static_argnames=("c", "kind", "use_kernel"))
def encode_fleet(key: jax.Array, xs: jax.Array, ys: jax.Array,
                 weights: jax.Array, c: int, kind: str = "normal",
                 use_kernel: bool = False) -> tuple[jax.Array, jax.Array]:
    """Encode every client and return the composite parity dataset.

    xs: (n, ell, d) stacked client features (equal-size shards)
    ys: (n, ell)    stacked client labels
    weights: (n, ell) per-client weight diagonals
    Returns (X~ (c, d), y~ (c,)) = sums of per-client parities.

    Each client uses an independent fold of `key` — mirroring the protocol
    where G_i is drawn locally and never shared; both paths stream through
    `encode_fleet_streamed` and therefore draw identical generators.
    """
    keys = jax.random.split(key, xs.shape[0])
    if use_kernel:
        from repro.kernels.encode import ops as encode_ops
        return encode_fleet_streamed(keys, xs, ys, weights, c, kind,
                                     encode_ops.encode_parity)
    return encode_fleet_streamed(keys, xs, ys, weights, c, kind,
                                 lambda g, w, x: g @ (w[:, None] * x))
