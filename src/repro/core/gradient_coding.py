"""Gradient coding baseline (Tandon et al., ICML 2017 — the paper's ref [5]).

The comparison the paper positions CFL against: instead of coding the DATA
(CFL), gradient coding replicates data across clients and codes the
GRADIENTS.  With replication factor r, client i holds the data of clients
{i, i+1, ..., i+r-1 (mod n)} and uploads a fixed linear combination of
those partial gradients; the server can recover the exact full gradient
from ANY n - (r - 1) clients (tolerates s = r - 1 stragglers).

We implement the "fractional repetition" construction for the common case
r | n (clients split into n/r groups of r; each group member holds the
whole group's data and returns the group-sum; the server needs >= 1
returner per group), plus the wall-clock simulator hook used by the
`coded_vs_uncoded` ablation benchmark.

Key contrasts with CFL recorded in EXPERIMENTS.md §Ablation:
  * requires SHARING RAW DATA between clients (privacy cost CFL avoids);
  * each client's per-epoch compute is r x larger (it processes r shards);
  * exact recovery (no LLN approximation), but the epoch ends only when
    every group has a returner — the tail is clipped less aggressively
    than CFL's fixed deadline.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation
from repro.core.delay_model import sample_total

if TYPE_CHECKING:  # annotation-only: core must not import sim/api at runtime
    from repro.api.report import TraceReport
    from repro.sim.network import FleetSpec


@dataclasses.dataclass(frozen=True)
class GradCodingPlan:
    r: int                  # replication factor
    groups: np.ndarray      # (n,) group id of each client

    @property
    def tolerated_stragglers_per_group(self) -> int:
        return self.r - 1


def make_plan(n_clients: int, r: int) -> GradCodingPlan:
    if n_clients % r != 0:
        raise ValueError(f"fractional repetition needs r | n "
                         f"({r} does not divide {n_clients})")
    groups = np.repeat(np.arange(n_clients // r), r)
    return GradCodingPlan(r=r, groups=groups)


def group_gradients(xs: jax.Array, ys: jax.Array, beta: jax.Array,
                    plan: GradCodingPlan) -> jax.Array:
    """Each group's exact gradient over all its members' data: (n_groups, d)."""
    per_client = aggregation.client_partial_gradients(
        xs, ys, jnp.ones(xs.shape[:2], dtype=xs.dtype), beta)   # (n, d)
    n_groups = int(plan.groups.max()) + 1
    onehot = jax.nn.one_hot(jnp.asarray(plan.groups), n_groups,
                            dtype=xs.dtype)                      # (n, g)
    return jnp.einsum("nd,ng->gd", per_client, onehot)


def epoch_time(fleet: FleetSpec, plan: GradCodingPlan, ell: int,
               rng: np.random.Generator) -> float:
    """Wall time until every group has >= 1 returner.

    Each client processes r*ell points (it holds its whole group's data);
    its return time is sampled from the same §II-A delay model.  The epoch
    ends at max over groups of (min over group members)."""
    loads = np.full(fleet.edge.n, plan.r * ell)
    t_i = sample_total(fleet.edge, loads, rng)
    n_groups = int(plan.groups.max()) + 1
    per_group = np.full(n_groups, np.inf)
    for i, g in enumerate(plan.groups):
        per_group[g] = min(per_group[g], t_i[i])
    return float(per_group.max())


def run_gradient_coding(fleet: FleetSpec, xs, ys, beta_true, lr: float,
                        epochs: int, rng: np.random.Generator, r: int,
                        label: str = "gradcode") -> TraceReport:
    """Wall-clock simulation of fractional-repetition gradient coding.

    Deprecated shim: delegates to the scan-jitted
    `Session(strategy=GradientCodingFL(r=...))` (see API.md).
    """
    from repro.api import GradientCodingFL, Session, TrainData
    session = Session(strategy=GradientCodingFL(r=r, label=label),
                      fleet=fleet, lr=lr, epochs=epochs)
    return session.run(TrainData(xs=xs, ys=ys, beta_true=beta_true), rng=rng)
