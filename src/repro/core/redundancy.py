"""Two-step coding-redundancy optimization (paper §III-B, Eqs. 14-16).

Given delay parameters for n edge devices + the central server (device n+1),
find:

  * per-device systematic loads  ell*_i(t*)   (points each device processes),
  * the epoch deadline           t*,
  * the coding redundancy        c = ell*_{n+1}(t*)  (parity rows the server
    processes each epoch == row dimension of every client generator matrix).

t* = argmin_t { m <= E[R(t; ell*(t))] <= m + eps }  (Eq. 16); the aggregate
expected return E[R] = sum_i ell*_i(t) Pr{T_i <= t} is nondecreasing in t.

The module also supports a *fixed redundancy* mode used by the paper's Fig. 2
and Fig. 5 sweeps: given c (equivalently delta = c/m), cap the server load at
c and solve only for t*.

`solve_redundancy` is now a thin single-fleet shim over the vectorized grid
solver in `repro.plan.solver` — sweeps should call
`repro.plan.solve_redundancy_batched` directly and plan every configuration
in one jitted call.  The seed's scalar bisection stack survives verbatim in
`repro.plan.reference` for parity tests.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .delay_model import DeviceDelayParams


@dataclasses.dataclass(frozen=True)
class RedundancyPlan:
    """Output of the two-step optimization.

    loads:           (n,) systematic points each edge device processes/epoch
    c:               parity rows processed by the server per epoch
                     (coding redundancy)
    t_star:          epoch deadline in seconds
    p_return:        (n+1,) Pr{T_i <= t*} at the optimized loads (server last)
    expected_agg:    aggregate expected return at t* (should be ~ m)
    loads_cap_total: m = total edge-resident points (the delta denominator)
    """

    loads: np.ndarray
    c: int
    t_star: float
    p_return: np.ndarray
    expected_agg: float
    loads_cap_total: int

    @property
    def delta(self) -> float:
        """Redundancy metric delta = c / m over the edge devices' total data."""
        if self.loads_cap_total <= 0:
            raise ValueError(
                "delta is undefined: loads_cap_total must be the positive "
                f"total edge dataset size m, got {self.loads_cap_total}")
        return float(self.c) / float(self.loads_cap_total)


def _fleet_with_server(edge: DeviceDelayParams,
                       server: DeviceDelayParams) -> DeviceDelayParams:
    if server.n != 1:
        raise ValueError("server params must describe exactly one device")
    return DeviceDelayParams(
        np.concatenate([edge.a, server.a]),
        np.concatenate([edge.mu, server.mu]),
        np.concatenate([edge.tau, server.tau]),
        np.concatenate([edge.p, server.p]),
    )


def solve_redundancy(edge: DeviceDelayParams, server: DeviceDelayParams,
                     data_sizes: np.ndarray, c_up: int | None = None,
                     eps_rel: float = 1e-3, t_hi: float | None = None,
                     fixed_c: int | None = None) -> RedundancyPlan:
    """Run the two-step optimization for ONE fleet (shim over `repro.plan`).

    edge:       delay params of the n client devices
    server:     delay params of the central server (tau=0: no comm leg)
    data_sizes: (n,) local dataset sizes ell_i
    c_up:       max parity rows the server may receive (default: m)
    fixed_c:    if given, skip the redundancy search and use exactly this c
                (delta-sweep mode for Fig. 2 / Fig. 5); the server cap is
                fixed_c and the target return stays m.
    """
    from repro.plan.solver import PlanRequest, solve_redundancy_batched
    req = PlanRequest(edge=edge, server=server, data_sizes=data_sizes,
                      c_up=c_up, fixed_c=fixed_c, t_hi=t_hi)
    return solve_redundancy_batched([req], eps_rel=eps_rel)[0]


def systematic_weights(plan: RedundancyPlan, data_sizes: np.ndarray) -> list[np.ndarray]:
    """Per-device diagonal weight vectors (Eq. 17).

    For device i: the first ell*_i points (the ones it will process) get
    w = sqrt(Pr{T_i >= t*}); the remaining (punctured) points get w = 1.
    Returns a list of (ell_i,) arrays — devices may have unequal data sizes.
    """
    data_sizes = np.asarray(data_sizes, dtype=np.int64)
    out = []
    for i, ell_i in enumerate(data_sizes):
        w = np.ones(int(ell_i), dtype=np.float64)
        k = int(plan.loads[i])
        w[:k] = np.sqrt(max(0.0, 1.0 - plan.p_return[i]))
        out.append(w)
    return out
