"""Two-step coding-redundancy optimization (paper §III-B, Eqs. 14-16).

Given delay parameters for n edge devices + the central server (device n+1),
find:

  * per-device systematic loads  ell*_i(t*)   (points each device processes),
  * the epoch deadline           t*,
  * the coding redundancy        c = ell*_{n+1}(t*)  (parity rows the server
    processes each epoch == row dimension of every client generator matrix).

t* = argmin_t { m <= E[R(t; ell*(t))] <= m + eps }  (Eq. 16); the aggregate
expected return E[R] = sum_i ell*_i(t) Pr{T_i <= t} is nondecreasing in t, so
t* is found by bisection to a relative tolerance.

The module also supports a *fixed redundancy* mode used by the paper's Fig. 2
and Fig. 5 sweeps: given c (equivalently delta = c/m), cap the server load at
c and solve only for t*.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .delay_model import DeviceDelayParams
from .returns import expected_return, optimal_loads


@dataclasses.dataclass(frozen=True)
class RedundancyPlan:
    """Output of the two-step optimization.

    loads:        (n,) systematic points each edge device processes per epoch
    c:            parity rows processed by the server per epoch (coding redundancy)
    t_star:       epoch deadline in seconds
    p_return:     (n+1,) Pr{T_i <= t*} at the optimized loads (server last)
    expected_agg: aggregate expected return at t* (should be ~ m)
    """

    loads: np.ndarray
    c: int
    t_star: float
    p_return: np.ndarray
    expected_agg: float

    @property
    def delta(self) -> float:
        """Redundancy metric delta = c / m over the edge devices' total data."""
        return float(self.c) / float(self.loads_cap_total)

    loads_cap_total: int = 0


def _fleet_with_server(edge: DeviceDelayParams,
                       server: DeviceDelayParams) -> DeviceDelayParams:
    if server.n != 1:
        raise ValueError("server params must describe exactly one device")
    return DeviceDelayParams(
        np.concatenate([edge.a, server.a]),
        np.concatenate([edge.mu, server.mu]),
        np.concatenate([edge.tau, server.tau]),
        np.concatenate([edge.p, server.p]),
    )


def aggregate_return(fleet: DeviceDelayParams, caps: np.ndarray,
                     t: float) -> tuple[float, np.ndarray, np.ndarray]:
    """max_load E[R(t)] plus the argmax loads and per-device return probs."""
    loads, vals = optimal_loads(fleet, caps, t)
    from .delay_model import total_cdf
    probs = total_cdf(fleet, loads, t)
    return float(np.sum(vals)), loads, probs


def solve_redundancy(edge: DeviceDelayParams, server: DeviceDelayParams,
                     data_sizes: np.ndarray, c_up: int | None = None,
                     eps_rel: float = 1e-3, t_hi: float | None = None,
                     fixed_c: int | None = None) -> RedundancyPlan:
    """Run the two-step optimization.

    edge:       delay params of the n client devices
    server:     delay params of the central server (tau=0: no comm leg)
    data_sizes: (n,) local dataset sizes ell_i
    c_up:       max parity rows the server may receive (default: m)
    fixed_c:    if given, skip the redundancy search and use exactly this c
                (delta-sweep mode for Fig. 2 / Fig. 5); the server cap is
                fixed_c and the target return stays m.
    """
    data_sizes = np.asarray(data_sizes, dtype=np.int64)
    m = int(data_sizes.sum())
    if c_up is None:
        c_up = m
    server_cap = int(fixed_c) if fixed_c is not None else int(c_up)
    fleet = _fleet_with_server(edge, server)
    caps = np.concatenate([data_sizes, [server_cap]])

    # --- bracket t*: find t_hi with E[R] >= m ------------------------------
    if t_hi is None:
        t_hi = float(np.max(fleet.mean_total(caps))) + 1.0
    t_lo = 0.0
    agg, loads, probs = aggregate_return(fleet, caps, t_hi)
    guard = 0
    while agg < m:
        t_hi *= 2.0
        agg, loads, probs = aggregate_return(fleet, caps, t_hi)
        guard += 1
        if guard > 60:
            raise RuntimeError(
                "cannot reach aggregate expected return m: the fleet cannot "
                f"return {m} points in finite time (best {agg:.1f})")

    # --- bisection on t (E[R] is nondecreasing in t) ------------------------
    for _ in range(64):
        t_mid = 0.5 * (t_lo + t_hi)
        agg_mid, loads_mid, probs_mid = aggregate_return(fleet, caps, t_mid)
        if agg_mid >= m:
            t_hi, agg, loads, probs = t_mid, agg_mid, loads_mid, probs_mid
        else:
            t_lo = t_mid
        if (t_hi - t_lo) <= eps_rel * max(t_hi, 1e-12):
            break

    c = int(loads[-1]) if fixed_c is None else int(fixed_c)
    return RedundancyPlan(
        loads=loads[:-1].astype(np.int64),
        c=c,
        t_star=float(t_hi),
        p_return=probs,
        expected_agg=float(agg),
        loads_cap_total=m,
    )


def systematic_weights(plan: RedundancyPlan, data_sizes: np.ndarray) -> list[np.ndarray]:
    """Per-device diagonal weight vectors (Eq. 17).

    For device i: the first ell*_i points (the ones it will process) get
    w = sqrt(Pr{T_i >= t*}); the remaining (punctured) points get w = 1.
    Returns a list of (ell_i,) arrays — devices may have unequal data sizes.
    """
    data_sizes = np.asarray(data_sizes, dtype=np.int64)
    out = []
    for i, ell_i in enumerate(data_sizes):
        w = np.ones(int(ell_i), dtype=np.float64)
        k = int(plan.loads[i])
        w[:k] = np.sqrt(max(0.0, 1.0 - plan.p_return[i]))
        out.append(w)
    return out
