"""CodedFedL: coded federated learning for non-linear regression /
classification in multi-access edge computing (arXiv:2007.03273,
reproduced on the source paper's substrate).

Two ideas ride on the CFL machinery:

  1. **Kernel embedding.**  Each client pushes its raw inputs through a
     shared random-Fourier-feature map (`repro.data.rff_map`) and runs
     LINEAR regression in the `d_feat`-wide feature space — the coded
     parity construction, Eq.-17 weighting, and deadline-`t*` epochs all
     apply unchanged because the learning problem is still least squares.
     `d_feat=None` skips the map entirely and the strategy degenerates to
     `CodedFL` bit-for-bit (same plan, same encoding draws, same arrival
     stream).

  2. **MEC delay model.**  Uplinks traverse a multi-access edge network,
     so the communication leg is a shifted exponential (shift `2 tau`,
     rate `(1-p)/(2 tau p)` — same minimum and mean as the base
     geometric-retransmission model) rather than a retransmission
     mixture.  The load allocation solves on `repro.plan`'s grid solver
     with `PlanRequest.mec_comm=True`: expected returns use the
     closed-form two-exponential convolution CDF, and the Eq.-17 weights
     see the same probabilities via `core.delay_model.mec_total_cdf`.
     Wall-clock epochs sample from `sample_total_mec`.

The classification recipe (paper §V): labels from
`repro.data.classification_dataset`, one-vs-rest ±1 targets via
`repro.data.one_vs_rest_targets`, `TrainData.beta_true` a feature-space
reference head so the NMSE trace measures distance to the kernel
regressor (the engine trains in `data.model_dim = d_feat` dimensions
while `data.xs` keeps the raw width `d`).

Parity oracle: `repro.plan.reference_schemes.solve_codedfedl_reference`.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, ClassVar, Dict, Hashable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.strategy import EpochSchedule, TrainData
from repro.core import aggregation, cfl
from repro.core.delay_model import sample_total, sample_total_mec
from repro.core.redundancy import RedundancyPlan
from repro.data.rff import rff_map

from .base import CodedSchemeState

if TYPE_CHECKING:  # annotation-only: keeps schemes free of sim imports
    from repro.serving.scheduler import ConvergenceCriterion
    from repro.sim.network import FleetSpec

# fold_in tweak for deriving the feature-map key from the strategy key;
# far outside encode_fleet's split(key, n) child range for any real fleet
_RFF_FOLD = 0x52FF


@dataclasses.dataclass
class CodedFedLState(CodedSchemeState):
    """`CodedSchemeState` + the client-resident feature tensor.

    features: (n, ell, d_feat) RFF embeddings (aliases `data.xs` when the
    feature map is the identity) — the matrices the engine trains on.
    """

    features: jax.Array


@dataclasses.dataclass(frozen=True)
class CodedFedL:
    """CodedFedL (arXiv:2007.03273): RFF kernel regression + MEC delays.

    key:        PRNG key for the one-time private generator matrices
    d_feat:     random-Fourier-feature width (even, >= 2); None = identity
                map, degenerating to `CodedFL` bit-for-bit
    rff_key:    PRNG key for the shared feature map (derived from `key`
                when omitted — all clients must draw the SAME map)
    rff_gamma:  Gaussian-kernel bandwidth of the feature map
    mec_comm:   use the MEC shifted-exponential communication model for
                the load solve and epoch sampling; None = `d_feat` set
    fixed_c / c_up / include_upload_delay / server_always_returns /
    use_kernel / generator / redundancy_plan: as in `CodedFL`
    """

    key: jax.Array
    d_feat: Optional[int] = None
    rff_key: Optional[jax.Array] = None
    rff_gamma: float = 1.0
    mec_comm: Optional[bool] = None
    fixed_c: Optional[int] = None
    c_up: Optional[int] = None
    include_upload_delay: bool = True
    server_always_returns: bool = False
    use_kernel: bool = False
    generator: str = "normal"
    label: str = "cfedl"
    redundancy_plan: Optional[RedundancyPlan] = None
    grad_path: str = aggregation.FUSED

    def _grad_path(self) -> str:
        return aggregation.resolve_grad_path(self.grad_path,
                                             self.use_kernel)

    # knobs that only shape the plan, host-side sampling, or operand
    # VALUES (rff_gamma moves feature values, never shapes); d_feat stays
    # keyed — it sets the operand widths the engine is traced at
    engine_value_fields: ClassVar[frozenset] = frozenset(
        {"fixed_c", "c_up", "include_upload_delay", "server_always_returns",
         "generator", "mec_comm", "rff_gamma"})
    # y and row ids are pure functions of the TrainData; x is NOT — it
    # depends on the per-strategy feature map — so it stays per-lane
    data_device_keys: ClassVar[frozenset] = frozenset({"y", "row_client"})

    def __post_init__(self):
        if self.d_feat is not None and (self.d_feat < 2 or self.d_feat % 2):
            raise ValueError(
                f"d_feat must be an even integer >= 2, got {self.d_feat}")

    # -- feature map --------------------------------------------------------

    def _mec(self) -> bool:
        if self.mec_comm is None:
            return self.d_feat is not None
        return bool(self.mec_comm)

    def _feature_key(self) -> jax.Array:
        if self.rff_key is not None:
            return self.rff_key
        return jax.random.fold_in(self.key, _RFF_FOLD)

    def features(self, data: TrainData) -> jax.Array:
        """The (n, ell, d_feat) training matrices: RFF embeddings of the
        raw inputs, or `data.xs` itself for the identity map."""
        if self.d_feat is None:
            return data.xs
        return rff_map(data.xs, self.d_feat, self._feature_key(),
                       gamma=self.rff_gamma)

    # -- planning (batched through repro.plan) ------------------------------

    def plan_request(self, fleet: "FleetSpec", data: TrainData):
        """The MEC redundancy problem `plan` would solve."""
        from repro.plan import PlanRequest
        return PlanRequest(edge=fleet.edge, server=fleet.server,
                           data_sizes=np.full(data.n, data.ell,
                                              dtype=np.int64),
                           c_up=self.c_up, fixed_c=self.fixed_c,
                           mec_comm=self._mec())

    def plan_with(self, fleet: "FleetSpec", data: TrainData,
                  plan: Optional[RedundancyPlan]) -> CodedFedLState:
        phi = self.features(data)
        st = cfl.setup(self.key, phi, data.ys, fleet.edge, fleet.server,
                       fixed_c=self.fixed_c, c_up=self.c_up,
                       generator=self.generator, use_kernel=self.use_kernel,
                       plan=plan if plan is not None
                       else self._solve(fleet, data))
        return CodedFedLState(plan=st.plan, load_mask=st.load_mask,
                              x_parity=st.x_parity, y_parity=st.y_parity,
                              edge=fleet.edge, server=fleet.server,
                              features=phi)

    def _solve(self, fleet: "FleetSpec",
               data: TrainData) -> RedundancyPlan:
        from repro.plan import solve_redundancy_batched
        return solve_redundancy_batched([self.plan_request(fleet, data)])[0]

    def plan(self, fleet: "FleetSpec", data: TrainData) -> CodedFedLState:
        return self.plan_with(fleet, data, self.redundancy_plan)

    # -- epoch sampling -----------------------------------------------------

    def sample_epochs(self, state: CodedFedLState, fleet: "FleetSpec",
                      epochs: int, rng: np.random.Generator) -> EpochSchedule:
        plan = state.plan
        n = fleet.edge.n
        t_star = plan.t_star
        # MEC epochs draw from the shifted-exponential model the solve
        # optimized; the base sampler keeps the degenerate path bit-equal
        # to CodedFL's arrival stream
        sampler = sample_total_mec if self._mec() else sample_total

        # One-time parity upload, drawn FIRST — the shared helper preserves
        # the legacy run_cfl generator order
        upload_time = cfl.sample_parity_upload_time(state, fleet, rng)

        received = np.empty((epochs, n), dtype=np.float32)
        parity_ok = np.empty(epochs, dtype=np.float32)
        for e in range(epochs):
            t_i = sampler(fleet.edge, plan.loads, rng)
            received[e] = (t_i <= t_star) & (plan.loads > 0)
            if self.server_always_returns or state.c == 0:
                parity_ok[e] = 1.0
            else:
                t_srv = sampler(fleet.server, np.array([state.c]), rng)[0]
                parity_ok[e] = float(t_srv <= t_star)

        return EpochSchedule(
            durations=np.full(epochs, t_star),
            arrivals={"received": received, "parity_ok": parity_ok},
            setup_time=upload_time,
            t0=upload_time if self.include_upload_delay else 0.0)

    # -- engine hooks -------------------------------------------------------

    def device_state(self, state: CodedFedLState,
                     data: TrainData) -> Dict[str, jax.Array]:
        d_feat = int(state.features.shape[-1])
        if self._grad_path() == aggregation.FUSED:
            # packed layout over the FEATURE matrices: kernel-regression
            # sessions ride the same fused path as raw CFL.  The reshape
            # is memoized on the state so `fused_coded_device_state`'s
            # identity-keyed operand cache hits on repeated runs.
            x_flat = getattr(state, "_features_flat", None)
            if x_flat is None:
                x_flat = state.features.reshape(data.m, d_feat)
                state._features_flat = x_flat
            return cfl.fused_coded_device_state(state, data, x=x_flat)
        # `cfl.coded_device_state` with x swapped for the feature tensor
        # (identical arrays when the map is the identity)
        n, ell = data.n, data.ell
        row_client = jnp.repeat(jnp.arange(n, dtype=jnp.int32), ell)
        return {"x": state.features.reshape(data.m, d_feat),
                "y": data.ys.reshape(data.m),
                "w_sys": state.load_mask.reshape(data.m),
                "row_client": row_client,
                "x_parity": state.x_parity,
                "y_parity": state.y_parity}

    def round_contributions(self, state, dev, beta, arrivals):
        if self._grad_path() == aggregation.FUSED:
            x, y, w0, client = aggregation.fused_sys_block(dev)
            w = w0 * arrivals["received"][client]
            if state.c == 0:
                return aggregation.round_gradient(
                    x, y, beta, w=w, path=aggregation.FUSED)
            return aggregation.fused_coded_gradient(
                dev, w, arrivals["parity_ok"], beta)
        resid = dev["x"] @ beta - dev["y"]
        w = dev["w_sys"] * arrivals["received"][dev["row_client"]]
        g_sys = (resid * w) @ dev["x"]
        if state.c == 0:  # delta = 0 degenerates to uncoded FL w/ deadline
            return g_sys
        g_par = aggregation.parity_gradient(
            dev["x_parity"], dev["y_parity"], beta,
            use_kernel=self.use_kernel)
        return g_sys + arrivals["parity_ok"] * g_par

    def tiered_contributions(self, state, dev, beta, arrivals, tier_masks):
        # systematic feature-space partials reduce per edge tier; the
        # parity gradient is server-resident and rides as the server term
        if self._grad_path() == aggregation.FUSED:
            x, y, w0, client = aggregation.fused_sys_block(dev)
            masks = aggregation.fused_tier_masks(dev, tier_masks)
            w = w0 * arrivals["received"][client]
            partials = aggregation.tiered_round_gradient(
                x, y, beta, w, masks, path=aggregation.FUSED)
            if state.c == 0:
                return partials, None
            g_par = aggregation.gram_parity_gradient(
                dev["par_gram"], dev["par_gramy"], beta, dev["par_c"])
            return partials, arrivals["parity_ok"] * g_par
        resid = dev["x"] @ beta - dev["y"]
        w = dev["w_sys"] * arrivals["received"][dev["row_client"]]
        partials = aggregation.tier_reduce(resid * w, dev["x"], tier_masks)
        if state.c == 0:
            return partials, None
        g_par = aggregation.parity_gradient(
            dev["x_parity"], dev["y_parity"], beta,
            use_kernel=self.use_kernel)
        return partials, arrivals["parity_ok"] * g_par

    def uplink_bits(self, state: CodedFedLState, fleet: "FleetSpec",
                    epochs: int) -> float:
        # parity shards are (c, d_feat + 1): encoding happens in feature
        # space, so the one-time upload is priced at the feature width
        return cfl.coded_uplink_bits(state, fleet, epochs)

    def engine_key(self, state: CodedFedLState) -> Hashable:
        return (state.c > 0, self.use_kernel, self.d_feat,
                self._grad_path())

    def sweep_inputs(self, state: CodedFedLState, fleet: "FleetSpec",
                     epochs: int, rng: np.random.Generator) -> EpochSchedule:
        """One sweep lane's inputs: `received (epochs, n)` and
        `parity_ok (epochs,)` stack across lanes sharing the fleet size;
        draws are exactly `sample_epochs` (upload first, then the
        per-epoch edge/server stream), so identity-map lanes stay
        bit-equal to `CodedFL` lanes."""
        return self.sample_epochs(state, fleet, epochs, rng)

    def serve_convergence(self, state: CodedFedLState,
                          criterion: "ConvergenceCriterion"):
        """Kernel-regression NMSE plateaus at the RFF approximation floor
        rather than reaching an absolute target, so a serving lane with
        no plateau clause would burn its whole epoch budget; arm a tight
        relative-plateau exit when the user left it off."""
        if self.d_feat is None or criterion.rel_delta is not None:
            return criterion
        return dataclasses.replace(criterion, rel_delta=1e-4)

    def report_extras(self, state: CodedFedLState) -> Dict[str, float]:
        return {"d_feat": float(self.d_feat or 0),
                "rff_gamma": float(self.rff_gamma),
                "mec_comm": float(self._mec()),
                "t_star": float(state.plan.t_star)}
