"""Low-latency coded federated learning over wireless edge networks
(arXiv:2011.06223, reproduced on the source paper's substrate).

The scenario: heterogeneous wireless links — per-device rates tau_i AND
erasure probabilities p_i differ (`sim.network.wireless_fleet`) — and
devices upload PARTIAL work: an assignment of ell points goes out in
`chunks` incremental uploads, chunk q covering the first q*ell/chunks
points, so a straggler that finishes only half its load still contributes
half a gradient instead of nothing.

The joint load-allocation + deadline solve runs on `repro.plan`'s grid
solver with `edge_chunks = chunks`: a device's expected return is

    E[R_i(t; ell)] = (ell/Q) * sum_q Pr{chunk q done by t}

(the partial-return objective), evaluated on the same (t_grid, n, L)
tensor — Q shifted copies of the base CDF grid — so a whole
link-heterogeneity sweep still solves in ONE jitted call.  Over-assignment
stays costly because the stochastic compute rate is mu/ell (the
memory-access slowdown scales with the full assignment), which is what
makes the allocation a real argmax rather than "assign everything".

Eq. 17 generalizes per chunk: the systematic rows of chunk q are encoded
with weight sqrt(1 - Pr{chunk q done by t*}), so parity compensates
exactly the expected shortfall of each chunk.

Parity oracle: `repro.plan.reference_schemes.solve_lowlatency_reference`.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, ClassVar, Dict, Hashable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.strategy import EpochSchedule, TrainData
from repro.core import aggregation, encoding
from repro.core.delay_model import partial_cdf, sample_total
from repro.core.redundancy import RedundancyPlan

from .base import (CodedSchemeState, coded_device_state, coded_uplink_bits,
                   fused_coded_device_state, sample_parity_upload_time)

if TYPE_CHECKING:  # annotation-only: keeps schemes free of sim imports
    from repro.sim.network import FleetSpec


def row_chunks(loads: np.ndarray, ell: int, chunks: int) -> np.ndarray:
    """(n, ell) chunk index of every row: row j < ell_i belongs to chunk
    floor(j * Q / ell_i); rows at or beyond the load get `chunks` (a chunk
    id that never completes, so they can only be covered by parity)."""
    j = np.arange(ell)[None, :]                       # (1, ell)
    ell_i = np.maximum(loads[:, None], 1)             # (n, 1)
    q = (j * chunks) // ell_i
    return np.where(j < loads[:, None], q, chunks).astype(np.int32)


@dataclasses.dataclass
class LowLatencyState(CodedSchemeState):
    """`CodedSchemeState` + per-chunk completion probabilities at t*."""

    chunk_probs: np.ndarray   # (n, Q) Pr{chunk q done by t*}
    row_chunk: np.ndarray     # (n, ell) chunk id per row (Q = punctured)


@dataclasses.dataclass(frozen=True)
class LowLatencyCFL:
    """Partial-return CFL for heterogeneous wireless fleets.

    key:    PRNG key for the one-time private generator matrices
    chunks: incremental uploads per device per epoch (1 = all-or-nothing,
            which degenerates to `CodedFL` bit-for-bit)
    fixed_c / c_up / include_upload_delay / generator: as in `CodedFL`
    redundancy_plan: pre-solved plan (one element of a batched sweep)
    """

    key: jax.Array
    chunks: int = 8
    fixed_c: Optional[int] = None
    c_up: Optional[int] = None
    include_upload_delay: bool = True
    generator: str = "normal"
    label: str = "lowlat"
    redundancy_plan: Optional[RedundancyPlan] = None
    grad_path: str = aggregation.FUSED

    def _grad_path(self) -> str:
        return aggregation.resolve_grad_path(self.grad_path)

    # all knobs (chunks included) reach the traced engine only through
    # operand values — row_chunk ids, chunks_done counts, the plan — so
    # a whole chunking/heterogeneity sweep shares one compiled engine
    engine_value_fields: ClassVar[frozenset] = frozenset(
        {"chunks", "fixed_c", "c_up", "include_upload_delay", "generator"})
    # data-only operands (one replicated copy per sweep); row_chunk is
    # plan-derived and stays per-lane
    data_device_keys: ClassVar[frozenset] = frozenset(
        {"x", "y", "row_client"})

    def __post_init__(self):
        if self.chunks < 1:
            raise ValueError(f"chunks must be >= 1, got {self.chunks}")

    # -- planning (batched through repro.plan) ------------------------------

    def plan_request(self, fleet: "FleetSpec", data: TrainData):
        """The partial-return redundancy problem `plan` would solve."""
        from repro.plan import PlanRequest
        return PlanRequest(edge=fleet.edge, server=fleet.server,
                           data_sizes=np.full(data.n, data.ell,
                                              dtype=np.int64),
                           c_up=self.c_up, fixed_c=self.fixed_c,
                           edge_chunks=self.chunks)

    def plan_with(self, fleet: "FleetSpec", data: TrainData,
                  plan: Optional[RedundancyPlan]) -> LowLatencyState:
        if plan is None:
            from repro.plan import solve_redundancy_batched
            plan = solve_redundancy_batched(
                [self.plan_request(fleet, data)])[0]

        n, ell = data.n, data.ell
        q = self.chunks
        # per-chunk Eq. 17: chunk-q rows weighted sqrt(1 - Pr{chunk done});
        # punctured rows (beyond the load) keep weight 1
        probs = partial_cdf(fleet.edge, plan.loads, plan.t_star, q)  # (n, Q)
        rc = row_chunks(plan.loads, ell, q)                       # (n, ell)
        # punctured rows carry chunk id Q, which indexes the appended
        # zero-probability column and therefore gets weight sqrt(1-0) = 1
        probs_ext = np.concatenate([probs, np.zeros((n, 1))], axis=1)
        w_np = np.sqrt(np.maximum(
            0.0, 1.0 - np.take_along_axis(probs_ext, rc, axis=1)))
        weights = jnp.asarray(w_np, dtype=data.xs.dtype)
        load_mask = jnp.asarray(
            np.arange(ell)[None, :] < plan.loads[:, None], dtype=data.xs.dtype)

        if plan.c > 0:
            x_par, y_par = encoding.encode_fleet(
                self.key, data.xs, data.ys, weights, plan.c,
                kind=self.generator)
        else:  # delta = 0 degenerates to uncoded FL with deadline t*
            x_par = jnp.zeros((0, data.d), dtype=data.xs.dtype)
            y_par = jnp.zeros((0,), dtype=data.xs.dtype)

        return LowLatencyState(plan=plan, load_mask=load_mask,
                               x_parity=x_par, y_parity=y_par,
                               edge=fleet.edge, server=fleet.server,
                               chunk_probs=probs, row_chunk=rc)

    def plan(self, fleet: "FleetSpec", data: TrainData) -> LowLatencyState:
        return self.plan_with(fleet, data, self.redundancy_plan)

    # -- epoch sampling -----------------------------------------------------

    def sample_epochs(self, state: LowLatencyState, fleet: "FleetSpec",
                      epochs: int, rng: np.random.Generator) -> EpochSchedule:
        plan = state.plan
        n = fleet.edge.n
        t_star = plan.t_star
        q = self.chunks
        upload_time = sample_parity_upload_time(state, fleet, rng)

        edge = fleet.edge
        loads = plan.loads.astype(np.float64)
        shift = loads * edge.a                               # (n,)
        with np.errstate(divide="ignore", invalid="ignore"):
            scale = np.where(loads > 0, loads / edge.mu, 0.0)
        comm = edge.tau > 0
        p = np.where(comm, edge.p, 0.0)
        fracs = np.arange(1, q + 1, dtype=np.float64) / q     # (Q,)

        chunks_done = np.empty((epochs, n), dtype=np.float32)
        parity_ok = np.ones(epochs, dtype=np.float32)
        for e in range(epochs):
            # component draws mirror `sample_total`'s internal order
            # (exponential, geometric down, geometric up) so chunks = 1
            # reproduces CodedFL's arrival stream exactly
            t_stoch = rng.exponential(1.0, size=n) * scale
            n_d = rng.geometric(1.0 - p, size=n)
            n_u = rng.geometric(1.0 - p, size=n)
            t_comm = np.where(comm, (n_d + n_u) * edge.tau, 0.0)
            t_q = (fracs[None, :] * shift[:, None] + t_stoch[:, None]) \
                + t_comm[:, None]                             # (n, Q)
            chunks_done[e] = np.where(
                loads > 0, np.sum(t_q <= t_star, axis=1), 0.0)
            if state.c > 0:
                t_srv = sample_total(fleet.server, np.array([state.c]),
                                     rng)[0]
                parity_ok[e] = float(t_srv <= t_star)

        return EpochSchedule(
            durations=np.full(epochs, t_star),
            arrivals={"chunks_done": chunks_done, "parity_ok": parity_ok},
            setup_time=upload_time,
            t0=upload_time if self.include_upload_delay else 0.0)

    # -- engine hooks -------------------------------------------------------

    def device_state(self, state: LowLatencyState,
                     data: TrainData) -> Dict[str, jax.Array]:
        if self._grad_path() == aggregation.FUSED:
            # copy: the packed dict is memoized on the state and must not
            # absorb per-strategy extras
            dev = dict(fused_coded_device_state(state, data))
            rc = state.row_chunk.reshape(data.m)
            if "sys_rows" in dev:
                rc = rc[np.asarray(dev["sys_rows"])]
            dev["sys_chunk"] = jnp.asarray(rc)
            return dev
        dev = coded_device_state(state, data)
        dev["row_chunk"] = jnp.asarray(state.row_chunk.reshape(data.m))
        return dev

    def _fused_weights(self, dev, arrivals):
        # a row contributes iff its chunk completed by t*
        x, _, w0, client = aggregation.fused_sys_block(dev)
        done = arrivals["chunks_done"][client]
        gate = (dev["sys_chunk"] < done).astype(x.dtype)
        return w0 * gate

    def round_contributions(self, state, dev, beta, arrivals):
        if self._grad_path() == aggregation.FUSED:
            x, y, _, _ = aggregation.fused_sys_block(dev)
            w = self._fused_weights(dev, arrivals)
            if state.c == 0:
                return aggregation.round_gradient(
                    x, y, beta, w=w, path=aggregation.FUSED)
            return aggregation.fused_coded_gradient(
                dev, w, arrivals["parity_ok"], beta)
        resid = dev["x"] @ beta - dev["y"]
        # a row contributes iff its chunk completed by t*
        done = arrivals["chunks_done"][dev["row_client"]]
        w = dev["w_sys"] * (dev["row_chunk"] < done).astype(resid.dtype)
        g_sys = (resid * w) @ dev["x"]
        if state.c == 0:
            return g_sys
        g_par = aggregation.parity_gradient(
            dev["x_parity"], dev["y_parity"], beta)
        return g_sys + arrivals["parity_ok"] * g_par

    def tiered_contributions(self, state, dev, beta, arrivals, tier_masks):
        # chunk-gated systematic partials reduce per edge tier; parity is
        # server-resident and rides as the server-side term
        if self._grad_path() == aggregation.FUSED:
            x, y, _, _ = aggregation.fused_sys_block(dev)
            masks = aggregation.fused_tier_masks(dev, tier_masks)
            w = self._fused_weights(dev, arrivals)
            partials = aggregation.tiered_round_gradient(
                x, y, beta, w, masks, path=aggregation.FUSED)
            if state.c == 0:
                return partials, None
            g_par = aggregation.gram_parity_gradient(
                dev["par_gram"], dev["par_gramy"], beta, dev["par_c"])
            return partials, arrivals["parity_ok"] * g_par
        resid = dev["x"] @ beta - dev["y"]
        done = arrivals["chunks_done"][dev["row_client"]]
        w = dev["w_sys"] * (dev["row_chunk"] < done).astype(resid.dtype)
        partials = aggregation.tier_reduce(resid * w, dev["x"], tier_masks)
        if state.c == 0:
            return partials, None
        g_par = aggregation.parity_gradient(
            dev["x_parity"], dev["y_parity"], beta)
        return partials, arrivals["parity_ok"] * g_par

    def uplink_bits(self, state: LowLatencyState, fleet: "FleetSpec",
                    epochs: int) -> float:
        # Q incremental chunk packets + 1 completion packet per device-epoch
        return coded_uplink_bits(state, fleet, epochs,
                                 packets_per_epoch=self.chunks + 1)

    def engine_key(self, state: LowLatencyState) -> Hashable:
        return (state.c > 0,)

    def sweep_inputs(self, state: LowLatencyState, fleet: "FleetSpec",
                     epochs: int, rng: np.random.Generator) -> EpochSchedule:
        """One sweep lane's inputs: `chunks_done (epochs, n)` (per-device
        completed-chunk counts) and `parity_ok (epochs,)` stack across
        lanes sharing the fleet size and parity budget; draws are exactly
        `sample_epochs` (component draws mirror `sample_total`'s order, so
        chunks=1 lanes remain bit-equal to CodedFL lanes)."""
        return self.sample_epochs(state, fleet, epochs, rng)

    def report_extras(self, state: LowLatencyState) -> Dict[str, float]:
        return {"chunks": float(self.chunks),
                "mean_chunk_prob": float(np.mean(state.chunk_probs)),
                "t_star": float(state.plan.t_star)}
