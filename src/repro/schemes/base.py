"""Shared machinery for the coded follow-up schemes (`repro.schemes`).

Both follow-up strategies are CFL-family protocols: a one-time redundancy
solve (through `repro.plan`'s batched grid solver), a one-time parity
upload, then deadline-`t*` epochs combining systematic and parity
gradients.  The accounting they share with `CodedFL` — parity-upload bits,
upload-time sampling, uplink totals — lives in ONE place, `repro.core.cfl`
(re-exported here), so the bit-for-bit degenerate-equivalence guarantees
cannot drift; this module adds only the shared state dataclass.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.cfl import (coded_device_state, coded_uplink_bits,
                            fused_coded_device_state, parity_upload_bits,
                            sample_parity_upload_time)
from repro.core.delay_model import DeviceDelayParams
from repro.core.redundancy import RedundancyPlan

__all__ = ["CodedSchemeState", "coded_device_state", "coded_uplink_bits",
           "fused_coded_device_state", "sample_parity_upload_time"]


@dataclasses.dataclass
class CodedSchemeState:
    """Protocol state shared by the coded follow-up schemes after `plan`.

    plan:      the redundancy solve's output (loads, c, t*, return probs)
    load_mask: (n, ell) 1.0 on each client's systematic points
    x_parity:  (c, d) composite parity features resident at the server
    y_parity:  (c,)   composite parity labels
    """

    plan: RedundancyPlan
    load_mask: jax.Array
    x_parity: jax.Array
    y_parity: jax.Array
    edge: DeviceDelayParams
    server: DeviceDelayParams

    @property
    def c(self) -> int:
        return int(self.x_parity.shape[0])

    def parity_upload_bits(self, bits_per_value: int = 32,
                           header_overhead: float = 0.10) -> np.ndarray:
        """Bits each client uploads for its parity shard (one-time cost)."""
        return parity_upload_bits(self.edge.n, self.c,
                                  int(self.x_parity.shape[1]),
                                  bits_per_value, header_overhead)
