"""Follow-up coding schemes on the Strategy/Session substrate (see API.md
"The schemes layer").

Every scheme here is a `repro.api.Strategy` dataclass whose load-allocation
solve is an objective evaluator in `repro.plan`'s batched grid solver — no
new epoch loops, no new host solvers:

  * `StochasticCodedFL` — stochastic CFL with calibrated privacy noise on
    the shared coded dataset and per-round parity subsampling
    (arXiv:2201.10092; `PlanRequest.srv_weight`).
  * `LowLatencyCFL` — partial-return CFL for heterogeneous wireless
    fleets, chunked uploads + joint load/deadline solve
    (arXiv:2011.06223; `PlanRequest.edge_chunks`).
  * `CodedFedL` — random-Fourier-feature kernel regression over the coded
    linear machinery, with the multi-access-edge shifted-exponential
    communication model (arXiv:2007.03273; `PlanRequest.mec_comm`).

Construct them directly or via `repro.api.make_strategy("stochastic", ...)`
/ `make_strategy("lowlatency", ...)` / `make_strategy("codedfedl", ...)`.
"""
from .base import CodedSchemeState
from .codedfedl import CodedFedL, CodedFedLState
from .lowlatency import LowLatencyCFL, LowLatencyState
from .stochastic import StochasticCodedFL, StochasticState

__all__ = [
    "CodedSchemeState",
    "StochasticCodedFL", "StochasticState",
    "LowLatencyCFL", "LowLatencyState",
    "CodedFedL", "CodedFedLState",
]
