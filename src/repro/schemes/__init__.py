"""Follow-up coding schemes on the Strategy/Session substrate (see API.md
"The schemes layer").

Every scheme here is a `repro.api.Strategy` dataclass whose load-allocation
solve is an objective evaluator in `repro.plan`'s batched grid solver — no
new epoch loops, no new host solvers:

  * `StochasticCodedFL` — stochastic CFL with calibrated privacy noise on
    the shared coded dataset and per-round parity subsampling
    (arXiv:2201.10092; `PlanRequest.srv_weight`).
  * `LowLatencyCFL` — partial-return CFL for heterogeneous wireless
    fleets, chunked uploads + joint load/deadline solve
    (arXiv:2011.06223; `PlanRequest.edge_chunks`).

Construct them directly or via `repro.api.make_strategy("stochastic", ...)`
/ `make_strategy("lowlatency", ...)`.
"""
from .base import CodedSchemeState
from .lowlatency import LowLatencyCFL, LowLatencyState
from .stochastic import StochasticCodedFL, StochasticState

__all__ = [
    "CodedSchemeState",
    "StochasticCodedFL", "StochasticState",
    "LowLatencyCFL", "LowLatencyState",
]
