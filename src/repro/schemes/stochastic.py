"""Stochastic Coded Federated Learning (arXiv:2201.10092, reproduced on the
source paper's linear-regression + §II-A delay substrate).

SCFL's two departures from the base CFL protocol:

  1. **Privacy noise on the shared coded dataset.**  Each client perturbs
     its parity shard before the one-time upload, so the server-resident
     composite parity is (X~ + N_x, y~ + n_y) with iid Gaussian noise
     calibrated to the coded data's RMS (`noise_multiplier` = noise std /
     coded-entry RMS, i.e. parity SNR ~ 1/noise_multiplier).  Noise buys
     privacy and costs accuracy — the knob is surfaced in
     `TraceReport.extras` via `report_extras`.
  2. **Per-round stochastic parity.**  Each epoch the server samples a
     Bernoulli(`sample_frac`) subset of parity rows and computes the
     (inverse-probability-weighted, hence unbiased) parity gradient on
     that subset only, cutting its per-round compute to rho*c rows.

Both effects discount what one parity row is worth to the aggregate
expected return, so the load-allocation solve runs on `repro.plan`'s grid
solver with `srv_weight = sample_frac / (1 + noise_multiplier^2)` — the
effective-rows factor (a row used with probability rho whose gradient
carries noise power sigma^2 relative to signal contributes rho/(1+sigma^2)
clean rows' worth of information).  Whole noise-level sweeps batch into
ONE jitted solve via `repro.api.plan_sweep` (the requests differ only in
the per-request `(B,)` weight input).

Note a deliberate asymmetry in the plan: `srv_weight` discounts only the
VALUE of the server's rows; the deadline feasibility term still evaluates
Pr{T_srv <= t} at the full parity-row load.  Per-round Bernoulli sampling
can draw close to all c rows, so planning the deadline for the full
budget keeps every realized round feasible — the simulated server
(`sample_epochs`) then draws its completion time at the round's actual
sampled row count, which only lands MORE often than the plan assumed
(conservative, never optimistic).

Parity oracle: `repro.plan.reference_schemes.solve_stochastic_reference` /
`stochastic_noise_scale`.

**Privacy accounting** (`repro.privacy`): the noise knob has quantitative
(epsilon, delta)-DP semantics.  Construct by budget —
`StochasticCodedFL(key=..., epsilon_target=2.0, delta=1e-5, rounds=600)`
— and the smallest adequate `noise_multiplier` is calibrated through the
batched Rényi-DP solve (`repro.privacy.calibrate_noise`); or set
`noise_multiplier` directly and pass `rounds=` to have the spend priced.
Either way `report_extras` surfaces the cumulative per-round trajectory
(`epsilon_schedule`) and the composed total (`epsilon_spent`) on
`TraceReport.extras`.  The accounting model treats each training round as
one release of a Poisson-subsampled Gaussian mechanism at
`(noise_multiplier, sample_frac)` — see the `repro.privacy.accountant`
module docs for the exact order grid and conversion.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, ClassVar, Dict, Hashable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.strategy import EpochSchedule, TrainData
from repro.core import aggregation, encoding
from repro.core.delay_model import sample_total
from repro.core.redundancy import RedundancyPlan, systematic_weights

from .base import (CodedSchemeState, coded_device_state, coded_uplink_bits,
                   fused_coded_device_state, sample_parity_upload_time)

if TYPE_CHECKING:  # annotation-only: keeps schemes free of sim imports
    from repro.sim.network import FleetSpec


@dataclasses.dataclass
class StochasticState(CodedSchemeState):
    """`CodedSchemeState` + the calibrated noise actually injected."""

    noise_scale_x: float
    noise_scale_y: float
    srv_weight: float


@dataclasses.dataclass(frozen=True)
class StochasticCodedFL:
    """SCFL: noisy shared parity + per-round stochastic parity sampling.

    key:              PRNG key for generator matrices AND the privacy noise
    noise_multiplier: privacy-noise std relative to the coded data's RMS
                      (0 = no noise; the paper's privacy/accuracy knob).
                      Defaults to 0.5 when neither it nor `epsilon_target`
                      is given; mutually exclusive with `epsilon_target`.
    sample_frac:      per-round Bernoulli parity-row sampling probability
                      (1 = every row every round; draws NO extra generator
                      randomness at 1, keeping the stream aligned with
                      CodedFL)
    fixed_c / c_up / include_upload_delay / generator: as in `CodedFL`
    redundancy_plan:  pre-solved plan (one element of a batched sweep)
    epsilon_target:   (epsilon, delta)-DP budget to train within; the
                      noise multiplier is then CALIBRATED via
                      `repro.privacy.calibrate_noise` (requires `rounds`).
                      Sweeps should batch the calibration themselves
                      (`repro.plan.srv_weight_for_epsilon` or a vector
                      `calibrate_noise` call) and pass `noise_multiplier=`
                      per strategy — per-strategy calibration here solves
                      one target at a time.
    delta:            DP delta for accounting/calibration
    rounds:           accounting horizon (training rounds composed); when
                      set, `report_extras` prices the run and surfaces
                      `epsilon_spent` + the per-round `epsilon_schedule`
    """

    key: jax.Array
    noise_multiplier: Optional[float] = None
    sample_frac: float = 1.0
    fixed_c: Optional[int] = None
    c_up: Optional[int] = None
    include_upload_delay: bool = True
    generator: str = "normal"
    label: str = "scfl"
    redundancy_plan: Optional[RedundancyPlan] = None
    epsilon_target: Optional[float] = None
    delta: float = 1e-5
    rounds: Optional[int] = None
    grad_path: str = aggregation.FUSED

    def _grad_path(self) -> str:
        return aggregation.resolve_grad_path(self.grad_path)

    # noise / budget knobs feed the plan, the encoded values and the DP
    # accounting report — never the traced engine — so a whole
    # noise/epsilon frontier shares ONE compiled sweep engine.
    # sample_frac stays keyed: it is baked into the traced 1/(c*rho).
    engine_value_fields: ClassVar[frozenset] = frozenset(
        {"fixed_c", "c_up", "include_upload_delay", "generator",
         "noise_multiplier", "epsilon_target", "delta", "rounds"})
    # data-only operands (one replicated copy per sweep); the noised
    # parity shards and load mask stay per-lane
    data_device_keys: ClassVar[frozenset] = frozenset(
        {"x", "y", "row_client"})

    def __post_init__(self):
        if not (0.0 < self.sample_frac <= 1.0):
            raise ValueError(
                f"sample_frac must be in (0, 1], got {self.sample_frac}")
        if not (0.0 < self.delta < 1.0):
            raise ValueError(f"delta must be in (0, 1), got {self.delta}")
        if self.rounds is not None and int(self.rounds) < 1:
            raise ValueError(f"rounds must be >= 1, got {self.rounds}")
        if self.epsilon_target is not None:
            if self.rounds is None:
                raise ValueError(
                    "epsilon_target needs rounds=<training rounds>: the "
                    "budget composes over the whole run")
            from repro.privacy import calibrate_noise
            sigma = float(calibrate_noise(
                self.epsilon_target, delta=self.delta, rounds=self.rounds,
                sample_frac=self.sample_frac))
            # Tolerate noise_multiplier == the calibrated value so
            # `dataclasses.replace` on a budget-constructed strategy
            # (which re-runs this hook with BOTH fields populated) works;
            # any other combination is a genuine conflict.
            if self.noise_multiplier is not None \
                    and self.noise_multiplier != sigma:
                raise ValueError(
                    "pass either epsilon_target= (calibrated noise) or "
                    "noise_multiplier= (manual noise), not both; to "
                    "recalibrate after changing the budget fields, pass "
                    "noise_multiplier=None explicitly")
            object.__setattr__(self, "noise_multiplier", sigma)
        elif self.noise_multiplier is None:
            object.__setattr__(self, "noise_multiplier", 0.5)
        if self.noise_multiplier < 0:
            raise ValueError(
                f"noise_multiplier must be >= 0, got {self.noise_multiplier}")

    @property
    def srv_weight(self) -> float:
        """Effective rows per parity row: rho / (1 + sigma^2)."""
        from repro.plan import effective_srv_weight
        return float(effective_srv_weight(self.noise_multiplier,
                                          self.sample_frac))

    # -- planning (batched through repro.plan) ------------------------------

    def plan_request(self, fleet: "FleetSpec", data: TrainData):
        """The weighted-server redundancy problem `plan` would solve."""
        from repro.plan import PlanRequest
        return PlanRequest(edge=fleet.edge, server=fleet.server,
                           data_sizes=np.full(data.n, data.ell,
                                              dtype=np.int64),
                           c_up=self.c_up, fixed_c=self.fixed_c,
                           srv_weight=self.srv_weight)

    def plan_with(self, fleet: "FleetSpec", data: TrainData,
                  plan: Optional[RedundancyPlan]) -> StochasticState:
        if plan is None:
            from repro.plan import solve_redundancy_batched
            plan = solve_redundancy_batched(
                [self.plan_request(fleet, data)])[0]

        n, ell = data.n, data.ell
        data_sizes = np.full(n, ell, dtype=np.int64)
        w_np = np.stack(systematic_weights(plan, data_sizes))   # (n, ell)
        weights = jnp.asarray(w_np, dtype=data.xs.dtype)
        load_mask = jnp.asarray(
            np.arange(ell)[None, :] < plan.loads[:, None], dtype=data.xs.dtype)

        # calibrated noise scale (float64 on host — the NumPy-reference
        # oracle `stochastic_noise_scale` computes the identical expression)
        d = data.d
        w2 = w_np.astype(np.float64) ** 2
        xs64 = np.asarray(data.xs, dtype=np.float64)
        ys64 = np.asarray(data.ys, dtype=np.float64)
        scale_x = self.noise_multiplier * float(
            np.sqrt(np.sum(w2[..., None] * xs64 ** 2) / d))
        scale_y = self.noise_multiplier * float(
            np.sqrt(np.sum(w2 * ys64 ** 2)))

        if plan.c > 0:
            # encode with the raw key (the exact CodedFL generator stream:
            # noise_multiplier = 0, sample_frac = 1 degenerates to CodedFL
            # bit-for-bit); the noise streams are independent fold-ins
            x_par, y_par = encoding.encode_fleet(
                self.key, data.xs, data.ys, weights, plan.c,
                kind=self.generator)
            if self.noise_multiplier > 0:
                dt = data.xs.dtype
                k_nx = jax.random.fold_in(self.key, 1)
                k_ny = jax.random.fold_in(self.key, 2)
                x_par = x_par + jnp.asarray(scale_x, dt) \
                    * jax.random.normal(k_nx, x_par.shape, dtype=dt)
                y_par = y_par + jnp.asarray(scale_y, dt) \
                    * jax.random.normal(k_ny, y_par.shape, dtype=dt)
        else:  # c = 0 degenerates to uncoded FL with deadline t*
            x_par = jnp.zeros((0, d), dtype=data.xs.dtype)
            y_par = jnp.zeros((0,), dtype=data.xs.dtype)

        return StochasticState(plan=plan, load_mask=load_mask,
                               x_parity=x_par, y_parity=y_par,
                               edge=fleet.edge, server=fleet.server,
                               noise_scale_x=scale_x, noise_scale_y=scale_y,
                               srv_weight=self.srv_weight)

    def plan(self, fleet: "FleetSpec", data: TrainData) -> StochasticState:
        return self.plan_with(fleet, data, self.redundancy_plan)

    # -- epoch sampling -----------------------------------------------------

    def sample_epochs(self, state: StochasticState, fleet: "FleetSpec",
                      epochs: int, rng: np.random.Generator) -> EpochSchedule:
        plan = state.plan
        n = fleet.edge.n
        t_star = plan.t_star
        c = state.c
        upload_time = sample_parity_upload_time(state, fleet, rng)

        received = np.empty((epochs, n), dtype=np.float32)
        parity_mask = np.ones((epochs, c), dtype=np.float32)
        parity_ok = np.ones(epochs, dtype=np.float32)
        for e in range(epochs):
            t_i = sample_total(fleet.edge, plan.loads, rng)
            received[e] = (t_i <= t_star) & (plan.loads > 0)
            if c == 0:
                continue
            if self.sample_frac < 1.0:
                parity_mask[e] = rng.random(c) < self.sample_frac
            rows = int(parity_mask[e].sum())
            t_srv = sample_total(fleet.server, np.array([rows]), rng)[0]
            parity_ok[e] = float(t_srv <= t_star)

        return EpochSchedule(
            durations=np.full(epochs, t_star),
            arrivals={"received": received, "parity_mask": parity_mask,
                      "parity_ok": parity_ok},
            setup_time=upload_time,
            t0=upload_time if self.include_upload_delay else 0.0)

    # -- engine hooks -------------------------------------------------------

    def device_state(self, state: StochasticState,
                     data: TrainData) -> Dict[str, jax.Array]:
        if self._grad_path() == aggregation.FUSED:
            # rho < 1 keeps the raw parity rows alongside the Gram
            # factors: the per-round Bernoulli mask needs the rows
            return fused_coded_device_state(
                state, data, parity_rows=self.sample_frac < 1.0)
        return coded_device_state(state, data)

    def _fused_round(self, state, dev, beta, arrivals):
        x, y, w0, client = aggregation.fused_sys_block(dev)
        w = w0 * arrivals["received"][client]
        if state.c == 0:
            return aggregation.round_gradient(
                x, y, beta, w=w, path=aggregation.FUSED)
        if self.sample_frac < 1.0:
            # inverse-probability row weights keep the subsampled parity
            # gradient unbiased; folding 1/(c*rho) into them lets the
            # systematic and parity streams share ONE fused launch
            w_par = arrivals["parity_mask"] \
                * (arrivals["parity_ok"]
                   / (dev["par_c"] * self.sample_frac))
            return aggregation.coded_round_gradient(
                x, y, w, dev["x_parity"],
                dev["y_parity"], w_par, beta, path=aggregation.FUSED)
        # rho == 1: static parity — the Gram-folded Eq. 18
        return aggregation.fused_coded_gradient(
            dev, w, arrivals["parity_ok"], beta, rho=self.sample_frac)

    def round_contributions(self, state, dev, beta, arrivals):
        if self._grad_path() == aggregation.FUSED:
            return self._fused_round(state, dev, beta, arrivals)
        resid = dev["x"] @ beta - dev["y"]
        w = dev["w_sys"] * arrivals["received"][dev["row_client"]]
        g_sys = (resid * w) @ dev["x"]
        if state.c == 0:
            return g_sys
        resid_par = dev["x_parity"] @ beta - dev["y_parity"]
        w_par = arrivals["parity_mask"] * arrivals["parity_ok"]
        # inverse-probability weighting keeps the subsampled parity
        # gradient unbiased: E[mask/rho] = 1 per row
        g_par = ((resid_par * w_par) @ dev["x_parity"]) \
            / (state.c * self.sample_frac)
        return g_sys + g_par

    def tiered_contributions(self, state, dev, beta, arrivals, tier_masks):
        # systematic partials reduce per edge tier; the stochastic parity
        # gradient is server-resident and rides as the server-side term
        if self._grad_path() == aggregation.FUSED:
            x, y, w0, client = aggregation.fused_sys_block(dev)
            masks = aggregation.fused_tier_masks(dev, tier_masks)
            w = w0 * arrivals["received"][client]
            partials = aggregation.tiered_round_gradient(
                x, y, beta, w, masks, path=aggregation.FUSED)
            if state.c == 0:
                return partials, None
            c_norm = dev["par_c"] * self.sample_frac
            if self.sample_frac < 1.0:
                w_par = arrivals["parity_mask"] \
                    * (arrivals["parity_ok"] / c_norm)
                g_par = aggregation.round_gradient(
                    dev["x_parity"], dev["y_parity"], beta, w=w_par,
                    path=aggregation.FUSED)
            else:
                g_par = arrivals["parity_ok"] \
                    * aggregation.gram_parity_gradient(
                        dev["par_gram"], dev["par_gramy"], beta, c_norm)
            return partials, g_par
        resid = dev["x"] @ beta - dev["y"]
        w = dev["w_sys"] * arrivals["received"][dev["row_client"]]
        partials = aggregation.tier_reduce(resid * w, dev["x"], tier_masks)
        if state.c == 0:
            return partials, None
        resid_par = dev["x_parity"] @ beta - dev["y_parity"]
        w_par = arrivals["parity_mask"] * arrivals["parity_ok"]
        g_par = ((resid_par * w_par) @ dev["x_parity"]) \
            / (state.c * self.sample_frac)
        return partials, g_par

    def uplink_bits(self, state: StochasticState, fleet: "FleetSpec",
                    epochs: int) -> float:
        return coded_uplink_bits(state, fleet, epochs)

    def engine_key(self, state: StochasticState) -> Hashable:
        # sample_frac is baked into the traced 1/(c*rho) constant
        return (state.c > 0, float(self.sample_frac))

    def sweep_inputs(self, state: StochasticState, fleet: "FleetSpec",
                     epochs: int, rng: np.random.Generator) -> EpochSchedule:
        """One sweep lane's inputs: `received (epochs, n)`,
        `parity_mask (epochs, c)` and `parity_ok (epochs,)` stack across
        lanes sharing the fleet size and parity budget (c is an operand
        shape, so mixed-c sweeps bucket apart); draws are exactly
        `sample_epochs` — a whole noise/epsilon frontier at one budget is
        a single engine bucket."""
        return self.sample_epochs(state, fleet, epochs, rng)

    def serve_convergence(self, state: StochasticState, criterion):
        """Serving-engine hook (`repro.serving`): epsilon-budget
        exhaustion.  With a calibrated (epsilon, delta) budget, every
        round past the accounting horizon overspends the target, so the
        served epoch budget is capped at `rounds` — the lane then frees
        its slot when the budget is spent, and its truncated
        `epsilon_schedule` lands on `TraceReport.extras`."""
        if self.epsilon_target is None or self.rounds is None:
            return criterion
        cap = int(self.rounds) if criterion.max_epochs is None \
            else min(int(criterion.max_epochs), int(self.rounds))
        return dataclasses.replace(criterion, max_epochs=cap)

    def report_extras(self, state: StochasticState) -> Dict[str, float]:
        """The privacy/accuracy knob — and, when an accounting horizon is
        set, the composed (epsilon, delta) spend — on every TraceReport."""
        extras = {"noise_multiplier": float(self.noise_multiplier),
                  "sample_frac": float(self.sample_frac),
                  "srv_weight": float(state.srv_weight),
                  "noise_scale_x": float(state.noise_scale_x),
                  "noise_scale_y": float(state.noise_scale_y)}
        if self.rounds is not None:
            from repro.privacy import epsilon_schedule
            sched = epsilon_schedule(self.noise_multiplier,
                                     self.sample_frac, self.rounds,
                                     self.delta)
            extras["delta"] = float(self.delta)
            extras["accounting_rounds"] = int(self.rounds)
            extras["epsilon_schedule"] = sched   # cumulative, per round
            extras["epsilon_spent"] = float(sched[-1])
            if self.epsilon_target is not None:
                extras["epsilon_target"] = float(self.epsilon_target)
        return extras
