"""Transformer building blocks: norms, rotary embeddings, GQA attention
(training, prefill, and single-token decode with optional sliding window),
cross-attention, and MLPs.

Conventions
-----------
* Params are plain nested dicts of jnp arrays; every init_* returns a dict.
* Shapes: tokens (B, S), activations (B, S, D), attention heads (B, S, H, Dh).
* `param_dtype` controls storage; matmuls run in `x.dtype` (the caller casts
  activations, typically bf16 on TPU, fp32 in CPU tests).
* GQA: n_heads = n_kv_heads * group; we compute scores with a grouped einsum
  so KV heads are never materialized `group`-fold.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / jnp.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def init_norm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def init_ln(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype),
            "bias": jnp.zeros((d,), dtype=dtype)}


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype, bias: bool = False,
                   kv_input_dim: Optional[int] = None,
                   fused: bool = False) -> dict:
    """QKVO projections. `kv_input_dim` != d_model for cross-attention.
    fused=True packs K and V into one `wkv` matrix so the backward dx
    partial-sum needs ONE all-reduce instead of two (§Perf iteration 6);
    the K/V halves sit on aligned shard boundaries (each G*hd divisible by
    the model-axis size)."""
    kq, kk, kv, ko = jax.random.split(key, 4)
    kv_in = kv_input_dim or d_model
    p = {
        "wq": dense_init(kq, d_model, n_heads * head_dim, dtype),
        "wo": dense_init(ko, n_heads * head_dim, d_model, dtype),
    }
    if fused:
        p["wkv"] = dense_init(kk, kv_in, 2 * n_kv_heads * head_dim, dtype)
    else:
        p["wk"] = dense_init(kk, kv_in, n_kv_heads * head_dim, dtype)
        p["wv"] = dense_init(kv, kv_in, n_kv_heads * head_dim, dtype)
    if bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype=dtype)
        if fused:
            p["bkv"] = jnp.zeros((2 * n_kv_heads * head_dim,), dtype=dtype)
        else:
            p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype=dtype)
            p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype=dtype)
    return p


def init_mlp(key, d_model: int, d_ff: int, dtype, act: str = "swiglu",
             fused: bool = False) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "swiglu":
        if fused:  # packed gate|up: one bwd dx all-reduce (§Perf iter. 6)
            return {"w_gu": dense_init(k1, d_model, 2 * d_ff, dtype),
                    "w_down": dense_init(k3, d_ff, d_model, dtype)}
        return {"w_gate": dense_init(k1, d_model, d_ff, dtype),
                "w_up": dense_init(k2, d_model, d_ff, dtype),
                "w_down": dense_init(k3, d_ff, d_model, dtype)}
    return {"w_up": dense_init(k1, d_model, d_ff, dtype),
            "w_down": dense_init(k2, d_ff, d_model, dtype)}


# ---------------------------------------------------------------------------
# norms / mlp
# ---------------------------------------------------------------------------

def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    # square in x.dtype, accumulate the mean in fp32: upcasting the whole
    # tensor (x.astype(f32)) materializes an f32 [B,S,D] cotangent in the
    # backward pass that the TP partial-sum all-reduce then moves at 2x the
    # bytes (§Perf iteration 2) — the f32 accumulation keeps the precision
    # that matters (the reduction) at bf16 wire cost.
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                   dtype=jnp.float32)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * p["scale"].astype(x.dtype)


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = ((xf - mean) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return y * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)


def apply_norm(p: dict, x: jax.Array, kind: str) -> jax.Array:
    return layernorm(p, x) if kind == "ln" else rmsnorm(p, x)


def mlp(p: dict, x: jax.Array, act: str = "swiglu") -> jax.Array:
    if act == "swiglu":
        if "w_gu" in p:
            gu = x @ p["w_gu"].astype(x.dtype)
            g, u = jnp.split(gu, 2, axis=-1)
            h = jax.nn.silu(g) * u
        else:
            h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype))
            h = h * (x @ p["w_up"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype))
    return h @ p["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) absolute token positions."""
    freqs = rope_freqs(x.shape[-1], theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (B, S, Dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------

def _project_qkv(p: dict, x: jax.Array, kv_src: jax.Array,
                 n_heads: int, n_kv_heads: int, head_dim: int):
    q = x @ p["wq"].astype(x.dtype)
    if "wkv" in p:
        kvp = kv_src @ p["wkv"].astype(x.dtype)
        if "bkv" in p:
            kvp = kvp + p["bkv"].astype(x.dtype)
        k, v = jnp.split(kvp, 2, axis=-1)
        if "bq" in p:
            q = q + p["bq"].astype(x.dtype)
    else:
        k = kv_src @ p["wk"].astype(x.dtype)
        v = kv_src @ p["wv"].astype(x.dtype)
        if "bq" in p:
            q = q + p["bq"].astype(x.dtype)
            k = k + p["bk"].astype(x.dtype)
            v = v + p["bv"].astype(x.dtype)
    B, S = x.shape[:2]
    T = kv_src.shape[1]
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, T, n_kv_heads, head_dim)
    v = v.reshape(B, T, n_kv_heads, head_dim)
    return q, k, v


def _seq_shard(x: jax.Array, axis: int) -> jax.Array:
    """Constrain an attention intermediate to shard dim `axis` over the
    `model` mesh axis (scores whose head count does not divide the mesh
    would otherwise replicate the whole (B, H, S, T) tensor — §Perf
    iterations B2/B3; axis=2 shards the query-seq dim (context parallel),
    axis=1 pad-shards the head dim).  No-op outside a mesh."""
    try:
        from jax.sharding import PartitionSpec as P
        spec = [None] * x.ndim
        spec[0] = "data"
        spec[axis] = "model"
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError, TypeError):
        return x


def gqa_scores_apply(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: Optional[jax.Array],
                     impl: str = "grouped",
                     softmax_dtype=jnp.float32,
                     seq_shard: bool = False) -> jax.Array:
    """Grouped-query attention core.

    q: (B, S, Hq, Dh), k/v: (B, T, Hkv, Dh), mask: broadcastable to
    (B, Hkv, R, S, T) (grouped) / (B, Hq, S, T) (repeat), or plain (S, T).
    Returns (B, S, Hq, Dh).

    impl="grouped": 5-D (B, .., G, R, ..) einsums — KV heads never
    materialized R-fold, but the G dim (often 8) does not divide a 16-way
    `model` mesh axis, which forces SPMD involuntary replication of the
    score tensors (§Perf iteration 1).
    impl="repeat": broadcast KV to Hq heads first — Hq (32/40/96) divides
    the mesh, so every attention intermediate shards over `model`; the
    broadcast fuses into the matmul and never hits HBM.
    """
    B, S, Hq, Dh = q.shape
    Hkv = k.shape[2]
    scale = 1.0 / jnp.sqrt(Dh).astype(q.dtype)
    if impl == "repeat":
        rep = Hq // Hkv
        if rep > 1:
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        scores = jnp.einsum("bshd,bthd->bhst", q * scale, k)
        scores = scores.astype(softmax_dtype)
        if seq_shard:
            ax = 1 if seq_shard == "head" else 2
            scores = _seq_shard(scores, ax)  # (B, H, S, T)
        if mask is not None:
            if mask.ndim == 5:  # (B, G, R, S, T) -> (B, H, S, T)
                mask = mask.reshape(mask.shape[0], -1, *mask.shape[3:])
            scores = jnp.where(mask, scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        if seq_shard:
            w = _seq_shard(w, 1 if seq_shard == "head" else 2)
        return jnp.einsum("bhst,bthd->bshd", w, v)
    R = Hq // Hkv
    qg = q.reshape(B, S, Hkv, R, Dh)
    scores = jnp.einsum("bsgrd,btgd->bgrst", qg * scale, k)
    scores = scores.astype(softmax_dtype)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrst,btgd->bsgrd", w, v)
    return out.reshape(B, S, Hq, Dh)


def causal_mask(S: int, T: int, window: Optional[int] = None,
                offset: int = 0) -> jax.Array:
    """(S, T) boolean mask; query i attends key j iff
    j <= i + offset and (no window or i + offset - j < window)."""
    i = jnp.arange(S)[:, None] + offset
    j = jnp.arange(T)[None, :]
    m = j <= i
    if window is not None:
        m = m & (i - j < window)
    return m


def self_attention(p: dict, x: jax.Array, positions: jax.Array, *,
                   n_heads: int, n_kv_heads: int, head_dim: int,
                   theta: float, causal: bool = True,
                   window: Optional[int] = None,
                   use_rope: bool = True, return_kv: bool = False,
                   impl: str = "grouped", softmax_dtype=jnp.float32,
                   seq_shard: bool = False):
    """Full-sequence self-attention (training / encoder / prefill).

    With return_kv=True also returns the post-rope (k, v) — the prefill path
    turns these into the decode cache."""
    q, k, v = _project_qkv(p, x, x, n_heads, n_kv_heads, head_dim)
    if use_rope:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    S = x.shape[1]
    mask = causal_mask(S, S, window) if causal else None
    out = gqa_scores_apply(q, k, v, mask, impl=impl,
                           softmax_dtype=softmax_dtype, seq_shard=seq_shard)
    out = out.reshape(x.shape[0], S, -1) @ p["wo"].astype(x.dtype)
    if return_kv:
        return out, (k, v)
    return out


def kv_to_cache(k: jax.Array, v: jax.Array, window: Optional[int] = None,
                cache_len: Optional[int] = None) -> dict:
    """Arrange full-sequence (B, S, G, Dh) K/V into the decode-cache layout.

    Full attention: slot == position, zero-padded out to `cache_len` so
    subsequent decode steps have room.  Sliding window: keep the last
    `window` positions at slots pos %% window, matching the rolling writes
    of `decode_self_attention`."""
    S = k.shape[1]
    if window is None:
        target = cache_len or S
        pad = target - S
        if pad < 0:
            raise ValueError(f"prompt {S} exceeds cache_len {target}")
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return {"k": k, "v": v}
    target = min(window, cache_len) if cache_len else window
    if S <= target:
        pad = target - S
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return {"k": k, "v": v}
    k_last = k[:, S - window:]
    v_last = v[:, S - window:]
    r = S % window
    return {"k": jnp.roll(k_last, r, axis=1), "v": jnp.roll(v_last, r, axis=1)}


def cross_attention(p: dict, x: jax.Array, memory: jax.Array, *,
                    n_heads: int, n_kv_heads: int, head_dim: int,
                    impl: str = "grouped") -> jax.Array:
    """Cross-attention over a memory sequence (no mask, no rope)."""
    q, k, v = _project_qkv(p, x, memory, n_heads, n_kv_heads, head_dim)
    out = gqa_scores_apply(q, k, v, None, impl=impl)
    return out.reshape(x.shape[0], x.shape[1], -1) @ p["wo"].astype(x.dtype)


def cross_attention_cached(p: dict, x: jax.Array, k: jax.Array,
                           v: jax.Array, *, n_heads: int, n_kv_heads: int,
                           head_dim: int, impl: str = "grouped") -> jax.Array:
    """Cross-attention against precomputed K/V (decode path)."""
    q = x @ p["wq"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    B, S = x.shape[:2]
    q = q.reshape(B, S, n_heads, head_dim)
    out = gqa_scores_apply(q, k, v, None, impl=impl)
    return out.reshape(B, S, -1) @ p["wo"].astype(x.dtype)


def project_cross_kv(p: dict, memory: jax.Array, *, n_kv_heads: int,
                     head_dim: int) -> tuple[jax.Array, jax.Array]:
    if "wkv" in p:
        kvp = memory @ p["wkv"].astype(memory.dtype)
        if "bkv" in p:
            kvp = kvp + p["bkv"].astype(memory.dtype)
        k, v = jnp.split(kvp, 2, axis=-1)
    else:
        k = memory @ p["wk"].astype(memory.dtype)
        v = memory @ p["wv"].astype(memory.dtype)
        if "bk" in p:
            k = k + p["bk"].astype(memory.dtype)
            v = v + p["bv"].astype(memory.dtype)
    B, T = memory.shape[:2]
    return (k.reshape(B, T, n_kv_heads, head_dim),
            v.reshape(B, T, n_kv_heads, head_dim))


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, cache_len: int, n_kv_heads: int, head_dim: int,
                  dtype) -> dict:
    return {
        "k": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype=dtype),
        "v": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype=dtype),
    }


def decode_self_attention(p: dict, x: jax.Array, cache: dict,
                          pos: jax.Array, *, n_heads: int, n_kv_heads: int,
                          head_dim: int, theta: float,
                          window: Optional[int] = None,
                          use_rope: bool = True,
                          impl: str = "grouped") -> tuple[jax.Array, dict]:
    """One-token decode: x (B, 1, D); `pos` (scalar) is the absolute position
    of the new token.  The cache holds the last `cache_len` K/V — for a
    sliding-window model cache_len == window and writes wrap (rolling cache);
    keys are stored post-rope at absolute positions so relative phases hold.
    """
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, x, n_heads, n_kv_heads, head_dim)
    if use_rope:
        pos_b = jnp.full((B, 1), pos)
        q = apply_rope(q, pos_b, theta)
        k = apply_rope(k, pos_b, theta)
    cache_len = cache["k"].shape[1]
    # full cache: pos < cache_len so the modulo is a no-op; rolling window
    # cache: writes wrap around.
    slot = jnp.asarray(pos % cache_len, dtype=jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    # valid keys: slots filled so far (all slots once pos >= cache_len)
    j = jnp.arange(cache_len)
    if window is None:
        valid = j <= pos
    else:
        valid = (j <= pos) | (pos >= cache_len)
    mask = valid[None, None, None, None, :]
    out = gqa_scores_apply(q, ck, cv, mask, impl=impl)
    out = out.reshape(B, 1, -1) @ p["wo"].astype(x.dtype)
    return out, {"k": ck, "v": cv}
