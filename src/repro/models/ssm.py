"""Mamba2 blocks via the State Space Duality (SSD) algorithm
[arXiv:2405.21060].

The selective state-space recurrence

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t,      y_t = C_t^T h_t

is evaluated with the chunked matmul-friendly SSD decomposition: split the
sequence into chunks of Q tokens; within a chunk the output is a masked
(C B^T)-weighted quadratic form; across chunks a tiny recurrence carries the
(H, P, N) state.  Everything maps onto MXU matmuls except the O(S/Q) carry
scan.  A per head is a scalar (Mamba2's "scalar-identity" A).

Shapes: x (B, S, H, P) with H heads of headdim P; B/C (B, S, G, N) with G
state groups (G divides H) and state size N; dt (B, S, H).

`ssd_chunked` is the pure-jnp oracle; `repro.kernels.ssd` provides the Pallas
kernel for the intra-chunk part.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import dense_init

DEFAULT_CHUNK = 256


def segsum(a: jax.Array) -> jax.Array:
    """Stable "segment sum": out[..., i, j] = sum_{k=j+1..i} a[..., k]
    for j < i, 0 on the diagonal, -inf above it. a: (..., Q)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]           # i, j -> cs_i - cs_j
    i = jnp.arange(q)[:, None]
    j = jnp.arange(q)[None, :]
    return jnp.where(j <= i, diff, -jnp.inf)


def _shard_heads(x: jax.Array, h_axis: int) -> jax.Array:
    """Constrain the SSD head dim onto the `model` mesh axis: without this
    the whole SSD computation replicates across model shards (its only
    sharded input dim is batch) — §Perf iteration C3.  No-op off-mesh."""
    try:
        from jax.sharding import PartitionSpec as P
        spec = [None] * x.ndim
        spec[0] = "data"
        spec[h_axis] = "model"
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError, TypeError):
        return x


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, chunk: int = DEFAULT_CHUNK,
                h0: Optional[jax.Array] = None,
                use_kernel: bool = False, head_shard: bool = False):
    """Chunked SSD scan.

    x: (B, S, H, P), dt: (B, S, H), a: (H,) negative decay rates,
    b, c: (B, S, G, N) with H % G == 0.
    Returns (y (B, S, H, P), h_final (B, H, P, N)).
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    orig_s = S
    if S % chunk != 0:
        # zero-pad the tail: dt=0 gives decay exp(0)=1 and zero input, so
        # padded steps leave the state untouched and emit garbage-free zeros.
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // chunk
    rep = H // G

    # broadcast state groups to heads
    bh = jnp.repeat(b, rep, axis=2)                      # (B, S, H, N)
    ch = jnp.repeat(c, rep, axis=2)
    if head_shard:
        x = _shard_heads(x, 2)
        dt = _shard_heads(dt, 2)
        bh = _shard_heads(bh, 2)
        ch = _shard_heads(ch, 2)

    xc = x.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H)
    bc = bh.reshape(B, nc, chunk, H, N)
    cc = ch.reshape(B, nc, chunk, H, N)

    da = dtc * a[None, None, None, :]                    # (B, nc, Q, H) decay log
    da = da.astype(jnp.float32)

    if use_kernel:
        from repro.kernels.ssd import ops as ssd_ops
        y_diag, states = ssd_ops.ssd_chunk(xc, dtc, da, bc, cc)
    else:
        y_diag, states = ssd_chunk_reference(xc, dtc, da, bc, cc)

    # ---- inter-chunk recurrence over the carried states -------------------
    # decay of a full chunk per head: exp(sum_t da_t)
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))           # (B, nc, H)
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), dtype=jnp.float32)

    def step(h, inp):
        dec, s = inp                                     # dec (B,H), s (B,H,P,N)
        h_new = h * dec[..., None, None] + s
        return h_new, h

    (h_final, h_prev) = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                  # (B, nc, H, P, N)

    # ---- contribution of the carried-in state to each chunk ---------------
    # decay from chunk start to position t: exp(cumsum inclusive of da)
    decay_in = jnp.exp(jnp.cumsum(da, axis=2))           # (B, nc, Q, H)
    y_off = jnp.einsum("bnqhs,bnhps,bnqh->bnqhp",
                       cc.astype(jnp.float32), h_prev, decay_in)

    y = (y_diag + y_off).astype(x.dtype).reshape(B, S, H, P)
    return y[:, :orig_s], h_final


def ssd_chunk_reference(xc, dtc, da, bc, cc):
    """Intra-chunk quadratic part + per-chunk carried state (jnp oracle).

    xc (B,nc,Q,H,P), dtc (B,nc,Q,H), da (B,nc,Q,H) fp32, bc/cc (B,nc,Q,H,N).
    Returns y_diag (B,nc,Q,H,P) fp32, states (B,nc,H,P,N) fp32.
    """
    f32 = jnp.float32
    xw = (xc * dtc[..., None]).astype(f32)               # dt-weighted inputs
    # attention-like intra-chunk matrix: L[t, s] = exp(sum_{s<k<=t} da_k)
    lmat = jnp.exp(segsum(jnp.moveaxis(da, 2, -1)))      # (B,nc,H,Q,Q)
    scores = jnp.einsum("bnqhs,bnths->bnhqt",
                        cc.astype(f32), bc.astype(f32))  # (B,nc,H,Q,T)
    y_diag = jnp.einsum("bnhqt,bnhqt,bnthp->bnqhp",
                        scores, lmat, xw)
    # carried state: decay from each position to chunk end (exclusive of t? —
    # inclusive of everything after t): exp(sum_{k>t} da_k)
    cum = jnp.cumsum(da, axis=2)
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,Q,H)
    states = jnp.einsum("bnqhs,bnqh,bnqhp->bnhps",
                        bc.astype(f32), decay_out, xw)
    return y_diag, states


def ssd_decode_step(h: jax.Array, x: jax.Array, dt: jax.Array, a: jax.Array,
                    b: jax.Array, c: jax.Array):
    """Single-token recurrence. h (B,H,P,N), x (B,H,P), dt (B,H),
    b,c (B,G,N). Returns (y (B,H,P), h_new)."""
    H = x.shape[1]
    rep = H // b.shape[1]
    bh = jnp.repeat(b, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    ch = jnp.repeat(c, rep, axis=1).astype(jnp.float32)
    da = (dt * a[None, :]).astype(jnp.float32)
    dec = jnp.exp(da)[..., None, None]                   # (B,H,1,1)
    xw = (x * dt[..., None]).astype(jnp.float32)
    h_new = h * dec + jnp.einsum("bhp,bhs->bhps", xw, bh)
    y = jnp.einsum("bhps,bhs->bhp", h_new, ch)
    return y.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# full Mamba2 block (projections + causal conv + SSD + gate)
# ---------------------------------------------------------------------------

def init_mamba2(key, d_model: int, d_state: int, n_heads: int, headdim: int,
                n_groups: int, d_conv: int, dtype) -> dict:
    d_inner = n_heads * headdim
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    conv_dim = d_inner + 2 * n_groups * d_state
    return {
        # order: [z (gate), x, B, C, dt]
        "w_in": dense_init(k1, d_model,
                           2 * d_inner + 2 * n_groups * d_state + n_heads,
                           dtype),
        "conv_w": (jax.random.normal(k2, (d_conv, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype=dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(dtype),
        "dt_bias": jnp.zeros((n_heads,), dtype=dtype),
        "d_skip": jnp.ones((n_heads,), dtype=dtype),
        "norm_scale": jnp.ones((d_inner,), dtype=dtype),
        "w_out": dense_init(k5, d_inner, d_model, dtype),
    }


def _split_in(proj, d_inner, n_groups, d_state, n_heads):
    zs = d_inner
    xs = d_inner
    bs = n_groups * d_state
    cs = n_groups * d_state
    z, xr, b, c, dt = jnp.split(
        proj, [zs, zs + xs, zs + xs + bs, zs + xs + bs + cs], axis=-1)
    return z, xr, b, c, dt


def causal_conv(w: jax.Array, bias: jax.Array, x: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. x (B, S, C), w (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # windowed sum: sum_j w[j] * x[t - (k-1) + j]
    out = jnp.zeros_like(x)
    for j in range(k):  # k is tiny (4); unrolled adds fuse fine
        out = out + pad[:, j:j + x.shape[1], :] * w[j][None, None, :]
    return out + bias[None, None, :]


def mamba2_block(p: dict, x: jax.Array, *, d_state: int, n_heads: int,
                 headdim: int, n_groups: int, chunk: int = DEFAULT_CHUNK,
                 use_kernel: bool = False,
                 head_shard: bool = False) -> jax.Array:
    """Full-sequence Mamba2 mixer. x: (B, S, D) -> (B, S, D)."""
    y, _ = mamba2_prefill(p, x, d_state=d_state, n_heads=n_heads,
                          headdim=headdim, n_groups=n_groups, chunk=chunk,
                          use_kernel=use_kernel, head_shard=head_shard)
    return y


def mamba2_prefill(p: dict, x: jax.Array, *, d_state: int, n_heads: int,
                   headdim: int, n_groups: int, chunk: int = DEFAULT_CHUNK,
                   use_kernel: bool = False,
                   head_shard: bool = False) -> tuple[jax.Array, dict]:
    """Full-sequence Mamba2 that also returns the decode cache (final SSM
    state + last d_conv-1 conv inputs)."""
    B, S, _ = x.shape
    d_inner = n_heads * headdim
    proj = x @ p["w_in"].astype(x.dtype)
    z, xr, b, c, dt = _split_in(proj, d_inner, n_groups, d_state, n_heads)
    conv_in = jnp.concatenate([xr, b, c], axis=-1)
    d_conv = p["conv_w"].shape[0]
    conv_hist = conv_in[:, S - (d_conv - 1):, :]          # decode conv cache
    conv_out = jax.nn.silu(causal_conv(p["conv_w"].astype(x.dtype),
                                       p["conv_b"].astype(x.dtype), conv_in))
    xr, b, c = jnp.split(conv_out,
                         [d_inner, d_inner + n_groups * d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xr.reshape(B, S, n_heads, headdim)
    bg = b.reshape(B, S, n_groups, d_state)
    cg = c.reshape(B, S, n_groups, d_state)
    y, h_final = ssd_chunked(xh, dt, a, bg, cg, chunk=chunk,
                             use_kernel=use_kernel, head_shard=head_shard)
    y = y + xh * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_inner)
    if head_shard:
        # keep the gated norm and out-projection channel-sharded: the mean
        # reduces cross-shard as a (B,S,1) all-reduce and the w_out matmul
        # partial-sums into one (B,S,D) all-reduce instead of gathering the
        # full (B,S,d_inner) y (§Perf iteration C4)
        y = _shard_heads(y, 2)
        z = _shard_heads(z, 2)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True,
                   dtype=jnp.float32)
    y = (y * jax.lax.rsqrt(var + 1e-6).astype(x.dtype)
         * p["norm_scale"].astype(x.dtype))
    out = y @ p["w_out"].astype(x.dtype)
    return out, {"conv": conv_hist, "ssm": h_final}


def init_mamba2_cache(batch: int, d_state: int, n_heads: int, headdim: int,
                      n_groups: int, d_conv: int, dtype) -> dict:
    conv_dim = n_heads * headdim + 2 * n_groups * d_state
    return {
        "conv": jnp.zeros((batch, d_conv - 1, conv_dim), dtype=dtype),
        "ssm": jnp.zeros((batch, n_heads, headdim, d_state),
                         dtype=jnp.float32),
    }


def mamba2_decode(p: dict, x: jax.Array, cache: dict, *, d_state: int,
                  n_heads: int, headdim: int,
                  n_groups: int) -> tuple[jax.Array, dict]:
    """Single-token Mamba2 step. x: (B, 1, D)."""
    B = x.shape[0]
    d_inner = n_heads * headdim
    proj = x[:, 0] @ p["w_in"].astype(x.dtype)            # (B, ...)
    z, xr, b, c, dt = _split_in(proj, d_inner, n_groups, d_state, n_heads)
    conv_in = jnp.concatenate([xr, b, c], axis=-1)        # (B, C)
    hist = jnp.concatenate([cache["conv"], conv_in[:, None, :]], axis=1)
    w = p["conv_w"].astype(x.dtype)                       # (K, C)
    conv_out = jnp.einsum("bkc,kc->bc", hist, w) + p["conv_b"].astype(x.dtype)
    conv_out = jax.nn.silu(conv_out)
    xr, b, c = jnp.split(conv_out,
                         [d_inner, d_inner + n_groups * d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))    # (B, H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xr.reshape(B, n_heads, headdim)
    bg = b.reshape(B, n_groups, d_state)
    cg = c.reshape(B, n_groups, d_state)
    y, h_new = ssd_decode_step(cache["ssm"], xh, dt, a, bg, cg)
    y = y + xh * p["d_skip"].astype(x.dtype)[None, :, None]
    y = y.reshape(B, d_inner)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-6).astype(x.dtype)
         * p["norm_scale"].astype(x.dtype))
    out = (y @ p["w_out"].astype(x.dtype))[:, None, :]
    return out, {"conv": hist[:, 1:, :], "ssm": h_new}
