"""The paper's workload: linear regression y = X beta + z (§II)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linreg_predict(beta: jax.Array, x: jax.Array) -> jax.Array:
    return x @ beta


def linreg_loss(beta: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    """Squared-error cost f(beta) = ||X beta - y||^2 (Eq. 1)."""
    r = x @ beta - y
    return jnp.sum(r * r)
