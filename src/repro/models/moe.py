"""Mixture-of-Experts FFN with top-k routing and capacity-bounded grouped
dispatch (GShard/Mesh-TF style).

Tokens are processed in groups of `group_size`; within each group, each
expert accepts at most `capacity` tokens (overflow is dropped — its residual
passes through).  Dispatch/combine are one-hot einsums so the partitioner can
shard the expert dimension over the `model` mesh axis and derive the
all-to-all; no gather/scatter, no host-side control flow.

Shapes: x (B, S, D) -> flattened (n_groups, group, D);
dispatch/combine (n_groups, group, E, C); expert buffers (n_groups, E, C, D).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import dense_init


@dataclasses.dataclass(frozen=True)
class MoEDims:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int
    group_size: int = 2048
    capacity_factor: float = 2.0

    def capacity(self, group: int) -> int:
        cap = int(self.capacity_factor * self.top_k * group / self.n_experts)
        return max(cap, self.top_k)


def init_moe(key, dims: MoEDims, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    e, d, f = dims.n_experts, dims.d_model, dims.d_ff
    scale_in = 1.0 / jnp.sqrt(d)
    scale_out = 1.0 / jnp.sqrt(f)
    return {
        "router": dense_init(k1, d, e, jnp.float32),  # router math stays fp32
        "w_gate": (jax.random.normal(k2, (e, d, f)) * scale_in).astype(dtype),
        "w_up": (jax.random.normal(k3, (e, d, f)) * scale_in).astype(dtype),
        "w_down": (jax.random.normal(k4, (e, f, d)) * scale_out).astype(dtype),
    }


def _top_k_mask(router_probs: jax.Array, k: int):
    """Per-token top-k expert selection.

    router_probs: (..., E).  Returns (mask (..., E) in {0,1},
    gates (..., E) with renormalized probs on the selected experts)."""
    top_vals, _ = jax.lax.top_k(router_probs, k)
    thresh = top_vals[..., -1:]
    mask = (router_probs >= thresh).astype(router_probs.dtype)
    gates = router_probs * mask
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return mask, gates


def moe_ffn(p: dict, x: jax.Array, dims: MoEDims):
    """Apply the MoE FFN. x: (B, S, D). Returns (y, aux) where aux carries the
    load-balancing loss terms (Switch/GShard auxiliary loss)."""
    B, S, D = x.shape
    T = B * S
    group = min(dims.group_size, T)
    if T % group != 0:  # shrink until it divides (T is a power-of-2 product)
        while T % group != 0:
            group //= 2
    n_groups = T // group
    e = dims.n_experts
    cap = dims.capacity(group)

    xg = x.reshape(n_groups, group, D)
    logits = (xg.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))          # (n, g, E)
    probs = jax.nn.softmax(logits, axis=-1)
    mask, gates = _top_k_mask(probs, dims.top_k)          # (n, g, E)

    # position of each token within its expert's queue (top-1 slot priority;
    # for top-k the k-th choices queue behind all (k-1)-th choices)
    # cumulative count per expert along the group axis
    pos_in_expert = jnp.cumsum(mask, axis=1) - mask       # (n, g, E)
    keep = mask * (pos_in_expert < cap)                   # drop overflow
    slot_onehot = jax.nn.one_hot(pos_in_expert.astype(jnp.int32), cap,
                                 dtype=jnp.float32)        # (n, g, E, C)
    dispatch = keep[..., None] * slot_onehot               # (n, g, E, C)
    combine = (gates * keep)[..., None] * slot_onehot      # (n, g, E, C)

    xd = x.dtype
    expert_in = jnp.einsum("ngec,ngd->necd", dispatch.astype(xd), xg)

    h = jax.nn.silu(jnp.einsum("necd,edf->necf", expert_in,
                               p["w_gate"].astype(xd)))
    h = h * jnp.einsum("necd,edf->necf", expert_in, p["w_up"].astype(xd))
    expert_out = jnp.einsum("necf,efd->necd", h, p["w_down"].astype(xd))

    y = jnp.einsum("ngec,necd->ngd", combine.astype(xd), expert_out)
    y = y.reshape(B, S, D)

    # auxiliary load-balance loss (Switch): E * sum_e f_e * P_e
    frac_tokens = jnp.mean(mask, axis=1)                   # (n, E)
    frac_probs = jnp.mean(probs, axis=1)                   # (n, E)
    aux_loss = e * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
    # router z-loss (stabilizes logits)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return y, {"aux_loss": aux_loss, "z_loss": z_loss,
               "dropped_frac": 1.0 - jnp.mean(jnp.sum(keep, -1)
                                              / dims.top_k)}
