"""Unified decoder-LM covering all six assigned architecture families.

One parameter tree + three entry points per config:

  * ``forward_train(cfg, params, batch)``  -> logits over the full sequence
  * ``prefill(cfg, params, batch)``        -> (last-token logits, cache)
  * ``decode_step(cfg, params, batch, cache)`` -> (logits, new cache)

Layer stacks are scanned (stacked params, ``jax.lax.scan``) so HLO size and
compile time are independent of depth — essential for the 88-layer /
48-layer production configs in the multi-pod dry-run.

Family wiring:
  dense   — uniform [attn + MLP] blocks
  moe     — [attn + (MoE every k-th | dense MLP)] blocks; k = cfg.moe.every
  ssm     — uniform Mamba2 blocks (attention-free)
  hybrid  — Mamba2 backbone; ONE weight-shared [attn + MLP] block applied
            every cfg.hybrid.attn_every layers (Zamba2)
  vlm     — groups of (cross_every-1) self blocks + 1 cross-attn block over
            vision patch embeddings (Llama-3.2-Vision); vision tower stubbed
  audio   — encoder (non-causal self blocks over stub frame embeddings) +
            decoder with self + cross blocks (Whisper)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig
from . import layers as L
from . import moe as M
from . import ssm as S


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack_init(key, n: int, init_fn):
    """Initialize n copies of a block and stack leaves -> [n, ...] arrays."""
    keys = jax.random.split(key, n)
    trees = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _norm_init(cfg: ArchConfig, d: int, dtype) -> dict:
    return L.init_ln(d, dtype) if cfg.norm == "ln" else L.init_norm(d, dtype)


def _init_self_block(key, cfg: ArchConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "attn_norm": _norm_init(cfg, d, dtype),
        "attn": L.init_attention(k1, d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                                 dtype, bias=cfg.attn_bias,
                                 fused=cfg.fused_proj),
        "mlp_norm": _norm_init(cfg, d, dtype),
        "mlp": L.init_mlp(k2, d, cfg.d_ff, dtype, act=cfg.act,
                          fused=cfg.fused_proj),
    }


def _init_moe_block(key, cfg: ArchConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    dims = M.MoEDims(cfg.moe.n_experts, cfg.moe.top_k, d, cfg.d_ff,
                     cfg.moe.group_size, cfg.moe.capacity_factor)
    return {
        "attn_norm": _norm_init(cfg, d, dtype),
        "attn": L.init_attention(k1, d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                                 dtype, bias=cfg.attn_bias,
                                 fused=cfg.fused_proj),
        "mlp_norm": _norm_init(cfg, d, dtype),
        "moe": M.init_moe(k2, dims, dtype),
    }


def _init_mamba_block(key, cfg: ArchConfig, dtype) -> dict:
    s = cfg.ssm
    return {
        "norm": L.init_norm(cfg.d_model, dtype),
        "mixer": S.init_mamba2(key, cfg.d_model, s.d_state,
                               s.n_heads(cfg.d_model), s.headdim,
                               s.n_groups, s.d_conv, dtype),
    }


def _init_cross_block(key, cfg: ArchConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    kv_in = cfg.vlm.d_vision if cfg.vlm else d
    return {
        "attn_norm": _norm_init(cfg, d, dtype),
        "attn": L.init_attention(k1, d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                                 dtype, kv_input_dim=kv_in),
        "mlp_norm": _norm_init(cfg, d, dtype),
        "mlp": L.init_mlp(k2, d, cfg.d_ff, dtype, act=cfg.act),
        "gate": jnp.zeros((1,), dtype=dtype),  # zero-init gated cross-attn
    }


def init_params(cfg: ArchConfig, key: jax.Array,
                dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, d)) * 0.02).astype(dtype),
        "final_norm": (L.init_ln(d, dtype) if cfg.norm == "ln"
                       else L.init_norm(d, dtype)),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[1], d, cfg.vocab, dtype)

    at = cfg.arch_type
    if at == "dense":
        p["blocks"] = _stack_init(ks[2], cfg.n_layers,
                                  lambda k: _init_self_block(k, cfg, dtype))
    elif at == "moe":
        every = cfg.moe.every
        n_moe = cfg.n_layers // every
        n_dense = cfg.n_layers - n_moe
        p["moe_blocks"] = _stack_init(ks[2], n_moe,
                                      lambda k: _init_moe_block(k, cfg, dtype))
        if n_dense:
            p["blocks"] = _stack_init(
                ks[3], n_dense, lambda k: _init_self_block(k, cfg, dtype))
    elif at == "ssm":
        p["blocks"] = _stack_init(ks[2], cfg.n_layers,
                                  lambda k: _init_mamba_block(k, cfg, dtype))
    elif at == "hybrid":
        p["blocks"] = _stack_init(ks[2], cfg.n_layers,
                                  lambda k: _init_mamba_block(k, cfg, dtype))
        p["shared_attn"] = _init_self_block(ks[3], cfg, dtype)
    elif at == "vlm":
        n_groups, n_self = _vlm_layout(cfg)
        p["blocks"] = _stack_init(
            ks[2], n_groups * n_self, lambda k: _init_self_block(k, cfg, dtype))
        p["cross_blocks"] = _stack_init(
            ks[3], n_groups, lambda k: _init_cross_block(k, cfg, dtype))
    elif at == "audio":
        p["enc_blocks"] = _stack_init(
            ks[2], cfg.encdec.n_enc_layers,
            lambda k: _init_self_block(k, cfg, dtype))
        p["enc_norm"] = (L.init_ln(d, dtype) if cfg.norm == "ln"
                         else L.init_norm(d, dtype))
        p["blocks"] = _stack_init(ks[3], cfg.n_layers,
                                  lambda k: _init_self_block(k, cfg, dtype))
        p["cross_blocks"] = _stack_init(
            ks[4], cfg.n_layers, lambda k: _init_cross_block(k, cfg, dtype))
    else:
        raise ValueError(f"unknown arch_type {at}")
    return p


def _softmax_dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.softmax_dtype == "bf16" else jnp.float32


def _vlm_layout(cfg: ArchConfig) -> tuple[int, int]:
    """(n_groups, self_layers_per_group): groups of (cross_every - 1) self
    layers followed by one cross layer, covering n_layers total."""
    ce = cfg.vlm.cross_every
    n_groups = cfg.n_layers // ce
    return n_groups, ce - 1


# ---------------------------------------------------------------------------
# block applications (full sequence)
# ---------------------------------------------------------------------------

def _self_block(cfg: ArchConfig, bp: dict, x, positions, causal=True):
    h = L.apply_norm(bp["attn_norm"], x, cfg.norm)
    attn = L.self_attention(
        bp["attn"], h, positions, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, theta=cfg.rope_theta,
        causal=causal, window=cfg.sliding_window if causal else None,
        impl=cfg.attn_impl, softmax_dtype=_softmax_dtype(cfg),
        seq_shard=cfg.attn_seq_shard)
    # name the post-all-reduce activations so the save_ar remat policy can
    # keep them: the TP partial-sum all-reduce is then not re-run during
    # the backward recompute (§Perf iteration 5)
    x = x + checkpoint_name(attn, "post_ar")
    h = L.apply_norm(bp["mlp_norm"], x, cfg.norm)
    return x + checkpoint_name(L.mlp(bp["mlp"], h, act=cfg.act), "post_ar")


def _moe_block(cfg: ArchConfig, bp: dict, x, positions):
    dims = M.MoEDims(cfg.moe.n_experts, cfg.moe.top_k, cfg.d_model, cfg.d_ff,
                     cfg.moe.group_size, cfg.moe.capacity_factor)
    h = L.apply_norm(bp["attn_norm"], x, cfg.norm)
    x = x + L.self_attention(
        bp["attn"], h, positions, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, theta=cfg.rope_theta,
        causal=True, window=cfg.sliding_window, impl=cfg.attn_impl,
        softmax_dtype=_softmax_dtype(cfg), seq_shard=cfg.attn_seq_shard)
    h = L.apply_norm(bp["mlp_norm"], x, cfg.norm)
    y, aux = M.moe_ffn(bp["moe"], h, dims)
    return x + y, aux


def _mamba_block(cfg: ArchConfig, bp: dict, x, use_kernel=False):
    s = cfg.ssm
    h = L.rmsnorm(bp["norm"], x)
    return x + S.mamba2_block(
        bp["mixer"], h, d_state=s.d_state, n_heads=s.n_heads(cfg.d_model),
        headdim=s.headdim, n_groups=s.n_groups, chunk=s.chunk,
        use_kernel=use_kernel, head_shard=s.head_shard)


def _cross_block(cfg: ArchConfig, bp: dict, x, memory):
    h = L.apply_norm(bp["attn_norm"], x, cfg.norm)
    attn = L.cross_attention(bp["attn"], h, memory, n_heads=cfg.n_heads,
                             n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                             impl=cfg.attn_impl)
    gate = jnp.tanh(bp["gate"].astype(x.dtype)) if "gate" in bp else 1.0
    x = x + gate * attn
    h = L.apply_norm(bp["mlp_norm"], x, cfg.norm)
    return x + L.mlp(bp["mlp"], h, act=cfg.act)


# ---------------------------------------------------------------------------
# forward (training / encoder)
# ---------------------------------------------------------------------------

def _embed(cfg: ArchConfig, params: dict, tokens: jax.Array,
           compute_dtype) -> jax.Array:
    return params["embed"].astype(compute_dtype)[tokens]


def _unembed(cfg: ArchConfig, params: dict, x: jax.Array) -> jax.Array:
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    return (x @ head.astype(x.dtype)).astype(jnp.float32)


def _remat(fn, remat):
    """remat: False | True ("full") | "save_ar"."""
    if not remat:
        return fn
    if remat == "save_ar":
        policy = jax.checkpoint_policies.save_only_these_names("post_ar")
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _scan_blocks(block_fn, stacked: dict, x, *, remat=False):
    fn = _remat(block_fn, remat)

    def step(carry, bp):
        return fn(carry, bp), None

    out, _ = jax.lax.scan(step, x, stacked)
    return out


def _run_backbone(cfg: ArchConfig, params: dict, x: jax.Array,
                  positions: jax.Array, batch: dict, *,
                  remat: bool = False, use_kernel: bool = False,
                  causal: bool = True):
    """Apply the full layer stack for any family. Returns (x, aux)."""
    at = cfg.arch_type
    aux: dict[str, jax.Array] = {}

    if at == "dense":
        x = _scan_blocks(
            lambda h, bp: _self_block(cfg, bp, h, positions, causal),
            params["blocks"], x, remat=remat)

    elif at == "moe":
        every = cfg.moe.every

        def moe_step(h, bp):
            h2, a = _moe_block(cfg, bp, h, positions)
            return h2, a["aux_loss"]

        if every == 1:
            fn = _remat(moe_step, remat)
            x, auxl = jax.lax.scan(lambda c, bp: fn(c, bp),
                                   x, params["moe_blocks"])
            aux["moe_aux_loss"] = jnp.mean(auxl)
        else:
            # interleave: (every-1) dense blocks then 1 MoE block, repeated
            n_moe = cfg.n_layers // every
            n_dense_per = every - 1
            dense = jax.tree.map(
                lambda a: a.reshape((n_moe, n_dense_per) + a.shape[1:]),
                params["blocks"])
            both = {"dense": dense, "moe": params["moe_blocks"]}

            def group(h, bp):
                h = _scan_blocks(
                    lambda hh, dd: _self_block(cfg, dd, hh, positions),
                    bp["dense"], h, remat=remat)
                if remat:
                    h, a = _remat(lambda hh: moe_step(hh, bp["moe"]),
                                  remat)(h)
                else:
                    h, a = moe_step(h, bp["moe"])
                return h, a

            x, auxl = jax.lax.scan(group, x, both)
            aux["moe_aux_loss"] = jnp.mean(auxl)

    elif at == "ssm":
        x = _scan_blocks(
            lambda h, bp: _mamba_block(cfg, bp, h, use_kernel=use_kernel),
            params["blocks"], x, remat=remat)

    elif at == "hybrid":
        ae = cfg.hybrid.attn_every
        shared = params["shared_attn"]

        def block(h, bp_i):
            bp, i = bp_i
            h = _mamba_block(cfg, bp, h, use_kernel=use_kernel)
            h = jax.lax.cond(
                (i + 1) % ae == 0,
                lambda hh: _self_block(cfg, shared, hh, positions),
                lambda hh: hh, h)
            return h

        idx = jnp.arange(cfg.n_layers)
        fn = _remat(block, remat)
        x, _ = jax.lax.scan(lambda c, bp: (fn(c, bp), None), x,
                            (params["blocks"], idx))

    elif at == "vlm":
        n_groups, n_self = _vlm_layout(cfg)
        memory = batch["patches"].astype(x.dtype)
        selfs = jax.tree.map(
            lambda a: a.reshape((n_groups, n_self) + a.shape[1:]),
            params["blocks"])
        both = {"self": selfs, "cross": params["cross_blocks"]}

        def group(h, bp):
            h = _scan_blocks(
                lambda hh, dd: _self_block(cfg, dd, hh, positions),
                bp["self"], h, remat=remat)
            cb = _remat(lambda hh: _cross_block(cfg, bp["cross"], hh,
                                                memory), remat)
            return cb(h), None

        x, _ = jax.lax.scan(group, x, both)

    elif at == "audio":
        # encode stub frames, then decode with interleaved self+cross
        frames = batch["frames"].astype(x.dtype)
        enc_pos = jnp.broadcast_to(
            jnp.arange(frames.shape[1])[None, :], frames.shape[:2])
        enc = _scan_blocks(
            lambda h, bp: _self_block(cfg, bp, h, enc_pos, causal=False),
            params["enc_blocks"], frames, remat=remat)
        enc = L.apply_norm(params["enc_norm"], enc, cfg.norm)
        both = {"self": params["blocks"], "cross": params["cross_blocks"]}

        def block(h, bp):
            h = _self_block(cfg, bp["self"], h, positions)
            h = _cross_block(cfg, bp["cross"], h, enc)
            return h, None

        fn = _remat(lambda h, bp: block(h, bp)[0], remat) if remat else None
        if remat:
            x, _ = jax.lax.scan(lambda c, bp: (fn(c, bp), None), x, both)
        else:
            x, _ = jax.lax.scan(block, x, both)

    else:
        raise ValueError(at)
    return x, aux


def forward_train(cfg: ArchConfig, params: dict, batch: dict, *,
                  compute_dtype=jnp.float32, remat: bool = False,
                  use_kernel: bool = False):
    """Full-sequence forward. Returns (logits fp32 (B, S, V), aux)."""
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    x = _embed(cfg, params, tokens, compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(Sq)[None, :], (B, Sq))
    x, aux = _run_backbone(cfg, params, x, positions, batch,
                           remat=remat, use_kernel=use_kernel)
    return _unembed(cfg, params, x), aux


def loss_fn(cfg: ArchConfig, params: dict, batch: dict, *,
            compute_dtype=jnp.float32, remat: bool = False,
            use_kernel: bool = False):
    """Next-token cross-entropy (+ MoE aux losses)."""
    logits, aux = forward_train(cfg, params, batch,
                                compute_dtype=compute_dtype, remat=remat,
                                use_kernel=use_kernel)
    targets = batch["targets"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    if "moe_aux_loss" in aux:
        loss = loss + 0.01 * aux["moe_aux_loss"]
    return loss, aux


# ---------------------------------------------------------------------------
# serving: cache init, prefill, single-token decode
# ---------------------------------------------------------------------------

def _attn_cache_len(cfg: ArchConfig, seq_len: int) -> int:
    """Rolling-window caches only keep `window` slots (sub-quadratic decode)."""
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg: ArchConfig, batch_size: int, seq_len: int,
               dtype=jnp.float32, batch: Optional[dict] = None) -> dict:
    """Zero-initialized decode cache for `seq_len` positions.

    For VLM/audio archs the cross-attention K/V are part of the cache and are
    filled by `prefill` (pass `batch` with patches/frames to precompute them
    here when skipping prefill)."""
    at = cfg.arch_type
    clen = _attn_cache_len(cfg, seq_len)
    B = batch_size

    def kv(n, t=clen):
        return {
            "k": jnp.zeros((n, B, t, cfg.n_kv_heads, cfg.hd), dtype=dtype),
            "v": jnp.zeros((n, B, t, cfg.n_kv_heads, cfg.hd), dtype=dtype),
        }

    if at in ("dense", "moe"):
        return {"attn": kv(cfg.n_layers)}
    if at == "ssm":
        return {"mamba": _mamba_cache_stack(cfg, cfg.n_layers, B, dtype)}
    if at == "hybrid":
        n_attn = cfg.n_layers // cfg.hybrid.attn_every
        return {"mamba": _mamba_cache_stack(cfg, cfg.n_layers, B, dtype),
                "attn": kv(n_attn)}
    if at == "vlm":
        n_groups, n_self = _vlm_layout(cfg)
        cache = {"attn": kv(n_groups * n_self)}
        P = cfg.vlm.n_patches
        cache["cross"] = {
            "k": jnp.zeros((n_groups, B, P, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((n_groups, B, P, cfg.n_kv_heads, cfg.hd), dtype),
        }
        if batch is not None:
            cache["cross"] = _vlm_cross_kv(cfg, None, batch)  # filled later
        return cache
    if at == "audio":
        F = cfg.encdec.n_frames
        return {"attn": kv(cfg.n_layers),
                "cross": {
                    "k": jnp.zeros((cfg.n_layers, B, F, cfg.n_kv_heads,
                                    cfg.hd), dtype),
                    "v": jnp.zeros((cfg.n_layers, B, F, cfg.n_kv_heads,
                                    cfg.hd), dtype),
                }}
    raise ValueError(at)


def _mamba_cache_stack(cfg: ArchConfig, n: int, B: int, dtype) -> dict:
    s = cfg.ssm
    H = s.n_heads(cfg.d_model)
    conv_dim = H * s.headdim + 2 * s.n_groups * s.d_state
    return {
        "conv": jnp.zeros((n, B, s.d_conv - 1, conv_dim), dtype=dtype),
        "ssm": jnp.zeros((n, B, H, s.headdim, s.d_state), dtype=jnp.float32),
    }


def cache_specs(cfg: ArchConfig, batch_size: int, seq_len: int,
                dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStruct tree of the decode cache (no allocation)."""
    return jax.eval_shape(
        lambda: init_cache(cfg, batch_size, seq_len, dtype=dtype))


def _vlm_cross_kv(cfg: ArchConfig, params: dict, batch: dict) -> dict:
    memory = batch["patches"]

    def one(cb):
        return L.project_cross_kv(cb["attn"], memory,
                                  n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd)

    ks, vs = jax.vmap(one)(params["cross_blocks"])
    return {"k": ks, "v": vs}


# -- decode blocks ----------------------------------------------------------

def _self_block_decode(cfg: ArchConfig, bp: dict, x, cache_l: dict, pos):
    h = L.apply_norm(bp["attn_norm"], x, cfg.norm)
    attn, new_cache = L.decode_self_attention(
        bp["attn"], h, cache_l, pos, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, theta=cfg.rope_theta,
        window=cfg.sliding_window, impl=cfg.attn_impl)
    x = x + attn
    h = L.apply_norm(bp["mlp_norm"], x, cfg.norm)
    return x + L.mlp(bp["mlp"], h, act=cfg.act), new_cache


def _moe_block_decode(cfg: ArchConfig, bp: dict, x, cache_l: dict, pos):
    # Decode groups hold only B tokens, so the training-time capacity bound
    # int(cf * k * group / E) can round below the tokens one expert may
    # receive, silently dropping a token's FFN output.  Decode must match
    # the full forward exactly: cf = E makes capacity k * group (drop-free)
    # at negligible buffer cost for decode-sized groups.
    dims = M.MoEDims(cfg.moe.n_experts, cfg.moe.top_k, cfg.d_model, cfg.d_ff,
                     cfg.moe.group_size, float(cfg.moe.n_experts))
    h = L.apply_norm(bp["attn_norm"], x, cfg.norm)
    attn, new_cache = L.decode_self_attention(
        bp["attn"], h, cache_l, pos, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, theta=cfg.rope_theta,
        window=cfg.sliding_window, impl=cfg.attn_impl)
    x = x + attn
    h = L.apply_norm(bp["mlp_norm"], x, cfg.norm)
    y, _ = M.moe_ffn(bp["moe"], h, dims)
    return x + y, new_cache


def _cross_block_decode(cfg: ArchConfig, bp: dict, x, ck, cv):
    h = L.apply_norm(bp["attn_norm"], x, cfg.norm)
    attn = L.cross_attention_cached(bp["attn"], h, ck, cv,
                                    n_heads=cfg.n_heads,
                                    n_kv_heads=cfg.n_kv_heads,
                                    head_dim=cfg.hd, impl=cfg.attn_impl)
    gate = jnp.tanh(bp["gate"].astype(x.dtype)) if "gate" in bp else 1.0
    x = x + gate * attn
    h = L.apply_norm(bp["mlp_norm"], x, cfg.norm)
    return x + L.mlp(bp["mlp"], h, act=cfg.act)


def decode_step(cfg: ArchConfig, params: dict, batch: dict, cache: dict, *,
                compute_dtype=jnp.bfloat16):
    """One new token against the cache.

    batch: {"token": (B, 1) int32, "pos": scalar int32 — absolute position
    of the new token}. Returns (logits fp32 (B, 1, V), new cache)."""
    token, pos = batch["token"], batch["pos"]
    x = _embed(cfg, params, token, compute_dtype)
    at = cfg.arch_type
    new_cache = dict(cache)

    if at == "dense":
        def step(h, bp_c):
            bp, cl = bp_c
            h2, nc = _self_block_decode(cfg, bp, h, cl, pos)
            return h2, nc
        x, nc = jax.lax.scan(step, x, (params["blocks"], cache["attn"]))
        new_cache["attn"] = nc

    elif at == "moe":
        every = cfg.moe.every
        if every == 1:
            def step(h, bp_c):
                bp, cl = bp_c
                return _moe_block_decode(cfg, bp, h, cl, pos)
            x, nc = jax.lax.scan(step, x, (params["moe_blocks"],
                                           cache["attn"]))
            new_cache["attn"] = nc
        else:
            n_moe = cfg.n_layers // every
            n_dense_per = every - 1
            dense = jax.tree.map(
                lambda a: a.reshape((n_moe, n_dense_per) + a.shape[1:]),
                params["blocks"])
            ac = cache["attn"]
            acg = jax.tree.map(
                lambda a: a.reshape((n_moe, every) + a.shape[1:]), ac)

            def group(h, bp_c):
                bp, cg = bp_c
                dcache = jax.tree.map(lambda a: a[:n_dense_per], cg)
                mcache = jax.tree.map(lambda a: a[n_dense_per], cg)

                def dstep(hh, dd_c):
                    dd, cl = dd_c
                    return _self_block_decode(cfg, dd, hh, cl, pos)
                h, ndc = jax.lax.scan(dstep, h, (bp["dense"], dcache))
                h, nmc = _moe_block_decode(cfg, bp["moe"], h, mcache, pos)
                nc = jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b[None]], axis=0),
                    ndc, nmc)
                return h, nc

            x, ncg = jax.lax.scan(group, x,
                                  ({"dense": dense,
                                    "moe": params["moe_blocks"]}, acg))
            new_cache["attn"] = jax.tree.map(
                lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), ncg)

    elif at == "ssm":
        s = cfg.ssm

        def step(h, bp_c):
            bp, cl = bp_c
            hn = L.rmsnorm(bp["norm"], h)
            y, nc = S.mamba2_decode(bp["mixer"], hn, cl, d_state=s.d_state,
                                    n_heads=s.n_heads(cfg.d_model),
                                    headdim=s.headdim, n_groups=s.n_groups)
            return h + y, nc
        x, nc = jax.lax.scan(step, x, (params["blocks"], cache["mamba"]))
        new_cache["mamba"] = nc

    elif at == "hybrid":
        s = cfg.ssm
        ae = cfg.hybrid.attn_every
        n_attn = cfg.n_layers // ae
        shared = params["shared_attn"]
        # head: n_attn groups of ae mamba layers each ending in shared attn
        n_head_layers = n_attn * ae
        mb = params["blocks"]
        head = jax.tree.map(
            lambda a: a[:n_head_layers].reshape((n_attn, ae) + a.shape[1:]),
            mb)
        tail = jax.tree.map(lambda a: a[n_head_layers:], mb)
        mc = cache["mamba"]
        head_c = jax.tree.map(
            lambda a: a[:n_head_layers].reshape((n_attn, ae) + a.shape[1:]),
            mc)
        tail_c = jax.tree.map(lambda a: a[n_head_layers:], mc)

        def mamba_step(h, bp_c):
            bp, cl = bp_c
            hn = L.rmsnorm(bp["norm"], h)
            y, nc = S.mamba2_decode(bp["mixer"], hn, cl, d_state=s.d_state,
                                    n_heads=s.n_heads(cfg.d_model),
                                    headdim=s.headdim, n_groups=s.n_groups)
            return h + y, nc

        def group(h, bp_c):
            bp, cg, ca = bp_c
            h, ncm = jax.lax.scan(mamba_step, h, (bp, cg))
            h, nca = _self_block_decode(cfg, shared, h, ca, pos)
            return h, (ncm, nca)

        x, (ncm_head, nc_attn) = jax.lax.scan(
            group, x, (head, head_c, cache["attn"]))
        x, ncm_tail = jax.lax.scan(mamba_step, x, (tail, tail_c))
        new_cache["mamba"] = jax.tree.map(
            lambda a, b: jnp.concatenate(
                [a.reshape((n_head_layers,) + a.shape[2:]), b], axis=0),
            ncm_head, ncm_tail)
        new_cache["attn"] = nc_attn

    elif at == "vlm":
        n_groups, n_self = _vlm_layout(cfg)
        selfs = jax.tree.map(
            lambda a: a.reshape((n_groups, n_self) + a.shape[1:]),
            params["blocks"])
        sc = jax.tree.map(
            lambda a: a.reshape((n_groups, n_self) + a.shape[1:]),
            cache["attn"])

        def group(h, bp_c):
            bp, cg, ck, cv = bp_c

            def sstep(hh, dd_c):
                dd, cl = dd_c
                return _self_block_decode(cfg, dd, hh, cl, pos)
            h, nsc = jax.lax.scan(sstep, h, (bp["self"], cg))
            h = _cross_block_decode(cfg, bp["cross"], h, ck, cv)
            return h, nsc

        x, nsc = jax.lax.scan(
            group, x,
            ({"self": selfs, "cross": params["cross_blocks"]}, sc,
             cache["cross"]["k"], cache["cross"]["v"]))
        new_cache["attn"] = jax.tree.map(
            lambda a: a.reshape((n_groups * n_self,) + a.shape[2:]), nsc)

    elif at == "audio":
        def block(h, bp_c):
            bp, cl, ck, cv = bp_c
            h, nc = _self_block_decode(cfg, bp["self"], h, cl, pos)
            h = _cross_block_decode(cfg, bp["cross"], h, ck, cv)
            return h, nc
        x, nc = jax.lax.scan(
            block, x,
            ({"self": params["blocks"], "cross": params["cross_blocks"]},
             cache["attn"], cache["cross"]["k"], cache["cross"]["v"]))
        new_cache["attn"] = nc

    else:
        raise ValueError(at)

    return _unembed(cfg, params, x), new_cache


# ---------------------------------------------------------------------------
# prefill: full-sequence forward that also builds the decode cache
# ---------------------------------------------------------------------------

def _self_block_prefill(cfg: ArchConfig, bp: dict, x, positions,
                        cache_len=None):
    h = L.apply_norm(bp["attn_norm"], x, cfg.norm)
    attn, (k, v) = L.self_attention(
        bp["attn"], h, positions, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, theta=cfg.rope_theta,
        causal=True, window=cfg.sliding_window, return_kv=True,
        impl=cfg.attn_impl)
    x = x + attn
    h = L.apply_norm(bp["mlp_norm"], x, cfg.norm)
    x = x + L.mlp(bp["mlp"], h, act=cfg.act)
    kv = L.kv_to_cache(k, v, cfg.sliding_window, cache_len)
    return x, kv


def _moe_block_prefill(cfg: ArchConfig, bp: dict, x, positions,
                       cache_len=None):
    dims = M.MoEDims(cfg.moe.n_experts, cfg.moe.top_k, cfg.d_model, cfg.d_ff,
                     cfg.moe.group_size, cfg.moe.capacity_factor)
    h = L.apply_norm(bp["attn_norm"], x, cfg.norm)
    attn, (k, v) = L.self_attention(
        bp["attn"], h, positions, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, theta=cfg.rope_theta,
        causal=True, window=cfg.sliding_window, return_kv=True,
        impl=cfg.attn_impl)
    x = x + attn
    h = L.apply_norm(bp["mlp_norm"], x, cfg.norm)
    y, _ = M.moe_ffn(bp["moe"], h, dims)
    kv = L.kv_to_cache(k, v, cfg.sliding_window, cache_len)
    return x + y, kv


def _mamba_block_prefill(cfg: ArchConfig, bp: dict, x, use_kernel=False):
    s = cfg.ssm
    h = L.rmsnorm(bp["norm"], x)
    y, cache = S.mamba2_prefill(
        bp["mixer"], h, d_state=s.d_state, n_heads=s.n_heads(cfg.d_model),
        headdim=s.headdim, n_groups=s.n_groups, chunk=s.chunk,
        use_kernel=use_kernel, head_shard=s.head_shard)
    return x + y, cache


def prefill(cfg: ArchConfig, params: dict, batch: dict, *,
            compute_dtype=jnp.bfloat16, use_kernel: bool = False,
            cache_len: Optional[int] = None):
    """Process the prompt and build the decode cache.

    batch: {"tokens": (B, S)} plus modality stubs.  `cache_len` reserves KV
    slots beyond the prompt for subsequent decode steps (defaults to the
    prompt length — pure scoring).  Returns (last-position logits fp32
    (B, 1, V), cache)."""
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    x = _embed(cfg, params, tokens, compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(Sq)[None, :], (B, Sq))
    at = cfg.arch_type
    cache: dict[str, Any] = {}

    if at == "dense":
        def step(h, bp):
            return _self_block_prefill(cfg, bp, h, positions, cache_len)
        x, kv = jax.lax.scan(step, x, params["blocks"])
        cache["attn"] = kv

    elif at == "moe":
        every = cfg.moe.every
        if every == 1:
            def step(h, bp):
                return _moe_block_prefill(cfg, bp, h, positions, cache_len)
            x, kv = jax.lax.scan(step, x, params["moe_blocks"])
            cache["attn"] = kv
        else:
            n_moe = cfg.n_layers // every
            n_dense_per = every - 1
            dense = jax.tree.map(
                lambda a: a.reshape((n_moe, n_dense_per) + a.shape[1:]),
                params["blocks"])

            def group(h, bp):
                def dstep(hh, dd):
                    return _self_block_prefill(cfg, dd, hh, positions, cache_len)
                h, kvd = jax.lax.scan(dstep, h, bp["dense"])
                h, kvm = _moe_block_prefill(cfg, bp["moe"], h, positions,
                                            cache_len)
                kv = jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b[None]], axis=0),
                    kvd, kvm)
                return h, kv

            x, kvg = jax.lax.scan(group, x,
                                  {"dense": dense,
                                   "moe": params["moe_blocks"]})
            cache["attn"] = jax.tree.map(
                lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), kvg)

    elif at == "ssm":
        def step(h, bp):
            return _mamba_block_prefill(cfg, bp, h, use_kernel=use_kernel)
        x, mc = jax.lax.scan(step, x, params["blocks"])
        cache["mamba"] = mc

    elif at == "hybrid":
        ae = cfg.hybrid.attn_every
        n_attn = cfg.n_layers // ae
        n_head_layers = n_attn * ae
        shared = params["shared_attn"]
        mb = params["blocks"]
        head = jax.tree.map(
            lambda a: a[:n_head_layers].reshape((n_attn, ae) + a.shape[1:]),
            mb)
        tail = jax.tree.map(lambda a: a[n_head_layers:], mb)

        def mstep(h, bp):
            return _mamba_block_prefill(cfg, bp, h, use_kernel=use_kernel)

        def group(h, bp):
            h, mc = jax.lax.scan(mstep, h, bp)
            h, kv = _self_block_prefill(cfg, shared, h, positions, cache_len)
            return h, (mc, kv)

        x, (mc_head, kva) = jax.lax.scan(group, x, head)
        x, mc_tail = jax.lax.scan(mstep, x, tail)
        cache["mamba"] = jax.tree.map(
            lambda a, b: jnp.concatenate(
                [a.reshape((n_head_layers,) + a.shape[2:]), b], axis=0),
            mc_head, mc_tail)
        cache["attn"] = kva

    elif at == "vlm":
        n_groups, n_self = _vlm_layout(cfg)
        memory = batch["patches"].astype(x.dtype)
        selfs = jax.tree.map(
            lambda a: a.reshape((n_groups, n_self) + a.shape[1:]),
            params["blocks"])

        def group(h, bp):
            def sstep(hh, dd):
                return _self_block_prefill(cfg, dd, hh, positions, cache_len)
            h, kv = jax.lax.scan(sstep, h, bp["self"])
            h = _cross_block(cfg, bp["cross"], h, memory)
            return h, kv

        x, kvg = jax.lax.scan(
            group, x, {"self": selfs, "cross": params["cross_blocks"]})
        cache["attn"] = jax.tree.map(
            lambda a: a.reshape((n_groups * n_self,) + a.shape[2:]), kvg)
        cache["cross"] = _vlm_cross_kv(cfg, params, batch)

    elif at == "audio":
        frames = batch["frames"].astype(x.dtype)
        enc_pos = jnp.broadcast_to(
            jnp.arange(frames.shape[1])[None, :], frames.shape[:2])
        enc = _scan_blocks(
            lambda h, bp: _self_block(cfg, bp, h, enc_pos, causal=False),
            params["enc_blocks"], frames)
        enc = L.apply_norm(params["enc_norm"], enc, cfg.norm)

        def one_cross_kv(cb):
            return L.project_cross_kv(cb["attn"], enc,
                                      n_kv_heads=cfg.n_kv_heads,
                                      head_dim=cfg.hd)
        cks, cvs = jax.vmap(one_cross_kv)(params["cross_blocks"])

        def block(h, bp_c):
            bp, ck, cv = bp_c
            h, kv = _self_block_prefill(cfg, bp["self"], h, positions, cache_len)
            h = _cross_block_decode(cfg, bp["cross"], h, ck, cv)
            return h, kv

        x, kv = jax.lax.scan(
            block, x,
            ({"self": params["blocks"], "cross": params["cross_blocks"]},
             cks, cvs))
        cache["attn"] = kv
        cache["cross"] = {"k": cks, "v": cvs}

    else:
        raise ValueError(at)

    logits = _unembed(cfg, params, x[:, -1:, :])
    return logits, cache
