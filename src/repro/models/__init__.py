"""Model zoo: unified multi-family transformer + the paper's linear model."""
from . import layers, moe, ssm, transformer
from .linear import linreg_predict, linreg_loss

__all__ = ["layers", "moe", "ssm", "transformer", "linreg_predict",
           "linreg_loss"]
