"""Continuous-batching serving engine (vLLM-style slot management on a
fixed-shape decode step).

The jitted `decode_step` has a static batch (the `decode_32k` shape's
global_batch on the pod); requests arrive asynchronously and are mapped
onto free slots:

  * arriving requests are prefilled one at a time (padded to the prefill
    bucket) and their per-slot cache rows spliced into the live batch
    cache (`dynamic_update_slice` on the batch dim — slot writes are cheap
    and shard-local, the batch dim is the `data` axis);
  * every engine step decodes ONE token for all active slots; finished or
    empty slots keep decoding garbage into a scratch row (masked out) so
    the compiled step never re-specializes;
  * per-slot position counters let slots run at different sequence offsets
    within the same fixed-size cache.

This is a single-host reference (the distributed version shards the slot
batch over `data` and is exercised compile-only in the dry-run); it runs
real end-to-end on CPU with reduced configs (tests/test_serving.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (len,) int32
    max_new_tokens: int
    out_tokens: list = dataclasses.field(default_factory=list)
    slot: Optional[int] = None

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, n_slots: int,
                 max_seq: int, compute_dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.compute_dtype = compute_dtype
        self.cache = T.init_cache(cfg, n_slots, max_seq,
                                  dtype=compute_dtype)
        self.positions = np.zeros(n_slots, dtype=np.int64)  # next pos per slot
        self.active: dict[int, Request] = {}                # slot -> request
        self.last_token = np.zeros(n_slots, dtype=np.int32)

        def _decode(params, cache, tokens, pos_vec):
            # every slot decodes at its OWN absolute position: vmap a
            # single-slot decode over the cache's batch (slot) dim so the
            # per-slot `pos` stays a scalar inside the model.
            def one(p, c, t, q):
                c1 = jax.tree.map(lambda a: a[:, None], c)  # re-add batch
                batch = {"token": t[None, None], "pos": q}
                logits, nc = T.decode_step(self.cfg, p, batch, c1,
                                           compute_dtype=compute_dtype)
                return logits[0, 0], jax.tree.map(lambda a: a[:, 0], nc)

            slot_axes = jax.tree.map(lambda _: 1, cache)
            logits, nc = jax.vmap(one, in_axes=(None, slot_axes, 0, 0),
                                  out_axes=(0, slot_axes))(
                params, cache, tokens, pos_vec)
            return logits, nc

        self._decode = jax.jit(_decode)

        def _prefill(params, tokens):
            return T.prefill(self.cfg, params, {"tokens": tokens},
                             compute_dtype=compute_dtype,
                             cache_len=max_seq)
        self._prefill = jax.jit(_prefill)

    # ------------------------------------------------------------------
    def fits(self, req: Request) -> bool:
        """A request is servable iff its prompt prefills into the cache
        with room to decode at least one token.  Oversized requests are
        NEVER admissible — admitting one would overflow the cache, and
        leaving one at the queue head would starve everything behind it
        (see `run`)."""
        return len(req.prompt) + 1 <= self.max_seq

    def try_admit(self, req: Request) -> bool:
        """Prefill a request into a free slot; False if engine is full
        or the request can never fit."""
        if not self.fits(req):
            return False
        free = [s for s in range(self.n_slots) if s not in self.active]
        if not free:
            return False
        slot = free[0]
        toks = jnp.asarray(req.prompt, dtype=jnp.int32)[None, :]
        logits, cache1 = self._prefill(self.params, toks)
        # splice this request's cache rows into the live batch cache
        self.cache = jax.tree.map(
            lambda live, new: jax.lax.dynamic_update_slice_in_dim(
                live, new.astype(live.dtype), slot, axis=1),
            self.cache, cache1)
        tok = int(jnp.argmax(logits[0, -1]))
        req.out_tokens.append(tok)
        req.slot = slot
        self.active[slot] = req
        self.positions[slot] = len(req.prompt)
        self.last_token[slot] = tok
        return True

    def step(self) -> list[Request]:
        """Decode one token for every active slot; returns finished reqs."""
        if not self.active:
            return []
        tokens = jnp.asarray(self.last_token)
        pos = jnp.asarray(self.positions, dtype=jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, tokens,
                                          pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), dtype=np.int32)
        finished = []
        for slot, req in list(self.active.items()):
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            self.positions[slot] += 1
            self.last_token[slot] = tok
            if req.done or self.positions[slot] >= self.max_seq - 1:
                finished.append(req)
                del self.active[slot]
        return finished

    def run(self, requests: list[Request], max_steps: int = 10_000
            ) -> list[Request]:
        """Drive a queue of requests to completion (continuous batching).

        Admission scans the WHOLE pending queue each iteration, not just
        its head: a request that cannot be admitted right now (engine
        momentarily full, or oversized and never admissible) must not
        starve admissible requests behind it.  Requests that can never
        fit are rejected up front and are not returned as done.
        """
        pending = [r for r in requests if self.fits(r)]
        done: list[Request] = []
        steps = 0
        while (pending or self.active) and steps < max_steps:
            pending = [r for r in pending if not self.try_admit(r)]
            if not self.active:
                break  # nothing running and nothing admissible: idle-exit
            done.extend(self.step())
            steps += 1
        return done
